//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment for this repository has no crates.io access, so
//! the workspace vendors this minimal, API-compatible subset of `anyhow`
//! as a path dependency.  It covers exactly the surface the `taxbreak`
//! crate uses:
//!
//! * [`Error`] — an opaque, message-carrying error type that converts
//!   from any `std::error::Error + Send + Sync + 'static` via `?`;
//! * [`Result`] — `std::result::Result` with `Error` as the default
//!   error type;
//! * [`anyhow!`] — construct an [`Error`] from a format string;
//! * [`bail!`] — early-return an `Err(anyhow!(...))`;
//! * [`ensure!`] — `bail!` unless a condition holds.
//!
//! Deliberately not implemented (unused by this workspace): `Context`,
//! downcasting, source chains, and backtrace capture.  Swapping this
//! path dependency for the real `anyhow = "1"` is a one-line change in
//! `rust/Cargo.toml` and requires no source edits.

use std::fmt;

/// An opaque error carrying a rendered message.
///
/// Like the real `anyhow::Error`, this type intentionally does **not**
/// implement `std::error::Error`: that keeps the blanket
/// `impl<E: std::error::Error> From<E> for Error` coherent with the
/// reflexive `From<Error> for Error` used by `?`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct an error from a printable message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `std::result::Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn anyhow_formats() {
        let e = anyhow!("bad value {} at {}", 7, "site");
        assert_eq!(e.to_string(), "bad value 7 at site");
        assert_eq!(format!("{e:?}"), "bad value 7 at site");
        assert_eq!(format!("{e:#}"), "bad value 7 at site");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> crate::Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        assert!(parse("x").unwrap_err().to_string().contains("invalid"));
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> crate::Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            ensure!(x != 13);
            Ok(x)
        }
        assert_eq!(f(7).unwrap(), 7);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big: 101");
        assert_eq!(
            f(13).unwrap_err().to_string(),
            "condition failed: x != 13"
        );
    }
}
