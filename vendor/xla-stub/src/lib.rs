//! Build-time stub of the `xla` crate (the PJRT bindings of
//! [xla-rs](https://github.com/LaurentMazare/xla-rs)).
//!
//! The `taxbreak` crate's `real-pjrt` feature gates every code path that
//! drives a real PJRT runtime.  The offline build environment cannot
//! fetch (or link) the real `xla` crate and its `xla_extension` native
//! library, so this stub provides the exact API surface those gated
//! paths use — enough for `cargo check --features real-pjrt` to verify
//! the gated code compiles.
//!
//! Every runtime entry point fails with a descriptive [`XlaError`]
//! (`Engine::load` fails at `PjRtClient::cpu()`, before any compute is
//! attempted), so enabling the feature against this stub is build-valid
//! but not runnable.  To actually run real-PJRT mode, replace the
//! `vendor/xla-stub` path dependency in `rust/Cargo.toml` with the real
//! crate:
//!
//! ```toml
//! [dependencies]
//! xla = { version = "0.1", optional = true }
//! ```
//!
//! No source changes are required — the types and signatures here match
//! the subset of xla-rs the gated code calls.

use std::borrow::Borrow;
use std::path::Path;

/// Error type mirroring `xla::Error` for the used surface.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

/// Result alias matching the real crate's fallible APIs.
pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: built against the vendor/xla-stub placeholder — replace the \
         `xla` path dependency in rust/Cargo.toml with the real xla-rs crate \
         to run real-PJRT mode"
    ))
}

/// Element dtypes accepted by [`Literal::create_from_shape_and_untyped_data`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// A host-side tensor value (stub: shape bookkeeping only).
#[derive(Debug, Clone)]
pub struct Literal {
    elements: usize,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(data: &[T]) -> Literal {
        Literal {
            elements: data.len(),
        }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.elements {
            return Err(XlaError(format!(
                "reshape: {} elements into {dims:?}",
                self.elements
            )));
        }
        Ok(Literal { elements: self.elements })
    }

    /// Build a literal from raw bytes plus an explicit shape/dtype.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elements: usize = dims.iter().product();
        let width = match ty {
            ElementType::F32 | ElementType::S32 => 4,
        };
        if elements * width != data.len() {
            return Err(XlaError(format!(
                "shape {dims:?} needs {} bytes, got {}",
                elements * width,
                data.len()
            )));
        }
        Ok(Literal { elements })
    }

    /// Copy the literal out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Destructure a 2-tuple literal.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(unavailable("Literal::to_tuple2"))
    }

    /// Total element count.
    pub fn element_count(&self) -> usize {
        self.elements
    }
}

/// A parsed HLO module (stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto {}

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// An XLA computation built from an HLO module (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// A PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation into a loaded executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// A compiled, device-loaded executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals; returns per-device,
    /// per-output buffers (`result[0][0]` is the first output on the
    /// first device, as in xla-rs).
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    /// Materialize the buffer as a host literal, synchronously.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_bookkeeping_works() {
        let l = Literal::vec1(&[0f32; 8]);
        assert_eq!(l.element_count(), 8);
        assert!(l.reshape(&[2, 4]).is_ok());
        assert!(l.reshape(&[3, 3]).is_err());
        let ok = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[0u8; 16],
        )
        .unwrap();
        assert_eq!(ok.element_count(), 4);
    }

    #[test]
    fn runtime_entry_points_report_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla-stub"));
    }
}
