#!/usr/bin/env bash
# Regenerate the three committed bench-trajectory datapoints with the
# exact flag sets the CI smoke uses, so a refreshed file is directly
# comparable to the committed one (scripts/check_bench.py guards the
# wall-clock rates at 0.5x).  Run from the repo root on a quiet
# machine; commit the refreshed files when the rates move for a reason
# worth recording (docs/bench.md explains the trajectory semantics).
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release

cargo run --release -- bench-trace --runs 5 --out BENCH_trace.json

cargo run --release -- loadgen --models gpt2,olmoe-1b-7b --requests 60 \
  --rate 3000 --bench-out BENCH_loadgen.json

cargo run --release -- loadgen --models olmoe-1b-7b --requests 48 \
  --rate 2000 --devices 2 --streams 2 --kv-pages 128 \
  --bench-out BENCH_timeline.json

# Fault-path datapoint: the loadgen runs above are fault-free, so the
# resilience KPIs they carry must come out exactly zero — proof that
# the fault machinery costs nothing when --faults is disabled
# (scripts/check_bench.py pins the same invariant in CI, DESIGN.md
# s16).  Json prints 0.0 as "0", so the greps are exact.
for f in BENCH_loadgen.json BENCH_timeline.json; do
  grep -q '"shed_rate": 0,' "$f"
  grep -q '"retry_rate": 0,' "$f"
  grep -q '"deadline_miss_p99_us": 0,' "$f"
done

echo "refreshed BENCH_trace.json BENCH_loadgen.json BENCH_timeline.json"
