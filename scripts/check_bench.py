#!/usr/bin/env python3
"""Bench-trajectory regression guard.

Compares freshly measured BENCH_*.json datapoints against the committed
baselines and fails if any guarded wall-clock rate drops below
RATIO_FLOOR x the committed value.  Only higher-is-better throughput
rates are guarded: latency percentiles, HDBI and size ratios move for
legitimate modelling reasons and are pinned elsewhere (golden corpus,
fixed-point tests), not here.

Usage:
    scripts/check_bench.py BASELINE_DIR FRESH.json [FRESH.json ...]

Each fresh file is matched to BASELINE_DIR/<basename>.  Committed
values <= 0 are skipped (a zero floor guards nothing by design).
"""

import json
import sys

RATIO_FLOOR = 0.5

# Guarded fields per bench kind, as paths into the JSON object.
GUARDED = {
    "trace": [
        ("json_compact", "encode_events_per_s"),
        ("json_compact", "decode_events_per_s"),
        ("binary", "encode_events_per_s"),
        ("binary", "decode_events_per_s"),
    ],
    "loadgen": [
        ("throughput_tps",),
        ("replay", "events_per_s"),
        ("replay", "tokens_per_s"),
        ("online_decompose_events_per_sec",),
    ],
}

# Fault-path-off pins (DESIGN.md s16): the bench workloads run without
# --faults or deadlines, so the resilience KPIs must be *exactly* zero
# in every fresh datapoint — the fault machinery may cost nothing when
# disabled.  A nonzero value here means the clean path started
# shedding, retrying or missing deadlines on its own.
ZERO_WHEN_CLEAN = {
    "loadgen": [("shed_rate",), ("retry_rate",), ("deadline_miss_p99_us",)],
}


def lookup(obj, path):
    for key in path:
        if not isinstance(obj, dict) or key not in obj:
            return None
        obj = obj[key]
    return obj if isinstance(obj, (int, float)) else None


def check(baseline_path, fresh_path):
    with open(baseline_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    kind = base.get("bench")
    if kind not in GUARDED:
        raise SystemExit(f"{baseline_path}: unknown bench kind {kind!r}")
    if fresh.get("bench") != kind:
        raise SystemExit(
            f"{fresh_path}: bench kind {fresh.get('bench')!r} != baseline {kind!r}"
        )
    failures = []
    for path in GUARDED[kind]:
        dotted = ".".join(path)
        committed = lookup(base, path)
        if committed is None or committed <= 0:
            print(f"  skip {dotted}: no committed floor")
            continue
        measured = lookup(fresh, path)
        if measured is None:
            failures.append(f"{dotted}: missing from {fresh_path}")
            continue
        ratio = measured / committed
        status = "ok" if ratio >= RATIO_FLOOR else "FAIL"
        print(f"  {status} {dotted}: {measured:.6g} vs floor {committed:.6g} ({ratio:.2f}x)")
        if ratio < RATIO_FLOOR:
            failures.append(
                f"{dotted}: {measured:.6g} < {RATIO_FLOOR} x committed {committed:.6g}"
            )
    for path in ZERO_WHEN_CLEAN.get(kind, []):
        dotted = ".".join(path)
        measured = lookup(fresh, path)
        if measured is None:
            failures.append(f"{dotted}: missing from {fresh_path}")
            continue
        status = "ok" if measured == 0 else "FAIL"
        print(f"  {status} {dotted}: {measured:.6g} (must be 0 on fault-free runs)")
        if measured != 0:
            failures.append(
                f"{dotted}: {measured:.6g} != 0 on a fault-free bench run"
            )
    return failures


def main(argv):
    if len(argv) < 3:
        raise SystemExit(__doc__)
    baseline_dir, fresh_paths = argv[1], argv[2:]
    all_failures = []
    for fresh in fresh_paths:
        name = fresh.rsplit("/", 1)[-1]
        baseline = f"{baseline_dir}/{name}"
        print(f"{name}:")
        all_failures += [f"{name} {f}" for f in check(baseline, fresh)]
    if all_failures:
        print("\nbench regression guard failed:", file=sys.stderr)
        for f in all_failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nbench regression guard passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
