//! Quickstart: simulate one workload, run the TaxBreak two-phase
//! pipeline, and read the diagnosis.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use taxbreak::hardware::Platform;
use taxbreak::models;
use taxbreak::sim::{simulate, Workload};
use taxbreak::taxbreak::{analyze, report, ReplayConfig, SimReplayBackend};

fn main() -> anyhow::Result<()> {
    // 1. Pick a workload point: Llama-3.2-1B decoding 10 tokens over a
    //    512-token context on the H200 platform.
    let model = models::llama_1b();
    let platform = Platform::h200();
    let workload = Workload::decode(1, 512, 10);

    // 2. Capture a full-model trace (the Phase-1 input). In real
    //    deployments this would come from nsys/CUPTI; here the
    //    calibrated execution-stack simulator emits the same format.
    let trace = simulate(&model, &platform, &workload, 42);
    println!(
        "trace: {} kernels, {:.1} ms wall, {:.1} ms device-active",
        trace.kernel_count(),
        trace.e2e_us() / 1000.0,
        trace.device_active_us() / 1000.0
    );

    // 3. Run TaxBreak: Phase 1 (kernel DB + per-invocation T_Py) +
    //    Phase 2 (null-kernel floor + deduplicated isolation replay),
    //    then the Eq. 1-3 decomposition.
    let mut backend = SimReplayBackend::new(platform, 7);
    let analysis = analyze(&trace, &mut backend, &ReplayConfig::paper());

    print!(
        "{}",
        report::decomposition_table("TaxBreak decomposition", &analysis.decomposition).render()
    );
    print!(
        "{}",
        report::family_launch_table("per-family launch latency (us)", &analysis).render()
    );

    // 4. The decomposition vs. the aggregate baselines it improves on.
    println!(
        "aggregate framework tax [14]: {:.1} ms   TKLQT [30]: {:.1} ms",
        analysis.baselines.framework_tax_us / 1000.0,
        analysis.baselines.tklqt_us / 1000.0
    );

    // 5. Diagnosis: which layer of the stack to optimize.
    println!(
        "\ndiagnosis [{}]\n  {}",
        analysis.diagnosis.target.as_str(),
        analysis.diagnosis.rationale
    );
    Ok(())
}
