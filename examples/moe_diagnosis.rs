//! MoE diagnosis walkthrough: why aggregate metrics mislead, and how
//! the TaxBreak decomposition finds the real optimization target.
//!
//! Compares Llama-3.2-1B (dense) with OLMoE-1B/7B (similar *active*
//! parameter count) at the same decode point, showing: the fragmentation
//! statistics (Table II style), the misleading aggregate views, the
//! decomposition, and the resulting prescriptions.
//!
//! ```bash
//! cargo run --release --example moe_diagnosis
//! ```

use taxbreak::hardware::Platform;
use taxbreak::kernels::KernelDb;
use taxbreak::models;
use taxbreak::sim::{simulate, Workload};
use taxbreak::taxbreak::{analyze, ReplayConfig, SimReplayBackend};
use taxbreak::util::table::{count, Table};

fn main() -> anyhow::Result<()> {
    let platform = Platform::h100();
    let wl = Workload::decode(4, 2048, 10);

    let dense = models::llama_1b();
    let moe = models::olmoe();
    println!(
        "comparing {} ({:.1}B params) vs {} ({:.1}B total / {:.1}B active)\n",
        dense.display,
        dense.params_total() / 1e9,
        moe.display,
        moe.params_total() / 1e9,
        moe.params_active() / 1e9
    );

    let mut rows: Vec<(String, Vec<String>)> = Vec::new();
    let mut analyses = Vec::new();
    for model in [&dense, &moe] {
        let trace = simulate(model, &platform, &wl, 2026);
        let db = KernelDb::from_trace(&trace);
        let mut backend = SimReplayBackend::new(platform.clone(), 7);
        let a = analyze(&trace, &mut backend, &ReplayConfig::paper());
        rows.push((
            model.display.clone(),
            vec![
                count(db.total_invocations()),
                db.unique_names().to_string(),
                format!("{:.4}", db.diversity_ratio()),
                format!("{:.1}ms", trace.e2e_us() / 1000.0),
                format!("{:.1}%", 100.0 * a.decomposition.gpu_utilization()),
                format!("{:.2}", a.decomposition.hdbi()),
            ],
        ));
        analyses.push((model.display.clone(), a));
    }

    let mut t = Table::new(
        "decode BS=4/SL=2048 (m=10) on H100",
        &["model", "launches", "unique", "diversity", "e2e", "GPU util", "HDBI"],
    );
    for (name, cells) in &rows {
        let mut row = vec![name.clone()];
        row.extend(cells.iter().cloned());
        t.row(row);
    }
    print!("{}", t.render());

    println!("\n--- what the aggregate views say ---");
    for (name, a) in &analyses {
        println!(
            "{name}: framework tax {:.0} ms (residual — no attribution); \
             TKLQT {:.0} ms (launch path only)",
            a.baselines.framework_tax_us / 1000.0,
            a.baselines.tklqt_us / 1000.0
        );
    }

    println!("\n--- what TaxBreak attributes ---");
    for (name, a) in &analyses {
        let d = &a.decomposition;
        println!(
            "{name}: dFT {:.0} ms ({:.0}%) | dCT {:.0} ms ({:.0}%) | dKT {:.0} ms ({:.0}%)",
            d.dft_us() / 1000.0,
            100.0 * a.diagnosis.shares.0,
            d.dct_us / 1000.0,
            100.0 * a.diagnosis.shares.1,
            d.dkt_us / 1000.0,
            100.0 * a.diagnosis.shares.2,
        );
        println!("  -> [{}] {}", a.diagnosis.target.as_str(), a.diagnosis.rationale);
    }

    println!(
        "\nKey takeaway #2: the MoE dispatches {}x more kernels per token \
         from a *smaller* relative kernel vocabulary — fix the expert \
         dispatch loop (fusion/grouped experts), not the memory system.",
        (rows[1].1[0].replace(',', "").parse::<f64>().unwrap()
            / rows[0].1[0].replace(',', "").parse::<f64>().unwrap())
        .round()
    );
    Ok(())
}
