//! CPU single-thread sensitivity sweep (paper §VI, generalized).
//!
//! The paper compares two concrete hosts; this example sweeps a
//! continuum of single-thread speeds around them (same GPU) to show
//! that for host-bound workloads CPU speed is a first-order design
//! parameter — and how the effect is gated by HDBI.
//!
//! ```bash
//! cargo run --release --example cpu_sensitivity
//! ```

use taxbreak::hardware::Platform;
use taxbreak::models;
use taxbreak::sim::{simulate_summary, Workload};
use taxbreak::util::table::{ms, Table};

fn main() -> anyhow::Result<()> {
    let speeds = [0.8, 1.0, 1.15, 1.3, 1.5, 2.0];

    for (model, wl, label) in [
        (
            models::llama_1b(),
            Workload::decode(1, 512, 10),
            "Llama-3.2-1B decode BS=1/SL=512 (host-visible)",
        ),
        (
            models::llama_1b(),
            Workload::prefill(4, 2048),
            "Llama-3.2-1B prefill BS=4/SL=2048 (device-bound)",
        ),
        (
            models::qwen_moe(),
            Workload::decode(1, 512, 10),
            "Qwen1.5-MoE decode BS=1/SL=512 (host-bound)",
        ),
    ] {
        let mut t = Table::new(
            &format!("CPU single-thread sweep — {label}"),
            &["st speed", "e2e (ms)", "host busy (ms)", "device (ms)", "e2e gain vs 1.0x"],
        );
        let base = {
            let mut p = Platform::h100();
            p.cpu.st_speed = 1.0;
            simulate_summary(&model, &p, &wl, 2026).wall_us
        };
        for &s in &speeds {
            let mut p = Platform::h100();
            p.cpu.st_speed = s;
            p.cpu.name = format!("hypothetical x{s:.2} single-thread");
            let sum = simulate_summary(&model, &p, &wl, 2026);
            t.row(vec![
                format!("{s:.2}x"),
                ms(sum.wall_us / 1000.0),
                ms(sum.host_busy_us / 1000.0),
                ms(sum.device_active_us / 1000.0),
                format!("{:+.1}%", 100.0 * (1.0 - sum.wall_us / base)),
            ]);
        }
        print!("{}", t.render());
        println!();
    }

    println!(
        "Takeaway #5: host-bound workloads (MoE decode) convert CPU \
         single-thread speed into end-to-end latency almost 1:1, while \
         device-bound points are insensitive — additional *cores* would \
         help neither (eager dispatch is single-threaded)."
    );
    Ok(())
}
