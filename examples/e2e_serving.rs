//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Loads the AOT-compiled model (JAX L2 + Pallas L1, lowered to HLO
//! text by `make artifacts`), serves a batched synthetic request mix
//! through the rust coordinator (continuous batcher + paged-KV
//! admission over PJRT), reports latency/throughput KPIs (TTFT/TPOT,
//! tok/s), measures the real null-executable launch floor, and runs
//! the TaxBreak host/device split on the captured real trace.
//!
//! Requires the `real-pjrt` feature (declared via `required-features`
//! in rust/Cargo.toml, so the default build skips this example):
//!
//! ```bash
//! make artifacts && cargo run --release --features real-pjrt --example e2e_serving
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §Real-mode.

use std::path::Path;

use taxbreak::serving::run_server_demo;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    anyhow::ensure!(
        Path::new(&dir).join("index.json").exists(),
        "no artifacts at {dir}/ — run `make artifacts` first"
    );

    println!("=== dense (fused Pallas attention) ===");
    let dense = run_server_demo(Path::new(&dir), "dense_fused", 16, 4, 2026)?;
    print!("{}", dense.render());

    println!("\n=== MoE (grouped Pallas expert FFN) ===");
    let moe = run_server_demo(Path::new(&dir), "moe", 16, 4, 2026)?;
    print!("{}", moe.render());

    println!("\n=== comparison ===");
    println!(
        "throughput: dense {:.1} tok/s vs moe {:.1} tok/s ({:.2}x)",
        dense.throughput_tps(),
        moe.throughput_tps(),
        dense.throughput_tps() / moe.throughput_tps().max(1e-9)
    );
    println!(
        "TPOT: dense {:.2} ms vs moe {:.2} ms",
        dense.tpot_us.mean / 1000.0,
        moe.tpot_us.mean / 1000.0
    );
    println!(
        "HDBI (real): dense {:.2} vs moe {:.2}",
        dense.hdbi(),
        moe.hdbi()
    );
    println!(
        "real launch floor: dense-run {:.1} us / moe-run {:.1} us",
        dense.null_floor_us.mean, moe.null_floor_us.mean
    );
    Ok(())
}
