//! FlashAttention-2 ablation across BOTH modes (Fig. 9 + real PJRT).
//!
//! Simulated: Llama-3.2-1B eager vs fused attention on H200 through the
//! full TaxBreak pipeline.  Real: the `dense_eager` vs `dense_fused`
//! artifact variants (identical weights; eager jnp attention vs the
//! Pallas online-softmax kernel) served over PJRT — same fusion, real
//! numerics, measured wall-clock.
//!
//! ```bash
//! make artifacts && cargo run --release --features real-pjrt --example fa2_ablation
//! ```
//!
//! Without `--features real-pjrt` only the simulated half runs.

use taxbreak::hardware::Platform;
use taxbreak::models;
use taxbreak::sim::{simulate, Workload};
use taxbreak::taxbreak::{analyze, ReplayConfig, SimReplayBackend};
use taxbreak::util::table::{ms, ratio, Table};

fn main() -> anyhow::Result<()> {
    // --- simulated (paper Fig. 9) -------------------------------------
    let model = models::llama_1b();
    let platform = Platform::h200();
    let mut t = Table::new(
        "simulated: eager vs fused attention, Llama-3.2-1B on H200",
        &["config", "mode", "e2e", "T_orch", "T_dev", "HDBI", "kernels"],
    );
    for (bs, sl) in [(1usize, 512usize), (8, 2048)] {
        for fused in [false, true] {
            let wl = Workload::prefill(bs, sl).with_fused_attention(fused);
            let trace = simulate(&model, &platform, &wl, 2026);
            let mut backend = SimReplayBackend::new(platform.clone(), 7);
            let a = analyze(&trace, &mut backend, &ReplayConfig::fast());
            let d = &a.decomposition;
            t.row(vec![
                format!("BS={bs}/SL={sl}"),
                if fused { "fused" } else { "eager" }.to_string(),
                ms(d.e2e_us / 1000.0),
                ms(d.orchestration_us() / 1000.0),
                ms(d.device_active_us / 1000.0),
                ratio(d.hdbi()),
                d.n_kernels.to_string(),
            ]);
        }
    }
    print!("{}", t.render());

    // --- real (PJRT, Pallas kernel vs eager jnp) -----------------------
    real_half()
}

#[cfg(feature = "real-pjrt")]
fn real_half() -> anyhow::Result<()> {
    use std::path::Path;
    use taxbreak::serving::run_server_demo;

    let dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    if !Path::new(&dir).join("index.json").exists() {
        println!("\n(real-mode half skipped: run `make artifacts` to enable)");
        return Ok(());
    }
    println!("\nreal PJRT serving (identical weights, 12 requests):");
    let eager = run_server_demo(Path::new(&dir), "dense_eager", 12, 4, 7)?;
    let fused = run_server_demo(Path::new(&dir), "dense_fused", 12, 4, 7)?;
    let mut rt = Table::new(
        "real: eager jnp attention vs Pallas fused kernel",
        &["variant", "wall (ms)", "tok/s", "TPOT (ms)", "device (ms)", "HDBI"],
    );
    for (name, s) in [("eager", &eager), ("fused (Pallas)", &fused)] {
        rt.row(vec![
            name.to_string(),
            ms(s.wall_us / 1000.0),
            format!("{:.1}", s.throughput_tps()),
            ms(s.tpot_us.mean / 1000.0),
            ms(s.device_us / 1000.0),
            ratio(s.hdbi()),
        ]);
    }
    print!("{}", rt.render());
    println!(
        "\nNote: at toy scale (d=128, S<=64) fusion overhead can outweigh \
         the saved score-matrix traffic — the win grows with S^2, which \
         the simulated half shows at SL=2048 (Key Takeaway #4)."
    );
    Ok(())
}

#[cfg(not(feature = "real-pjrt"))]
fn real_half() -> anyhow::Result<()> {
    println!(
        "\n(real-mode half skipped: rebuild with --features real-pjrt \
         and run `make artifacts` to enable)"
    );
    Ok(())
}
