"""Grouped expert-FFN Pallas kernel vs the per-expert eager oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.moe import expert_ffn, vmem_bytes
from compile.kernels.ref import expert_ffn_ref

jax.config.update("jax_platform_name", "cpu")


def _weights(seed, e, t, d, hidden):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(keys[0], (e, t, d))
    w1 = jax.random.normal(keys[1], (e, d, hidden)) / np.sqrt(d)
    b1 = jax.random.normal(keys[2], (e, hidden)) * 0.1
    w2 = jax.random.normal(keys[3], (e, hidden, d)) / np.sqrt(hidden)
    b2 = jax.random.normal(keys[4], (e, d)) * 0.1
    return x, w1, b1, w2, b2


class TestExpertFfn:
    @pytest.mark.parametrize("e,t,d,hidden", [(1, 4, 8, 16), (4, 32, 16, 32),
                                              (8, 16, 32, 64)])
    def test_matches_ref(self, e, t, d, hidden):
        x, w1, b1, w2, b2 = _weights(0, e, t, d, hidden)
        got = expert_ffn(x, w1, b1, w2, b2)
        want = expert_ffn_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_experts_are_independent(self):
        # Perturbing expert j's weights must not change expert i's output.
        x, w1, b1, w2, b2 = _weights(1, 4, 8, 16, 32)
        base = expert_ffn(x, w1, b1, w2, b2)
        w1_mod = w1.at[3].set(w1[3] * 10.0)
        mod = expert_ffn(x, w1_mod, b1, w2, b2)
        np.testing.assert_allclose(base[:3], mod[:3], rtol=1e-6, atol=1e-6)
        assert not np.allclose(base[3], mod[3])

    def test_zero_input_gives_bias_path(self):
        x, w1, b1, w2, b2 = _weights(2, 2, 4, 8, 16)
        x = jnp.zeros_like(x)
        got = expert_ffn(x, w1, b1, w2, b2)
        want = expert_ffn_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        e=st.integers(1, 8),
        t=st.sampled_from([1, 4, 16, 64]),
        d=st.sampled_from([4, 8, 32]),
        hidden=st.sampled_from([8, 16, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, e, t, d, hidden, seed):
        x, w1, b1, w2, b2 = _weights(seed, e, t, d, hidden)
        got = expert_ffn(x, w1, b1, w2, b2)
        want = expert_ffn_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_rejects_bad_shapes(self):
        x, w1, b1, w2, b2 = _weights(3, 2, 4, 8, 16)
        with pytest.raises(ValueError):
            expert_ffn(x, w1[:, :, :8], b1, w2, b2)
        with pytest.raises(ValueError):
            expert_ffn(x, w1, b1[:, :4], w2, b2)

    def test_vmem_estimate_positive_and_monotone(self):
        assert 0 < vmem_bytes(16, 32, 64) < vmem_bytes(64, 32, 64)
