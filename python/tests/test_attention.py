"""Pallas fused attention vs the pure-jnp oracle (the core L1 signal)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    flash_attention,
    mxu_flops_per_step,
    vmem_bytes,
)
from compile.kernels.ref import attention_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


def _qkv(seed, b, h, sq, sk, d, dtype=jnp.float32):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        _rand(k0, (b, h, sq, d), dtype),
        _rand(k1, (b, h, sk, d), dtype),
        _rand(k2, (b, h, sk, d), dtype),
    )


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5
    )


class TestCausalPrefill:
    @pytest.mark.parametrize("b,h,s,d", [(1, 1, 32, 16), (2, 4, 64, 32), (1, 2, 128, 64)])
    def test_matches_ref(self, b, h, s, d):
        q, k, v = _qkv(0, b, h, s, s, d)
        got = flash_attention(q, k, v, causal=True)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, **_tol(jnp.float32))

    def test_first_row_is_v0(self):
        # Causal: position 0 can only attend to itself.
        q, k, v = _qkv(1, 1, 1, 32, 32, 16)
        got = flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(got[0, 0, 0], v[0, 0, 0], rtol=1e-5, atol=1e-5)

    def test_block_shape_invariance(self):
        q, k, v = _qkv(2, 1, 2, 64, 64, 32)
        a = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
        b_ = flash_attention(q, k, v, causal=True, block_q=32, block_k=64)
        np.testing.assert_allclose(a, b_, rtol=1e-5, atol=1e-5)

    def test_scale_applied(self):
        # Uniform q,k => softmax uniform over prefix, so row i == mean(v[:i+1]).
        d = 16
        q = jnp.ones((1, 1, 8, d))
        k = jnp.ones((1, 1, 8, d))
        v = jnp.arange(8, dtype=jnp.float32)[None, None, :, None].repeat(d, -1)
        got = flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
        for i in range(8):
            np.testing.assert_allclose(got[0, 0, i, 0], np.mean(np.arange(i + 1)),
                                       rtol=1e-5, atol=1e-5)


class TestDecodeMasking:
    @pytest.mark.parametrize("kv_len", [1, 7, 32, 100, 128])
    def test_kv_len_mask_matches_ref(self, kv_len):
        q, k, v = _qkv(3, 2, 2, 1, 128, 32)
        got = flash_attention(q, k, v, kv_len=kv_len, causal=False, block_q=1)
        want = attention_ref(q, k, v, kv_len=kv_len, causal=False)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_tail_is_ignored(self):
        # Garbage past kv_len must not change the output.
        q, k, v = _qkv(4, 1, 1, 1, 64, 16)
        k_dirty = k.at[:, :, 32:].set(1e6)
        v_dirty = v.at[:, :, 32:].set(-1e6)
        a = flash_attention(q, k, v, kv_len=32, causal=False, block_q=1)
        b = flash_attention(q, k_dirty, v_dirty, kv_len=32, causal=False, block_q=1)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_zero_len_emits_zeros(self):
        q, k, v = _qkv(5, 1, 1, 1, 32, 16)
        got = flash_attention(q, k, v, kv_len=0, causal=False, block_q=1)
        np.testing.assert_array_equal(np.asarray(got), np.zeros_like(got))


class TestDtypes:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_roundtrip(self, dtype):
        q, k, v = _qkv(6, 1, 2, 32, 32, 16, dtype)
        got = flash_attention(q, k, v, causal=True)
        assert got.dtype == dtype
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32),
            np.asarray(want, dtype=np.float32),
            **_tol(dtype),
        )


class TestHypothesisSweep:
    """hypothesis sweeps of the kernel's shape/dtype space vs ref."""

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        sq_blocks=st.integers(1, 4),
        d=st.sampled_from([8, 16, 32, 64]),
        block=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    )
    def test_fused_matches_ref(self, b, h, sq_blocks, d, block, causal, seed, dtype):
        s = sq_blocks * block
        q, k, v = _qkv(seed, b, h, s, s, d, dtype)
        got = flash_attention(q, k, v, causal=causal, block_q=block, block_k=block)
        want = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32),
            np.asarray(want, dtype=np.float32),
            **_tol(dtype),
        )

    @settings(max_examples=15, deadline=None)
    @given(
        sk_blocks=st.integers(1, 8),
        block=st.sampled_from([8, 16]),
        kv_frac=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**16),
    )
    def test_decode_kv_len_sweep(self, sk_blocks, block, kv_frac, seed):
        sk = sk_blocks * block
        kv_len = int(round(kv_frac * sk))
        q, k, v = _qkv(seed, 1, 2, 1, sk, 16)
        got = flash_attention(q, k, v, kv_len=kv_len, causal=False,
                              block_q=1, block_k=block)
        want = attention_ref(q, k, v, kv_len=kv_len, causal=False)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestValidation:
    def test_rejects_mismatched_shapes(self):
        q, k, v = _qkv(7, 1, 1, 16, 16, 8)
        with pytest.raises(ValueError):
            flash_attention(q, k[:, :, :8], v, causal=True)

    def test_rejects_non_divisible_blocks(self):
        q, k, v = _qkv(8, 1, 1, 48, 48, 8)
        with pytest.raises(ValueError):
            flash_attention(q, k, v, block_q=32, block_k=32)


class TestStructuralEstimates:
    def test_vmem_fits_tpu_budget(self):
        # DESIGN.md §8: default tiles must sit far below 16 MB VMEM.
        assert vmem_bytes(128, 128, 64) < 16 * 2**20 / 8

    def test_mxu_flops_formula(self):
        assert mxu_flops_per_step(128, 128, 64) == 2 * 128 * 128 * 64 * 2

    def test_vmem_monotone_in_blocks(self):
        assert vmem_bytes(64, 64, 64) < vmem_bytes(128, 64, 64) < vmem_bytes(
            128, 128, 64
        )
