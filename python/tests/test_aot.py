"""AOT pipeline tests: lowering output, weights serialization, manifests."""

import json
import os
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

TINY = M.ModelConfig(
    vocab=32, d_model=16, n_layers=1, n_heads=2, head_dim=8,
    ffn_hidden=32, max_seq=16,
)


class TestHloText:
    def test_null_kernel_lowers(self):
        hlo, io = aot.lower_null()
        assert "HloModule" in hlo
        assert io["inputs"][0]["shape"] == [8]

    def test_prefill_lowers_to_parseable_text(self):
        M.VARIANTS["_tiny"] = TINY
        try:
            hlo, io = aot.lower_prefill(TINY, 1, 8)
        finally:
            del M.VARIANTS["_tiny"]
        assert "HloModule" in hlo
        # No serialized-proto artifacts; plain text.
        assert hlo.isprintable() or "\n" in hlo
        assert io["inputs"][-1]["name"] == "tokens"
        assert io["outputs"][0]["name"] == "logits"

    def test_no_topk_largest_attribute(self):
        """xla_extension 0.5.1's HLO parser rejects topk(largest=true);
        the MoE router must lower through iterative argmax instead."""
        moe_tiny = M.ModelConfig(
            vocab=32, d_model=16, n_layers=1, n_heads=2, head_dim=8,
            max_seq=16, n_experts=4, top_k=2, expert_hidden=16,
        )
        hlo, _ = aot.lower_prefill(moe_tiny, 1, 8)
        assert "topk(" not in hlo, "lax.top_k leaked into HLO"

    def test_decode_manifest_has_cache_pos_tokens_tail(self):
        hlo, io = aot.lower_decode(TINY, 1)
        names = [s["name"] for s in io["inputs"]]
        assert names[-3:] == ["cache", "pos", "tokens"]
        assert "HloModule" in hlo


class TestWeights:
    def test_params_bin_layout(self):
        with tempfile.TemporaryDirectory() as d:
            table = aot.write_params(TINY, "tiny", d, seed=0)
            bin_path = os.path.join(d, "tiny.params.bin")
            size = os.path.getsize(bin_path)
            assert size == table["total_bytes"]
            # Offsets are contiguous and ordered.
            offset = 0
            for e in table["params"]:
                assert e["offset"] == offset
                assert e["bytes"] == 4 * int(np.prod(e["shape"]))
                offset += e["bytes"]
            # First tensor round-trips.
            params = M.init_params(TINY, seed=0)
            e0 = table["params"][0]
            with open(bin_path, "rb") as f:
                raw = f.read(e0["bytes"])
            got = np.frombuffer(raw, dtype="<f4").reshape(e0["shape"])
            np.testing.assert_array_equal(got, np.asarray(params[e0["name"]]))

    def test_params_deterministic_per_seed(self):
        a = M.init_params(TINY, seed=1)
        b = M.init_params(TINY, seed=1)
        c = M.init_params(TINY, seed=2)
        np.testing.assert_array_equal(a["tok_emb"], b["tok_emb"])
        assert not np.array_equal(np.asarray(a["tok_emb"]), np.asarray(c["tok_emb"]))


class TestIndexMerge:
    def test_variant_rebuild_preserves_other_entries(self):
        with tempfile.TemporaryDirectory() as d:
            index_path = os.path.join(d, "index.json")
            with open(index_path, "w") as f:
                json.dump(
                    {
                        "artifacts": [
                            "null_kernel",
                            "dense_fused_prefill_b1_s32",
                            "moe_decode_b1",
                        ],
                        "params": ["dense_fused.params", "moe.params"],
                    },
                    f,
                )
            M.VARIANTS["_tiny"] = TINY
            try:
                # Monkeypatch the bucket grids down for speed.
                old_p, old_d = aot.PREFILL_BUCKETS, aot.DECODE_BUCKETS
                aot.PREFILL_BUCKETS, aot.DECODE_BUCKETS = [(1, 8)], [1]
                try:
                    index = aot.build(d, ["_tiny"], seed=0)
                finally:
                    aot.PREFILL_BUCKETS, aot.DECODE_BUCKETS = old_p, old_d
            finally:
                del M.VARIANTS["_tiny"]
            assert "dense_fused_prefill_b1_s32" in index["artifacts"]
            assert "moe_decode_b1" in index["artifacts"]
            assert "_tiny_prefill_b1_s8" in index["artifacts"]
            assert "dense_fused.params" in index["params"]


class TestPallasLowering:
    def test_fused_variant_contains_no_mosaic_custom_call(self):
        """interpret=True must lower Pallas to plain HLO — a Mosaic
        custom-call would be unrunnable on the CPU PJRT client."""
        hlo, _ = aot.lower_prefill(M.ModelConfig(
            vocab=32, d_model=16, n_layers=1, n_heads=2, head_dim=8,
            ffn_hidden=32, max_seq=16, attention_impl="fused",
        ), 1, 8)
        assert "mosaic" not in hlo.lower()
