"""L2 model invariants: shapes, cache semantics, prefill/decode agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

SMALL_DENSE = M.ModelConfig(
    vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16,
    ffn_hidden=64, max_seq=32,
)
SMALL_EAGER = M.ModelConfig(
    vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16,
    ffn_hidden=64, max_seq=32, attention_impl="eager",
)
SMALL_MOE = M.ModelConfig(
    vocab=64, d_model=32, n_layers=2, n_heads=2, head_dim=16,
    max_seq=32, n_experts=4, top_k=2, expert_hidden=32,
)


def _tokens(cfg, b, s, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, cfg.vocab)


class TestParams:
    @pytest.mark.parametrize("cfg", [SMALL_DENSE, SMALL_MOE])
    def test_init_matches_specs(self, cfg):
        params = M.init_params(cfg, seed=0)
        specs = M.param_specs(cfg)
        assert set(params) == {n for n, _ in specs}
        for name, shape in specs:
            assert params[name].shape == shape, name

    def test_spec_order_deterministic(self):
        a = [n for n, _ in M.param_specs(SMALL_MOE)]
        b = [n for n, _ in M.param_specs(SMALL_MOE)]
        assert a == b

    def test_moe_has_router_dense_does_not(self):
        dense = {n for n, _ in M.param_specs(SMALL_DENSE)}
        moe = {n for n, _ in M.param_specs(SMALL_MOE)}
        assert not any("router" in n for n in dense)
        assert any("router" in n for n in moe)

    def test_norm_gains_init_to_one(self):
        params = M.init_params(SMALL_DENSE)
        np.testing.assert_array_equal(np.asarray(params["l0.ln1"]), 1.0)


class TestPrefill:
    @pytest.mark.parametrize("cfg", [SMALL_DENSE, SMALL_MOE])
    @pytest.mark.parametrize("b,s", [(1, 8), (2, 16), (4, 32)])
    def test_shapes(self, cfg, b, s):
        params = M.init_params(cfg)
        logits, cache = M.prefill(cfg, params, _tokens(cfg, b, s))
        assert logits.shape == (b, s, cfg.vocab)
        assert cache.shape == M.cache_shape(cfg, b)

    def test_cache_tail_is_zero(self):
        params = M.init_params(SMALL_DENSE)
        _, cache = M.prefill(SMALL_DENSE, params, _tokens(SMALL_DENSE, 1, 8))
        np.testing.assert_array_equal(np.asarray(cache[:, :, :, 8:]), 0.0)

    def test_causality(self):
        # Changing a later token must not affect earlier logits.
        cfg = SMALL_DENSE
        params = M.init_params(cfg)
        t = _tokens(cfg, 1, 16)
        la, _ = M.prefill(cfg, params, t)
        t2 = t.at[0, 12].set((t[0, 12] + 1) % cfg.vocab)
        lb, _ = M.prefill(cfg, params, t2)
        np.testing.assert_allclose(la[0, :12], lb[0, :12], rtol=1e-5, atol=1e-5)
        assert not np.allclose(la[0, 12:], lb[0, 12:])

    def test_fused_matches_eager_variant(self):
        # Same weights, fused vs eager attention — Fig. 9's invariant:
        # the optimization changes performance, not numerics.
        params = M.init_params(SMALL_DENSE)
        t = _tokens(SMALL_DENSE, 2, 16)
        lf, cf = M.prefill(SMALL_DENSE, params, t)
        le, ce = M.prefill(SMALL_EAGER, params, t)
        np.testing.assert_allclose(lf, le, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(cf, ce, rtol=1e-4, atol=1e-4)


class TestDecode:
    @pytest.mark.parametrize("cfg", [SMALL_DENSE, SMALL_EAGER, SMALL_MOE])
    def test_decode_matches_prefill_teacher_forcing(self, cfg):
        """Step-by-step decode over a prompt must reproduce prefill logits."""
        params = M.init_params(cfg)
        b, s = 2, 12
        t = _tokens(cfg, b, s, seed=3)
        logits_pre, cache_pre = M.prefill(cfg, params, t)

        cache = jnp.zeros(M.cache_shape(cfg, b), dtype=jnp.float32)
        for pos in range(s):
            logits_step, cache = M.decode_step(
                cfg, params, cache, jnp.array([pos], dtype=jnp.int32), t[:, pos]
            )
            np.testing.assert_allclose(
                np.asarray(logits_step),
                np.asarray(logits_pre[:, pos]),
                rtol=2e-3, atol=2e-3,
                err_msg=f"pos={pos}",
            )
        np.testing.assert_allclose(
            np.asarray(cache[:, :, :, :s]),
            np.asarray(cache_pre[:, :, :, :s]),
            rtol=2e-3, atol=2e-3,
        )

    def test_decode_continues_from_prefill_cache(self):
        cfg = SMALL_DENSE
        params = M.init_params(cfg)
        t = _tokens(cfg, 1, 10, seed=4)
        _, cache = M.prefill(cfg, params, t[:, :8])
        # Decode steps 8, 9 from the prefill cache == prefill over all 10.
        logits_all, _ = M.prefill(cfg, params, t)
        logits8, cache = M.decode_step(
            cfg, params, cache, jnp.array([8], dtype=jnp.int32), t[:, 8]
        )
        logits9, _ = M.decode_step(
            cfg, params, cache, jnp.array([9], dtype=jnp.int32), t[:, 9]
        )
        np.testing.assert_allclose(logits8, logits_all[:, 8], rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(logits9, logits_all[:, 9], rtol=2e-3, atol=2e-3)

    def test_decode_updates_only_pos(self):
        cfg = SMALL_DENSE
        params = M.init_params(cfg)
        cache0 = jnp.zeros(M.cache_shape(cfg, 1), dtype=jnp.float32)
        tok = jnp.array([5], dtype=jnp.int32)
        _, cache1 = M.decode_step(cfg, params, cache0, jnp.array([3], jnp.int32), tok)
        changed = np.any(np.asarray(cache1) != 0.0, axis=(0, 1, 2, 4, 5))
        assert changed[3]
        assert not changed[:3].any() and not changed[4:].any()

    def test_moe_routing_is_topk(self):
        # Router mixes exactly top_k experts: zeroing a non-selected
        # expert's weights leaves the layer output unchanged for tokens
        # that did not select it. Indirect check: outputs differ across
        # tokens routed differently, and logits are finite.
        cfg = SMALL_MOE
        params = M.init_params(cfg)
        logits, _ = M.prefill(cfg, params, _tokens(cfg, 1, 16, seed=5))
        assert np.isfinite(np.asarray(logits)).all()


class TestNullKernel:
    def test_identity(self):
        x = jnp.arange(8.0)
        np.testing.assert_array_equal(np.asarray(M.null_kernel(x)), np.asarray(x))
