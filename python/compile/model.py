"""Layer-2 JAX model: small dense / MoE transformer for the real-mode path.

The rust coordinator serves these models through PJRT (see
``rust/src/runtime``): ``prefill`` and ``decode_step`` are AOT-lowered by
``aot.py`` to HLO text, once per (variant, batch, seq) bucket.  Weights
are *inputs* (not baked constants) so the HLO stays small; ``aot.py``
serializes them to a flat binary the rust side memory-maps.

Three variants map to the paper's workload axes:

* ``dense_fused`` — dense transformer, Pallas fused attention (the
  FA2-on-TPU kernel from ``kernels.attention``).
* ``dense_eager`` — identical weights/architecture, eager attention from
  ``kernels.ref`` (materializes the score matrix).  The Fig. 9 pair.
* ``moe``         — top-k routed MoE FFN via the grouped Pallas expert
  kernel (``kernels.moe``), the fragmentation workload of Table II.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.attention import flash_attention
from .kernels.moe import expert_ffn
from .kernels.ref import attention_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture descriptor. Defaults give a ~0.6 M-param model whose
    HLO artifacts stay small enough for text interchange."""

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    head_dim: int = 32
    ffn_hidden: int = 512
    max_seq: int = 128
    n_experts: int = 0  # 0 => dense FFN
    top_k: int = 2
    expert_hidden: int = 256
    attention_impl: str = "fused"  # "fused" | "eager"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim


DENSE_FUSED = ModelConfig(attention_impl="fused")
DENSE_EAGER = ModelConfig(attention_impl="eager")
MOE = ModelConfig(n_experts=4, top_k=2, attention_impl="fused")

VARIANTS: Dict[str, ModelConfig] = {
    "dense_fused": DENSE_FUSED,
    "dense_eager": DENSE_EAGER,
    "moe": MOE,
}


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Deterministic (name, shape) list — the flat weights-file order."""
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.max_seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"l{i}."
        specs += [
            (p + "ln1", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.qkv_dim)),
            (p + "wk", (cfg.d_model, cfg.qkv_dim)),
            (p + "wv", (cfg.d_model, cfg.qkv_dim)),
            (p + "wo", (cfg.qkv_dim, cfg.d_model)),
            (p + "ln2", (cfg.d_model,)),
        ]
        if cfg.is_moe:
            specs += [
                (p + "router", (cfg.d_model, cfg.n_experts)),
                (p + "exp_w1", (cfg.n_experts, cfg.d_model, cfg.expert_hidden)),
                (p + "exp_b1", (cfg.n_experts, cfg.expert_hidden)),
                (p + "exp_w2", (cfg.n_experts, cfg.expert_hidden, cfg.d_model)),
                (p + "exp_b2", (cfg.n_experts, cfg.d_model)),
            ]
        else:
            specs += [
                (p + "ffn_w1", (cfg.d_model, cfg.ffn_hidden)),
                (p + "ffn_b1", (cfg.ffn_hidden,)),
                (p + "ffn_w2", (cfg.ffn_hidden, cfg.d_model)),
                (p + "ffn_b2", (cfg.d_model,)),
            ]
    specs += [
        ("ln_f", (cfg.d_model,)),
        ("lm_head", (cfg.d_model, cfg.vocab)),
    ]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Scaled-normal init; norm gains start at 1."""
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jax.Array] = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params[name] = jnp.ones(shape, dtype=jnp.float32)
        elif name.endswith(("_b1", "_b2")) or ".ffn_b" in name:
            params[name] = jnp.zeros(shape, dtype=jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            params[name] = (
                jax.random.normal(sub, shape, dtype=jnp.float32)
                * (1.0 / jnp.sqrt(fan_in))
            )
    return params


def cache_shape(cfg: ModelConfig, batch: int) -> Tuple[int, ...]:
    """(layers, k/v, batch, max_seq, heads, head_dim) KV cache."""
    return (cfg.n_layers, 2, batch, cfg.max_seq, cfg.n_heads, cfg.head_dim)


def _rmsnorm(x: jax.Array, g: jax.Array) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * g


def _split_heads(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(B, S, H*D) -> (B, H, S, D)."""
    b, s, _ = x.shape
    return x.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    """(B, H, S, D) -> (B, S, H*D)."""
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def _attention(cfg: ModelConfig, q, k, v, *, kv_len=None, causal: bool):
    if cfg.attention_impl == "fused":
        return flash_attention(q, k, v, kv_len=kv_len, causal=causal)
    return attention_ref(q, k, v, kv_len=kv_len, causal=causal)


def _top_k(probs: jax.Array, k: int):
    """Iterative argmax top-k.

    ``lax.top_k`` lowers to an HLO ``topk(..., largest=true)`` custom
    attribute that xla_extension 0.5.1's text parser rejects; k rounds
    of argmax + one-hot masking lower to plain reduce/select/gather ops
    that round-trip cleanly (k <= 2 for the artifact models).
    """
    vals, idxs = [], []
    p = probs
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)  # (T,)
        v = jnp.take_along_axis(p, i[:, None], axis=-1)[:, 0]
        vals.append(v)
        idxs.append(i)
        p = p * (1.0 - jax.nn.one_hot(i, p.shape[-1], dtype=p.dtype))
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def _moe_ffn(cfg: ModelConfig, params, prefix: str, x2d: jax.Array) -> jax.Array:
    """Top-k routed MoE FFN over tokens x2d: (T, d) -> (T, d).

    Routing uses dense combine (every expert computes every token via
    the grouped Pallas kernel; router weights zero the non-selected
    pairs).  For the tiny artifact models E is small, and this keeps
    shapes static for AOT lowering.
    """
    logits = x2d @ params[prefix + "router"]  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = _top_k(probs, cfg.top_k)  # (T, K)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(topi, cfg.n_experts, dtype=x2d.dtype)  # (T, K, E)
    w_full = jnp.einsum("tk,tke->te", topv, onehot)  # (T, E)

    xe = jnp.broadcast_to(x2d[None], (cfg.n_experts,) + x2d.shape)
    outs = expert_ffn(
        xe,
        params[prefix + "exp_w1"],
        params[prefix + "exp_b1"],
        params[prefix + "exp_w2"],
        params[prefix + "exp_b2"],
    )  # (E, T, d)
    return jnp.einsum("te,etd->td", w_full, outs)


def _dense_ffn(params, prefix: str, x2d: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x2d @ params[prefix + "ffn_w1"] + params[prefix + "ffn_b1"])
    return h @ params[prefix + "ffn_w2"] + params[prefix + "ffn_b2"]


def _ffn(cfg: ModelConfig, params, prefix: str, x: jax.Array) -> jax.Array:
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    y = _moe_ffn(cfg, params, prefix, x2d) if cfg.is_moe else _dense_ffn(
        params, prefix, x2d
    )
    return y.reshape(b, s, d)


def prefill(cfg: ModelConfig, params: Dict[str, jax.Array], tokens: jax.Array):
    """Process the prompt; return (logits (B,S,vocab), cache).

    The cache is sized at ``cfg.max_seq`` so decode artifacts are
    bucket-independent: positions >= S are zero and masked by decode's
    kv_len.
    """
    b, s = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :s]
    cache = jnp.zeros(cache_shape(cfg, b), dtype=jnp.float32)

    for i in range(cfg.n_layers):
        p = f"l{i}."
        h = _rmsnorm(x, params[p + "ln1"])
        q = _split_heads(h @ params[p + "wq"], cfg)
        k = _split_heads(h @ params[p + "wk"], cfg)
        v = _split_heads(h @ params[p + "wv"], cfg)

        # Persist k/v into the fixed-size cache at positions [0, S).
        kv = jnp.stack([k, v])  # (2, B, H, S, D)
        kv = kv.transpose(0, 1, 3, 2, 4)  # (2, B, S, H, D)
        cache = lax.dynamic_update_slice(cache, kv[None], (i, 0, 0, 0, 0, 0))

        att = _attention(cfg, q, k, v, causal=True)
        x = x + _merge_heads(att) @ params[p + "wo"]
        x = x + _ffn(cfg, params, p, _rmsnorm(x, params[p + "ln2"]))

    x = _rmsnorm(x, params["ln_f"])
    logits = x @ params["lm_head"]
    return logits, cache


def decode_step(
    cfg: ModelConfig,
    params: Dict[str, jax.Array],
    cache: jax.Array,
    pos: jax.Array,
    tokens: jax.Array,
):
    """One autoregressive step.

    Args:
      cache: (L, 2, B, max_seq, H, D) from prefill / previous steps.
      pos: scalar i32 — index the new token occupies (== #valid tokens).
      tokens: (B,) i32 current input token per sequence.

    Returns (logits (B, vocab), updated cache).
    """
    b = tokens.shape[0]
    pos = jnp.asarray(pos, dtype=jnp.int32).reshape(())
    pos_emb = lax.dynamic_slice(params["pos_emb"], (pos, 0), (1, cfg.d_model))
    x = params["tok_emb"][tokens][:, None, :] + pos_emb[None]  # (B, 1, d)

    for i in range(cfg.n_layers):
        p = f"l{i}."
        h = _rmsnorm(x, params[p + "ln1"])
        q = _split_heads(h @ params[p + "wq"], cfg)  # (B, H, 1, D)
        k = _split_heads(h @ params[p + "wk"], cfg)
        v = _split_heads(h @ params[p + "wv"], cfg)

        kv = jnp.stack([k, v]).transpose(0, 1, 3, 2, 4)  # (2, B, 1, H, D)
        cache = lax.dynamic_update_slice(cache, kv[None], (i, 0, 0, pos, 0, 0))

        # Attend over the cache prefix [0, pos]; tail masked via kv_len.
        k_all = lax.dynamic_slice(
            cache, (i, 0, 0, 0, 0, 0), (1, 1, b, cfg.max_seq, cfg.n_heads, cfg.head_dim)
        )[0, 0].transpose(0, 2, 1, 3)  # (B, H, max_seq, D)
        v_all = lax.dynamic_slice(
            cache, (i, 1, 0, 0, 0, 0), (1, 1, b, cfg.max_seq, cfg.n_heads, cfg.head_dim)
        )[0, 0].transpose(0, 2, 1, 3)

        att = _attention(cfg, q, k_all, v_all, kv_len=pos + 1, causal=False)
        x = x + _merge_heads(att) @ params[p + "wo"]
        x = x + _ffn(cfg, params, p, _rmsnorm(x, params[p + "ln2"]))

    x = _rmsnorm(x, params["ln_f"])
    logits = (x @ params["lm_head"])[:, 0, :]  # (B, vocab)
    return logits, cache


def null_kernel(x: jax.Array) -> jax.Array:
    """The paper's null-kernel floor probe: minimal device work."""
    return x + 0.0
