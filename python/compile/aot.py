"""AOT pipeline: lower the L2 model to HLO text + weights for rust/PJRT.

Interchange is HLO *text*, not serialized ``HloModuleProto``: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 rust crate links) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly — see /opt/xla-example/load_hlo/.

Outputs, per (variant, entry, bucket):

* ``artifacts/<name>.hlo.txt``       — the lowered computation
* ``artifacts/<name>.manifest.json`` — positional input/output specs
* ``artifacts/<variant>.params.bin`` — flat little-endian f32 weights
* ``artifacts/<variant>.params.json``— name/shape/offset table
* ``artifacts/index.json``           — everything above, for discovery

Weights are passed as *inputs* (not folded constants) so HLO text stays
small and one weights file serves every bucket of a variant.

Usage: ``python -m compile.aot --out-dir ../artifacts [--variant moe]``
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import struct
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

# (batch, seq) buckets compiled for prefill; decode is bucketed by batch
# only (the KV cache is always max_seq-sized).
PREFILL_BUCKETS: List[Tuple[int, int]] = [(1, 32), (1, 64), (4, 32), (4, 64)]
DECODE_BUCKETS: List[int] = [1, 4]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name: str, shape: Tuple[int, ...], dtype: str) -> Dict:
    return {"name": name, "shape": list(shape), "dtype": dtype}


def _param_structs(cfg: M.ModelConfig):
    return [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in M.param_specs(cfg)
    ]


def _make_prefill_fn(cfg: M.ModelConfig):
    names = [n for n, _ in M.param_specs(cfg)]

    def fn(*args):
        params = dict(zip(names, args[: len(names)]))
        tokens = args[len(names)]
        return M.prefill(cfg, params, tokens)

    return fn


def _make_decode_fn(cfg: M.ModelConfig):
    names = [n for n, _ in M.param_specs(cfg)]

    def fn(*args):
        params = dict(zip(names, args[: len(names)]))
        cache, pos, tokens = args[len(names) :]
        return M.decode_step(cfg, params, cache, pos, tokens)

    return fn


def lower_prefill(cfg: M.ModelConfig, batch: int, seq: int) -> Tuple[str, Dict]:
    fn = _make_prefill_fn(cfg)
    args = _param_structs(cfg) + [jax.ShapeDtypeStruct((batch, seq), jnp.int32)]
    lowered = jax.jit(fn).lower(*args)
    inputs = [
        _spec(n, s, "f32") for n, s in M.param_specs(cfg)
    ] + [_spec("tokens", (batch, seq), "i32")]
    outputs = [
        _spec("logits", (batch, seq, cfg.vocab), "f32"),
        _spec("cache", M.cache_shape(cfg, batch), "f32"),
    ]
    return to_hlo_text(lowered), {"inputs": inputs, "outputs": outputs}


def lower_decode(cfg: M.ModelConfig, batch: int) -> Tuple[str, Dict]:
    fn = _make_decode_fn(cfg)
    n_params = len(M.param_specs(cfg))
    args = _param_structs(cfg) + [
        jax.ShapeDtypeStruct(M.cache_shape(cfg, batch), jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    ]
    # Donate the KV cache: lowers to input_output_alias in the HLO, so
    # PJRT updates the cache in place instead of copying it every step
    # (EXPERIMENTS.md §Perf L2.1).
    lowered = jax.jit(fn, donate_argnums=(n_params,)).lower(*args)
    inputs = (
        [_spec(n, s, "f32") for n, s in M.param_specs(cfg)]
        + [
            _spec("cache", M.cache_shape(cfg, batch), "f32"),
            _spec("pos", (1,), "i32"),
            _spec("tokens", (batch,), "i32"),
        ]
    )
    outputs = [
        _spec("logits", (batch, cfg.vocab), "f32"),
        _spec("cache", M.cache_shape(cfg, batch), "f32"),
    ]
    return to_hlo_text(lowered), {"inputs": inputs, "outputs": outputs}


def lower_null() -> Tuple[str, Dict]:
    """The null-kernel floor probe (paper §III-B / Table III analog)."""
    lowered = jax.jit(M.null_kernel).lower(jax.ShapeDtypeStruct((8,), jnp.float32))
    return to_hlo_text(lowered), {
        "inputs": [_spec("x", (8,), "f32")],
        "outputs": [_spec("y", (8,), "f32")],
    }


def write_params(cfg: M.ModelConfig, variant: str, out_dir: str, seed: int) -> Dict:
    """Serialize weights: flat LE f32 bin + offset table json."""
    params = M.init_params(cfg, seed=seed)
    entries = []
    offset = 0
    bin_path = os.path.join(out_dir, f"{variant}.params.bin")
    with open(bin_path, "wb") as f:
        for name, shape in M.param_specs(cfg):
            arr = np.asarray(params[name], dtype="<f4")
            assert tuple(arr.shape) == tuple(shape), (name, arr.shape, shape)
            data = arr.tobytes()
            entries.append(
                {
                    "name": name,
                    "shape": list(shape),
                    "offset": offset,
                    "bytes": len(data),
                }
            )
            f.write(data)
            offset += len(data)
    table = {"variant": variant, "total_bytes": offset, "params": entries}
    with open(os.path.join(out_dir, f"{variant}.params.json"), "w") as f:
        json.dump(table, f, indent=1)
    return table


def _config_dict(cfg: M.ModelConfig) -> Dict:
    return dataclasses.asdict(cfg)


def build(out_dir: str, variants: List[str], seed: int = 0) -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    # Merge with any existing index so `--variant X` refreshes one
    # variant without orphaning the others' entries.
    index = {"artifacts": [], "params": []}
    index_path = os.path.join(out_dir, "index.json")
    if os.path.exists(index_path):
        with open(index_path) as f:
            old = json.load(f)
        index["artifacts"] = [
            a for a in old.get("artifacts", [])
            if a != "null_kernel" and a.rsplit("_prefill", 1)[0].rsplit("_decode", 1)[0]
            not in variants
        ]
        index["params"] = [
            p for p in old.get("params", []) if p.removesuffix(".params") not in variants
        ]

    hlo, io = lower_null()
    name = "null_kernel"
    _write_artifact(out_dir, name, hlo, io, entry="null", variant="", batch=0, seq=0)
    index["artifacts"].append(name)

    for variant in variants:
        cfg = M.VARIANTS[variant]
        write_params(cfg, variant, out_dir, seed)
        index["params"].append(f"{variant}.params")

        for batch, seq in PREFILL_BUCKETS:
            name = f"{variant}_prefill_b{batch}_s{seq}"
            print(f"lowering {name} ...", flush=True)
            hlo, io = lower_prefill(cfg, batch, seq)
            _write_artifact(
                out_dir, name, hlo, io,
                entry="prefill", variant=variant, batch=batch, seq=seq,
                config=_config_dict(cfg),
            )
            index["artifacts"].append(name)

        for batch in DECODE_BUCKETS:
            name = f"{variant}_decode_b{batch}"
            print(f"lowering {name} ...", flush=True)
            hlo, io = lower_decode(cfg, batch)
            _write_artifact(
                out_dir, name, hlo, io,
                entry="decode", variant=variant, batch=batch, seq=cfg.max_seq,
                config=_config_dict(cfg),
            )
            index["artifacts"].append(name)

    with open(os.path.join(out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    return index


def _write_artifact(
    out_dir: str,
    name: str,
    hlo: str,
    io: Dict,
    *,
    entry: str,
    variant: str,
    batch: int,
    seq: int,
    config: Dict | None = None,
):
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(hlo)
    manifest = {
        "name": name,
        "entry": entry,
        "variant": variant,
        "batch": batch,
        "seq": seq,
        "params_file": f"{variant}.params.bin" if variant else "",
        "inputs": io["inputs"],
        "outputs": io["outputs"],
    }
    if config is not None:
        manifest["config"] = config
    with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--variant",
        action="append",
        choices=sorted(M.VARIANTS),
        help="restrict to specific variants (default: all)",
    )
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    variants = args.variant or sorted(M.VARIANTS)
    index = build(args.out_dir, variants, seed=args.seed)
    print(f"wrote {len(index['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
