"""Grouped expert-FFN Pallas kernel.

MoE layers fragment execution into many small expert GEMMs — the very
behaviour TaxBreak diagnoses (Table II: 8-11x more kernels per token).
On the device side we implement the expert compute as ONE grouped
kernel: the Pallas grid iterates over experts, and each grid step runs
the expert's two MXU matmuls over its token tile held in VMEM.

This is the TPU analog of grouped/batched expert GEMms (e.g.
FlashDMoE): instead of E separate cuBLAS launches, a single kernel with
an expert-indexed BlockSpec — exactly the "reduce N directly" remedy the
paper's diagnostic prescribes for launch-floor-dominated workloads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expert_ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One expert: o = gelu(x @ w1 + b1) @ w2 + b2.

    ``x_ref``: (tokens, d) VMEM tile — this expert's token group.
    ``w1_ref``: (d, hidden), ``w2_ref``: (hidden, d) weight tiles.
    """
    x = x_ref[...].astype(jnp.float32)
    h = jnp.dot(x, w1_ref[...].astype(jnp.float32)) + b1_ref[...].astype(jnp.float32)
    h = jax.nn.gelu(h)
    o = jnp.dot(h, w2_ref[...].astype(jnp.float32)) + b2_ref[...].astype(jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)


def expert_ffn(
    x: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    *,
    interpret: bool = True,
):
    """Run every expert's FFN over its token tile in one grouped kernel.

    Args:
      x:  (experts, tokens, d) — token tile per expert (dense routing:
          every expert sees all tokens; the router mask zeroes the
          non-selected combinations afterwards).
      w1: (experts, d, hidden); b1: (experts, hidden)
      w2: (experts, hidden, d); b2: (experts, d)

    Returns:
      (experts, tokens, d) expert outputs.
    """
    e, t, d = x.shape
    hidden = w1.shape[-1]
    if w1.shape != (e, d, hidden) or w2.shape != (e, hidden, d):
        raise ValueError(f"weight shape mismatch: {w1.shape} / {w2.shape}")
    if b1.shape != (e, hidden) or b2.shape != (e, d):
        raise ValueError(f"bias shape mismatch: {b1.shape} / {b2.shape}")

    out = pl.pallas_call(
        _expert_ffn_kernel,
        grid=(e,),
        in_specs=[
            pl.BlockSpec((None, t, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, d, hidden), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, hidden), lambda i: (i, 0)),
            pl.BlockSpec((None, hidden, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((None, t, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, t, d), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)
    return out


def vmem_bytes(tokens: int, d: int, hidden: int, dtype_bytes: int = 4) -> int:
    """Structural VMEM footprint of one expert grid step."""
    return (
        tokens * d * dtype_bytes  # x tile
        + d * hidden * dtype_bytes  # w1
        + hidden * dtype_bytes  # b1
        + hidden * d * dtype_bytes  # w2
        + d * dtype_bytes  # b2
        + tokens * hidden * 4  # h intermediate
        + tokens * d * 4  # out
    )
