"""Pure-jnp oracles for the Pallas kernels.

These are the "eager" reference implementations: they materialize the
full N x N attention matrix (the behaviour FlashAttention-2 removes) and
run each expert FFN as separate dense ops.  pytest checks the Pallas
kernels against these with ``assert_allclose`` across shape/dtype sweeps
(hypothesis), and the L2 model's ``attention_impl="eager"`` variant uses
them directly — giving the real-mode Fig. 9 comparison.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    kv_len=None,
    causal: bool = True,
):
    """Eager attention: scores -> mask -> softmax -> weighted sum.

    Shapes as in ``flash_attention``: q (B,H,Sq,D), k/v (B,H,Sk,D).
    Materializes the (Sq, Sk) score matrix per head — the HBM
    round-trip FA2 eliminates.
    """
    b, h, sq, d = q.shape
    sk = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale

    k_idx = jnp.arange(sk)[None, :]
    if kv_len is None:
        valid = jnp.ones((1, sk), dtype=bool)
    else:
        valid = k_idx < jnp.asarray(kv_len, dtype=jnp.int32).reshape(())
    mask = jnp.broadcast_to(valid, (sq, sk))
    if causal:
        q_idx = jnp.arange(sq)[:, None]
        mask = jnp.logical_and(mask, k_idx <= q_idx)
    s = jnp.where(mask[None, None], s, NEG_INF)

    # Guard fully-masked rows against NaN, matching the kernel.
    row_any = jnp.any(mask, axis=-1)[None, None, :, None]
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(row_any, p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def expert_ffn_ref(x, w1, b1, w2, b2):
    """Per-expert eager FFN: E separate (gelu(x@w1+b1))@w2+b2 chains."""
    outs = []
    for i in range(x.shape[0]):
        h = jax.nn.gelu(
            x[i].astype(jnp.float32) @ w1[i].astype(jnp.float32)
            + b1[i].astype(jnp.float32)
        )
        outs.append(h @ w2[i].astype(jnp.float32) + b2[i].astype(jnp.float32))
    return jnp.stack(outs).astype(x.dtype)
