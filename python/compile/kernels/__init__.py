"""Layer-1 Pallas kernels (build-time only).

Kernels are authored for the TPU execution model (VMEM tiles + MXU
matmuls via BlockSpec) but always lowered with ``interpret=True`` so the
resulting HLO runs on the CPU PJRT client that the rust coordinator
embeds.  Real-TPU efficiency is estimated structurally in DESIGN.md §8.
"""

from .attention import flash_attention
from .moe import expert_ffn

__all__ = ["flash_attention", "expert_ffn"]
