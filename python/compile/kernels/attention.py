"""Fused attention Pallas kernel (FlashAttention-2 re-derived for TPU).

The paper's Fig. 9 ablation contrasts eager multi-kernel attention with
FlashAttention-2.  FA2 is a CUDA warp/threadblock kernel; per
DESIGN.md §3 we re-derive its core insight for the TPU execution model:

* the N x N score matrix is never materialized to HBM — each q-tile
  holds online-softmax state (running max ``m``, normalizer ``l`` and
  the weighted accumulator ``acc``) while streaming kv-tiles;
* CUDA shared memory becomes VMEM tiles expressed through ``BlockSpec``;
* tensor-core WMMA becomes MXU-shaped ``jnp.dot`` over
  (block_q, d) x (d, block_k) tiles with f32 accumulation;
* the CUDA grid over (batch*heads, q-blocks) becomes the Pallas grid,
  and the kv stream is the innermost ``fori_loop``.

Always lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness path and real
TPU efficiency is estimated structurally (DESIGN.md §8).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attention_kernel(
    q_ref,
    k_ref,
    v_ref,
    kv_len_ref,
    o_ref,
    *,
    block_q: int,
    block_k: int,
    seq_k: int,
    causal: bool,
    scale: float,
):
    """One (batch*head, q-block) grid step of the fused attention.

    ``q_ref``: (block_q, d) VMEM tile of queries.
    ``k_ref``/``v_ref``: (seq_k, d) — the kv stream for this head; tiles
      of ``block_k`` rows are loaded per inner iteration (HBM->VMEM
      schedule; on real TPU the BlockSpec pipeline double-buffers this).
    ``kv_len_ref``: (1,) i32 — valid kv length (decode masks the tail of
      a fixed-size cache; prefill passes seq_k).
    ``o_ref``: (block_q, d) output tile.
    """
    q_blk = pl.program_id(1)
    d = q_ref.shape[-1]

    q = q_ref[...].astype(jnp.float32) * scale
    kv_len = kv_len_ref[0]

    num_kv_blocks = pl.cdiv(seq_k, block_k)
    if causal:
        # Blocks strictly above the diagonal contribute nothing; the
        # upper bound for this q-block is the last kv-block that
        # intersects row (q_blk+1)*block_q - 1.
        hi = lax.min(
            num_kv_blocks,
            lax.div((q_blk + 1) * block_q + block_k - 1, block_k),
        )
    else:
        hi = num_kv_blocks

    def body(kv_blk, carry):
        m_prev, l_prev, acc_prev = carry
        k_tile = pl.load(k_ref, (pl.dslice(kv_blk * block_k, block_k), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(kv_blk * block_k, block_k), slice(None)))

        # MXU matmul: (block_q, d) x (d, block_k).
        s = jnp.dot(q, k_tile.astype(jnp.float32).T)

        # Validity / causal masks on global indices.
        k_idx = kv_blk * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = k_idx < kv_len
        if causal:
            q_idx = q_blk * block_q + lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            mask = jnp.logical_and(mask, k_idx <= q_idx)
        s = jnp.where(mask, s, NEG_INF)

        # Online softmax update (FA2 eq. 10-12).
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        # Re-mask explicitly: on a fully-masked tile m_new == NEG_INF and
        # exp(s - m_new) would be exp(0) == 1 for the masked entries.
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[:, None] + jnp.dot(
            p, v_tile.astype(jnp.float32)
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d), dtype=jnp.float32)
    m, l, acc = lax.fori_loop(0, hi, body, (m0, l0, acc0))

    # Fully-masked rows (kv_len == 0, or causal rows past kv_len) have
    # l == 0; emit zeros rather than NaN.
    l_safe = jnp.where(l == 0.0, 1.0, l)
    o_ref[...] = (acc / l_safe[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    kv_len=None,
    causal: bool = True,
    block_q: int = 32,
    block_k: int = 32,
    interpret: bool = True,
):
    """Fused multi-head attention.

    Args:
      q: (batch, heads, seq_q, d)
      k, v: (batch, heads, seq_k, d)
      kv_len: optional scalar i32 — number of valid kv positions
        (decode over a fixed-size cache); defaults to ``seq_k``.
      causal: apply a causal mask on absolute positions (prefill).
      block_q / block_k: VMEM tile shapes (the HBM<->VMEM schedule).
      interpret: must stay True for CPU-PJRT lowering.

    Returns:
      (batch, heads, seq_q, d) attention output in q's dtype.
    """
    batch, heads, seq_q, d = q.shape
    seq_k = k.shape[2]
    if k.shape != (batch, heads, seq_k, d) or v.shape != k.shape:
        raise ValueError(f"shape mismatch: q={q.shape} k={k.shape} v={v.shape}")
    block_q = min(block_q, seq_q)
    block_k = min(block_k, seq_k)
    if seq_q % block_q != 0 or seq_k % block_k != 0:
        raise ValueError(
            f"seq_q={seq_q} / seq_k={seq_k} must divide block_q={block_q} / "
            f"block_k={block_k}"
        )
    scale = 1.0 / math.sqrt(d)

    if kv_len is None:
        kv_len = jnp.full((1,), seq_k, dtype=jnp.int32)
    else:
        kv_len = jnp.asarray(kv_len, dtype=jnp.int32).reshape((1,))

    bh = batch * heads
    q3 = q.reshape(bh, seq_q, d)
    k3 = k.reshape(bh, seq_k, d)
    v3 = v.reshape(bh, seq_k, d)

    kernel = functools.partial(
        _attention_kernel,
        block_q=block_q,
        block_k=block_k,
        seq_k=seq_k,
        causal=causal,
        scale=scale,
    )

    grid = (bh, seq_q // block_q)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, seq_k, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, seq_k, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1,), lambda b, i: (0,)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq_q, d), q.dtype),
        interpret=interpret,
    )(q3, k3, v3, kv_len)
    return out.reshape(batch, heads, seq_q, d)


def vmem_bytes(block_q: int, block_k: int, d: int, dtype_bytes: int = 4) -> int:
    """Structural VMEM footprint of one grid step (DESIGN.md §8).

    q-tile + k-tile + v-tile + acc + (m, l) state; used by the perf
    report to estimate real-TPU residency/double-buffering headroom.
    """
    return (
        block_q * d * dtype_bytes  # q tile
        + block_k * d * dtype_bytes  # k tile
        + block_k * d * dtype_bytes  # v tile
        + block_q * d * 4  # f32 accumulator
        + 2 * block_q * 4  # m, l
    )


def mxu_flops_per_step(block_q: int, block_k: int, d: int) -> int:
    """MXU FLOPs per inner kv iteration: QK^T + PV matmuls."""
    return 2 * block_q * block_k * d * 2
