//! Deterministic pseudo-random numbers (SplitMix64).
//!
//! The simulator draws per-kernel host/launch latency jitter from
//! family-dependent distributions; determinism matters both for test
//! reproducibility and for TaxBreak's Phase-2 replay semantics (replaying
//! the same kernel key must observe the same latency distribution).
//! `fork` derives independent streams (per kernel, per run) so replay
//! order can change without perturbing other streams.

/// SplitMix64: tiny, fast, passes BigCrush for this use, and — unlike
/// `rand` — available offline.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box-Muller variate.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng {
            state: seed ^ 0x9E3779B97F4A7C15,
            spare: None,
        }
    }

    /// Derive an independent stream keyed by `id` — deterministic,
    /// order-insensitive.
    pub fn fork(&self, id: u64) -> Rng {
        // Mix the base seed with the stream id through one extra round.
        let mut z = self.state ^ id.wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        Rng {
            state: z ^ (z >> 31),
            spare: None,
        }
    }

    /// Derive a stream from a string key (kernel names, model ids).
    pub fn fork_str(&self, key: &str) -> Rng {
        self.fork(fnv1a(key.as_bytes()))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn std_normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // u1 in (0, 1] so ln is finite.
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.std_normal()
    }

    /// Log-normal parameterized by the *target* median and a shape
    /// parameter sigma (latency tails are right-skewed; the paper's
    /// Table IV p95s sit well above p50).
    pub fn lognormal_med(&mut self, median: f64, sigma: f64) -> f64 {
        median * (sigma * self.std_normal()).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// FNV-1a — stable string hash for stream derivation and kernel keys.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.uniform(2.0, 3.0);
            assert!((2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal(5.0, 2.0)).collect();
        let m = crate::util::stats::mean(&xs);
        let s = crate::util::stats::stddev(&xs);
        assert!((m - 5.0).abs() < 0.1, "mean {m}");
        assert!((s - 2.0).abs() < 0.1, "std {s}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..20001).map(|_| r.lognormal_med(4.7, 0.1)).collect();
        let med = crate::util::stats::median(&xs);
        assert!((med - 4.7).abs() < 0.05, "median {med}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn fork_is_stable_and_independent() {
        let base = Rng::new(99);
        let mut f1a = base.fork(1);
        let mut f1b = base.fork(1);
        let mut f2 = base.fork(2);
        assert_eq!(f1a.next_u64(), f1b.next_u64());
        assert_ne!(f1a.next_u64(), f2.next_u64());
    }

    #[test]
    fn fork_str_matches_same_key() {
        let base = Rng::new(5);
        assert_eq!(
            base.fork_str("gemm_kernel").next_u64(),
            base.fork_str("gemm_kernel").next_u64()
        );
        assert_ne!(
            base.fork_str("a").next_u64(),
            base.fork_str("b").next_u64()
        );
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
