//! Tiny argv parser (clap is unavailable offline).
//!
//! Grammar: `prog [subcommand] [--flag] [--key value | --key=value] [positional...]`.
//! Typed getters with defaults; unknown-option detection is the caller's
//! choice via [`Args::finish`].

use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Clone, Default)]
pub struct Args {
    positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: BTreeSet<String>,
    /// Option/flag names the caller has asked about — for unknown-option
    /// diagnostics in `finish`.
    consumed: BTreeSet<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) = iter.next_if(|n| !n.starts_with("--")) {
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string());
                }
            } else {
                out.positionals.push(arg);
            }
        }
        out
    }

    /// Parse the process argv (skipping the program name).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Remove and return the first positional (subcommand-style).
    pub fn shift(&mut self) -> Option<String> {
        if self.positionals.is_empty() {
            None
        } else {
            Some(self.positionals.remove(0))
        }
    }

    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.insert(name.to_string());
        self.flags.contains(name)
    }

    pub fn opt(&mut self, name: &str) -> Option<&str> {
        self.consumed.insert(name.to_string());
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_string(&mut self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&mut self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an unsigned integer, got '{v}'")),
        }
    }

    pub fn opt_u64(&mut self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an unsigned integer, got '{v}'")),
        }
    }

    pub fn opt_f64(&mut self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Comma-separated list option (empty segments dropped).
    pub fn opt_list(&mut self, name: &str) -> Vec<String> {
        self.opt(name)
            .map(|v| {
                v.split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Error on any option/flag that was provided but never queried.
    pub fn finish(&self) -> anyhow::Result<()> {
        for k in self.options.keys().chain(self.flags.iter()) {
            if !self.consumed.contains(k) {
                anyhow::bail!("unknown option --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn positionals_and_shift() {
        let mut a = args("repro fig5 --out x.json");
        assert_eq!(a.shift().as_deref(), Some("repro"));
        assert_eq!(a.shift().as_deref(), Some("fig5"));
        assert_eq!(a.shift(), None);
    }

    #[test]
    fn options_space_and_equals() {
        let mut a = args("--model llama-1b --bs=4");
        assert_eq!(a.opt("model"), Some("llama-1b"));
        assert_eq!(a.opt_usize("bs", 1).unwrap(), 4);
    }

    #[test]
    fn flags_vs_options() {
        let mut a = args("--verbose --seed 9 --json");
        assert!(a.flag("verbose"));
        assert!(a.flag("json"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 9);
    }

    #[test]
    fn flag_before_flag_not_eaten() {
        // "--a --b": --a must be a flag, not an option consuming "--b".
        let mut a = args("--a --b");
        assert!(a.flag("a") && a.flag("b"));
    }

    #[test]
    fn defaults() {
        let mut a = args("");
        assert_eq!(a.opt_usize("n", 7).unwrap(), 7);
        assert_eq!(a.opt_f64("x", 1.5).unwrap(), 1.5);
        assert_eq!(a.opt_string("s", "d"), "d");
    }

    #[test]
    fn bad_numbers_error() {
        let mut a = args("--n abc");
        assert!(a.opt_usize("n", 0).is_err());
    }

    #[test]
    fn list_option() {
        let mut a = args("--models gpt2,llama-1b, olmoe");
        // (argv can't contain free spaces, but trimming still applies)
        assert_eq!(a.opt_list("models"), vec!["gpt2", "llama-1b"]);
        let mut b = args("--models gpt2,llama-1b,olmoe");
        assert_eq!(b.opt_list("models"), vec!["gpt2", "llama-1b", "olmoe"]);
    }

    #[test]
    fn finish_catches_unknown() {
        let mut a = args("--known 1 --typo 2");
        let _ = a.opt("known");
        assert!(a.finish().is_err());
        let _ = a.opt("typo");
        assert!(a.finish().is_ok());
    }
}
