//! Descriptive statistics: means, percentiles, confidence intervals.
//!
//! The paper reports averages over R=150 runs with 95% CIs, and
//! per-family p50/p95 launch latencies (Tables III/IV); this module is
//! the shared implementation.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample variance (n-1 denominator); 0.0 for n < 2.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile in [0, 100] with linear interpolation between order
/// statistics (the numpy default). 0.0 for empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_of_sorted(&sorted, p)
}

/// Percentile over an already-sorted slice (hot-path variant; avoids
/// the re-sort when many percentiles are taken from one sample).
pub fn percentile_of_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Half-width of the 95% confidence interval on the mean (normal
/// approximation, z = 1.96 — R = 150 in the paper, comfortably normal).
pub fn ci95_half_width(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * stddev(xs) / (xs.len() as f64).sqrt()
}

/// Five-number-plus summary of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p5: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
    pub ci95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p5: 0.0,
                p50: 0.0,
                p95: 0.0,
                p99: 0.0,
                max: 0.0,
                ci95: 0.0,
            };
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            min: sorted[0],
            p5: percentile_of_sorted(&sorted, 5.0),
            p50: percentile_of_sorted(&sorted, 50.0),
            p95: percentile_of_sorted(&sorted, 95.0),
            p99: percentile_of_sorted(&sorted, 99.0),
            max: sorted[sorted.len() - 1],
            ci95: ci95_half_width(xs),
        }
    }
}

/// Streaming mean/variance (Welford) — used by the hot serving path to
/// avoid retaining samples.
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((variance(&xs) - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(Summary::of(&[]).n, 0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        // numpy.percentile([1,2,3,4], 25) == 1.75
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert_eq!(median(&xs), 5.0);
    }

    #[test]
    fn percentile_clamps_out_of_range() {
        let xs = [1.0, 2.0];
        assert_eq!(percentile(&xs, -5.0), 1.0);
        assert_eq!(percentile(&xs, 150.0), 2.0);
    }

    #[test]
    fn summary_matches_parts() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-12);
        assert!(s.p5 < s.p50 && s.p50 < s.p95 && s.p95 < s.p99);
        // numpy.percentile(1..=100, 99) == 99.01
        assert!((s.p99 - 99.01).abs() < 1e-12);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a: Vec<f64> = (0..10).map(|i| (i % 5) as f64).collect();
        let b: Vec<f64> = (0..1000).map(|i| (i % 5) as f64).collect();
        assert!(ci95_half_width(&b) < ci95_half_width(&a));
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.5, 2.5, 3.5, 10.0, -4.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-12);
    }

    #[test]
    fn single_sample() {
        assert_eq!(stddev(&[3.0]), 0.0);
        let s = Summary::of(&[3.0]);
        assert_eq!((s.min, s.p50, s.max), (3.0, 3.0, 3.0));
    }
}
