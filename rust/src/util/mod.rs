//! Support substrates.
//!
//! The offline build environment has no crates.io access (only the
//! in-repo `vendor/` path crates), so the usual ecosystem crates
//! (serde_json, clap, rand, criterion, proptest) are unavailable.
//! Their roles are filled by the small, fully-tested modules here
//! (DESIGN.md §6.9).

pub mod bench;
pub mod cli;
pub mod intern;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
