//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! Provides seeded case generation with automatic input logging on
//! failure.  Used by the coordinator invariant tests (routing, batching,
//! KV state) and the taxbreak decomposition invariants.
//!
//! ```
//! use taxbreak::util::prop::forall;
//! use taxbreak::prop_assert;
//! forall("sum is commutative", 100, |g| {
//!     let a = g.f64_in(-1e6, 1e6);
//!     let b = g.f64_in(-1e6, 1e6);
//!     prop_assert!(g, (a + b - (b + a)).abs() < 1e-9, "a={a} b={b}");
//!     true
//! });
//! ```

use crate::util::rng::Rng;

/// Per-case generator: wraps an RNG and records a description of the
/// drawn values so failures print their inputs.
pub struct Gen {
    rng: Rng,
    pub case: usize,
    log: Vec<String>,
    failed: Option<String>,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let v = lo + self.rng.below(hi - lo + 1);
        self.log.push(format!("usize[{lo}..={hi}]={v}"));
        v
    }

    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.log.push(format!("u64={v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform(lo, hi);
        self.log.push(format!("f64[{lo}..{hi}]={v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.log.push(format!("bool={v}"));
        v
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len());
        self.log.push(format!("choice#{i}"));
        &xs[i]
    }

    /// A vector of f64 samples.
    pub fn vec_f64(&mut self, len_lo: usize, len_hi: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.usize_in(len_lo, len_hi);
        (0..n).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    /// Record a failure message (used by `prop_assert!`).
    pub fn fail(&mut self, msg: String) {
        if self.failed.is_none() {
            self.failed = Some(msg);
        }
    }

    pub fn raw_rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Fixed base seed: "taxbreak 2026".
const SEED: u64 = 0x7A6B_5EED_2026;

/// Run `cases` random cases of `property`. Panics (test failure) on the
/// first returning `false` or calling [`Gen::fail`], printing the case
/// seed and drawn values for reproduction.
pub fn forall<F: FnMut(&mut Gen) -> bool>(name: &str, cases: usize, mut property: F) {
    forall_seeded(name, SEED, cases, &mut property);
}

/// `forall` with an explicit base seed.
pub fn forall_seeded<F: FnMut(&mut Gen) -> bool>(
    name: &str,
    seed: u64,
    cases: usize,
    property: &mut F,
) {
    let base = Rng::new(seed);
    for case in 0..cases {
        let mut g = Gen {
            rng: base.fork(case as u64),
            case,
            log: Vec::new(),
            failed: None,
        };
        let ok = property(&mut g);
        if !ok || g.failed.is_some() {
            panic!(
                "property '{name}' failed at case {case} (seed={seed}):\n  drawn: {}\n  {}",
                g.log.join(", "),
                g.failed.unwrap_or_else(|| "returned false".to_string()),
            );
        }
    }
}

/// Assert inside a property with context; records the message in the Gen
/// so `forall` reports it with the drawn inputs.
#[macro_export]
macro_rules! prop_assert {
    ($g:expr, $cond:expr, $($fmt:tt)*) => {
        if !$cond {
            $g.fail(format!($($fmt)*));
            return false;
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall_seeded("count", 1, 50, &mut |g| {
            count += 1;
            g.usize_in(0, 10) <= 10
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails' failed")]
    fn failing_property_panics_with_inputs() {
        forall_seeded("fails", 2, 100, &mut |g| g.usize_in(0, 9) < 9);
    }

    #[test]
    fn gen_is_deterministic_per_case() {
        let mut first = Vec::new();
        forall_seeded("collect", 3, 10, &mut |g| {
            first.push(g.u64());
            true
        });
        let mut second = Vec::new();
        forall_seeded("collect", 3, 10, &mut |g| {
            second.push(g.u64());
            true
        });
        assert_eq!(first, second);
    }

    #[test]
    fn choice_and_vec() {
        forall_seeded("choice", 4, 20, &mut |g| {
            let xs = [1, 2, 3];
            let c = *g.choice(&xs);
            let v = g.vec_f64(0, 5, -1.0, 1.0);
            xs.contains(&c) && v.len() <= 5 && v.iter().all(|x| (-1.0..1.0).contains(x))
        });
    }

    #[test]
    fn prop_assert_macro_reports() {
        let result = std::panic::catch_unwind(|| {
            forall_seeded("macro", 5, 10, &mut |g| {
                let x = g.usize_in(0, 100);
                prop_assert!(g, x < 1000, "x was {x}");
                true
            });
        });
        assert!(result.is_ok());
    }
}
