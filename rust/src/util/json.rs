//! Minimal JSON value model, parser and serializer.
//!
//! Used for trace files, AOT artifact manifests, run configs and report
//! output.  Object key order is preserved (insertion order) so emitted
//! files diff cleanly run-to-run.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects keep insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or replace) a key in an object. Panics on non-objects —
    /// builder misuse is a programming error.
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Json {
        match self {
            Json::Obj(entries) => {
                let value = value.into();
                if let Some(e) = entries.iter_mut().find(|(k, _)| k == key) {
                    e.1 = value;
                } else {
                    entries.push((key.to_string(), value));
                }
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Chainable builder form of [`Json::set`].
    pub fn with(mut self, key: &str, value: impl Into<Json>) -> Json {
        self.set(key, value);
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing helper.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key '{key}' in JSON object"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Typed convenience getters for object fields.
    pub fn f64_of(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("key '{key}' is not a number"))
    }

    pub fn usize_of(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("key '{key}' is not an unsigned integer"))
    }

    pub fn str_of(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("key '{key}' is not a string"))
    }

    pub fn arr_of(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("key '{key}' is not an array"))
    }

    /// Parse a JSON document.
    pub fn parse(input: &str) -> anyhow::Result<Json> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            anyhow::bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 1-space indent (matches the python
    /// artifact manifests, `json.dump(..., indent=1)`).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(entries) => {
                out.push('{');
                for (i, (k, v)) in entries.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !entries.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 9.0e15 {
            fmt::Write::write_fmt(out, format_args!("{}", n as i64)).unwrap();
        } else {
            fmt::Write::write_fmt(out, format_args!("{n}")).unwrap();
        }
    } else {
        // JSON has no Inf/NaN; clamp like most emitters.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32)).unwrap()
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, text: &str, value: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(value)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            entries.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(entries));
                }
                other => anyhow::bail!(
                    "expected ',' or '}}' at byte {} (found {:?})",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!(
                    "expected ',' or ']' at byte {} (found {:?})",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("short \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            // Surrogate pairs: read the low half if present.
                            let c = if (0xD800..0xDC00).contains(&code) {
                                self.pos += 5;
                                anyhow::ensure!(
                                    self.bytes.get(self.pos) == Some(&b'\\')
                                        && self.bytes.get(self.pos + 1) == Some(&b'u'),
                                    "lone high surrogate"
                                );
                                let hex2 = self
                                    .bytes
                                    .get(self.pos + 2..self.pos + 6)
                                    .ok_or_else(|| anyhow::anyhow!("short \\u escape"))?;
                                let low =
                                    u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                self.pos += 1; // compensates the uniform +5 below
                                char::from_u32(
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00),
                                )
                                .ok_or_else(|| anyhow::anyhow!("bad surrogate pair"))?
                            } else {
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u code"))?
                            };
                            s.push(c);
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(b) if b < 0x80 => {
                    // ASCII fast path — overwhelmingly common in traces.
                    // Consume a whole run of plain ASCII at once.
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c < 0x80 && c != b'"' && c != b'\\')
                    {
                        self.pos += 1;
                    }
                    // SAFETY-free: ASCII bytes are valid UTF-8.
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
                Some(b) => {
                    // One multi-byte UTF-8 scalar: decode just its own
                    // bytes (validating the whole remaining input per
                    // character was the O(n^2) hot spot — §Perf L3.1).
                    let width = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (self.pos + width).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[self.pos..end])?;
                    let c = chunk
                        .chars()
                        .next()
                        .ok_or_else(|| anyhow::anyhow!("truncated UTF-8"))?;
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}
impl From<&[usize]> for Json {
    fn from(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&n| Json::from(n)).collect())
    }
}
impl From<BTreeMap<String, f64>> for Json {
    fn from(m: BTreeMap<String, f64>) -> Json {
        Json::Obj(m.into_iter().map(|(k, v)| (k, Json::Num(v))).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.dump()).unwrap(), v, "{text}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        match &v {
            Json::Obj(entries) => {
                let keys: Vec<_> = entries.iter().map(|(k, _)| k.as_str()).collect();
                assert_eq!(keys, vec!["z", "a", "m"]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".to_string())
        );
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".to_string())
        );
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::parse("-2.5e-1").unwrap().as_f64(), Some(-0.25));
        assert_eq!(Json::parse("12").unwrap().as_usize(), Some(12));
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a': 1}").is_err());
    }

    #[test]
    fn set_replaces_and_appends() {
        let mut o = Json::obj();
        o.set("x", 1.0).set("y", 2.0).set("x", 3.0);
        assert_eq!(o.get("x").unwrap().as_f64(), Some(3.0));
        match &o {
            Json::Obj(e) => assert_eq!(e.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::parse(r#"{"a": [1, {"b": [true, null]}]}"#).unwrap();
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn req_and_typed_getters() {
        let v = Json::parse(r#"{"n": 4, "s": "x", "a": [1]}"#).unwrap();
        assert_eq!(v.usize_of("n").unwrap(), 4);
        assert_eq!(v.str_of("s").unwrap(), "x");
        assert_eq!(v.arr_of("a").unwrap().len(), 1);
        assert!(v.f64_of("missing").is_err());
        assert!(v.str_of("n").is_err());
    }

    #[test]
    fn integral_floats_emit_without_fraction() {
        assert_eq!(Json::Num(5.0).dump(), "5");
        assert_eq!(Json::Num(5.25).dump(), "5.25");
    }
}
