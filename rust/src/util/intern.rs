//! Global string interner for hot trace-event symbols.
//!
//! Kernel metadata repeats a tiny vocabulary (kernel symbols, family
//! tags, ATen ops, shape keys are all emitted by the lowering's
//! quantized name cache) across millions of events, so storing them as
//! per-event `String`s made `KernelMeta` clone/hash/compare costs — and
//! the per-call `dedup_key()` allocation — the dominant trace-path
//! overhead (DESIGN.md §15). [`Sym`] replaces them: a `Copy` handle to
//! a leaked, deduplicated `&'static str`.
//!
//! Invariant: equal strings intern to the *same* pointer, so `Sym`
//! equality and hashing are pointer operations, never content scans.
//! The table only grows (entries are `Box::leak`ed); its size is
//! bounded by the lowering vocabulary, which tile-quantizes kernel
//! names precisely so this universe stays small. The hit/miss counters
//! make that claim observable: `stats()` reports (hits, misses) where
//! `misses` is the number of distinct symbols ever allocated.

use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::ops::Deref;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

static INTERNER: OnceLock<Mutex<HashMap<&'static str, &'static str>>> = OnceLock::new();
static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

fn table() -> &'static Mutex<HashMap<&'static str, &'static str>> {
    INTERNER.get_or_init(|| Mutex::new(HashMap::new()))
}

fn intern_with(s: &str, leak: impl FnOnce() -> &'static str) -> &'static str {
    let mut map = table().lock().unwrap_or_else(|e| e.into_inner());
    if let Some(&v) = map.get(s) {
        HITS.fetch_add(1, Ordering::Relaxed);
        return v;
    }
    MISSES.fetch_add(1, Ordering::Relaxed);
    let leaked = leak();
    map.insert(leaked, leaked);
    leaked
}

/// (hits, misses) over the process lifetime: `hits` counts symbol
/// lookups satisfied without allocating, `misses` the distinct symbols
/// ever allocated (== table size). The loadgen bench report exposes
/// both so capture runs can assert O(vocabulary), not O(events),
/// allocation.
pub fn stats() -> (u64, u64) {
    (HITS.load(Ordering::Relaxed), MISSES.load(Ordering::Relaxed))
}

/// An interned string symbol: `Copy`, pointer-compared, pointer-hashed.
///
/// `Sym` derefs to `str`, so read sites (`.as_str()`, `format!`,
/// `.starts_with(..)`, passing `&sym` where `&str` is expected) keep
/// working unchanged; only construction goes through the interner
/// (`Sym::from(&str | String)`).
#[derive(Clone, Copy)]
pub struct Sym(&'static str);

impl Sym {
    pub fn new(s: &str) -> Sym {
        Sym(intern_with(s, || Box::leak(s.to_owned().into_boxed_str())))
    }

    /// Intern an owned string, reusing its allocation on first sight.
    pub fn from_owned(s: String) -> Sym {
        Sym(intern_with(&s, || Box::leak(s.into_boxed_str())))
    }

    pub fn as_str(&self) -> &'static str {
        self.0
    }
}

impl Deref for Sym {
    type Target = str;

    fn deref(&self) -> &str {
        self.0
    }
}

// No `Borrow<str>` impl: `Sym` hashes by pointer while `str` hashes by
// content, so a `HashMap<Sym, _>` must never be probed with a bare
// `&str` — the Borrow contract (hash equality across forms) would not
// hold. Intern first, then look up.

impl PartialEq for Sym {
    fn eq(&self, other: &Sym) -> bool {
        // The interner maps equal content to one pointer.
        std::ptr::eq(self.0, other.0)
    }
}

impl Eq for Sym {}

impl Hash for Sym {
    fn hash<H: Hasher>(&self, state: &mut H) {
        (self.0.as_ptr() as usize).hash(state);
    }
}

impl PartialEq<&str> for Sym {
    fn eq(&self, other: &&str) -> bool {
        self.0 == *other
    }
}

impl PartialEq<str> for Sym {
    fn eq(&self, other: &str) -> bool {
        self.0 == other
    }
}

impl PartialEq<Sym> for &str {
    fn eq(&self, other: &Sym) -> bool {
        *self == other.0
    }
}

impl From<&str> for Sym {
    fn from(s: &str) -> Sym {
        Sym::new(s)
    }
}

impl From<String> for Sym {
    fn from(s: String) -> Sym {
        Sym::from_owned(s)
    }
}

impl fmt::Display for Sym {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.0)
    }
}

impl fmt::Debug for Sym {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self.0, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_content_is_one_pointer() {
        let a = Sym::new("taxbreak::intern_test_a");
        let b = Sym::from_owned("taxbreak::intern_test_a".to_string());
        assert_eq!(a, b);
        assert!(std::ptr::eq(a.as_str(), b.as_str()));
        let c = Sym::new("taxbreak::intern_test_c");
        assert_ne!(a, c);
    }

    #[test]
    fn str_comparisons_and_deref_work() {
        let s = Sym::new("aten::mm_test");
        assert_eq!(s, "aten::mm_test");
        assert_eq!("aten::mm_test", s);
        assert!(s.starts_with("aten::"));
        assert_eq!(format!("{s}"), "aten::mm_test");
        assert_eq!(format!("{s:?}"), "\"aten::mm_test\"");
        fn takes_str(x: &str) -> usize {
            x.len()
        }
        assert_eq!(takes_str(&s), 12);
    }

    #[test]
    fn hash_is_consistent_with_eq() {
        use std::collections::HashMap;
        let mut m: HashMap<Sym, u32> = HashMap::new();
        m.insert(Sym::new("sym_hash_test"), 1);
        *m.entry(Sym::from_owned("sym_hash_test".into())).or_insert(0) += 1;
        assert_eq!(m.len(), 1);
        assert_eq!(m[&Sym::new("sym_hash_test")], 2);
    }

    #[test]
    fn repeat_interning_counts_hits_not_misses() {
        let (_, m0) = stats();
        let _ = Sym::new("taxbreak::intern_counter_probe");
        let (h1, m1) = stats();
        assert!(m1 >= m0);
        for _ in 0..10 {
            let _ = Sym::new("taxbreak::intern_counter_probe");
        }
        let (h2, m2) = stats();
        assert!(h2 >= h1 + 10, "repeat lookups must count as hits");
        // Other tests may intern concurrently; the probe itself must
        // not have allocated again.
        assert!(m2 >= m1);
        let before = stats().1;
        let _ = Sym::new("taxbreak::intern_counter_probe");
        assert_eq!(stats().1, before, "no new allocation on a hit");
    }
}
