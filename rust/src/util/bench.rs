//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this directly:
//! warm-up, N timed iterations, mean/min/p50 report, and a global
//! results collector for the tee'd bench_output.txt.

use std::time::Instant;

use crate::util::stats::Summary;

/// One benchmark's timing result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub summary_us: Summary,
    /// Optional throughput denominator (items per iteration).
    pub items: Option<f64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary_us;
        let mut line = format!(
            "{:<44} {:>10.1} us/iter (min {:>9.1}, p50 {:>9.1}, n={})",
            self.name, s.mean, s.min, s.p50, self.iters
        );
        if let Some(items) = self.items {
            let per_sec = items / (s.mean / 1e6);
            line.push_str(&format!("  [{:.2} Mitems/s]", per_sec / 1e6));
        }
        line
    }
}

/// Run `f` for `iters` timed iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    BenchResult {
        name: name.to_string(),
        iters,
        summary_us: Summary::of(&samples),
        items: None,
    }
}

/// `bench` with a throughput denominator (e.g. kernels per iteration).
pub fn bench_items<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    items: f64,
    f: F,
) -> BenchResult {
    let mut r = bench(name, warmup, iters, f);
    r.items = Some(items);
    r
}

/// Keep the optimizer honest.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a suite header + every result.
pub fn report(suite: &str, results: &[BenchResult]) {
    println!("\n### bench suite: {suite}");
    for r in results {
        println!("{}", r.report());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_exact_iterations() {
        let mut count = 0;
        let r = bench("noop", 2, 10, || count += 1);
        assert_eq!(count, 12);
        assert_eq!(r.iters, 10);
        assert!(r.summary_us.mean >= 0.0);
    }

    #[test]
    fn throughput_reported() {
        let r = bench_items("items", 0, 3, 1000.0, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.report().contains("Mitems/s"));
    }
}
