//! ASCII table rendering for the table/figure regeneration harnesses.
//!
//! Every `taxbreak repro <id>` command prints the paper's rows/series
//! through this formatter so EXPERIMENTS.md diffs stay readable.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table with a header row.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            // First column left (labels), the rest right (numbers).
            aligns: header
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            rows: Vec::new(),
        }
    }

    pub fn align(mut self, col: usize, align: Align) -> Table {
        self.aligns[col] = align;
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Table {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Row from string slices (convenience).
    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Table {
        self.row(cells.iter().map(|s| s.to_string()).collect())
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push('|');
                }
                let cell = &cells[i];
                let pad = widths[i].saturating_sub(cell.chars().count());
                match self.aligns[i] {
                    Align::Left => {
                        line.push(' ');
                        line.push_str(cell);
                        line.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        line.push_str(&" ".repeat(pad + 1));
                        line.push_str(cell);
                        line.push(' ');
                    }
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format microseconds with 2 decimals ("4.72").
pub fn us(v: f64) -> String {
    format!("{v:.2}")
}

/// Format milliseconds with adaptive precision.
pub fn ms(v: f64) -> String {
    if v >= 100.0 {
        format!("{v:.0}")
    } else if v >= 10.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.2}")
    }
}

/// Format a ratio/index ("0.74").
pub fn ratio(v: f64) -> String {
    format!("{v:.2}")
}

/// Format a percentage ("12.3%").
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

/// Format a count with thousands separators ("13,741").
pub fn count(v: usize) -> String {
    let s = v.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row_strs(&["a", "1"]);
        t.row_strs(&["long-name", "12345"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // Header + sep + 2 rows + title.
        assert_eq!(lines.len(), 5);
        // All data lines same width.
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn count_separators() {
        assert_eq!(count(0), "0");
        assert_eq!(count(999), "999");
        assert_eq!(count(1000), "1,000");
        assert_eq!(count(13741), "13,741");
        assert_eq!(count(1234567), "1,234,567");
    }

    #[test]
    fn formatters() {
        assert_eq!(us(4.72), "4.72"); // paper's floor precision
        assert_eq!(ms(5.041), "5.04");
        assert_eq!(ms(22.0), "22.0");
        assert_eq!(ms(586.4), "586");
        assert_eq!(ratio(0.737), "0.74");
        assert_eq!(pct(0.155), "15.5%");
    }
}
