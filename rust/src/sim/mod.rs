//! Host + device co-simulation: lowers a workload into the eager
//! dispatch path and feeds it through the shared discrete-event
//! timeline engine ([`crate::timeline::Engine`]), emitting an nsys-like
//! [`Trace`]. `sim` owns *what* is dispatched (lowering, host/device
//! cost sampling, trace emission); the engine owns *when* (host
//! cursors, stream FIFOs, sync points). The default workload runs on
//! the single topology (1 host thread, 1 stream) and reproduces the
//! pre-engine timeline bit-for-bit (`rust/tests/timeline.rs`); the
//! multi-stream/multi-device scenarios live in [`parallel`].
//!
//! Timeline semantics (eager mode, paper §II-C):
//! * the host thread dispatches kernels serially — per kernel it spends
//!   `T_Py + T_dispatch_base (+ ΔCT) + api_call`, then immediately moves
//!   to the next op (launches are asynchronous);
//! * each kernel becomes *ready* `launch_gap = T_sys_floor + ΔKT_fw`
//!   after its API call and starts at `max(ready, stream cursor)`;
//! * every pass ends with a device synchronization (decode needs the
//!   logits host-side for sampling), so steps do not overlap;
//! * non-kernel framework time (module-tree traversal, tokenization,
//!   generate()-loop bookkeeping, and the *python* expert-loop control
//!   flow for MoE) is modeled as per-pass glue that occupies the host
//!   without touching the device — the "framework tax" residual that
//!   makes observed idle fractions (Fig. 6) larger than orchestration
//!   alone explains.

pub mod parallel;

pub use parallel::{simulate_expert_parallel, simulate_tensor_parallel};

use crate::hardware::Platform;
use crate::host::HostModel;
use crate::kernels::cost;
use crate::kernels::family::Family;
use crate::lowering::{self, LowerOpts, PassKind};
use crate::models::ModelSpec;
use crate::timeline::{self, StreamRef};
use crate::trace::{EventKind, Trace, TraceBufferSink, TraceEvent, TraceMeta, TraceSink, Track};
use crate::util::rng::Rng;

/// Fixed per-pass python overhead at the reference CPU, us.
pub const PASS_CONST_US: f64 = 1500.0;
/// Per-layer python module-traversal overhead, us.
pub const PER_LAYER_US: f64 = 300.0;
/// Python control-flow cost of one expert iteration (MoE loop), us.
pub const EXPERT_LOOP_US: f64 = 45.0;
/// Host-side cost of the end-of-pass synchronization, us.
pub const SYNC_US: f64 = 30.0;

/// Inference phase of a workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

impl Phase {
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }
}

/// What-if mitigation modes — the paper's §III diagnostic
/// prescriptions, modeled so the advisor's recommendations can be
/// validated quantitatively (EXPERIMENTS.md §Prescriptions):
///
/// * `TorchCompile` — targets ΔFT: Python dispatch nearly vanishes, the
///   ATen path shortens, and elementwise chains fuse (fewer kernels).
/// * `CudaGraphs` — targets ΔKT/N: after a capture pass, each replayed
///   pass issues ONE graph launch instead of N kernel launches; the
///   paper notes the capture cost and static-shape requirement (§II-C).
/// * `KernelFusion` — targets N directly: fused attention + fused
///   elementwise chains, host path otherwise unchanged (eager).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mitigation {
    None,
    TorchCompile,
    CudaGraphs,
    KernelFusion,
}

impl Mitigation {
    pub fn as_str(&self) -> &'static str {
        match self {
            Mitigation::None => "none",
            Mitigation::TorchCompile => "torch-compile",
            Mitigation::CudaGraphs => "cuda-graphs",
            Mitigation::KernelFusion => "kernel-fusion",
        }
    }

    pub fn parse(tag: &str) -> anyhow::Result<Mitigation> {
        Ok(match tag {
            "none" => Mitigation::None,
            "torch-compile" => Mitigation::TorchCompile,
            "cuda-graphs" => Mitigation::CudaGraphs,
            "kernel-fusion" => Mitigation::KernelFusion,
            other => anyhow::bail!(
                "unknown mitigation '{other}' (none|torch-compile|cuda-graphs|kernel-fusion)"
            ),
        })
    }
}

/// torch.compile host-path savings: Python dispatch is compiled away,
/// ATen dispatch shortens to the compiled-graph runner's cost.
const COMPILE_PY_FACTOR: f64 = 0.10;
const COMPILE_BASE_FACTOR: f64 = 0.35;
/// Host cost of launching a captured CUDA graph, us (reference CPU).
/// Shared with the what-if CUDA-graph counterfactual (`whatif`).
pub const GRAPH_LAUNCH_US: f64 = 12.0;
/// One-time graph capture/instantiation overhead per unique pass shape.
pub const GRAPH_CAPTURE_US: f64 = 8000.0;

/// A workload point: model × phase × (BS, SL, m).
#[derive(Debug, Clone)]
pub struct Workload {
    pub phase: Phase,
    pub batch: usize,
    pub seq: usize,
    /// Output tokens for decode (the paper's m; decode traces aggregate
    /// all m steps). Ignored for prefill.
    pub m_tokens: usize,
    pub fused_attention: bool,
    pub mitigation: Mitigation,
}

impl Workload {
    pub fn prefill(batch: usize, seq: usize) -> Workload {
        Workload {
            phase: Phase::Prefill,
            batch,
            seq,
            m_tokens: 1,
            fused_attention: false,
            mitigation: Mitigation::None,
        }
    }

    pub fn decode(batch: usize, seq: usize, m_tokens: usize) -> Workload {
        Workload {
            phase: Phase::Decode,
            batch,
            seq,
            m_tokens,
            fused_attention: false,
            mitigation: Mitigation::None,
        }
    }

    pub fn with_fused_attention(mut self, fused: bool) -> Workload {
        self.fused_attention = fused;
        self
    }

    pub fn with_mitigation(mut self, mitigation: Mitigation) -> Workload {
        self.mitigation = mitigation;
        self
    }
}

/// Aggregate outcome of a simulated run (no event storage) — used by
/// the large heatmap sweeps (Figs. 5/6) where whole traces of
/// ~10⁶ events would dominate memory for no analytical gain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimSummary {
    pub wall_us: f64,
    pub device_active_us: f64,
    pub kernels: usize,
    /// Σ host-thread occupancy (dispatch path time).
    pub host_busy_us: f64,
    /// Σ (kernel start − api call): the TKLQT baseline [30].
    pub tklqt_us: f64,
}

impl SimSummary {
    /// GPU idle fraction (Fig. 6).
    pub fn idle_fraction(&self) -> f64 {
        if self.wall_us <= 0.0 {
            0.0
        } else {
            ((self.wall_us - self.device_active_us) / self.wall_us).clamp(0.0, 1.0)
        }
    }
}

/// The m-token pass list of a workload — `(kind, seq_q, ctx)` per
/// pass: one prefill (which produces output token 1) + m−1 decode
/// steps ("prefill (m=1)" in Fig. 5; §V-C's kernel arithmetic
/// 8,437 = 850 prefill + 9 × ~843 decode steps). The one pass-window
/// definition shared by the single-stream simulator and the
/// [`parallel`] scenarios.
pub fn passes_of(workload: &Workload) -> Vec<(PassKind, usize, usize)> {
    let m = match workload.phase {
        Phase::Prefill => 1,
        Phase::Decode => workload.m_tokens.max(1),
    };
    let mut passes: Vec<(PassKind, usize, usize)> =
        vec![(PassKind::Prefill, workload.seq, workload.seq)];
    passes.extend((0..m - 1).map(|i| (PassKind::DecodeStep, 1, workload.seq + i + 1)));
    passes
}

/// Unmitigated per-pass framework glue at the reference CPU, us:
/// module-tree traversal, tokenization/bookkeeping, and the python MoE
/// expert-loop control flow. The one calibration expression shared by
/// the single-stream simulator (which scales it under compiled
/// mitigations) and the [`parallel`] scenarios.
pub fn pass_glue_us(model: &ModelSpec) -> f64 {
    let mut glue = PASS_CONST_US + PER_LAYER_US * model.layers as f64;
    if let Some(moe) = &model.moe {
        glue += EXPERT_LOOP_US * (model.layers * (moe.n_experts + moe.shared_experts)) as f64;
    }
    glue
}

/// The [`TraceMeta`] a simulated run of `workload` carries (`wall_us`
/// is stamped at the end of the run — 0 here).
pub fn trace_meta_of(model: &ModelSpec, platform: &Platform, workload: &Workload) -> TraceMeta {
    TraceMeta {
        platform: platform.name.clone(),
        model: model.name.clone(),
        phase: workload.phase.as_str().to_string(),
        batch: workload.batch,
        seq: workload.seq,
        m_tokens: if workload.phase == Phase::Decode {
            workload.m_tokens
        } else {
            1
        },
        wall_us: 0.0,
    }
}

/// Simulate one profiled iteration of `workload` on `platform`.
///
/// Deterministic in `(model, platform, workload, seed)`.
pub fn simulate(
    model: &ModelSpec,
    platform: &Platform,
    workload: &Workload,
    seed: u64,
) -> Trace {
    let mut sink = TraceBufferSink::new(trace_meta_of(model, platform, workload));
    simulate_inner(model, platform, workload, seed, Some(&mut sink))
        .expect("buffering into memory cannot fail");
    sink.into_trace()
}

/// Aggregates-only simulation: identical timeline, no event storage.
pub fn simulate_summary(
    model: &ModelSpec,
    platform: &Platform,
    workload: &Workload,
    seed: u64,
) -> SimSummary {
    simulate_inner(model, platform, workload, seed, None)
        .expect("no sink: nothing can fail")
}

/// Stream one simulated iteration through `sink` (the streaming binary
/// writer gives O(1)-memory capture); `sink.finish` receives the
/// run's wall-clock. The emitted events are identical to
/// [`simulate`]'s.
pub fn simulate_to_sink(
    model: &ModelSpec,
    platform: &Platform,
    workload: &Workload,
    seed: u64,
    sink: &mut dyn TraceSink,
) -> anyhow::Result<SimSummary> {
    simulate_inner(model, platform, workload, seed, Some(sink))
}

fn simulate_inner(
    model: &ModelSpec,
    platform: &Platform,
    workload: &Workload,
    seed: u64,
    mut sink: Option<&mut dyn TraceSink>,
) -> anyhow::Result<SimSummary> {
    let host = HostModel::new(platform.clone());
    let base = Rng::new(seed)
        .fork_str(&model.name)
        .fork_str(&platform.name);
    let mut host_rng = base.fork(1);
    let mut dev_rng = base.fork(2);
    let mut lower_rng = base.fork(3);

    let mit = workload.mitigation;
    let opts = LowerOpts {
        fused_attention: workload.fused_attention
            || matches!(mit, Mitigation::KernelFusion | Mitigation::TorchCompile),
    };
    let st = platform.cpu.st_speed;
    // The single topology: 1 host dispatch thread, 1 FIFO stream.
    let mut tl = timeline::Engine::single();
    let mut corr: u64 = 0;
    let mut host_busy_us = 0.0f64;
    let mut tklqt_us = 0.0f64;

    let mut graph_captured = false;
    for (pass_idx, (kind, seq_q, ctx)) in passes_of(workload).into_iter().enumerate() {
        // Non-kernel framework glue for this pass. Compiled execution
        // skips the python module-tree traversal and the MoE python
        // expert loop (the graph runner owns control flow).
        let mut glue = pass_glue_us(model);
        if mit == Mitigation::TorchCompile || mit == Mitigation::CudaGraphs {
            glue *= 0.25;
        }
        tl.host_advance(0, glue / st);

        // CUDA graphs: decode steps after the capture pass replay the
        // whole pass as one graph launch (static shapes; the prefill /
        // first decode step pays the capture cost).
        let graphed = mit == Mitigation::CudaGraphs && kind == PassKind::DecodeStep;
        if graphed && !graph_captured {
            tl.host_advance(0, GRAPH_CAPTURE_US / st);
            graph_captured = true;
        }

        let mut seq = lowering::lower_pass(
            model,
            kind,
            workload.batch,
            seq_q,
            ctx,
            &opts,
            &mut lower_rng,
        );
        if mit == Mitigation::TorchCompile || mit == Mitigation::KernelFusion {
            seq = lowering::fuse_elementwise(seq);
        }
        if graphed {
            // One host-side graph launch; kernels run back-to-back.
            let (graph_ts, _) = tl.host_advance(0, GRAPH_LAUNCH_US / st);
            let floor = host.sample_floor(&mut host_rng);
            for meta in seq {
                corr += 1;
                let family =
                    Family::from_tag(&meta.family).expect("lowering emits valid tags");
                let dur = cost::sample_duration_us(
                    family,
                    meta.flops,
                    meta.bytes,
                    &platform.gpu,
                    &mut dev_rng,
                );
                let timing = tl.submit(StreamRef::PRIMARY, graph_ts, floor, dur);
                tklqt_us += timing.launch_plus_queue_us;
                if let Some(s) = sink.as_deref_mut() {
                    s.event(&TraceEvent {
                        kind: EventKind::Kernel,
                        name: meta.kernel_name.to_string(),
                        ts_us: timing.start_us,
                        dur_us: dur,
                        correlation_id: corr,
                        track: Track::Device(0),
                        device: None,
                        args: None,
                        meta: Some(meta),
                    })?;
                }
            }
            host_busy_us += GRAPH_LAUNCH_US / st;
            let _ = pass_idx;
            tl.host_wait_until(0, tl.sync_point());
            tl.host_advance(0, SYNC_US / st);
            continue;
        }
        for meta in seq {
            corr += 1;
            let family = Family::from_tag(&meta.family).expect("lowering emits valid tags");
            let mut hs = host.sample(family, &mut host_rng);
            if mit == Mitigation::TorchCompile {
                hs.t_py *= COMPILE_PY_FACTOR;
                hs.t_base *= COMPILE_BASE_FACTOR;
            }
            let dur = cost::sample_duration_us(
                family,
                meta.flops,
                meta.bytes,
                &platform.gpu,
                &mut dev_rng,
            );

            // Segment-wise host advances reproduce the pre-engine
            // cursor chain `((t + py) + base) + ct) + api` exactly.
            let (torch_ts, aten_ts) = tl.host_advance(0, hs.t_py);
            tl.host_advance(0, hs.t_base);
            let (_, api_ts) = tl.host_advance(0, hs.t_ct);
            let (_, api_end) = tl.host_advance(0, hs.api_dur);
            let timing = tl.submit(StreamRef::PRIMARY, api_ts, hs.launch_gap, dur);
            host_busy_us += api_end - torch_ts;
            tklqt_us += timing.launch_plus_queue_us;

            let Some(s) = sink.as_deref_mut() else {
                continue;
            };
            s.event(&TraceEvent {
                kind: EventKind::TorchOp,
                name: format!("torch.{}", meta.aten_op.trim_start_matches("aten::")),
                ts_us: torch_ts,
                dur_us: api_end - torch_ts,
                correlation_id: corr,
                track: Track::Host,
                device: None,
                args: None,
                meta: None,
            })?;
            s.event(&TraceEvent {
                kind: EventKind::AtenOp,
                name: meta.aten_op.to_string(),
                ts_us: aten_ts,
                dur_us: api_end - aten_ts,
                correlation_id: corr,
                track: Track::Host,
                device: None,
                args: None,
                meta: None,
            })?;
            s.event(&TraceEvent {
                kind: EventKind::RuntimeApi,
                name: "cudaLaunchKernel".to_string(),
                ts_us: api_ts,
                dur_us: hs.api_dur,
                correlation_id: corr,
                track: Track::Host,
                device: None,
                args: None,
                meta: None,
            })?;
            s.event(&TraceEvent {
                kind: EventKind::Kernel,
                name: meta.kernel_name.to_string(),
                ts_us: timing.start_us,
                dur_us: dur,
                correlation_id: corr,
                track: Track::Device(0),
                device: None,
                args: None,
                meta: Some(meta),
            })?;
        }

        // End-of-pass device sync (logits needed host-side).
        tl.host_wait_until(0, tl.sync_point());
        tl.host_advance(0, SYNC_US / st);
    }

    tl.host_wait_until(0, tl.sync_point());
    let wall_us = tl.host_now(0);
    if let Some(s) = sink.as_deref_mut() {
        s.finish(wall_us)?;
    }
    Ok(SimSummary {
        wall_us,
        device_active_us: tl.active_us(),
        kernels: tl.launched(),
        host_busy_us,
        tklqt_us,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn sim(model: &ModelSpec, platform: &Platform, wl: &Workload) -> Trace {
        simulate(model, platform, wl, 42)
    }

    #[test]
    fn trace_is_deterministic() {
        let m = models::gpt2();
        let p = Platform::h200();
        let wl = Workload::prefill(1, 512);
        assert_eq!(sim(&m, &p, &wl), sim(&m, &p, &wl));
    }

    #[test]
    fn kernel_events_match_lowering_count() {
        let m = models::gpt2();
        let p = Platform::h200();
        let tr = sim(&m, &p, &Workload::prefill(1, 512));
        let mut rng = Rng::new(0);
        let expect = lowering::lower_pass(
            &m,
            PassKind::Prefill,
            1,
            512,
            512,
            &LowerOpts::default(),
            &mut rng,
        )
        .len();
        assert_eq!(tr.kernel_count(), expect);
        // Each kernel has its torch/aten/api chain.
        assert_eq!(tr.events.len(), 4 * expect);
    }

    #[test]
    fn kernels_are_fifo_on_device() {
        let m = models::gpt2();
        let tr = sim(&m, &Platform::h100(), &Workload::prefill(1, 512));
        let mut last_end = 0.0;
        for k in tr.kernels() {
            assert!(k.ts_us >= last_end - 1e-9, "FIFO violated");
            last_end = k.end_us();
        }
    }

    #[test]
    fn host_events_are_serial() {
        let m = models::gpt2();
        let tr = sim(&m, &Platform::h100(), &Workload::prefill(1, 128));
        let mut last_end = 0.0;
        for e in tr.events.iter().filter(|e| e.kind == EventKind::TorchOp) {
            assert!(e.ts_us >= last_end - 1e-9, "host dispatch must be serial");
            last_end = e.end_us();
        }
    }

    #[test]
    fn wall_covers_all_events() {
        let m = models::llama_1b();
        let tr = sim(&m, &Platform::h100(), &Workload::decode(1, 512, 3));
        let span_end = tr
            .events
            .iter()
            .map(|e| e.end_us())
            .fold(0.0f64, f64::max);
        assert!(tr.meta.wall_us >= span_end - 1e-6);
    }

    #[test]
    fn decode_window_is_prefill_plus_steps() {
        // §V-C arithmetic: the m-token window = 1 prefill pass + (m-1)
        // decode steps (8,437 = 850 + 9 x ~843 for Llama-1B).
        let m = models::gpt2();
        let p = Platform::h200();
        let prefill = sim(&m, &p, &Workload::prefill(1, 128));
        let m1 = sim(&m, &p, &Workload::decode(1, 128, 1));
        assert_eq!(m1.kernel_count(), prefill.kernel_count());
        let m5 = sim(&m, &p, &Workload::decode(1, 128, 5));
        let per_step = (m5.kernel_count() - prefill.kernel_count()) / 4;
        // Decode steps carry a few extra kernels (cache writes,
        // sampling) and drop the prefill mask.
        assert!(
            per_step.abs_diff(prefill.kernel_count()) < 20,
            "per_step={per_step} prefill={}",
            prefill.kernel_count()
        );
    }

    #[test]
    fn bigger_batch_increases_device_time_not_kernel_count() {
        // The §V-C GPT-2 result: T_Orchestration flat, T_DeviceActive
        // grows with batch.
        let m = models::gpt2();
        let p = Platform::h200();
        let bs1 = sim(&m, &p, &Workload::prefill(1, 512));
        let bs16 = sim(&m, &p, &Workload::prefill(16, 512));
        assert_eq!(bs1.kernel_count(), bs16.kernel_count());
        assert!(bs16.device_active_us() > 5.0 * bs1.device_active_us());
    }

    #[test]
    fn h200_reduces_wall_for_host_bound_moe() {
        // §VI: the faster host CPU wins end-to-end for MoE decode even
        // though the H200 GPU is clocked lower.
        let m = models::olmoe();
        let wl = Workload::decode(1, 512, 2);
        let h100 = sim(&m, &Platform::h100(), &wl);
        let h200 = sim(&m, &Platform::h200(), &wl);
        assert!(
            h200.meta.wall_us < h100.meta.wall_us,
            "h100={} h200={}",
            h100.meta.wall_us,
            h200.meta.wall_us
        );
    }
}

#[cfg(test)]
mod summary_tests {
    use super::*;
    use crate::models;

    #[test]
    fn summary_matches_full_trace() {
        let m = models::gpt2();
        let p = Platform::h200();
        let wl = Workload::prefill(2, 256);
        let trace = simulate(&m, &p, &wl, 17);
        let sum = simulate_summary(&m, &p, &wl, 17);
        assert_eq!(sum.kernels, trace.kernel_count());
        assert!((sum.wall_us - trace.meta.wall_us).abs() < 1e-9);
        assert!((sum.device_active_us - trace.device_active_us()).abs() < 1e-9);
    }

    #[test]
    fn streamed_sink_reproduces_buffered_trace() {
        let m = models::gpt2();
        let p = Platform::h200();
        let wl = Workload::prefill(1, 128);
        let buffered = simulate(&m, &p, &wl, 11);
        let mut w =
            crate::trace::BinaryTraceWriter::new(Vec::new(), &trace_meta_of(&m, &p, &wl))
                .unwrap();
        let sum = simulate_to_sink(&m, &p, &wl, 11, &mut w).unwrap();
        let streamed = crate::trace::binary::decode(&w.into_inner()).unwrap();
        assert_eq!(streamed, buffered, "streamed capture must match buffered");
        assert!((sum.wall_us - buffered.meta.wall_us).abs() < 1e-12);
    }

    #[test]
    fn tklqt_matches_baseline_computation() {
        let m = models::gpt2();
        let p = Platform::h200();
        let wl = Workload::prefill(1, 128);
        let trace = simulate(&m, &p, &wl, 3);
        let sum = simulate_summary(&m, &p, &wl, 3);
        let b = crate::taxbreak::baselines::compute(&trace);
        assert!((sum.tklqt_us - b.tklqt_us).abs() < 1e-6);
    }
}
