//! Multi-stream / multi-device execution scenarios on the shared
//! timeline engine — the regimes a single-FIFO model cannot express
//! (Fernandez et al.'s framework-tax shift under overlap; Wang et
//! al.'s dispatch-overlap characterization):
//!
//! * **Tensor-parallel dense** ([`simulate_tensor_parallel`]): N
//!   devices run the same pass SPMD — one host dispatch thread per
//!   rank, weight-carrying kernels (GEMM / fused attention) sharded
//!   N-ways, everything else replicated — with a ring **all-reduce
//!   sync point after every layer** that joins all N streams. Each
//!   rank pays the *full* launch path for its shard, so aggregate
//!   orchestration cost multiplies by N while aggregate device work
//!   stays constant: exactly the "does a second GPU help a host-bound
//!   workload?" question the `tensor-parallel:<N>` counterfactual
//!   asks.
//! * **Expert-parallel MoE** ([`simulate_expert_parallel`]): expert
//!   chains round-robin across N streams of one device (router →
//!   experts fan-out, combine joins every stream), while the *single*
//!   host thread still dispatches every launch serially — device-side
//!   overlap cannot buy back host-bound dispatch, which is the MoE
//!   finding the paper's single-stream decomposition only hints at.
//!
//! Both producers stamp multi-stream structure into the trace
//! (`TraceEvent::device`, `Track::Device(stream)`), so the per-device
//! decomposition (`taxbreak::decompose`) and the Chrome exporter see
//! real lanes.

use crate::hardware::{ALLREDUCE_HOP_US, NVLINK_GBPS, Platform};
use crate::host::HostModel;
use crate::kernels::cost;
use crate::kernels::family::Family;
use crate::lowering::{self, LowerOpts, MarkKind};
use crate::models::ModelSpec;
use crate::sim::{pass_glue_us, passes_of, Mitigation, Phase, SYNC_US, Workload};
use crate::timeline::{Engine, StreamRef, Topology};
use crate::trace::{EventKind, KernelMeta, Trace, TraceEvent, TraceMeta, Track};
use crate::util::rng::Rng;

/// Device time of one ring all-reduce over `act_bytes` of activations
/// across `ways` ranks: `2·(N−1)` latency hops plus the
/// `2·(N−1)/N · act_bytes` per-rank wire traffic at NVLink bandwidth.
/// Latency-dominated for decode activations, bandwidth-dominated for
/// long prefills. Shared with the `tensor-parallel:<N>` counterfactual.
pub fn allreduce_device_us(ways: usize, act_bytes: f64) -> f64 {
    let n = ways.max(1) as f64;
    let hops = 2.0 * (n - 1.0);
    let wire_bytes = 2.0 * (n - 1.0) / n * act_bytes;
    hops * ALLREDUCE_HOP_US + wire_bytes / (NVLINK_GBPS * 1000.0)
}

/// Per-rank wire traffic of that all-reduce (stored as the comm
/// kernel's `bytes`).
pub fn allreduce_wire_bytes(ways: usize, act_bytes: f64) -> f64 {
    let n = ways.max(1) as f64;
    2.0 * (n - 1.0) / n * act_bytes
}

/// Emit one full TorchOp → AtenOp → RuntimeApi → Kernel chain.
#[allow(clippy::too_many_arguments)]
fn push_chain(
    trace: &mut Trace,
    corr: u64,
    device: Option<u32>,
    stream: u32,
    torch_name: String,
    aten_name: String,
    torch_ts: f64,
    aten_ts: f64,
    api_ts: f64,
    api_end: f64,
    kernel_ts: f64,
    kernel_dur: f64,
    meta: KernelMeta,
) {
    trace.push(TraceEvent {
        kind: EventKind::TorchOp,
        name: torch_name,
        ts_us: torch_ts,
        dur_us: api_end - torch_ts,
        correlation_id: corr,
        track: Track::Host,
        device,
        args: None,
        meta: None,
    });
    trace.push(TraceEvent {
        kind: EventKind::AtenOp,
        name: aten_name,
        ts_us: aten_ts,
        dur_us: api_end - aten_ts,
        correlation_id: corr,
        track: Track::Host,
        device,
        args: None,
        meta: None,
    });
    trace.push(TraceEvent {
        kind: EventKind::RuntimeApi,
        name: "cudaLaunchKernel".to_string(),
        ts_us: api_ts,
        dur_us: api_end - api_ts,
        correlation_id: corr,
        track: Track::Host,
        device,
        args: None,
        meta: None,
    });
    trace.push(TraceEvent {
        kind: EventKind::Kernel,
        name: meta.kernel_name.to_string(),
        ts_us: kernel_ts,
        dur_us: kernel_dur,
        correlation_id: corr,
        track: Track::Device(stream),
        device,
        args: None,
        meta: Some(meta),
    });
}

/// Families whose work shards across tensor-parallel ranks (weight /
/// head partitioning); norms, glue and index ops replicate, which is
/// what keeps real TP efficiency below the ideal 1/N. The **single**
/// shardability predicate — the `tensor-parallel:<N>` counterfactual
/// uses it too, so the simulator and the replay can never disagree
/// about what shards.
pub fn tp_sharded(family: Family) -> bool {
    matches!(
        family,
        Family::GemmCublas | Family::GemmNvjet | Family::FusedAttention
    )
}

/// Simulate one profiled iteration of `workload` executed
/// tensor-parallel over `ways` devices (SPMD: one host dispatch thread
/// and one stream per rank; per-layer ring all-reduce joins).
///
/// Deterministic in `(model, platform, workload, ways, seed)`. The
/// mitigated execution modes are out of scope for the parallel
/// scenarios (graph capture per rank is future work).
pub fn simulate_tensor_parallel(
    model: &ModelSpec,
    platform: &Platform,
    workload: &Workload,
    ways: usize,
    seed: u64,
) -> anyhow::Result<Trace> {
    anyhow::ensure!(
        (2..=64).contains(&ways),
        "tensor parallelism needs 2..=64 ways, got {ways}"
    );
    anyhow::ensure!(
        workload.mitigation == Mitigation::None,
        "tensor-parallel simulation supports --mitigation none only"
    );

    let host = HostModel::new(platform.clone());
    let base = Rng::new(seed)
        .fork_str(&model.name)
        .fork_str(&platform.name)
        .fork_str("tensor-parallel");
    let mut host_rng = base.fork(1);
    let mut dev_rng = base.fork(2);
    let mut lower_rng = base.fork(3);

    let mut trace = Trace::new(TraceMeta {
        platform: platform.name.clone(),
        model: model.name.clone(),
        phase: workload.phase.as_str().to_string(),
        batch: workload.batch,
        seq: workload.seq,
        m_tokens: if workload.phase == Phase::Decode {
            workload.m_tokens
        } else {
            1
        },
        wall_us: 0.0,
    });

    let opts = LowerOpts {
        fused_attention: workload.fused_attention,
    };
    let st = platform.cpu.st_speed;
    let mut tl = Engine::new(Topology {
        devices: ways,
        streams_per_device: 1,
        host_threads: ways,
    });
    let streams: Vec<StreamRef> = (0..ways as u32)
        .map(|device| StreamRef { device, stream: 0 })
        .collect();
    let mut corr = 0u64;
    let glue = pass_glue_us(model);

    for (kind, seq_q, ctx) in passes_of(workload) {
        for r in 0..ways {
            tl.host_advance(r, glue / st);
        }
        let (seq, marks) = lowering::lower_pass_marked(
            model,
            kind,
            workload.batch,
            seq_q,
            ctx,
            &opts,
            &mut lower_rng,
        );
        let layer_ends: Vec<usize> = marks
            .iter()
            .filter(|m| m.kind == MarkKind::LayerEnd)
            .map(|m| m.index)
            .collect();
        let mut next_layer = 0usize;
        let act_bytes = (workload.batch * seq_q * model.d_model) as f64 * 2.0;

        for (i, meta) in seq.into_iter().enumerate() {
            let family = Family::from_tag(&meta.family).expect("lowering emits valid tags");
            let (flops, bytes) = if tp_sharded(family) {
                (meta.flops / ways as f64, meta.bytes / ways as f64)
            } else {
                (meta.flops, meta.bytes)
            };
            // SPMD: one host/device cost draw shared by every rank —
            // the ranks run the identical binary over identical shapes.
            let hs = host.sample(family, &mut host_rng);
            let dur = cost::sample_duration_us(family, flops, bytes, &platform.gpu, &mut dev_rng);
            let shard_meta = KernelMeta {
                flops,
                bytes,
                ..meta
            };
            // Hoisted out of the rank loop: the SPMD ranks share the
            // identical strings (format! per invocation dominated the
            // lowering profile once before — §Perf L3.2).
            let torch_name =
                format!("torch.{}", shard_meta.aten_op.trim_start_matches("aten::"));
            for (r, &sref) in streams.iter().enumerate() {
                corr += 1;
                let (torch_ts, aten_ts) = tl.host_advance(r, hs.t_py);
                tl.host_advance(r, hs.t_base);
                let (_, api_ts) = tl.host_advance(r, hs.t_ct);
                let (_, api_end) = tl.host_advance(r, hs.api_dur);
                let timing = tl.submit(sref, api_ts, hs.launch_gap, dur);
                push_chain(
                    &mut trace,
                    corr,
                    Some(r as u32),
                    0,
                    torch_name.clone(),
                    shard_meta.aten_op.to_string(),
                    torch_ts,
                    aten_ts,
                    api_ts,
                    api_end,
                    timing.start_us,
                    dur,
                    shard_meta.clone(),
                );
            }

            // Per-layer ring all-reduce: joins all ranks' streams.
            while next_layer < layer_ends.len() && layer_ends[next_layer] == i + 1 {
                next_layer += 1;
                let hs_ar = host.sample(Family::Memcpy, &mut host_rng);
                let dur_ar = allreduce_device_us(ways, act_bytes);
                let dep = tl.join(&streams);
                let ar_meta = KernelMeta {
                    kernel_name: "nccl_all_reduce_ring".into(),
                    family: Family::Memcpy.tag().into(),
                    aten_op: "nccl::all_reduce".into(),
                    shapes_key: format!(
                        "bf16[{},{}]xtp{ways}",
                        workload.batch * seq_q,
                        model.d_model
                    )
                    .into(),
                    grid: [ways as u32, 1, 1],
                    block: [256, 1, 1],
                    lib_mediated: false,
                    flops: 0.0,
                    bytes: allreduce_wire_bytes(ways, act_bytes),
                };
                for (r, &sref) in streams.iter().enumerate() {
                    corr += 1;
                    let (torch_ts, aten_ts) = tl.host_advance(r, hs_ar.t_py);
                    tl.host_advance(r, hs_ar.t_base);
                    let (_, api_ts) = tl.host_advance(r, hs_ar.t_ct);
                    let (_, api_end) = tl.host_advance(r, hs_ar.api_dur);
                    let timing = tl.submit_after(sref, api_ts, hs_ar.launch_gap, dur_ar, dep);
                    push_chain(
                        &mut trace,
                        corr,
                        Some(r as u32),
                        0,
                        "torch.distributed.all_reduce".to_string(),
                        "nccl::all_reduce".to_string(),
                        torch_ts,
                        aten_ts,
                        api_ts,
                        api_end,
                        timing.start_us,
                        dur_ar,
                        ar_meta.clone(),
                    );
                }
            }
        }

        // End-of-pass device sync on every rank (logits host-side).
        for r in 0..ways {
            tl.host_wait_until(r, tl.device_sync_point(r as u32));
            tl.host_advance(r, SYNC_US / st);
        }
    }

    let mut wall = 0.0f64;
    for r in 0..ways {
        tl.host_wait_until(r, tl.device_sync_point(r as u32));
        wall = wall.max(tl.host_now(r));
    }
    trace.meta.wall_us = wall;
    Ok(trace)
}

/// Simulate one profiled iteration of a MoE `workload` with expert
/// chains sharded round-robin over `streams` CUDA streams of one
/// device. The host dispatch thread stays single (eager PyTorch), so
/// launches still serialize — only device execution overlaps:
/// router → experts fan out (each chain waits for the router output on
/// stream 0), the combine joins every stream.
///
/// Deterministic in `(model, platform, workload, streams, seed)`.
pub fn simulate_expert_parallel(
    model: &ModelSpec,
    platform: &Platform,
    workload: &Workload,
    streams: usize,
    seed: u64,
) -> anyhow::Result<Trace> {
    anyhow::ensure!(
        (2..=32).contains(&streams),
        "expert parallelism needs 2..=32 streams, got {streams}"
    );
    anyhow::ensure!(
        model.is_moe(),
        "expert parallelism applies to MoE models; '{}' is dense",
        model.name
    );
    anyhow::ensure!(
        workload.mitigation == Mitigation::None,
        "expert-parallel simulation supports --mitigation none only"
    );

    let host = HostModel::new(platform.clone());
    let base = Rng::new(seed)
        .fork_str(&model.name)
        .fork_str(&platform.name)
        .fork_str("expert-parallel");
    let mut host_rng = base.fork(1);
    let mut dev_rng = base.fork(2);
    let mut lower_rng = base.fork(3);

    let mut trace = Trace::new(TraceMeta {
        platform: platform.name.clone(),
        model: model.name.clone(),
        phase: workload.phase.as_str().to_string(),
        batch: workload.batch,
        seq: workload.seq,
        m_tokens: if workload.phase == Phase::Decode {
            workload.m_tokens
        } else {
            1
        },
        wall_us: 0.0,
    });

    let opts = LowerOpts {
        fused_attention: workload.fused_attention,
    };
    let st = platform.cpu.st_speed;
    let mut tl = Engine::new(Topology {
        devices: 1,
        streams_per_device: streams,
        host_threads: 1,
    });
    let all_streams: Vec<StreamRef> = (0..streams as u32)
        .map(|stream| StreamRef { device: 0, stream })
        .collect();
    let s0 = StreamRef::PRIMARY;
    let mut corr = 0u64;
    let glue = pass_glue_us(model);

    for (kind, seq_q, ctx) in passes_of(workload) {
        tl.host_advance(0, glue / st);
        let (seq, marks) = lowering::lower_pass_marked(
            model,
            kind,
            workload.batch,
            seq_q,
            ctx,
            &opts,
            &mut lower_rng,
        );

        let mut mark_ptr = 0usize;
        let mut cur_stream = 0u32;
        let mut expert_counter = 0usize;
        let mut in_expert_section = false;
        let mut section_dep = 0.0f64;
        let mut chain_first = false;
        let mut combine_next = false;

        for (i, meta) in seq.into_iter().enumerate() {
            while mark_ptr < marks.len() && marks[mark_ptr].index == i {
                match marks[mark_ptr].kind {
                    MarkKind::ExpertChain => {
                        cur_stream = (expert_counter % streams) as u32;
                        expert_counter += 1;
                        chain_first = true;
                        if !in_expert_section {
                            in_expert_section = true;
                            // The expert chains consume the router
                            // output produced on stream 0.
                            section_dep = tl.stream_sync_point(s0);
                        }
                    }
                    MarkKind::Combine => {
                        cur_stream = 0;
                        in_expert_section = false;
                        combine_next = true;
                    }
                    MarkKind::LayerEnd => {}
                }
                mark_ptr += 1;
            }

            let family = Family::from_tag(&meta.family).expect("lowering emits valid tags");
            let hs = host.sample(family, &mut host_rng);
            let dur = cost::sample_duration_us(
                family,
                meta.flops,
                meta.bytes,
                &platform.gpu,
                &mut dev_rng,
            );
            let dep = if combine_next {
                // The combine consumes every expert stream's output.
                tl.join(&all_streams)
            } else if chain_first {
                section_dep
            } else {
                0.0
            };
            combine_next = false;
            chain_first = false;

            corr += 1;
            let (torch_ts, aten_ts) = tl.host_advance(0, hs.t_py);
            tl.host_advance(0, hs.t_base);
            let (_, api_ts) = tl.host_advance(0, hs.t_ct);
            let (_, api_end) = tl.host_advance(0, hs.api_dur);
            let sref = StreamRef {
                device: 0,
                stream: cur_stream,
            };
            let timing = tl.submit_after(sref, api_ts, hs.launch_gap, dur, dep);
            push_chain(
                &mut trace,
                corr,
                None,
                cur_stream,
                format!("torch.{}", meta.aten_op.trim_start_matches("aten::")),
                meta.aten_op.to_string(),
                torch_ts,
                aten_ts,
                api_ts,
                api_end,
                timing.start_us,
                dur,
                meta,
            );
        }

        // End-of-pass device sync across every stream.
        tl.host_wait_until(0, tl.sync_point());
        tl.host_advance(0, SYNC_US / st);
    }

    tl.host_wait_until(0, tl.sync_point());
    trace.meta.wall_us = tl.host_now(0);
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::sim::simulate;

    #[test]
    fn tensor_parallel_is_deterministic_and_stamps_devices() {
        let m = models::llama_1b();
        let p = Platform::h100();
        let wl = Workload::prefill(1, 64);
        let a = simulate_tensor_parallel(&m, &p, &wl, 2, 7).unwrap();
        let b = simulate_tensor_parallel(&m, &p, &wl, 2, 7).unwrap();
        assert_eq!(a, b);
        let devices: std::collections::BTreeSet<u32> =
            a.events.iter().map(|e| e.device_id()).collect();
        assert_eq!(devices.into_iter().collect::<Vec<_>>(), vec![0, 1]);
        crate::taxbreak::phase1::validate_trace(&a).unwrap();
    }

    #[test]
    fn tensor_parallel_multiplies_launches_not_wall() {
        // 2-way TP dispatches 2x the kernels (per-rank launch path is
        // not shared) plus per-layer all-reduces.
        let m = models::llama_1b();
        let p = Platform::h100();
        let wl = Workload::prefill(1, 64);
        let single = simulate(&m, &p, &wl, 7);
        let tp = simulate_tensor_parallel(&m, &p, &wl, 2, 7).unwrap();
        assert_eq!(
            tp.kernel_count(),
            2 * (single.kernel_count() + m.layers),
            "per-rank kernels + one all-reduce per layer per rank"
        );
        assert!(
            tp.kernels().any(|k| k.name == "nccl_all_reduce_ring"),
            "all-reduce sync points present"
        );
    }

    #[test]
    fn tensor_parallel_ranks_are_symmetric() {
        let m = models::gpt2();
        let p = Platform::h200();
        let wl = Workload::decode(1, 64, 2);
        let tr = simulate_tensor_parallel(&m, &p, &wl, 2, 3).unwrap();
        // SPMD: both ranks see identical timelines — every event has a
        // same-timestamp twin on the other rank.
        let of_dev = |d: u32| -> Vec<(String, f64, f64)> {
            tr.events
                .iter()
                .filter(|e| e.device_id() == d)
                .map(|e| (e.name.clone(), e.ts_us, e.dur_us))
                .collect()
        };
        assert_eq!(of_dev(0), of_dev(1));
    }

    #[test]
    fn tensor_parallel_rejects_bad_input() {
        let m = models::gpt2();
        let p = Platform::h200();
        let wl = Workload::prefill(1, 32);
        assert!(simulate_tensor_parallel(&m, &p, &wl, 1, 0).is_err());
        assert!(simulate_tensor_parallel(&m, &p, &wl, 65, 0).is_err());
        let graphed = Workload::decode(1, 32, 3).with_mitigation(Mitigation::CudaGraphs);
        assert!(simulate_tensor_parallel(&m, &p, &graphed, 2, 0).is_err());
    }

    #[test]
    fn expert_parallel_spreads_expert_chains_across_streams() {
        let m = models::olmoe();
        let p = Platform::h100();
        let wl = Workload::decode(1, 128, 2);
        let ep = simulate_expert_parallel(&m, &p, &wl, 4, 9).unwrap();
        let used: std::collections::BTreeSet<u32> = ep
            .kernels()
            .map(|k| match k.track {
                Track::Device(s) => s,
                Track::Host => unreachable!("kernels sit on device tracks"),
            })
            .collect();
        assert_eq!(used.len(), 4, "expert chains cover all 4 streams: {used:?}");
        crate::taxbreak::phase1::validate_trace(&ep).unwrap();

        // Same kernel count as the single-stream run (sharding moves
        // work, it does not add or remove launches).
        let single = simulate(&m, &p, &wl, 9);
        assert_eq!(ep.kernel_count(), single.kernel_count());
    }

    #[test]
    fn expert_parallel_host_is_still_serial() {
        // The single dispatch thread is the bottleneck: host events
        // never overlap even though device streams do.
        let m = models::olmoe();
        let p = Platform::h100();
        let ep = simulate_expert_parallel(&m, &p, &Workload::decode(1, 64, 2), 4, 5).unwrap();
        let mut last_end = 0.0f64;
        for e in ep.events.iter().filter(|e| e.kind == EventKind::TorchOp) {
            assert!(e.ts_us >= last_end - 1e-9, "host dispatch must stay serial");
            last_end = e.end_us();
        }
    }

    #[test]
    fn expert_parallel_rejects_dense_models() {
        let p = Platform::h100();
        let wl = Workload::decode(1, 64, 2);
        assert!(simulate_expert_parallel(&models::gpt2(), &p, &wl, 4, 0).is_err());
        assert!(simulate_expert_parallel(&models::olmoe(), &p, &wl, 1, 0).is_err());
    }

    #[test]
    fn allreduce_model_scales_with_ways_and_bytes() {
        let small = allreduce_device_us(2, 32.0 * 1024.0);
        let big = allreduce_device_us(2, 512.0 * 1024.0 * 1024.0);
        assert!(small < big);
        // Decode-sized payloads are latency-dominated: ~2 hops.
        assert!((small - 2.0 * ALLREDUCE_HOP_US).abs() < 1.0, "{small}");
        assert!(allreduce_device_us(4, 1e6) > allreduce_device_us(2, 1e6));
        assert_eq!(allreduce_wire_bytes(2, 1000.0), 1000.0);
    }
}
