//! Metrics registry: labeled counters, gauges and log-bucketed
//! histograms with Prometheus text exposition and JSON snapshot export.
//!
//! Everything is deterministic by construction: families and samples
//! live in `BTreeMap`s keyed by name / rendered label set, histogram
//! bucket bounds are fixed powers of two, and numbers render through
//! one shared formatter — two registries fed the same observations
//! produce byte-identical expositions. That determinism is what lets
//! `taxbreak replay --verify` treat the metrics snapshot as a replay
//! fixed point (DESIGN.md §14). The exposition format follows the
//! Prometheus text format 0.0.4 (`# HELP` / `# TYPE` headers, cumulative
//! `_bucket{le=...}` / `_sum` / `_count` histogram series); metric names
//! and labels are specified in `docs/metrics.md`.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Prometheus metric kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// Smallest histogram bucket bound exponent: 2^-10 ≈ 0.00098 (sub-us
/// ratios and fractions land in real buckets, not a catch-all).
pub const HIST_MIN_EXP: i32 = -10;
/// Largest finite bucket bound exponent: 2^30 ≈ 1.07e9 us ≈ 18 min.
pub const HIST_MAX_EXP: i32 = 30;

const N_FINITE_BUCKETS: usize = (HIST_MAX_EXP - HIST_MIN_EXP + 1) as usize;

/// Log-bucketed histogram: finite bucket upper bounds are the powers of
/// two `2^HIST_MIN_EXP ..= 2^HIST_MAX_EXP`, plus the implicit `+Inf`
/// overflow bucket. Counts are stored per-bucket (non-cumulative) and
/// rendered cumulatively as the exposition format requires.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    counts: Vec<u64>,
    sum: f64,
    count: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            counts: vec![0; N_FINITE_BUCKETS + 1],
            sum: 0.0,
            count: 0,
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Upper bound of finite bucket `i`.
    pub fn bound(i: usize) -> f64 {
        2f64.powi(HIST_MIN_EXP + i as i32)
    }

    /// Index of the first bucket whose bound is `>= v` (the `+Inf`
    /// overflow bucket for anything above `2^HIST_MAX_EXP`).
    fn bucket_of(v: f64) -> usize {
        for i in 0..N_FINITE_BUCKETS {
            if v <= Histogram::bound(i) {
                return i;
            }
        }
        N_FINITE_BUCKETS
    }

    pub fn observe(&mut self, v: f64) {
        self.counts[Histogram::bucket_of(v)] += 1;
        self.sum += v;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// `(le, cumulative_count)` pairs over every finite bucket plus
    /// `(+Inf, total)` — exactly the exposition series.
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.counts.len());
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            let le = if i < N_FINITE_BUCKETS {
                Histogram::bound(i)
            } else {
                f64::INFINITY
            };
            out.push((le, acc));
        }
        out
    }
}

/// One sample: parsed label pairs plus the value.
#[derive(Debug, Clone, PartialEq)]
enum MetricValue {
    Num(f64),
    Hist(Histogram),
}

#[derive(Debug, Clone)]
struct Sample {
    labels: Vec<(String, String)>,
    value: MetricValue,
}

/// A named metric family: kind, help text, samples keyed by their
/// rendered (sorted) label set.
#[derive(Debug, Clone)]
pub struct MetricFamily {
    kind: MetricKind,
    help: String,
    samples: BTreeMap<String, Sample>,
}

/// The registry: `BTreeMap` of families, so iteration (and therefore
/// exposition) order is the lexicographic metric-name order.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    families: BTreeMap<String, MetricFamily>,
}

/// Escape a label value per the exposition format (`\`, `"`, newline).
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            other => out.push(other),
        }
    }
    out
}

/// Render a label set as `k1="v1",k2="v2"` with keys sorted.
fn label_key(labels: &[(&str, &str)]) -> String {
    let mut pairs: Vec<_> = labels.to_vec();
    pairs.sort();
    pairs
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect::<Vec<_>>()
        .join(",")
}

/// Exposition number formatting: integral values print without a
/// fraction, `+Inf` as the exposition spells it, everything else via
/// Rust's shortest-roundtrip float formatting.
pub fn fmt_value(v: f64) -> String {
    if v.is_infinite() {
        if v > 0.0 {
            "+Inf".into()
        } else {
            "-Inf".into()
        }
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    fn family(&mut self, name: &str, kind: MetricKind, help: &str) -> &mut MetricFamily {
        let f = self
            .families
            .entry(name.to_string())
            .or_insert_with(|| MetricFamily {
                kind,
                help: help.to_string(),
                samples: BTreeMap::new(),
            });
        assert!(
            f.kind == kind,
            "metric '{name}' re-registered as {} (was {})",
            kind.as_str(),
            f.kind.as_str()
        );
        f
    }

    fn sample(
        &mut self,
        name: &str,
        kind: MetricKind,
        help: &str,
        labels: &[(&str, &str)],
    ) -> &mut Sample {
        let key = label_key(labels);
        let owned: Vec<(String, String)> = {
            let mut pairs: Vec<_> = labels.to_vec();
            pairs.sort();
            pairs
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect()
        };
        let f = self.family(name, kind, help);
        f.samples.entry(key).or_insert_with(|| Sample {
            labels: owned,
            value: match kind {
                MetricKind::Histogram => MetricValue::Hist(Histogram::new()),
                _ => MetricValue::Num(0.0),
            },
        })
    }

    /// Add to a counter (creating it at 0 on first touch).
    pub fn counter_add(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        let s = self.sample(name, MetricKind::Counter, help, labels);
        if let MetricValue::Num(ref mut n) = s.value {
            *n += v;
        }
    }

    /// Set a gauge.
    pub fn gauge_set(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        let s = self.sample(name, MetricKind::Gauge, help, labels);
        s.value = MetricValue::Num(v);
    }

    /// Observe one value into a histogram.
    pub fn observe(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        let s = self.sample(name, MetricKind::Histogram, help, labels);
        if let MetricValue::Hist(ref mut h) = s.value {
            h.observe(v);
        }
    }

    /// Merge a pre-built histogram under a label set (the serving probe
    /// aggregates off-registry, then registers the result).
    pub fn histogram_merge(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        h: &Histogram,
    ) {
        let s = self.sample(name, MetricKind::Histogram, help, labels);
        s.value = MetricValue::Hist(h.clone());
    }

    pub fn is_empty(&self) -> bool {
        self.families.is_empty()
    }

    /// Prometheus text exposition (format 0.0.4) of the full registry.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        for (name, f) in &self.families {
            out.push_str(&format!("# HELP {name} {}\n", f.help));
            out.push_str(&format!("# TYPE {name} {}\n", f.kind.as_str()));
            for s in f.samples.values() {
                let base = label_key(
                    &s.labels
                        .iter()
                        .map(|(k, v)| (k.as_str(), v.as_str()))
                        .collect::<Vec<_>>(),
                );
                match &s.value {
                    MetricValue::Num(v) => {
                        if base.is_empty() {
                            out.push_str(&format!("{name} {}\n", fmt_value(*v)));
                        } else {
                            out.push_str(&format!("{name}{{{base}}} {}\n", fmt_value(*v)));
                        }
                    }
                    MetricValue::Hist(h) => {
                        for (le, c) in h.cumulative() {
                            let le = fmt_value(le);
                            if base.is_empty() {
                                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {c}\n"));
                            } else {
                                out.push_str(&format!("{name}_bucket{{{base},le=\"{le}\"}} {c}\n"));
                            }
                        }
                        let suffix = |s: &str| {
                            if base.is_empty() {
                                format!("{name}_{s}")
                            } else {
                                format!("{name}_{s}{{{base}}}")
                            }
                        };
                        out.push_str(&format!("{} {}\n", suffix("sum"), fmt_value(h.sum)));
                        out.push_str(&format!("{} {}\n", suffix("count"), h.count));
                    }
                }
            }
        }
        out
    }

    /// JSON snapshot of the registry (one object per family). Histogram
    /// buckets are exported sparsely: only buckets that received
    /// observations appear, each with its upper bound and cumulative
    /// count, followed by the `+Inf` total.
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        for (name, f) in &self.families {
            let mut samples = Vec::with_capacity(f.samples.len());
            for s in f.samples.values() {
                let mut labels = Json::obj();
                for (k, v) in &s.labels {
                    labels.set(k, Json::Str(v.clone()));
                }
                let mut o = Json::obj().with("labels", labels);
                match &s.value {
                    MetricValue::Num(v) => o.set("value", Json::Num(*v)),
                    MetricValue::Hist(h) => {
                        o.set("count", Json::from(h.count as usize));
                        o.set("sum", Json::Num(h.sum));
                        let mut buckets = Vec::new();
                        let mut prev = 0u64;
                        for (le, c) in h.cumulative() {
                            if c != prev || le.is_infinite() {
                                buckets.push(
                                    Json::obj()
                                        .with(
                                            "le",
                                            if le.is_infinite() {
                                                Json::Str("+Inf".into())
                                            } else {
                                                Json::Num(le)
                                            },
                                        )
                                        .with("count", c as usize),
                                );
                                prev = c;
                            }
                        }
                        o.set("buckets", Json::Arr(buckets));
                    }
                }
                samples.push(o);
            }
            root.set(
                name,
                Json::obj()
                    .with("kind", f.kind.as_str())
                    .with("help", f.help.as_str())
                    .with("samples", Json::Arr(samples)),
            );
        }
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_render() {
        let mut r = MetricsRegistry::new();
        r.counter_add("tb_events_total", "events", &[("model", "gpt2")], 3.0);
        r.counter_add("tb_events_total", "events", &[("model", "gpt2")], 2.0);
        r.counter_add("tb_events_total", "events", &[("model", "olmoe")], 1.0);
        let text = r.prometheus_text();
        assert!(text.contains("# HELP tb_events_total events\n"));
        assert!(text.contains("# TYPE tb_events_total counter\n"));
        assert!(text.contains("tb_events_total{model=\"gpt2\"} 5\n"));
        assert!(text.contains("tb_events_total{model=\"olmoe\"} 1\n"));
    }

    #[test]
    fn gauges_overwrite() {
        let mut r = MetricsRegistry::new();
        r.gauge_set("tb_hdbi", "hdbi", &[], 0.25);
        r.gauge_set("tb_hdbi", "hdbi", &[], 0.75);
        assert!(r.prometheus_text().contains("tb_hdbi 0.75\n"));
    }

    #[test]
    fn label_sets_are_sorted_and_escaped() {
        let mut r = MetricsRegistry::new();
        r.gauge_set("g", "g", &[("z", "a\"b\\c\nd"), ("a", "1")], 1.0);
        let text = r.prometheus_text();
        assert!(text.contains("g{a=\"1\",z=\"a\\\"b\\\\c\\nd\"} 1\n"));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_log_spaced() {
        let mut h = Histogram::new();
        for v in [0.5, 3.0, 3.9, 1000.0, 1e12] {
            h.observe(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.sum() - (0.5 + 3.0 + 3.9 + 1000.0 + 1e12)).abs() < 1.0);
        let cum = h.cumulative();
        // Monotone non-decreasing, ends at the total in +Inf.
        for w in cum.windows(2) {
            assert!(w[0].1 <= w[1].1);
            assert!(w[0].0 < w[1].0);
        }
        let (last_le, last_c) = *cum.last().unwrap();
        assert!(last_le.is_infinite());
        assert_eq!(last_c, 5);
        // 3.0 and 3.9 share the le=4 bucket.
        let at_4 = cum.iter().find(|(le, _)| *le == 4.0).unwrap().1;
        let at_2 = cum.iter().find(|(le, _)| *le == 2.0).unwrap().1;
        assert_eq!(at_4 - at_2, 2);
    }

    #[test]
    fn histogram_renders_exposition_series() {
        let mut r = MetricsRegistry::new();
        r.observe("tb_kv", "kv", &[("model", "m")], 0.5);
        r.observe("tb_kv", "kv", &[("model", "m")], 0.25);
        let text = r.prometheus_text();
        assert!(text.contains("# TYPE tb_kv histogram\n"));
        assert!(text.contains("tb_kv_bucket{model=\"m\",le=\"0.5\"} 2\n"));
        assert!(text.contains("tb_kv_bucket{model=\"m\",le=\"+Inf\"} 2\n"));
        assert!(text.contains("tb_kv_sum{model=\"m\"} 0.75\n"));
        assert!(text.contains("tb_kv_count{model=\"m\"} 2\n"));
    }

    #[test]
    fn json_snapshot_roundtrips_and_is_sparse() {
        let mut r = MetricsRegistry::new();
        r.counter_add("c", "a counter", &[("model", "m")], 2.0);
        r.observe("h", "a histogram", &[], 3.0);
        let j = r.to_json();
        let back = Json::parse(&j.dump()).unwrap();
        let c = back.req("c").unwrap();
        assert_eq!(c.str_of("kind").unwrap(), "counter");
        assert_eq!(c.arr_of("samples").unwrap()[0].f64_of("value").unwrap(), 2.0);
        let h = back.req("h").unwrap().arr_of("samples").unwrap()[0].clone();
        assert_eq!(h.usize_of("count").unwrap(), 1);
        // Sparse: one touched bucket + the +Inf terminator.
        assert_eq!(h.arr_of("buckets").unwrap().len(), 2);
    }

    #[test]
    fn identical_observations_render_identically() {
        let build = || {
            let mut r = MetricsRegistry::new();
            r.counter_add("c", "c", &[("m", "x")], 1.0);
            r.observe("h", "h", &[("m", "x")], 2.5);
            r.gauge_set("g", "g", &[], 0.125);
            r
        };
        assert_eq!(build().prometheus_text(), build().prometheus_text());
        assert_eq!(build().to_json().dump(), build().to_json().dump());
    }

    #[test]
    fn fmt_value_shapes() {
        assert_eq!(fmt_value(5.0), "5");
        assert_eq!(fmt_value(0.5), "0.5");
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(-3.0), "-3");
    }
}
