//! Streaming (online) Eq. 1–3 decomposition over a trace event stream.
//!
//! [`OnlineDecomposer`] implements [`TraceSink`], so it can sit in the
//! same sink fan-out as a file capture and consume a serving run (or
//! any trace) event by event. It maintains:
//!
//! - an incremental Phase-1 view: invocation chains assembled by
//!   correlation id, the kernel database built with
//!   [`KernelDb::record`] as kernel events stream past;
//! - per-window slices on the *virtual* clock (`--window-us`): kernel
//!   launches, T_fw (ΔFT), T_lib (I_lib·ΔCT), T_launch (ΔKT),
//!   device-active time, per-phase HDBI and the output-token proxy for
//!   kernel-launches-per-output-token (the paper's 8–11× MoE dispatch
//!   amplification, live);
//! - event-stream counters (arrivals, RNG draws, clock jumps,
//!   scheduler decisions, per-stream device activity) fed by the
//!   spec-v3 recording events — which stay invisible to the
//!   decomposition itself, exactly as in the post-hoc path.
//!
//! [`OnlineDecomposer::finalize`] runs the Phase-2 replay over the
//! incrementally-built database with the same backend seed and config
//! as `taxbreak analyze` ([`ANALYZE_REPLAY_SEED`] + fast config), then
//! folds the retained per-invocation records *in correlation order*
//! through the identical accumulation loop as
//! [`crate::taxbreak::decompose::decompose`] — so the end-of-run totals
//! are bit-identical to the post-hoc pass on the same trace, field by
//! field (pinned by `rust/tests/obs.rs`). See DESIGN.md §14 for the
//! full semantics and the window boundary rules.

use std::collections::{BTreeMap, HashMap};

use crate::hardware::Platform;
use crate::kernels::KernelDb;
use crate::taxbreak::decompose::{hdbi_of, Decomposition};
use crate::taxbreak::phase2::{run as phase2_run, ReplayConfig, SimReplayBackend};
use crate::trace::{DedupKey, EventKind, ReplayArgs, TraceEvent, TraceSink, Track};
use crate::util::intern::Sym;
use crate::util::json::Json;

/// Phase-2 replay seed used by `taxbreak analyze` — and therefore by
/// [`OnlineDecomposer::finalize`], so the online totals land on the
/// same calibration bits as the post-hoc pass.
pub const ANALYZE_REPLAY_SEED: u64 = 0x5EED;

/// Serving phase labels, in classification order ("prefill" is checked
/// first — matches `serving::loadgen`'s phase split).
pub const PHASES: [&str; 2] = ["prefill", "decode"];

const OTHER_PHASE: u8 = 2;

/// Per-phase share of one window (or of the whole run).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseWindow {
    pub invocations: usize,
    pub orchestration_us: f64,
    pub device_us: f64,
}

impl PhaseWindow {
    pub fn hdbi(&self) -> f64 {
        hdbi_of(self.orchestration_us, self.device_us)
    }
}

/// One virtual-time window of the decomposition. Windows are
/// half-open `[index·W, (index+1)·W)` intervals of the trace clock; an
/// invocation belongs to the window containing its kernel's completion
/// timestamp. Only non-empty windows are materialized.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WindowSlice {
    pub index: u64,
    pub start_us: f64,
    pub end_us: f64,
    pub n_kernels: usize,
    pub t_py_us: f64,
    pub t_base_us: f64,
    pub dct_us: f64,
    pub dkt_us: f64,
    pub device_active_us: f64,
    /// Output-token proxy: Σ post-step active batch over the window's
    /// `SchedDecision` events (each serving step advances every active
    /// sequence by one token). 0 for eager traces.
    pub tokens: usize,
    pub phases: [PhaseWindow; 2],
}

impl WindowSlice {
    /// ΔFT: T_Py + dispatch baseline.
    pub fn t_fw_us(&self) -> f64 {
        self.t_py_us + self.t_base_us
    }

    pub fn orchestration_us(&self) -> f64 {
        self.t_py_us + self.t_base_us + self.dct_us + self.dkt_us
    }

    pub fn hdbi(&self) -> f64 {
        hdbi_of(self.orchestration_us(), self.device_active_us)
    }

    pub fn launches_per_token(&self) -> f64 {
        if self.tokens == 0 {
            0.0
        } else {
            self.n_kernels as f64 / self.tokens as f64
        }
    }
}

/// Event-stream counters maintained by the sink (the instrumentation
/// plane: spec-v3 recording events feed these, never the decomposition).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EventCounts {
    pub total: usize,
    /// Events carrying correlation id 0 (recordings + floor probes).
    pub recording: usize,
    pub by_kind: BTreeMap<&'static str, usize>,
    pub arrivals: usize,
    pub rng_draws: usize,
    pub clock_jumps: usize,
    /// Σ idle time skipped by clock jumps, us.
    pub clock_jump_us: f64,
    pub sched_steps: usize,
    /// Requests admitted across all scheduler steps.
    pub admitted: usize,
    pub preempted: usize,
    /// Σ post-step active batch — the output-token proxy.
    pub batch_sum: usize,
}

/// Per-(device, stream) kernel activity observed on the stream.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StreamActivity {
    pub device: u32,
    pub stream: u32,
    pub kernels: usize,
    pub active_us: f64,
}

/// Compact per-invocation record retained until [`finalize`] — the
/// dedup key and family are `Copy` interned symbols, so memory stays
/// O(kernels), not O(events), no raw [`TraceEvent`]s are buffered, and
/// recording one costs zero allocations.
///
/// [`finalize`]: OnlineDecomposer::finalize
#[derive(Debug, Clone, Copy)]
struct InvRecord {
    corr: u64,
    key: DedupKey,
    family: Sym,
    device: u32,
    phase: u8,
    lib: bool,
    t_py_us: f64,
    device_us: f64,
    window: u64,
}

/// Open invocation chain (events seen so far for one correlation id).
#[derive(Debug, Clone, Copy, Default)]
struct PendingChain {
    torch_ts: Option<f64>,
    phase: u8,
    aten_ts: Option<f64>,
    api_seen: bool,
    kernel: Option<KernelHit>,
}

#[derive(Debug, Clone, Copy)]
struct KernelHit {
    end_us: f64,
    dur_us: f64,
    device: u32,
    /// `(dedup key, family, lib_mediated)` — `None` for meta-less
    /// kernels, which the post-hoc Phase 1 skips too.
    interned: Option<(DedupKey, Sym, bool)>,
}

/// The streaming decomposer. Feed it a trace (as a [`TraceSink`] or via
/// [`observe`](OnlineDecomposer::observe)), then [`finalize`] it.
///
/// [`finalize`]: OnlineDecomposer::finalize
#[derive(Debug, Clone, Default)]
pub struct OnlineDecomposer {
    window_us: f64,
    db: KernelDb,
    pending: HashMap<u64, PendingChain>,
    records: Vec<InvRecord>,
    counts: EventCounts,
    streams: BTreeMap<(u32, u32), StreamActivity>,
    /// Output-token proxy per window (from `SchedDecision` events).
    token_windows: BTreeMap<u64, usize>,
    /// Observed event span (fallback e2e when no wall was recorded).
    lo_ts: f64,
    hi_ts: f64,
    wall_us: f64,
}

fn phase_of(torch_name: &str) -> u8 {
    for (i, p) in PHASES.iter().enumerate() {
        if torch_name.contains(p) {
            return i as u8;
        }
    }
    OTHER_PHASE
}

impl OnlineDecomposer {
    /// `window_us <= 0` means a single whole-run window.
    pub fn new(window_us: f64) -> OnlineDecomposer {
        OnlineDecomposer {
            window_us,
            lo_ts: f64::INFINITY,
            hi_ts: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn window_us(&self) -> f64 {
        self.window_us
    }

    fn window_of(&self, t_us: f64) -> u64 {
        if self.window_us <= 0.0 {
            0
        } else {
            (t_us / self.window_us).floor().max(0.0) as u64
        }
    }

    /// Consume one event. Order-insensitive: chains close as soon as
    /// all four components arrived; stragglers close at finalize.
    pub fn observe(&mut self, e: &TraceEvent) {
        self.counts.total += 1;
        *self.counts.by_kind.entry(e.kind.as_str()).or_insert(0) += 1;
        self.lo_ts = self.lo_ts.min(e.ts_us);
        self.hi_ts = self.hi_ts.max(e.end_us());

        if e.correlation_id == 0 {
            self.counts.recording += 1;
            match e.kind {
                EventKind::Arrival => self.counts.arrivals += 1,
                EventKind::RngDraw => self.counts.rng_draws += 1,
                EventKind::ClockJump => {
                    self.counts.clock_jumps += 1;
                    self.counts.clock_jump_us += e.dur_us;
                }
                EventKind::SchedDecision => {
                    self.counts.sched_steps += 1;
                    if let Some(ReplayArgs::SchedDecision {
                        admitted,
                        preempted,
                        batch,
                        ..
                    }) = &e.args
                    {
                        self.counts.admitted += admitted.iter().map(|g| g.len()).sum::<usize>();
                        self.counts.preempted += preempted.len();
                        self.counts.batch_sum += *batch as usize;
                        let w = self.window_of(e.ts_us);
                        *self.token_windows.entry(w).or_insert(0) += *batch as usize;
                    }
                }
                _ => {}
            }
            return;
        }

        match e.kind {
            EventKind::TorchOp => {
                let c = self.pending.entry(e.correlation_id).or_default();
                c.torch_ts = Some(e.ts_us);
                c.phase = phase_of(&e.name);
            }
            EventKind::AtenOp => {
                self.pending.entry(e.correlation_id).or_default().aten_ts = Some(e.ts_us);
            }
            EventKind::RuntimeApi => {
                self.pending.entry(e.correlation_id).or_default().api_seen = true;
            }
            EventKind::Kernel => {
                let stream = match e.track {
                    Track::Device(s) => s,
                    Track::Host => 0,
                };
                let device = e.device_id();
                let s = self.streams.entry((device, stream)).or_default();
                s.device = device;
                s.stream = stream;
                s.kernels += 1;
                s.active_us += e.dur_us;

                let interned = e.meta.as_ref().map(|m| {
                    self.db.record(m, e.dur_us);
                    (m.dedup(), m.family, m.lib_mediated)
                });
                let c = self.pending.entry(e.correlation_id).or_default();
                c.kernel = Some(KernelHit {
                    end_us: e.end_us(),
                    dur_us: e.dur_us,
                    device,
                    interned,
                });
            }
            _ => return,
        }

        // Close the chain early once all four components are present
        // (the recorder invariant: at most one event per kind per
        // correlation id, kernel last). Chains missing host events
        // close at finalize with the same fallbacks as Phase 1.
        let complete = match self.pending.get(&e.correlation_id) {
            Some(c) => {
                c.torch_ts.is_some() && c.aten_ts.is_some() && c.api_seen && c.kernel.is_some()
            }
            None => false,
        };
        if complete {
            let c = self.pending.remove(&e.correlation_id).unwrap();
            self.close_chain(e.correlation_id, &c);
        }
    }

    fn close_chain(&mut self, corr: u64, c: &PendingChain) {
        let Some(k) = c.kernel else { return };
        let Some((key, family, lib)) = k.interned else {
            return; // meta-less kernels are skipped, as in Phase 1
        };
        let t_py = match (c.torch_ts, c.aten_ts) {
            (Some(t), Some(a)) => (a - t).max(0.0),
            _ => 0.0,
        };
        let phase = if c.torch_ts.is_some() {
            c.phase
        } else {
            OTHER_PHASE
        };
        self.records.push(InvRecord {
            corr,
            key,
            family,
            device: k.device,
            phase,
            lib,
            t_py_us: t_py,
            device_us: k.dur_us,
            window: self.window_of(k.end_us),
        });
    }

    /// Events seen so far (all kinds).
    pub fn events_seen(&self) -> usize {
        self.counts.total
    }

    /// Run Phase 2 over the incrementally-built kernel database and
    /// fold the retained invocation records into totals + windows.
    /// Uses the exact replay seed/config of `taxbreak analyze`, and the
    /// exact accumulation order of the post-hoc `decompose()` (records
    /// sorted by correlation id), so totals are bit-identical to it.
    pub fn finalize(mut self, platform: Platform) -> OnlineReport {
        // Drain chains that never saw all four components.
        let mut leftovers: Vec<(u64, PendingChain)> = self.pending.drain().collect();
        leftovers.sort_by_key(|(corr, _)| *corr);
        for (corr, c) in leftovers {
            self.close_chain(corr, &c);
        }
        self.records.sort_by_key(|r| r.corr);

        let mut backend = SimReplayBackend::new(platform, ANALYZE_REPLAY_SEED);
        let p2 = phase2_run(&self.db, &mut backend, &ReplayConfig::fast());

        let e2e_us = if self.wall_us > 0.0 {
            self.wall_us
        } else if self.lo_ts.is_finite() {
            self.hi_ts - self.lo_ts
        } else {
            0.0
        };

        let mut totals = Decomposition {
            e2e_us,
            floor_us: p2.floor.mean,
            ..Default::default()
        };
        let mut windows: BTreeMap<u64, WindowSlice> = BTreeMap::new();
        let mut phase_totals = [PhaseWindow::default(); 2];
        for r in &self.records {
            let dct = p2.replay_of(r.key).map(|k| k.dct_us).unwrap_or(0.0);
            let lib_dct = if r.lib { dct } else { 0.0 };

            totals.n_kernels += 1;
            totals.t_py_us += r.t_py_us;
            totals.t_base_us += p2.dispatch_base_us;
            totals.dct_us += lib_dct;
            totals.dkt_us += p2.floor.mean;
            totals.device_active_us += r.device_us;

            // Probe by `&str` first; allocate the `String` key only on
            // first sight of a family (same trick as `decompose()`).
            let slice = match totals.per_family.get_mut(r.family.as_str()) {
                Some(s) => s,
                None => totals.per_family.entry(r.family.to_string()).or_default(),
            };
            slice.invocations += 1;
            slice.t_py_us += r.t_py_us;
            slice.t_base_us += p2.dispatch_base_us;
            slice.dct_us += lib_dct;
            slice.dkt_us += p2.floor.mean;
            slice.device_us += r.device_us;

            let dev = totals.per_device.entry(r.device).or_default();
            dev.invocations += 1;
            dev.t_py_us += r.t_py_us;
            dev.t_base_us += p2.dispatch_base_us;
            dev.dct_us += lib_dct;
            dev.dkt_us += p2.floor.mean;
            dev.device_active_us += r.device_us;

            let w = windows.entry(r.window).or_default();
            w.n_kernels += 1;
            w.t_py_us += r.t_py_us;
            w.t_base_us += p2.dispatch_base_us;
            w.dct_us += lib_dct;
            w.dkt_us += p2.floor.mean;
            w.device_active_us += r.device_us;
            let orch = r.t_py_us + p2.dispatch_base_us + lib_dct + p2.floor.mean;
            if (r.phase as usize) < 2 {
                let p = &mut w.phases[r.phase as usize];
                p.invocations += 1;
                p.orchestration_us += orch;
                p.device_us += r.device_us;
                let pt = &mut phase_totals[r.phase as usize];
                pt.invocations += 1;
                pt.orchestration_us += orch;
                pt.device_us += r.device_us;
            }
        }

        // Token-only windows (scheduler steps with no kernel in-window)
        // still materialize, so the series covers the whole run.
        for (&w, &toks) in &self.token_windows {
            windows.entry(w).or_default().tokens += toks;
        }
        // `+=` above touched existing windows with 0; re-assign cleanly.
        for (w, slice) in windows.iter_mut() {
            slice.index = *w;
            slice.tokens = self.token_windows.get(w).copied().unwrap_or(slice.tokens);
            if self.window_us > 0.0 {
                slice.start_us = *w as f64 * self.window_us;
                slice.end_us = slice.start_us + self.window_us;
            } else {
                slice.start_us = 0.0;
                slice.end_us = e2e_us;
            }
        }

        OnlineReport {
            window_us: self.window_us,
            totals,
            phase_totals,
            windows: windows.into_values().collect(),
            counts: self.counts,
            streams: self.streams.into_values().collect(),
        }
    }
}

impl TraceSink for OnlineDecomposer {
    fn event(&mut self, ev: &TraceEvent) -> anyhow::Result<()> {
        self.observe(ev);
        Ok(())
    }

    fn finish(&mut self, wall_us: f64) -> anyhow::Result<()> {
        self.wall_us = wall_us;
        Ok(())
    }
}

/// Finalized online decomposition: whole-run totals (bit-identical to
/// the post-hoc [`decompose`](crate::taxbreak::decompose::decompose)),
/// the per-window series, per-phase shares, event counters and
/// per-stream activity.
#[derive(Debug, Clone)]
pub struct OnlineReport {
    pub window_us: f64,
    pub totals: Decomposition,
    pub phase_totals: [PhaseWindow; 2],
    pub windows: Vec<WindowSlice>,
    pub counts: EventCounts,
    pub streams: Vec<StreamActivity>,
}

impl OnlineReport {
    /// Kernel launches per output token over the whole run (token
    /// proxy: Σ scheduler batch). 0 when no scheduler ran (eager).
    pub fn launches_per_token(&self) -> f64 {
        if self.counts.batch_sum == 0 {
            0.0
        } else {
            self.totals.n_kernels as f64 / self.counts.batch_sum as f64
        }
    }

    /// The per-window HDBI series as `(window_start_us, hdbi)` points.
    pub fn hdbi_series(&self) -> Vec<(f64, f64)> {
        self.windows
            .iter()
            .map(|w| (w.start_us, w.hdbi()))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let d = &self.totals;
        let totals = Json::obj()
            .with("n_kernels", d.n_kernels)
            .with("t_py_us", d.t_py_us)
            .with("t_base_us", d.t_base_us)
            .with("t_fw_us", d.dft_us())
            .with("dct_us", d.dct_us)
            .with("dkt_us", d.dkt_us)
            .with("orchestration_us", d.orchestration_us())
            .with("device_active_us", d.device_active_us)
            .with("e2e_us", d.e2e_us)
            .with("hdbi", d.hdbi());
        let phases = Json::Arr(
            PHASES
                .iter()
                .zip(self.phase_totals.iter())
                .map(|(name, p)| {
                    Json::obj()
                        .with("phase", *name)
                        .with("invocations", p.invocations)
                        .with("orchestration_us", p.orchestration_us)
                        .with("device_us", p.device_us)
                        .with("hdbi", p.hdbi())
                })
                .collect(),
        );
        let windows = Json::Arr(
            self.windows
                .iter()
                .map(|w| {
                    Json::obj()
                        .with("index", w.index as usize)
                        .with("start_us", w.start_us)
                        .with("end_us", w.end_us)
                        .with("kernels", w.n_kernels)
                        .with("t_fw_us", w.t_fw_us())
                        .with("t_lib_us", w.dct_us)
                        .with("t_launch_us", w.dkt_us)
                        .with("orchestration_us", w.orchestration_us())
                        .with("device_active_us", w.device_active_us)
                        .with("hdbi", w.hdbi())
                        .with("hdbi_prefill", w.phases[0].hdbi())
                        .with("hdbi_decode", w.phases[1].hdbi())
                        .with("tokens", w.tokens)
                        .with("launches_per_token", w.launches_per_token())
                })
                .collect(),
        );
        let mut by_kind = Json::obj();
        for (k, n) in &self.counts.by_kind {
            by_kind.set(k, Json::from(*n));
        }
        let events = Json::obj()
            .with("total", self.counts.total)
            .with("recording", self.counts.recording)
            .with("by_kind", by_kind)
            .with("arrivals", self.counts.arrivals)
            .with("rng_draws", self.counts.rng_draws)
            .with("clock_jumps", self.counts.clock_jumps)
            .with("clock_jump_us", self.counts.clock_jump_us)
            .with("sched_steps", self.counts.sched_steps)
            .with("admitted", self.counts.admitted)
            .with("preempted", self.counts.preempted)
            .with("output_tokens", self.counts.batch_sum);
        let streams = Json::Arr(
            self.streams
                .iter()
                .map(|s| {
                    Json::obj()
                        .with("device", s.device)
                        .with("stream", s.stream)
                        .with("kernels", s.kernels)
                        .with("active_us", s.active_us)
                        .with("idle_fraction", self.stream_idle_fraction(s))
                })
                .collect(),
        );
        Json::obj()
            .with("window_us", self.window_us)
            .with("totals", totals)
            .with("phases", phases)
            .with("kernel_launches_per_output_token", self.launches_per_token())
            .with("windows", windows)
            .with("events", events)
            .with("streams", streams)
    }

    /// Fraction of the run a stream spent idle (1 − active/e2e).
    pub fn stream_idle_fraction(&self, s: &StreamActivity) -> f64 {
        if self.totals.e2e_us <= 0.0 {
            0.0
        } else {
            (1.0 - s.active_us / self.totals.e2e_us).clamp(0.0, 1.0)
        }
    }

    /// Register every trace-derived metric under the given model label
    /// (names and labels per `docs/metrics.md`).
    pub fn register_into(&self, reg: &mut super::MetricsRegistry, model: &str) {
        let m: &[(&str, &str)] = &[("model", model)];
        for (kind, n) in &self.counts.by_kind {
            reg.counter_add(
                "taxbreak_events_total",
                "Trace events consumed, by event kind.",
                &[("model", model), ("kind", kind)],
                *n as f64,
            );
        }
        let c = &self.counts;
        for (name, help, v) in [
            (
                "taxbreak_recording_events_total",
                "Recording events (correlation id 0): spec-v3 nondeterminism plus spec-v4 faults.",
                c.recording as f64,
            ),
            (
                "taxbreak_arrivals_total",
                "Requests that entered the serving system.",
                c.arrivals as f64,
            ),
            (
                "taxbreak_rng_draws_total",
                "Random values consumed by the engine.",
                c.rng_draws as f64,
            ),
            (
                "taxbreak_clock_jumps_total",
                "Virtual-clock jumps over idle time.",
                c.clock_jumps as f64,
            ),
            (
                "taxbreak_clock_jump_us_total",
                "Idle microseconds skipped by clock jumps.",
                c.clock_jump_us,
            ),
            (
                "taxbreak_sched_steps_total",
                "Scheduler steps (iteration-level batching).",
                c.sched_steps as f64,
            ),
            (
                "taxbreak_sched_admitted_total",
                "Requests admitted by the scheduler.",
                c.admitted as f64,
            ),
            (
                "taxbreak_sched_preempted_total",
                "Preemptions issued by the scheduler.",
                c.preempted as f64,
            ),
            (
                "taxbreak_output_tokens_total",
                "Output-token proxy: post-step active batch, summed.",
                c.batch_sum as f64,
            ),
            (
                "taxbreak_kernel_launches_total",
                "Kernel launches decomposed (Phase-1 invocations).",
                self.totals.n_kernels as f64,
            ),
            (
                "taxbreak_t_fw_us_total",
                "Framework translation time ΔFT (T_Py + dispatch baseline), us.",
                self.totals.dft_us(),
            ),
            (
                "taxbreak_t_lib_us_total",
                "Library dispatch overhead I_lib·ΔCT, us.",
                self.totals.dct_us,
            ),
            (
                "taxbreak_t_launch_us_total",
                "Kernel-launch floor ΔKT, us.",
                self.totals.dkt_us,
            ),
            (
                "taxbreak_orchestration_us_total",
                "T_Orchestration (Eq. 2), us.",
                self.totals.orchestration_us(),
            ),
            (
                "taxbreak_device_active_us_total",
                "Device-active (kernel execution) time, us.",
                self.totals.device_active_us,
            ),
        ] {
            reg.counter_add(name, help, m, v);
        }
        reg.gauge_set(
            "taxbreak_e2e_us",
            "End-to-end wall clock of the run, us.",
            m,
            self.totals.e2e_us,
        );
        reg.gauge_set(
            "taxbreak_hdbi",
            "Host-Device Balance Index (Eq. 3) over the whole run.",
            m,
            self.totals.hdbi(),
        );
        for (name, p) in PHASES.iter().zip(self.phase_totals.iter()) {
            reg.gauge_set(
                "taxbreak_phase_hdbi",
                "Per-phase HDBI over the whole run.",
                &[("model", model), ("phase", name)],
                p.hdbi(),
            );
        }
        reg.gauge_set(
            "taxbreak_kernel_launches_per_output_token",
            "Kernel launches per generated token (dispatch amplification).",
            m,
            self.launches_per_token(),
        );
        for w in &self.windows {
            let idx = w.index.to_string();
            reg.gauge_set(
                "taxbreak_window_hdbi",
                "Per-window HDBI (virtual-time windows of --window-us).",
                &[("model", model), ("window", &idx)],
                w.hdbi(),
            );
        }
        for s in &self.streams {
            let d = s.device.to_string();
            let st = s.stream.to_string();
            let labels: &[(&str, &str)] = &[("model", model), ("device", &d), ("stream", &st)];
            reg.gauge_set(
                "taxbreak_stream_active_us",
                "Device-active time per (device, stream), us.",
                labels,
                s.active_us,
            );
            reg.gauge_set(
                "taxbreak_stream_idle_fraction",
                "Idle fraction per (device, stream) over the run wall.",
                labels,
                self.stream_idle_fraction(s),
            );
        }
    }
}
