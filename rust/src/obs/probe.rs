//! Serving-side probe: KV occupancy, scheduler queue depth and latency
//! histograms sampled while the load generator runs.
//!
//! [`ServingProbe`] complements [`super::OnlineDecomposer`]: the
//! decomposer watches the *trace* and is therefore a pure function of
//! it (DESIGN.md §14), while the probe watches serving-side state that
//! never reaches the trace — free-page counts, reservation totals,
//! admission-queue depth. Replay reproduces the former bit-for-bit; the
//! probe's view is only meaningful on recorded runs (KV occupancy is
//! not modeled under replay, DESIGN.md §13), which is why
//! `replay --verify` compares trace-derived snapshots only.

use std::collections::BTreeMap;

use super::registry::{Histogram, MetricsRegistry};
use crate::util::stats::Welford;

/// Streaming sampler for serving-side state, advanced once per
/// scheduler step via [`ServingProbe::on_step`].
#[derive(Debug, Clone, Default)]
pub struct ServingProbe {
    window_us: f64,
    steps: u64,
    kv_occupancy: Histogram,
    queue_depth: Histogram,
    ttft_us: Histogram,
    tpot_us: Histogram,
    /// Per-window mean occupancy ratio (the Perfetto counter series).
    occupancy_windows: BTreeMap<u64, Welford>,
    last_used_pages: u64,
    last_reserved_pages: u64,
    last_free_pages: u64,
    total_pages: u64,
    /// Requests terminated by deadline-aware load shedding.
    sheds: u64,
    /// Transient kernel-launch re-issues paid by the backend.
    retries: u64,
    /// Requests terminated by launch-retry exhaustion.
    failed: u64,
    /// Completed requests that blew a configured TTFT/TPOT deadline.
    deadline_misses: u64,
}

impl ServingProbe {
    /// `window_us <= 0` collapses the occupancy series to one point.
    pub fn new(window_us: f64) -> ServingProbe {
        ServingProbe {
            window_us,
            ..Default::default()
        }
    }

    fn window_of(&self, t_us: f64) -> u64 {
        if self.window_us <= 0.0 {
            0
        } else {
            (t_us / self.window_us).floor().max(0.0) as u64
        }
    }

    /// Record one scheduler step's KV + queue state at virtual time
    /// `now_us`. `used` counts pages holding live tokens, `reserved`
    /// the admission-reserved worst-case pages, `free` the remainder.
    pub fn on_step(&mut self, now_us: f64, used: u64, reserved: u64, free: u64, queue: usize) {
        self.steps += 1;
        let total = used + reserved + free;
        let ratio = if total == 0 {
            0.0
        } else {
            (used + reserved) as f64 / total as f64
        };
        self.kv_occupancy.observe(ratio);
        self.queue_depth.observe(queue as f64);
        self.occupancy_windows
            .entry(self.window_of(now_us))
            .or_default()
            .push(ratio);
        self.last_used_pages = used;
        self.last_reserved_pages = reserved;
        self.last_free_pages = free;
        self.total_pages = total;
    }

    /// Observe one completed request's time-to-first-token (us).
    pub fn observe_ttft_us(&mut self, v: f64) {
        self.ttft_us.observe(v);
    }

    /// Observe one completed request's mean time-per-output-token (us).
    pub fn observe_tpot_us(&mut self, v: f64) {
        self.tpot_us.observe(v);
    }

    /// Fold one drive's resilience counters (DESIGN.md §16) into the
    /// probe — called once per replica after its drive completes.
    pub fn observe_outcomes(
        &mut self,
        sheds: u64,
        retries: u64,
        failed: u64,
        deadline_misses: u64,
    ) {
        self.sheds += sheds;
        self.retries += retries;
        self.failed += failed;
        self.deadline_misses += deadline_misses;
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Per-window mean KV occupancy ratio as `(window_start_us, ratio)`
    /// points — the KV counter-track series for the chrome exporter.
    pub fn kv_series(&self) -> Vec<(f64, f64)> {
        let w = self.window_us.max(0.0);
        self.occupancy_windows
            .iter()
            .map(|(ix, acc)| (*ix as f64 * w, acc.mean()))
            .collect()
    }

    /// Register every probe metric under the given model label (names
    /// and labels per `docs/metrics.md`).
    pub fn register_into(&self, reg: &mut MetricsRegistry, model: &str) {
        let m: &[(&str, &str)] = &[("model", model)];
        reg.counter_add(
            "taxbreak_probe_steps_total",
            "Scheduler steps sampled by the serving probe.",
            m,
            self.steps as f64,
        );
        for (name, help, v) in [
            (
                "taxbreak_sheds_total",
                "Requests terminated by deadline-aware load shedding.",
                self.sheds,
            ),
            (
                "taxbreak_launch_retries_total",
                "Transient kernel-launch re-issues paid by the backend.",
                self.retries,
            ),
            (
                "taxbreak_failed_requests_total",
                "Requests terminated by launch-retry exhaustion.",
                self.failed,
            ),
            (
                "taxbreak_deadline_misses_total",
                "Completed requests that blew a configured TTFT/TPOT deadline.",
                self.deadline_misses,
            ),
        ] {
            reg.counter_add(name, help, m, v as f64);
        }
        for (name, help, v) in [
            (
                "taxbreak_kv_pages_used",
                "KV pages holding live tokens at end of run.",
                self.last_used_pages,
            ),
            (
                "taxbreak_kv_pages_reserved",
                "KV pages reserved for admitted requests at end of run.",
                self.last_reserved_pages,
            ),
            (
                "taxbreak_kv_pages_free",
                "Free KV pages at end of run.",
                self.last_free_pages,
            ),
            (
                "taxbreak_kv_pages_total",
                "Total KV pages in the pool.",
                self.total_pages,
            ),
        ] {
            reg.gauge_set(name, help, m, v as f64);
        }
        reg.histogram_merge(
            "taxbreak_kv_occupancy_ratio",
            "Committed (used+reserved) fraction of KV pages, per step.",
            m,
            &self.kv_occupancy,
        );
        reg.histogram_merge(
            "taxbreak_sched_queue_depth",
            "Requests waiting for admission, sampled per step.",
            m,
            &self.queue_depth,
        );
        reg.histogram_merge(
            "taxbreak_ttft_us",
            "Time to first token per completed request, us.",
            m,
            &self.ttft_us,
        );
        reg.histogram_merge(
            "taxbreak_tpot_us",
            "Mean time per output token per completed request, us.",
            m,
            &self.tpot_us,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_windows_track_means() {
        let mut p = ServingProbe::new(100.0);
        p.on_step(10.0, 2, 2, 4, 0); // ratio 0.5, window 0
        p.on_step(50.0, 6, 0, 2, 1); // ratio 0.75, window 0
        p.on_step(150.0, 8, 0, 0, 3); // ratio 1.0, window 1
        let series = p.kv_series();
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].0, 0.0);
        assert!((series[0].1 - 0.625).abs() < 1e-12);
        assert_eq!(series[1], (100.0, 1.0));
        assert_eq!(p.steps(), 3);
    }

    #[test]
    fn zero_window_collapses_to_single_point() {
        let mut p = ServingProbe::new(0.0);
        p.on_step(10.0, 1, 0, 1, 0);
        p.on_step(9000.0, 1, 0, 1, 0);
        assert_eq!(p.kv_series().len(), 1);
    }

    #[test]
    fn empty_pool_counts_as_zero_occupancy() {
        let mut p = ServingProbe::new(50.0);
        p.on_step(0.0, 0, 0, 0, 5);
        assert_eq!(p.kv_series(), vec![(0.0, 0.0)]);
    }

    #[test]
    fn registers_gauges_and_histograms() {
        let mut p = ServingProbe::new(50.0);
        p.on_step(0.0, 3, 1, 4, 2);
        p.observe_ttft_us(1234.5);
        p.observe_tpot_us(88.0);
        p.observe_outcomes(2, 3, 1, 4);
        let mut reg = MetricsRegistry::new();
        p.register_into(&mut reg, "gpt2");
        let text = reg.prometheus_text();
        assert!(text.contains("taxbreak_probe_steps_total{model=\"gpt2\"} 1\n"));
        assert!(text.contains("taxbreak_sheds_total{model=\"gpt2\"} 2\n"));
        assert!(text.contains("taxbreak_launch_retries_total{model=\"gpt2\"} 3\n"));
        assert!(text.contains("taxbreak_failed_requests_total{model=\"gpt2\"} 1\n"));
        assert!(text.contains("taxbreak_deadline_misses_total{model=\"gpt2\"} 4\n"));
        assert!(text.contains("taxbreak_kv_pages_used{model=\"gpt2\"} 3\n"));
        assert!(text.contains("taxbreak_kv_pages_reserved{model=\"gpt2\"} 1\n"));
        assert!(text.contains("taxbreak_kv_pages_total{model=\"gpt2\"} 8\n"));
        assert!(text.contains("taxbreak_ttft_us_count{model=\"gpt2\"} 1\n"));
        assert!(text.contains("taxbreak_tpot_us_sum{model=\"gpt2\"} 88\n"));
        assert!(text.contains("taxbreak_sched_queue_depth_sum{model=\"gpt2\"} 2\n"));
    }
}
