//! Live observability plane: metrics registry, streaming windowed
//! decomposition, and serving-side probes.
//!
//! The paper's decomposition (Eq. 1–3) is a whole-run aggregate; this
//! module makes it a live signal. Three pieces:
//!
//! - [`registry`]: labeled counters / gauges / log-bucketed histograms
//!   with deterministic Prometheus text exposition and JSON snapshots;
//! - [`online`]: [`OnlineDecomposer`], a [`crate::trace::TraceSink`]
//!   that maintains per-window T_fw / T_lib / T_launch / HDBI slices as
//!   events stream past, with end-of-run totals bit-identical to the
//!   post-hoc [`crate::taxbreak::decompose::decompose`] pass;
//! - [`probe`]: [`ServingProbe`], sampling serving-side state (KV
//!   occupancy, queue depth, TTFT/TPOT) the trace never carries.
//!
//! `taxbreak loadgen --metrics-out <file> [--window-us N]` wires all
//! three together; metric names and labels are specified in
//! `docs/metrics.md` (pinned by a spec-drift test), semantics in
//! DESIGN.md §14.

pub mod online;
pub mod probe;
pub mod registry;

pub use online::{
    EventCounts, OnlineDecomposer, OnlineReport, PhaseWindow, StreamActivity, WindowSlice,
    ANALYZE_REPLAY_SEED, PHASES,
};
pub use probe::ServingProbe;
pub use registry::{fmt_value, Histogram, MetricKind, MetricsRegistry};

use crate::hardware::Platform;
use crate::trace::{Trace, TraceSink};

/// Per-model telemetry bundle produced by an instrumented loadgen run.
#[derive(Debug, Clone)]
pub struct Telemetry {
    /// Trace-derived windowed decomposition (pure function of the
    /// event stream + wall clock).
    pub online: OnlineReport,
    /// Serving-side samples (KV occupancy, queue depth, latency).
    pub probe: ServingProbe,
}

/// Post-hoc equivalent of the streaming path: feed every event of an
/// in-memory [`Trace`] through an [`OnlineDecomposer`] and return the
/// report plus a registry snapshot labeled with the trace's model name.
///
/// Used by `taxbreak replay --verify` and the conformance tests: the
/// result is a pure function of `(events, wall_us)`, so byte-identical
/// traces yield byte-identical snapshots (DESIGN.md §14).
pub fn snapshot_of_trace(
    trace: &Trace,
    platform: Platform,
    window_us: f64,
) -> (OnlineReport, MetricsRegistry) {
    let mut online = OnlineDecomposer::new(window_us);
    for e in &trace.events {
        online.observe(e);
    }
    let _ = TraceSink::finish(&mut online, trace.meta.wall_us);
    let report = online.finalize(platform);
    let mut reg = MetricsRegistry::new();
    report.register_into(&mut reg, &trace.meta.model);
    (report, reg)
}
