//! Fig. 7: the GPT-2/H200 case study comparing TaxBreak's HDBI against
//! prior TKLQT characterization across batch sizes.
//!
//! (a) HDBI rises monotonically with BS (host→device crossover between
//!     BS=4 and BS=8) while TKLQT blows up at saturation;
//! (b) the host orchestration decomposition stays nearly flat while
//!     T_DeviceActive grows ~10x — the crossover is device-work-driven.

use crate::hardware::Platform;
use crate::repro::{points, ReproOpts};
use crate::sim::Workload;
use crate::util::table::{ms, ratio, Table};

pub fn run(opts: &ReproOpts) -> anyhow::Result<String> {
    let model = points::model("gpt2");
    let platform = Platform::h200();
    let batches: &[usize] = if opts.full {
        &[1, 2, 4, 8, 16]
    } else {
        &[1, 4, 8, 16]
    };

    let mut a_tab = Table::new(
        "Fig. 7a — HDBI vs TKLQT, GPT-2 (SL=512) on H200",
        &["BS", "HDBI", "TKLQT (us)", "queue share"],
    );
    let mut b_tab = Table::new(
        "Fig. 7b — host orchestration decomposition vs device-active (ms)",
        &["BS", "T_Py", "T_base", "dCT", "T_sys", "T_orch", "T_dev", "per-kern host (us)"],
    );

    for &bs in batches {
        let a = points::analyze_point(&model, &platform, &Workload::prefill(bs, 512), opts.seed);
        let d = &a.decomposition;
        a_tab.row(vec![
            bs.to_string(),
            ratio(d.hdbi()),
            format!("{:.0}", a.baselines.tklqt_us),
            format!("{:.0}%", 100.0 * a.baselines.queue_share),
        ]);
        b_tab.row(vec![
            bs.to_string(),
            ms(d.t_py_us / 1000.0),
            ms(d.t_base_us / 1000.0),
            ms(d.dct_us / 1000.0),
            ms(d.dkt_us / 1000.0),
            ms(d.orchestration_us() / 1000.0),
            ms(d.device_active_us / 1000.0),
            format!("{:.1}", d.per_kernel_host_us()),
        ]);
    }
    Ok(format!(
        "{}\n{}\nShape checks: HDBI 0.25→0.74 with crossover between \
         BS=4 and BS=8; T_orch flat (~5 ms) and dCT == 0 \
         (framework-native nvjet GEMMs); per-kernel host cost ≈ 13.7 us \
         constant; T_dev grows ~10x.\n",
        a_tab.render(),
        b_tab.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[ignore = "multi-point replay; run in release via `taxbreak repro fig7`"]
    fn renders() {
        let out = run(&ReproOpts::default()).unwrap();
        assert!(out.contains("Fig. 7a"));
    }
}
