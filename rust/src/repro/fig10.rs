//! Fig. 10: latency decomposition H100 vs H200 — T_Orchestration and
//! T_DeviceActive stacked per platform pair, Llama-3.2-1B and
//! Qwen1.5-MoE at {BS1/SL512, BS4/SL2048} × {prefill, decode}.
//!
//! Both GPUs are Hopper; the H200's GPU is clocked −9.9% but its host
//! CPU is faster — isolating CPU single-thread speed (paper §VI).

use crate::hardware::Platform;
use crate::repro::{points, ReproOpts};
use crate::sim::{Phase, Workload};
use crate::util::table::{ms, Table};

pub const MODELS: [&str; 2] = ["llama-3.2-1b", "qwen1.5-moe-a2.7b"];
pub const CONFIGS: [(usize, usize); 2] = [(1, 512), (4, 2048)];

pub fn run(opts: &ReproOpts) -> anyhow::Result<String> {
    let mut t = Table::new(
        "Fig. 10 — H100 vs H200 decomposition (ms; decode = m=10 totals)",
        &[
            "model", "phase", "BS/SL",
            "orch H100", "orch H200", "orch delta",
            "dev H100", "dev H200",
            "e2e H100", "e2e H200", "e2e delta",
        ],
    );
    for name in MODELS {
        let model = points::model(name);
        for phase in [Phase::Prefill, Phase::Decode] {
            for (bs, sl) in CONFIGS {
                let wl = match phase {
                    Phase::Prefill => Workload::prefill(bs, sl),
                    Phase::Decode => Workload::decode(bs, sl, points::M_TOKENS),
                };
                let a100 = points::analyze_point(&model, &Platform::h100(), &wl, opts.seed);
                let a200 = points::analyze_point(&model, &Platform::h200(), &wl, opts.seed);
                let (o1, o2) = (
                    a100.decomposition.orchestration_us(),
                    a200.decomposition.orchestration_us(),
                );
                let (e1, e2) = (a100.decomposition.e2e_us, a200.decomposition.e2e_us);
                t.row(vec![
                    model.display.clone(),
                    phase.as_str().to_string(),
                    format!("{bs}/{sl}"),
                    ms(o1 / 1000.0),
                    ms(o2 / 1000.0),
                    format!("-{:.0}%", 100.0 * (1.0 - o2 / o1)),
                    ms(a100.decomposition.device_active_us / 1000.0),
                    ms(a200.decomposition.device_active_us / 1000.0),
                    ms(e1 / 1000.0),
                    ms(e2 / 1000.0),
                    format!("-{:.0}%", 100.0 * (1.0 - e2 / e1)),
                ]);
            }
        }
    }
    Ok(format!(
        "{}\nShape checks: T_Orchestration consistently 10-29% lower on \
         H200 (faster host CPU); T_DeviceActive comparable or slightly \
         worse (−9.9% GPU clock); for host-bound MoE the CPU gain \
         outweighs the GPU penalty end-to-end.\n",
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[ignore = "16 analysis points; run in release via `taxbreak repro fig10`"]
    fn renders() {
        let out = run(&ReproOpts::default()).unwrap();
        assert!(out.contains("Fig. 10"));
    }
}
