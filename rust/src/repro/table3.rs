//! Table III: null-kernel `T_sys_floor` measured in isolation on both
//! platforms (avg / p50 / p5 / p95).

use crate::hardware::Platform;
use crate::repro::ReproOpts;
use crate::taxbreak::{ReplayBackend, ReplayConfig, SimReplayBackend};
use crate::util::stats::Summary;
use crate::util::table::{us, Table};

pub fn run(opts: &ReproOpts) -> anyhow::Result<String> {
    let mut t = Table::new(
        "Table III — null-kernel T_sys_floor (us), isolation protocol (W=50, R=150)",
        &["GPU", "avg", "p50", "p5", "p95"],
    );
    for platform in [Platform::h100(), Platform::h200()] {
        let mut backend = SimReplayBackend::new(platform.clone(), opts.seed);
        let runs = backend.null_kernel(&ReplayConfig::paper());
        let s = Summary::of(&runs);
        t.row(vec![
            platform.gpu.name.clone(),
            us(s.mean),
            us(s.p50),
            us(s.p5),
            us(s.p95),
        ]);
    }
    Ok(format!(
        "{}\nPaper reference: H100 ≈ 4.72 avg (p5 4.26); H200 avg 4.503, \
         p50 4.452, p5 4.177, p95 4.909. Floors are small and stable \
         across Hopper platforms.\n",
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floors_near_paper() {
        let out = run(&ReproOpts::default()).unwrap();
        assert!(out.contains("H100"));
        assert!(out.contains("H200"));
    }
}
