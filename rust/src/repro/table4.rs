//! Table IV: per-family launch latency (p50/p95) relative to the floor
//! for Llama-3.2-3B and OLMoE-1B/7B (BS=1/SL=512 prefill, H100) —
//! `ΔKT_fw = p50 − T_sys_floor` per family.

use crate::hardware::Platform;
use crate::repro::{points, ReproOpts};
use crate::sim::Workload;
use crate::taxbreak::report;

pub fn run(opts: &ReproOpts) -> anyhow::Result<String> {
    let platform = Platform::h100();
    let mut out = String::new();
    for name in ["llama-3.2-3b", "olmoe-1b-7b"] {
        let model = points::model(name);
        let a = points::analyze_point(&model, &platform, &Workload::prefill(1, 512), opts.seed);
        let t = report::family_launch_table(
            &format!(
                "Table IV — per-family launch latency (us), {} (BS=1/SL=512 prefill, H100)",
                model.display
            ),
            &a,
        );
        out.push_str(&t.render());
        out.push('\n');
    }
    out.push_str(
        "Shape checks: scan/elementwise/reduce families launch within \
         ~7-12% of the floor; GEMM families carry the largest ΔKT_fw \
         (cuBLAS ≈ +40%), supporting the floor/ΔKT_fw split.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[ignore = "replay over full prefill DB; run in release via `taxbreak repro table4`"]
    fn table_renders() {
        let out = run(&ReproOpts::default()).unwrap();
        assert!(out.contains("GEMM (cuBLAS)"));
    }
}
