//! Fig. 8: H200 T_Orchestration decomposition + HDBI across dense and
//! MoE workloads — prefill (m=1) and decode (m=10 totals) at
//! {BS1/SL512, BS4/SL512, BS1/SL4096, BS4/SL4096}.

use crate::hardware::Platform;
use crate::repro::{points, ReproOpts};
use crate::sim::{Phase, Workload};
use crate::util::table::{ms, ratio, Table};

const MODELS: [&str; 4] = ["llama-3.2-1b", "llama-3.2-3b", "olmoe-1b-7b", "qwen1.5-moe-a2.7b"];
const POINTS: [(usize, usize); 4] = [(1, 512), (4, 512), (1, 4096), (4, 4096)];

pub fn run(opts: &ReproOpts) -> anyhow::Result<String> {
    let platform = Platform::h200();
    let mut out = String::new();
    for name in MODELS {
        let model = points::model(name);
        let mut t = Table::new(
            &format!(
                "Fig. 8 — {} T_Orchestration decomposition + HDBI, H200 (decode totals over m=10)",
                model.display
            ),
            &["phase", "BS/SL", "T_Py", "T_base", "dCT", "T_sys", "T_orch(ms)", "T_dev(ms)", "HDBI"],
        );
        for phase in [Phase::Prefill, Phase::Decode] {
            for (bs, sl) in POINTS {
                let wl = match phase {
                    Phase::Prefill => Workload::prefill(bs, sl),
                    Phase::Decode => Workload::decode(bs, sl, points::M_TOKENS),
                };
                let a = points::analyze_point(&model, &platform, &wl, opts.seed);
                let d = &a.decomposition;
                t.row(vec![
                    phase.as_str().to_string(),
                    format!("{bs}/{sl}"),
                    ms(d.t_py_us / 1000.0),
                    ms(d.t_base_us / 1000.0),
                    ms(d.dct_us / 1000.0),
                    ms(d.dkt_us / 1000.0),
                    ms(d.orchestration_us() / 1000.0),
                    ms(d.device_active_us / 1000.0),
                    ratio(d.hdbi()),
                ]);
            }
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out.push_str(
        "Shape checks: dense — balanced prefill (HDBI≈0.4), host-visible \
         small decode (≈0.23), returning device-dominant as BS/SL grow. \
         MoE — host-bound in prefill (HDBI≈0.15) and stays host-bound \
         across ALL decode points; decode orchestration ≈ 10x the \
         single-pass prefill value (m=10 multiplicative).\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[ignore = "32 analysis points; run in release via `taxbreak repro fig8`"]
    fn renders() {
        let out = run(&ReproOpts::default()).unwrap();
        assert!(out.contains("Fig. 8"));
    }
}
