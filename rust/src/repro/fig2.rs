//! Fig. 2: the prior-work view of GPT-2 across batch sizes —
//! end-to-end latency (framework-bound → compute-bound transition,
//! the framework-tax characterization [14]) and TKLQT (the kernel
//! launch/queue tax [30]).

use crate::hardware::Platform;
use crate::repro::{points, ReproOpts};
use crate::sim::Workload;
use crate::taxbreak::baselines;
use crate::trace::Trace;
use crate::util::table::{ms, Table};

pub fn run(opts: &ReproOpts) -> anyhow::Result<String> {
    let model = points::model("gpt2");
    let platform = Platform::h200();
    let batches: &[usize] = if opts.full {
        &[1, 2, 4, 8, 16]
    } else {
        &[1, 4, 16]
    };

    let mut t = Table::new(
        "Fig. 2 — GPT-2 prior-work characterizations (SL=512, H200 prefill)",
        &["BS", "e2e (ms)", "device (ms)", "fw tax (ms)", "TKLQT (us)", "TKLQT/kern (us)"],
    );
    for &bs in batches {
        let trace: Trace = crate::sim::simulate(
            &model,
            &platform,
            &Workload::prefill(bs, 512),
            opts.seed,
        );
        let b = baselines::compute(&trace);
        t.row(vec![
            bs.to_string(),
            ms(trace.e2e_us() / 1000.0),
            ms(trace.device_active_us() / 1000.0),
            ms(b.framework_tax_us / 1000.0),
            format!("{:.0}", b.tklqt_us),
            format!("{:.1}", b.tklqt_us / b.n_kernels.max(1) as f64),
        ]);
    }
    Ok(format!(
        "{}\nShape check: latency transitions framework-bound (flat) → \
         compute-bound (scaling), while TKLQT/kernel rises with GPU \
         occupancy at large BS.\n",
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_rows() {
        let out = run(&ReproOpts::default()).unwrap();
        assert!(out.contains("Fig. 2"));
        assert!(out.lines().count() >= 6);
    }
}
