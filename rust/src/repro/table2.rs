//! Table II: kernel fragmentation for dense vs MoE models on H100
//! (BS=4 / SL=2048, m=10 decode): total launches, unique names,
//! kernels/token, diversity ratio, GPU utilization.

use crate::hardware::Platform;
use crate::kernels::KernelDb;
use crate::repro::{points, ReproOpts};
use crate::sim::{simulate, Workload};
use crate::util::table::{count, Table};

const MODELS: [&str; 4] = ["llama-3.2-1b", "llama-3.2-3b", "olmoe-1b-7b", "qwen1.5-moe-a2.7b"];

pub fn run(opts: &ReproOpts) -> anyhow::Result<String> {
    let platform = Platform::h100();
    let wl = Workload::decode(4, 2048, points::M_TOKENS);

    let mut t = Table::new(
        "Table II — kernel fragmentation, H100 (BS=4/SL=2048, m=10)",
        &[
            "Metric",
            "Llama-3.2-1B",
            "Llama-3.2-3B",
            "OLMoE-1B/7B",
            "Qwen1.5-MoE",
        ],
    );

    let mut totals = Vec::new();
    let mut uniques = Vec::new();
    let mut per_tok = Vec::new();
    let mut diversity = Vec::new();
    let mut util = Vec::new();
    for name in MODELS {
        let model = points::model(name);
        let trace = simulate(&model, &platform, &wl, opts.seed);
        let db = KernelDb::from_trace(&trace);
        totals.push(count(db.total_invocations()));
        uniques.push(db.unique_names().to_string());
        per_tok.push(format!(
            "{:.1}",
            db.total_invocations() as f64 / points::M_TOKENS as f64
        ));
        diversity.push(format!("{:.4}", db.diversity_ratio()));
        util.push(format!(
            "{:.1}",
            100.0 * trace.device_active_us() / trace.e2e_us()
        ));
    }
    let mut push = |label: &str, vals: &[String]| {
        let mut row = vec![label.to_string()];
        row.extend(vals.iter().cloned());
        t.row(row);
    };
    push("Total kernel launches", &totals);
    push("Unique kernel names", &uniques);
    push("Kernels per token", &per_tok);
    push("Diversity ratio", &diversity);
    push("GPU utilization (%)", &util);

    Ok(format!(
        "{}\nShape checks: MoE launches 8-11x dense per token; MoE \
         diversity ratio LOWER than dense (repeated routing/expert \
         kernels, not heterogeneity); MoE GPU utilization far below \
         dense.\n",
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[ignore = "heavy trace (93k kernels); run in release via `taxbreak repro table2`"]
    fn fragmentation_shape() {
        let out = run(&ReproOpts::default()).unwrap();
        assert!(out.contains("Diversity ratio"));
    }
}
