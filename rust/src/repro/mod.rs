//! Regeneration harnesses for every table and figure in the paper's
//! evaluation (DESIGN.md §5 experiment index).
//!
//! Each `figN`/`tableN` function reproduces the corresponding artifact's
//! rows/series as text tables. Absolute values come from the calibrated
//! simulator; the *shape* (who wins, by what factor, where crossovers
//! fall) is the reproduction target.
//!
//! Run via `taxbreak repro <id>` (or `repro all`).

pub mod points;

mod fig10;
mod fig11;
mod fig2;
mod fig5;
mod fig6;
mod fig7;
mod fig8;
mod fig9;
mod table2;
mod table3;
mod table4;

/// All artifact ids in paper order.
pub const ALL: [&str; 11] = [
    "fig2", "fig5", "fig6", "table2", "table3", "table4", "fig7", "fig8",
    "fig9", "fig10", "fig11",
];

/// Options common to the harnesses.
#[derive(Debug, Clone, Copy)]
pub struct ReproOpts {
    /// Full paper grids (slower) vs reduced grids.
    pub full: bool,
    pub seed: u64,
}

impl Default for ReproOpts {
    fn default() -> Self {
        ReproOpts {
            full: false,
            seed: 2026,
        }
    }
}

/// Run one artifact regeneration; returns the rendered report.
pub fn run(id: &str, opts: &ReproOpts) -> anyhow::Result<String> {
    match id {
        "fig2" => fig2::run(opts),
        "fig5" => fig5::run(opts),
        "fig6" => fig6::run(opts),
        "table2" => table2::run(opts),
        "table3" => table3::run(opts),
        "table4" => table4::run(opts),
        "fig7" => fig7::run(opts),
        "fig8" => fig8::run(opts),
        "fig9" => fig9::run(opts),
        "fig10" => fig10::run(opts),
        "fig11" => fig11::run(opts),
        "all" => {
            let mut out = String::new();
            for id in ALL {
                out.push_str(&run(id, opts)?);
                out.push('\n');
            }
            Ok(out)
        }
        other => anyhow::bail!(
            "unknown artifact '{other}' (expected one of: {}, all)",
            ALL.join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_errors() {
        assert!(run("fig99", &ReproOpts::default()).is_err());
    }

    #[test]
    fn fig2_runs_reduced() {
        let out = run("fig2", &ReproOpts::default()).unwrap();
        assert!(out.contains("TKLQT"));
    }
}
