//! Fig. 5: end-to-end latency heatmaps across dense and MoE workloads —
//! BS × SL grids for prefill (m=1) and decode (m=10) on H100/H200.

use crate::hardware::Platform;
use crate::repro::{points, ReproOpts};
use crate::sim::{Phase, Workload};
use crate::util::table::{ms, Table};

const MODELS: [&str; 4] = ["llama-3.2-1b", "llama-3.2-3b", "olmoe-1b-7b", "qwen1.5-moe-a2.7b"];

pub fn run(opts: &ReproOpts) -> anyhow::Result<String> {
    let mut out = String::new();
    let batches = points::batch_grid(opts.full);
    let seqs = points::seq_grid(opts.full);

    for platform in [Platform::h100(), Platform::h200()] {
        for phase in [Phase::Prefill, Phase::Decode] {
            for name in MODELS {
                let model = points::model(name);
                let mut header: Vec<String> = vec!["BS \\ SL".to_string()];
                header.extend(seqs.iter().map(|s| s.to_string()));
                let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
                let mut t = Table::new(
                    &format!(
                        "Fig. 5 — {} {} latency (ms), {}",
                        model.display,
                        phase.as_str(),
                        platform.name
                    ),
                    &header_refs,
                );
                for &bs in &batches {
                    let mut row = vec![bs.to_string()];
                    for &sl in &seqs {
                        if !points::model_supports_seq(&model, sl) {
                            row.push("n/a".to_string());
                            continue;
                        }
                        let wl = match phase {
                            Phase::Prefill => Workload::prefill(bs, sl),
                            Phase::Decode => Workload::decode(bs, sl, points::M_TOKENS),
                        };
                        let s = points::summarize(&model, &platform, &wl, opts.seed);
                        row.push(ms(s.wall_us / 1000.0));
                    }
                    t.row(row);
                }
                out.push_str(&t.render());
                out.push('\n');
            }
        }
    }
    out.push_str(
        "Shape checks: dense prefill scales ~SL^2 at long context and \
         amortizes batch well; dense decode accumulates per-step cost; \
         MoE decode stays nearly flat across SL (dispatch-dominated); \
         H200 wins everywhere, most at short context / decode.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[ignore = "sweep: run with --ignored (release) or via `taxbreak repro fig5`"]
    fn full_grid_renders() {
        let out = run(&ReproOpts::default()).unwrap();
        assert!(out.contains("Llama-3.2-1B"));
        assert!(out.contains("n/a")); // OLMoE SL=8192 gap
    }
}
