//! Shared helpers for the repro harnesses: run a workload point through
//! the simulator and the TaxBreak pipeline.

use crate::hardware::Platform;
use crate::models::{self, ModelSpec};
use crate::sim::{simulate, simulate_summary, SimSummary, Workload};
use crate::taxbreak::{analyze, Analysis, ReplayConfig, SimReplayBackend};

/// Decode window used throughout the paper's evaluation (m = 10).
pub const M_TOKENS: usize = 10;

/// Resolve a model or panic with context (repro ids are hard-coded).
pub fn model(name: &str) -> ModelSpec {
    models::by_name(name).expect("catalog model")
}

/// Full TaxBreak analysis of one workload point (trace + 2-phase
/// pipeline with the paper's W=50/R=150 protocol).
pub fn analyze_point(
    model: &ModelSpec,
    platform: &Platform,
    wl: &Workload,
    seed: u64,
) -> Analysis {
    let trace = simulate(model, platform, wl, seed);
    let mut backend = SimReplayBackend::new(platform.clone(), seed ^ 0x9E37);
    analyze(&trace, &mut backend, &ReplayConfig::paper())
}

/// Aggregates-only simulation of one point.
pub fn summarize(model: &ModelSpec, platform: &Platform, wl: &Workload, seed: u64) -> SimSummary {
    simulate_summary(model, platform, wl, seed)
}

/// The Fig. 5/6 heatmap grids.
pub fn batch_grid(full: bool) -> Vec<usize> {
    if full {
        vec![1, 4, 8, 16]
    } else {
        vec![1, 4, 16]
    }
}

pub fn seq_grid(full: bool) -> Vec<usize> {
    if full {
        vec![512, 1024, 2048, 4096, 8192]
    } else {
        vec![512, 2048, 8192]
    }
}

/// OLMoE does not support SL=8192 (paper Fig. 5 note).
pub fn model_supports_seq(model: &ModelSpec, seq: usize) -> bool {
    !(model.name == "olmoe-1b-7b" && seq >= 8192)
}
