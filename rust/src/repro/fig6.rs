//! Fig. 6: GPU idle fraction across BS × SL on H200 — dense
//! (Llama-3.2-3B) vs MoE (Qwen1.5-MoE-A2.7B), prefill and decode.

use crate::hardware::Platform;
use crate::repro::{points, ReproOpts};
use crate::sim::{Phase, Workload};
use crate::util::table::Table;

pub fn run(opts: &ReproOpts) -> anyhow::Result<String> {
    let platform = Platform::h200();
    let mut out = String::new();
    let batches = points::batch_grid(opts.full);
    let seqs = points::seq_grid(opts.full);

    for name in ["llama-3.2-3b", "qwen1.5-moe-a2.7b"] {
        let model = points::model(name);
        for phase in [Phase::Prefill, Phase::Decode] {
            let mut header: Vec<String> = vec!["BS \\ SL".to_string()];
            header.extend(seqs.iter().map(|s| s.to_string()));
            let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
            let mut t = Table::new(
                &format!(
                    "Fig. 6 — {} {} idle fraction (%), H200",
                    model.display,
                    phase.as_str()
                ),
                &header_refs,
            );
            for &bs in &batches {
                let mut row = vec![bs.to_string()];
                for &sl in &seqs {
                    let wl = match phase {
                        Phase::Prefill => Workload::prefill(bs, sl),
                        Phase::Decode => Workload::decode(bs, sl, points::M_TOKENS),
                    };
                    let s = points::summarize(&model, &platform, &wl, opts.seed);
                    row.push(format!("{:.1}", 100.0 * s.idle_fraction()));
                }
                t.row(row);
            }
            out.push_str(&t.render());
            out.push('\n');
        }
    }
    out.push_str(
        "Shape checks: dense idle fraction collapses to <3% once BS/SL \
         grow (compute-bound); MoE idle stays high across the entire \
         sweep — batching does not remove expert-routing dispatch.\n",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[ignore = "sweep: run with --ignored (release) or via `taxbreak repro fig6`"]
    fn grid_renders() {
        let out = run(&ReproOpts::default()).unwrap();
        assert!(out.contains("idle fraction"));
    }
}
