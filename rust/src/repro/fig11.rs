//! Fig. 11: end-to-end latency gain (H100→H200) vs HDBI scatter —
//! host-bound points benefit most from the faster CPU; device-bound
//! points see attenuated gains.

use crate::hardware::Platform;
use crate::repro::{points, ReproOpts};
use crate::sim::{Phase, Workload};
use crate::util::table::{ratio, Table};

pub fn run(opts: &ReproOpts) -> anyhow::Result<String> {
    let mut t = Table::new(
        "Fig. 11 — e2e latency gain (H100→H200) vs HDBI",
        &["model", "phase", "BS/SL", "HDBI (H100)", "e2e gain (%)"],
    );
    let mut series: Vec<(f64, f64)> = Vec::new();
    for name in super::fig10::MODELS {
        let model = points::model(name);
        for phase in [Phase::Prefill, Phase::Decode] {
            for (bs, sl) in super::fig10::CONFIGS {
                let wl = match phase {
                    Phase::Prefill => Workload::prefill(bs, sl),
                    Phase::Decode => Workload::decode(bs, sl, points::M_TOKENS),
                };
                let a100 = points::analyze_point(&model, &Platform::h100(), &wl, opts.seed);
                let a200 = points::analyze_point(&model, &Platform::h200(), &wl, opts.seed);
                let hdbi = a100.decomposition.hdbi();
                let gain =
                    100.0 * (1.0 - a200.decomposition.e2e_us / a100.decomposition.e2e_us);
                series.push((hdbi, gain));
                t.row(vec![
                    model.display.clone(),
                    phase.as_str().to_string(),
                    format!("{bs}/{sl}"),
                    ratio(hdbi),
                    format!("{gain:.1}"),
                ]);
            }
        }
    }
    // Rank correlation between (1 - HDBI) and the gain: host-bound
    // points should gain most.
    let n = series.len() as f64;
    let mean_h: f64 = series.iter().map(|(h, _)| h).sum::<f64>() / n;
    let mean_g: f64 = series.iter().map(|(_, g)| g).sum::<f64>() / n;
    let cov: f64 = series
        .iter()
        .map(|(h, g)| (h - mean_h) * (g - mean_g))
        .sum::<f64>();
    let var_h: f64 = series.iter().map(|(h, _)| (h - mean_h).powi(2)).sum::<f64>();
    let var_g: f64 = series.iter().map(|(_, g)| (g - mean_g).powi(2)).sum::<f64>();
    let corr = cov / (var_h * var_g).sqrt().max(1e-12);
    Ok(format!(
        "{}\ncorr(HDBI, gain) = {corr:.2} — negative: the lower the HDBI \
         (more host-bound), the larger the end-to-end win from the \
         faster host CPU. The effect weakens as HDBI rises above ≈0.3.\n",
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[ignore = "16 analysis points; run in release via `taxbreak repro fig11`"]
    fn renders() {
        let out = run(&ReproOpts::default()).unwrap();
        assert!(out.contains("corr(HDBI, gain)"));
    }
}
