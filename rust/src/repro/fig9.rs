//! Fig. 9: eager vs FlashAttention-2 for Llama-3.2-1B on H200 —
//! e2e runtime, T_Orchestration, GPU utilization, HDBI and kernel
//! counts at BS=1/SL=512 and BS=8/SL=2048 (prefill).

use crate::hardware::Platform;
use crate::repro::{points, ReproOpts};
use crate::sim::Workload;
use crate::util::table::{ms, ratio, Table};

pub fn run(opts: &ReproOpts) -> anyhow::Result<String> {
    let model = points::model("llama-3.2-1b");
    let platform = Platform::h200();

    let mut t = Table::new(
        "Fig. 9 — eager vs FlashAttention-2, Llama-3.2-1B on H200 (prefill)",
        &["BS/SL", "mode", "e2e (ms)", "T_orch (ms)", "T_dev (ms)", "GPU util", "HDBI", "kernels"],
    );
    let mut summary = String::new();
    for (bs, sl) in [(1usize, 512usize), (8, 2048)] {
        let mut cells: Vec<(f64, f64, usize)> = Vec::new();
        for fused in [false, true] {
            let wl = Workload::prefill(bs, sl).with_fused_attention(fused);
            let a = points::analyze_point(&model, &platform, &wl, opts.seed);
            let d = &a.decomposition;
            cells.push((d.e2e_us, d.orchestration_us(), d.n_kernels));
            t.row(vec![
                format!("{bs}/{sl}"),
                if fused { "FA2" } else { "eager" }.to_string(),
                ms(d.e2e_us / 1000.0),
                ms(d.orchestration_us() / 1000.0),
                ms(d.device_active_us / 1000.0),
                format!("{:.1}%", 100.0 * d.gpu_utilization()),
                ratio(d.hdbi()),
                d.n_kernels.to_string(),
            ]);
        }
        let (e_eager, o_eager, k_eager) = cells[0];
        let (e_fa2, o_fa2, k_fa2) = cells[1];
        summary.push_str(&format!(
            "BS={bs}/SL={sl}: e2e -{:.1}%, T_orch -{:.1}%, kernels -{:.0}% \
             ({} -> {})\n",
            100.0 * (1.0 - e_fa2 / e_eager),
            100.0 * (1.0 - o_fa2 / o_eager),
            100.0 * (1.0 - k_fa2 as f64 / k_eager as f64),
            k_eager,
            k_fa2,
        ));
    }
    Ok(format!(
        "{}\n{}Shape checks: small config — modest e2e and orch gains; \
         large config — large e2e collapse driven by device-side \
         attention-traffic elimination while orchestration falls only \
         modestly. HDBI *decreases* despite both absolute values \
         improving: FA2 removes device work faster than host overhead \
         (the boundedness-ratio pitfall TaxBreak resolves).\n",
        t.render(),
        summary
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[ignore = "4 analysis points; run in release via `taxbreak repro fig9`"]
    fn renders() {
        let out = run(&ReproOpts::default()).unwrap();
        assert!(out.contains("FA2"));
    }
}
