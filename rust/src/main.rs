//! `taxbreak` — CLI for the TaxBreak reproduction.
//!
//! Subcommands:
//! * `repro <fig2|fig5|fig6|table2|table3|table4|fig7|fig8|fig9|fig10|fig11|all>`
//!   — regenerate a paper table/figure.
//! * `analyze` — simulate one workload point and print the full
//!   TaxBreak decomposition, diagnosis and baselines.
//! * `trace` — simulate and dump a trace (json / chrome format).
//! * `serve` — serving demo over a runtime backend: the deterministic
//!   simulated engine by default (`--backend sim`), or PJRT artifacts
//!   with `--backend pjrt` when built with `--features real-pjrt` (see
//!   `examples/e2e_serving.rs` for the scripted version).
//! * `loadgen` — arrival-driven load test of the serving scheduler:
//!   Poisson arrivals, configurable length distributions, a
//!   dense-vs-MoE model mix, and a throughput/TTFT/TPOT/KV-occupancy
//!   report with per-phase HDBI; `--capture`/`--chrome-out` save each
//!   run's trace for replay and timeline inspection, `--bench-out`
//!   emits the compact benchmark datapoint, `--metrics-out` streams the
//!   run through the live telemetry plane (`obs`) and writes a
//!   Prometheus text + JSON metrics snapshot, with `--window-us`
//!   controlling the per-window HDBI series resolution; `--faults`
//!   injects a deterministic fault plan (device stalls, host jitter
//!   storms, transient launch failures, KV pressure) recorded as
//!   spec-v4 `fault` events, and `--ttft-deadline-us` /
//!   `--tpot-deadline-us` arm deadline-aware load shedding.
//! * `replay` — deterministic re-execution of a spec-v3 serving capture
//!   (`loadgen --capture`): arrivals, RNG draws and scheduler decisions
//!   are replayed from the recorded events, not re-decided; `--verify`
//!   proves record → replay → re-record is byte-identical in both trace
//!   dialects, `--counterfactual` runs whatif prescriptions against the
//!   replayed timeline.
//! * `whatif` — counterfactual replay: re-simulate a recorded trace (or
//!   a fresh workload point, or a `--bundled` preset) under composable
//!   transforms — host-CPU scaling, CUDA-graph amortization, library
//!   dispatch elision, kernel fusion / MoE dispatch reduction, device
//!   swap — and report predicted e2e/HDBI/component deltas next to the
//!   baseline.
//! * `convert` — round-trip a trace between the canonical JSON dialect
//!   and the compact binary dialect (`.tbt`); input format is detected
//!   by magic, output follows the extension (or `--to`); `--salvage`
//!   recovers the longest valid event prefix of a truncated binary
//!   capture (crashed writer, lost trailer) instead of erroring.
//! * `bench-trace` — encode/decode throughput and bytes-per-event for
//!   both trace dialects on the bundled moe-decode capture (the
//!   `BENCH_trace.json` datapoint).
//! * `models` / `platforms` — list the catalog.

use taxbreak::hardware::Platform;
use taxbreak::models;
use taxbreak::repro::{self, ReproOpts};
use taxbreak::sim::{simulate, Phase};
use taxbreak::taxbreak::{analyze, report, SimReplayBackend};
use taxbreak::trace::chrome;
use taxbreak::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let mut args = Args::from_env();
    let cmd = args.shift().unwrap_or_else(|| "help".to_string());
    match cmd.as_str() {
        "repro" => cmd_repro(args),
        "analyze" => cmd_analyze(args),
        "trace" => cmd_trace(args),
        "serve" => cmd_serve(args),
        "loadgen" => cmd_loadgen(args),
        "replay" => cmd_replay(args),
        "whatif" => cmd_whatif(args),
        "convert" => cmd_convert(args),
        "bench-trace" => cmd_bench_trace(args),
        "models" => {
            for m in models::catalog() {
                println!(
                    "{:<22} {:<20} layers={:<3} params={:.2}B active={:.2}B {}",
                    m.name,
                    m.display,
                    m.layers,
                    m.params_total() / 1e9,
                    m.params_active() / 1e9,
                    if m.is_moe() { "moe" } else { "dense" }
                );
            }
            Ok(())
        }
        "platforms" => {
            for p in Platform::all() {
                println!(
                    "{:<6} gpu={} ({} MHz, {} GB/s, floor {:.2}us) cpu={} (st x{:.2})",
                    p.name,
                    p.gpu.name,
                    p.gpu.clock_mhz,
                    p.gpu.hbm_gbps,
                    p.gpu.t_sys_floor_us,
                    p.cpu.name,
                    p.cpu.st_speed
                );
            }
            Ok(())
        }
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' — try `taxbreak help`"),
    }
}

const HELP: &str = "\
taxbreak — trace-driven decomposition of host-side LLM inference overhead

USAGE:
  taxbreak repro <id|all> [--full] [--seed N] [--out FILE]
  taxbreak analyze [--config run.json] --model M --platform h100|h200
                   [--phase prefill|decode] [--bs N] [--sl N] [--m N]
                   [--fused] [--mitigation none|torch-compile|cuda-graphs|
                    kernel-fusion] [--tensor-parallel N | --expert-parallel N]
                   [--json]
  taxbreak analyze --trace FILE [--json]       (decompose a saved trace)
  taxbreak trace   --model M --platform P [--phase ...] [--bs] [--sl] [--m]
                   [--tensor-parallel N | --expert-parallel N]
                   --out FILE (.json or .tbt) [--chrome FILE]
  taxbreak serve   [--backend sim|pjrt] [--requests N] [--max-batch N]
                   [--report FILE] [--seed N]
                   sim:  [--model M] [--platform h100|h200]
                   pjrt: --artifacts DIR [--variant dense_fused]
                         (requires building with --features real-pjrt)
  taxbreak loadgen [--models M1,M2] [--platform h100|h200] [--requests N]
                   [--rate REQ_PER_S] [--prompt-dist uniform:LO:HI|lognormal:MED:SIGMA]
                   [--out-dist ...] [--max-batch N] [--max-groups N]
                   [--kv-pages N] [--kv-page-tokens N] [--seed N]
                   [--devices N] [--streams N] [--report FILE]
                   [--capture FILE] [--chrome-out FILE] [--bench-out FILE]
                   [--metrics-out FILE] [--window-us US]
                   [--faults SPEC[;SPEC...]] [--ttft-deadline-us US]
                   [--tpot-deadline-us US]
                   fault SPEC: stall:ONSET:DUR:MAG[:STREAM]
                         | jitter:ONSET:DUR:MAG[:prep|exec|all]
                         | launchfail:ONSET:DUR:ATTEMPTS
                         | kv:ONSET:DUR:FRAC | storm:SEED:N
                   (faults are injected deterministically and recorded as
                    spec-v4 `fault` events, so faulted captures replay
                    byte-identically; deadlines enable load shedding)
  taxbreak replay  <TRACE> [--counterfactual SPEC[,SPEC...]] [--verify]
                   [--json] [--report FILE]
                   (re-drive a `loadgen --capture` recording; --verify
                    byte-compares the re-recording in both dialects and
                    checks the telemetry snapshot is a fixed point too)
  taxbreak whatif  --counterfactual SPEC[,SPEC...]
                   [--trace FILE | --bundled moe-decode|dense-prefill |
                    --model M --platform P --phase ... --bs --sl --m]
                   [--json] [--report FILE] [--chrome FILE]
                   SPEC: host-cpu:<profile|factor> | cuda-graphs[:LAUNCH_US]
                         | lib-elision[:fam+fam] | fusion:elem
                         | fusion:moe[:KEEP] | device:<h100|h200>
                         | tensor-parallel:<N> | fault-free[:<kind|all>]
  taxbreak convert <IN> <OUT> [--to json|binary] [--salvage]
                   (trace dialect round-trip: input detected by magic,
                    output follows the extension — .tbt = binary;
                    --salvage recovers the longest valid event prefix of
                    a truncated binary capture instead of erroring)
  taxbreak bench-trace [--out FILE] [--runs N]
  taxbreak models | platforms | help

Artifact ids: fig2 fig5 fig6 table2 table3 table4 fig7 fig8 fig9 fig10 fig11";

/// Build a RunConfig from `--config file.json` (if given) overridden by
/// explicit flags.
fn parse_run_config(args: &mut Args) -> anyhow::Result<taxbreak::config::RunConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => taxbreak::config::RunConfig::load(std::path::Path::new(path))?,
        None => taxbreak::config::RunConfig::default(),
    };
    if let Some(m) = args.opt("model") {
        cfg.model = m.to_string();
    }
    if let Some(p) = args.opt("platform") {
        cfg.platform = p.to_string();
    }
    if let Some(ph) = args.opt("phase") {
        cfg.phase = match ph {
            "prefill" => Phase::Prefill,
            "decode" => Phase::Decode,
            other => anyhow::bail!("--phase must be prefill|decode, got '{other}'"),
        };
    }
    cfg.batch = args.opt_usize("bs", cfg.batch)?;
    cfg.seq = args.opt_usize("sl", cfg.seq)?;
    cfg.m_tokens = args.opt_usize("m", cfg.m_tokens)?;
    if args.flag("fused") {
        cfg.fused_attention = true;
    }
    if let Some(mit) = args.opt("mitigation") {
        cfg.mitigation = taxbreak::sim::Mitigation::parse(mit)?;
    }
    cfg.seed = args.opt_u64("seed", cfg.seed)?;
    Ok(cfg)
}

fn cmd_repro(mut args: Args) -> anyhow::Result<()> {
    let id = args
        .shift()
        .ok_or_else(|| anyhow::anyhow!("usage: taxbreak repro <id|all>"))?;
    let opts = ReproOpts {
        full: args.flag("full"),
        seed: args.opt_u64("seed", 2026)?,
    };
    let out_path = args.opt("out").map(|s| s.to_string());
    args.finish()?;
    let output = repro::run(&id, &opts)?;
    match out_path {
        Some(p) => {
            write_file(&p, &output)?;
            println!("wrote {p}");
        }
        None => print!("{output}"),
    }
    Ok(())
}

/// Which execution scenario the `--tensor-parallel`/`--expert-parallel`
/// flags select. Parsed *before* `Args::finish` so flag typos error
/// out before any (potentially long) simulation runs.
enum Scenario {
    Single,
    TensorParallel(usize),
    ExpertParallel(usize),
}

impl Scenario {
    fn parse(args: &mut Args) -> anyhow::Result<Scenario> {
        let tp = args.opt_usize("tensor-parallel", 1)?;
        let ep = args.opt_usize("expert-parallel", 1)?;
        anyhow::ensure!(tp >= 1, "--tensor-parallel must be >= 1 (1 = off)");
        anyhow::ensure!(ep >= 1, "--expert-parallel must be >= 1 (1 = off)");
        anyhow::ensure!(
            tp == 1 || ep == 1,
            "--tensor-parallel and --expert-parallel are mutually exclusive"
        );
        Ok(if tp > 1 {
            Scenario::TensorParallel(tp)
        } else if ep > 1 {
            Scenario::ExpertParallel(ep)
        } else {
            Scenario::Single
        })
    }

    /// Simulate under this scenario. Returns the single-timeline flag
    /// too (the schedule-level quantifier only applies there).
    fn simulate(
        &self,
        model: &taxbreak::models::ModelSpec,
        platform: &Platform,
        wl: &taxbreak::sim::Workload,
        seed: u64,
    ) -> anyhow::Result<(taxbreak::trace::Trace, bool)> {
        Ok(match *self {
            Scenario::TensorParallel(n) => {
                (taxbreak::sim::simulate_tensor_parallel(model, platform, wl, n, seed)?, false)
            }
            Scenario::ExpertParallel(n) => {
                (taxbreak::sim::simulate_expert_parallel(model, platform, wl, n, seed)?, false)
            }
            Scenario::Single => (simulate(model, platform, wl, seed), true),
        })
    }
}

fn cmd_analyze(mut args: Args) -> anyhow::Result<()> {
    // `--trace FILE`: decompose a saved trace (either dialect) instead
    // of simulating a fresh workload point.
    if let Some(path) = args.opt("trace").map(|s| s.to_string()) {
        let as_json = args.flag("json");
        args.finish()?;
        return analyze_trace_file(&path, as_json);
    }
    let cfg = parse_run_config(&mut args)?;
    let as_json = args.flag("json");
    let scenario = Scenario::parse(&mut args)?;
    args.finish()?;
    let model = cfg.model_spec()?;
    let platform = cfg.platform_spec()?;
    let wl = cfg.workload();
    let seed = cfg.seed;
    let (trace, single_timeline) = scenario.simulate(&model, &platform, &wl, seed)?;

    let mut backend = SimReplayBackend::new(platform.clone(), seed ^ 0x9E37);
    let mut a = analyze(&trace, &mut backend, &cfg.replay_config());
    // Quantify the prescription by counterfactual replay (whatif).
    // Best-effort, single-timeline runs only: graphed traces
    // (mitigation cuda-graphs) have no per-kernel host chain to
    // extract, and multi-stream schedules are not extractable — both
    // keep the qualitative diagnosis.
    if single_timeline {
        if let Ok(schedule) = taxbreak::whatif::Schedule::from_eager_trace(&trace, &a.phase2)
        {
            taxbreak::whatif::quantify_diagnosis(&mut a, &schedule)?;
        }
    }
    let a = a;

    if as_json {
        println!("{}", report::to_json(&a).pretty());
        return Ok(());
    }
    let title = format!(
        "{} {} BS={} SL={} ({}, m={})",
        model.display, wl.phase.as_str(), wl.batch, wl.seq, platform.name, wl.m_tokens
    );
    print!("{}", report::decomposition_table(&title, &a.decomposition).render());
    if a.decomposition.per_device.len() > 1 {
        print!(
            "{}",
            report::per_device_table("per-device decomposition", &a.decomposition).render()
        );
    }
    print!("{}", report::family_launch_table("per-family launch latency (us)", &a).render());
    println!(
        "baselines: framework-tax {:.2} ms | TKLQT {:.2} ms (queue share {:.0}%)",
        a.baselines.framework_tax_us / 1000.0,
        a.baselines.tklqt_us / 1000.0,
        100.0 * a.baselines.queue_share
    );
    println!(
        "phase-2: floor {:.2} us, dispatch base {:.2} us, {} unique kernels ({} cache hits)",
        a.phase2.floor.mean,
        a.phase2.dispatch_base_us,
        a.phase2.kernels.len(),
        a.phase2.cache_hits
    );
    println!("diagnosis [{}]: {}", a.diagnosis.target.as_str(), a.diagnosis.rationale);
    if let Some(q) = &a.diagnosis.quantified {
        println!("quantified: {}", q.render());
    }
    Ok(())
}

/// `taxbreak analyze --trace FILE`: run the TaxBreak decomposition on a
/// previously saved trace — JSON or binary, detected by magic.
fn analyze_trace_file(path: &str, as_json: bool) -> anyhow::Result<()> {
    let trace = taxbreak::trace::Trace::load(std::path::Path::new(path))?;
    let platform = Platform::by_name(&trace.meta.platform)?;
    // Same seed as the streaming decomposer's finalize pass, so
    // `loadgen --metrics-out` snapshots are bit-identical to this
    // command on the captured trace (DESIGN.md §14).
    let mut backend = SimReplayBackend::new(platform, taxbreak::obs::ANALYZE_REPLAY_SEED);
    let mut a = analyze(&trace, &mut backend, &taxbreak::taxbreak::ReplayConfig::fast());
    // Best-effort quantification: serving/graphed traces have no
    // extractable per-kernel host chain and keep the qualitative
    // diagnosis (same policy as the simulate path).
    if trace.meta.phase != "serve" {
        if let Ok(schedule) = taxbreak::whatif::Schedule::from_eager_trace(&trace, &a.phase2) {
            taxbreak::whatif::quantify_diagnosis(&mut a, &schedule)?;
        }
    }
    if as_json {
        println!("{}", report::to_json(&a).pretty());
        return Ok(());
    }
    let m = &trace.meta;
    let title = format!(
        "{} {} BS={} SL={} ({}, m={}) [{}]",
        m.model, m.phase, m.batch, m.seq, m.platform, m.m_tokens, path
    );
    print!("{}", report::decomposition_table(&title, &a.decomposition).render());
    if a.decomposition.per_device.len() > 1 {
        print!(
            "{}",
            report::per_device_table("per-device decomposition", &a.decomposition).render()
        );
    }
    print!("{}", report::family_launch_table("per-family launch latency (us)", &a).render());
    println!("diagnosis [{}]: {}", a.diagnosis.target.as_str(), a.diagnosis.rationale);
    if let Some(q) = &a.diagnosis.quantified {
        println!("quantified: {}", q.render());
    }
    Ok(())
}

/// Insert the model name before the path's extension
/// ("out.json" + "gpt2" -> "out.gpt2.json") so multi-model runs write
/// one artifact each.
fn path_for_model(path: &str, model: &str) -> String {
    let p = std::path::Path::new(path);
    match (p.file_stem().and_then(|s| s.to_str()), p.extension().and_then(|e| e.to_str())) {
        (Some(stem), Some(ext)) => p
            .with_file_name(format!("{stem}.{model}.{ext}"))
            .to_string_lossy()
            .into_owned(),
        _ => format!("{path}.{model}"),
    }
}

fn cmd_whatif(mut args: Args) -> anyhow::Result<()> {
    use taxbreak::taxbreak::ReplayConfig;
    use taxbreak::whatif::{self, Schedule};

    let specs = args.opt_list("counterfactual");
    let trace_path = args.opt("trace").map(|s| s.to_string());
    let bundled = args.opt("bundled").map(|s| s.to_string());
    let as_json = args.flag("json");
    let report_path = args.opt("report").map(|s| s.to_string());
    let chrome_path = args.opt("chrome").map(|s| s.to_string());
    anyhow::ensure!(
        !specs.is_empty(),
        "whatif needs --counterfactual SPEC[,SPEC...] — try \
         `taxbreak whatif --bundled moe-decode --counterfactual host-cpu:xeon-6538y`"
    );
    let cfs = whatif::parse_specs(&specs)?;

    // Source trace: a file, a bundled preset, or explicit workload flags.
    anyhow::ensure!(
        trace_path.is_none() || bundled.is_none(),
        "--trace and --bundled are mutually exclusive"
    );
    let (trace, replay_cfg) = match (&trace_path, &bundled) {
        (Some(path), _) => {
            args.finish()?;
            (taxbreak::trace::Trace::load(std::path::Path::new(path))?, ReplayConfig::fast())
        }
        (None, bundled) => {
            let cfg = match bundled {
                Some(name) => {
                    let cfg = whatif::bundled::by_name(name)?;
                    args.finish()?;
                    cfg
                }
                None => {
                    let cfg = parse_run_config(&mut args)?;
                    args.finish()?;
                    cfg
                }
            };
            let trace = simulate(&cfg.model_spec()?, &cfg.platform_spec()?, &cfg.workload(), cfg.seed);
            (trace, cfg.replay_config())
        }
    };

    // Extract the replayable schedule; eager traces also get the full
    // analysis so the diagnosis can carry its quantified counterfactual.
    let (schedule, analysis) = if trace.meta.phase == "serve" {
        (Schedule::from_serving_trace(&trace)?, None)
    } else {
        let platform = Platform::by_name(&trace.meta.platform)?;
        let mut backend = SimReplayBackend::new(platform, 0x5EED);
        let mut a = analyze(&trace, &mut backend, &replay_cfg);
        let schedule = Schedule::from_eager_trace(&trace, &a.phase2)?;
        whatif::quantify_diagnosis(&mut a, &schedule)?;
        (schedule, Some(a))
    };

    let (result, final_schedule) = whatif::run_with_schedule(&schedule, &cfs)?;
    if as_json {
        println!("{}", whatif::report::to_json(&result).pretty());
    } else {
        print!("{}", whatif::report::whatif_table(&result).render());
        if let Some(a) = &analysis {
            println!(
                "diagnosis [{}]: {}",
                a.diagnosis.target.as_str(),
                a.diagnosis.rationale
            );
            if let Some(q) = &a.diagnosis.quantified {
                println!("quantified: {}", q.render());
            }
        }
    }
    if let Some(p) = report_path {
        write_file(&p, whatif::report::to_json(&result).pretty())?;
        println!("wrote {p}");
    }
    if let Some(p) = chrome_path {
        let (_, cf_trace) = whatif::schedule::resimulate_with_trace(&final_schedule, true);
        let cf_trace = cf_trace.ok_or_else(|| {
            anyhow::anyhow!("counterfactual resimulation returned no trace for --chrome")
        })?;
        chrome::save_chrome(&cf_trace, std::path::Path::new(&p))?;
        println!("wrote {p} (counterfactual timeline, chrome://tracing format)");
    }
    Ok(())
}

/// `taxbreak replay <TRACE>`: re-drive the engine + scheduler stack
/// from a spec-v3 serving capture. Every nondeterministic input —
/// arrivals, RNG draws, admission/preemption decisions, clock jumps —
/// comes from the recorded events, so the replayed run reproduces the
/// recording exactly (`--verify` proves it byte-for-byte in both
/// dialects) and any capture becomes a deterministic substrate for
/// `--counterfactual` analysis.
fn cmd_replay(mut args: Args) -> anyhow::Result<()> {
    use taxbreak::trace::binary;
    use taxbreak::util::json::Json;
    use taxbreak::whatif::{self, Schedule};

    let usage = "usage: taxbreak replay <TRACE> \
                 [--counterfactual SPEC[,SPEC...]] [--verify] [--json] [--report FILE]";
    let specs = args.opt_list("counterfactual");
    let verify = args.flag("verify");
    let as_json = args.flag("json");
    let report_path = args.opt("report").map(|s| s.to_string());
    let path = args.shift().ok_or_else(|| anyhow::anyhow!("{usage}"))?;
    args.finish()?;

    let recording = taxbreak::trace::Trace::load(std::path::Path::new(&path))?;
    let out = taxbreak::serving::replay(&recording)?;
    let run = &out.run;

    let mut kpis = Json::obj()
        .with("trace", path.as_str())
        .with("model", run.model.as_str())
        .with("platform", recording.meta.platform.as_str())
        .with("completed", run.completed)
        .with("iterations", run.iterations)
        .with("preemptions", run.preemptions)
        .with("tokens_generated", run.tokens_generated)
        .with("wall_us", run.wall_us)
        .with("orchestration_us", run.orchestration_us())
        .with("device_us", run.device_us())
        .with(
            "phases",
            Json::Arr(
                run.phases
                    .iter()
                    .map(|p| {
                        Json::obj()
                            .with("phase", p.phase)
                            .with("host_us", p.host_us)
                            .with("device_us", p.device_us)
                            .with("kernels", p.kernels)
                            .with("hdbi", p.hdbi())
                    })
                    .collect(),
            ),
        )
        .with(
            "per_device_hdbi",
            Json::Arr(run.per_device.iter().map(|d| Json::from(d.hdbi)).collect()),
        );

    if verify {
        // The fixed-point theorem, checked in both dialects: the
        // replayed run's re-recording must be byte-identical to the
        // input recording.
        anyhow::ensure!(
            out.trace.to_json().dump() == recording.to_json().dump(),
            "replay diverged from the recording in the JSON dialect"
        );
        anyhow::ensure!(
            binary::encode(&out.trace) == binary::encode(&recording),
            "replay diverged from the recording in the binary dialect"
        );
        // The telemetry snapshot is a pure function of (events, wall),
        // so it must be a fixed point too (DESIGN.md §14): the same
        // windowed decomposition, exposed byte-for-byte.
        let platform = Platform::by_name(&recording.meta.platform)?;
        let window_us = recording.e2e_us() / 8.0;
        let (_, reg_rec) =
            taxbreak::obs::snapshot_of_trace(&recording, platform.clone(), window_us);
        let (_, reg_rep) = taxbreak::obs::snapshot_of_trace(&out.trace, platform, window_us);
        anyhow::ensure!(
            reg_rec.prometheus_text() == reg_rep.prometheus_text(),
            "the replayed run's metrics snapshot diverged from the recording's"
        );
        kpis.set("verified", Json::Bool(true));
        kpis.set("metrics_fixed_point", Json::Bool(true));
    }

    if as_json {
        println!("{}", kpis.pretty());
    } else {
        println!(
            "== replay ({path}: {} on {}) ==",
            run.model, recording.meta.platform
        );
        println!(
            "{} requests completed, {} iterations ({} preemptions), {} tokens, wall {:.2} ms",
            run.completed,
            run.iterations,
            run.preemptions,
            run.tokens_generated,
            run.wall_us / 1000.0
        );
        for p in &run.phases {
            println!(
                "  {:<8} host {:>10.1} us  device {:>10.1} us  kernels {:>6}  HDBI {:.3}",
                p.phase,
                p.host_us,
                p.device_us,
                p.kernels,
                p.hdbi()
            );
        }
        if run.per_device.len() > 1 {
            let hdbis: Vec<String> =
                run.per_device.iter().map(|d| format!("{:.3}", d.hdbi)).collect();
            println!("  per-device HDBI: {}", hdbis.join(" "));
        }
        if verify {
            println!(
                "verify: record → replay → re-record is byte-identical in both dialects \
                 ({} events), and the telemetry snapshot is a fixed point",
                out.trace.events.len()
            );
        }
    }

    if !specs.is_empty() {
        let cfs = whatif::parse_specs(&specs)?;
        let schedule = Schedule::from_serving_trace(&out.trace)?;
        let (result, _) = whatif::run_with_schedule(&schedule, &cfs)?;
        if as_json {
            println!("{}", whatif::report::to_json(&result).pretty());
        } else {
            print!("{}", whatif::report::whatif_table(&result).render());
        }
        kpis.set("whatif", whatif::report::to_json(&result));
    }

    if let Some(p) = report_path {
        write_file(&p, kpis.pretty())?;
        println!("wrote {p}");
    }
    Ok(())
}

fn cmd_trace(mut args: Args) -> anyhow::Result<()> {
    let cfg = parse_run_config(&mut args)?;
    let out = args.opt_string("out", "trace.json");
    let chrome_out = args.opt("chrome").map(|s| s.to_string());
    let scenario = Scenario::parse(&mut args)?;
    args.finish()?;
    let (trace, _) =
        scenario.simulate(&cfg.model_spec()?, &cfg.platform_spec()?, &cfg.workload(), cfg.seed)?;

    trace.save_auto(std::path::Path::new(&out))?;
    println!(
        "wrote {} ({} kernels, {:.2} ms wall)",
        out,
        trace.kernel_count(),
        trace.meta.wall_us / 1000.0
    );
    if let Some(p) = chrome_out {
        chrome::save_chrome(&trace, std::path::Path::new(&p))?;
        println!("wrote {p} (chrome://tracing format)");
    }
    Ok(())
}

fn cmd_serve(mut args: Args) -> anyhow::Result<()> {
    let backend = args.opt_string("backend", "sim");
    let requests = args.opt_usize("requests", 16)?;
    let max_batch = args.opt_usize("max-batch", 4)?;
    let report_path = args.opt("report").map(|s| s.to_string());
    let seed = args.opt_u64("seed", 2026)?;
    let summary = match backend.as_str() {
        "sim" => {
            let model = args.opt_string("model", "gpt2");
            let platform = args.opt_string("platform", "h200");
            args.finish()?;
            taxbreak::serving::run_sim_server_demo(&model, &platform, requests, max_batch, seed)?
        }
        "pjrt" => {
            let artifacts = args.opt_string("artifacts", "artifacts");
            let variant = args.opt_string("variant", "dense_fused");
            args.finish()?;
            serve_pjrt(&artifacts, &variant, requests, max_batch, seed)?
        }
        other => anyhow::bail!("--backend must be sim|pjrt, got '{other}'"),
    };
    print!("{}", summary.render());
    if let Some(p) = report_path {
        write_file(&p, summary.to_json().pretty())?;
        println!("wrote {p}");
    }
    Ok(())
}

fn cmd_loadgen(mut args: Args) -> anyhow::Result<()> {
    use taxbreak::serving::{run_sim_loadgen, run_sim_loadgen_streaming, LenDist, LoadgenConfig};
    let models = {
        let list = args.opt_list("models");
        if list.is_empty() {
            // Default mix: the paper's dense-vs-MoE serving contrast.
            vec!["gpt2".to_string(), "olmoe-1b-7b".to_string()]
        } else {
            list
        }
    };
    let platform = args.opt_string("platform", "h200");
    let base = LoadgenConfig::default();
    let prompt_dist = args.opt("prompt-dist").map(|s| s.to_string());
    let out_dist = args.opt("out-dist").map(|s| s.to_string());
    let cfg = LoadgenConfig {
        requests: args.opt_usize("requests", base.requests)?,
        rate_per_s: args.opt_f64("rate", base.rate_per_s)?,
        prompt_len: match prompt_dist {
            Some(d) => LenDist::parse(&d)?,
            None => base.prompt_len,
        },
        output_len: match out_dist {
            Some(d) => LenDist::parse(&d)?,
            None => base.output_len,
        },
        seed: args.opt_u64("seed", base.seed)?,
        sched: taxbreak::serving::SchedulerConfig {
            max_batch: args.opt_usize("max-batch", base.sched.max_batch)?,
            max_groups: args.opt_usize("max-groups", base.sched.max_groups)?,
            kv_pages: args.opt_usize("kv-pages", base.sched.kv_pages)?,
            kv_page_tokens: args.opt_usize("kv-page-tokens", base.sched.kv_page_tokens)?,
            ttft_deadline_us: args.opt_f64("ttft-deadline-us", base.sched.ttft_deadline_us)?,
            tpot_deadline_us: args.opt_f64("tpot-deadline-us", base.sched.tpot_deadline_us)?,
        },
        devices: args.opt_usize("devices", base.devices)?,
        streams: args.opt_usize("streams", base.streams)?,
        // Parse eagerly so a malformed spec dies before any simulation
        // runs (the plan itself is re-derived per replica inside
        // `run_sim_loadgen`, which owns the authoritative parse).
        faults: match args.opt("faults").map(|s| s.to_string()) {
            Some(spec) => {
                taxbreak::faults::FaultPlan::parse(&spec)?;
                Some(spec)
            }
            None => None,
        },
        capture: false,
        metrics: false,
        window_us: 0.0,
    };
    let report_path = args.opt("report").map(|s| s.to_string());
    let capture_path = args.opt("capture").map(|s| s.to_string());
    let chrome_path = args.opt("chrome-out").map(|s| s.to_string());
    let bench_path = args.opt("bench-out").map(|s| s.to_string());
    let metrics_path = args.opt("metrics-out").map(|s| s.to_string());
    // The Chrome export and the bench datapoint's replay-throughput
    // measurement need the whole trace in memory; `--capture` itself
    // streams each event to disk as the scheduler steps, and the
    // telemetry plane (`--metrics-out`) taps the same stream without
    // buffering it.
    let cfg = LoadgenConfig {
        capture: chrome_path.is_some() || bench_path.is_some(),
        metrics: metrics_path.is_some(),
        window_us: args.opt_f64("window-us", 0.0)?,
        ..cfg
    };
    args.finish()?;
    let report = match &capture_path {
        Some(prefix) => {
            let mut written: Vec<String> = Vec::new();
            let mut factory = |model: &str,
                               meta: &taxbreak::trace::TraceMeta|
             -> anyhow::Result<Box<dyn taxbreak::trace::TraceSink>> {
                let path = path_for_model(prefix, model);
                let sink = taxbreak::trace::sink::file_sink(std::path::Path::new(&path), meta)?;
                written.push(path);
                Ok(sink)
            };
            let report = run_sim_loadgen_streaming(&models, &platform, &cfg, &mut factory)?;
            for path in written {
                println!(
                    "wrote {path} (captured serving trace; re-drive it with \
                     `taxbreak replay {path}` or `taxbreak whatif --trace {path}`)"
                );
            }
            report
        }
        None => run_sim_loadgen(&models, &platform, &cfg)?,
    };
    print!("{}", report.render());
    if let Some(p) = report_path {
        write_file(&p, report.to_json().pretty())?;
        println!("wrote {p}");
    }
    if let Some(p) = metrics_path {
        let reg = report
            .metrics_registry()
            .ok_or_else(|| anyhow::anyhow!("--metrics-out produced no telemetry"))?;
        write_file(&p, reg.prometheus_text())?;
        println!("wrote {p} (Prometheus text exposition)");
        let jp = json_twin(&p);
        write_file(&jp, reg.to_json().pretty())?;
        println!("wrote {jp} (metrics JSON snapshot)");
    }
    if let Some(p) = bench_path {
        use taxbreak::util::json::Json;
        // The bench trajectory also tracks replay throughput: re-drive
        // every captured run through `serving::replay` and time it.
        let mut bench = report.bench_json();
        let mut events = 0usize;
        let mut tokens = 0usize;
        let t0 = std::time::Instant::now();
        for run in &report.runs {
            let Some(trace) = &run.trace else { continue };
            let out = taxbreak::serving::replay(trace)?;
            anyhow::ensure!(
                out.run.tokens_generated == run.tokens_generated,
                "replay of the bench run diverged from its recording ({})",
                run.model
            );
            events += trace.events.len();
            tokens += out.run.tokens_generated;
        }
        let secs = t0.elapsed().as_secs_f64();
        let rate = |n: usize| if secs > 0.0 { n as f64 / secs } else { 0.0 };
        bench.set(
            "replay",
            Json::obj()
                .with("events", events)
                .with("tokens", tokens)
                .with("wall_s", secs)
                .with("events_per_s", rate(events))
                .with("tokens_per_s", rate(tokens)),
        );
        // Streaming-telemetry throughput: feed every captured event
        // through the windowed online decomposer (the `--metrics-out`
        // path, replay pass included) and time it.
        let mut online_events = 0usize;
        let t0 = std::time::Instant::now();
        for run in &report.runs {
            let Some(trace) = &run.trace else { continue };
            let spec = Platform::by_name(&trace.meta.platform)?;
            let (r, _) = taxbreak::obs::snapshot_of_trace(trace, spec, 0.0);
            anyhow::ensure!(
                r.totals.n_kernels > 0,
                "online decomposition of the bench run saw no kernels ({})",
                run.model
            );
            online_events += trace.events.len();
        }
        let osecs = t0.elapsed().as_secs_f64();
        bench.set(
            "online_decompose_events_per_sec",
            if osecs > 0.0 { online_events as f64 / osecs } else { 0.0 },
        );
        write_file(&p, bench.pretty())?;
        println!("wrote {p}");
    }
    for run in &report.runs {
        let Some(trace) = &run.trace else { continue };
        if let Some(prefix) = &chrome_path {
            let path = path_for_model(prefix, &run.model);
            // Metrics-enabled runs also carry their per-window HDBI and
            // KV-occupancy series as Perfetto counter tracks.
            let mut counters = Vec::new();
            if let Some(t) = &run.telemetry {
                counters.push(chrome::CounterSeries {
                    name: "hdbi".into(),
                    points: t.online.hdbi_series(),
                });
                counters.push(chrome::CounterSeries {
                    name: "kv_occupancy".into(),
                    points: t.probe.kv_series(),
                });
            }
            chrome::save_chrome_with_counters(trace, &counters, std::path::Path::new(&path))?;
            println!("wrote {path} (chrome://tracing format)");
        }
    }
    Ok(())
}

/// `std::fs::write` with the destination in the error: a bad `--report`
/// / `--metrics-out` / `--bench-out` path must die with a one-line
/// diagnostic that names the file, not a bare OS error.
fn write_file(path: &str, data: impl AsRef<[u8]>) -> anyhow::Result<()> {
    std::fs::write(path, data).map_err(|e| anyhow::anyhow!("writing {path}: {e}"))
}

/// Path for the JSON twin of a metrics exposition file
/// ("m.prom" -> "m.json"); appends ".json" when the input already has
/// that extension.
fn json_twin(path: &str) -> String {
    let twin = std::path::Path::new(path)
        .with_extension("json")
        .to_string_lossy()
        .into_owned();
    if twin == path {
        format!("{path}.json")
    } else {
        twin
    }
}

fn cmd_convert(mut args: Args) -> anyhow::Result<()> {
    use taxbreak::trace::binary::{self, Dialect};
    let to = match args.opt("to").map(|s| s.to_string()) {
        None => None,
        Some(s) if s == "json" => Some(Dialect::Json),
        Some(s) if s == "binary" || s == "tbt" => Some(Dialect::Binary),
        Some(other) => anyhow::bail!("--to must be json|binary, got '{other}'"),
    };
    let salvage = args.flag("salvage");
    let usage = "usage: taxbreak convert <IN> <OUT> [--to json|binary] [--salvage]";
    let input = args.shift().ok_or_else(|| anyhow::anyhow!("{usage}"))?;
    let output = args.shift().ok_or_else(|| anyhow::anyhow!("{usage}"))?;
    args.finish()?;
    if salvage {
        // Crash recovery: accept a truncated / trailer-less binary
        // capture and keep the longest prefix of complete events.
        let bytes = std::fs::read(&input)
            .map_err(|e| anyhow::anyhow!("reading {input}: {e}"))?;
        anyhow::ensure!(
            binary::is_binary(&bytes),
            "--salvage only applies to binary (.tbt) traces; '{input}' is not one \
             (JSON captures are either whole or unparseable)"
        );
        let out = binary::salvage(&bytes)?;
        let dialect = to.unwrap_or_else(|| Dialect::of_path(std::path::Path::new(&output)));
        let data = match dialect {
            Dialect::Binary => binary::encode(&out.trace),
            Dialect::Json => out.trace.to_json().dump().into_bytes(),
        };
        std::fs::write(&output, &data)
            .map_err(|e| anyhow::anyhow!("writing {output}: {e}"))?;
        println!(
            "salvaged {input} -> {output} ({}): recovered {} events; {}",
            dialect.as_str(),
            out.recovered(),
            out.reason,
        );
        return Ok(());
    }
    let stats =
        binary::convert(std::path::Path::new(&input), std::path::Path::new(&output), to)?;
    println!(
        "{} ({}, {} bytes) -> {} ({}, {} bytes): {} events, {:.2}x size",
        input,
        stats.from.as_str(),
        stats.in_bytes,
        output,
        stats.to.as_str(),
        stats.out_bytes,
        stats.events,
        stats.out_bytes as f64 / stats.in_bytes.max(1) as f64,
    );
    Ok(())
}

fn cmd_bench_trace(mut args: Args) -> anyhow::Result<()> {
    use std::time::Instant;
    use taxbreak::trace::binary;
    use taxbreak::util::json::Json;
    let out_path = args.opt("out").map(|s| s.to_string());
    let runs = args.opt_usize("runs", 5)?;
    args.finish()?;
    anyhow::ensure!(runs >= 1, "--runs must be >= 1");

    // The bundled moe-decode capture — the paper's worst-tax workload
    // and the corpus `BENCH_trace.json` tracks.
    let cfg = taxbreak::whatif::bundled::by_name("moe-decode")?;
    let trace = simulate(&cfg.model_spec()?, &cfg.platform_spec()?, &cfg.workload(), cfg.seed);
    let events = trace.events.len();
    anyhow::ensure!(events > 0, "bundled trace is empty");

    let json_compact = trace.to_json().dump();
    let json_pretty = trace.to_json().pretty();
    let bin = binary::encode(&trace);

    // Accumulate output sizes so the encode/decode loops stay observed.
    let mut observed = 0usize;
    let t0 = Instant::now();
    for _ in 0..runs {
        observed += binary::encode(&trace).len();
    }
    let bin_enc_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..runs {
        observed += binary::decode(&bin)
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .events
            .len();
    }
    let bin_dec_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..runs {
        observed += trace.to_json().dump().len();
    }
    let json_enc_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    for _ in 0..runs {
        observed += taxbreak::trace::Trace::from_json(&Json::parse(&json_compact)?)?
            .events
            .len();
    }
    let json_dec_s = t0.elapsed().as_secs_f64();
    anyhow::ensure!(observed > 0, "benchmark loops produced no output");

    let rate = |secs: f64| {
        if secs > 0.0 {
            (events * runs) as f64 / secs
        } else {
            0.0
        }
    };
    let per_event = |bytes: usize| bytes as f64 / events as f64;
    let datapoint = Json::obj()
        .with("bench", "trace")
        .with("source", "moe-decode (bundled)")
        .with("events", events)
        .with("runs", runs)
        .with(
            "json_compact",
            Json::obj()
                .with("bytes", json_compact.len())
                .with("bytes_per_event", per_event(json_compact.len()))
                .with("encode_events_per_s", rate(json_enc_s))
                .with("decode_events_per_s", rate(json_dec_s)),
        )
        .with(
            "json_pretty",
            Json::obj()
                .with("bytes", json_pretty.len())
                .with("bytes_per_event", per_event(json_pretty.len())),
        )
        .with(
            "binary",
            Json::obj()
                .with("bytes", bin.len())
                .with("bytes_per_event", per_event(bin.len()))
                .with("encode_events_per_s", rate(bin_enc_s))
                .with("decode_events_per_s", rate(bin_dec_s)),
        )
        .with("binary_vs_pretty_json", bin.len() as f64 / json_pretty.len() as f64)
        .with("binary_vs_compact_json", bin.len() as f64 / json_compact.len() as f64);
    println!("{}", datapoint.pretty());
    if let Some(p) = out_path {
        write_file(&p, datapoint.pretty())?;
        println!("wrote {p}");
    }
    Ok(())
}

#[cfg(feature = "real-pjrt")]
fn serve_pjrt(
    artifacts: &str,
    variant: &str,
    requests: usize,
    max_batch: usize,
    seed: u64,
) -> anyhow::Result<taxbreak::serving::ServeSummary> {
    taxbreak::serving::run_server_demo(
        std::path::Path::new(artifacts),
        variant,
        requests,
        max_batch,
        seed,
    )
}

#[cfg(not(feature = "real-pjrt"))]
fn serve_pjrt(
    _artifacts: &str,
    _variant: &str,
    _requests: usize,
    _max_batch: usize,
    _seed: u64,
) -> anyhow::Result<taxbreak::serving::ServeSummary> {
    anyhow::bail!(
        "the pjrt backend is feature-gated: rebuild with \
         `cargo build --features real-pjrt` (and a real xla crate — see \
         DESIGN.md §8); the default build serves with `--backend sim`"
    )
}
