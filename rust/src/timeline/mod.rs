//! Unified discrete-event timeline engine: the one host/device
//! co-simulation clock shared by `sim::simulate`, the `whatif` replay
//! loop and the serving engines' virtual clock (DESIGN.md §11).
//!
//! The engine owns explicit **resources**:
//!
//! * *host dispatch threads* — serial cursors, one per rank/process
//!   (eager dispatch is single-threaded per process, paper §I, but
//!   tensor-parallel SPMD runs one dispatch thread per device);
//! * *CUDA streams* — FIFO queues ([`crate::device::Stream`] is the
//!   per-stream primitive; the engine composes many of them);
//! * *devices* — groups of streams with per-device active-time
//!   accounting, the substrate for per-device decomposition and HDBI.
//!
//! **Determinism.** The engine has no internal event queue to race:
//! every operation is applied in caller order and is a pure function of
//! the cursors it touches, so a workload generator that issues
//! operations in a fixed order always produces the identical timeline
//! (and therefore byte-identical traces — enforced by
//! `rust/tests/timeline.rs`).
//!
//! **Single-timeline equivalence.** With the default topology (1 host
//! thread, 1 device, 1 stream) the engine reduces *exactly* to the
//! pre-refactor `Stream` + host-cursor loops: `submit` delegates to
//! [`Stream::submit`] unchanged and the host cursor operations
//! (`advance`, `wait_until`) reproduce the original `t += dur` /
//! `t = t.max(sync)` arithmetic operation-for-operation, so the
//! single-stream configuration reproduces the recorded seed traces
//! bit-for-bit.

use crate::device::{KernelTiming, Stream};

/// Location of one stream: `(device, stream-on-device)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StreamRef {
    pub device: u32,
    pub stream: u32,
}

impl StreamRef {
    /// Stream 0 on device 0 — the single-timeline default.
    pub const PRIMARY: StreamRef = StreamRef { device: 0, stream: 0 };
}

/// Resource shape of one engine instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub devices: usize,
    pub streams_per_device: usize,
    pub host_threads: usize,
}

impl Default for Topology {
    fn default() -> Self {
        Topology {
            devices: 1,
            streams_per_device: 1,
            host_threads: 1,
        }
    }
}

/// The discrete-event timeline engine.
#[derive(Debug, Clone)]
pub struct Engine {
    topo: Topology,
    /// Host-thread cursors (time each dispatch thread is free again).
    hosts: Vec<f64>,
    /// Device-major stream states: index = device * streams_per_device
    /// + stream.
    streams: Vec<Stream>,
}

impl Engine {
    pub fn new(topo: Topology) -> Engine {
        assert!(topo.devices >= 1, "topology needs at least one device");
        assert!(
            topo.streams_per_device >= 1,
            "topology needs at least one stream per device"
        );
        assert!(
            topo.host_threads >= 1,
            "topology needs at least one host thread"
        );
        Engine {
            topo,
            hosts: vec![0.0; topo.host_threads],
            streams: vec![Stream::new(); topo.devices * topo.streams_per_device],
        }
    }

    /// The single-timeline engine (1 host thread, 1 device, 1 stream).
    pub fn single() -> Engine {
        Engine::new(Topology::default())
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    fn idx(&self, s: StreamRef) -> usize {
        let d = s.device as usize;
        let st = s.stream as usize;
        assert!(d < self.topo.devices, "device {d} outside topology");
        assert!(
            st < self.topo.streams_per_device,
            "stream {st} outside topology"
        );
        d * self.topo.streams_per_device + st
    }

    // --- host threads ---------------------------------------------------

    /// Current cursor of host thread `tid`.
    pub fn host_now(&self, tid: usize) -> f64 {
        self.hosts[tid]
    }

    /// Occupy host thread `tid` for `dur_us`; returns `(start, end)`.
    pub fn host_advance(&mut self, tid: usize, dur_us: f64) -> (f64, f64) {
        let start = self.hosts[tid];
        let end = start + dur_us;
        self.hosts[tid] = end;
        (start, end)
    }

    /// Block host thread `tid` until at least `t_us` (device sync wait,
    /// serving idle jump, arrival gating). Never moves time backwards.
    pub fn host_wait_until(&mut self, tid: usize, t_us: f64) {
        self.hosts[tid] = self.hosts[tid].max(t_us);
    }

    // --- streams --------------------------------------------------------

    /// Submit a kernel to `s`, launched at `api_start_us` with the
    /// sampled empty-queue launch gap. Exactly [`Stream::submit`] on the
    /// addressed stream.
    pub fn submit(
        &mut self,
        s: StreamRef,
        api_start_us: f64,
        launch_gap_us: f64,
        dur_us: f64,
    ) -> KernelTiming {
        let i = self.idx(s);
        self.streams[i].submit(api_start_us, launch_gap_us, dur_us)
    }

    /// Submit with an extra readiness dependency: the kernel cannot
    /// start before `dep_us` (cross-stream event wait — all-reduce
    /// joins, router→expert hand-offs). `dep_us = 0.0` is exactly
    /// [`Engine::submit`].
    pub fn submit_after(
        &mut self,
        s: StreamRef,
        api_start_us: f64,
        launch_gap_us: f64,
        dur_us: f64,
        dep_us: f64,
    ) -> KernelTiming {
        let i = self.idx(s);
        self.streams[i].submit_dep(api_start_us, launch_gap_us, dep_us, dur_us)
    }

    /// When stream `s` drains (cudaStreamSynchronize).
    pub fn stream_sync_point(&self, s: StreamRef) -> f64 {
        self.streams[self.idx(s)].sync_point()
    }

    /// When every stream of `device` drains (cudaDeviceSynchronize).
    pub fn device_sync_point(&self, device: u32) -> f64 {
        let spd = self.topo.streams_per_device;
        let base = device as usize * spd;
        self.streams[base..base + spd]
            .iter()
            .map(Stream::sync_point)
            .fold(0.0f64, f64::max)
    }

    /// When every stream on every device drains. With the single
    /// topology this is exactly the one stream's `sync_point()`.
    pub fn sync_point(&self) -> f64 {
        self.streams
            .iter()
            .map(Stream::sync_point)
            .fold(0.0f64, f64::max)
    }

    /// Latest cursor over an explicit stream set (all-reduce join).
    pub fn join(&self, streams: &[StreamRef]) -> f64 {
        streams
            .iter()
            .map(|&s| self.stream_sync_point(s))
            .fold(0.0f64, f64::max)
    }

    // --- accounting -----------------------------------------------------

    /// Σ kernel-active time on one device.
    pub fn device_active_us(&self, device: u32) -> f64 {
        let spd = self.topo.streams_per_device;
        let base = device as usize * spd;
        self.streams[base..base + spd]
            .iter()
            .map(Stream::active_us)
            .sum()
    }

    /// Σ kernel-active time over every stream.
    pub fn active_us(&self) -> f64 {
        self.streams.iter().map(Stream::active_us).sum()
    }

    /// Kernels launched over every stream.
    pub fn launched(&self) -> usize {
        self.streams.iter().map(Stream::launched).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_topology_delegates_to_stream_exactly() {
        // Hand-checkable numbers mirroring device::Stream's own tests.
        let mut e = Engine::single();
        let t = e.submit(StreamRef::PRIMARY, 10.0, 4.7, 2.0);
        assert_eq!(t.start_us, 14.7);
        assert_eq!(t.end_us, 16.7);
        let mut s = Stream::new();
        let r = s.submit(10.0, 4.7, 2.0);
        assert_eq!((t.start_us, t.end_us), (r.start_us, r.end_us));
        assert_eq!(e.sync_point(), s.sync_point());
        assert_eq!(e.active_us(), s.active_us());
        assert_eq!(e.launched(), s.launched());
    }

    #[test]
    fn host_cursor_arithmetic() {
        let mut e = Engine::single();
        assert_eq!(e.host_now(0), 0.0);
        let (a, b) = e.host_advance(0, 3.5);
        assert_eq!((a, b), (0.0, 3.5));
        e.host_wait_until(0, 2.0); // backwards is a no-op
        assert_eq!(e.host_now(0), 3.5);
        e.host_wait_until(0, 10.0);
        assert_eq!(e.host_now(0), 10.0);
    }

    #[test]
    fn streams_are_independent_fifos() {
        let mut e = Engine::new(Topology {
            devices: 1,
            streams_per_device: 2,
            host_threads: 1,
        });
        let s0 = StreamRef { device: 0, stream: 0 };
        let s1 = StreamRef { device: 0, stream: 1 };
        let a = e.submit(s0, 0.0, 1.0, 100.0); // stream 0 busy to 101
        let b = e.submit(s1, 0.0, 1.0, 5.0); // stream 1 free: starts at 1
        assert_eq!(a.start_us, 1.0);
        assert_eq!(b.start_us, 1.0, "second stream does not queue behind the first");
        assert_eq!(e.stream_sync_point(s0), 101.0);
        assert_eq!(e.stream_sync_point(s1), 6.0);
        assert_eq!(e.sync_point(), 101.0);
    }

    #[test]
    fn submit_after_honors_cross_stream_dependency() {
        let mut e = Engine::new(Topology {
            devices: 1,
            streams_per_device: 2,
            host_threads: 1,
        });
        let s0 = StreamRef { device: 0, stream: 0 };
        let s1 = StreamRef { device: 0, stream: 1 };
        let a = e.submit(s0, 0.0, 1.0, 50.0); // ends 51
        // Dependent kernel on stream 1 must wait for the stream-0 event.
        let b = e.submit_after(s1, 0.0, 1.0, 2.0, a.end_us);
        assert_eq!(b.start_us, 51.0);
        // Zero dependency degrades to plain submit.
        let mut e2 = Engine::single();
        let p = e2.submit_after(StreamRef::PRIMARY, 3.0, 1.5, 2.0, 0.0);
        let mut s = Stream::new();
        let q = s.submit(3.0, 1.5, 2.0);
        assert_eq!((p.start_us, p.end_us), (q.start_us, q.end_us));
    }

    #[test]
    fn per_device_accounting_partitions_totals() {
        let mut e = Engine::new(Topology {
            devices: 2,
            streams_per_device: 2,
            host_threads: 2,
        });
        e.submit(StreamRef { device: 0, stream: 0 }, 0.0, 1.0, 10.0);
        e.submit(StreamRef { device: 0, stream: 1 }, 0.0, 1.0, 20.0);
        e.submit(StreamRef { device: 1, stream: 0 }, 0.0, 1.0, 40.0);
        assert_eq!(e.device_active_us(0), 30.0);
        assert_eq!(e.device_active_us(1), 40.0);
        assert_eq!(e.active_us(), 70.0);
        assert_eq!(e.launched(), 3);
        assert_eq!(e.device_sync_point(0), 21.0);
        assert_eq!(e.device_sync_point(1), 41.0);
        assert_eq!(
            e.join(&[
                StreamRef { device: 0, stream: 1 },
                StreamRef { device: 1, stream: 0 }
            ]),
            41.0
        );
    }

    #[test]
    #[should_panic(expected = "outside topology")]
    fn out_of_topology_stream_panics() {
        let mut e = Engine::single();
        e.submit(StreamRef { device: 0, stream: 1 }, 0.0, 0.0, 1.0);
    }
}
