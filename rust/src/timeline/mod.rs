//! Unified discrete-event timeline engine: the one host/device
//! co-simulation clock shared by `sim::simulate`, the `whatif` replay
//! loop and the serving engines' virtual clock (DESIGN.md §11).
//!
//! The engine owns explicit **resources**:
//!
//! * *host dispatch threads* — serial cursors, one per rank/process
//!   (eager dispatch is single-threaded per process, paper §I, but
//!   tensor-parallel SPMD runs one dispatch thread per device);
//! * *CUDA streams* — FIFO queues ([`crate::device::Stream`] is the
//!   per-stream primitive; the engine composes many of them);
//! * *devices* — groups of streams with per-device active-time
//!   accounting, the substrate for per-device decomposition and HDBI.
//!
//! **Determinism.** The engine has no internal event queue to race:
//! every operation is applied in caller order and is a pure function of
//! the cursors it touches, so a workload generator that issues
//! operations in a fixed order always produces the identical timeline
//! (and therefore byte-identical traces — enforced by
//! `rust/tests/timeline.rs`).
//!
//! **Single-timeline equivalence.** With the default topology (1 host
//! thread, 1 device, 1 stream) the engine reduces *exactly* to the
//! pre-refactor `Stream` + host-cursor loops: `submit` delegates to
//! [`Stream::submit`] unchanged and the host cursor operations
//! (`advance`, `wait_until`) reproduce the original `t += dur` /
//! `t = t.max(sync)` arithmetic operation-for-operation, so the
//! single-stream configuration reproduces the recorded seed traces
//! bit-for-bit.

use crate::device::{KernelTiming, Stream};

/// Location of one stream: `(device, stream-on-device)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StreamRef {
    pub device: u32,
    pub stream: u32,
}

impl StreamRef {
    /// Stream 0 on device 0 — the single-timeline default.
    pub const PRIMARY: StreamRef = StreamRef { device: 0, stream: 0 };
}

/// Resource shape of one engine instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    pub devices: usize,
    pub streams_per_device: usize,
    pub host_threads: usize,
}

impl Default for Topology {
    fn default() -> Self {
        Topology {
            devices: 1,
            streams_per_device: 1,
            host_threads: 1,
        }
    }
}

/// O(1) readiness index over the stream array: the sync points and
/// launch counter the hot loops poll after every submit, maintained
/// incrementally instead of re-folded over all streams per query.
///
/// Exactness: a stream cursor only moves forward (durations are
/// non-negative, `start >= cursor`), and `f64::max` of a monotone
/// sequence is order-independent, so the running maxima are
/// *bit-identical* to the linear fold they replace — debug builds
/// assert it on every query. Float *sums* (`active_us`) stay
/// query-time folds: an incremental sum would change addition order.
#[derive(Debug, Clone)]
struct ReadyIndex {
    /// Per-device max sync point (when the device drains).
    device_sync: Vec<f64>,
    /// Global max sync point (when every stream drains).
    global_sync: f64,
    /// Kernels launched across every stream.
    launched: usize,
}

impl ReadyIndex {
    fn new(devices: usize) -> ReadyIndex {
        ReadyIndex {
            device_sync: vec![0.0; devices],
            global_sync: 0.0,
            launched: 0,
        }
    }

    fn note(&mut self, device: u32, end_us: f64) {
        let d = device as usize;
        if end_us > self.device_sync[d] {
            self.device_sync[d] = end_us;
        }
        if end_us > self.global_sync {
            self.global_sync = end_us;
        }
        self.launched += 1;
    }
}

/// The discrete-event timeline engine.
#[derive(Debug, Clone)]
pub struct Engine {
    topo: Topology,
    /// Host-thread cursors (time each dispatch thread is free again).
    hosts: Vec<f64>,
    /// Device-major stream states: index = device * streams_per_device
    /// + stream.
    streams: Vec<Stream>,
    /// Incrementally-maintained sync points / launch counter.
    ready: ReadyIndex,
}

impl Engine {
    pub fn new(topo: Topology) -> Engine {
        assert!(topo.devices >= 1, "topology needs at least one device");
        assert!(
            topo.streams_per_device >= 1,
            "topology needs at least one stream per device"
        );
        assert!(
            topo.host_threads >= 1,
            "topology needs at least one host thread"
        );
        Engine {
            topo,
            hosts: vec![0.0; topo.host_threads],
            streams: vec![Stream::new(); topo.devices * topo.streams_per_device],
            ready: ReadyIndex::new(topo.devices),
        }
    }

    /// The single-timeline engine (1 host thread, 1 device, 1 stream).
    pub fn single() -> Engine {
        Engine::new(Topology::default())
    }

    pub fn topology(&self) -> Topology {
        self.topo
    }

    fn idx(&self, s: StreamRef) -> usize {
        let d = s.device as usize;
        let st = s.stream as usize;
        assert!(d < self.topo.devices, "device {d} outside topology");
        assert!(
            st < self.topo.streams_per_device,
            "stream {st} outside topology"
        );
        d * self.topo.streams_per_device + st
    }

    // --- host threads ---------------------------------------------------

    /// Current cursor of host thread `tid`.
    pub fn host_now(&self, tid: usize) -> f64 {
        self.hosts[tid]
    }

    /// Occupy host thread `tid` for `dur_us`; returns `(start, end)`.
    pub fn host_advance(&mut self, tid: usize, dur_us: f64) -> (f64, f64) {
        let start = self.hosts[tid];
        let end = start + dur_us;
        self.hosts[tid] = end;
        (start, end)
    }

    /// Block host thread `tid` until at least `t_us` (device sync wait,
    /// serving idle jump, arrival gating). Never moves time backwards.
    pub fn host_wait_until(&mut self, tid: usize, t_us: f64) {
        self.hosts[tid] = self.hosts[tid].max(t_us);
    }

    // --- streams --------------------------------------------------------

    /// Submit a kernel to `s`, launched at `api_start_us` with the
    /// sampled empty-queue launch gap. Exactly [`Stream::submit`] on the
    /// addressed stream.
    pub fn submit(
        &mut self,
        s: StreamRef,
        api_start_us: f64,
        launch_gap_us: f64,
        dur_us: f64,
    ) -> KernelTiming {
        debug_assert!(dur_us >= 0.0, "kernel durations are non-negative");
        let i = self.idx(s);
        let t = self.streams[i].submit(api_start_us, launch_gap_us, dur_us);
        self.ready.note(s.device, t.end_us);
        t
    }

    /// Submit with an extra readiness dependency: the kernel cannot
    /// start before `dep_us` (cross-stream event wait — all-reduce
    /// joins, router→expert hand-offs). `dep_us = 0.0` is exactly
    /// [`Engine::submit`].
    pub fn submit_after(
        &mut self,
        s: StreamRef,
        api_start_us: f64,
        launch_gap_us: f64,
        dur_us: f64,
        dep_us: f64,
    ) -> KernelTiming {
        debug_assert!(dur_us >= 0.0, "kernel durations are non-negative");
        let i = self.idx(s);
        let t = self.streams[i].submit_dep(api_start_us, launch_gap_us, dep_us, dur_us);
        self.ready.note(s.device, t.end_us);
        t
    }

    /// When stream `s` drains (cudaStreamSynchronize).
    pub fn stream_sync_point(&self, s: StreamRef) -> f64 {
        self.streams[self.idx(s)].sync_point()
    }

    /// When every stream of `device` drains (cudaDeviceSynchronize).
    /// O(1): read off the [`ReadyIndex`] instead of folding the
    /// device's streams (bit-identical — monotone cursors).
    pub fn device_sync_point(&self, device: u32) -> f64 {
        let d = device as usize;
        assert!(d < self.topo.devices, "device {d} outside topology");
        debug_assert_eq!(self.ready.device_sync[d], {
            let spd = self.topo.streams_per_device;
            self.streams[d * spd..(d + 1) * spd]
                .iter()
                .map(Stream::sync_point)
                .fold(0.0f64, f64::max)
        });
        self.ready.device_sync[d]
    }

    /// When every stream on every device drains. With the single
    /// topology this is exactly the one stream's `sync_point()`.
    /// O(1): read off the [`ReadyIndex`].
    pub fn sync_point(&self) -> f64 {
        debug_assert_eq!(
            self.ready.global_sync,
            self.streams
                .iter()
                .map(Stream::sync_point)
                .fold(0.0f64, f64::max)
        );
        self.ready.global_sync
    }

    /// Latest cursor over an explicit stream set (all-reduce join).
    pub fn join(&self, streams: &[StreamRef]) -> f64 {
        streams
            .iter()
            .map(|&s| self.stream_sync_point(s))
            .fold(0.0f64, f64::max)
    }

    // --- accounting -----------------------------------------------------

    /// Σ kernel-active time on one device.
    pub fn device_active_us(&self, device: u32) -> f64 {
        let spd = self.topo.streams_per_device;
        let base = device as usize * spd;
        self.streams[base..base + spd]
            .iter()
            .map(Stream::active_us)
            .sum()
    }

    /// Σ kernel-active time over every stream.
    pub fn active_us(&self) -> f64 {
        self.streams.iter().map(Stream::active_us).sum()
    }

    /// Kernels launched over every stream. O(1): counted at submit.
    pub fn launched(&self) -> usize {
        debug_assert_eq!(
            self.ready.launched,
            self.streams.iter().map(Stream::launched).sum::<usize>()
        );
        self.ready.launched
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_topology_delegates_to_stream_exactly() {
        // Hand-checkable numbers mirroring device::Stream's own tests.
        let mut e = Engine::single();
        let t = e.submit(StreamRef::PRIMARY, 10.0, 4.7, 2.0);
        assert_eq!(t.start_us, 14.7);
        assert_eq!(t.end_us, 16.7);
        let mut s = Stream::new();
        let r = s.submit(10.0, 4.7, 2.0);
        assert_eq!((t.start_us, t.end_us), (r.start_us, r.end_us));
        assert_eq!(e.sync_point(), s.sync_point());
        assert_eq!(e.active_us(), s.active_us());
        assert_eq!(e.launched(), s.launched());
    }

    #[test]
    fn host_cursor_arithmetic() {
        let mut e = Engine::single();
        assert_eq!(e.host_now(0), 0.0);
        let (a, b) = e.host_advance(0, 3.5);
        assert_eq!((a, b), (0.0, 3.5));
        e.host_wait_until(0, 2.0); // backwards is a no-op
        assert_eq!(e.host_now(0), 3.5);
        e.host_wait_until(0, 10.0);
        assert_eq!(e.host_now(0), 10.0);
    }

    #[test]
    fn streams_are_independent_fifos() {
        let mut e = Engine::new(Topology {
            devices: 1,
            streams_per_device: 2,
            host_threads: 1,
        });
        let s0 = StreamRef { device: 0, stream: 0 };
        let s1 = StreamRef { device: 0, stream: 1 };
        let a = e.submit(s0, 0.0, 1.0, 100.0); // stream 0 busy to 101
        let b = e.submit(s1, 0.0, 1.0, 5.0); // stream 1 free: starts at 1
        assert_eq!(a.start_us, 1.0);
        assert_eq!(b.start_us, 1.0, "second stream does not queue behind the first");
        assert_eq!(e.stream_sync_point(s0), 101.0);
        assert_eq!(e.stream_sync_point(s1), 6.0);
        assert_eq!(e.sync_point(), 101.0);
    }

    #[test]
    fn submit_after_honors_cross_stream_dependency() {
        let mut e = Engine::new(Topology {
            devices: 1,
            streams_per_device: 2,
            host_threads: 1,
        });
        let s0 = StreamRef { device: 0, stream: 0 };
        let s1 = StreamRef { device: 0, stream: 1 };
        let a = e.submit(s0, 0.0, 1.0, 50.0); // ends 51
        // Dependent kernel on stream 1 must wait for the stream-0 event.
        let b = e.submit_after(s1, 0.0, 1.0, 2.0, a.end_us);
        assert_eq!(b.start_us, 51.0);
        // Zero dependency degrades to plain submit.
        let mut e2 = Engine::single();
        let p = e2.submit_after(StreamRef::PRIMARY, 3.0, 1.5, 2.0, 0.0);
        let mut s = Stream::new();
        let q = s.submit(3.0, 1.5, 2.0);
        assert_eq!((p.start_us, p.end_us), (q.start_us, q.end_us));
    }

    #[test]
    fn per_device_accounting_partitions_totals() {
        let mut e = Engine::new(Topology {
            devices: 2,
            streams_per_device: 2,
            host_threads: 2,
        });
        e.submit(StreamRef { device: 0, stream: 0 }, 0.0, 1.0, 10.0);
        e.submit(StreamRef { device: 0, stream: 1 }, 0.0, 1.0, 20.0);
        e.submit(StreamRef { device: 1, stream: 0 }, 0.0, 1.0, 40.0);
        assert_eq!(e.device_active_us(0), 30.0);
        assert_eq!(e.device_active_us(1), 40.0);
        assert_eq!(e.active_us(), 70.0);
        assert_eq!(e.launched(), 3);
        assert_eq!(e.device_sync_point(0), 21.0);
        assert_eq!(e.device_sync_point(1), 41.0);
        assert_eq!(
            e.join(&[
                StreamRef { device: 0, stream: 1 },
                StreamRef { device: 1, stream: 0 }
            ]),
            41.0
        );
    }

    #[test]
    fn ready_index_matches_linear_fold_under_interleaved_submits() {
        // Exercise the O(1) index against the fold it replaced: the
        // debug_asserts inside the queries do the comparison, so this
        // test just has to interleave submits and queries across a
        // non-trivial topology. Deterministic pseudo-random pattern.
        let mut e = Engine::new(Topology {
            devices: 3,
            streams_per_device: 2,
            host_threads: 1,
        });
        let mut rng = crate::util::rng::Rng::new(7);
        let mut dep = 0.0f64;
        for i in 0..500 {
            let s = StreamRef {
                device: rng.below(3) as u32,
                stream: rng.below(2) as u32,
            };
            let api = i as f64 * 0.25;
            let gap = 1.0 + rng.next_f64();
            let dur = rng.next_f64() * 5.0;
            let t = if i % 3 == 0 {
                e.submit_after(s, api, gap, dur, dep)
            } else {
                e.submit(s, api, gap, dur)
            };
            dep = t.end_us;
            // Each query re-checks the index against the fold in
            // debug builds.
            let per_dev: f64 = (0..3u32)
                .map(|d| e.device_sync_point(d))
                .fold(0.0f64, f64::max);
            assert_eq!(per_dev, e.sync_point(), "global max is the max of per-device maxes");
            assert_eq!(e.launched(), i + 1);
        }
        assert!(e.sync_point() > 0.0);
        assert!(e.active_us() > 0.0);
    }

    #[test]
    #[should_panic(expected = "outside topology")]
    fn out_of_topology_stream_panics() {
        let mut e = Engine::single();
        e.submit(StreamRef { device: 0, stream: 1 }, 0.0, 0.0, 1.0);
    }
}
