//! Typed run configuration with JSON load/save.
//!
//! A [`RunConfig`] fully describes one measurement: model, platform,
//! workload point, replay protocol and mitigation mode. The CLI accepts
//! `--config file.json` (flags override file values), and sweep drivers
//! serialize the exact config next to every result for provenance.

use std::path::Path;

use crate::hardware::Platform;
use crate::models::{self, ModelSpec};
use crate::sim::{Mitigation, Phase, Workload};
use crate::taxbreak::ReplayConfig;
use crate::util::json::Json;

/// One fully-specified measurement run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub model: String,
    pub platform: String,
    pub phase: Phase,
    pub batch: usize,
    pub seq: usize,
    pub m_tokens: usize,
    pub fused_attention: bool,
    pub mitigation: Mitigation,
    pub seed: u64,
    pub warmup: usize,
    pub runs: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "gpt2".to_string(),
            platform: "h200".to_string(),
            phase: Phase::Prefill,
            batch: 1,
            seq: 512,
            m_tokens: 10,
            fused_attention: false,
            mitigation: Mitigation::None,
            seed: 2026,
            // Paper §IV: W=50 warm-up, R=150 measured runs.
            warmup: 50,
            runs: 150,
        }
    }
}

impl RunConfig {
    pub fn workload(&self) -> Workload {
        let wl = match self.phase {
            Phase::Prefill => Workload::prefill(self.batch, self.seq),
            Phase::Decode => Workload::decode(self.batch, self.seq, self.m_tokens),
        };
        wl.with_fused_attention(self.fused_attention)
            .with_mitigation(self.mitigation)
    }

    pub fn model_spec(&self) -> anyhow::Result<ModelSpec> {
        models::by_name(&self.model)
    }

    pub fn platform_spec(&self) -> anyhow::Result<Platform> {
        Platform::by_name(&self.platform)
    }

    pub fn replay_config(&self) -> ReplayConfig {
        ReplayConfig {
            warmup: self.warmup,
            runs: self.runs,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("model", self.model.as_str())
            .with("platform", self.platform.as_str())
            .with("phase", self.phase.as_str())
            .with("batch", self.batch)
            .with("seq", self.seq)
            .with("m_tokens", self.m_tokens)
            .with("fused_attention", self.fused_attention)
            .with("mitigation", self.mitigation.as_str())
            .with("seed", self.seed)
            .with("warmup", self.warmup)
            .with("runs", self.runs)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<RunConfig> {
        let d = RunConfig::default();
        let phase = match v.get("phase").and_then(|p| p.as_str()) {
            None => d.phase,
            Some("prefill") => Phase::Prefill,
            Some("decode") => Phase::Decode,
            Some(other) => anyhow::bail!("bad phase '{other}'"),
        };
        let mitigation = match v.get("mitigation").and_then(|m| m.as_str()) {
            None => d.mitigation,
            Some(tag) => Mitigation::parse(tag)?,
        };
        let get_usize = |key: &str, dv: usize| -> anyhow::Result<usize> {
            match v.get(key) {
                None => Ok(dv),
                Some(x) => x
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("'{key}' must be an unsigned integer")),
            }
        };
        Ok(RunConfig {
            model: v
                .get("model")
                .and_then(|m| m.as_str())
                .unwrap_or(&d.model)
                .to_string(),
            platform: v
                .get("platform")
                .and_then(|m| m.as_str())
                .unwrap_or(&d.platform)
                .to_string(),
            phase,
            batch: get_usize("batch", d.batch)?,
            seq: get_usize("seq", d.seq)?,
            m_tokens: get_usize("m_tokens", d.m_tokens)?,
            fused_attention: v
                .get("fused_attention")
                .and_then(|b| b.as_bool())
                .unwrap_or(d.fused_attention),
            mitigation,
            seed: v.get("seed").and_then(|s| s.as_u64()).unwrap_or(d.seed),
            warmup: get_usize("warmup", d.warmup)?,
            runs: get_usize("runs", d.runs)?,
        })
    }

    pub fn load(path: &Path) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        RunConfig::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let c = RunConfig {
            model: "olmoe-1b-7b".into(),
            phase: Phase::Decode,
            mitigation: Mitigation::CudaGraphs,
            batch: 4,
            ..RunConfig::default()
        };
        let back = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn partial_json_fills_defaults() {
        let c = RunConfig::from_json(&Json::parse(r#"{"model": "gpt2", "batch": 8}"#).unwrap())
            .unwrap();
        assert_eq!(c.batch, 8);
        assert_eq!(c.seq, 512);
        assert_eq!(c.runs, 150);
        assert_eq!(c.mitigation, Mitigation::None);
    }

    #[test]
    fn rejects_bad_phase_and_mitigation() {
        assert!(RunConfig::from_json(&Json::parse(r#"{"phase": "warp"}"#).unwrap()).is_err());
        assert!(
            RunConfig::from_json(&Json::parse(r#"{"mitigation": "magic"}"#).unwrap()).is_err()
        );
    }

    #[test]
    fn resolves_specs() {
        let c = RunConfig::default();
        assert_eq!(c.model_spec().unwrap().name, "gpt2");
        assert_eq!(c.platform_spec().unwrap().name, "h200");
        assert_eq!(c.replay_config().runs, 150);
        assert_eq!(c.workload().batch, 1);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("taxbreak_config_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.json");
        let c = RunConfig::default();
        c.save(&path).unwrap();
        assert_eq!(RunConfig::load(&path).unwrap(), c);
    }
}
