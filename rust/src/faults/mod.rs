//! Fault injection: deterministic, seeded perturbations of a serving
//! run (`taxbreak loadgen --faults SPEC`).
//!
//! TaxBreak's decomposition is only actionable if it survives
//! non-fair-weather runs: production serving is defined by SLOs under
//! device stalls, host jitter storms, transient launch failures and KV
//! pressure. A [`FaultPlan`] is a *pre-realized* list of fault windows
//! — every window is fixed before the run starts, a pure function of
//! the spec (and, for `storm:SEED:N` clauses, of the seed), never of
//! run dynamics. That choice is what keeps record → replay → re-record
//! a byte-equal fixed point under faults (DESIGN.md §16):
//!
//! * every armed window is emitted as a first-class spec-v4 `fault`
//!   trace event (corr id 0, decomposition-blind), so a capture carries
//!   its own fault schedule;
//! * replay re-arms the schedule from those events and re-applies the
//!   *computed* perturbations (device stalls, launch-failure retries)
//!   while the *sampled* perturbations (host jitter) ride the recorded
//!   `rng_draw` values automatically;
//! * KV-pressure windows shape only live admission decisions, which
//!   replay takes from the recorded `sched_decision` events verbatim.
//!
//! The four kinds map onto the paper's overhead components: host
//! jitter dilates the T_fw/T_lib/T_launch host segments, device stalls
//! dilate kernel time on a stream, launch failures pay the launch path
//! again per retry, and KV pressure converts capacity into queueing
//! (sheds/preemptions) without touching any segment.

use crate::util::rng::Rng;

/// Kind of an injected fault window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// Multiplicative straggler window on a device stream: kernel
    /// durations on the target stream are scaled by `magnitude`.
    DeviceStall,
    /// Host jitter storm: host-latency draws (prep and/or exec) are
    /// scaled by `magnitude` while the window is active.
    HostJitter,
    /// Transient kernel-launch failures: a launch issued inside the
    /// window fails `ceil(magnitude)` times before succeeding, paying
    /// the launch path (a fresh exec draw + exponential backoff) per
    /// attempt; at [`MAX_LAUNCH_ATTEMPTS`] the invocation fails with a
    /// typed transient error instead.
    LaunchFail,
    /// Transient KV-page pressure: a `magnitude` fraction of the pool
    /// is sequestered while the window is active, forcing backpressure
    /// (sheds / preemptions) at admission time.
    KvPressure,
}

impl FaultKind {
    /// Stable tag serialized in the spec-v4 `fault` event.
    pub fn as_str(&self) -> &'static str {
        match self {
            FaultKind::DeviceStall => "device_stall",
            FaultKind::HostJitter => "host_jitter",
            FaultKind::LaunchFail => "launch_fail",
            FaultKind::KvPressure => "kv_pressure",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<FaultKind> {
        Ok(match s {
            "device_stall" => FaultKind::DeviceStall,
            "host_jitter" => FaultKind::HostJitter,
            "launch_fail" => FaultKind::LaunchFail,
            "kv_pressure" => FaultKind::KvPressure,
            other => anyhow::bail!(
                "unknown fault kind '{other}' (expected device_stall, host_jitter, \
                 launch_fail or kv_pressure)"
            ),
        })
    }
}

/// Host-latency segment a jitter window targets. The simulated engine
/// splits each invocation's host span into a preparation draw (the
/// T_fw framework analog, the `AtenOp` span) and an execute-call draw
/// (the T_lib/T_launch analog, the `RuntimeApi` span) — jitter can
/// dilate either individually or both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostSeg {
    Prep,
    Exec,
}

/// Bounded retry budget for transient launch failures: a window asking
/// for this many (or more) failures exhausts the retry loop and the
/// invocation fails with a typed transient error — never a panic.
pub const MAX_LAUNCH_ATTEMPTS: u32 = 6;

/// Base of the deterministic exponential backoff paid between launch
/// retries, us (attempt `i` waits `BACKOFF_BASE_US * 2^i`).
pub const BACKOFF_BASE_US: f64 = 25.0;

/// Marker every transient launch-exhaustion error carries; the
/// scheduler detects it by substring (the vendored error type has no
/// downcast) and degrades the group to `Failed` instead of panicking.
pub const TRANSIENT_LAUNCH_MARKER: &str = "transient launch failure";

/// One realized fault window. `target` is the stable string serialized
/// into the spec-v4 `fault` event:
/// `stream:N` / `stream:*` (device stalls), `host:prep` / `host:exec` /
/// `host:all` (jitter), `launch` (launch failures), `kv` (KV pressure).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultWindow {
    pub kind: FaultKind,
    pub target: String,
    pub onset_us: f64,
    pub dur_us: f64,
    pub magnitude: f64,
}

impl FaultWindow {
    /// Is the window active at virtual time `t_us`? Half-open
    /// `[onset, onset + dur)`, so back-to-back windows never overlap.
    pub fn active_at(&self, t_us: f64) -> bool {
        t_us >= self.onset_us && t_us < self.onset_us + self.dur_us
    }

    /// Does the stall window target `stream`? (`stream:*` hits all.)
    fn hits_stream(&self, stream: u32) -> bool {
        self.target == "stream:*" || self.target == format!("stream:{stream}")
    }

    /// Does the jitter window target host segment `seg`?
    fn hits_seg(&self, seg: HostSeg) -> bool {
        match seg {
            HostSeg::Prep => self.target == "host:prep" || self.target == "host:all",
            HostSeg::Exec => self.target == "host:exec" || self.target == "host:all",
        }
    }
}

/// A deterministic fault plan: the realized window list plus the spec
/// it was parsed from (echoed in reports).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub spec: String,
    pub windows: Vec<FaultWindow>,
}

impl FaultPlan {
    /// Parse a `--faults` spec: `;`-separated clauses, each
    ///
    /// * `stall:ONSET:DUR:MAG[:STREAM]` — device stall (`MAG >= 1`
    ///   multiplier; `STREAM` a stream id, default every stream),
    /// * `jitter:ONSET:DUR:MAG[:SEG]` — host jitter (`SEG` one of
    ///   `prep`/`exec`/`all`, default `all`),
    /// * `launchfail:ONSET:DUR:ATTEMPTS` — launches inside the window
    ///   fail `ATTEMPTS` times before succeeding,
    /// * `kv:ONSET:DUR:FRAC` — sequester `FRAC` (0..=1) of KV pages,
    /// * `storm:SEED:N` — N seeded pseudo-random windows of mixed
    ///   kinds (the chaos generator).
    ///
    /// Times are microseconds of virtual time.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut windows = Vec::new();
        for clause in spec.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            let parts: Vec<&str> = clause.split(':').collect();
            let num = |s: &str, what: &str| -> anyhow::Result<f64> {
                let v: f64 = s
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad {what} '{s}' in fault clause '{clause}'"))?;
                anyhow::ensure!(
                    v.is_finite() && v >= 0.0,
                    "{what} must be finite and >= 0 in fault clause '{clause}'"
                );
                Ok(v)
            };
            match parts.as_slice() {
                ["stall", onset, dur, mag] | ["stall", onset, dur, mag, _] => {
                    let magnitude = num(mag, "magnitude")?;
                    anyhow::ensure!(
                        magnitude >= 1.0,
                        "stall magnitude must be >= 1 (a slowdown factor), got '{mag}'"
                    );
                    let target = match parts.get(4) {
                        Some(s) => {
                            let id: u32 = s.parse().map_err(|_| {
                                anyhow::anyhow!("bad stall stream '{s}' in fault clause '{clause}'")
                            })?;
                            format!("stream:{id}")
                        }
                        None => "stream:*".to_string(),
                    };
                    windows.push(FaultWindow {
                        kind: FaultKind::DeviceStall,
                        target,
                        onset_us: num(onset, "onset")?,
                        dur_us: num(dur, "duration")?,
                        magnitude,
                    });
                }
                ["jitter", onset, dur, mag] | ["jitter", onset, dur, mag, _] => {
                    let magnitude = num(mag, "magnitude")?;
                    anyhow::ensure!(
                        magnitude >= 1.0,
                        "jitter magnitude must be >= 1 (a dilation factor), got '{mag}'"
                    );
                    let target = match parts.get(4) {
                        Some(&"prep") => "host:prep",
                        Some(&"exec") => "host:exec",
                        Some(&"all") | None => "host:all",
                        Some(other) => anyhow::bail!(
                            "bad jitter segment '{other}' in fault clause '{clause}' \
                             (expected prep, exec or all)"
                        ),
                    }
                    .to_string();
                    windows.push(FaultWindow {
                        kind: FaultKind::HostJitter,
                        target,
                        onset_us: num(onset, "onset")?,
                        dur_us: num(dur, "duration")?,
                        magnitude,
                    });
                }
                ["launchfail", onset, dur, attempts] => {
                    let magnitude = num(attempts, "attempts")?;
                    anyhow::ensure!(
                        magnitude >= 1.0 && magnitude == magnitude.trunc(),
                        "launchfail attempts must be a whole number >= 1, got '{attempts}'"
                    );
                    windows.push(FaultWindow {
                        kind: FaultKind::LaunchFail,
                        target: "launch".to_string(),
                        onset_us: num(onset, "onset")?,
                        dur_us: num(dur, "duration")?,
                        magnitude,
                    });
                }
                ["kv", onset, dur, frac] => {
                    let magnitude = num(frac, "fraction")?;
                    anyhow::ensure!(
                        magnitude <= 1.0,
                        "kv pressure fraction must be in 0..=1, got '{frac}'"
                    );
                    windows.push(FaultWindow {
                        kind: FaultKind::KvPressure,
                        target: "kv".to_string(),
                        onset_us: num(onset, "onset")?,
                        dur_us: num(dur, "duration")?,
                        magnitude,
                    });
                }
                ["storm", seed, n] => {
                    let seed: u64 = seed.parse().map_err(|_| {
                        anyhow::anyhow!("bad storm seed '{seed}' in fault clause '{clause}'")
                    })?;
                    let n: usize = n.parse().map_err(|_| {
                        anyhow::anyhow!("bad storm count '{n}' in fault clause '{clause}'")
                    })?;
                    anyhow::ensure!(
                        (1..=256).contains(&n),
                        "storm count must be in 1..=256, got {n}"
                    );
                    windows.extend(storm_windows(seed, n));
                }
                _ => anyhow::bail!(
                    "bad fault clause '{clause}': expected stall:ONSET:DUR:MAG[:STREAM], \
                     jitter:ONSET:DUR:MAG[:prep|exec|all], launchfail:ONSET:DUR:ATTEMPTS, \
                     kv:ONSET:DUR:FRAC or storm:SEED:N"
                ),
            }
        }
        anyhow::ensure!(!windows.is_empty(), "fault spec '{spec}' contains no clauses");
        Ok(FaultPlan {
            spec: spec.to_string(),
            windows,
        })
    }

    /// Rebuild a plan from windows extracted out of a capture's spec-v4
    /// `fault` events (`serving::replay` re-arming path).
    pub fn from_windows(windows: Vec<FaultWindow>) -> FaultPlan {
        FaultPlan {
            spec: "(replayed)".to_string(),
            windows,
        }
    }

    /// Product of active host-jitter magnitudes for segment `seg` at
    /// time `t_us` (1.0 outside every window).
    pub fn host_factor(&self, t_us: f64, seg: HostSeg) -> f64 {
        self.windows
            .iter()
            .filter(|w| {
                w.kind == FaultKind::HostJitter && w.active_at(t_us) && w.hits_seg(seg)
            })
            .map(|w| w.magnitude)
            .product()
    }

    /// Product of active device-stall magnitudes for `stream` at time
    /// `t_us` (1.0 outside every window).
    pub fn stall_factor(&self, t_us: f64, stream: u32) -> f64 {
        self.windows
            .iter()
            .filter(|w| {
                w.kind == FaultKind::DeviceStall && w.active_at(t_us) && w.hits_stream(stream)
            })
            .map(|w| w.magnitude)
            .product()
    }

    /// Number of times a launch issued at `t_us` fails before
    /// succeeding (0 outside every window; the max over overlapping
    /// windows).
    pub fn launch_failures(&self, t_us: f64) -> u32 {
        self.windows
            .iter()
            .filter(|w| w.kind == FaultKind::LaunchFail && w.active_at(t_us))
            .map(|w| w.magnitude as u32)
            .max()
            .unwrap_or(0)
    }

    /// KV pages sequestered at `t_us` out of a pool of `total` (the max
    /// fraction over overlapping windows; never the whole pool, so a
    /// storm cannot render the scheduler permanently stuck).
    pub fn kv_sequestered(&self, t_us: f64, total: usize) -> usize {
        let frac = self
            .windows
            .iter()
            .filter(|w| w.kind == FaultKind::KvPressure && w.active_at(t_us))
            .map(|w| w.magnitude)
            .fold(0.0f64, f64::max);
        ((total as f64 * frac) as usize).min(total.saturating_sub(1))
    }

    /// Does any window of `kind` exist in the plan?
    pub fn has_kind(&self, kind: FaultKind) -> bool {
        self.windows.iter().any(|w| w.kind == kind)
    }
}

/// The chaos generator: `n` pseudo-random fault windows, a pure
/// function of `seed`. Magnitudes stay in ranges the property suite
/// can always survive (stalls/jitter 1..=8x, 1..=3 launch failures,
/// up to 90% KV sequestration).
fn storm_windows(seed: u64, n: usize) -> Vec<FaultWindow> {
    let mut rng = Rng::new(seed).fork_str("fault-storm");
    (0..n)
        .map(|_| {
            let onset_us = rng.next_f64() * 20_000.0;
            let dur_us = 100.0 + rng.next_f64() * 5_000.0;
            match rng.below(4) {
                0 => FaultWindow {
                    kind: FaultKind::DeviceStall,
                    target: if rng.below(2) == 0 {
                        "stream:*".to_string()
                    } else {
                        format!("stream:{}", rng.below(4))
                    },
                    onset_us,
                    dur_us,
                    magnitude: 1.0 + rng.next_f64() * 7.0,
                },
                1 => FaultWindow {
                    kind: FaultKind::HostJitter,
                    target: ["host:prep", "host:exec", "host:all"][rng.below(3)].to_string(),
                    onset_us,
                    dur_us,
                    magnitude: 1.0 + rng.next_f64() * 7.0,
                },
                2 => FaultWindow {
                    kind: FaultKind::LaunchFail,
                    target: "launch".to_string(),
                    onset_us,
                    dur_us,
                    magnitude: (1 + rng.below(3)) as f64,
                },
                _ => FaultWindow {
                    kind: FaultKind::KvPressure,
                    target: "kv".to_string(),
                    onset_us,
                    dur_us,
                    magnitude: rng.next_f64() * 0.9,
                },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_clause_kind() {
        let p = FaultPlan::parse(
            "stall:1000:500:3.0:1;jitter:0:2000:4.0:prep;launchfail:100:50:2;kv:10:20:0.5",
        )
        .unwrap();
        assert_eq!(p.windows.len(), 4);
        assert_eq!(p.windows[0].kind, FaultKind::DeviceStall);
        assert_eq!(p.windows[0].target, "stream:1");
        assert_eq!(p.windows[1].target, "host:prep");
        assert_eq!(p.windows[2].magnitude, 2.0);
        assert_eq!(p.windows[3].target, "kv");
        // Defaults: all streams, all host segments.
        let d = FaultPlan::parse("stall:0:1:2;jitter:0:1:2").unwrap();
        assert_eq!(d.windows[0].target, "stream:*");
        assert_eq!(d.windows[1].target, "host:all");
    }

    #[test]
    fn rejects_malformed_clauses() {
        for bad in [
            "",
            "stall:0:1",
            "stall:0:1:0.5",      // slowdown below 1
            "jitter:0:1:2:weird", // unknown segment
            "launchfail:0:1:1.5", // fractional attempts
            "kv:0:1:1.5",         // fraction above 1
            "storm:7:0",          // empty storm
            "storm:x:4",
            "nonsense:1:2:3",
            "stall:a:1:2",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn storm_is_deterministic_and_seeded() {
        let a = FaultPlan::parse("storm:7:16").unwrap();
        let b = FaultPlan::parse("storm:7:16").unwrap();
        let c = FaultPlan::parse("storm:8:16").unwrap();
        assert_eq!(a, b);
        assert_ne!(a.windows, c.windows);
        assert_eq!(a.windows.len(), 16);
        for w in &a.windows {
            assert!(w.onset_us >= 0.0 && w.dur_us > 0.0);
            match w.kind {
                FaultKind::DeviceStall | FaultKind::HostJitter => {
                    assert!((1.0..=8.0).contains(&w.magnitude))
                }
                FaultKind::LaunchFail => {
                    assert!(w.magnitude >= 1.0 && w.magnitude <= 3.0)
                }
                FaultKind::KvPressure => assert!((0.0..=0.9).contains(&w.magnitude)),
            }
        }
    }

    #[test]
    fn factors_compose_and_respect_windows() {
        let p = FaultPlan::parse(
            "jitter:100:100:2.0:prep;jitter:150:100:3.0:all;stall:0:50:4.0:2",
        )
        .unwrap();
        assert_eq!(p.host_factor(50.0, HostSeg::Prep), 1.0);
        assert_eq!(p.host_factor(120.0, HostSeg::Prep), 2.0);
        assert_eq!(p.host_factor(120.0, HostSeg::Exec), 1.0);
        assert_eq!(p.host_factor(180.0, HostSeg::Prep), 6.0); // both active
        assert_eq!(p.host_factor(220.0, HostSeg::Exec), 3.0);
        assert_eq!(p.stall_factor(10.0, 2), 4.0);
        assert_eq!(p.stall_factor(10.0, 1), 1.0, "stall targets stream 2 only");
        assert_eq!(p.stall_factor(60.0, 2), 1.0, "window over");
        // Half-open: the onset is in, the end is out.
        assert_eq!(p.stall_factor(0.0, 2), 4.0);
        assert_eq!(p.stall_factor(50.0, 2), 1.0);
    }

    #[test]
    fn launch_failures_and_kv_sequestration() {
        let p = FaultPlan::parse("launchfail:0:100:3;kv:0:100:0.5;kv:50:100:0.75").unwrap();
        assert_eq!(p.launch_failures(50.0), 3);
        assert_eq!(p.launch_failures(200.0), 0);
        assert_eq!(p.kv_sequestered(10.0, 64), 32);
        assert_eq!(p.kv_sequestered(60.0, 64), 48, "max of overlapping fractions");
        assert_eq!(p.kv_sequestered(10.0, 1), 0, "never sequesters the whole pool");
        assert_eq!(p.kv_sequestered(500.0, 64), 0);
    }

    #[test]
    fn kind_tags_roundtrip() {
        for k in [
            FaultKind::DeviceStall,
            FaultKind::HostJitter,
            FaultKind::LaunchFail,
            FaultKind::KvPressure,
        ] {
            assert_eq!(FaultKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(FaultKind::parse("gremlin").is_err());
    }
}
