//! Attention block lowering: eager multi-kernel path vs the fused
//! FlashAttention-2-style single kernel (the Fig. 9 ablation pair).

use crate::lowering::{PassKind, LowerOpts, SeqBuilder};

/// Lower one attention block (pre-norm + projections + attention core +
/// output projection + residual).
pub fn lower_attention_block(
    b: &mut SeqBuilder,
    layer: usize,
    kind: PassKind,
    opts: &LowerOpts,
) {
    let m = b.model;
    let (bs, sq, ctx) = (b.batch, b.seq_q, b.ctx);
    let tokens = bs * sq;
    let tag = if m.gemm_lib == crate::models::GemmLib::Nvjet {
        // GPT-2 path uses LayerNorm; Llama-family uses RMSNorm.
        b.layernorm("ln_attn");
        "attn"
    } else {
        b.rmsnorm("ln_attn");
        "attn"
    };

    // q/k/v projections (GQA: k/v are narrower).
    b.gemm("aten::linear", &format!("{tag}_q"), tokens, m.qkv_dim(), m.d_model, 1);
    b.gemm("aten::linear", &format!("{tag}_k"), tokens, m.kv_dim(), m.d_model, 1);
    b.gemm("aten::linear", &format!("{tag}_v"), tokens, m.kv_dim(), m.d_model, 1);

    // RoPE (Llama-family only; GPT-2 uses learned positions).
    if m.gemm_lib == crate::models::GemmLib::Cublas {
        let qk_elems = tokens * (m.qkv_dim() + m.kv_dim());
        b.elem("aten::mul", "rope_cos", qk_elems);
        b.elem("aten::mul", "rope_sin", qk_elems);
        b.elem("aten::cat", "rope_rotate_half", qk_elems);
        b.elem("aten::add", "rope_combine", qk_elems);
    }

    // KV-cache update in decode: write the step's k/v at `pos`.
    if kind == PassKind::DecodeStep {
        b.scatter("aten::index_copy_", "kv_cache_k", bs, m.kv_dim());
        b.scatter("aten::index_copy_", "kv_cache_v", bs, m.kv_dim());
    }

    // GQA head expansion: repeat_interleave materializes k/v at the
    // full query-head width every pass — a 4x write amplification for
    // Llama-3.2 (32q/8kv) that decode pays per step over the whole
    // cache.
    if m.n_kv_heads < m.n_heads {
        b.gather("aten::repeat_interleave", "gqa_expand_k", bs * ctx, m.qkv_dim());
        b.gather("aten::repeat_interleave", "gqa_expand_v", bs * ctx, m.qkv_dim());
    }

    if opts.fused_attention {
        // One fused kernel replaces the 6-kernel eager core.
        b.fused_attention(m.n_heads, m.head_dim);
    } else {
        // Eager attention: materializes the (sq × ctx) score matrix.
        // Every op on it round-trips the full matrix through HBM — the
        // traffic FA2 eliminates (Fig. 9's device-side win); the
        // 2x factor reflects the fp32 upcast of the softmax path.
        let bh = bs * m.n_heads;
        let score = 2 * bh * sq * ctx;
        // QK^T
        b.gemm("aten::bmm", "attn_qk", sq, ctx, m.head_dim, bh);
        // scale
        b.elem("aten::div", "attn_scale", score);
        // causal / validity mask add (prefill builds the full mask).
        if kind == PassKind::Prefill {
            b.elem("aten::add", "attn_mask", score);
        }
        // softmax over ctx
        b.reduce("aten::_softmax", "softmax_warp", score);
        // AV
        b.gemm("aten::bmm", "attn_av", sq, m.head_dim, ctx, bh);
        // merge-heads contiguity copy
        b.elem("aten::clone", "attn_merge", tokens * m.qkv_dim());
    }

    // Output projection + residual.
    b.gemm("aten::linear", &format!("{tag}_o"), tokens, m.d_model, m.qkv_dim(), 1);
    b.elem("aten::add", "residual_attn", tokens * m.d_model);

    let _ = layer;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn block_len(model: &crate::models::ModelSpec, kind: PassKind, fused: bool) -> usize {
        let mut b = SeqBuilder::new(model, 2, if kind == PassKind::Prefill { 64 } else { 1 }, 64);
        lower_attention_block(
            &mut b,
            0,
            kind,
            &LowerOpts {
                fused_attention: fused,
            },
        );
        b.len()
    }

    #[test]
    fn fused_saves_five_kernels_in_prefill() {
        let m = models::llama_1b();
        let eager = block_len(&m, PassKind::Prefill, false);
        let fused = block_len(&m, PassKind::Prefill, true);
        assert_eq!(eager - fused, 5); // 6-kernel core -> 1 fused kernel
    }

    #[test]
    fn decode_adds_cache_writes() {
        let m = models::llama_1b();
        let mut b = SeqBuilder::new(&m, 1, 1, 64);
        lower_attention_block(&mut b, 0, PassKind::DecodeStep, &LowerOpts::default());
        let seq = b.finish();
        let cache_writes = seq
            .iter()
            .filter(|k| k.aten_op == "aten::index_copy_")
            .count();
        assert_eq!(cache_writes, 2);
    }

    #[test]
    fn gqa_models_expand_kv() {
        let m = models::llama_1b(); // 32 q heads / 8 kv heads
        let mut b = SeqBuilder::new(&m, 1, 8, 8);
        lower_attention_block(&mut b, 0, PassKind::Prefill, &LowerOpts::default());
        let seq = b.finish();
        assert!(seq.iter().any(|k| k.aten_op == "aten::repeat_interleave"));

        let m = models::gpt2(); // MHA
        let mut b = SeqBuilder::new(&m, 1, 8, 8);
        lower_attention_block(&mut b, 0, PassKind::Prefill, &LowerOpts::default());
        let seq = b.finish();
        assert!(!seq.iter().any(|k| k.aten_op == "aten::repeat_interleave"));
    }

    #[test]
    fn eager_prefill_score_matrix_is_quadratic() {
        let m = models::llama_1b();
        let grab = |sl: usize| -> f64 {
            let mut b = SeqBuilder::new(&m, 1, sl, sl);
            lower_attention_block(&mut b, 0, PassKind::Prefill, &LowerOpts::default());
            b.finish()
                .iter()
                .find(|k| k.kernel_name.contains("attn_qk"))
                .unwrap()
                .flops
        };
        let r = grab(1024) / grab(512);
        assert!((r - 4.0).abs() < 1e-9, "QK^T flops must scale as S^2: {r}");
    }
}
