//! Eager-mode lowering: model × phase × (batch, seq) → kernel sequence.
//!
//! This is the PyTorch-eager analog: each forward pass expands into the
//! ordered list of kernel launches the framework would emit, with
//! analytic FLOPs/bytes for the device cost model and full `KernelMeta`
//! (ATen op, shapes key, launch config, `I_lib`) for TaxBreak.
//!
//! Structure per layer: RMSNorm/LayerNorm glue → q/k/v projections →
//! RoPE → (eager attention: QKᵀ, scale, mask, softmax, AV — or ONE fused
//! FlashAttention-2 kernel, Fig. 9) → output projection → FFN (dense
//! GELU/SwiGLU, or the MoE router + per-expert loop).
//!
//! The MoE expert loop mirrors HF eager implementations: **every**
//! expert iterates (index bookkeeping dispatches regardless of
//! assignment), which is why observed MoE kernel counts are nearly
//! batch-invariant (§V-A: OLMoE decode latency flat across context;
//! Table II counts at BS=4 match BS=1 observations).  Kernel-count
//! calibration constants live in `models::catalog` and are verified
//! against Table II by the lowering unit tests and `taxbreak repro table2`.

pub mod attention;
pub mod builder;
pub mod dense;
pub mod moe;

use crate::models::ModelSpec;
use crate::trace::KernelMeta;
use crate::util::rng::Rng;

pub use builder::{Mark, MarkKind, SeqBuilder};

/// Inference phase of one lowered pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassKind {
    /// Process `seq_q` prompt tokens; context == seq_q.
    Prefill,
    /// One autoregressive step: 1 new token/seq over `ctx` cached
    /// tokens.
    DecodeStep,
}

/// Options shared across the lowering.
#[derive(Debug, Clone, Copy)]
pub struct LowerOpts {
    /// Use the fused FlashAttention-2-style kernel instead of the eager
    /// multi-kernel attention sequence (Fig. 9 ablation).
    pub fused_attention: bool,
}

impl Default for LowerOpts {
    fn default() -> Self {
        LowerOpts {
            fused_attention: false,
        }
    }
}

/// Lower one forward pass.
///
/// * `batch` — sequences in the batch.
/// * `seq_q` — tokens processed per sequence this pass (prompt length
///   for prefill, 1 for a decode step).
/// * `ctx` — attention context length (== seq_q in prefill; cached
///   tokens + 1 in decode).
///
/// `rng` drives MoE token-to-expert assignment (autotune-style shape
/// variety); lowering is deterministic given the seed.
pub fn lower_pass(
    model: &ModelSpec,
    kind: PassKind,
    batch: usize,
    seq_q: usize,
    ctx: usize,
    opts: &LowerOpts,
    rng: &mut Rng,
) -> Vec<KernelMeta> {
    lower_pass_marked(model, kind, batch, seq_q, ctx, opts, rng).0
}

/// [`lower_pass`] keeping the structural [`Mark`]s: layer boundaries
/// (tensor-parallel all-reduce points) and, for MoE models, expert
/// chain starts + the combine (expert-parallel shard boundaries).
/// Marks annotate positions only — the kernel sequence and every RNG
/// draw are identical to `lower_pass`.
pub fn lower_pass_marked(
    model: &ModelSpec,
    kind: PassKind,
    batch: usize,
    seq_q: usize,
    ctx: usize,
    opts: &LowerOpts,
    rng: &mut Rng,
) -> (Vec<KernelMeta>, Vec<Mark>) {
    let mut b = SeqBuilder::new(model, batch, seq_q, ctx);

    // Embedding lookup.
    b.gather("aten::embedding", "embedding_dense", batch * seq_q, model.d_model);

    for layer in 0..model.layers {
        attention::lower_attention_block(&mut b, layer, kind, opts);
        if model.is_moe() {
            moe::lower_moe_ffn(&mut b, layer, kind, rng);
        } else {
            dense::lower_dense_ffn(&mut b, layer);
        }
        // Eager-mode glue: contiguity copies, mask/position index ops,
        // dtype casts (calibration constant; models::catalog).
        builder::lower_glue(&mut b, layer, model.glue_kernels_per_layer);
        b.mark(MarkKind::LayerEnd);
    }

    // Final norm + LM head + (decode) sampling ops.
    b.rmsnorm("final_norm");
    b.gemm(
        "aten::linear",
        "lm_head",
        batch * seq_q,
        model.vocab,
        model.d_model,
        1,
    );
    if kind == PassKind::DecodeStep {
        // Greedy sampling: softmax + argmax + token index ops.
        b.reduce("aten::softmax", "softmax_lastdim", batch * model.vocab);
        b.reduce("aten::argmax", "argmax_dim", batch * model.vocab);
        b.gather("aten::index_select", "token_select", batch, 1);
    }
    b.finish_marked()
}

/// Total kernels of an m-token decode run (pass-per-step; the sequence
/// is per-step shape-invariant for dense models — §V-C).
pub fn decode_run_kernels(
    model: &ModelSpec,
    batch: usize,
    prompt: usize,
    m_tokens: usize,
    opts: &LowerOpts,
    rng: &mut Rng,
) -> usize {
    (0..m_tokens)
        .map(|i| {
            lower_pass(
                model,
                PassKind::DecodeStep,
                batch,
                1,
                prompt + i + 1,
                opts,
                rng,
            )
            .len()
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn count(model: &ModelSpec, kind: PassKind, bs: usize, sq: usize, ctx: usize) -> usize {
        let mut rng = Rng::new(7);
        lower_pass(model, kind, bs, sq, ctx, &LowerOpts::default(), &mut rng).len()
    }

    #[test]
    fn dense_count_is_batch_invariant() {
        let m = models::llama_1b();
        assert_eq!(
            count(&m, PassKind::Prefill, 1, 512, 512),
            count(&m, PassKind::Prefill, 16, 512, 512)
        );
    }

    #[test]
    fn dense_count_is_seq_invariant() {
        // §V-C: "the dispatch count N per forward pass is approximately
        // shape-invariant" for a fixed dense architecture in eager mode.
        let m = models::llama_1b();
        assert_eq!(
            count(&m, PassKind::Prefill, 1, 512, 512),
            count(&m, PassKind::Prefill, 1, 8192, 8192)
        );
    }

    #[test]
    fn fused_attention_reduces_kernels() {
        let m = models::llama_1b();
        let mut rng = Rng::new(7);
        let eager = lower_pass(&m, PassKind::Prefill, 1, 512, 512, &LowerOpts::default(), &mut rng).len();
        let mut rng = Rng::new(7);
        let fused = lower_pass(
            &m,
            PassKind::Prefill,
            1,
            512,
            512,
            &LowerOpts {
                fused_attention: true,
            },
            &mut rng,
        )
        .len();
        assert!(fused < eager, "fused={fused} eager={eager}");
        // Fig. 9: ~7% fewer at BS=1/SL=512 (850 -> 791 = 59 fewer).
        let saved = eager - fused;
        assert!(saved >= 3 * m.layers && saved <= 6 * m.layers, "saved={saved}");
    }

    #[test]
    fn moe_dispatches_order_of_magnitude_more() {
        let dense = count(&models::llama_1b(), PassKind::DecodeStep, 4, 1, 2048);
        let moe = count(&models::olmoe(), PassKind::DecodeStep, 4, 1, 2048);
        assert!(
            moe > 8 * dense && moe < 14 * dense,
            "Table II: 8-11x — got {moe} vs {dense}"
        );
    }

    #[test]
    fn every_kernel_has_valid_meta() {
        let mut rng = Rng::new(3);
        let seq = lower_pass(
            &models::olmoe(),
            PassKind::Prefill,
            2,
            128,
            128,
            &LowerOpts::default(),
            &mut rng,
        );
        for k in &seq {
            assert!(!k.kernel_name.is_empty());
            assert!(!k.aten_op.is_empty());
            assert!(k.bytes >= 0.0 && k.flops >= 0.0);
            assert!(k.grid.iter().all(|&g| g >= 1));
            assert!(k.block.iter().all(|&b| b >= 1));
        }
    }

    #[test]
    fn decode_step_has_sampling_tail() {
        let mut rng = Rng::new(3);
        let seq = lower_pass(
            &models::gpt2(),
            PassKind::DecodeStep,
            1,
            1,
            64,
            &LowerOpts::default(),
            &mut rng,
        );
        let names: Vec<&str> = seq.iter().map(|k| k.aten_op.as_str()).collect();
        assert!(names.contains(&"aten::argmax"));
    }

    #[test]
    fn lowering_is_deterministic() {
        let m = models::qwen_moe();
        let a = {
            let mut rng = Rng::new(11);
            lower_pass(&m, PassKind::Prefill, 1, 256, 256, &LowerOpts::default(), &mut rng)
        };
        let b = {
            let mut rng = Rng::new(11);
            lower_pass(&m, PassKind::Prefill, 1, 256, 256, &LowerOpts::default(), &mut rng)
        };
        assert_eq!(a, b);
    }

    #[test]
    fn marked_lowering_is_the_same_sequence_with_boundaries() {
        let m = models::olmoe();
        let spec = m.moe.unwrap();
        let opts = LowerOpts::default();
        let plain = {
            let mut rng = Rng::new(21);
            lower_pass(&m, PassKind::DecodeStep, 1, 1, 128, &opts, &mut rng)
        };
        let (marked, marks) = {
            let mut rng = Rng::new(21);
            lower_pass_marked(&m, PassKind::DecodeStep, 1, 1, 128, &opts, &mut rng)
        };
        assert_eq!(plain, marked, "marks must not perturb the sequence");
        let layers = marks.iter().filter(|x| x.kind == MarkKind::LayerEnd).count();
        assert_eq!(layers, m.layers);
        let experts = marks
            .iter()
            .filter(|x| x.kind == MarkKind::ExpertChain)
            .count();
        assert_eq!(
            experts,
            m.layers * (spec.n_experts + spec.shared_experts),
            "every expert iteration is a shard boundary"
        );
        let combines = marks.iter().filter(|x| x.kind == MarkKind::Combine).count();
        assert_eq!(combines, m.layers);
        // Marks are sorted and in-range.
        for w in marks.windows(2) {
            assert!(w[0].index <= w[1].index);
        }
        assert!(marks.iter().all(|x| x.index <= marked.len()));
    }

    #[test]
    fn decode_run_scales_linearly() {
        let m = models::llama_1b();
        let opts = LowerOpts::default();
        let mut rng = Rng::new(1);
        let one = decode_run_kernels(&m, 1, 512, 1, &opts, &mut rng);
        let mut rng = Rng::new(1);
        let ten = decode_run_kernels(&m, 1, 512, 10, &opts, &mut rng);
        assert_eq!(ten, 10 * one);
    }
}

/// Fuse runs of consecutive elementwise kernels into single kernels —
/// what TorchInductor does for pointwise chains (and the paper's
/// "kernel fusion" prescription). Work (FLOPs/bytes) is conserved; the
/// kernel count drops by the run lengths.
pub fn fuse_elementwise(seq: Vec<KernelMeta>) -> Vec<KernelMeta> {
    let is_elem = |m: &KernelMeta| {
        matches!(
            m.family.as_str(),
            "elem_unroll" | "elem_vector" | "elem_generic"
        )
    };
    let mut out: Vec<KernelMeta> = Vec::with_capacity(seq.len());
    let mut run: Option<(KernelMeta, usize)> = None;
    for k in seq {
        if is_elem(&k) {
            match &mut run {
                Some((acc, n)) => {
                    acc.flops += k.flops;
                    acc.bytes += k.bytes;
                    *n += 1;
                }
                None => run = Some((k, 1)),
            }
        } else {
            if let Some((mut acc, n)) = run.take() {
                if n > 1 {
                    acc.kernel_name = format!("triton_fused_pointwise_{n}").into();
                    acc.aten_op = "inductor::fused".into();
                }
                out.push(acc);
            }
            out.push(k);
        }
    }
    if let Some((mut acc, n)) = run.take() {
        if n > 1 {
            acc.kernel_name = format!("triton_fused_pointwise_{n}").into();
            acc.aten_op = "inductor::fused".into();
        }
        out.push(acc);
    }
    out
}

#[cfg(test)]
mod fusion_tests {
    use super::*;
    use crate::models;

    #[test]
    fn fusion_conserves_work_and_reduces_count() {
        let m = models::llama_1b();
        let mut rng = Rng::new(2);
        let seq = lower_pass(&m, PassKind::Prefill, 1, 256, 256, &LowerOpts::default(), &mut rng);
        let flops: f64 = seq.iter().map(|k| k.flops).sum();
        let bytes: f64 = seq.iter().map(|k| k.bytes).sum();
        let fused = fuse_elementwise(seq.clone());
        assert!(fused.len() < seq.len());
        let f2: f64 = fused.iter().map(|k| k.flops).sum();
        let b2: f64 = fused.iter().map(|k| k.bytes).sum();
        assert!((f2 - flops).abs() < 1e-6 && (b2 - bytes).abs() < 1e-6);
        assert!(fused.iter().any(|k| k.kernel_name.starts_with("triton_fused")));
    }

    #[test]
    fn fusion_preserves_non_elementwise_order() {
        let m = models::gpt2();
        let mut rng = Rng::new(2);
        let seq = lower_pass(&m, PassKind::Prefill, 1, 64, 64, &LowerOpts::default(), &mut rng);
        let gemms_before: Vec<&str> = seq
            .iter()
            .filter(|k| k.family.starts_with("gemm"))
            .map(|k| k.kernel_name.as_str())
            .collect();
        let fused = fuse_elementwise(seq.clone());
        let gemms_after: Vec<&str> = fused
            .iter()
            .filter(|k| k.family.starts_with("gemm"))
            .map(|k| k.kernel_name.as_str())
            .collect();
        assert_eq!(gemms_before, gemms_after);
    }
}
