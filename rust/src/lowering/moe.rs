//! MoE FFN lowering: router + eager per-expert loop.
//!
//! Mirrors HF-style eager MoE implementations: the router computes
//! gate logits, softmax and top-k, then the layer **iterates over every
//! expert**, dispatching index bookkeeping (where / index_select) and
//! the expert GEMM chain per iteration.  This loop — not architectural
//! heterogeneity — is the structural source of the paper's Table II
//! fragmentation: kernel counts are nearly batch/context-invariant, and
//! unique names stay low relative to launches (low diversity ratio)
//! while per-expert token counts create autotune-style GEMM variants.

use crate::lowering::{MarkKind, PassKind, SeqBuilder};
use crate::models::MoeSpec;
use crate::util::rng::Rng;

/// Lower one MoE FFN block.
pub fn lower_moe_ffn(b: &mut SeqBuilder, layer: usize, kind: PassKind, rng: &mut Rng) {
    let m = b.model;
    let spec = *m.moe.as_ref().expect("lower_moe_ffn on dense model");
    let tokens = b.batch * b.seq_q;

    b.rmsnorm("ln_ffn");

    // --- Router block ------------------------------------------------
    b.gemm("aten::linear", "router_gate", tokens, spec.n_experts, m.d_model, 1);
    b.reduce("aten::softmax", "router_softmax", tokens * spec.n_experts);
    b.topk("aten::topk", tokens, spec.n_experts);
    // Remaining router bookkeeping up to the calibrated count.
    let extra = spec.router_kernels.saturating_sub(3);
    for i in 0..extra {
        match i % 5 {
            0 => b.elem("aten::one_hot", "router_one_hot", tokens * spec.n_experts),
            1 => b.scan("aten::cumsum", "router_cumsum", tokens * spec.top_k),
            2 => b.elem("aten::div", "router_norm_weights", tokens * spec.top_k),
            3 => b.gather("aten::argsort", "router_sort", tokens * spec.top_k, 1),
            _ => b.elem("aten::to", "router_cast", tokens * spec.n_experts),
        }
    }

    // --- Token-to-expert assignment ----------------------------------
    let counts = assign_tokens(tokens * spec.top_k, spec.n_experts, rng);

    // --- Per-expert loop (every expert iterates) ----------------------
    let k_per = match kind {
        PassKind::Prefill => spec.expert_kernels_prefill,
        PassKind::DecodeStep => spec.expert_kernels_decode,
    };
    for (e, &count) in counts.iter().enumerate() {
        b.mark(MarkKind::ExpertChain);
        lower_expert_chain(b, &spec, e, count.max(1), k_per);
    }
    // Shared experts process every token each pass (Qwen1.5-MoE) —
    // they are plain dense FFNs, so they always run the canonical
    // chain even when routed experts use the grouped fast path.
    for s in 0..spec.shared_experts {
        b.mark(MarkKind::ExpertChain);
        lower_expert_chain(b, &spec, spec.n_experts + s, tokens.max(1), k_per.max(8));
    }

    // --- Combine: weighted scatter-add + residual ---------------------
    b.mark(MarkKind::Combine);
    b.scatter("aten::index_add_", "expert_combine", tokens, m.d_model);
    b.elem("aten::add", "residual_ffn", tokens * m.d_model);
    let _ = layer;
}

/// One expert iteration of `k_per` kernels.
///
/// `k_per <= 4` models batched/grouped implementations (Qwen's fused
/// expert chunks): one grouped GEMM carries the full gate·up·down work.
/// Larger budgets use the canonical HF chain (2 index ops + 3 GEMMs +
/// 2 elementwise + combine) padded with capacity/bookkeeping ops.
fn lower_expert_chain(
    b: &mut SeqBuilder,
    spec: &MoeSpec,
    expert: usize,
    expert_tokens: usize,
    k_per: usize,
) {
    let d = b.model.d_model;
    let h = spec.expert_hidden;
    let t = expert_tokens;
    if k_per <= 4 {
        let v = expert % 24;
        b.gather("aten::index_select", "expert_dispatch", t, d);
        // Grouped GEMM: gate+up+down in one launch (3x the flops).
        b.gemm("aten::bmm", &format!("expert_grouped_v{v}"), t, h, 3 * d, 1);
        b.scatter("aten::index_add_", "expert_out", t, d);
        for i in 0..k_per.saturating_sub(3) {
            let _ = i;
            b.elem("aten::silu", "expert_act", t * h);
        }
        return;
    }

    // Canonical 8-kernel chain. Each expert's weight tensors are
    // distinct allocations, so cuBLAS heuristic/autotune selection is
    // per-expert — the variant suffix models the resulting symbol
    // spread (Table II: MoE has ~3x the unique names of dense while
    // its *diversity ratio* is far lower).
    let v = expert % 24;
    b.gather("aten::nonzero", "expert_mask_where", t, 1);
    b.gather("aten::index_select", "expert_dispatch", t, d);
    b.gemm("aten::linear", &format!("expert_gate_v{v}"), t, h, d, 1);
    b.gemm("aten::linear", &format!("expert_up_v{v}"), t, h, d, 1);
    b.elem("aten::silu", "expert_silu", t * h);
    b.elem("aten::mul", "expert_hadamard", t * h);
    b.gemm("aten::linear", &format!("expert_down_v{v}"), t, d, h, 1);
    b.scatter("aten::index_add_", "expert_out", t, d);

    // Capacity / bookkeeping padding beyond the core chain (prefill).
    for i in 0..k_per.saturating_sub(8) {
        match (expert + i) % 4 {
            0 => b.elem("aten::mul", "expert_weight_mul", t * d),
            1 => b.scan("aten::cumsum", "expert_capacity_cumsum", t),
            2 => b.elem("aten::to", "expert_cast", t * d),
            _ => b.memset(2 * t * d),
        }
    }
}

/// Distribute `assignments` token-slots over `n_experts` (binomial
/// normal approximation — exact multinomial sampling is unnecessary for
/// count calibration and would dominate lowering time at BS·SL·top_k
/// draws per layer).
fn assign_tokens(assignments: usize, n_experts: usize, rng: &mut Rng) -> Vec<usize> {
    let mean = assignments as f64 / n_experts as f64;
    let sd = mean.sqrt();
    (0..n_experts)
        .map(|_| (mean + sd * rng.std_normal()).round().max(0.0) as usize)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn every_expert_iterates_even_at_bs1_decode() {
        let m = models::olmoe();
        let spec = m.moe.unwrap();
        let mut b = SeqBuilder::new(&m, 1, 1, 512);
        let mut rng = Rng::new(1);
        lower_moe_ffn(&mut b, 0, PassKind::DecodeStep, &mut rng);
        let seq = b.finish();
        let dispatches = seq
            .iter()
            .filter(|k| k.kernel_name.contains("expert_dispatch"))
            .count();
        assert_eq!(dispatches, spec.n_experts);
    }

    #[test]
    fn moe_kernel_count_is_batch_invariant() {
        // §V-A: OLMoE decode latency (and kernel count) stays flat
        // across batch/context — the host-bound signature.
        let m = models::olmoe();
        let count = |bs: usize| {
            let mut b = SeqBuilder::new(&m, bs, 1, 2048);
            let mut rng = Rng::new(9);
            lower_moe_ffn(&mut b, 0, PassKind::DecodeStep, &mut rng);
            b.len()
        };
        assert_eq!(count(1), count(16));
    }

    #[test]
    fn shared_experts_add_kernels() {
        let q = models::qwen_moe();
        let spec = q.moe.unwrap();
        assert_eq!(spec.shared_experts, 4);
        let mut b = SeqBuilder::new(&q, 1, 8, 8);
        let mut rng = Rng::new(2);
        lower_moe_ffn(&mut b, 0, PassKind::DecodeStep, &mut rng);
        let seq = b.finish();
        let dispatches = seq
            .iter()
            .filter(|k| k.kernel_name.contains("expert_dispatch"))
            .count();
        assert_eq!(dispatches, spec.n_experts + spec.shared_experts);
    }

    #[test]
    fn assignment_conserves_mass_approximately() {
        let mut rng = Rng::new(5);
        let counts = assign_tokens(8 * 512, 64, &mut rng);
        assert_eq!(counts.len(), 64);
        let total: usize = counts.iter().sum();
        let expect = 8 * 512;
        assert!(
            (total as f64 / expect as f64 - 1.0).abs() < 0.15,
            "total={total}"
        );
    }

    #[test]
    fn expert_gemm_shapes_vary_with_assignment() {
        // Autotune-style variant names: different token counts produce
        // different GEMM symbols — the Table II unique-name mechanism.
        let m = models::olmoe();
        let mut b = SeqBuilder::new(&m, 4, 128, 128);
        let mut rng = Rng::new(3);
        lower_moe_ffn(&mut b, 0, PassKind::Prefill, &mut rng);
        let seq = b.finish();
        let mut gate_names: Vec<&str> = seq
            .iter()
            .filter(|k| k.kernel_name.contains("expert_gate"))
            .map(|k| k.kernel_name.as_str())
            .collect();
        gate_names.sort();
        gate_names.dedup();
        assert!(gate_names.len() > 5, "expected shape variety, got {}", gate_names.len());
    }

    #[test]
    fn prefill_chain_longer_than_decode() {
        let m = models::olmoe();
        let len_of = |kind| {
            let mut b = SeqBuilder::new(&m, 1, 32, 32);
            let mut rng = Rng::new(4);
            lower_moe_ffn(&mut b, 0, kind, &mut rng);
            b.len()
        };
        assert!(len_of(PassKind::Prefill) > len_of(PassKind::DecodeStep));
    }
}
