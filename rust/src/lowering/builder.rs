//! Kernel-sequence builder: op-level helpers that append fully-formed
//! [`KernelMeta`] records with analytic FLOPs/bytes and synthesized
//! kernel symbols.
//!
//! Kernel symbols encode the op and a shape signature, mimicking how
//! real profiles distinguish autotuned GEMM variants — this is what
//! drives the unique-name / diversity-ratio statistics of Table II.

use crate::kernels::family::Family;
use crate::models::{GemmLib, ModelSpec};
use crate::trace::KernelMeta;
use crate::util::intern::Sym;

/// Elements per thread-block used to synthesize launch configs.
const BLOCK_THREADS: u32 = 256;
/// BF16 element size.
const EB: f64 = 2.0;

/// Structural annotation on a lowered kernel sequence: records where a
/// dependency-relevant boundary sits *without* perturbing the sequence
/// itself (same kernels, same RNG draws). The parallel-execution
/// scenarios (`sim::parallel`) consume marks to place per-layer
/// all-reduce sync points (tensor parallelism) and to shard expert
/// chains across streams (expert parallelism); plain `lower_pass`
/// callers never see them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MarkKind {
    /// Boundary after one transformer layer (kernels `< index` include
    /// the whole layer) — tensor-parallel all-reduce point.
    LayerEnd,
    /// The kernel at `index` starts one expert's chain (routed or
    /// shared) — expert-parallel shard boundary.
    ExpertChain,
    /// The kernel at `index` is the MoE combine (scatter-add joining
    /// every expert stream).
    Combine,
}

/// One mark: `kind` anchored before the kernel at `index` (or, for
/// [`MarkKind::LayerEnd`], after the kernel at `index - 1`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mark {
    pub index: usize,
    pub kind: MarkKind,
}

pub struct SeqBuilder<'m> {
    pub model: &'m ModelSpec,
    pub batch: usize,
    pub seq_q: usize,
    pub ctx: usize,
    out: Vec<KernelMeta>,
    marks: Vec<Mark>,
    /// Symbol/shape-key cache: kernel names repeat heavily (layers ×
    /// experts × steps), and `format!` per invocation dominated the
    /// lowering profile (§Perf L3.2). Keyed by FNV of the inputs; the
    /// values are interned [`Sym`]s, so a cache hit is a `Copy`, not a
    /// `String` clone.
    name_cache: std::collections::HashMap<u64, Sym>,
}

impl<'m> SeqBuilder<'m> {
    pub fn new(model: &'m ModelSpec, batch: usize, seq_q: usize, ctx: usize) -> SeqBuilder<'m> {
        SeqBuilder {
            model,
            batch,
            seq_q,
            ctx,
            out: Vec::with_capacity(1024),
            marks: Vec::new(),
            name_cache: std::collections::HashMap::with_capacity(256),
        }
    }

    /// Record a structural mark at the current sequence position.
    pub fn mark(&mut self, kind: MarkKind) {
        self.marks.push(Mark {
            index: self.out.len(),
            kind,
        });
    }

    /// Memoized symbol build: renders (and interns) once per distinct
    /// key, then hands out the `Copy` symbol.
    fn cached(&mut self, key_parts: (&str, &str, usize), build: impl FnOnce() -> String) -> Sym {
        let mut h = crate::util::rng::fnv1a(key_parts.0.as_bytes());
        h ^= crate::util::rng::fnv1a(key_parts.1.as_bytes()).rotate_left(17);
        h ^= (key_parts.2 as u64).wrapping_mul(0x9E3779B97F4A7C15);
        *self
            .name_cache
            .entry(h)
            .or_insert_with(|| Sym::from_owned(build()))
    }

    pub fn len(&self) -> usize {
        self.out.len()
    }

    pub fn finish(self) -> Vec<KernelMeta> {
        self.out
    }

    /// Finish, keeping the structural marks alongside the sequence.
    pub fn finish_marked(self) -> (Vec<KernelMeta>, Vec<Mark>) {
        (self.out, self.marks)
    }

    fn grid_for(&self, elements: usize) -> [u32; 3] {
        let blocks = (elements as u32).div_ceil(BLOCK_THREADS).max(1);
        [blocks, 1, 1]
    }

    fn push(
        &mut self,
        family: Family,
        aten_op: &str,
        kernel_name: Sym,
        shapes_key: Sym,
        grid: [u32; 3],
        flops: f64,
        bytes: f64,
    ) {
        self.out.push(KernelMeta {
            kernel_name,
            family: family.tag().into(),
            aten_op: aten_op.into(),
            shapes_key,
            grid,
            block: [BLOCK_THREADS, 1, 1],
            lib_mediated: family.params().lib_mediated,
            flops,
            bytes,
        });
    }

    /// Elementwise op on `elements` scalars. The family (and hence the
    /// kernel symbol) depends on size — vectorized for large aligned
    /// tensors, unrolled for small ones, generic otherwise — matching
    /// the family split real ATen kernels exhibit (Table IV rows).
    pub fn elem(&mut self, aten_op: &str, tag: &str, elements: usize) {
        let family = if elements >= 4096 && elements % 4 == 0 {
            Family::ElemVector
        } else if elements < 1024 {
            Family::ElemUnroll
        } else {
            Family::ElemGeneric
        };
        let sym = self.cached(("elem", tag, family as usize), || match family {
            Family::ElemVector => format!("vectorized_elementwise_kernel<4, {tag}>"),
            Family::ElemUnroll => format!("unrolled_elementwise_kernel<{tag}>"),
            _ => format!("elementwise_kernel<128, 2, {tag}>"),
        });
        let shapes = self.cached(("elem-shape", "", elements), || format!("bf16[{elements}]"));
        self.push(
            family,
            aten_op,
            sym,
            shapes,
            self.grid_for(elements),
            elements as f64,
            3.0 * EB * elements as f64,
        );
    }

    /// Reduction over `elements` (mean/max/softmax/norm inner loops).
    pub fn reduce(&mut self, aten_op: &str, tag: &str, elements: usize) {
        let sym = self.cached(("reduce", tag, 0), || format!("reduce_kernel<512, {tag}>"));
        let shapes = self.cached(("elem-shape", "", elements), || format!("bf16[{elements}]"));
        self.push(
            Family::Reduce,
            aten_op,
            sym,
            shapes,
            self.grid_for(elements),
            elements as f64,
            EB * elements as f64,
        );
    }

    /// Prefix-scan (cumsum — MoE routing bookkeeping).
    pub fn scan(&mut self, aten_op: &str, tag: &str, elements: usize) {
        let sym = self.cached(("scan", tag, 0), || format!("scan_kernel<{tag}>"));
        let shapes = self.cached(("scan-shape", "", elements), || format!("i32[{elements}]"));
        self.push(
            Family::Scan,
            aten_op,
            sym,
            shapes,
            self.grid_for(elements),
            elements as f64,
            2.0 * 4.0 * elements as f64,
        );
    }

    /// Gather / index_select of `rows` rows of width `width`.
    pub fn gather(&mut self, aten_op: &str, tag: &str, rows: usize, width: usize) {
        let elements = rows * width;
        let sym = self.cached(("gather", tag, 0), || format!("index_elementwise_kernel<{tag}>"));
        let shapes = self.cached(("rw-shape", "", (rows << 20) ^ width), || {
            format!("bf16[{rows},{width}]")
        });
        self.push(
            Family::Gather,
            aten_op,
            sym,
            shapes,
            self.grid_for(elements),
            0.0,
            2.0 * EB * elements as f64,
        );
    }

    /// Scatter / index_add (MoE combine).
    pub fn scatter(&mut self, aten_op: &str, tag: &str, rows: usize, width: usize) {
        let elements = rows * width;
        let sym = self.cached(("scatter", tag, 0), || format!("index_put_kernel<{tag}>"));
        let shapes = self.cached(("rw-shape", "", (rows << 20) ^ width), || {
            format!("bf16[{rows},{width}]")
        });
        self.push(
            Family::Scatter,
            aten_op,
            sym,
            shapes,
            self.grid_for(elements),
            0.0,
            3.0 * EB * elements as f64,
        );
    }

    /// top-k over `rows` rows of `cols` (router).
    pub fn topk(&mut self, aten_op: &str, rows: usize, cols: usize) {
        let elements = rows * cols;
        let sym = self.cached(("topk", "", cols), || format!("radix_topk_kernel<{cols}>"));
        let shapes = self.cached(("topk-shape", "", (rows << 20) ^ cols), || {
            format!("f32[{rows},{cols}]")
        });
        self.push(
            Family::TopK,
            aten_op,
            sym,
            shapes,
            self.grid_for(elements),
            elements as f64,
            2.0 * 4.0 * elements as f64,
        );
    }

    /// cudaMemsetAsync of `bytes`.
    pub fn memset(&mut self, bytes: usize) {
        let shapes = self.cached(("memset-shape", "", bytes), || format!("u8[{bytes}]"));
        self.push(
            Family::Memset,
            "cudaMemsetAsync",
            "memset_kernel".into(),
            shapes,
            self.grid_for(bytes / 16),
            0.0,
            bytes as f64,
        );
    }

    /// Batched GEMM: `bcount` × (m × n × k). Library routing (and so
    /// `I_lib`) follows the model's GEMM path; the symbol carries the
    /// shape signature like autotuned cuBLAS/nvjet variant names do.
    pub fn gemm(&mut self, aten_op: &str, tag: &str, m: usize, n: usize, k: usize, bcount: usize) {
        let shape_hash = (m << 42) ^ (n << 21) ^ k;
        let family = match self.model.gemm_lib {
            GemmLib::Cublas => Family::GemmCublas,
            GemmLib::Nvjet => Family::GemmNvjet,
        };
        // Autotuned variant *names* are tile-quantized: nearby m values
        // select the same kernel (cuBLAS tiles, not exact shapes), so
        // the symbol uses the next power of two of m while FLOPs/bytes
        // stay exact — keeps Table II unique-name counts realistic.
        let mq = m.next_power_of_two();
        let name_hash = (mq << 42) ^ (n << 21) ^ k;
        let sym = self.cached(("gemm", tag, name_hash), || match family {
            Family::GemmCublas => format!("ampere_bf16_s16816gemm_{tag}_{mq}x{n}x{k}_tn"),
            _ => format!("nvjet_tst_{tag}_{mq}x{n}x{k}"),
        });
        let flops = 2.0 * bcount as f64 * m as f64 * n as f64 * k as f64;
        let bytes = EB * bcount as f64 * (m * k + k * n + m * n) as f64;
        let grid = [
            (m as u32).div_ceil(128).max(1),
            (n as u32).div_ceil(128).max(1),
            bcount as u32,
        ];
        let shapes = self.cached(("gemm-shape", "", shape_hash ^ (bcount << 10)), || {
            format!("bf16[{bcount},{m},{k}]x[{k},{n}]")
        });
        self.push(family, aten_op, sym, shapes, grid, flops, bytes);
    }

    /// The fused FlashAttention-2-style kernel: both matmuls + online
    /// softmax in one launch; HBM traffic excludes the S×S matrix.
    pub fn fused_attention(&mut self, heads: usize, head_dim: usize) {
        let (b, sq, ctx) = (self.batch, self.seq_q, self.ctx);
        let flops = 4.0 * (b * heads * sq * ctx * head_dim) as f64;
        let bytes = EB * (b * heads) as f64 * (2.0 * (sq * head_dim) as f64
            + 2.0 * (ctx * head_dim) as f64);
        let sym = self.cached(("fa", "", head_dim), || {
            format!("flash_fwd_kernel_hdim{head_dim}")
        });
        let shapes = self.cached(("fa-shape", "", (heads << 20) ^ head_dim), || {
            format!("bf16[{b},{heads},{sq},{head_dim}]x[{ctx}]")
        });
        self.push(
            Family::FusedAttention,
            "flash::attention_fwd",
            sym,
            shapes,
            [(b * heads) as u32, (sq as u32).div_ceil(128).max(1), 1],
            flops,
            bytes,
        );
    }

    /// RMSNorm as its eager 4-kernel chain (pow, mean, rsqrt·mul, gain).
    pub fn rmsnorm(&mut self, tag: &str) {
        let t = self.batch * self.seq_q * self.model.d_model;
        self.elem("aten::pow", &format!("{tag}_pow2"), t);
        self.reduce("aten::mean", &format!("{tag}_mean"), t);
        self.elem("aten::rsqrt", &format!("{tag}_rsqrt_mul"), t);
        self.elem("aten::mul", &format!("{tag}_gain"), t);
    }

    /// LayerNorm (GPT-2 path): fused reduce + affine pair.
    pub fn layernorm(&mut self, tag: &str) {
        let t = self.batch * self.seq_q * self.model.d_model;
        self.reduce("aten::native_layer_norm", &format!("{tag}_stats"), t);
        self.elem("aten::native_layer_norm", &format!("{tag}_affine"), t);
    }
}

/// Per-layer eager glue: contiguity copies, dtype casts, mask/position
/// index ops. Count is the model's calibration constant; a 4-op rotation
/// keeps symbols realistic without inflating unique-name counts.
pub fn lower_glue(b: &mut SeqBuilder, layer: usize, count: usize) {
    let t = (b.batch * b.seq_q * b.model.d_model / 4).max(64);
    for i in 0..count {
        match (layer + i) % 4 {
            0 => b.elem("aten::copy_", "copy_contiguous", t),
            1 => b.elem("aten::to", "cast_bf16", t),
            2 => b.elem("aten::slice", "slice_copy", t / 2),
            _ => b.gather("aten::index", "pos_index", b.batch * b.seq_q, 8),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn elem_family_by_size() {
        let m = models::gpt2();
        let mut b = SeqBuilder::new(&m, 1, 1, 1);
        b.elem("aten::mul", "t", 8192); // vector
        b.elem("aten::mul", "t", 100); // unroll
        b.elem("aten::mul", "t", 2000); // generic
        let seq = b.finish();
        assert_eq!(seq[0].family, "elem_vector");
        assert_eq!(seq[1].family, "elem_unroll");
        assert_eq!(seq[2].family, "elem_generic");
    }

    #[test]
    fn gemm_lib_follows_model() {
        let g = models::gpt2();
        let mut b = SeqBuilder::new(&g, 1, 8, 8);
        b.gemm("aten::mm", "qkv", 8, 2304, 768, 1);
        let seq = b.finish();
        assert_eq!(seq[0].family, "gemm_nvjet");
        assert!(!seq[0].lib_mediated);

        let l = models::llama_1b();
        let mut b = SeqBuilder::new(&l, 1, 8, 8);
        b.gemm("aten::mm", "q", 8, 2048, 2048, 1);
        let seq = b.finish();
        assert_eq!(seq[0].family, "gemm_cublas");
        assert!(seq[0].lib_mediated);
    }

    #[test]
    fn gemm_flops_bytes() {
        let l = models::llama_1b();
        let mut b = SeqBuilder::new(&l, 1, 4, 4);
        b.gemm("aten::mm", "x", 4, 8, 16, 2);
        let k = &b.finish()[0];
        assert_eq!(k.flops, 2.0 * 2.0 * 4.0 * 8.0 * 16.0);
        assert_eq!(k.bytes, 2.0 * 2.0 * (4 * 16 + 16 * 8 + 4 * 8) as f64);
    }

    #[test]
    fn fused_attention_traffic_excludes_score_matrix() {
        let l = models::llama_1b();
        let mut b = SeqBuilder::new(&l, 1, 2048, 2048);
        b.fused_attention(32, 64);
        let k = &b.finish()[0];
        // Bytes must be linear in S, far below the S^2 score matrix.
        let s2 = 2.0 * (1 * 32 * 2048 * 2048) as f64;
        assert!(k.bytes < s2 / 4.0, "bytes={} s2={}", k.bytes, s2);
        assert_eq!(k.family, "fused_attention");
    }

    #[test]
    fn rmsnorm_is_four_kernels() {
        let l = models::llama_1b();
        let mut b = SeqBuilder::new(&l, 1, 16, 16);
        b.rmsnorm("ln1");
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn glue_count_matches() {
        let l = models::llama_1b();
        let mut b = SeqBuilder::new(&l, 1, 16, 16);
        lower_glue(&mut b, 0, 9);
        assert_eq!(b.len(), 9);
    }

    #[test]
    fn marks_record_positions_without_touching_the_sequence() {
        let m = models::gpt2();
        let mut a = SeqBuilder::new(&m, 1, 8, 8);
        a.elem("aten::mul", "x", 100);
        a.mark(MarkKind::LayerEnd);
        a.elem("aten::mul", "y", 100);
        a.mark(MarkKind::Combine);
        let (seq, marks) = a.finish_marked();
        assert_eq!(seq.len(), 2);
        assert_eq!(
            marks,
            vec![
                Mark { index: 1, kind: MarkKind::LayerEnd },
                Mark { index: 2, kind: MarkKind::Combine },
            ]
        );

        // The marked and unmarked builds emit identical kernels.
        let mut b = SeqBuilder::new(&m, 1, 8, 8);
        b.elem("aten::mul", "x", 100);
        b.elem("aten::mul", "y", 100);
        assert_eq!(b.finish(), seq);
    }

    #[test]
    fn shapes_key_distinguishes_sizes() {
        let l = models::llama_1b();
        let mut b = SeqBuilder::new(&l, 1, 4, 4);
        b.gemm("aten::mm", "x", 4, 8, 16, 1);
        b.gemm("aten::mm", "x", 4, 8, 32, 1);
        let seq = b.finish();
        assert_ne!(seq[0].shapes_key, seq[1].shapes_key);
        assert_ne!(seq[0].kernel_name, seq[1].kernel_name);
    }
}
