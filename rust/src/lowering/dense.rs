//! Dense FFN lowering (SwiGLU for Llama-family, GELU MLP for GPT-2).

use crate::lowering::SeqBuilder;
use crate::models::GemmLib;

/// Lower a dense FFN block: pre-norm, up/gate projections, activation,
/// down projection, residual.
pub fn lower_dense_ffn(b: &mut SeqBuilder, layer: usize) {
    let m = b.model;
    let tokens = b.batch * b.seq_q;
    match m.gemm_lib {
        GemmLib::Cublas => {
            // Llama-family SwiGLU: gate & up GEMMs, SiLU, hadamard, down.
            b.rmsnorm("ln_ffn");
            b.gemm("aten::linear", "ffn_gate", tokens, m.ffn_hidden, m.d_model, 1);
            b.gemm("aten::linear", "ffn_up", tokens, m.ffn_hidden, m.d_model, 1);
            b.elem("aten::silu", "silu", tokens * m.ffn_hidden);
            b.elem("aten::mul", "ffn_hadamard", tokens * m.ffn_hidden);
            b.gemm("aten::linear", "ffn_down", tokens, m.d_model, m.ffn_hidden, 1);
        }
        GemmLib::Nvjet => {
            // GPT-2 MLP: two GEMMs around a GELU.
            b.layernorm("ln_ffn");
            b.gemm("aten::addmm", "mlp_fc", tokens, m.ffn_hidden, m.d_model, 1);
            b.elem("aten::gelu", "gelu", tokens * m.ffn_hidden);
            b.gemm("aten::addmm", "mlp_proj", tokens, m.d_model, m.ffn_hidden, 1);
        }
    }
    b.elem("aten::add", "residual_ffn", tokens * m.d_model);
    let _ = layer;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn swiglu_has_three_gemms() {
        let m = models::llama_1b();
        let mut b = SeqBuilder::new(&m, 1, 16, 16);
        lower_dense_ffn(&mut b, 0);
        let gemms = b
            .finish()
            .iter()
            .filter(|k| k.family.starts_with("gemm"))
            .count();
        assert_eq!(gemms, 3);
    }

    #[test]
    fn gpt2_mlp_has_two_gemms() {
        let m = models::gpt2();
        let mut b = SeqBuilder::new(&m, 1, 16, 16);
        lower_dense_ffn(&mut b, 0);
        let seq = b.finish();
        let gemms = seq.iter().filter(|k| k.family.starts_with("gemm")).count();
        assert_eq!(gemms, 2);
        assert!(seq.iter().any(|k| k.kernel_name.contains("gelu")));
    }

    #[test]
    fn ffn_flops_dominated_by_gemms() {
        let m = models::llama_1b();
        let mut b = SeqBuilder::new(&m, 1, 512, 512);
        lower_dense_ffn(&mut b, 0);
        let seq = b.finish();
        let gemm_flops: f64 = seq
            .iter()
            .filter(|k| k.family.starts_with("gemm"))
            .map(|k| k.flops)
            .sum();
        let total: f64 = seq.iter().map(|k| k.flops).sum();
        assert!(gemm_flops / total > 0.99);
    }
}
