//! Kernel taxonomy, database and device cost model.
//!
//! * [`family`] — the paper's kernel-family taxonomy (§III-A + Table IV)
//!   with per-family host-path latency parameters.
//! * [`cost`] — analytic device-duration model (roofline GEMM +
//!   bandwidth-bound families).
//! * [`database`] — the Phase-1 kernel database: unique kernels keyed on
//!   ATen metadata + launch config, with invocation counts.

pub mod cost;
pub mod database;
pub mod family;

pub use database::KernelDb;
pub use family::{Family, FamilyParams};
