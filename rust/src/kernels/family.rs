//! Kernel-family taxonomy (paper §III-A, Table IV).
//!
//! The `I_lib` indicator gates the ΔCT term: only **library-mediated**
//! kernels (cuBLAS/cuDNN) traverse a vendor front-end (heuristic
//! selection, descriptor setup, packing); **framework-native** kernels
//! (ATen/Inductor elementwise, reductions, data movement) go straight
//! from the dispatcher to the launch API.
//!
//! Per-family latency parameters are the H100 reference values from the
//! paper (Table IV ΔKT_fw medians; DESIGN.md §7); host-side components
//! divide by the platform's CPU single-thread speed.

/// Kernel families. The first seven rows mirror Table IV; the rest
/// cover data movement and MoE routing ops observed in the workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Family {
    /// Prefix-scan kernels (cumsum etc.).
    Scan,
    /// Unrolled elementwise.
    ElemUnroll,
    /// Vectorized elementwise.
    ElemVector,
    /// Generic (catch-all) elementwise, copies, casts.
    ElemGeneric,
    /// Reductions (mean, max, norm, softmax inner).
    Reduce,
    /// GEMMs emitted framework-natively (nvjet/gemv2T — GPT-2's path,
    /// `I_lib = 0`, so ΔCT is gated to zero; paper §V-C).
    GemmNvjet,
    /// GEMMs routed through cuBLAS/cuBLASLt (`I_lib = 1`).
    GemmCublas,
    /// Async H2D/D2D copies (cudaMemcpyAsync).
    Memcpy,
    /// cudaMemset.
    Memset,
    /// Index/gather kernels (MoE token dispatch, embedding lookup).
    Gather,
    /// Scatter/index_add kernels (MoE combine).
    Scatter,
    /// top-k / sort kernels (MoE routing).
    TopK,
    /// Fused attention megakernel (FlashAttention-2 analog; Fig. 9).
    FusedAttention,
}

/// Host-path latency parameters for one family (H100-host reference).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FamilyParams {
    /// Median Python-side dispatch overhead T_Py, us.
    pub py_med_us: f64,
    /// Median vendor-library front-end excess ΔCT, us (0 when !lib).
    pub ct_med_us: f64,
    /// Median framework launch excess above the floor, ΔKT_fw, us
    /// (Table IV column 3).
    pub launch_excess_med_us: f64,
    /// Lognormal shape of the launch excess (fatter for autotuned GEMM
    /// families — Table IV's nvjet p95 long tail).
    pub launch_excess_sigma: f64,
    /// `I_lib`.
    pub lib_mediated: bool,
    /// Device-side compute efficiency (fraction of peak MXU/FMA
    /// throughput reachable) — 0 for flops-free families.
    pub compute_eff: f64,
    /// Device-side memory-bandwidth efficiency.
    pub mem_eff: f64,
}

/// Irreducible ATen dispatch cost median (T_dispatch_base), us, on the
/// H100 reference host.  Calibrated from the paper's GPT-2/H200 stack
/// decomposition (DESIGN.md §7: 7.8 us on H200 × 1.30 CPU ratio).
pub const DISPATCH_BASE_MED_US: f64 = 10.2;
/// Lognormal sigma of the ATen dispatch cost.
pub const DISPATCH_SIGMA: f64 = 0.10;
/// Lognormal sigma of T_Py.
pub const PY_SIGMA: f64 = 0.18;
/// Lognormal sigma of ΔCT.
pub const CT_SIGMA: f64 = 0.15;

impl Family {
    pub const ALL: [Family; 13] = [
        Family::Scan,
        Family::ElemUnroll,
        Family::ElemVector,
        Family::ElemGeneric,
        Family::Reduce,
        Family::GemmNvjet,
        Family::GemmCublas,
        Family::Memcpy,
        Family::Memset,
        Family::Gather,
        Family::Scatter,
        Family::TopK,
        Family::FusedAttention,
    ];

    /// Stable machine tag (stored in traces).
    pub fn tag(&self) -> &'static str {
        match self {
            Family::Scan => "scan",
            Family::ElemUnroll => "elem_unroll",
            Family::ElemVector => "elem_vector",
            Family::ElemGeneric => "elem_generic",
            Family::Reduce => "reduce",
            Family::GemmNvjet => "gemm_nvjet",
            Family::GemmCublas => "gemm_cublas",
            Family::Memcpy => "memcpy",
            Family::Memset => "memset",
            Family::Gather => "gather",
            Family::Scatter => "scatter",
            Family::TopK => "topk",
            Family::FusedAttention => "fused_attention",
        }
    }

    /// Human label matching the paper's Table IV rows.
    pub fn label(&self) -> &'static str {
        match self {
            Family::Scan => "Scan (prefix)",
            Family::ElemUnroll => "Elem. (unroll)",
            Family::ElemVector => "Elem. (vector)",
            Family::ElemGeneric => "Elem. (generic)",
            Family::Reduce => "Reduce",
            Family::GemmNvjet => "GEMM (nvjet)",
            Family::GemmCublas => "GEMM (cuBLAS)",
            Family::Memcpy => "MemcpyAsync",
            Family::Memset => "Memset",
            Family::Gather => "Gather/Index",
            Family::Scatter => "Scatter/IndexAdd",
            Family::TopK => "TopK/Sort",
            Family::FusedAttention => "Fused attention",
        }
    }

    pub fn from_tag(tag: &str) -> anyhow::Result<Family> {
        Family::ALL
            .iter()
            .copied()
            .find(|f| f.tag() == tag)
            .ok_or_else(|| anyhow::anyhow!("unknown kernel family '{tag}'"))
    }

    /// Latency + efficiency parameters (H100-host reference values).
    pub fn params(&self) -> FamilyParams {
        // Table IV ΔKT_fw medians (Llama-3.2-3B column; the OLMoE
        // column differs by <0.3 us and is covered by the sigma).
        match self {
            Family::Scan => FamilyParams {
                py_med_us: 1.5,
                ct_med_us: 0.0,
                launch_excess_med_us: 0.32,
                launch_excess_sigma: 0.10,
                lib_mediated: false,
                compute_eff: 0.0,
                mem_eff: 0.45,
            },
            Family::ElemUnroll => FamilyParams {
                py_med_us: 1.4,
                ct_med_us: 0.0,
                launch_excess_med_us: 0.36,
                launch_excess_sigma: 0.08,
                lib_mediated: false,
                compute_eff: 0.0,
                mem_eff: 0.60,
            },
            Family::ElemVector => FamilyParams {
                py_med_us: 1.4,
                ct_med_us: 0.0,
                launch_excess_med_us: 0.38,
                launch_excess_sigma: 0.12,
                lib_mediated: false,
                compute_eff: 0.0,
                mem_eff: 0.65,
            },
            Family::ElemGeneric => FamilyParams {
                py_med_us: 1.8,
                ct_med_us: 0.0,
                launch_excess_med_us: 0.56,
                launch_excess_sigma: 0.10,
                lib_mediated: false,
                compute_eff: 0.0,
                mem_eff: 0.50,
            },
            Family::Reduce => FamilyParams {
                py_med_us: 1.6,
                ct_med_us: 0.0,
                launch_excess_med_us: 0.55,
                launch_excess_sigma: 0.10,
                lib_mediated: false,
                compute_eff: 0.0,
                mem_eff: 0.50,
            },
            Family::GemmNvjet => FamilyParams {
                py_med_us: 1.7,
                ct_med_us: 0.0,
                launch_excess_med_us: 1.18,
                // nvjet shows a long p95 tail (Table IV: 18.58 us p95
                // vs 5.93 p50 — "long-tail launch anomaly").
                launch_excess_sigma: 0.55,
                lib_mediated: false,
                compute_eff: 0.50,
                mem_eff: 0.70,
            },
            Family::GemmCublas => FamilyParams {
                py_med_us: 1.7,
                // cuBLAS front-end: heuristic selection + descriptor
                // setup + packing (§III-A).
                ct_med_us: 3.0,
                launch_excess_med_us: 1.88,
                launch_excess_sigma: 0.12,
                lib_mediated: true,
                compute_eff: 0.60,
                mem_eff: 0.70,
            },
            Family::Memcpy => FamilyParams {
                py_med_us: 1.2,
                ct_med_us: 0.0,
                launch_excess_med_us: 0.40,
                launch_excess_sigma: 0.10,
                lib_mediated: false,
                compute_eff: 0.0,
                mem_eff: 0.80,
            },
            Family::Memset => FamilyParams {
                py_med_us: 1.0,
                ct_med_us: 0.0,
                launch_excess_med_us: 0.30,
                launch_excess_sigma: 0.10,
                lib_mediated: false,
                compute_eff: 0.0,
                mem_eff: 0.80,
            },
            Family::Gather => FamilyParams {
                // MoE dispatch index ops carry heavy Python-side
                // bookkeeping (nonzero/where/masking) — the mechanism
                // behind MoE's elevated per-kernel host cost (§V-C).
                py_med_us: 4.2,
                ct_med_us: 0.0,
                launch_excess_med_us: 0.50,
                launch_excess_sigma: 0.12,
                lib_mediated: false,
                compute_eff: 0.0,
                mem_eff: 0.35,
            },
            Family::Scatter => FamilyParams {
                py_med_us: 4.2,
                ct_med_us: 0.0,
                launch_excess_med_us: 0.52,
                launch_excess_sigma: 0.12,
                lib_mediated: false,
                compute_eff: 0.0,
                mem_eff: 0.35,
            },
            Family::TopK => FamilyParams {
                py_med_us: 2.5,
                ct_med_us: 0.0,
                launch_excess_med_us: 0.60,
                launch_excess_sigma: 0.15,
                lib_mediated: false,
                compute_eff: 0.0,
                mem_eff: 0.30,
            },
            Family::FusedAttention => FamilyParams {
                py_med_us: 1.9,
                ct_med_us: 0.0,
                launch_excess_med_us: 0.90,
                launch_excess_sigma: 0.20,
                lib_mediated: false,
                compute_eff: 0.55,
                mem_eff: 0.75,
            },
        }
    }

    /// Families reported in the paper's Table IV, in its row order.
    pub fn table4_rows() -> Vec<Family> {
        vec![
            Family::Scan,
            Family::ElemUnroll,
            Family::ElemVector,
            Family::Reduce,
            Family::ElemGeneric,
            Family::GemmNvjet,
            Family::GemmCublas,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_roundtrip() {
        for f in Family::ALL {
            assert_eq!(Family::from_tag(f.tag()).unwrap(), f);
        }
        assert!(Family::from_tag("warp_specialized").is_err());
    }

    #[test]
    fn only_cublas_is_lib_mediated() {
        for f in Family::ALL {
            assert_eq!(
                f.params().lib_mediated,
                f == Family::GemmCublas,
                "{f:?}"
            );
        }
    }

    #[test]
    fn ct_zero_unless_lib() {
        for f in Family::ALL {
            let p = f.params();
            if !p.lib_mediated {
                assert_eq!(p.ct_med_us, 0.0, "{f:?}");
            } else {
                assert!(p.ct_med_us > 0.0);
            }
        }
    }

    #[test]
    fn table4_excess_ordering() {
        // Paper: GEMM families show the highest ΔKT_fw; cuBLAS > nvjet
        // > elementwise/reduce/scan.
        let e = |f: Family| f.params().launch_excess_med_us;
        assert!(e(Family::GemmCublas) > e(Family::GemmNvjet));
        assert!(e(Family::GemmNvjet) > e(Family::ElemGeneric));
        assert!(e(Family::Scan) < e(Family::Reduce));
        for f in [Family::Scan, Family::ElemUnroll, Family::ElemVector, Family::Reduce] {
            assert!(e(f) < 0.6, "{f:?} should launch near the floor");
        }
    }

    #[test]
    fn table4_values_match_paper() {
        assert!((Family::Scan.params().launch_excess_med_us - 0.32).abs() < 1e-9);
        assert!((Family::GemmCublas.params().launch_excess_med_us - 1.88).abs() < 1e-9);
        assert!((Family::GemmNvjet.params().launch_excess_med_us - 1.18).abs() < 1e-9);
    }

    #[test]
    fn moe_routing_ops_have_heavier_python_side() {
        assert!(Family::Gather.params().py_med_us > 2.0 * Family::ElemVector.params().py_med_us);
    }

    #[test]
    fn gemm_families_have_compute_eff() {
        for f in Family::ALL {
            let p = f.params();
            match f {
                Family::GemmNvjet | Family::GemmCublas | Family::FusedAttention => {
                    assert!(p.compute_eff > 0.0)
                }
                _ => assert_eq!(p.compute_eff, 0.0),
            }
        }
    }

    #[test]
    fn table4_rows_are_seven() {
        assert_eq!(Family::table4_rows().len(), 7);
    }
}
