//! Analytic device-duration model.
//!
//! Each kernel's GPU execution time is the roofline maximum of its
//! compute time (FLOPs over family-efficiency-scaled peak) and its
//! memory time (bytes over bandwidth), plus a fixed ramp/tail, with a
//! size-dependent efficiency ramp so tiny kernels (MoE expert GEMMs,
//! decode GEMVs) cannot reach peak — the device-side half of the MoE
//! fragmentation story (§V-B).

use crate::hardware::GpuSpec;
use crate::kernels::family::Family;
use crate::util::rng::Rng;

/// Fixed per-kernel ramp/drain overhead on the device, us.
pub const KERNEL_TAIL_US: f64 = 0.8;
/// Minimum kernel duration, us (nothing completes faster on Hopper).
pub const MIN_KERNEL_US: f64 = 1.0;
/// FLOPs at which a compute kernel reaches half its family efficiency.
const COMPUTE_RAMP_FLOPS: f64 = 2.0e8;
/// Bytes at which a memory-bound kernel reaches half its bandwidth
/// efficiency.
const MEM_RAMP_BYTES: f64 = 1.5e6;
/// Multiplicative lognormal jitter sigma on device durations.
const DEVICE_JITTER_SIGMA: f64 = 0.03;

/// Deterministic (jitter-free) device duration in us.
pub fn device_duration_us(family: Family, flops: f64, bytes: f64, gpu: &GpuSpec) -> f64 {
    let p = family.params();
    let mut dur = KERNEL_TAIL_US;

    let compute_us = if p.compute_eff > 0.0 && flops > 0.0 {
        let ramp = flops / (flops + COMPUTE_RAMP_FLOPS);
        flops / (gpu.flops_per_us() * p.compute_eff * ramp.max(1e-6))
    } else {
        0.0
    };
    let mem_us = if p.mem_eff > 0.0 && bytes > 0.0 {
        let ramp = bytes / (bytes + MEM_RAMP_BYTES);
        bytes / (gpu.bytes_per_us() * p.mem_eff * ramp.max(1e-6))
    } else {
        0.0
    };
    dur += compute_us.max(mem_us);
    dur.max(MIN_KERNEL_US)
}

/// Device duration with per-invocation jitter (used by the simulator).
pub fn sample_duration_us(
    family: Family,
    flops: f64,
    bytes: f64,
    gpu: &GpuSpec,
    rng: &mut Rng,
) -> f64 {
    device_duration_us(family, flops, bytes, gpu) * rng.lognormal_med(1.0, DEVICE_JITTER_SIGMA)
}

/// Achieved-vs-peak compute utilization for a kernel sample — feeds the
/// Table II "GPU utilization" column and the §Perf roofline report.
pub fn compute_utilization(flops: f64, dur_us: f64, gpu: &GpuSpec) -> f64 {
    if dur_us <= 0.0 {
        return 0.0;
    }
    (flops / dur_us) / gpu.flops_per_us()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Platform;

    fn gpu() -> GpuSpec {
        Platform::h100().gpu
    }

    #[test]
    fn tiny_kernels_hit_min_duration() {
        let d = device_duration_us(Family::ElemVector, 0.0, 64.0, &gpu());
        assert!((MIN_KERNEL_US..2.0).contains(&d), "{d}");
    }

    #[test]
    fn large_gemm_approaches_roofline() {
        // 4096^3 GEMM: 137 GFLOP at 60% of 989 TFLOPs ≈ 232 us.
        let flops = 2.0 * 4096.0f64.powi(3);
        let bytes = 2.0 * 3.0 * 4096.0f64.powi(2);
        let d = device_duration_us(Family::GemmCublas, flops, bytes, &gpu());
        let ideal = flops / (gpu().flops_per_us() * 0.60);
        assert!(d > ideal && d < ideal * 1.1, "d={d} ideal={ideal}");
    }

    #[test]
    fn small_gemm_is_inefficient() {
        // A 128x128x128 expert-GEMM fragment must run far below peak.
        let flops = 2.0 * 128.0f64.powi(3);
        let d = device_duration_us(Family::GemmCublas, flops, 3.0 * 2.0 * 128.0 * 128.0, &gpu());
        let util = compute_utilization(flops, d, &gpu());
        assert!(util < 0.05, "util={util}");
    }

    #[test]
    fn memory_bound_kernel_scales_with_bytes() {
        let d1 = device_duration_us(Family::ElemVector, 0.0, 100e6, &gpu());
        let d2 = device_duration_us(Family::ElemVector, 0.0, 200e6, &gpu());
        assert!(d2 > 1.8 * d1 && d2 < 2.2 * d1, "{d1} {d2}");
    }

    #[test]
    fn h200_bandwidth_helps_memory_bound() {
        let h100 = Platform::h100().gpu;
        let h200 = Platform::h200().gpu;
        let d100 = device_duration_us(Family::ElemVector, 0.0, 500e6, &h100);
        let d200 = device_duration_us(Family::ElemVector, 0.0, 500e6, &h200);
        assert!(d200 < d100);
    }

    #[test]
    fn h200_clock_hurts_compute_bound() {
        let h100 = Platform::h100().gpu;
        let h200 = Platform::h200().gpu;
        let flops = 2.0 * 8192.0f64.powi(3);
        let d100 = device_duration_us(Family::GemmCublas, flops, 1e6, &h100);
        let d200 = device_duration_us(Family::GemmCublas, flops, 1e6, &h200);
        assert!(d200 > d100, "H200 is clocked -9.9%");
    }

    #[test]
    fn jitter_is_small_and_deterministic() {
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        let a = sample_duration_us(Family::Reduce, 0.0, 1e6, &gpu(), &mut r1);
        let b = sample_duration_us(Family::Reduce, 0.0, 1e6, &gpu(), &mut r2);
        assert_eq!(a, b);
        let base = device_duration_us(Family::Reduce, 0.0, 1e6, &gpu());
        assert!((a / base - 1.0).abs() < 0.2);
    }

    #[test]
    fn utilization_bounds() {
        assert_eq!(compute_utilization(0.0, 1.0, &gpu()), 0.0);
        let u = compute_utilization(gpu().flops_per_us() * 10.0, 10.0, &gpu());
        assert!((u - 1.0).abs() < 1e-12);
    }
}
