//! The Phase-1 kernel database (paper §III-B).
//!
//! Built from a full-model trace: each *unique* kernel — keyed on ATen
//! metadata (operator, shapes, dtypes, scalars), cleaned kernel name and
//! launch configuration — gets one entry recording its invocation count
//! and classification.  Phase 2 replays exactly one invocation per entry
//! (the dedup cache that "saves significant runtime").

use std::collections::HashMap;

use crate::trace::{DedupKey, KernelMeta, Trace};

/// One unique kernel entry.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelEntry {
    pub meta: KernelMeta,
    /// How many times this exact kernel was invoked in the trace.
    pub invocations: usize,
    /// Mean observed device duration in the full-model trace, us.
    pub mean_device_us: f64,
}

/// Database of unique kernels from one (or more) traces.
#[derive(Debug, Clone, Default)]
pub struct KernelDb {
    entries: Vec<KernelEntry>,
    index: HashMap<DedupKey, usize>,
}

impl KernelDb {
    pub fn new() -> KernelDb {
        KernelDb::default()
    }

    /// Build from a trace's kernel events.
    pub fn from_trace(trace: &Trace) -> KernelDb {
        let mut db = KernelDb::new();
        for ev in trace.kernels() {
            if let Some(meta) = &ev.meta {
                db.record(meta, ev.dur_us);
            }
        }
        db
    }

    /// Record one invocation. Allocation-free on the repeat path: the
    /// dedup key is the `Copy` [`DedupKey`], not a formatted string.
    pub fn record(&mut self, meta: &KernelMeta, device_us: f64) {
        let key = meta.dedup();
        match self.index.get(&key) {
            Some(&i) => {
                let e = &mut self.entries[i];
                // Streaming mean of the device duration.
                e.mean_device_us += (device_us - e.mean_device_us) / (e.invocations + 1) as f64;
                e.invocations += 1;
            }
            None => {
                self.index.insert(key, self.entries.len());
                self.entries.push(KernelEntry {
                    meta: meta.clone(),
                    invocations: 1,
                    mean_device_us: device_us,
                });
            }
        }
    }

    pub fn entries(&self) -> &[KernelEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, key: DedupKey) -> Option<&KernelEntry> {
        self.index.get(&key).map(|&i| &self.entries[i])
    }

    /// Total invocations across all entries (== trace kernel count).
    pub fn total_invocations(&self) -> usize {
        self.entries.iter().map(|e| e.invocations).sum()
    }

    /// Unique *cleaned kernel names* (Table II numerator) — weaker than
    /// the dedup key (a name may appear with several launch configs).
    pub fn unique_names(&self) -> usize {
        let mut names: Vec<&str> = self
            .entries
            .iter()
            .map(|e| e.meta.kernel_name.as_str())
            .collect();
        names.sort();
        names.dedup();
        names.len()
    }

    /// Kernel diversity ratio: unique names / total launches (Table II).
    pub fn diversity_ratio(&self) -> f64 {
        let total = self.total_invocations();
        if total == 0 {
            0.0
        } else {
            self.unique_names() as f64 / total as f64
        }
    }

    /// Entries partitioned by the dedup cache: `(uncached, cached)`
    /// given a set of already-profiled keys. Mirrors the paper's global
    /// replay cache partitioning.
    pub fn partition_cached<'a>(
        &'a self,
        cached_keys: &HashMap<DedupKey, f64>,
    ) -> (Vec<&'a KernelEntry>, Vec<&'a KernelEntry>) {
        let mut uncached = Vec::new();
        let mut cached = Vec::new();
        for e in &self.entries {
            if cached_keys.contains_key(&e.meta.dedup()) {
                cached.push(e);
            } else {
                uncached.push(e);
            }
        }
        (uncached, cached)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{EventKind, Track, TraceEvent, TraceMeta};

    fn meta(name: &str, shapes: &str) -> KernelMeta {
        KernelMeta {
            kernel_name: name.into(),
            family: "elem_vector".into(),
            aten_op: "aten::mul".into(),
            shapes_key: shapes.into(),
            grid: [1, 1, 1],
            block: [256, 1, 1],
            lib_mediated: false,
            flops: 0.0,
            bytes: 1024.0,
        }
    }

    #[test]
    fn dedups_identical_kernels() {
        let mut db = KernelDb::new();
        db.record(&meta("k1", "f32[8]"), 2.0);
        db.record(&meta("k1", "f32[8]"), 4.0);
        db.record(&meta("k1", "f32[16]"), 3.0);
        assert_eq!(db.len(), 2);
        assert_eq!(db.total_invocations(), 3);
        let e = db.get(meta("k1", "f32[8]").dedup()).unwrap();
        assert_eq!(e.invocations, 2);
        assert!((e.mean_device_us - 3.0).abs() < 1e-12);
    }

    #[test]
    fn unique_names_and_diversity() {
        let mut db = KernelDb::new();
        for i in 0..10 {
            db.record(&meta("same_kernel", &format!("f32[{i}]")), 1.0);
        }
        db.record(&meta("other_kernel", "f32[1]"), 1.0);
        assert_eq!(db.len(), 11);
        assert_eq!(db.unique_names(), 2);
        assert!((db.diversity_ratio() - 2.0 / 11.0).abs() < 1e-12);
    }

    #[test]
    fn from_trace_collects_kernels_only() {
        let mut t = Trace::new(TraceMeta::default());
        t.push(TraceEvent {
            kind: EventKind::RuntimeApi,
            name: "cudaLaunchKernel".into(),
            ts_us: 0.0,
            dur_us: 1.0,
            correlation_id: 1,
            track: Track::Host,
            device: None,
            args: None,
            meta: None,
        });
        t.push(TraceEvent {
            kind: EventKind::Kernel,
            name: "k".into(),
            ts_us: 5.0,
            dur_us: 2.0,
            correlation_id: 1,
            track: Track::Device(0),
            device: None,
            args: None,
            meta: Some(meta("k", "f32[4]")),
        });
        let db = KernelDb::from_trace(&t);
        assert_eq!(db.len(), 1);
        assert_eq!(db.total_invocations(), 1);
    }

    #[test]
    fn cache_partition() {
        let mut db = KernelDb::new();
        db.record(&meta("a", "x"), 1.0);
        db.record(&meta("b", "y"), 1.0);
        let mut cache = HashMap::new();
        cache.insert(meta("a", "x").dedup(), 1.0);
        let (uncached, cached) = db.partition_cached(&cache);
        assert_eq!(uncached.len(), 1);
        assert_eq!(cached.len(), 1);
        assert_eq!(uncached[0].meta.kernel_name, "b");
    }

    #[test]
    fn empty_db() {
        let db = KernelDb::new();
        assert!(db.is_empty());
        assert_eq!(db.diversity_ratio(), 0.0);
    }
}
