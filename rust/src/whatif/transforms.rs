//! Counterfactual transforms: composable edits to a [`Schedule`] that
//! model the paper's optimization prescriptions quantitatively.
//!
//! Specs (comma-separated on the CLI, applied left to right):
//!
//! | spec | models |
//! |---|---|
//! | `host-cpu:<profile\|factor>` | §VI single-thread scaling of every CPU-attributed Eq. 1 component |
//! | `cuda-graphs[:<launch_us>]` | per-graph amortization of the N·T_sys_floor launch path |
//! | `lib-elision[:fam+fam]` | dropping I_lib·ΔCT for selected kernel families |
//! | `fusion:elem` / `fusion:moe[:<keep>]` | kernel-count reduction (pointwise chains / MoE dispatch) |
//! | `device:<platform>` | per-family device-time rescaling onto another GPU |
//! | `tensor-parallel:<N>` | N-way sharding of weight-carrying device work + per-pass all-reduce; the per-rank launch path is untouched |
//!
//! **What `host-cpu` scales** (DESIGN.md §10): the components the
//! two-phase measurement attributes to the host CPU — `T_Py`,
//! `T_dispatch` (base + ΔCT), the launch-API span and the framework
//! launch excess ΔKT_fw. The hardware floor `T_sys_floor`, device time
//! and the *unattributed* host residual (`pre_host_us`: per-pass
//! framework glue outside the per-kernel decomposition, or serving
//! arrival idle) are held fixed, making the prediction a conservative
//! lower bound exactly where TaxBreak's attribution ends.

use std::collections::BTreeSet;

use crate::faults::{FaultKind, FaultPlan, HostSeg, BACKOFF_BASE_US, MAX_LAUNCH_ATTEMPTS};
use crate::hardware::{HostProfile, Platform};
use crate::kernels::cost;
use crate::kernels::family::Family;
use crate::sim::{GRAPH_CAPTURE_US, GRAPH_LAUNCH_US};
use crate::whatif::schedule::{Schedule, ScheduleMode, Step, SYNC_EPS_US};

/// A composable counterfactual edit.
pub trait Counterfactual {
    /// Row label for reports (echoes the spec).
    fn label(&self) -> String;

    /// Apply in place.
    fn apply(&self, s: &mut Schedule) -> anyhow::Result<()>;
}

/// Parse one spec (see module docs).
pub fn parse_spec(spec: &str) -> anyhow::Result<Box<dyn Counterfactual>> {
    let (head, rest) = match spec.split_once(':') {
        Some((h, r)) => (h, Some(r)),
        None => (spec, None),
    };
    Ok(match head {
        "host-cpu" => {
            let arg = rest.ok_or_else(|| {
                anyhow::anyhow!("host-cpu needs a profile or factor, e.g. host-cpu:xeon-6538y")
            })?;
            let target = match HostProfile::by_name(arg) {
                Ok(p) => HostTarget::Profile(p),
                Err(profile_err) => {
                    let f: f64 = arg.parse().map_err(|_| profile_err)?;
                    anyhow::ensure!(
                        f > 0.0 && f.is_finite(),
                        "host-cpu factor must be a positive number, got '{arg}'"
                    );
                    HostTarget::Factor(f)
                }
            };
            Box::new(HostCpu { target })
        }
        "cuda-graphs" => {
            let launch_us = match rest {
                None => GRAPH_LAUNCH_US,
                Some(v) => {
                    let x: f64 = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("cuda-graphs launch cost must be a number, got '{v}'"))?;
                    anyhow::ensure!(x >= 0.0, "cuda-graphs launch cost must be >= 0");
                    x
                }
            };
            Box::new(CudaGraphs { launch_us })
        }
        "lib-elision" => {
            let families = match rest {
                None => None,
                Some(list) => {
                    let mut set = BTreeSet::new();
                    for tag in list.split('+').filter(|t| !t.is_empty()) {
                        Family::from_tag(tag)?;
                        set.insert(tag.to_string());
                    }
                    anyhow::ensure!(!set.is_empty(), "lib-elision family list is empty");
                    Some(set)
                }
            };
            Box::new(LibElision { families })
        }
        "fusion" => match rest {
            Some("elem") => Box::new(FuseElementwise),
            Some(moe) if moe == "moe" || moe.starts_with("moe:") => {
                let keep = match moe.strip_prefix("moe:") {
                    // Default: toward the dense kernels/token ratio
                    // (Table II: MoE dispatches 8-11x more).
                    None => 0.125,
                    Some(v) => {
                        let k: f64 = v.parse().map_err(|_| {
                            anyhow::anyhow!("fusion:moe keep-fraction must be a number, got '{v}'")
                        })?;
                        anyhow::ensure!(
                            k > 0.0 && k <= 1.0,
                            "fusion:moe keep-fraction must be in (0, 1], got {k}"
                        );
                        k
                    }
                };
                Box::new(FuseMoeDispatch { keep })
            }
            _ => anyhow::bail!("fusion spec must be fusion:elem or fusion:moe[:<keep>], got '{spec}'"),
        },
        "device" => {
            let name = rest
                .ok_or_else(|| anyhow::anyhow!("device needs a platform, e.g. device:h200"))?;
            Box::new(DeviceSwap {
                platform: Platform::by_name(name)?,
            })
        }
        "fault-free" => {
            let kind = match rest {
                None | Some("all") => None,
                Some(k) => Some(FaultKind::parse(k)?),
            };
            anyhow::ensure!(
                kind != Some(FaultKind::KvPressure),
                "fault-free:kv_pressure is not expressible as a schedule rescale: \
                 KV pressure converts capacity into queueing, so its cost lives in \
                 the recorded admission/shed decisions, not in any time segment — \
                 re-run `taxbreak loadgen` without the kv clause to compare"
            );
            Box::new(FaultFree { kind })
        }
        "tensor-parallel" => {
            let arg = rest.ok_or_else(|| {
                anyhow::anyhow!("tensor-parallel needs a way count, e.g. tensor-parallel:2")
            })?;
            let ways: usize = arg
                .parse()
                .map_err(|_| anyhow::anyhow!("tensor-parallel ways must be an integer, got '{arg}'"))?;
            anyhow::ensure!(
                (2..=64).contains(&ways),
                "tensor-parallel ways must be in 2..=64, got {ways}"
            );
            Box::new(TensorParallel { ways })
        }
        other => anyhow::bail!(
            "unknown counterfactual '{other}' \
             (host-cpu:<profile|factor> | cuda-graphs[:<launch_us>] | \
             lib-elision[:fam+fam] | fusion:elem | fusion:moe[:<keep>] | \
             device:<platform> | tensor-parallel:<N> | fault-free[:<kind|all>])"
        ),
    })
}

/// Parse a comma-separated spec list (composition order preserved).
pub fn parse_specs(specs: &[String]) -> anyhow::Result<Vec<Box<dyn Counterfactual>>> {
    anyhow::ensure!(!specs.is_empty(), "need at least one --counterfactual spec");
    specs.iter().map(|s| parse_spec(s)).collect()
}

/// Spec for the next-faster named host relative to `baseline_st` — the
/// diagnosis quantifier's default software-stack counterfactual.
pub fn faster_host_spec(baseline_st: f64) -> String {
    let mut profiles = HostProfile::all();
    profiles.sort_by(|a, b| a.st_speed.partial_cmp(&b.st_speed).unwrap());
    profiles
        .into_iter()
        .find(|p| p.st_speed > baseline_st * 1.01)
        .map(|p| format!("host-cpu:{}", p.name))
        // Already past every named profile: extrapolate the paper's
        // measured pair ratio.
        .unwrap_or_else(|| "host-cpu:1.3".to_string())
}

enum HostTarget {
    Profile(HostProfile),
    Factor(f64),
}

/// (1) Host-CPU scaling per the paper's §VI single-thread model.
pub struct HostCpu {
    target: HostTarget,
}

impl HostCpu {
    fn factor(&self, s: &Schedule) -> f64 {
        match &self.target {
            HostTarget::Profile(p) => p.st_speed / s.baseline_st_speed.max(1e-9),
            HostTarget::Factor(f) => *f,
        }
    }
}

impl Counterfactual for HostCpu {
    fn label(&self) -> String {
        match &self.target {
            HostTarget::Profile(p) => format!("host-cpu:{}", p.name),
            HostTarget::Factor(f) => format!("host-cpu:{f}"),
        }
    }

    fn apply(&self, s: &mut Schedule) -> anyhow::Result<()> {
        let inv = 1.0 / self.factor(s);
        anyhow::ensure!(
            inv.is_finite() && inv > 0.0,
            "host-cpu scaling produced a non-positive factor"
        );
        for st in &mut s.steps {
            st.t_py_us *= inv;
            st.t_base_us *= inv;
            st.t_ct_us *= inv;
            st.api_us *= inv;
            st.excess_us *= inv;
        }
        Ok(())
    }
}

/// (2) CUDA-Graph amortization: decode passes (every pass after the
/// first capture pass) replay as one graph launch; the per-invocation
/// launch path collapses to a single per-graph floor + launch cost.
/// Per-pass framework glue is *not* removed (graph capture amortizes
/// the launch path, not Python control flow) and the one-time capture
/// cost is charged up front — both per the paper's §II-C caveats.
pub struct CudaGraphs {
    pub launch_us: f64,
}

impl Counterfactual for CudaGraphs {
    fn label(&self) -> String {
        if (self.launch_us - GRAPH_LAUNCH_US).abs() < 1e-12 {
            "cuda-graphs".to_string()
        } else {
            format!("cuda-graphs:{}", self.launch_us)
        }
    }

    fn apply(&self, s: &mut Schedule) -> anyhow::Result<()> {
        anyhow::ensure!(
            s.mode == ScheduleMode::Eager,
            "cuda-graphs applies to eager traces (serving engines already \
             launch one executable per step)"
        );
        let floor = s.floor_hint_us;
        let mut pass = 0usize;
        let mut captured = false;
        let mut first_in_pass = false;
        for st in &mut s.steps {
            if st.synced {
                pass += 1;
                first_in_pass = true;
            }
            if pass <= 1 {
                // Capture pass runs eagerly.
                first_in_pass = false;
                continue;
            }
            st.graphed = true;
            st.t_py_us = 0.0;
            st.t_base_us = 0.0;
            st.t_ct_us = 0.0;
            if first_in_pass {
                first_in_pass = false;
                st.api_us = self.launch_us;
                st.floor_us = floor;
                st.excess_us = 0.0;
                if !captured {
                    captured = true;
                    st.pre_host_us += GRAPH_CAPTURE_US;
                }
            } else {
                st.api_us = 0.0;
                st.floor_us = 0.0;
                st.excess_us = 0.0;
            }
        }
        Ok(())
    }
}

/// (3) Library-dispatch elision: drop `I_lib·ΔCT` for the selected
/// kernel families (all library-mediated families when unspecified).
pub struct LibElision {
    pub families: Option<BTreeSet<String>>,
}

impl Counterfactual for LibElision {
    fn label(&self) -> String {
        match &self.families {
            None => "lib-elision".to_string(),
            Some(f) => format!(
                "lib-elision:{}",
                f.iter().cloned().collect::<Vec<_>>().join("+")
            ),
        }
    }

    fn apply(&self, s: &mut Schedule) -> anyhow::Result<()> {
        for st in &mut s.steps {
            let selected = self
                .families
                .as_ref()
                .map(|f| f.contains(&st.family))
                .unwrap_or(true);
            if st.lib_mediated && selected {
                st.t_ct_us = 0.0;
                st.lib_mediated = false;
            }
        }
        Ok(())
    }
}

/// A step may be absorbed into the preceding one only mid-pass (no sync
/// boundary, no host residual between them).
fn absorbable(st: &Step) -> bool {
    !st.synced && st.pre_host_us <= SYNC_EPS_US
}

/// Merge `src` into `dst`: device work is conserved, the host dispatch
/// path and launch charge of `src` disappear.
fn absorb(dst: &mut Step, src: &Step) {
    dst.device_us += src.device_us;
    dst.flops += src.flops;
    dst.bytes += src.bytes;
}

/// (4a) Elementwise fusion (TorchInductor pointwise chains): runs of
/// consecutive `elem_*` kernels become one kernel.
pub struct FuseElementwise;

impl Counterfactual for FuseElementwise {
    fn label(&self) -> String {
        "fusion:elem".to_string()
    }

    fn apply(&self, s: &mut Schedule) -> anyhow::Result<()> {
        let is_elem = |st: &Step| st.family.starts_with("elem_");
        let mut out: Vec<Step> = Vec::with_capacity(s.steps.len());
        for st in s.steps.drain(..) {
            match out.last_mut() {
                Some(prev) if is_elem(prev) && is_elem(&st) && absorbable(&st) => {
                    absorb(prev, &st);
                }
                _ => out.push(st),
            }
        }
        s.steps = out;
        Ok(())
    }
}

/// (4b) MoE dispatch reduction: runs of consecutive `expert_*` kernels
/// (the eager per-expert loop) shrink toward the dense kernels/token
/// ratio — `keep` is the surviving fraction (grouped/batched expert
/// execution), device work conserved.
pub struct FuseMoeDispatch {
    pub keep: f64,
}

impl Counterfactual for FuseMoeDispatch {
    fn label(&self) -> String {
        format!("fusion:moe:{}", self.keep)
    }

    fn apply(&self, s: &mut Schedule) -> anyhow::Result<()> {
        let is_expert = |st: &Step| st.name.contains("expert_");
        let group = (1.0 / self.keep).round().max(1.0) as usize;
        let mut out: Vec<Step> = Vec::with_capacity(s.steps.len());
        let mut run_len = 0usize; // expert steps in the current run
        for st in s.steps.drain(..) {
            if is_expert(&st) && absorbable(&st) && run_len > 0 && run_len % group != 0 {
                run_len += 1;
                absorb(out.last_mut().expect("run_len > 0"), &st);
                continue;
            }
            run_len = if is_expert(&st) { 1 } else { 0 };
            out.push(st);
        }
        s.steps = out;
        Ok(())
    }
}

/// (5) Device swap: rescale each kernel's device time by the analytic
/// cost-model ratio between the target GPU and the recorded one, and
/// move the launch floor to the target's `T_sys_floor`. Families
/// outside the taxonomy (serving `sim_exec` invocations) rescale by
/// the HBM bandwidth ratio — the decode-dominant, memory-bound
/// assumption, documented in DESIGN.md §10.
pub struct DeviceSwap {
    pub platform: Platform,
}

impl Counterfactual for DeviceSwap {
    fn label(&self) -> String {
        format!("device:{}", self.platform.name)
    }

    fn apply(&self, s: &mut Schedule) -> anyhow::Result<()> {
        let base = Platform::by_name(&s.platform).map_err(|e| {
            anyhow::anyhow!("device swap needs a recorded catalog platform: {e}")
        })?;
        let floor_ratio = self.platform.gpu.t_sys_floor_us / base.gpu.t_sys_floor_us;
        let bw_ratio = base.gpu.bytes_per_us() / self.platform.gpu.bytes_per_us();
        for st in &mut s.steps {
            let ratio = match Family::from_tag(&st.family) {
                Ok(family) => {
                    let old = cost::device_duration_us(family, st.flops, st.bytes, &base.gpu);
                    let new =
                        cost::device_duration_us(family, st.flops, st.bytes, &self.platform.gpu);
                    new / old
                }
                Err(_) => bw_ratio,
            };
            st.device_us *= ratio;
            st.floor_us *= floor_ratio;
        }
        s.floor_hint_us *= floor_ratio;
        s.platform = self.platform.name.clone();
        Ok(())
    }
}

/// (6) Tensor parallelism: replay the per-rank timeline of an N-way
/// sharded execution (SPMD — every rank replays the same schedule).
/// Weight-carrying device work (GEMM / fused attention) rescales via
/// the analytic cost model over `flops/N, bytes/N` (small shards fall
/// off the efficiency ramp, so the gain is sub-linear by construction);
/// other families replicate. One ring **all-reduce step is appended to
/// every pass** (`sim::parallel::allreduce_device_us` — the schedule
/// carries pass boundaries, not layer boundaries, so this is the
/// conservative per-pass approximation; activation size is estimated
/// from the pass's largest GEMM output). The per-rank host launch path
/// is deliberately untouched: each rank dispatches its full shard, so
/// a host-bound schedule predicts ~no end-to-end gain — adding devices
/// multiplies aggregate launch-path cost instead of hiding it.
pub struct TensorParallel {
    pub ways: usize,
}

impl TensorParallel {
    /// Activation-size estimate for one pass: the largest GEMM-family
    /// step's output matrix, taking `bytes ≈ A + B + C` with the three
    /// operands of comparable order → `C ≈ bytes / 3`. Must be fed the
    /// *unsharded* steps: the all-reduce moves the full partial-sum
    /// output, not one rank's shard.
    fn pass_act_bytes(steps: &[Step]) -> f64 {
        steps
            .iter()
            .filter(|st| st.family.starts_with("gemm"))
            .map(|st| st.bytes / 3.0)
            .fold(0.0f64, f64::max)
    }

    /// The per-pass ring all-reduce step over `act` activation bytes.
    fn ar_step(&self, act: f64, floor: f64) -> Step {
        Step {
            name: "nccl_all_reduce_ring".to_string(),
            family: "memcpy".to_string(),
            dedup_key: "nccl::all_reduce".to_string(),
            lib_mediated: false,
            synced: false,
            pre_host_us: 0.0,
            t_py_us: 0.0,
            t_base_us: 0.0,
            t_ct_us: 0.0,
            api_us: crate::host::API_CALL_MED_US,
            floor_us: floor,
            excess_us: 0.0,
            device_us: crate::sim::parallel::allreduce_device_us(self.ways, act),
            flops: 0.0,
            bytes: crate::sim::parallel::allreduce_wire_bytes(self.ways, act),
            graphed: false,
            device: 0,
            stream: 0,
            ts_us: 0.0,
        }
    }
}

impl Counterfactual for TensorParallel {
    fn label(&self) -> String {
        format!("tensor-parallel:{}", self.ways)
    }

    fn apply(&self, s: &mut Schedule) -> anyhow::Result<()> {
        anyhow::ensure!(
            s.mode == ScheduleMode::Eager,
            "tensor-parallel applies to eager schedules (serving invocations are \
             opaque whole-model executables with no shardable kernel structure — \
             shard serving at the engine level with `taxbreak loadgen --devices`)"
        );
        let base = Platform::by_name(&s.platform).map_err(|e| {
            anyhow::anyhow!("tensor-parallel needs a recorded catalog platform: {e}")
        })?;

        // Pass boundaries + activation estimates from the *unsharded*
        // steps, before the sharding loop rewrites flops/bytes:
        // (last step index of the pass, activation bytes).
        let mut pass_acts: Vec<(usize, f64)> = Vec::new();
        let mut pass_start = 0usize;
        for i in 0..s.steps.len() {
            if i + 1 == s.steps.len() || s.steps[i + 1].synced {
                pass_acts.push((i, Self::pass_act_bytes(&s.steps[pass_start..=i])));
                pass_start = i + 1;
            }
        }

        let w = self.ways as f64;
        for st in &mut s.steps {
            // Shardability comes from the one shared predicate
            // (`sim::parallel::tp_sharded`); families outside the
            // taxonomy replicate.
            let family = match Family::from_tag(&st.family) {
                Ok(f) if crate::sim::parallel::tp_sharded(f) => f,
                _ => continue,
            };
            let old = cost::device_duration_us(family, st.flops, st.bytes, &base.gpu);
            let new = cost::device_duration_us(family, st.flops / w, st.bytes / w, &base.gpu);
            st.device_us *= new / old;
            st.flops /= w;
            st.bytes /= w;
        }

        // Append one all-reduce step at the end of each pass.
        let floor = s.floor_hint_us;
        let old_steps = std::mem::take(&mut s.steps);
        let mut out: Vec<Step> = Vec::with_capacity(old_steps.len() + pass_acts.len());
        let mut boundaries = pass_acts.into_iter().peekable();
        for (i, step) in old_steps.into_iter().enumerate() {
            out.push(step);
            if boundaries.peek().is_some_and(|&(end, _)| end == i) {
                let (_, act) = boundaries.next().expect("peeked");
                out.push(self.ar_step(act, floor));
            }
        }
        s.steps = out;
        Ok(())
    }
}

/// (7) Fault removal: invert the injected fault factors of a faulted
/// serving capture (`loadgen --faults`), turning "what did that
/// straggler window cost us" into a counterfactual row. The schedule
/// carries the capture's spec-v4 fault windows and each step's source
/// timestamp, so every factor is looked up against the *same clock the
/// injection used* (`runtime::backend`): jitter and launch failures at
/// the host-op start, the device stall at the submit clock.
///
/// * `device_stall` — exact: kernel time divides by the recorded
///   stall-factor product for the step's stream.
/// * `host_jitter` — exact on the prep span; on the exec span the
///   division is exact unless a launch-fail window overlapped (the
///   deterministic backoff part of the span was never jitter-scaled).
/// * `launch_fail` — the deterministic backoff is subtracted exactly;
///   the re-issued launch draws are folded out by an even split of the
///   remaining span over `1 + retries` attempts (the individual retry
///   draws are i.i.d. with the base draw, so the split is the unbiased
///   estimate — the capture only stores their sum).
/// * `kv_pressure` — rejected at parse time: its cost is queueing shape
///   (admissions/sheds), not a time segment, so there is nothing to
///   rescale. `fault-free`/`fault-free:all` removes the three timing
///   kinds and leaves kv windows in place.
pub struct FaultFree {
    /// `None` = every timing-visible kind (`all`).
    pub kind: Option<FaultKind>,
}

impl FaultFree {
    fn wants(&self, kind: FaultKind) -> bool {
        match self.kind {
            Some(sel) => sel == kind,
            None => kind != FaultKind::KvPressure,
        }
    }
}

impl Counterfactual for FaultFree {
    fn label(&self) -> String {
        match self.kind {
            None => "fault-free".to_string(),
            Some(k) => format!("fault-free:{}", k.as_str()),
        }
    }

    fn apply(&self, s: &mut Schedule) -> anyhow::Result<()> {
        anyhow::ensure!(
            s.mode == ScheduleMode::Synchronous,
            "fault-free applies to serving captures — faults are injected by the \
             serving engine (`taxbreak loadgen --faults`), so only those schedules \
             carry fault windows"
        );
        anyhow::ensure!(
            !s.fault_windows.is_empty(),
            "this capture carries no fault events; there is nothing to remove \
             (record one with `taxbreak loadgen --faults ... --capture ...`)"
        );
        let plan = FaultPlan::from_windows(s.fault_windows.clone());
        for st in &mut s.steps {
            // Every lookup uses the step's original clock, captured
            // before any span below is rewritten.
            let t0 = st.ts_us;
            let submit_us = t0 + st.t_base_us + st.api_us;
            if self.wants(FaultKind::DeviceStall) {
                st.device_us /= plan.stall_factor(submit_us, st.stream);
            }
            if self.wants(FaultKind::LaunchFail) {
                let failures = plan.launch_failures(t0);
                if failures > 0 {
                    let reissues = failures.min(MAX_LAUNCH_ATTEMPTS - 1);
                    let backoff: f64 = (0..reissues)
                        .map(|i| BACKOFF_BASE_US * f64::from(1u32 << i))
                        .sum();
                    st.api_us = (st.api_us - backoff).max(0.0) / f64::from(reissues + 1);
                }
            }
            if self.wants(FaultKind::HostJitter) {
                st.t_base_us /= plan.host_factor(t0, HostSeg::Prep);
                st.api_us /= plan.host_factor(t0, HostSeg::Exec);
            }
        }
        // Composed transforms (and a second fault-free) see only the
        // windows that are still in force.
        let keep = |k: FaultKind| !self.wants(k);
        s.fault_windows.retain(|w| keep(w.kind));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(name: &str, family: &str, synced: bool) -> Step {
        Step {
            name: name.to_string(),
            family: family.to_string(),
            dedup_key: name.to_string(),
            lib_mediated: family == "gemm_cublas",
            synced,
            pre_host_us: if synced { 100.0 } else { 0.0 },
            t_py_us: 2.0,
            t_base_us: 10.0,
            t_ct_us: if family == "gemm_cublas" { 3.0 } else { 0.0 },
            api_us: 0.8,
            floor_us: 4.7,
            excess_us: 0.4,
            device_us: 5.0,
            flops: 100.0,
            bytes: 200.0,
            graphed: false,
            device: 0,
            stream: 0,
            ts_us: 0.0,
        }
    }

    fn sched(steps: Vec<Step>) -> Schedule {
        Schedule {
            mode: ScheduleMode::Eager,
            platform: "h100".to_string(),
            model: "test".to_string(),
            phase: "prefill".to_string(),
            steps,
            tail_host_us: 10.0,
            baseline_st_speed: 1.0,
            floor_hint_us: 4.7,
            devices: 1,
            streams_per_device: 1,
            fault_windows: Vec::new(),
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_spec("warp-speed").is_err());
        assert!(parse_spec("host-cpu").is_err());
        assert!(parse_spec("host-cpu:-2").is_err());
        assert!(parse_spec("fusion").is_err());
        assert!(parse_spec("fusion:moe:0").is_err());
        assert!(parse_spec("fusion:moe:1.5").is_err());
        assert!(parse_spec("lib-elision:warp_gemm").is_err());
        assert!(parse_spec("device:b200").is_err());
        assert!(parse_spec("cuda-graphs:x").is_err());
        assert!(parse_spec("tensor-parallel").is_err());
        assert!(parse_spec("tensor-parallel:1").is_err());
        assert!(parse_spec("tensor-parallel:x").is_err());
        assert!(parse_spec("fault-free:gremlin").is_err());
        let err = parse_spec("fault-free:kv_pressure").unwrap_err().to_string();
        assert!(err.contains("queueing"), "{err}");
    }

    #[test]
    fn parse_accepts_every_documented_form() {
        for spec in [
            "host-cpu:xeon-6538y",
            "host-cpu:1.5",
            "cuda-graphs",
            "cuda-graphs:8",
            "lib-elision",
            "lib-elision:gemm_cublas",
            "fusion:elem",
            "fusion:moe",
            "fusion:moe:0.25",
            "device:h200",
            "tensor-parallel:2",
            "fault-free",
            "fault-free:all",
            "fault-free:device_stall",
            "fault-free:host_jitter",
            "fault-free:launch_fail",
        ] {
            let cf = parse_spec(spec).unwrap();
            assert!(cf.label().starts_with(spec.split(':').next().unwrap()));
        }
    }

    #[test]
    fn host_cpu_scales_decomposed_components_only() {
        let mut s = sched(vec![step("a", "gemm_cublas", true), step("b", "reduce", false)]);
        parse_spec("host-cpu:1.30").unwrap().apply(&mut s).unwrap();
        let a = &s.steps[0];
        assert!((a.t_py_us - 2.0 / 1.3).abs() < 1e-12);
        assert!((a.t_base_us - 10.0 / 1.3).abs() < 1e-12);
        assert!((a.t_ct_us - 3.0 / 1.3).abs() < 1e-12);
        assert!((a.excess_us - 0.4 / 1.3).abs() < 1e-12);
        // Floor, device and unattributed residual are invariant.
        assert_eq!(a.floor_us, 4.7);
        assert_eq!(a.device_us, 5.0);
        assert_eq!(a.pre_host_us, 100.0);
    }

    #[test]
    fn host_cpu_profile_is_relative_to_the_recorded_host() {
        let mut s = sched(vec![step("a", "reduce", true)]);
        s.baseline_st_speed = 1.30; // recorded on the H200 host
        parse_spec("host-cpu:xeon-6538y").unwrap().apply(&mut s).unwrap();
        // Same host => no change.
        assert!((s.steps[0].t_base_us - 10.0).abs() < 1e-12);
    }

    #[test]
    fn lib_elision_zeroes_dct() {
        let mut s = sched(vec![step("g", "gemm_cublas", true), step("r", "reduce", false)]);
        parse_spec("lib-elision").unwrap().apply(&mut s).unwrap();
        assert_eq!(s.steps[0].t_ct_us, 0.0);
        assert!(!s.steps[0].lib_mediated);
    }

    #[test]
    fn fusion_elem_conserves_device_work() {
        let mut s = sched(vec![
            step("e1", "elem_vector", true),
            step("e2", "elem_vector", false),
            step("e3", "elem_generic", false),
            step("g", "gemm_cublas", false),
            step("e4", "elem_vector", false),
        ]);
        let dev: f64 = s.steps.iter().map(|st| st.device_us).sum();
        parse_spec("fusion:elem").unwrap().apply(&mut s).unwrap();
        assert_eq!(s.steps.len(), 3, "e1+e2+e3 merge; g and e4 survive");
        let dev2: f64 = s.steps.iter().map(|st| st.device_us).sum();
        assert!((dev - dev2).abs() < 1e-12);
    }

    #[test]
    fn fusion_moe_keeps_the_requested_fraction() {
        let mut steps = vec![step("router_gate", "gemm_cublas", true)];
        for i in 0..64 {
            steps.push(step(&format!("expert_gate_v{i}"), "gemm_cublas", false));
        }
        let mut s = sched(steps);
        parse_spec("fusion:moe:0.25").unwrap().apply(&mut s).unwrap();
        // 64 expert steps in groups of 4 => 16 survivors + the router.
        assert_eq!(s.steps.len(), 17);
        let dev: f64 = s.steps.iter().map(|st| st.device_us).sum();
        assert!((dev - 65.0 * 5.0).abs() < 1e-12);
    }

    #[test]
    fn cuda_graphs_collapses_passes_after_the_first() {
        let mut s = sched(vec![
            step("p1", "reduce", true),
            step("p2", "reduce", false),
            step("d1", "reduce", true),
            step("d2", "reduce", false),
        ]);
        parse_spec("cuda-graphs").unwrap().apply(&mut s).unwrap();
        assert!(!s.steps[0].graphed && !s.steps[1].graphed, "capture pass is eager");
        assert!(s.steps[2].graphed && s.steps[3].graphed);
        assert_eq!(s.steps[2].api_us, GRAPH_LAUNCH_US);
        assert_eq!(s.steps[2].floor_us, 4.7);
        assert!(s.steps[2].pre_host_us > 100.0, "capture cost charged once");
        assert_eq!(s.steps[3].host_path_us(), 0.0);
        assert_eq!(s.steps[3].floor_us, 0.0);
    }

    #[test]
    fn device_swap_moves_floor_and_device_times() {
        let mut s = sched(vec![step("g", "gemm_cublas", true)]);
        parse_spec("device:h200").unwrap().apply(&mut s).unwrap();
        assert_eq!(s.platform, "h200");
        let ratio = Platform::h200().gpu.t_sys_floor_us / Platform::h100().gpu.t_sys_floor_us;
        assert!((s.steps[0].floor_us - 4.7 * ratio).abs() < 1e-9);
    }

    #[test]
    fn tensor_parallel_shards_gemms_and_appends_allreduce() {
        let mut s = sched(vec![
            step("g1", "gemm_cublas", true),
            step("r", "reduce", false),
            step("g2", "gemm_cublas", true), // second pass
        ]);
        // A roofline-sized GEMM so sharding actually shows (tiny
        // kernels sit on the efficiency ramp and barely shrink —
        // which is itself the honest sub-linear-TP behavior).
        s.steps[0].flops = 2.0e12;
        s.steps[0].bytes = 6.0e9;
        s.steps[0].device_us = 3000.0;
        let host_before: f64 = s.steps.iter().map(|st| st.host_path_us()).sum();
        parse_spec("tensor-parallel:2").unwrap().apply(&mut s).unwrap();
        // One all-reduce appended per pass: 3 steps -> 5.
        assert_eq!(s.steps.len(), 5);
        assert_eq!(s.steps[2].name, "nccl_all_reduce_ring");
        assert_eq!(s.steps[4].name, "nccl_all_reduce_ring");
        assert!(!s.steps[2].synced && s.steps[2].pre_host_us == 0.0);
        assert!(s.steps[2].device_us > 0.0, "all-reduce costs device time");
        // Big GEMM halves (to within the efficiency ramp)...
        assert!(
            s.steps[0].device_us > 1450.0 && s.steps[0].device_us < 1560.0,
            "sharded GEMM ~halves: {}",
            s.steps[0].device_us
        );
        // ...replicated families are untouched.
        assert_eq!(s.steps[1].device_us, 5.0, "reduce is replicated, not sharded");
        assert!((s.steps[0].flops - 1.0e12).abs() < 1.0);
        // The per-rank host launch path is untouched (nothing removed;
        // only the all-reduce launches are added).
        let host_after: f64 = s.steps.iter().map(|st| st.host_path_us()).sum();
        assert!(host_after >= host_before);
    }

    #[test]
    fn tensor_parallel_rejects_serving_schedules() {
        // Serving steps are opaque executables (family sim_exec/
        // pjrt_exec): nothing to shard, and every step is synced, so a
        // per-pass all-reduce would fire per invocation. Hard error.
        let mut s = sched(vec![step("g", "gemm_cublas", true)]);
        s.mode = ScheduleMode::Synchronous;
        let err = parse_spec("tensor-parallel:2").unwrap().apply(&mut s).unwrap_err();
        assert!(err.to_string().contains("eager"), "{err}");
    }

    #[test]
    fn faster_host_spec_walks_the_catalog() {
        assert_eq!(faster_host_spec(1.0), "host-cpu:xeon-6538y");
        assert_eq!(faster_host_spec(1.30), "host-cpu:hypothetical-2x");
        assert_eq!(faster_host_spec(2.5), "host-cpu:1.3");
    }

    /// A serving-mode schedule carrying one faulted step per fault kind,
    /// with timings hand-placed inside/outside the windows.
    fn faulted_serving_sched() -> Schedule {
        let mut faulted = step("f", "sim_exec", true);
        faulted.ts_us = 1000.0; // inside every window below
        faulted.t_base_us = 40.0; // prep, jitter-dilated 2x from 20
        faulted.api_us = 99.0; // exec: (8 + 8) * 3 + 75 backoff (see tests)
        faulted.device_us = 500.0; // stalled 5x from 100
        let mut clean = step("c", "sim_exec", true);
        clean.ts_us = 50_000.0; // outside every window
        clean.t_base_us = 20.0;
        clean.api_us = 8.0;
        clean.device_us = 100.0;
        let mut s = sched(vec![faulted, clean]);
        s.mode = ScheduleMode::Synchronous;
        s.fault_windows = FaultPlan::parse(
            "stall:0:10000:5.0;jitter:0:10000:2.0:prep;jitter:0:10000:3.0:exec;\
             launchfail:0:10000:1;kv:0:10000:0.5",
        )
        .unwrap()
        .windows;
        s
    }

    #[test]
    fn fault_free_inverts_stall_jitter_and_launch_retries() {
        let mut s = faulted_serving_sched();
        parse_spec("fault-free").unwrap().apply(&mut s).unwrap();
        let f = &s.steps[0];
        // Stall: device time divides by the 5x window factor.
        assert!((f.device_us - 100.0).abs() < 1e-9, "device {}", f.device_us);
        // Jitter: prep divides by the 2x prep window.
        assert!((f.t_base_us - 20.0).abs() < 1e-9, "prep {}", f.t_base_us);
        // Launch retry: one re-issue = 25us backoff out, even split of
        // the 99 - 25 = 74 remainder over 2 attempts = 37, then the 3x
        // exec jitter divides out -> 37/3.
        assert!((f.api_us - 37.0 / 3.0).abs() < 1e-9, "exec {}", f.api_us);
        // Steps outside every window are untouched.
        let c = &s.steps[1];
        assert_eq!((c.t_base_us, c.api_us, c.device_us), (20.0, 8.0, 100.0));
        // kv windows survive `all` (their cost is queueing shape, not a
        // segment); the three timing kinds are consumed.
        assert_eq!(s.fault_windows.len(), 1);
        assert_eq!(s.fault_windows[0].kind, FaultKind::KvPressure);
    }

    #[test]
    fn fault_free_single_kind_leaves_the_others() {
        let mut s = faulted_serving_sched();
        parse_spec("fault-free:device_stall").unwrap().apply(&mut s).unwrap();
        let f = &s.steps[0];
        assert!((f.device_us - 100.0).abs() < 1e-9);
        assert_eq!(f.t_base_us, 40.0, "jitter untouched");
        assert_eq!(f.api_us, 99.0, "launch retries untouched");
        assert_eq!(s.fault_windows.len(), 4, "only the stall window consumed");
    }

    #[test]
    fn fault_free_rejects_eager_and_fault_free_captures() {
        let mut eager = sched(vec![step("a", "reduce", true)]);
        let err = parse_spec("fault-free").unwrap().apply(&mut eager).unwrap_err();
        assert!(err.to_string().contains("serving"), "{err}");
        let mut clean = sched(vec![step("a", "reduce", true)]);
        clean.mode = ScheduleMode::Synchronous;
        let err = parse_spec("fault-free").unwrap().apply(&mut clean).unwrap_err();
        assert!(err.to_string().contains("no fault events"), "{err}");
    }
}
