//! Replayable schedule: the intermediate representation between a
//! recorded trace and a counterfactual re-simulation.
//!
//! Extraction inverts the producer's timeline exactly (DESIGN.md §10):
//!
//! * **Eager traces** (`sim::simulate`, `taxbreak trace`) — each
//!   correlation chain contributes one [`Step`] carrying the measured
//!   per-invocation host path (`T_Py`, `T_dispatch`, api-call span),
//!   the empty-queue launch gap split into floor + framework excess,
//!   and the device duration.  Inter-chain gaps become `pre_host_us`;
//!   a gap above [`SYNC_EPS_US`] marks a pass boundary, i.e. a device
//!   synchronization precedes the gap (`synced`).  Mid-pass the eager
//!   host never waits, so gaps there are exactly zero.
//! * **Serving traces** (`phase == "serve"`, captured via
//!   `taxbreak loadgen --capture`) — engines execute synchronously, so
//!   every invocation is a synced step whose preparation span is the
//!   host path and whose execute-call + device spans follow serially;
//!   inter-chain gaps are arrival idle time.  Multi-replica captures
//!   (`--devices N --streams M`) extract directly: each step carries
//!   its replica `device` and stream label, replicas replay on
//!   independent host threads of a matching [`timeline::Topology`],
//!   and the re-derived wall is the slowest replica's — the same
//!   merge convention the recording used.
//!
//! Re-simulating the unmodified schedule reproduces the recorded
//! wall-clock (identity fidelity — enforced by `rust/tests/whatif.rs`);
//! counterfactual transforms then edit steps and the same re-simulation
//! yields the predicted timeline, so decode-phase host-bound stalls
//! shorten wall-clock correctly instead of being subtracted as sums.

use crate::taxbreak::decompose::hdbi_of;
use crate::taxbreak::phase2::Phase2Result;
use crate::timeline::{self, StreamRef};
use crate::trace::{EventKind, KernelMeta, Trace, TraceEvent, Track};

/// Inter-chain host gap (us) above which the gap is a pass boundary
/// (device sync + per-pass framework glue). Mid-pass eager dispatch
/// chains back-to-back, so real gaps are either ~0 or ≫ this.
pub const SYNC_EPS_US: f64 = 1e-6;

/// How a schedule's host and device interleave.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleMode {
    /// Asynchronous eager dispatch: kernels queue on a FIFO stream and
    /// only pass boundaries synchronize.
    Eager,
    /// One executable invocation at a time, host-blocking (the serving
    /// engines' contract).
    Synchronous,
}

/// One kernel invocation of the replayable schedule (all times us).
#[derive(Debug, Clone, PartialEq)]
pub struct Step {
    /// Kernel symbol (family-level transforms match on it).
    pub name: String,
    /// Kernel family tag.
    pub family: String,
    /// Phase-2 dedup key (device-swap lookups).
    pub dedup_key: String,
    pub lib_mediated: bool,
    /// A device synchronization precedes `pre_host_us`.
    pub synced: bool,
    /// Unattributed host residual before this invocation: per-pass
    /// framework glue, sync epilogue, or (serving) arrival idle.
    pub pre_host_us: f64,
    /// Measured T_Py (eager) — 0 in serving mode.
    pub t_py_us: f64,
    /// Measured dispatch cost net of ΔCT (eager); preparation span
    /// (serving).
    pub t_base_us: f64,
    /// ΔCT share of the measured dispatch (library-mediated only).
    pub t_ct_us: f64,
    /// Launch-API call span (eager); execute-call span (serving).
    pub api_us: f64,
    /// Launch-floor share of the empty-queue launch gap.
    pub floor_us: f64,
    /// Framework launch excess (ΔKT_fw) share of the gap.
    pub excess_us: f64,
    /// Device execution time.
    pub device_us: f64,
    /// Analytic work estimates (device-swap rescaling).
    pub flops: f64,
    pub bytes: f64,
    /// Collapsed into a captured CUDA graph by a transform.
    pub graphed: bool,
    /// Replica/device the step ran on (0 in single-timeline traces).
    pub device: u32,
    /// Stream label within the device (serving engines rotate
    /// invocations over streams; host-blocking keeps them serial).
    pub stream: u32,
    /// Host-op start in the *source* trace's clock (us). Fault factors
    /// were evaluated against that clock at injection time, so the
    /// `fault-free` transform needs it to look the factors back up;
    /// nothing else consults it, and re-simulation rebuilds its own
    /// timeline regardless.
    pub ts_us: f64,
}

impl Step {
    /// Host dispatch-path occupancy of this step (excludes `pre_host_us`).
    pub fn host_path_us(&self) -> f64 {
        self.t_py_us + self.t_base_us + self.t_ct_us + self.api_us
    }
}

/// A replayable schedule extracted from one trace.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub mode: ScheduleMode,
    /// Copied from the source trace (reports echo it).
    pub platform: String,
    pub model: String,
    pub phase: String,
    pub steps: Vec<Step>,
    /// Trailing synced host time after the last invocation (final sync
    /// + epilogue).
    pub tail_host_us: f64,
    /// Single-thread speed of the recorded host (1.0 when the platform
    /// is not in the catalog) — host-CPU profiles rescale against it.
    pub baseline_st_speed: f64,
    /// Phase-2 null-kernel floor (gap splitting, graph-launch floors).
    pub floor_hint_us: f64,
    /// Replicas (devices) the schedule spans; each replays on its own
    /// host thread.
    pub devices: usize,
    /// Stream lanes per device the re-simulation topology needs.
    pub streams_per_device: usize,
    /// Fault windows the source capture carried as spec-v4 `fault`
    /// events (empty for fault-free and eager traces). Every replica
    /// records the same armed plan, so this is one replica's list —
    /// the `fault-free` counterfactual inverts against it.
    pub fault_windows: Vec<crate::faults::FaultWindow>,
}

impl Schedule {
    /// Extract from an eager trace + its Phase-2 replay results.
    ///
    /// Single-timeline traces only: multi-device traces
    /// (tensor-parallel SPMD) and multi-stream traces (expert-parallel)
    /// interleave several concurrent timelines, which a serial-host /
    /// single-FIFO replay would silently serialize into a bogus
    /// baseline — they are rejected instead (replay them at the engine
    /// level via `sim::parallel`).
    pub fn from_eager_trace(trace: &Trace, p2: &Phase2Result) -> anyhow::Result<Schedule> {
        crate::taxbreak::phase1::validate_trace(trace)?;
        let devices = 1 + trace.events.iter().map(|e| e.device_id()).max().unwrap_or(0) as usize;
        let streams = 1 + trace
            .events
            .iter()
            .filter_map(|e| match e.track {
                Track::Device(s) => Some(s),
                Track::Host => None,
            })
            .max()
            .unwrap_or(0) as usize;
        anyhow::ensure!(
            devices == 1 && streams == 1,
            "eager schedule extraction requires a single-device, single-stream \
             trace, but this one spans {devices} device(s) x {streams} stream(s); \
             concurrent eager timelines do not replay on a serial schedule \
             (replay them at the engine level via `sim::parallel`). Serving \
             captures of any topology replay deterministically via \
             `taxbreak replay <trace>`."
        );
        let chains = trace.correlation_chains();
        let mut ids: Vec<u64> = chains
            .iter()
            .filter(|(_, c)| c.kernel.is_some_and(|k| k.meta.is_some()))
            .map(|(&id, _)| id)
            .collect();
        ids.sort();

        let floor_hint = p2.floor.mean.max(0.0);
        let mut steps = Vec::with_capacity(ids.len());
        let mut prev_api_end = 0.0f64;
        let mut prev_kernel_end = 0.0f64;
        for id in ids {
            let c = &chains[&id];
            let (torch, aten, api, kernel) =
                match (c.torch_op, c.aten_op, c.runtime_api, c.kernel) {
                    (Some(t), Some(a), Some(r), Some(k)) => (t, a, r, k),
                    // validate_trace guarantees api+kernel; chains that
                    // still lack a host op (partial traces) are skipped.
                    _ => continue,
                };
            let meta = kernel.meta.as_ref().expect("filtered for meta");

            let gap = torch.ts_us - prev_api_end;
            let synced = gap > SYNC_EPS_US;
            // A synced gap contains the wait for the device to drain;
            // only the remainder is host think time.
            let pre_host = if synced {
                (torch.ts_us - prev_api_end.max(prev_kernel_end)).max(0.0)
            } else {
                gap.max(0.0)
            };

            let t_py = (aten.ts_us - torch.ts_us).max(0.0);
            let t_dispatch = (api.ts_us - aten.ts_us).max(0.0);
            let key = meta.dedup();
            let t_ct = if meta.lib_mediated {
                p2.replay_of(key)
                    .map(|k| k.dct_us)
                    .unwrap_or(0.0)
                    .min(t_dispatch)
            } else {
                0.0
            };

            // Empty-queue launch gap. When the kernel queued behind the
            // previous one its true gap is censored (start == previous
            // end); fall back to the Phase-2 isolation measurement.
            let gap_obs = (kernel.ts_us - api.ts_us).max(0.0);
            let queued = prev_kernel_end > api.ts_us
                && (kernel.ts_us - prev_kernel_end).abs() < 1e-9;
            let (floor, excess) = if queued {
                let iso = p2
                    .replay_of(key)
                    .map(|k| (k.t_launch.mean - floor_hint).max(0.0))
                    .unwrap_or(0.0);
                (floor_hint.min(gap_obs), iso)
            } else {
                let f = gap_obs.min(floor_hint);
                (f, gap_obs - f)
            };

            steps.push(Step {
                name: meta.kernel_name.to_string(),
                family: meta.family.to_string(),
                dedup_key: meta.dedup_key(),
                lib_mediated: meta.lib_mediated,
                synced,
                pre_host_us: pre_host,
                t_py_us: t_py,
                t_base_us: (t_dispatch - t_ct).max(0.0),
                t_ct_us: t_ct,
                api_us: api.dur_us,
                floor_us: floor,
                excess_us: excess,
                device_us: kernel.dur_us,
                flops: meta.flops,
                bytes: meta.bytes,
                graphed: false,
                device: 0,
                stream: 0,
                ts_us: torch.ts_us,
            });
            prev_api_end = api.end_us();
            prev_kernel_end = prev_kernel_end.max(kernel.end_us());
        }

        let tail = (trace.e2e_us() - prev_api_end.max(prev_kernel_end)).max(0.0);
        Ok(Schedule {
            mode: ScheduleMode::Eager,
            platform: trace.meta.platform.clone(),
            model: trace.meta.model.clone(),
            phase: trace.meta.phase.clone(),
            steps,
            tail_host_us: tail,
            baseline_st_speed: crate::hardware::baseline_st_speed(&trace.meta.platform),
            floor_hint_us: floor_hint,
            devices: 1,
            streams_per_device: 1,
            fault_windows: Vec::new(),
        })
    }

    /// Extract from a captured serving run (`phase == "serve"`): every
    /// invocation is host-blocking, inter-chain gaps are arrival idle.
    ///
    /// Any `taxbreak loadgen --capture` output works, including merged
    /// multi-replica / multi-stream captures: replicas carry disjoint
    /// correlation-id ranges and `device` stamps, so each chain is
    /// attributed to its replica's independent clock (per-device
    /// `prev_end`), and the schedule records the topology the
    /// re-simulation must rebuild. Spec-v3 recording events (`arrival`,
    /// `rng_draw`, ...) carry correlation id 0 and never form chains,
    /// so they pass through extraction untouched.
    pub fn from_serving_trace(trace: &Trace) -> anyhow::Result<Schedule> {
        crate::taxbreak::phase1::validate_trace(trace)?;
        let chains = trace.correlation_chains();
        let mut ids: Vec<u64> = chains
            .iter()
            .filter(|(_, c)| c.kernel.is_some_and(|k| k.meta.is_some()))
            .map(|(&id, _)| id)
            .collect();
        // Replica correlation ranges are offset by 1e9 per device, so
        // the sorted order groups replicas and stays chronological
        // within each.
        ids.sort();

        let mut steps = Vec::with_capacity(ids.len());
        let mut prev_end: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();
        let mut devices = 1usize;
        let mut streams = 1usize;
        for id in ids {
            let c = &chains[&id];
            let (torch, kernel) = match (c.torch_op, c.kernel) {
                (Some(t), Some(k)) => (t, k),
                _ => continue,
            };
            let meta = kernel.meta.as_ref().expect("filtered for meta");
            let prep = c.aten_op.map(|a| a.dur_us).unwrap_or(0.0);
            let exec = c.runtime_api.map(|r| r.dur_us).unwrap_or(0.0);
            let device = kernel.device_id();
            let stream = match kernel.track {
                Track::Device(s) => s,
                Track::Host => 0,
            };
            devices = devices.max(device as usize + 1);
            streams = streams.max(stream as usize + 1);
            let prev = prev_end.entry(device).or_insert(0.0);
            steps.push(Step {
                name: meta.kernel_name.to_string(),
                family: meta.family.to_string(),
                dedup_key: meta.dedup_key(),
                lib_mediated: meta.lib_mediated,
                synced: true,
                pre_host_us: (torch.ts_us - *prev).max(0.0),
                t_py_us: 0.0,
                t_base_us: prep,
                t_ct_us: 0.0,
                api_us: exec,
                floor_us: 0.0,
                excess_us: 0.0,
                device_us: kernel.dur_us,
                flops: meta.flops,
                bytes: meta.bytes,
                graphed: false,
                device,
                stream,
                ts_us: torch.ts_us,
            });
            *prev = kernel.end_us();
        }
        let last = prev_end.values().fold(0.0f64, |a, &b| a.max(b));
        let tail = (trace.e2e_us() - last).max(0.0);

        // Fault windows ride corr id 0 and never form chains, so they
        // are collected straight off the event stream. Every replica's
        // engine records the same armed plan; keep one replica's list
        // (the lowest device id) so overlapping-window factor products
        // are not double-counted across replicas.
        let mut by_dev: std::collections::BTreeMap<u32, Vec<crate::faults::FaultWindow>> =
            std::collections::BTreeMap::new();
        for e in &trace.events {
            if let (EventKind::Fault, Some(crate::trace::ReplayArgs::Fault {
                kind,
                target,
                onset_us,
                dur_us,
                magnitude,
            })) = (&e.kind, &e.args)
            {
                by_dev.entry(e.device_id()).or_default().push(crate::faults::FaultWindow {
                    kind: crate::faults::FaultKind::parse(kind)?,
                    target: target.clone(),
                    onset_us: *onset_us,
                    dur_us: *dur_us,
                    magnitude: *magnitude,
                });
            }
        }
        let fault_windows = by_dev.into_values().next().unwrap_or_default();

        Ok(Schedule {
            mode: ScheduleMode::Synchronous,
            platform: trace.meta.platform.clone(),
            model: trace.meta.model.clone(),
            phase: trace.meta.phase.clone(),
            steps,
            tail_host_us: tail,
            baseline_st_speed: crate::hardware::baseline_st_speed(&trace.meta.platform),
            floor_hint_us: 0.0,
            devices,
            streams_per_device: streams,
            fault_windows,
        })
    }
}

/// Aggregate prediction of one re-simulated schedule, in the Eq. 1-3
/// vocabulary so baseline and counterfactual rows compare directly.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Outcome {
    /// Re-derived wall-clock.
    pub e2e_us: f64,
    pub device_active_us: f64,
    pub n_kernels: usize,
    /// Σ T_Py.
    pub t_py_us: f64,
    /// Σ dispatch net of ΔCT.
    pub t_base_us: f64,
    /// Σ I_lib·ΔCT.
    pub dct_us: f64,
    /// Σ launch-floor charges (collapses under CUDA-graph amortization).
    pub dkt_us: f64,
}

impl Outcome {
    /// ΔFT.
    pub fn dft_us(&self) -> f64 {
        self.t_py_us + self.t_base_us
    }

    /// Eq. 2 over the (counterfactual) run.
    pub fn orchestration_us(&self) -> f64 {
        self.dft_us() + self.dct_us + self.dkt_us
    }

    /// Eq. 3 via the shared [`hdbi_of`] convention.
    pub fn hdbi(&self) -> f64 {
        hdbi_of(self.orchestration_us(), self.device_active_us)
    }

    /// Relative reduction of `f(self)` vs `f(baseline)` (0 when the
    /// baseline quantity vanishes).
    pub fn reduction_vs(&self, baseline: &Outcome, f: impl Fn(&Outcome) -> f64) -> f64 {
        let b = f(baseline);
        if b <= 0.0 {
            0.0
        } else {
            1.0 - f(self) / b
        }
    }
}

/// Re-simulate a schedule; optionally record a synthetic trace (host
/// span + kernel span per step) for Chrome-timeline export.
///
/// The timeline is the shared discrete-event engine
/// ([`timeline::Engine`]) on the schedule's own topology — one host
/// thread per replica device, the identical host-cursor/stream-FIFO
/// semantics the recording ran on, so identity replay stays exact by
/// construction. The re-derived wall is the slowest replica's
/// (matching the recording's merge convention); single-timeline
/// schedules degenerate to the old serial behavior bit-for-bit.
pub fn resimulate_with_trace(s: &Schedule, record: bool) -> (Outcome, Option<Trace>) {
    let mut out = Outcome::default();
    let mut events: Vec<TraceEvent> = Vec::new();
    let devices = s.devices.max(1);
    let mut tl = timeline::Engine::new(timeline::Topology {
        devices,
        streams_per_device: s.streams_per_device.max(1),
        host_threads: devices,
    });
    let mut corr = 0u64;

    for step in &s.steps {
        let tid = step.device as usize;
        let sref = StreamRef {
            device: step.device,
            stream: step.stream,
        };
        if step.synced {
            tl.host_wait_until(tid, tl.device_sync_point(step.device));
        }
        tl.host_advance(tid, step.pre_host_us);
        // Segment-wise advances preserve the pre-engine cursor chain
        // `((t + py) + base) + ct` bit-for-bit (identity fidelity).
        let (torch_ts, _) = tl.host_advance(tid, step.t_py_us);
        tl.host_advance(tid, step.t_base_us);
        let (_, api_ts) = tl.host_advance(tid, step.t_ct_us);
        let (_, api_end) = tl.host_advance(tid, step.api_us);
        let timing = match s.mode {
            ScheduleMode::Eager => tl.submit(
                sref,
                api_ts,
                step.floor_us + step.excess_us,
                step.device_us,
            ),
            ScheduleMode::Synchronous => {
                // Host blocks through the device computation.
                let timing = tl.submit(
                    sref,
                    api_end.max(tl.device_sync_point(step.device)),
                    step.floor_us + step.excess_us,
                    step.device_us,
                );
                tl.host_wait_until(tid, timing.end_us);
                timing
            }
        };
        out.n_kernels += 1;
        out.device_active_us += step.device_us;
        out.t_py_us += step.t_py_us;
        out.t_base_us += step.t_base_us;
        out.dct_us += step.t_ct_us;
        out.dkt_us += step.floor_us;
        if record {
            corr += 1;
            let stamp = (step.device != 0).then_some(step.device);
            events.push(TraceEvent {
                kind: EventKind::TorchOp,
                name: format!("whatif.{}", step.name),
                ts_us: torch_ts,
                dur_us: api_end - torch_ts,
                correlation_id: corr,
                track: Track::Host,
                device: stamp,
                args: None,
                meta: None,
            });
            events.push(TraceEvent {
                kind: EventKind::Kernel,
                name: step.name.clone(),
                ts_us: timing.start_us,
                dur_us: step.device_us,
                correlation_id: corr,
                track: Track::Device(step.stream),
                device: stamp,
                args: None,
                meta: Some(KernelMeta {
                    kernel_name: step.name.as_str().into(),
                    family: step.family.as_str().into(),
                    aten_op: "".into(),
                    shapes_key: "".into(),
                    grid: [1, 1, 1],
                    block: [1, 1, 1],
                    lib_mediated: step.lib_mediated,
                    flops: step.flops,
                    bytes: step.bytes,
                }),
            });
        }
    }
    // Every replica drains, then the slowest one carries the trailing
    // host time — the recording's merge convention (wall = max).
    for d in 0..devices {
        tl.host_wait_until(d, tl.device_sync_point(d as u32));
    }
    let end = (0..devices)
        .map(|d| tl.host_now(d))
        .fold(0.0f64, f64::max)
        .max(tl.sync_point());
    out.e2e_us = end + s.tail_host_us;

    let trace = record.then(|| {
        let mut tr = Trace::new(crate::trace::TraceMeta {
            platform: s.platform.clone(),
            model: s.model.clone(),
            phase: s.phase.clone(),
            batch: 0,
            seq: 0,
            m_tokens: 0,
            wall_us: out.e2e_us,
        });
        tr.events = events;
        tr
    });
    (out, trace)
}

/// Re-simulate without event recording (the hot path).
pub fn resimulate(s: &Schedule) -> Outcome {
    resimulate_with_trace(s, false).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Platform;
    use crate::models;
    use crate::sim::{simulate, Workload};
    use crate::taxbreak::phase2::{run, ReplayConfig, SimReplayBackend};
    use crate::taxbreak::Phase1;

    fn schedule_for(model: &models::ModelSpec, wl: &Workload) -> (crate::trace::Trace, Schedule) {
        let platform = Platform::h100();
        let trace = simulate(model, &platform, wl, 11);
        let p1 = Phase1::from_trace(&trace);
        let mut backend = SimReplayBackend::new(platform, 13);
        let p2 = run(&p1.db, &mut backend, &ReplayConfig::fast());
        let s = Schedule::from_eager_trace(&trace, &p2).unwrap();
        (trace, s)
    }

    #[test]
    fn identity_resim_reproduces_the_recorded_wall() {
        for (model, wl) in [
            (models::gpt2(), Workload::prefill(1, 128)),
            (models::gpt2(), Workload::decode(1, 64, 3)),
            (models::llama_1b(), Workload::prefill(4, 256)),
        ] {
            let (trace, s) = schedule_for(&model, &wl);
            let out = resimulate(&s);
            let rel = (out.e2e_us - trace.meta.wall_us).abs() / trace.meta.wall_us;
            assert!(
                rel < 1e-3,
                "{} identity replay drifted: {} vs {} ({rel})",
                model.name,
                out.e2e_us,
                trace.meta.wall_us
            );
            assert_eq!(out.n_kernels, trace.kernel_count());
            assert!(
                (out.device_active_us - trace.device_active_us()).abs()
                    < 1e-6 * trace.device_active_us()
            );
        }
    }

    #[test]
    fn pass_boundaries_are_detected() {
        let (_, s) = schedule_for(&models::gpt2(), &Workload::decode(1, 64, 4));
        // 1 prefill + 3 decode steps => 4 synced pass starts.
        let synced = s.steps.iter().filter(|st| st.synced).count();
        assert_eq!(synced, 4, "one synced step per pass");
        // Mid-pass steps carry no host residual.
        for st in s.steps.iter().filter(|st| !st.synced) {
            assert!(st.pre_host_us.abs() < SYNC_EPS_US);
        }
    }

    #[test]
    fn extraction_splits_the_gap_into_floor_and_excess() {
        let (_, s) = schedule_for(&models::gpt2(), &Workload::prefill(1, 128));
        for st in &s.steps {
            assert!(st.floor_us >= 0.0 && st.floor_us <= s.floor_hint_us + 1e-9);
            assert!(st.excess_us >= 0.0);
            assert!(st.device_us > 0.0);
        }
        assert!(s.steps.iter().any(|st| st.excess_us > 0.0));
    }

    #[test]
    fn serving_trace_extracts_synchronously() {
        use crate::runtime::backend::Backend;
        use crate::serving::ModelBackend;
        let mut e = crate::runtime::SimEngine::with_defaults(
            models::gpt2(),
            Platform::h200(),
            5,
        );
        let (next, cache) = e.prefill_group(&[vec![1, 2, 3]]).unwrap();
        let _ = e.decode_group(cache, 3, &next).unwrap();
        let trace = e.take_trace();
        let s = Schedule::from_serving_trace(&trace).unwrap();
        assert_eq!(s.mode, ScheduleMode::Synchronous);
        assert_eq!(s.steps.len(), 2);
        let out = resimulate(&s);
        let rel = (out.e2e_us - trace.meta.wall_us).abs() / trace.meta.wall_us;
        assert!(rel < 1e-9, "synchronous identity replay must be exact: {rel}");
    }

    #[test]
    fn empty_trace_is_rejected() {
        let trace = crate::trace::Trace::default();
        assert!(Schedule::from_serving_trace(&trace).is_err());
    }

    #[test]
    fn multi_stream_and_multi_device_eager_traces_are_rejected() {
        // Expert-parallel trace: kernels overlap across streams — a
        // serial replay would mis-derive the baseline.
        let ep = crate::sim::simulate_expert_parallel(
            &models::olmoe(),
            &Platform::h100(),
            &Workload::decode(1, 64, 2),
            4,
            3,
        )
        .unwrap();
        let p1 = crate::taxbreak::Phase1::from_trace(&ep);
        let mut backend = SimReplayBackend::new(Platform::h100(), 5);
        let p2 = run(&p1.db, &mut backend, &ReplayConfig::fast());
        let err = Schedule::from_eager_trace(&ep, &p2).unwrap_err();
        assert!(err.to_string().contains("single-device"), "{err}");
        // The rejection names the offending topology and the replay
        // path that does handle it.
        assert!(err.to_string().contains("stream(s)"), "{err}");
        assert!(err.to_string().contains("taxbreak replay"), "{err}");

        // Tensor-parallel trace: device-stamped SPMD ranks.
        let tp = crate::sim::simulate_tensor_parallel(
            &models::gpt2(),
            &Platform::h100(),
            &Workload::prefill(1, 32),
            2,
            3,
        )
        .unwrap();
        assert!(Schedule::from_eager_trace(&tp, &p2).is_err());
    }

    #[test]
    fn device_stamped_serving_traces_extract_and_replay_exactly() {
        // A device-stamped serving trace (one replica of a merged
        // `--devices N` capture): extraction attributes the chains to
        // the replica's own clock and identity replay runs on a
        // matching topology.
        let mut engine = crate::runtime::SimEngine::with_topology(
            models::gpt2(),
            Platform::h200(),
            5,
            1,
            1, // replica id 1 => events stamped device 1
        );
        use crate::runtime::backend::Backend;
        use crate::serving::ModelBackend;
        let (next, cache) = engine.prefill_group(&[vec![1, 2]]).unwrap();
        let _ = engine.decode_group(cache, 2, &next).unwrap();
        let trace = engine.take_trace();
        let s = Schedule::from_serving_trace(&trace).unwrap();
        assert_eq!(s.mode, ScheduleMode::Synchronous);
        assert_eq!(s.devices, 2, "device ids are preserved, not compacted");
        assert!(s.steps.iter().all(|st| st.device == 1));
        let out = resimulate(&s);
        let rel = (out.e2e_us - trace.meta.wall_us).abs() / trace.meta.wall_us;
        assert!(rel < 1e-9, "replica identity replay must be exact: {rel}");
    }

    #[test]
    fn merged_multi_replica_capture_extracts_and_replays_exactly() {
        // The previously-rejected case: a merged `loadgen --devices 2
        // --streams 2 --capture` trace goes straight into schedule
        // extraction, and identity re-simulation reproduces the merged
        // (slowest-replica) wall exactly.
        let cfg = crate::serving::LoadgenConfig {
            requests: 8,
            rate_per_s: 0.0,
            devices: 2,
            streams: 2,
            sched: crate::serving::SchedulerConfig { kv_pages: 64, ..Default::default() },
            capture: true,
            ..Default::default()
        };
        let report =
            crate::serving::run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg).unwrap();
        let trace = report.runs[0].trace.as_ref().unwrap();
        let s = Schedule::from_serving_trace(trace).unwrap();
        assert_eq!(s.mode, ScheduleMode::Synchronous);
        assert_eq!(s.devices, 2);
        assert_eq!(s.streams_per_device, 2);
        assert!(s.steps.iter().any(|st| st.device == 0));
        assert!(s.steps.iter().any(|st| st.device == 1));
        let out = resimulate(&s);
        assert_eq!(out.n_kernels, trace.kernel_count());
        let rel = (out.e2e_us - trace.meta.wall_us).abs() / trace.meta.wall_us;
        assert!(rel < 1e-9, "merged-capture identity replay must be exact: {rel}");
    }
}
