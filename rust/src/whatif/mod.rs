//! **Counterfactual replay** (`taxbreak whatif`, DESIGN.md §10): take a
//! recorded trace, apply a composable set of counterfactual transforms,
//! re-derive the schedule, and report predicted e2e / HDBI /
//! per-component deltas side-by-side with the baseline.
//!
//! This is what turns TaxBreak from a profiler into an advisor: the
//! paper's headline is *predictive* — a faster host CPU cuts
//! orchestration overhead by 10-29% and end-to-end latency by up to
//! 14%, and MoE workloads are where it matters — and those numbers fall
//! out of replaying the same schedule under the §VI single-thread model
//! rather than re-running hardware experiments.
//!
//! Pipeline:
//!
//! 1. [`schedule::Schedule`] extracts a replayable schedule from the
//!    trace (eager or captured-serving dialect);
//! 2. [`transforms`] edits it — host-CPU scaling, CUDA-graph
//!    amortization, library-dispatch elision, kernel fusion / MoE
//!    dispatch reduction, device swap, tensor-parallel sharding — in
//!    CLI composition order;
//! 3. [`schedule::resimulate`] re-derives the timeline (the serving
//!    decode-phase host-bound stalls shorten wall-clock correctly —
//!    nothing is "subtracted", the schedule is re-run);
//! 4. [`report`] renders the baseline row plus one row per composition
//!    prefix; [`quantify_diagnosis`] attaches the best counterfactual
//!    for the diagnosed [`OptimizationTarget`] to the diagnosis.

pub mod bundled;
pub mod report;
pub mod schedule;
pub mod transforms;

pub use schedule::{resimulate, Outcome, Schedule, ScheduleMode, Step};
pub use transforms::{parse_spec, parse_specs, Counterfactual};

use crate::taxbreak::{Analysis, OptimizationTarget, QuantifiedAdvice};

/// One composed scenario: the cumulative counterfactual after applying
/// a prefix of the spec list.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Spec applied at this stage (rows render as `+<label>`).
    pub label: String,
    pub outcome: Outcome,
}

/// Baseline + progressively composed counterfactual outcomes.
#[derive(Debug, Clone)]
pub struct WhatIf {
    pub platform: String,
    pub model: String,
    pub phase: String,
    pub baseline: Outcome,
    pub scenarios: Vec<Scenario>,
}

impl WhatIf {
    /// The fully composed (last) scenario.
    pub fn final_outcome(&self) -> &Outcome {
        self.scenarios
            .last()
            .map(|s| &s.outcome)
            .unwrap_or(&self.baseline)
    }
}

/// Apply `cfs` left to right, re-simulating after each stage; also
/// returns the final composed schedule (for Chrome export of the
/// counterfactual timeline).
///
/// The baseline row is the *identity replay* of the extracted schedule
/// (not the raw trace wall-clock) so every delta is measured within one
/// self-consistent model; identity fidelity is enforced by tests.
pub fn run_with_schedule(
    s: &Schedule,
    cfs: &[Box<dyn Counterfactual>],
) -> anyhow::Result<(WhatIf, Schedule)> {
    let baseline = schedule::resimulate(s);
    let mut cur = s.clone();
    let mut scenarios = Vec::with_capacity(cfs.len());
    for cf in cfs {
        cf.apply(&mut cur)?;
        scenarios.push(Scenario {
            label: cf.label(),
            outcome: schedule::resimulate(&cur),
        });
    }
    let report = WhatIf {
        platform: s.platform.clone(),
        model: s.model.clone(),
        phase: s.phase.clone(),
        baseline,
        scenarios,
    };
    Ok((report, cur))
}

/// [`run_with_schedule`] without the composed-schedule return.
pub fn run(s: &Schedule, cfs: &[Box<dyn Counterfactual>]) -> anyhow::Result<WhatIf> {
    run_with_schedule(s, cfs).map(|(report, _)| report)
}

/// Candidate counterfactual specs for one diagnosed target.
pub fn candidate_specs(target: OptimizationTarget, s: &Schedule) -> Vec<String> {
    match target {
        OptimizationTarget::SoftwareStack => {
            let mut v = vec![transforms::faster_host_spec(s.baseline_st_speed)];
            if s.steps.iter().any(|st| st.lib_mediated) {
                v.push("lib-elision".to_string());
            }
            v
        }
        OptimizationTarget::KernelFusion => {
            let mut v = vec!["fusion:elem".to_string()];
            if s.steps.iter().any(|st| st.name.contains("expert_")) {
                v.push("fusion:moe".to_string());
            }
            if s.mode == ScheduleMode::Eager {
                v.push("cuda-graphs".to_string());
            }
            v
        }
        OptimizationTarget::DeviceWork => {
            let other = if s.platform == "h100" { "h200" } else { "h100" };
            let mut v = vec![format!("device:{other}")];
            // Device-bound eager runs can also scale *out*: shard the
            // device work tensor-parallel (the quantifier keeps
            // whichever candidate predicts the larger e2e win).
            // Serving schedules are opaque executables — not shardable.
            if s.mode == ScheduleMode::Eager {
                v.push("tensor-parallel:2".to_string());
            }
            v
        }
    }
}

/// Attach the *quantified* best counterfactual for the diagnosed target
/// to the analysis (extends `taxbreak::diagnose` from a qualitative
/// prescription to a number): each candidate is applied alone to a
/// fresh copy of the schedule and the largest predicted e2e reduction
/// wins. A candidate that would *regress* end-to-end latency (e.g. a
/// device swap onto a slower-clocked GPU for a compute-bound run) is
/// never attached — no advice beats bad advice, and the diagnosis then
/// keeps its qualitative prescription only.
pub fn quantify_diagnosis(a: &mut Analysis, s: &Schedule) -> anyhow::Result<()> {
    let baseline = schedule::resimulate(s);
    let mut best: Option<QuantifiedAdvice> = None;
    for spec in candidate_specs(a.diagnosis.target, s) {
        let cf = transforms::parse_spec(&spec)?;
        let mut cur = s.clone();
        cf.apply(&mut cur)?;
        let out = schedule::resimulate(&cur);
        let advice = QuantifiedAdvice {
            counterfactual: cf.label(),
            orch_reduction: out.reduction_vs(&baseline, |o| o.orchestration_us()),
            e2e_reduction: out.reduction_vs(&baseline, |o| o.e2e_us),
        };
        if advice.e2e_reduction > 0.0
            && best
                .as_ref()
                .map(|b| advice.e2e_reduction > b.e2e_reduction)
                .unwrap_or(true)
        {
            best = Some(advice);
        }
    }
    a.diagnosis.quantified = best;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Platform;
    use crate::models;
    use crate::sim::{simulate, Workload};
    use crate::taxbreak::{analyze, ReplayConfig, SimReplayBackend};

    fn analysis_and_schedule(
        model: &models::ModelSpec,
        wl: &Workload,
    ) -> (Analysis, Schedule) {
        let platform = Platform::h100();
        let trace = simulate(model, &platform, wl, 19);
        let mut backend = SimReplayBackend::new(platform, 23);
        let a = analyze(&trace, &mut backend, &ReplayConfig::fast());
        let s = Schedule::from_eager_trace(&trace, &a.phase2).unwrap();
        (a, s)
    }

    #[test]
    fn composition_is_progressive() {
        // m=5: four graphed decode passes comfortably amortize the
        // one-time capture cost.
        let (_, s) = analysis_and_schedule(&models::gpt2(), &Workload::decode(1, 64, 5));
        let cfs = parse_specs(&[
            "host-cpu:xeon-6538y".to_string(),
            "cuda-graphs".to_string(),
        ])
        .unwrap();
        let w = run(&s, &cfs).unwrap();
        assert_eq!(w.scenarios.len(), 2);
        // Host scaling shrinks orchestration; graphs then collapse dKT
        // further on top of the already-scaled schedule.
        let o1 = &w.scenarios[0].outcome;
        let o2 = &w.scenarios[1].outcome;
        assert!(o1.orchestration_us() < w.baseline.orchestration_us());
        assert!(o2.dkt_us < 0.5 * o1.dkt_us);
        assert!(o2.e2e_us <= o1.e2e_us);
        assert_eq!(w.baseline.n_kernels, o1.n_kernels);
    }

    #[test]
    fn quantify_attaches_advice_for_the_diagnosed_target() {
        let (mut a, s) =
            analysis_and_schedule(&models::olmoe(), &Workload::decode(1, 64, 2));
        assert!(a.diagnosis.quantified.is_none());
        quantify_diagnosis(&mut a, &s).unwrap();
        let q = a.diagnosis.quantified.as_ref().expect("advice attached");
        assert!(q.orch_reduction > 0.0, "{q:?}");
        assert!(!q.counterfactual.is_empty());
        assert!(q.render().contains("T_Orchestration"));
    }

    #[test]
    fn candidates_follow_the_target() {
        let (_, s) = analysis_and_schedule(&models::olmoe(), &Workload::decode(1, 64, 2));
        let sw = candidate_specs(OptimizationTarget::SoftwareStack, &s);
        assert!(sw.iter().any(|c| c.starts_with("host-cpu:")));
        assert!(sw.iter().any(|c| c == "lib-elision"));
        let kf = candidate_specs(OptimizationTarget::KernelFusion, &s);
        assert!(kf.contains(&"fusion:moe".to_string()), "{kf:?}");
        let dw = candidate_specs(OptimizationTarget::DeviceWork, &s);
        assert_eq!(
            dw,
            vec!["device:h200".to_string(), "tensor-parallel:2".to_string()]
        );
    }
}
