//! Rendering of what-if reports: the side-by-side baseline vs
//! counterfactual table and the JSON export.

use crate::util::json::Json;
use crate::util::table::{ms, ratio, Table};
use crate::whatif::schedule::Outcome;
use crate::whatif::WhatIf;

fn delta_pct(cur: f64, base: f64) -> String {
    if base <= 0.0 {
        "-".to_string()
    } else {
        format!("{:+.1}%", 100.0 * (cur / base - 1.0))
    }
}

fn outcome_row(label: &str, o: &Outcome, base: &Outcome, is_base: bool) -> Vec<String> {
    let d = |cur: f64, b: f64| {
        if is_base {
            "-".to_string()
        } else {
            delta_pct(cur, b)
        }
    };
    vec![
        label.to_string(),
        ms(o.e2e_us / 1000.0),
        d(o.e2e_us, base.e2e_us),
        ms(o.dft_us() / 1000.0),
        ms(o.dct_us / 1000.0),
        ms(o.dkt_us / 1000.0),
        ms(o.orchestration_us() / 1000.0),
        d(o.orchestration_us(), base.orchestration_us()),
        ms(o.device_active_us / 1000.0),
        ratio(o.hdbi()),
    ]
}

/// Baseline + one row per composed counterfactual stage.
pub fn whatif_table(w: &WhatIf) -> Table {
    let title = format!(
        "what-if: {} {} on {} ({} kernels)",
        w.model, w.phase, w.platform, w.baseline.n_kernels
    );
    let mut t = Table::new(
        &title,
        &[
            "scenario", "e2e(ms)", "de2e", "dFT(ms)", "dCT(ms)", "dKT(ms)",
            "T_orch(ms)", "dorch", "T_dev(ms)", "HDBI",
        ],
    );
    t.row(outcome_row("baseline", &w.baseline, &w.baseline, true));
    for s in &w.scenarios {
        t.row(outcome_row(
            &format!("+{}", s.label),
            &s.outcome,
            &w.baseline,
            false,
        ));
    }
    t
}

fn outcome_json(o: &Outcome) -> Json {
    Json::obj()
        .with("e2e_us", o.e2e_us)
        .with("device_active_us", o.device_active_us)
        .with("n_kernels", o.n_kernels)
        .with("dft_us", o.dft_us())
        .with("dct_us", o.dct_us)
        .with("dkt_us", o.dkt_us)
        .with("orchestration_us", o.orchestration_us())
        .with("hdbi", o.hdbi())
}

/// JSON export (`taxbreak whatif --report`).
pub fn to_json(w: &WhatIf) -> Json {
    let base = &w.baseline;
    let mut scenarios: Vec<Json> = Vec::with_capacity(w.scenarios.len());
    for s in &w.scenarios {
        let o = &s.outcome;
        scenarios.push(
            outcome_json(o)
                .with("counterfactual", s.label.as_str())
                .with("e2e_reduction", o.reduction_vs(base, |x| x.e2e_us))
                .with(
                    "orch_reduction",
                    o.reduction_vs(base, |x| x.orchestration_us()),
                ),
        );
    }
    Json::obj()
        .with("platform", w.platform.as_str())
        .with("model", w.model.as_str())
        .with("phase", w.phase.as_str())
        .with("baseline", outcome_json(base))
        .with("scenarios", scenarios)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::whatif::Scenario;

    fn sample() -> WhatIf {
        let base = Outcome {
            e2e_us: 10_000.0,
            device_active_us: 3_000.0,
            n_kernels: 100,
            t_py_us: 1_000.0,
            t_base_us: 2_000.0,
            dct_us: 500.0,
            dkt_us: 470.0,
        };
        let cf = Outcome {
            e2e_us: 8_800.0,
            t_py_us: 769.2,
            t_base_us: 1_538.5,
            dct_us: 384.6,
            ..base
        };
        WhatIf {
            platform: "h100".to_string(),
            model: "gpt2".to_string(),
            phase: "decode".to_string(),
            baseline: base,
            scenarios: vec![Scenario {
                label: "host-cpu:xeon-6538y".to_string(),
                outcome: cf,
            }],
        }
    }

    #[test]
    fn table_renders_baseline_and_deltas() {
        let t = whatif_table(&sample());
        let out = t.render();
        assert!(out.contains("baseline"));
        assert!(out.contains("+host-cpu:xeon-6538y"));
        assert!(out.contains("-12.0%"), "e2e delta rendered:\n{out}");
        assert!(out.contains("HDBI"));
    }

    #[test]
    fn json_roundtrips_and_carries_reductions() {
        let j = to_json(&sample());
        let back = Json::parse(&j.pretty()).unwrap();
        let scenarios = back.arr_of("scenarios").unwrap();
        assert_eq!(scenarios.len(), 1);
        let e2e_red = scenarios[0].f64_of("e2e_reduction").unwrap();
        assert!((e2e_red - 0.12).abs() < 1e-9);
        assert!(scenarios[0].f64_of("orch_reduction").unwrap() > 0.0);
        assert_eq!(back.req("baseline").unwrap().usize_of("n_kernels").unwrap(), 100);
    }
}
