//! Bundled what-if workload points (`taxbreak whatif --bundled <name>`)
//! — the paper's diagnostic contrast, pinned by `rust/tests/whatif.rs`:
//!
//! * [`moe_decode`] — a host-bound MoE serving burst. The host-CPU
//!   counterfactual (H100 host → H200 host, 1.30x single-thread) must
//!   land its orchestration reduction in the paper's 10-29% band with
//!   an end-to-end improvement ≤ 14%.
//! * [`dense_prefill`] — a device-bound dense prefill. The same
//!   counterfactual must report a near-zero e2e delta: when HDBI says
//!   the device is the bottleneck, a faster host buys nothing.

use crate::config::RunConfig;
use crate::sim::Phase;

/// The paper's MoE serving shape (Table II: SL=2048, m=10) at a
/// serving batch on the H100 platform. Decode steps dominate the
/// schedule; prompt processing keeps the device honest — together the
/// point is host-bound (HDBI < 0.5) but not degenerate.
///
/// Phase-2 replay uses the reduced protocol: the bundled points back
/// CLI demos and regression tests, not Table III/IV reproduction.
pub fn moe_decode() -> RunConfig {
    RunConfig {
        model: "qwen1.5-moe-a2.7b".to_string(),
        platform: "h100".to_string(),
        phase: Phase::Decode,
        batch: 8,
        seq: 2048,
        m_tokens: 10,
        warmup: 2,
        runs: 20,
        ..RunConfig::default()
    }
}

/// Device-bound dense prefill (Llama-3.2-1B, BS=8, SL=2048 on H100):
/// the attention score matrix and the GEMMs keep the GPU saturated, so
/// host-side counterfactuals are predicted to buy ~nothing end-to-end.
pub fn dense_prefill() -> RunConfig {
    RunConfig {
        model: "llama-3.2-1b".to_string(),
        platform: "h100".to_string(),
        phase: Phase::Prefill,
        batch: 8,
        seq: 2048,
        m_tokens: 1,
        warmup: 2,
        runs: 20,
        ..RunConfig::default()
    }
}

/// Resolve a bundled point by CLI name.
pub fn by_name(name: &str) -> anyhow::Result<RunConfig> {
    match name {
        "moe-decode" => Ok(moe_decode()),
        "dense-prefill" => Ok(dense_prefill()),
        other => anyhow::bail!("unknown bundled workload '{other}' (moe-decode|dense-prefill)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bundled_points_resolve() {
        for name in ["moe-decode", "dense-prefill"] {
            let cfg = by_name(name).unwrap();
            assert!(cfg.model_spec().is_ok());
            assert!(cfg.platform_spec().is_ok());
        }
        assert!(by_name("tpu-sprint").is_err());
        assert!(moe_decode().model_spec().unwrap().is_moe());
        assert!(!dense_prefill().model_spec().unwrap().is_moe());
    }
}
