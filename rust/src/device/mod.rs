//! Device-side stream timeline: a FIFO CUDA stream — the per-stream
//! primitive composed into multi-stream/multi-device timelines by
//! [`crate::timeline::Engine`].
//!
//! Kernels start at `max(api_start + launch_gap, previous kernel end)`;
//! the second term is the queue delay that makes TKLQT blow up once the
//! GPU saturates (Fig. 7a) while the launch *floor* stays constant.

/// One in-order device stream.
#[derive(Debug, Clone, Default)]
pub struct Stream {
    /// Time the last-enqueued kernel finishes.
    cursor_us: f64,
    /// Total kernel-active time on this stream.
    active_us: f64,
    launched: usize,
}

/// Result of submitting one kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelTiming {
    pub start_us: f64,
    pub end_us: f64,
    /// start - api_start: launch gap + queue delay (the TKLQT per-kernel
    /// term of [30]).
    pub launch_plus_queue_us: f64,
    /// Queue-induced extra over the pure launch gap.
    pub queue_delay_us: f64,
}

impl Stream {
    pub fn new() -> Stream {
        Stream::default()
    }

    /// Submit a kernel launched at `api_start_us` with the sampled
    /// empty-queue launch gap and device duration.
    pub fn submit(&mut self, api_start_us: f64, launch_gap_us: f64, dur_us: f64) -> KernelTiming {
        let ready = api_start_us + launch_gap_us;
        self.submit_ready(api_start_us, ready, dur_us)
    }

    /// [`Stream::submit`] with an extra readiness floor `dep_us`: the
    /// kernel additionally waits for a cross-stream event (all-reduce
    /// join, producer on another stream). `dep_us = 0.0` is exactly
    /// `submit` (timestamps are non-negative).
    pub fn submit_dep(
        &mut self,
        api_start_us: f64,
        launch_gap_us: f64,
        dep_us: f64,
        dur_us: f64,
    ) -> KernelTiming {
        let ready = (api_start_us + launch_gap_us).max(dep_us);
        self.submit_ready(api_start_us, ready, dur_us)
    }

    fn submit_ready(&mut self, api_start_us: f64, ready: f64, dur_us: f64) -> KernelTiming {
        let start = ready.max(self.cursor_us);
        let end = start + dur_us;
        self.cursor_us = end;
        self.active_us += dur_us;
        self.launched += 1;
        KernelTiming {
            start_us: start,
            end_us: end,
            launch_plus_queue_us: start - api_start_us,
            queue_delay_us: start - ready,
        }
    }

    /// When the stream drains (cudaDeviceSynchronize).
    pub fn sync_point(&self) -> f64 {
        self.cursor_us
    }

    pub fn active_us(&self) -> f64 {
        self.active_us
    }

    pub fn launched(&self) -> usize {
        self.launched
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stream_starts_after_gap() {
        let mut s = Stream::new();
        let t = s.submit(10.0, 4.7, 2.0);
        assert_eq!(t.start_us, 14.7);
        assert_eq!(t.end_us, 16.7);
        assert!((t.launch_plus_queue_us - 4.7).abs() < 1e-12);
        assert_eq!(t.queue_delay_us, 0.0);
    }

    #[test]
    fn busy_stream_queues() {
        let mut s = Stream::new();
        s.submit(0.0, 4.7, 100.0); // ends at 104.7
        let t = s.submit(10.0, 4.7, 5.0);
        assert_eq!(t.start_us, 104.7);
        assert!((t.queue_delay_us - 90.0).abs() < 1e-9);
        assert!((t.launch_plus_queue_us - 94.7).abs() < 1e-9);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut s = Stream::new();
        let a = s.submit(0.0, 1.0, 10.0);
        let b = s.submit(0.0, 1.0, 10.0);
        assert!(b.start_us >= a.end_us);
    }

    #[test]
    fn accounting() {
        let mut s = Stream::new();
        s.submit(0.0, 1.0, 3.0);
        s.submit(0.0, 1.0, 4.0);
        assert_eq!(s.active_us(), 7.0);
        assert_eq!(s.launched(), 2);
        assert_eq!(s.sync_point(), 8.0);
    }

    #[test]
    fn submit_dep_waits_for_the_event() {
        let mut s = Stream::new();
        // Dependency beyond the launch gap dominates readiness.
        let t = s.submit_dep(0.0, 4.7, 20.0, 2.0);
        assert_eq!(t.start_us, 20.0);
        assert_eq!(t.queue_delay_us, 0.0);
        assert!((t.launch_plus_queue_us - 20.0).abs() < 1e-12);
        // A zero dependency reproduces submit exactly.
        let mut a = Stream::new();
        let mut b = Stream::new();
        let x = a.submit(3.0, 1.5, 2.0);
        let y = b.submit_dep(3.0, 1.5, 0.0, 2.0);
        assert_eq!(x, y);
    }

    #[test]
    fn idle_gap_when_host_is_slow() {
        // Host-bound regime: kernels finish before the next is
        // submitted, so the GPU sits idle between them.
        let mut s = Stream::new();
        let a = s.submit(0.0, 4.7, 1.0); // ends 5.7
        let b = s.submit(20.0, 4.7, 1.0); // starts 24.7 — 19 us idle
        assert!(b.start_us - a.end_us > 18.0);
        assert_eq!(b.queue_delay_us, 0.0);
    }
}
