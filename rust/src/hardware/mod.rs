//! Hardware platform specifications.
//!
//! A [`Platform`] couples a GPU with a host CPU — the paper's central
//! cross-platform variable (§VI): both eval systems use Hopper GPUs but
//! different host CPUs, letting CPU single-thread speed be isolated.
//!
//! Calibration constants come from the paper's own measurements
//! (DESIGN.md §7): null-kernel floors from Table III, GPU clocks from
//! §VI, host-speed ratio set so H200-host orchestration lands 10-29%
//! below H100-host.

/// GPU device model parameters for the analytic cost model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// SM clock in MHz (paper §VI: H100 1980, H200 1785 — H200 is the
    /// *slower-clocked* GPU, which makes the CPU result non-trivial).
    pub clock_mhz: f64,
    /// Peak dense BF16 throughput at the reference clock, TFLOP/s.
    pub peak_tflops_bf16: f64,
    /// HBM bandwidth, GB/s (H100 HBM3 3350; H200 HBM3e 4800).
    pub hbm_gbps: f64,
    /// Null-kernel launch floor `T_sys^floor` mean, us (Table III).
    pub t_sys_floor_us: f64,
    /// Lognormal sigma of per-launch floor jitter (Table III p5..p95
    /// spread is ±5% around the mean).
    pub floor_sigma: f64,
}

impl GpuSpec {
    /// Effective compute throughput in FLOP/us, scaled by clock.
    pub fn flops_per_us(&self) -> f64 {
        self.peak_tflops_bf16 * 1e12 / 1e6
    }

    /// Bytes per microsecond of HBM bandwidth.
    pub fn bytes_per_us(&self) -> f64 {
        self.hbm_gbps * 1e9 / 1e6
    }
}

/// Host CPU parameters. Eager-mode dispatch is single-threaded (§I), so
/// the model needs only single-thread speed; core count is recorded for
/// documentation parity with the paper's 6-core allocations.
#[derive(Debug, Clone, PartialEq)]
pub struct CpuSpec {
    pub name: String,
    /// Relative single-thread speed; the H100 host (Xeon 8480C,
    /// Sapphire Rapids) is the 1.0 reference. All host-side latency
    /// components divide by this.
    pub st_speed: f64,
    pub cores_allocated: usize,
}

/// A (GPU, CPU) pairing under test.
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    pub name: String,
    pub gpu: GpuSpec,
    pub cpu: CpuSpec,
}

impl Platform {
    /// DGX H100: H100-80GB + Intel Xeon 8480C (Sapphire Rapids).
    pub fn h100() -> Platform {
        Platform {
            name: "h100".to_string(),
            gpu: GpuSpec {
                name: "NVIDIA H100 80GB".to_string(),
                clock_mhz: 1980.0,
                peak_tflops_bf16: 989.0,
                hbm_gbps: 3350.0,
                // Table III: H100 floor ~4.7 us (p5 4.26); Table IV's
                // in-context replay floor is 4.75.
                t_sys_floor_us: 4.72,
                floor_sigma: 0.045,
            },
            cpu: CpuSpec {
                name: "Intel Xeon 8480C (2.0/3.8 GHz)".to_string(),
                st_speed: 1.0,
                cores_allocated: 6,
            },
        }
    }

    /// H200 NVL + Intel Xeon Gold 6538Y+ (Emerald Rapids).
    pub fn h200() -> Platform {
        Platform {
            name: "h200".to_string(),
            gpu: GpuSpec {
                name: "NVIDIA H200 NVL 141GB".to_string(),
                // -9.9% vs H100 (paper §VI) — compute-bound kernels run
                // slower on H200.
                clock_mhz: 1785.0,
                peak_tflops_bf16: 989.0 * 1785.0 / 1980.0,
                // H200 NVL's *peak* HBM3e is 4.8 TB/s, but the paper
                // measures T_DeviceActive as comparable across the two
                // systems ("ruling out GPU memory bandwidth as the
                // source of improvement", §VI) — the achieved bandwidth
                // on these kernel mixes, which is what the cost model
                // consumes, is calibrated to that observation.
                hbm_gbps: 3450.0,
                // Table III: avg 4.503, p50 4.452, p5 4.177, p95 4.909.
                t_sys_floor_us: 4.503,
                floor_sigma: 0.05,
            },
            cpu: CpuSpec {
                name: "Intel Xeon Gold 6538Y+ (2.2/4.0 GHz)".to_string(),
                // Calibrated: puts T_Orchestration 10-29% below the
                // H100 host across the Fig. 10 sweep (DESIGN.md §7).
                st_speed: 1.30,
                cores_allocated: 6,
            },
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Platform> {
        match name {
            "h100" => Ok(Platform::h100()),
            "h200" => Ok(Platform::h200()),
            other => anyhow::bail!("unknown platform '{other}' (expected h100|h200)"),
        }
    }

    pub fn all() -> Vec<Platform> {
        vec![Platform::h100(), Platform::h200()]
    }
}

/// NVLink-generation inter-GPU bandwidth, GB/s per direction (Hopper
/// NVLink4: 900 GB/s aggregate) — the bandwidth term of the
/// tensor-parallel all-reduce model (`sim::parallel`,
/// `whatif` `tensor-parallel:<N>`).
pub const NVLINK_GBPS: f64 = 900.0;

/// Per-hop latency of a ring all-reduce step, us (NCCL small-message
/// launch + SM hand-off; latency-dominated for decode activations).
pub const ALLREDUCE_HOP_US: f64 = 3.0;

/// Single-thread speed of the host CPU recorded for `platform`, on the
/// same scale as [`CpuSpec::st_speed`] / [`HostProfile::st_speed`]
/// (H100 host = 1.0). Platforms outside the catalog fall back to the
/// reference `1.0` — the **single** baseline-speed lookup used by the
/// what-if engine (schedule extraction, host-CPU rescaling); it
/// returns exactly the `HostProfile` catalog's factors because the
/// `Platform` presets share them (pinned by a test below).
pub fn baseline_st_speed(platform: &str) -> f64 {
    Platform::by_name(platform)
        .map(|p| p.cpu.st_speed)
        .unwrap_or(1.0)
}

/// A named host-CPU profile for counterfactual replay (`taxbreak
/// whatif --counterfactual host-cpu:<name>`): the paper's §VI pairing
/// plus one documented extrapolation point.
///
/// Profiles carry the same single-thread-speed scale as
/// [`CpuSpec::st_speed`]; the what-if engine rescales every CPU-bound
/// Eq. 1 component by `profile.st_speed / baseline.st_speed`.
#[derive(Debug, Clone, PartialEq)]
pub struct HostProfile {
    /// Stable CLI name ("xeon-8480c").
    pub name: &'static str,
    /// Human CPU description.
    pub cpu: &'static str,
    /// Relative single-thread speed (H100 host = 1.0 reference).
    pub st_speed: f64,
    /// Where the number comes from.
    pub note: &'static str,
}

impl HostProfile {
    /// All named host profiles.
    pub fn all() -> Vec<HostProfile> {
        vec![
            HostProfile {
                name: "xeon-8480c",
                cpu: "Intel Xeon 8480C (Sapphire Rapids, H100 host)",
                st_speed: 1.0,
                note: "paper §VI reference host",
            },
            HostProfile {
                name: "xeon-6538y",
                cpu: "Intel Xeon Gold 6538Y+ (Emerald Rapids, H200 host)",
                st_speed: 1.30,
                note: "calibrated to the paper's 10-29% orchestration band",
            },
            HostProfile {
                name: "hypothetical-2x",
                cpu: "hypothetical 2x-single-thread host",
                st_speed: 2.0,
                note: "extrapolation beyond the paper's measured pair",
            },
        ]
    }

    pub fn by_name(name: &str) -> anyhow::Result<HostProfile> {
        HostProfile::all()
            .into_iter()
            .find(|p| p.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown host profile '{name}' (expected one of: {})",
                    HostProfile::all()
                        .iter()
                        .map(|p| p.name)
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h200_gpu_is_slower_clocked() {
        let (a, b) = (Platform::h100(), Platform::h200());
        assert!(b.gpu.clock_mhz < a.gpu.clock_mhz);
        let ratio = b.gpu.clock_mhz / a.gpu.clock_mhz;
        assert!((ratio - 0.901).abs() < 0.01, "paper: -9.9%");
    }

    #[test]
    fn h200_cpu_is_faster() {
        assert!(Platform::h200().cpu.st_speed > Platform::h100().cpu.st_speed);
    }

    #[test]
    fn h200_has_more_bandwidth() {
        assert!(Platform::h200().gpu.hbm_gbps > Platform::h100().gpu.hbm_gbps);
    }

    #[test]
    fn floors_match_table3() {
        assert!((Platform::h100().gpu.t_sys_floor_us - 4.72).abs() < 0.01);
        assert!((Platform::h200().gpu.t_sys_floor_us - 4.503).abs() < 0.01);
    }

    #[test]
    fn by_name_roundtrip() {
        for p in Platform::all() {
            assert_eq!(Platform::by_name(&p.name).unwrap(), p);
        }
        assert!(Platform::by_name("b200").is_err());
    }

    #[test]
    fn host_profiles_cover_the_paper_pairing() {
        let h100 = HostProfile::by_name("xeon-8480c").unwrap();
        let h200 = HostProfile::by_name("xeon-6538y").unwrap();
        assert_eq!(h100.st_speed, Platform::h100().cpu.st_speed);
        assert_eq!(h200.st_speed, Platform::h200().cpu.st_speed);
        assert!(HostProfile::by_name("epyc-9999").is_err());
        for p in HostProfile::all() {
            assert_eq!(HostProfile::by_name(p.name).unwrap(), p);
        }
    }

    #[test]
    fn baseline_st_speed_matches_the_profile_catalog() {
        // The lookup must agree with the HostProfile factors for the
        // paper's pairing (this is the dedup contract: one source of
        // single-thread truth).
        assert_eq!(
            baseline_st_speed("h100"),
            HostProfile::by_name("xeon-8480c").unwrap().st_speed
        );
        assert_eq!(
            baseline_st_speed("h200"),
            HostProfile::by_name("xeon-6538y").unwrap().st_speed
        );
        // Unknown platforms (pjrt-cpu, test stubs) use the reference.
        assert_eq!(baseline_st_speed("pjrt-cpu"), 1.0);
    }

    #[test]
    fn unit_conversions() {
        let g = Platform::h100().gpu;
        assert!((g.flops_per_us() - 989.0e6).abs() < 1.0);
        assert!((g.bytes_per_us() - 3.35e6).abs() < 1e3);
    }
}
