//! Model architecture descriptors + the paper's workload catalog (§IV-C).
//!
//! The host-side claims of the paper depend on the *kernel launch
//! sequence* each model's eager forward pass emits, not on weights
//! (DESIGN.md §2).  A [`ModelSpec`] carries the architectural dimensions
//! (for FLOPs/bytes) plus the eager-implementation calibration constants
//! that set per-layer kernel counts, calibrated to the paper's Table II.

/// MoE-specific architecture parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MoeSpec {
    pub n_experts: usize,
    pub top_k: usize,
    /// Always-active shared experts (Qwen1.5-MoE has 4).
    pub shared_experts: usize,
    pub expert_hidden: usize,
    /// Kernels dispatched per expert iteration in eager prefill
    /// (HF-style loop over ALL experts: index bookkeeping + 3 GEMMs +
    /// activation + combine). Calibrated to Table II / §V-A counts.
    pub expert_kernels_prefill: usize,
    /// Same for one decode step.
    pub expert_kernels_decode: usize,
    /// Router block kernels per layer (gate GEMM, softmax, top-k,
    /// one-hot/mask builds).
    pub router_kernels: usize,
}

/// Which path GEMMs take (determines `I_lib`, §III-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmLib {
    /// cuBLAS/cuBLASLt — library-mediated, ΔCT > 0.
    Cublas,
    /// Framework-native nvjet/gemv2T (GPT-2's observed path, ΔCT = 0).
    Nvjet,
}

/// Architecture descriptor of one catalog model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Machine id ("llama-3.2-1b").
    pub name: String,
    /// Paper display name ("Llama-3.2-1B").
    pub display: String,
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    /// KV heads (GQA); == n_heads when MHA.
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// Dense-FFN hidden size (MoE models: the shared/dense fallback).
    pub ffn_hidden: usize,
    pub vocab: usize,
    pub moe: Option<MoeSpec>,
    pub gemm_lib: GemmLib,
    /// Extra eager-mode glue kernels per layer (mask building, rope
    /// trig, contiguity copies, cache index ops ...) — calibrated so
    /// per-pass kernel counts match the paper (§V-A, Table II).
    pub glue_kernels_per_layer: usize,
    /// LM head shares the embedding matrix (GPT-2, Llama-3.2).
    pub tie_embeddings: bool,
}

impl ModelSpec {
    pub fn is_moe(&self) -> bool {
        self.moe.is_some()
    }

    pub fn qkv_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Total parameter count (embeddings + blocks + head).
    pub fn params_total(&self) -> f64 {
        let d = self.d_model as f64;
        let emb = (self.vocab as f64) * d;
        let attn = d * self.qkv_dim() as f64 // wq
            + 2.0 * d * self.kv_dim() as f64 // wk, wv
            + self.qkv_dim() as f64 * d; // wo
        let ffn = match &self.moe {
            Some(m) => {
                let per_expert = 3.0 * d * m.expert_hidden as f64; // gate/up/down
                (m.n_experts + m.shared_experts) as f64 * per_expert
                    + d * m.n_experts as f64 // router
            }
            // SwiGLU carries 3 matrices; the GPT-2 GELU MLP only 2.
            None => self.ffn_matrices() * d * self.ffn_hidden as f64,
        };
        let norms = 2.0 * d;
        let head = if self.tie_embeddings { 0.0 } else { emb };
        emb + self.layers as f64 * (attn + ffn + norms) + d + head
    }

    fn ffn_matrices(&self) -> f64 {
        match self.gemm_lib {
            GemmLib::Cublas => 3.0, // SwiGLU: gate/up/down
            GemmLib::Nvjet => 2.0,  // GELU MLP: fc/proj
        }
    }

    /// Parameters touched per token in decode (active experts only) —
    /// the memory-bound decode working set.
    pub fn params_active(&self) -> f64 {
        match &self.moe {
            None => self.params_total(),
            Some(m) => {
                let d = self.d_model as f64;
                let emb = (self.vocab as f64) * d;
                let attn = d * self.qkv_dim() as f64
                    + 2.0 * d * self.kv_dim() as f64
                    + self.qkv_dim() as f64 * d;
                let per_expert = 3.0 * d * m.expert_hidden as f64;
                let ffn = (m.top_k + m.shared_experts) as f64 * per_expert
                    + d * m.n_experts as f64;
                let head = if self.tie_embeddings { 0.0 } else { emb };
                emb + self.layers as f64 * (attn + ffn + 2.0 * d) + d + head
            }
        }
    }

    /// KV-cache bytes per token (bf16).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * 2.0 * (self.layers * self.kv_dim()) as f64
    }
}

/// GPT-2 124M — the Fig. 2 / Fig. 7 case study. Its GEMMs are emitted
/// framework-natively (nvjet/gemv2T), so ΔCT = 0 (§V-C).
pub fn gpt2() -> ModelSpec {
    ModelSpec {
        name: "gpt2".into(),
        display: "GPT-2 (124M)".into(),
        layers: 12,
        d_model: 768,
        n_heads: 12,
        n_kv_heads: 12,
        head_dim: 64,
        ffn_hidden: 3072,
        vocab: 50257,
        moe: None,
        gemm_lib: GemmLib::Nvjet,
        // ~380 kernels/pass on H200 (§V-C: 376-394) => ~31/layer + epilogue.
        glue_kernels_per_layer: 12,
        tie_embeddings: true,
    }
}

/// Llama-3.2-1B (dense).
pub fn llama_1b() -> ModelSpec {
    ModelSpec {
        name: "llama-3.2-1b".into(),
        display: "Llama-3.2-1B".into(),
        layers: 16,
        d_model: 2048,
        n_heads: 32,
        n_kv_heads: 8,
        head_dim: 64,
        ffn_hidden: 8192,
        vocab: 128256,
        moe: None,
        gemm_lib: GemmLib::Cublas,
        // 850 kernels/prefill pass, ~844/decode step (§V-C) => 53/layer.
        glue_kernels_per_layer: 22,
        tie_embeddings: true,
    }
}

/// Llama-3.2-3B (dense).
pub fn llama_3b() -> ModelSpec {
    ModelSpec {
        name: "llama-3.2-3b".into(),
        display: "Llama-3.2-3B".into(),
        layers: 28,
        d_model: 3072,
        n_heads: 24,
        n_kv_heads: 8,
        head_dim: 128,
        ffn_hidden: 8192,
        vocab: 128256,
        moe: None,
        gemm_lib: GemmLib::Cublas,
        // 15,369 kernels over m=10 decode (Table II) => ~55/layer.
        glue_kernels_per_layer: 23,
        tie_embeddings: true,
    }
}

/// OLMoE-1B/7B: 64 experts, top-8, 1B active / 7B total.
pub fn olmoe() -> ModelSpec {
    ModelSpec {
        name: "olmoe-1b-7b".into(),
        display: "OLMoE-1B/7B".into(),
        layers: 16,
        d_model: 2048,
        n_heads: 16,
        n_kv_heads: 16,
        head_dim: 128,
        ffn_hidden: 1024,
        vocab: 50304,
        moe: Some(MoeSpec {
            n_experts: 64,
            top_k: 8,
            shared_experts: 0,
            expert_hidden: 1024,
            // Table II: 93,053 kernels (BS=4/SL=2048, m=10) ≈ 9,305
            // per token => (64·8 + router + attn + glue) per layer;
            // prefill at BS=1/SL=512 dispatches 13,741 (§V-A) =>
            // ~12.5 kernels per expert iteration there.
            expert_kernels_prefill: 12,
            expert_kernels_decode: 8,
            router_kernels: 9,
        }),
        gemm_lib: GemmLib::Cublas,
        glue_kernels_per_layer: 34,
        tie_embeddings: false,
    }
}

/// Qwen1.5-MoE-A2.7B: 60 experts top-4 + 4 shared, 2.7B active.
pub fn qwen_moe() -> ModelSpec {
    ModelSpec {
        name: "qwen1.5-moe-a2.7b".into(),
        display: "Qwen1.5-MoE-A2.7B".into(),
        layers: 24,
        d_model: 2048,
        n_heads: 16,
        n_kv_heads: 16,
        head_dim: 128,
        ffn_hidden: 5632,
        vocab: 151936,
        moe: Some(MoeSpec {
            n_experts: 60,
            top_k: 4,
            shared_experts: 4,
            expert_hidden: 1408,
            // 22,558 prefill kernels at BS=1/SL=512 (§V-A) and 66,951
            // over m=10 decode at BS=4/SL=2048 (Table II ≈ 6,695/token).
            expert_kernels_prefill: 13,
            expert_kernels_decode: 3,
            router_kernels: 10,
        }),
        gemm_lib: GemmLib::Cublas,
        glue_kernels_per_layer: 31,
        tie_embeddings: false,
    }
}

/// All catalog models in the paper's reporting order.
pub fn catalog() -> Vec<ModelSpec> {
    vec![gpt2(), llama_1b(), llama_3b(), olmoe(), qwen_moe()]
}

pub fn by_name(name: &str) -> anyhow::Result<ModelSpec> {
    catalog()
        .into_iter()
        .find(|m| m.name == name)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown model '{name}' (expected one of: {})",
                catalog()
                    .iter()
                    .map(|m| m.name.clone())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_names_resolve() {
        for m in catalog() {
            assert_eq!(by_name(&m.name).unwrap(), m);
        }
        assert!(by_name("gpt5").is_err());
    }

    #[test]
    fn param_counts_are_plausible() {
        // Within 20% of the advertised sizes.
        let close = |got: f64, want: f64| (got / want - 1.0).abs() < 0.20;
        assert!(close(gpt2().params_total(), 124e6), "{}", gpt2().params_total());
        assert!(close(llama_1b().params_total(), 1.24e9), "{}", llama_1b().params_total());
        assert!(close(llama_3b().params_total(), 3.2e9), "{}", llama_3b().params_total());
        assert!(close(olmoe().params_total(), 6.9e9), "{}", olmoe().params_total());
        assert!(close(qwen_moe().params_total(), 14.3e9), "{}", qwen_moe().params_total());
    }

    #[test]
    fn moe_active_params_much_smaller_than_total() {
        let m = olmoe();
        assert!(m.params_active() < 0.35 * m.params_total());
        // OLMoE: ~1.3B active of 6.9B.
        assert!((m.params_active() / 1.3e9 - 1.0).abs() < 0.3, "{}", m.params_active());
    }

    #[test]
    fn dense_active_equals_total() {
        let m = llama_1b();
        assert_eq!(m.params_active(), m.params_total());
    }

    #[test]
    fn gqa_kv_dim() {
        let m = llama_1b();
        assert_eq!(m.qkv_dim(), 2048);
        assert_eq!(m.kv_dim(), 512);
    }

    #[test]
    fn gpt2_is_framework_native() {
        assert_eq!(gpt2().gemm_lib, GemmLib::Nvjet);
        assert_eq!(llama_1b().gemm_lib, GemmLib::Cublas);
    }

    #[test]
    fn kv_bytes_per_token() {
        // Llama-1B: 16 layers × 512 kv_dim × 2 (k+v) × 2 bytes = 32 KiB.
        assert_eq!(llama_1b().kv_bytes_per_token(), 32768.0);
    }

    #[test]
    fn moe_specs_match_paper() {
        let o = olmoe().moe.unwrap();
        assert_eq!((o.n_experts, o.top_k), (64, 8));
        let q = qwen_moe().moe.unwrap();
        assert_eq!((q.n_experts, q.top_k, q.shared_experts), (60, 4, 4));
    }
}
