//! Request model + synthetic workload generation for the serving demo.

use crate::util::rng::Rng;

/// An inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Arrival time offset, us (0 = all at once).
    pub arrival_us: f64,
}

/// Terminal outcome of a request — every submitted request ends in
/// exactly one of these (the chaos property suite pins this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Generated its full decode budget (or hit the context cutoff).
    Completed,
    /// Unservable at the door (prompt exceeds the context window, or
    /// worst-case KV demand exceeds the whole pool).
    Rejected,
    /// Dropped by deadline-aware load shedding: its TTFT deadline
    /// passed while it was waiting (or while requeued by preemption),
    /// so serving it could only head-of-line block feasible work.
    Shed,
    /// The backend exhausted the transient launch-retry budget while
    /// running its group (DESIGN.md §16) — a typed failure, never a
    /// panic.
    Failed,
}

impl RequestOutcome {
    /// Stable label for reports and metrics.
    pub fn as_str(&self) -> &'static str {
        match self {
            RequestOutcome::Completed => "completed",
            RequestOutcome::Rejected => "rejected",
            RequestOutcome::Shed => "shed",
            RequestOutcome::Failed => "failed",
        }
    }
}

/// Lifecycle state tracked by the scheduler.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestState {
    pub request: Request,
    pub generated: Vec<i32>,
    /// Set when the first output token is produced (TTFT), us.
    pub first_token_us: Option<f64>,
    /// Set when the request completes, us.
    pub finish_us: Option<f64>,
    /// The request was unservable (e.g. its prompt exceeds the
    /// backend's context window) and finished without running.
    pub rejected: bool,
    /// Dropped by deadline-aware load shedding ([`RequestOutcome::Shed`]).
    pub shed: bool,
    /// Terminated by launch-retry exhaustion ([`RequestOutcome::Failed`]).
    pub failed: bool,
}

impl RequestState {
    pub fn new(request: Request) -> RequestState {
        RequestState {
            request,
            generated: Vec::new(),
            first_token_us: None,
            finish_us: None,
            rejected: false,
            shed: false,
            failed: false,
        }
    }

    /// The typed terminal outcome. The flags are mutually exclusive by
    /// construction (the scheduler sets at most one); precedence here
    /// only guards against hand-rolled states.
    pub fn outcome(&self) -> RequestOutcome {
        if self.rejected {
            RequestOutcome::Rejected
        } else if self.failed {
            RequestOutcome::Failed
        } else if self.shed {
            RequestOutcome::Shed
        } else {
            RequestOutcome::Completed
        }
    }

    pub fn done(&self) -> bool {
        self.generated.len() >= self.request.max_new_tokens
    }

    /// Current sequence position (next token index).
    pub fn pos(&self) -> usize {
        self.request.prompt.len() + self.generated.len()
    }

    pub fn ttft_us(&self) -> Option<f64> {
        self.first_token_us.map(|t| t - self.request.arrival_us)
    }

    /// Time per output token over the decode window.
    pub fn tpot_us(&self) -> Option<f64> {
        match (self.first_token_us, self.finish_us) {
            (Some(first), Some(finish)) if self.generated.len() > 1 => {
                Some((finish - first) / (self.generated.len() - 1) as f64)
            }
            _ => None,
        }
    }
}

/// Deterministic synthetic request mix: prompt lengths and decode
/// budgets sized for the AOT bucket grid (max prompt 64, max_seq 128).
pub fn synthetic_requests(
    n: usize,
    vocab: usize,
    max_seq: usize,
    seed: u64,
) -> Vec<Request> {
    let mut rng = Rng::new(seed).fork_str("requests");
    (0..n as u64)
        .map(|id| {
            let prompt_len = 8 + rng.below(41); // 8..=48
            let prompt: Vec<i32> = (0..prompt_len)
                .map(|_| rng.below(vocab) as i32)
                .collect();
            let budget = max_seq.saturating_sub(prompt_len + 1);
            let max_new = (4 + rng.below(9)).min(budget); // 4..=12
            Request {
                id,
                prompt,
                max_new_tokens: max_new.max(1),
                arrival_us: 0.0,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_fits_buckets() {
        for r in synthetic_requests(64, 256, 128, 1) {
            assert!((8..=48).contains(&r.prompt.len()));
            assert!(r.prompt.len() + r.max_new_tokens < 128);
            assert!(r.prompt.iter().all(|&t| (0..256).contains(&t)));
        }
    }

    #[test]
    fn synthetic_is_deterministic() {
        assert_eq!(
            synthetic_requests(8, 256, 128, 7),
            synthetic_requests(8, 256, 128, 7)
        );
        assert_ne!(
            synthetic_requests(8, 256, 128, 7),
            synthetic_requests(8, 256, 128, 8)
        );
    }

    #[test]
    fn state_lifecycle() {
        let r = Request {
            id: 1,
            prompt: vec![1, 2, 3],
            max_new_tokens: 2,
            arrival_us: 100.0,
        };
        let mut s = RequestState::new(r);
        assert!(!s.done());
        assert_eq!(s.pos(), 3);
        s.generated.push(9);
        s.first_token_us = Some(400.0);
        assert_eq!(s.pos(), 4);
        assert!(!s.done());
        s.generated.push(10);
        s.finish_us = Some(700.0);
        assert!(s.done());
        assert_eq!(s.ttft_us(), Some(300.0));
        assert_eq!(s.tpot_us(), Some(300.0));
    }

    #[test]
    fn outcomes_are_typed_and_exclusive() {
        let r = || Request {
            id: 1,
            prompt: vec![1],
            max_new_tokens: 1,
            arrival_us: 0.0,
        };
        assert_eq!(RequestState::new(r()).outcome(), RequestOutcome::Completed);
        let mut s = RequestState::new(r());
        s.rejected = true;
        assert_eq!(s.outcome(), RequestOutcome::Rejected);
        let mut s = RequestState::new(r());
        s.shed = true;
        assert_eq!(s.outcome(), RequestOutcome::Shed);
        let mut s = RequestState::new(r());
        s.failed = true;
        assert_eq!(s.outcome(), RequestOutcome::Failed);
        for o in [
            RequestOutcome::Completed,
            RequestOutcome::Rejected,
            RequestOutcome::Shed,
            RequestOutcome::Failed,
        ] {
            assert!(!o.as_str().is_empty());
        }
    }

    #[test]
    fn tpot_requires_two_tokens() {
        let r = Request {
            id: 1,
            prompt: vec![1],
            max_new_tokens: 1,
            arrival_us: 0.0,
        };
        let mut s = RequestState::new(r);
        s.generated.push(5);
        s.first_token_us = Some(10.0);
        s.finish_us = Some(10.0);
        assert_eq!(s.tpot_us(), None);
    }
}
