//! `serving::replay` — re-drive the timeline engine from a recording.
//!
//! A spec-v3 capture (`taxbreak loadgen --capture`) records every
//! nondeterministic input of a serving run as first-class trace events:
//! `arrival` (who entered, when, with what shape), `rng_draw` (each
//! consumed random value), `sched_decision` (each step's
//! admissions/preemptions) and `clock_jump` (idle-time skips). Replay
//! reconstructs the per-replica scripts from those events and drives
//! the same engine + scheduler stack with every decision *replayed,
//! not re-decided*:
//!
//! - arrivals are resubmitted at their recorded timestamps (prompt
//!   token *values* never influence sim timing, so filler tokens of
//!   the recorded length suffice);
//! - the engine's timing RNG is replaced by the recorded draw script
//!   ([`crate::runtime::SimEngine::script_draws`]);
//! - the scheduler replays the recorded admission/preemption sequence
//!   ([`crate::serving::Scheduler::script_decisions`]) against an
//!   effectively unbounded KV pool — capacity pressure already shaped
//!   the recorded decisions, so it must not be re-applied.
//!
//! The result is a *bit-identical* re-recording: record → replay →
//! re-record is a byte-equal fixed point in both trace dialects
//! (golden + property tests pin this). That makes any capture a
//! deterministic substrate for counterfactual analysis — `taxbreak
//! replay <trace> --counterfactual ...` re-runs `whatif` prescriptions
//! against the replayed timeline.

use std::collections::BTreeMap;

use crate::faults::{FaultKind, FaultPlan, FaultWindow};
use crate::serving::batcher::StepDecision;
use crate::serving::loadgen::{
    drive_collect, merge_replicas, ModelRun, OffsetSink,
};
use crate::serving::{Request, SchedulerConfig};
use crate::trace::{
    EventKind, ReplayArgs, Trace, TraceBufferSink, TraceEvent, TraceSink, Track,
};

/// Disjoint correlation-id range per replica — must match the offset
/// `run_sim_loadgen` applies when recording.
const REPLICA_CORR_STRIDE: u64 = 1_000_000_000;

/// KV pool size for replayed schedulers: effectively unbounded, so the
/// recorded admissions/preemptions are honored verbatim instead of
/// being second-guessed by capacity checks.
const REPLAY_KV_PAGES: usize = 1 << 20;

/// One replica's reconstructed script: everything `drive_collect`
/// needs to re-drive it deterministically.
struct ReplicaScript {
    device: u32,
    requests: Vec<Request>,
    draws: Vec<f64>,
    decisions: Vec<StepDecision>,
    /// Fault windows recorded as spec-v4 `fault` events: re-armed on
    /// the replayed engine so device stalls re-stretch the *computed*
    /// kernel times exactly as recorded (host jitter and launch-retry
    /// draws replay through the rng script; KV pressure only ever
    /// shaped the recorded decisions, which replay verbatim).
    fault_windows: Vec<FaultWindow>,
    /// Streams the replica's engine rotated over, inferred from the
    /// highest device-track stream id. Stream labels are assigned
    /// round-robin by invocation index, so `max + 1` reproduces the
    /// recorded labeling exactly (an invocation count below the
    /// recorded `--streams` yields the same labels either way).
    streams: usize,
}

/// The outcome of replaying a recording: the re-driven run's KPIs plus
/// the re-recorded trace (byte-identical to the input for a faithful
/// recording).
pub struct ReplayOutcome {
    pub run: ModelRun,
    pub trace: Trace,
}

/// Reconstruct the per-replica scripts from a recording's spec-v3
/// events, keyed by replica device id (unstamped events are device 0).
fn extract_scripts(recording: &Trace) -> anyhow::Result<Vec<ReplicaScript>> {
    let mut by_dev: BTreeMap<u32, Vec<&TraceEvent>> = BTreeMap::new();
    for e in &recording.events {
        by_dev.entry(e.device_id()).or_default().push(e);
    }
    let mut scripts = Vec::with_capacity(by_dev.len());
    for (device, events) in by_dev {
        let mut s = ReplicaScript {
            device,
            requests: Vec::new(),
            draws: Vec::new(),
            decisions: Vec::new(),
            fault_windows: Vec::new(),
            streams: 1,
        };
        for e in events {
            match (&e.kind, &e.args) {
                (EventKind::Arrival, Some(ReplayArgs::Arrival { req, plen, max_new, model })) => {
                    anyhow::ensure!(
                        *model == recording.meta.model,
                        "arrival for request {req} targets model '{model}', \
                         but the trace head says '{}'",
                        recording.meta.model
                    );
                    s.requests.push(Request {
                        id: *req,
                        // Token values never influence sim timing; only
                        // the recorded length matters. 0 is always a
                        // valid non-pad token.
                        prompt: vec![0; *plen as usize],
                        max_new_tokens: *max_new as usize,
                        arrival_us: e.ts_us,
                    });
                }
                (EventKind::RngDraw, Some(ReplayArgs::RngDraw { value, .. })) => {
                    s.draws.push(*value);
                }
                (
                    EventKind::SchedDecision,
                    Some(ReplayArgs::SchedDecision { admitted, preempted, shed, .. }),
                ) => {
                    s.decisions.push(StepDecision {
                        admitted: admitted.clone(),
                        preempted: preempted.clone(),
                        shed: shed.clone(),
                    });
                }
                (
                    EventKind::Fault,
                    Some(ReplayArgs::Fault { kind, target, onset_us, dur_us, magnitude }),
                ) => {
                    s.fault_windows.push(FaultWindow {
                        kind: FaultKind::parse(kind)?,
                        target: target.clone(),
                        onset_us: *onset_us,
                        dur_us: *dur_us,
                        magnitude: *magnitude,
                    });
                }
                _ => {}
            }
            if let Track::Device(stream) = e.track {
                s.streams = s.streams.max(stream as usize + 1);
            }
        }
        anyhow::ensure!(
            !s.requests.is_empty() && !s.decisions.is_empty(),
            "device {device} has kernels but no arrival/sched_decision recording events — \
             this trace predates spec v3; re-capture it with `taxbreak loadgen --capture`"
        );
        scripts.push(s);
    }
    anyhow::ensure!(
        !scripts.is_empty(),
        "the trace is empty; nothing to replay"
    );
    Ok(scripts)
}

/// Replay a recorded serving trace: re-drive the engine + scheduler
/// stack from the recording's spec-v3 events and return the re-driven
/// KPIs plus the re-recorded trace. For a faithful recording the
/// re-recording is byte-identical to the input in both dialects.
pub fn replay(recording: &Trace) -> anyhow::Result<ReplayOutcome> {
    let scripts = extract_scripts(recording)?;
    let model = crate::models::by_name(&recording.meta.model)?;
    let platform = crate::hardware::Platform::by_name(&recording.meta.platform)?;
    let moe = model.is_moe();

    let mut meta = recording.meta.clone();
    meta.wall_us = 0.0;
    let mut buf = TraceBufferSink::new(meta);
    let mut outcomes = Vec::with_capacity(scripts.len());
    for script in scripts {
        // The replayed engine's seed is irrelevant: every timing draw
        // comes from the recorded script, and the RNG is never
        // consulted for anything that reaches the trace.
        let mut engine = crate::runtime::SimEngine::with_topology(
            model.clone(),
            platform.clone(),
            0,
            script.streams,
            script.device,
        );
        engine.script_draws(script.draws);
        if !script.fault_windows.is_empty() {
            // Re-arming re-emits the replica's fault events at the head
            // of its stream — exactly where the recording placed them —
            // and re-applies the device-stall factors to the computed
            // kernel times. The *scheduler* stays unarmed: KV pressure
            // already shaped the recorded decisions, which replay
            // verbatim against the unbounded pool.
            engine.set_faults(FaultPlan::from_windows(script.fault_windows));
        }
        let sched = SchedulerConfig {
            kv_pages: REPLAY_KV_PAGES,
            ..SchedulerConfig::default()
        };
        let mut off = OffsetSink::new(&mut buf, script.device as u64 * REPLICA_CORR_STRIDE);
        outcomes.push(drive_collect(
            engine,
            sched,
            script.requests,
            script.device,
            Some(script.decisions),
            None,
            None,
            &mut off,
        )?);
    }
    let mut run = merge_replicas(outcomes);
    run.model = recording.meta.model.clone();
    run.moe = moe;
    TraceSink::finish(&mut buf, run.wall_us)?;
    run.trace = None;
    Ok(ReplayOutcome {
        run,
        trace: buf.into_trace(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::loadgen::{run_sim_loadgen, LoadgenConfig};
    use crate::trace::binary;

    fn fixed_point(cfg: LoadgenConfig) -> (Trace, ReplayOutcome) {
        let report = run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg).unwrap();
        let recording = report.runs[0].trace.clone().unwrap();
        let out = replay(&recording).unwrap();
        assert_eq!(
            out.trace.events, recording.events,
            "replay must re-record the exact event stream"
        );
        assert_eq!(out.trace.meta, recording.meta);
        assert_eq!(
            out.trace.to_json().dump(),
            recording.to_json().dump(),
            "JSON dialect fixed point"
        );
        assert_eq!(
            binary::encode(&out.trace),
            binary::encode(&recording),
            "binary dialect fixed point"
        );
        (recording, out)
    }

    #[test]
    fn single_device_record_replay_rerecord_is_a_fixed_point() {
        let cfg = LoadgenConfig {
            requests: 6,
            rate_per_s: 2000.0,
            capture: true,
            ..Default::default()
        };
        let (recording, out) = fixed_point(cfg);
        assert!(recording.kernel_count() > 0);
        assert_eq!(out.run.completed, 6);
    }

    #[test]
    fn multi_device_multi_stream_record_replay_is_a_fixed_point() {
        let cfg = LoadgenConfig {
            requests: 9,
            rate_per_s: 1500.0,
            devices: 3,
            streams: 2,
            sched: SchedulerConfig { kv_pages: 96, ..Default::default() },
            capture: true,
            ..Default::default()
        };
        let (recording, out) = fixed_point(cfg);
        let devs: std::collections::BTreeSet<u32> =
            recording.events.iter().map(|e| e.device_id()).collect();
        assert_eq!(devs.len(), 3, "the capture spans all replicas");
        assert_eq!(out.run.completed, 9);
        assert_eq!(out.run.per_device.len(), 3);
    }

    #[test]
    fn replay_kpis_match_the_recorded_run() {
        let cfg = LoadgenConfig {
            requests: 5,
            rate_per_s: 0.0,
            capture: true,
            ..Default::default()
        };
        let report = run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg).unwrap();
        let orig = &report.runs[0];
        let recording = orig.trace.as_ref().unwrap();
        let out = replay(recording).unwrap();
        assert_eq!(out.run.completed, orig.completed);
        assert_eq!(out.run.iterations, orig.iterations);
        assert_eq!(out.run.tokens_generated, orig.tokens_generated);
        assert_eq!(out.run.phases, orig.phases, "decomposition is identical");
        assert!((out.run.wall_us - orig.wall_us).abs() < 1e-12);
        assert!(
            (out.run.per_device[0].hdbi - orig.per_device[0].hdbi).abs() < 1e-12,
            "HDBI is identical"
        );
    }

    #[test]
    fn pre_v3_traces_are_rejected_with_a_recapture_hint() {
        let report = run_sim_loadgen(
            &["gpt2".to_string()],
            "h200",
            &LoadgenConfig { requests: 2, rate_per_s: 0.0, capture: true, ..Default::default() },
        )
        .unwrap();
        let mut stripped = report.runs[0].trace.clone().unwrap();
        stripped.events.retain(|e| e.args.is_none() && e.kind != EventKind::ClockJump);
        let err = replay(&stripped).unwrap_err().to_string();
        assert!(err.contains("taxbreak loadgen --capture"), "{err}");
        let empty = Trace::new(stripped.meta.clone());
        let err = replay(&empty).unwrap_err().to_string();
        assert!(err.contains("nothing to replay"), "{err}");
    }
}
