//! Group-based continuous batcher + iteration-level scheduler.
//!
//! Orca-style iteration-level scheduling adapted to AOT static shapes:
//! requests are admitted into *groups* sized to a compiled batch bucket;
//! each scheduler iteration advances every active group by one step
//! (prefill on admission, then one decode step), so new groups join at
//! iteration boundaries rather than waiting for a full drain.  The
//! paged-KV manager gates admission.
//!
//! Static-shape consequences (documented substitution, DESIGN.md §2):
//! prompts inside a group are right-padded to the group maximum and the
//! pad tokens are treated as real prompt content; a group retires when
//! all real members hit their decode budgets.

use std::collections::VecDeque;

use crate::serving::kv::PagedKvManager;
use crate::serving::request::{Request, RequestState};

/// Abstract model execution so the scheduler is testable without PJRT.
pub trait ModelBackend {
    type Cache;

    fn max_seq(&self) -> usize;
    /// Decode batch buckets available (sorted ascending).
    fn decode_buckets(&self) -> Vec<usize>;
    /// Prefill a group of equal-padded prompts; returns the argmax next
    /// token per prompt and the group cache (bucket-batch-shaped).
    fn prefill_group(
        &mut self,
        prompts: &[Vec<i32>],
    ) -> anyhow::Result<(Vec<i32>, Self::Cache)>;
    /// One decode step; `tokens` is bucket-sized.
    fn decode_group(
        &mut self,
        cache: Self::Cache,
        pos: usize,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<i32>, Self::Cache)>;
    /// Monotonic clock, us (trace-aligned in real mode).
    fn now_us(&self) -> f64;
}

/// Scheduler configuration.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Max *real* requests per group (rounded up to a bucket).
    pub max_batch: usize,
    /// Max concurrently active groups.
    pub max_groups: usize,
    pub kv_pages: usize,
    pub kv_page_tokens: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 4,
            max_groups: 2,
            kv_pages: 64,
            kv_page_tokens: 16,
        }
    }
}

struct Group<C> {
    members: Vec<RequestState>,
    /// Padded prompt length shared by the group.
    padded_len: usize,
    cache: Option<C>,
    /// Next position to decode (== tokens stored so far).
    pos: usize,
    /// Bucket batch the cache is shaped for.
    bucket: usize,
    /// Last emitted token per bucket slot (input to the next step).
    last_tokens: Vec<i32>,
}

/// The serving scheduler.
pub struct Scheduler<B: ModelBackend> {
    pub backend: B,
    pub kv: PagedKvManager,
    cfg: SchedulerConfig,
    waiting: VecDeque<Request>,
    groups: Vec<Group<B::Cache>>,
    finished: Vec<RequestState>,
    /// Iterations executed (for stats).
    pub iterations: usize,
}

impl<B: ModelBackend> Scheduler<B> {
    pub fn new(backend: B, cfg: SchedulerConfig) -> Scheduler<B> {
        let kv = PagedKvManager::new(cfg.kv_pages, cfg.kv_page_tokens);
        Scheduler {
            backend,
            kv,
            cfg,
            waiting: VecDeque::new(),
            groups: Vec::new(),
            finished: Vec::new(),
            iterations: 0,
        }
    }

    pub fn submit(&mut self, request: Request) {
        self.waiting.push_back(request);
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.groups.iter().map(|g| g.members.len()).sum::<usize>()
    }

    pub fn is_idle(&self) -> bool {
        self.pending() == 0
    }

    pub fn finished(&self) -> &[RequestState] {
        &self.finished
    }

    /// (bucket, padded prompt length) of each active group — batching
    /// observability for tests and reports.
    pub fn active_group_shapes(&self) -> Vec<(usize, usize)> {
        self.groups.iter().map(|g| (g.bucket, g.padded_len)).collect()
    }

    pub fn into_finished(self) -> Vec<RequestState> {
        self.finished
    }

    /// Round a group size up to the smallest compiled bucket.
    fn bucket_for(&self, n: usize) -> usize {
        let buckets = self.backend.decode_buckets();
        buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| *buckets.last().expect("no decode buckets"))
    }

    /// One scheduler iteration: admit (prefill) then advance every
    /// active group by one decode step.
    pub fn step(&mut self) -> anyhow::Result<()> {
        self.iterations += 1;
        self.admit()?;
        self.advance()?;
        self.retire();
        Ok(())
    }

    /// Run until every submitted request completed.
    pub fn run_to_completion(&mut self) -> anyhow::Result<()> {
        // Each iteration makes progress (a prefill or a decode token);
        // bound by total work + admission stalls.
        let mut stall = 0usize;
        while !self.is_idle() {
            let before = self.total_progress();
            self.step()?;
            if self.total_progress() == before {
                stall += 1;
                anyhow::ensure!(
                    stall < 1000,
                    "scheduler stalled: {} waiting, {} groups, {} kv pages free",
                    self.waiting.len(),
                    self.groups.len(),
                    self.kv.free_pages()
                );
            } else {
                stall = 0;
            }
        }
        Ok(())
    }

    fn total_progress(&self) -> usize {
        self.finished.len() * 1_000_000
            + self
                .groups
                .iter()
                .map(|g| g.pos + g.members.iter().map(|m| m.generated.len()).sum::<usize>())
                .sum::<usize>()
    }

    fn admit(&mut self) -> anyhow::Result<()> {
        // Group size is capped by both the configured max batch and the
        // largest compiled decode bucket (static AOT shapes).
        let bucket_cap = self
            .backend
            .decode_buckets()
            .last()
            .copied()
            .unwrap_or(1);
        while !self.waiting.is_empty() && self.groups.len() < self.cfg.max_groups {
            let take = self
                .waiting
                .len()
                .min(self.cfg.max_batch)
                .min(bucket_cap);
            // Worst-case KV demand of the candidate group.
            let candidates: Vec<&Request> = self.waiting.iter().take(take).collect();
            let padded_len = candidates.iter().map(|r| r.prompt.len()).max().unwrap();
            let worst: usize = candidates
                .iter()
                .map(|r| self.kv.pages_for(padded_len + r.max_new_tokens))
                .sum();
            if worst > self.kv.free_pages() {
                break; // wait for a group to retire
            }
            let members: Vec<Request> =
                (0..take).map(|_| self.waiting.pop_front().unwrap()).collect();
            self.start_group(members, padded_len)?;
        }
        Ok(())
    }

    fn start_group(&mut self, members: Vec<Request>, padded_len: usize) -> anyhow::Result<()> {
        let bucket = self.bucket_for(members.len());
        // Right-pad prompts to the shared length; pad tokens are real
        // prompt content under static shapes.
        let prompts: Vec<Vec<i32>> = members
            .iter()
            .map(|r| {
                let mut p = r.prompt.clone();
                p.resize(padded_len, 0);
                p
            })
            .collect();
        for r in &members {
            self.kv.register(r.id, padded_len)?;
        }
        let (next, cache) = self.backend.prefill_group(&prompts)?;
        let now = self.backend.now_us();

        let mut states: Vec<RequestState> = members.into_iter().map(RequestState::new).collect();
        let mut last_tokens = vec![0i32; bucket];
        for (i, s) in states.iter_mut().enumerate() {
            s.generated.push(next[i]);
            s.first_token_us = Some(now);
            last_tokens[i] = next[i];
            if s.done() {
                s.finish_us = Some(now);
            }
        }
        self.groups.push(Group {
            members: states,
            padded_len,
            cache: Some(cache),
            pos: padded_len,
            bucket,
            last_tokens,
        });
        Ok(())
    }

    fn advance(&mut self) -> anyhow::Result<()> {
        let max_seq = self.backend.max_seq();
        for gi in 0..self.groups.len() {
            let (pos, tokens, cache) = {
                let g = &mut self.groups[gi];
                if g.members.iter().all(|m| m.done()) || g.pos >= max_seq {
                    continue;
                }
                (g.pos, g.last_tokens.clone(), g.cache.take().expect("cache present"))
            };
            let (next, cache) = self.backend.decode_group(cache, pos, &tokens)?;
            let now = self.backend.now_us();
            let g = &mut self.groups[gi];
            g.cache = Some(cache);
            g.pos += 1;
            for (i, m) in g.members.iter_mut().enumerate() {
                if m.done() {
                    continue;
                }
                self.kv.extend(m.request.id, 1)?;
                m.generated.push(next[i]);
                g.last_tokens[i] = next[i];
                if m.done() {
                    m.finish_us = Some(now);
                }
            }
        }
        Ok(())
    }

    fn retire(&mut self) {
        let max_seq = self.backend.max_seq();
        let mut kept = Vec::new();
        for mut g in self.groups.drain(..) {
            let exhausted = g.pos >= max_seq;
            if g.members.iter().all(|m| m.done()) || exhausted {
                let now = self.backend.now_us();
                for mut m in g.members.drain(..) {
                    if m.finish_us.is_none() {
                        m.finish_us = Some(now); // context-exhausted cutoff
                    }
                    let _ = self.kv.release(m.request.id);
                    self.finished.push(m);
                }
            } else {
                kept.push(g);
            }
        }
        self.groups = kept;
        debug_assert!(self.kv.check_invariants().is_ok());
    }
}

pub mod mock_backend {
    //! Deterministic in-memory backend — used by unit, integration and
    //! property tests (and the scheduler benches) to exercise the
    //! coordinator without PJRT.
    use super::*;

    pub struct MockBackend {
        pub max_seq: usize,
        pub buckets: Vec<usize>,
        pub clock_us: f64,
        pub prefills: usize,
        pub decodes: usize,
    }

    impl MockBackend {
        pub fn new() -> MockBackend {
            MockBackend {
                max_seq: 128,
                buckets: vec![1, 4],
                clock_us: 0.0,
                prefills: 0,
                decodes: 0,
            }
        }
    }

    /// Mock cache: (bucket, last position written).
    pub struct MockCache {
        pub bucket: usize,
        pub written_to: usize,
    }

    impl ModelBackend for MockBackend {
        type Cache = MockCache;

        fn max_seq(&self) -> usize {
            self.max_seq
        }

        fn decode_buckets(&self) -> Vec<usize> {
            self.buckets.clone()
        }

        fn prefill_group(
            &mut self,
            prompts: &[Vec<i32>],
        ) -> anyhow::Result<(Vec<i32>, MockCache)> {
            self.prefills += 1;
            self.clock_us += 1000.0;
            anyhow::ensure!(
                prompts.len() <= *self.buckets.last().unwrap(),
                "group of {} exceeds largest bucket {}",
                prompts.len(),
                self.buckets.last().unwrap()
            );
            let bucket = self
                .buckets
                .iter()
                .copied()
                .find(|&b| b >= prompts.len())
                .unwrap();
            let next = prompts
                .iter()
                .map(|p| (p.iter().map(|&t| t as i64).sum::<i64>() % 251) as i32)
                .collect();
            Ok((
                next,
                MockCache {
                    bucket,
                    written_to: prompts[0].len(),
                },
            ))
        }

        fn decode_group(
            &mut self,
            cache: MockCache,
            pos: usize,
            tokens: &[i32],
        ) -> anyhow::Result<(Vec<i32>, MockCache)> {
            anyhow::ensure!(tokens.len() == cache.bucket, "bucket mismatch");
            anyhow::ensure!(pos == cache.written_to, "cache position continuity");
            self.decodes += 1;
            self.clock_us += 100.0;
            let next = tokens.iter().map(|&t| (t + pos as i32) % 251).collect();
            Ok((
                next,
                MockCache {
                    bucket: cache.bucket,
                    written_to: pos + 1,
                },
            ))
        }

        fn now_us(&self) -> f64 {
            self.clock_us
        }
    }
}

#[cfg(test)]
mod tests {
    use super::mock_backend::MockBackend;
    use super::*;
    use crate::serving::request::synthetic_requests;

    fn scheduler(cfg: SchedulerConfig) -> Scheduler<MockBackend> {
        Scheduler::new(MockBackend::new(), cfg)
    }

    #[test]
    fn completes_all_requests() {
        let mut s = scheduler(SchedulerConfig::default());
        for r in synthetic_requests(10, 251, 128, 42) {
            s.submit(r);
        }
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 10);
        for f in s.finished() {
            assert_eq!(f.generated.len(), f.request.max_new_tokens);
            assert!(f.first_token_us.is_some() && f.finish_us.is_some());
        }
        assert_eq!(s.kv.used_pages(), 0, "all KV reclaimed");
    }

    #[test]
    fn every_output_token_is_deterministic() {
        let run = || {
            let mut s = scheduler(SchedulerConfig::default());
            for r in synthetic_requests(6, 251, 128, 9) {
                s.submit(r);
            }
            s.run_to_completion().unwrap();
            let mut f = s.into_finished();
            f.sort_by_key(|s| s.request.id);
            f.into_iter().map(|s| s.generated).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn admission_respects_kv_capacity() {
        // Tiny KV pool: only one group fits at a time.
        let cfg = SchedulerConfig {
            max_batch: 4,
            max_groups: 4,
            kv_pages: 20,
            kv_page_tokens: 16,
        };
        let mut s = scheduler(cfg);
        for r in synthetic_requests(12, 251, 128, 3) {
            s.submit(r);
        }
        s.step().unwrap();
        assert!(
            s.groups.len() <= 2,
            "KV pool must limit concurrent groups, got {}",
            s.groups.len()
        );
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 12);
    }

    #[test]
    fn groups_round_up_to_buckets() {
        let mut s = scheduler(SchedulerConfig::default());
        for r in synthetic_requests(3, 251, 128, 5) {
            s.submit(r);
        }
        s.step().unwrap();
        assert_eq!(s.groups.len(), 1);
        assert_eq!(s.groups[0].bucket, 4, "3 members round up to bucket 4");
        assert_eq!(s.groups[0].members.len(), 3);
    }

    #[test]
    fn iteration_level_admission() {
        // A later request joins as soon as a group slot frees, not
        // after a full drain.
        let cfg = SchedulerConfig {
            max_batch: 4,
            max_groups: 1,
            kv_pages: 64,
            kv_page_tokens: 16,
        };
        let mut s = scheduler(cfg);
        for r in synthetic_requests(8, 251, 128, 7) {
            s.submit(r);
        }
        s.step().unwrap();
        let first_batch = s.finished().len() + s.groups.iter().map(|g| g.members.len()).sum::<usize>();
        assert_eq!(first_batch, 4);
        assert_eq!(s.waiting.len(), 4);
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 8);
        assert!(s.backend.prefills >= 2);
    }

    #[test]
    fn ttft_precedes_finish() {
        let mut s = scheduler(SchedulerConfig::default());
        for r in synthetic_requests(5, 251, 128, 11) {
            s.submit(r);
        }
        s.run_to_completion().unwrap();
        for f in s.finished() {
            assert!(f.first_token_us.unwrap() <= f.finish_us.unwrap());
        }
    }
}
