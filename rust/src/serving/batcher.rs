//! Group-based continuous batcher + iteration-level scheduler.
//!
//! Orca-style iteration-level scheduling adapted to AOT static shapes:
//! requests are admitted into *groups* sized to a compiled batch bucket;
//! each scheduler iteration advances every active group by one step
//! (prefill on admission, then one decode step), so new groups join at
//! iteration boundaries rather than waiting for a full drain.  The
//! paged-KV manager gates admission.
//!
//! **Admission is reservation-backed** (DESIGN.md §2): a group is
//! admitted only if the pool can hold every member's *worst-case*
//! context (`padded_len + max_new_tokens`), and those pages are
//! reserved at admission via [`PagedKvManager::reserve`].  Decode-time
//! `extend`s draw from the reservation, so an admitted request can
//! never fail with `OutOfPages` mid-decode — the check-vs-allocate
//! deadlock of check-only admission.  When the full candidate set does
//! not fit, admission shrinks the group instead of head-of-line
//! blocking, and a member's pages (stored + unused reservation) are
//! released the moment it finishes, not when its group retires.
//! Should the pool still run dry (possible only for hand-rolled
//! configurations that bypass reservations), [`Scheduler::step`]
//! treats it as backpressure and preempts the youngest group rather
//! than crashing.
//!
//! Static-shape consequences (documented substitution, DESIGN.md §2):
//! prompts inside a group are right-padded to the group maximum with
//! the backend's dedicated [`ModelBackend::pad_id`] (never a real
//! vocab token), and a group retires when all real members hit their
//! decode budgets.

use std::collections::{HashSet, VecDeque};

use crate::faults::{FaultPlan, TRANSIENT_LAUNCH_MARKER};
use crate::serving::kv::PagedKvManager;
use crate::serving::request::{Request, RequestState};

/// One iteration's scheduling decisions — what [`Scheduler::step`]
/// decided, in a form that can be recorded as a `sched_decision` trace
/// event and replayed verbatim by [`Scheduler::script_decisions`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StepDecision {
    /// Request ids admitted this iteration, one inner vec per started
    /// group (group boundaries matter: they fix bucket and padding).
    pub admitted: Vec<Vec<u64>>,
    /// Request ids requeued by KV backpressure preemption this
    /// iteration, sorted ascending.
    pub preempted: Vec<u64>,
    /// Request ids terminated by deadline-aware load shedding this
    /// iteration (spec v4), sorted ascending. Serialized only when
    /// non-empty, so deadline-free recordings stay byte-identical to
    /// spec v3.
    pub shed: Vec<u64>,
}

/// Abstract model execution so the scheduler is testable without PJRT.
pub trait ModelBackend {
    type Cache;

    fn max_seq(&self) -> usize;
    /// Decode batch buckets available (sorted ascending).
    fn decode_buckets(&self) -> Vec<usize>;
    /// Token id used for right-padding prompts and for unused bucket
    /// slots.  Must never collide with genuine prompt content (real
    /// backends reserve an id; the mock uses a sentinel outside the
    /// vocab).
    fn pad_id(&self) -> i32;
    /// Prefill a group of equal-padded prompts; returns the argmax next
    /// token per prompt and the group cache (bucket-batch-shaped).
    fn prefill_group(
        &mut self,
        prompts: &[Vec<i32>],
    ) -> anyhow::Result<(Vec<i32>, Self::Cache)>;
    /// One decode step; `tokens` is bucket-sized.
    fn decode_group(
        &mut self,
        cache: Self::Cache,
        pos: usize,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<i32>, Self::Cache)>;
    /// Monotonic clock, us (trace-aligned in real mode).
    fn now_us(&self) -> f64;
    /// Advance the clock to at least `t_us`.  Virtual-clock engines
    /// (the simulator) jump forward so arrival-gated load generation
    /// can model idle gaps; wall-clock engines cannot time-travel and
    /// ignore this (the default).
    fn wait_until_us(&mut self, _t_us: f64) {}
}

/// Detects a permanently stalled scheduler.  Feed it the
/// [`Scheduler::progress_marker`] once per iteration; after 1000
/// consecutive iterations without progress it errors with the caller's
/// diagnostics.  The one stall policy shared by
/// [`Scheduler::run_to_completion`] and `serving::loadgen::drive`.
#[derive(Debug, Default)]
pub struct StallGuard {
    last: Option<usize>,
    stalled: usize,
}

impl StallGuard {
    const LIMIT: usize = 1000;

    pub fn observe(
        &mut self,
        marker: usize,
        diagnostics: impl Fn() -> String,
    ) -> anyhow::Result<()> {
        if self.last == Some(marker) {
            self.stalled += 1;
            anyhow::ensure!(
                self.stalled < Self::LIMIT,
                "scheduler stalled: {}",
                diagnostics()
            );
        } else {
            self.stalled = 0;
            self.last = Some(marker);
        }
        Ok(())
    }
}

/// Scheduler configuration.
#[derive(Debug, Clone, Copy)]
pub struct SchedulerConfig {
    /// Max *real* requests per group (rounded up to a bucket).
    pub max_batch: usize,
    /// Max concurrently active groups.
    pub max_groups: usize,
    pub kv_pages: usize,
    pub kv_page_tokens: usize,
    /// TTFT deadline, us (0 = disabled). A waiting request whose
    /// deadline has already passed is shed instead of admitted — it
    /// could never be served in time, and admitting it would only
    /// head-of-line block feasible work behind it.
    pub ttft_deadline_us: f64,
    /// Per-output-token deadline, us (0 = disabled). Used to pick KV
    /// backpressure preemption victims: a group dragging past its
    /// token budget yields before a healthy one.
    pub tpot_deadline_us: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 4,
            max_groups: 2,
            kv_pages: 64,
            kv_page_tokens: 16,
            ttft_deadline_us: 0.0,
            tpot_deadline_us: 0.0,
        }
    }
}

struct Group<C> {
    members: Vec<RequestState>,
    /// Padded prompt length shared by the group.
    padded_len: usize,
    cache: Option<C>,
    /// Next position to decode (== tokens stored so far).
    pos: usize,
    /// Bucket batch the cache is shaped for.
    bucket: usize,
    /// Last emitted token per bucket slot (input to the next step).
    last_tokens: Vec<i32>,
}

/// The serving scheduler.
pub struct Scheduler<B: ModelBackend> {
    pub backend: B,
    pub kv: PagedKvManager,
    cfg: SchedulerConfig,
    waiting: VecDeque<Request>,
    groups: Vec<Group<B::Cache>>,
    finished: Vec<RequestState>,
    /// Iterations executed (for stats).
    pub iterations: usize,
    /// Groups preempted under KV backpressure (for stats; always 0
    /// under reservation-backed admission with no fault plan armed).
    pub preemptions: usize,
    /// Requests terminated by deadline-aware load shedding.
    pub sheds: usize,
    /// Requests terminated by launch-retry exhaustion
    /// ([`RequestOutcome::Failed`](crate::serving::request::RequestOutcome::Failed)).
    pub failures: usize,
    /// Armed fault plan (DESIGN.md §16): only its KV-pressure windows
    /// act at this layer, converting sequestered capacity into
    /// admission backpressure. Device/host/launch faults act inside the
    /// backend.
    faults: Option<crate::faults::FaultPlan>,
    /// What the most recent [`step`](Self::step) decided — recorded by
    /// the capture path as a `sched_decision` event.
    last_decision: StepDecision,
    /// Decision replay script: when armed, `step` consumes one recorded
    /// decision per iteration instead of running the admission
    /// heuristics (decisions are *replayed, not re-decided*).
    script: Option<VecDeque<StepDecision>>,
    /// Every id the script ever admits — `submit` mirrors the recorded
    /// door rejections by rejecting exactly the ids outside this set.
    script_admitted: HashSet<u64>,
}

impl<B: ModelBackend> Scheduler<B> {
    pub fn new(backend: B, cfg: SchedulerConfig) -> Scheduler<B> {
        let kv = PagedKvManager::new(cfg.kv_pages, cfg.kv_page_tokens);
        Scheduler {
            backend,
            kv,
            cfg,
            waiting: VecDeque::new(),
            groups: Vec::new(),
            finished: Vec::new(),
            iterations: 0,
            preemptions: 0,
            sheds: 0,
            failures: 0,
            faults: None,
            last_decision: StepDecision::default(),
            script: None,
            script_admitted: HashSet::new(),
        }
    }

    /// Arm decision replay: every subsequent [`step`](Self::step) pops
    /// the next recorded [`StepDecision`] and executes it verbatim.
    /// `serving::replay` fills this from a recording's `sched_decision`
    /// events (and sizes the KV pool so reservations cannot fail — the
    /// recording already proved the schedule feasible).
    pub fn script_decisions(&mut self, decisions: Vec<StepDecision>) {
        // Every id the script ever schedules: admitted ids and shed ids
        // both entered the wait queue in the recording, so neither may
        // be door-rejected on replay.
        self.script_admitted = decisions
            .iter()
            .flat_map(|d| {
                d.admitted
                    .iter()
                    .flatten()
                    .copied()
                    .chain(d.shed.iter().copied())
            })
            .collect();
        self.script = Some(decisions.into());
    }

    /// Arm a fault plan at the scheduler layer. Only KV-pressure
    /// windows act here: while one is active *and the scheduler is
    /// serving*, the sequestered fraction of the pool is invisible to
    /// admission, converting capacity into queueing (sheds and
    /// preemptions). An idle scheduler admits from the real pool — the
    /// virtual clock only advances through backend work, so pressure
    /// on an empty system could otherwise freeze time and deadlock the
    /// run (the chaos suite pins this liveness rule).
    pub fn set_faults(&mut self, plan: FaultPlan) {
        self.faults = Some(plan);
    }

    /// What the most recent [`step`](Self::step) decided.
    pub fn last_decision(&self) -> &StepDecision {
        &self.last_decision
    }

    /// Sequences that will participate in the next decode iteration —
    /// the `batch` field of the recorded `sched_decision` event.
    pub fn active_members(&self) -> usize {
        self.groups
            .iter()
            .map(|g| g.members.iter().filter(|m| !m.done()).count())
            .sum()
    }

    /// Queue a request.  Unservable requests — a prompt the context
    /// window cannot hold, or a worst-case KV demand larger than the
    /// entire pool — are rejected at the door
    /// ([`RequestState::rejected`]): admission candidates are a prefix
    /// of this queue, so one such request would otherwise head-of-line
    /// block every request behind it forever.
    pub fn submit(&mut self, request: Request) {
        let infeasible = if self.script.is_some() {
            // Decision replay: the recording already decided — an id
            // that never appears in any admitted group was rejected at
            // the door, and the replay mirrors that verbatim.
            !self.script_admitted.contains(&request.id)
        } else {
            let max_seq = self.backend.max_seq();
            let worst = self
                .kv
                .pages_for((request.prompt.len() + request.max_new_tokens).min(max_seq));
            request.prompt.len() > max_seq || worst > self.cfg.kv_pages
        };
        if infeasible {
            let mut st = RequestState::new(request);
            st.rejected = true;
            st.finish_us = Some(self.backend.now_us());
            self.finished.push(st);
            return;
        }
        self.waiting.push_back(request);
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.groups.iter().map(|g| g.members.len()).sum::<usize>()
    }

    /// Requests submitted but not yet admitted into a batch group —
    /// the admission-queue depth the serving probe samples per step.
    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn is_idle(&self) -> bool {
        self.pending() == 0
    }

    pub fn finished(&self) -> &[RequestState] {
        &self.finished
    }

    /// (bucket, padded prompt length) of each active group — batching
    /// observability for tests and reports.
    pub fn active_group_shapes(&self) -> Vec<(usize, usize)> {
        self.groups.iter().map(|g| (g.bucket, g.padded_len)).collect()
    }

    pub fn into_finished(self) -> Vec<RequestState> {
        self.finished
    }

    /// Round a group size up to the smallest compiled bucket.  Errors
    /// when the group exceeds the largest bucket (or none exist): the
    /// backend would reject such a group, so a silent clamp could only
    /// fail downstream.
    fn bucket_for(&self, n: usize) -> anyhow::Result<usize> {
        let buckets = self.backend.decode_buckets();
        buckets.iter().copied().find(|&b| b >= n).ok_or_else(|| {
            anyhow::anyhow!(
                "group of {n} does not fit any compiled decode bucket {buckets:?}"
            )
        })
    }

    /// One scheduler iteration: admit (prefill) then advance every
    /// active group by one decode step.
    pub fn step(&mut self) -> anyhow::Result<()> {
        self.iterations += 1;
        self.last_decision = StepDecision::default();
        let scripted = match self.script.as_mut() {
            Some(q) => Some(q.pop_front().ok_or_else(|| {
                anyhow::anyhow!(
                    "replay decision script exhausted at iteration {} — the \
                     recording and the replayed run diverged",
                    self.iterations
                )
            })?),
            None => None,
        };
        match scripted {
            Some(d) => {
                self.shed_scripted(&d.shed, &d.admitted);
                self.admit_scripted(&d.admitted)?;
                self.advance_scripted(&d.preempted, &d.shed)?;
            }
            None => {
                self.shed_overdue_waiting();
                self.admit()?;
                self.advance()?;
            }
        }
        self.last_decision.preempted.sort_unstable();
        self.last_decision.shed.sort_unstable();
        self.retire();
        Ok(())
    }

    /// Run until every submitted request completed.
    pub fn run_to_completion(&mut self) -> anyhow::Result<()> {
        // Each iteration makes progress (a prefill or a decode token);
        // bound by total work + admission stalls.
        let mut guard = StallGuard::default();
        while !self.is_idle() {
            self.step()?;
            guard.observe(self.progress_marker(), || {
                format!(
                    "{} waiting, {} groups, {} kv pages free ({} reserved)",
                    self.waiting.len(),
                    self.groups.len(),
                    self.kv.free_pages(),
                    self.kv.reserved_pages()
                )
            })?;
        }
        Ok(())
    }

    /// Progress marker: unchanged across a [`step`](Self::step) means
    /// the iteration did no work (no prefill, no decode token, nothing
    /// finished).  External drivers use it to detect permanent
    /// admission stalls the same way
    /// [`run_to_completion`](Self::run_to_completion) does internally.
    pub fn progress_marker(&self) -> usize {
        self.total_progress()
    }

    fn total_progress(&self) -> usize {
        self.finished.len() * 1_000_000
            + self
                .groups
                .iter()
                .map(|g| g.pos + g.members.iter().map(|m| m.generated.len()).sum::<usize>())
                .sum::<usize>()
    }

    /// Deadline-aware load shedding: a waiting request whose TTFT
    /// deadline has already passed can never be served in time, so it
    /// is shed (terminal, typed) before admission candidates are
    /// selected — admitting it would only head-of-line block feasible
    /// work behind it. No-op with deadlines disabled.
    fn shed_overdue_waiting(&mut self) {
        if self.cfg.ttft_deadline_us <= 0.0 || self.waiting.is_empty() {
            return;
        }
        let now = self.backend.now_us();
        let deadline = self.cfg.ttft_deadline_us;
        let mut kept = VecDeque::with_capacity(self.waiting.len());
        for r in self.waiting.drain(..) {
            if now - r.arrival_us > deadline {
                let mut st = RequestState::new(r);
                st.shed = true;
                st.finish_us = Some(now);
                self.last_decision.shed.push(st.request.id);
                self.sheds += 1;
                self.finished.push(st);
            } else {
                kept.push_back(r);
            }
        }
        self.waiting = kept;
    }

    /// Replayed shedding: terminate the recorded shed ids still in the
    /// wait queue. Ids that are also admitted this step were
    /// preempt-shed *after* admission — those stay queued here and are
    /// handled by [`advance_scripted`](Self::advance_scripted).
    fn shed_scripted(&mut self, shed: &[u64], admitted: &[Vec<u64>]) {
        for &id in shed {
            if admitted.iter().flatten().any(|&a| a == id) {
                continue;
            }
            if let Some(pos) = self.waiting.iter().position(|r| r.id == id) {
                let r = self.waiting.remove(pos).unwrap();
                let mut st = RequestState::new(r);
                st.shed = true;
                st.finish_us = Some(self.backend.now_us());
                self.last_decision.shed.push(id);
                self.sheds += 1;
                self.finished.push(st);
            }
        }
    }

    /// Pages admission may draw on right now: the free pool minus any
    /// KV-pressure sequestration. Pressure only acts while groups are
    /// being served (see [`set_faults`](Self::set_faults) for the
    /// liveness rule) and never hides the whole pool.
    fn admission_free_pages(&self) -> usize {
        let free = self.kv.free_pages();
        match &self.faults {
            Some(p) if !self.groups.is_empty() => free.saturating_sub(
                p.kv_sequestered(self.backend.now_us(), self.cfg.kv_pages),
            ),
            _ => free,
        }
    }

    /// Admission: reserve-then-register with partial admission.  The
    /// candidate group shrinks until its worst-case KV demand fits the
    /// free pool; only when not even one request fits does admission
    /// wait for pages to free up.
    fn admit(&mut self) -> anyhow::Result<()> {
        if self.waiting.is_empty() {
            return Ok(());
        }
        // Group size is capped by both the configured max batch and the
        // largest compiled decode bucket (static AOT shapes).
        let bucket_cap = self
            .backend
            .decode_buckets()
            .last()
            .copied()
            .ok_or_else(|| anyhow::anyhow!("cannot admit: backend has no decode buckets"))?;
        // Decode hard-stops at max_seq, so no member ever stores more
        // than max_seq tokens — demand past it would be phantom pages.
        // (Oversized prompts were already rejected at submit.)
        let max_seq = self.backend.max_seq();
        while !self.waiting.is_empty() && self.groups.len() < self.cfg.max_groups {
            let mut take = self
                .waiting
                .len()
                .min(self.cfg.max_batch)
                .min(bucket_cap);
            // Shrink the candidate set until its worst-case KV demand
            // (padded prompt + full decode budget per member) fits.
            let admit = loop {
                if take == 0 {
                    break None;
                }
                let padded_len = self
                    .waiting
                    .iter()
                    .take(take)
                    .map(|r| r.prompt.len())
                    .max()
                    .unwrap();
                debug_assert!(
                    padded_len <= max_seq,
                    "oversized prompts are rejected before candidate selection"
                );
                let worst: usize = self
                    .waiting
                    .iter()
                    .take(take)
                    .map(|r| self.kv.pages_for((padded_len + r.max_new_tokens).min(max_seq)))
                    .sum();
                if worst <= self.admission_free_pages() {
                    break Some((take, padded_len));
                }
                take -= 1;
            };
            let Some((take, padded_len)) = admit else {
                break; // backpressure: wait for pages to free up
            };
            let members: Vec<Request> =
                (0..take).map(|_| self.waiting.pop_front().unwrap()).collect();
            self.start_group(members, padded_len)?;
        }
        Ok(())
    }

    /// Replayed admission: start exactly the recorded groups, extracting
    /// members from the wait queue by id (order-independent — the queue
    /// may hold requeued preemption victims in a different order).
    fn admit_scripted(&mut self, admitted: &[Vec<u64>]) -> anyhow::Result<()> {
        for group in admitted {
            anyhow::ensure!(!group.is_empty(), "replay: recorded an empty admitted group");
            let mut members = Vec::with_capacity(group.len());
            for &id in group {
                let pos = self.waiting.iter().position(|r| r.id == id).ok_or_else(|| {
                    anyhow::anyhow!(
                        "replay: admitted request {id} is not waiting — the \
                         recording and the replayed run diverged"
                    )
                })?;
                members.push(self.waiting.remove(pos).unwrap());
            }
            let padded_len = members.iter().map(|r| r.prompt.len()).max().unwrap();
            self.start_group(members, padded_len)?;
        }
        Ok(())
    }

    /// Replayed advance: drop the recorded preemption victims first (a
    /// preempted group never decodes in the step that drops it — the
    /// live path pops victims before reaching them), then run the
    /// normal front-to-back decode over the survivors. Victim groups
    /// are matched against both the requeued (`preempted`) and the
    /// shed ids — a fully-shed victim has no requeued members — and the
    /// recorded shed set decides each member's shed-vs-requeue fate
    /// verbatim, so replay never re-runs the deadline heuristics.
    fn advance_scripted(&mut self, preempted: &[u64], shed: &[u64]) -> anyhow::Result<()> {
        if !preempted.is_empty() || !shed.is_empty() {
            let shed_set: HashSet<u64> = shed.iter().copied().collect();
            let mut gi = 0;
            while gi < self.groups.len() {
                let hit = self.groups[gi].members.iter().any(|m| {
                    !m.done()
                        && (preempted.contains(&m.request.id)
                            || shed_set.contains(&m.request.id))
                });
                if hit {
                    self.preempt_group(gi, Some(&shed_set));
                } else {
                    gi += 1;
                }
            }
        }
        self.advance()
    }

    fn start_group(&mut self, members: Vec<Request>, padded_len: usize) -> anyhow::Result<()> {
        let bucket = self.bucket_for(members.len())?;
        self.last_decision
            .admitted
            .push(members.iter().map(|r| r.id).collect());
        let pad = self.backend.pad_id();
        // Right-pad prompts to the shared length with the dedicated pad
        // id (static shapes); pad can never collide with real content.
        let prompts: Vec<Vec<i32>> = members
            .iter()
            .map(|r| {
                let mut p = r.prompt.clone();
                p.resize(padded_len, pad);
                p
            })
            .collect();
        let max_seq = self.backend.max_seq();
        for r in &members {
            // Hold the worst case, clamped to the context window (a
            // member never stores past max_seq); the prompt commit
            // below draws from the reservation, as does every
            // decode-time extend.  The final generated token is never
            // written back, so this deliberately over-holds by at most
            // one token's page — conservative and simple beats exact.
            self.kv.reserve(r.id, (padded_len + r.max_new_tokens).min(max_seq))?;
            self.kv.extend(r.id, padded_len)?;
        }
        let (next, cache) = match self.backend.prefill_group(&prompts) {
            Ok(v) => v,
            // Transient launch-retry exhaustion (DESIGN.md §16): the
            // group degrades to typed Failed outcomes — pages return
            // to the pool, the run continues. Any other backend error
            // still aborts the run.
            Err(e) if e.to_string().contains(TRANSIENT_LAUNCH_MARKER) => {
                let now = self.backend.now_us();
                for r in members {
                    let _ = self.kv.release(r.id);
                    let mut st = RequestState::new(r);
                    st.failed = true;
                    st.finish_us = Some(now);
                    self.failures += 1;
                    self.finished.push(st);
                }
                return Ok(());
            }
            Err(e) => return Err(e),
        };
        let now = self.backend.now_us();

        let mut states: Vec<RequestState> = members.into_iter().map(RequestState::new).collect();
        let mut last_tokens = vec![pad; bucket];
        for (i, s) in states.iter_mut().enumerate() {
            s.generated.push(next[i]);
            s.first_token_us = Some(now);
            last_tokens[i] = next[i];
            if s.done() {
                s.finish_us = Some(now);
                self.kv.release(s.request.id)?;
            }
        }
        self.groups.push(Group {
            members: states,
            padded_len,
            cache: Some(cache),
            pos: padded_len,
            bucket,
            last_tokens,
        });
        Ok(())
    }

    fn advance(&mut self) -> anyhow::Result<()> {
        let max_seq = self.backend.max_seq();
        let mut gi = 0;
        while gi < self.groups.len() {
            {
                let g = &self.groups[gi];
                if g.members.iter().all(|m| m.done()) || g.pos >= max_seq {
                    gi += 1;
                    continue;
                }
            }
            // Account this step's KV demand *before* touching the
            // backend, so an out-of-pages condition is backpressure,
            // not a half-applied step.  Under reservation-backed
            // admission the demand on the free pool is always zero.
            let step_need: usize = {
                let g = &self.groups[gi];
                g.members
                    .iter()
                    .filter(|m| !m.done())
                    .map(|m| self.kv.extend_need(m.request.id, 1))
                    .sum()
            };
            if step_need > self.kv.free_pages() {
                self.preempt_backpressure();
                continue; // re-evaluate gi against the shrunk group list
            }
            let (pos, tokens, cache) = {
                let g = &mut self.groups[gi];
                (g.pos, g.last_tokens.clone(), g.cache.take().expect("cache present"))
            };
            let (next, cache) = match self.backend.decode_group(cache, pos, &tokens) {
                Ok(v) => v,
                // Launch-retry exhaustion mid-decode: the group's cache
                // is gone, so the whole group degrades — unfinished
                // members become typed Failed outcomes, members that
                // already hit their budgets keep their results, every
                // page returns to the pool, and the run continues.
                Err(e) if e.to_string().contains(TRANSIENT_LAUNCH_MARKER) => {
                    let g = self.groups.remove(gi);
                    let now = self.backend.now_us();
                    for mut m in g.members {
                        let _ = self.kv.release(m.request.id);
                        if !m.done() {
                            m.failed = true;
                            m.finish_us = Some(now);
                            self.failures += 1;
                        }
                        self.finished.push(m);
                    }
                    continue; // gi now indexes the next group
                }
                Err(e) => return Err(e),
            };
            let now = self.backend.now_us();
            let g = &mut self.groups[gi];
            g.cache = Some(cache);
            g.pos += 1;
            for (i, m) in g.members.iter_mut().enumerate() {
                if m.done() {
                    continue;
                }
                self.kv.extend(m.request.id, 1)?;
                m.generated.push(next[i]);
                g.last_tokens[i] = next[i];
                if m.done() {
                    m.finish_us = Some(now);
                    // Early release: a finished member's pages (stored
                    // + unused reservation) free immediately, not at
                    // group retire.
                    self.kv.release(m.request.id)?;
                }
            }
            gi += 1;
        }
        Ok(())
    }

    /// KV backpressure: drop a victim group, requeueing (or shedding)
    /// its unfinished members; their partial progress is discarded and
    /// admission re-reserves for requeued ones.  Members that already
    /// finished keep their results.
    fn preempt_backpressure(&mut self) {
        if let Some(idx) = self.preemption_victim() {
            self.preempt_group(idx, None);
        }
    }

    /// Deadline-aware victim choice: with a TPOT deadline armed, the
    /// youngest group containing a member already dragging past its
    /// per-token budget yields first (it contributes the least
    /// deliverable work); otherwise — and always with deadlines off —
    /// the youngest group, preserving the pre-deadline behavior
    /// exactly.
    fn preemption_victim(&self) -> Option<usize> {
        if self.groups.is_empty() {
            return None;
        }
        if self.cfg.tpot_deadline_us > 0.0 {
            let now = self.backend.now_us();
            for idx in (0..self.groups.len()).rev() {
                let over = self.groups[idx].members.iter().any(|m| {
                    !m.done()
                        && m.first_token_us.is_some_and(|t| {
                            (now - t) / m.generated.len().max(1) as f64
                                > self.cfg.tpot_deadline_us
                        })
                });
                if over {
                    return Some(idx);
                }
            }
        }
        Some(self.groups.len() - 1)
    }

    /// Drop group `idx`, requeueing or shedding its unfinished members
    /// and logging them in [`Self::last_decision`] (so the recording
    /// can replay the preemption verbatim). Live runs shed a member
    /// whose TTFT deadline has already passed — requeueing it could
    /// only produce a late answer, since TTFT is re-measured from
    /// arrival after readmission. Replays (`scripted_shed` present)
    /// follow the recorded shed set instead of re-deciding.
    fn preempt_group(&mut self, idx: usize, scripted_shed: Option<&HashSet<u64>>) {
        let g = self.groups.remove(idx);
        self.preemptions += 1;
        let now = self.backend.now_us();
        for m in g.members.into_iter().rev() {
            let _ = self.kv.release(m.request.id);
            if m.done() {
                self.finished.push(m);
                continue;
            }
            let shed = match scripted_shed {
                Some(set) => set.contains(&m.request.id),
                None => {
                    self.cfg.ttft_deadline_us > 0.0
                        && now - m.request.arrival_us > self.cfg.ttft_deadline_us
                }
            };
            if shed {
                let mut st = m;
                st.shed = true;
                st.finish_us = Some(now);
                self.last_decision.shed.push(st.request.id);
                self.sheds += 1;
                self.finished.push(st);
            } else {
                self.last_decision.preempted.push(m.request.id);
                self.waiting.push_front(m.request);
            }
        }
    }

    fn retire(&mut self) {
        let max_seq = self.backend.max_seq();
        let mut kept = Vec::new();
        for mut g in self.groups.drain(..) {
            let exhausted = g.pos >= max_seq;
            if g.members.iter().all(|m| m.done()) || exhausted {
                let now = self.backend.now_us();
                for mut m in g.members.drain(..) {
                    if m.finish_us.is_none() {
                        m.finish_us = Some(now); // context-exhausted cutoff
                    }
                    // Members that finished mid-flight released their
                    // pages already; this reclaims only cutoff members.
                    let _ = self.kv.release(m.request.id);
                    self.finished.push(m);
                }
            } else {
                kept.push(g);
            }
        }
        self.groups = kept;
        debug_assert!(self.kv.check_invariants().is_ok());
    }
}

pub mod mock_backend {
    //! Deterministic in-memory backend — used by unit, integration and
    //! property tests (and the scheduler benches) to exercise the
    //! coordinator without PJRT.
    use super::*;

    pub struct MockBackend {
        pub max_seq: usize,
        pub buckets: Vec<usize>,
        pub clock_us: f64,
        pub prefills: usize,
        pub decodes: usize,
        /// Prompts seen by the last `prefill_group` call (pad-id
        /// observability for tests).
        pub last_prompts: Vec<Vec<i32>>,
    }

    impl MockBackend {
        pub fn new() -> MockBackend {
            MockBackend {
                max_seq: 128,
                buckets: vec![1, 4],
                clock_us: 0.0,
                prefills: 0,
                decodes: 0,
                last_prompts: Vec::new(),
            }
        }
    }

    impl Default for MockBackend {
        fn default() -> Self {
            MockBackend::new()
        }
    }

    /// Mock cache: (bucket, last position written).
    pub struct MockCache {
        pub bucket: usize,
        pub written_to: usize,
    }

    impl ModelBackend for MockBackend {
        type Cache = MockCache;

        fn max_seq(&self) -> usize {
            self.max_seq
        }

        fn decode_buckets(&self) -> Vec<usize> {
            self.buckets.clone()
        }

        fn pad_id(&self) -> i32 {
            // A sentinel no real token can equal (mock tokens are
            // non-negative), so padded positions are distinguishable.
            -1
        }

        fn prefill_group(
            &mut self,
            prompts: &[Vec<i32>],
        ) -> anyhow::Result<(Vec<i32>, MockCache)> {
            self.prefills += 1;
            self.clock_us += 1000.0;
            anyhow::ensure!(
                prompts.len() <= *self.buckets.last().unwrap(),
                "group of {} exceeds largest bucket {}",
                prompts.len(),
                self.buckets.last().unwrap()
            );
            self.last_prompts = prompts.to_vec();
            let bucket = self
                .buckets
                .iter()
                .copied()
                .find(|&b| b >= prompts.len())
                .unwrap();
            let next = prompts
                .iter()
                .map(|p| (p.iter().map(|&t| t as i64).sum::<i64>().rem_euclid(251)) as i32)
                .collect();
            Ok((
                next,
                MockCache {
                    bucket,
                    written_to: prompts[0].len(),
                },
            ))
        }

        fn decode_group(
            &mut self,
            cache: MockCache,
            pos: usize,
            tokens: &[i32],
        ) -> anyhow::Result<(Vec<i32>, MockCache)> {
            anyhow::ensure!(tokens.len() == cache.bucket, "bucket mismatch");
            anyhow::ensure!(pos == cache.written_to, "cache position continuity");
            self.decodes += 1;
            self.clock_us += 100.0;
            let next = tokens
                .iter()
                .map(|&t| (t + pos as i32).rem_euclid(251))
                .collect();
            Ok((
                next,
                MockCache {
                    bucket: cache.bucket,
                    written_to: pos + 1,
                },
            ))
        }

        fn now_us(&self) -> f64 {
            self.clock_us
        }

        fn wait_until_us(&mut self, t_us: f64) {
            self.clock_us = self.clock_us.max(t_us);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::mock_backend::MockBackend;
    use super::*;
    use crate::serving::request::{synthetic_requests, RequestOutcome};

    fn scheduler(cfg: SchedulerConfig) -> Scheduler<MockBackend> {
        Scheduler::new(MockBackend::new(), cfg)
    }

    fn request(id: u64, prompt_len: usize, max_new: usize) -> Request {
        Request {
            id,
            prompt: vec![7; prompt_len],
            max_new_tokens: max_new,
            arrival_us: 0.0,
        }
    }

    #[test]
    fn completes_all_requests() {
        let mut s = scheduler(SchedulerConfig::default());
        for r in synthetic_requests(10, 251, 128, 42) {
            s.submit(r);
        }
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 10);
        for f in s.finished() {
            assert_eq!(f.generated.len(), f.request.max_new_tokens);
            assert!(f.first_token_us.is_some() && f.finish_us.is_some());
        }
        assert_eq!(s.kv.used_pages(), 0, "all KV reclaimed");
        assert_eq!(s.preemptions, 0, "reservations make backpressure preemption unreachable");
    }

    #[test]
    fn every_output_token_is_deterministic() {
        let run = || {
            let mut s = scheduler(SchedulerConfig::default());
            for r in synthetic_requests(6, 251, 128, 9) {
                s.submit(r);
            }
            s.run_to_completion().unwrap();
            let mut f = s.into_finished();
            f.sort_by_key(|s| s.request.id);
            f.into_iter().map(|s| s.generated).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn admission_respects_kv_capacity() {
        // Tiny KV pool: only one group fits at a time.
        let cfg = SchedulerConfig {
            max_batch: 4,
            max_groups: 4,
            kv_pages: 20,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        };
        let mut s = scheduler(cfg);
        for r in synthetic_requests(12, 251, 128, 3) {
            s.submit(r);
        }
        s.step().unwrap();
        assert!(
            s.groups.len() <= 2,
            "KV pool must limit concurrent groups, got {}",
            s.groups.len()
        );
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 12);
    }

    #[test]
    fn groups_round_up_to_buckets() {
        let mut s = scheduler(SchedulerConfig::default());
        for r in synthetic_requests(3, 251, 128, 5) {
            s.submit(r);
        }
        s.step().unwrap();
        assert_eq!(s.groups.len(), 1);
        assert_eq!(s.groups[0].bucket, 4, "3 members round up to bucket 4");
        assert_eq!(s.groups[0].members.len(), 3);
    }

    #[test]
    fn iteration_level_admission() {
        // A later request joins as soon as a group slot frees, not
        // after a full drain.
        let cfg = SchedulerConfig {
            max_batch: 4,
            max_groups: 1,
            kv_pages: 64,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        };
        let mut s = scheduler(cfg);
        for r in synthetic_requests(8, 251, 128, 7) {
            s.submit(r);
        }
        s.step().unwrap();
        let first_batch = s.finished().len() + s.groups.iter().map(|g| g.members.len()).sum::<usize>();
        assert_eq!(first_batch, 4);
        assert_eq!(s.waiting.len(), 4);
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 8);
        assert!(s.backend.prefills >= 2);
    }

    #[test]
    fn ttft_precedes_finish() {
        let mut s = scheduler(SchedulerConfig::default());
        for r in synthetic_requests(5, 251, 128, 11) {
            s.submit(r);
        }
        s.run_to_completion().unwrap();
        for f in s.finished() {
            assert!(f.first_token_us.unwrap() <= f.finish_us.unwrap());
        }
    }

    #[test]
    fn admission_reserves_worst_case() {
        // One member, prompt 16, budget 32: the reservation must hold
        // pages_for(16 + 32) = 3 pages from the moment of admission.
        let cfg = SchedulerConfig {
            max_batch: 1,
            max_groups: 2,
            kv_pages: 8,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        };
        let mut s = scheduler(cfg);
        s.submit(request(0, 16, 32));
        s.step().unwrap();
        assert_eq!(s.kv.used_pages(), 3, "worst case held at admission");
        // The prompt commit (1 page) and the first decode extend (page
        // 2 at token 17) both drew from the reservation; 1 page left.
        assert_eq!(s.kv.reserved_pages(), 1);
    }

    #[test]
    fn partial_admission_shrinks_instead_of_blocking() {
        // Four candidates of 2 worst-case pages each against a 5-page
        // pool: check-only admission would block the whole group; the
        // scheduler must admit the 2 that fit.
        let cfg = SchedulerConfig {
            max_batch: 4,
            max_groups: 2,
            kv_pages: 5,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        };
        let mut s = scheduler(cfg);
        for id in 0..4 {
            s.submit(request(id, 16, 16)); // pages_for(32) = 2 each
        }
        s.step().unwrap();
        assert_eq!(s.groups.len(), 1);
        assert_eq!(s.groups[0].members.len(), 2, "2 of 4 fit (4 of 5 pages)");
        assert_eq!(s.waiting.len(), 2);
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 4);
        assert_eq!(s.kv.used_pages(), 0);
    }

    #[test]
    fn member_pages_release_at_finish_not_group_retire() {
        // Two members, budgets 3 and 40: the short member's pages must
        // free as soon as it finishes, while the group is still alive.
        let cfg = SchedulerConfig {
            max_batch: 2,
            max_groups: 1,
            kv_pages: 16,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        };
        let mut s = scheduler(cfg);
        s.submit(request(0, 16, 3));
        s.submit(request(1, 16, 40));
        s.step().unwrap(); // prefill (token 1) + one decode (token 2)
        assert_eq!(s.kv.active_requests(), 2);
        s.step().unwrap(); // decode: member 0 hits its budget of 3
        assert_eq!(s.groups.len(), 1, "group still running");
        assert_eq!(s.kv.active_requests(), 1, "finished member released early");
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 2);
        assert_eq!(s.kv.used_pages(), 0);
    }

    #[test]
    fn prompts_pad_with_dedicated_pad_id() {
        let mut s = scheduler(SchedulerConfig::default());
        s.submit(request(0, 3, 4));
        s.submit(request(1, 5, 4));
        s.step().unwrap();
        let pad = s.backend.pad_id();
        let prompts = &s.backend.last_prompts;
        assert_eq!(prompts.len(), 2);
        assert!(prompts.iter().all(|p| p.len() == 5));
        assert_eq!(&prompts[0][3..], &[pad, pad], "short prompt pads with pad id");
        assert!(prompts[0][..3].iter().all(|&t| t != pad), "real content is never the pad");
        assert!(prompts[1].iter().all(|&t| t != pad), "full prompt has no pads");
    }

    #[test]
    fn empty_bucket_grid_errors_instead_of_panicking() {
        let mut backend = MockBackend::new();
        backend.buckets = Vec::new();
        let mut s = Scheduler::new(backend, SchedulerConfig::default());
        s.submit(request(0, 4, 4));
        let err = s.step().unwrap_err();
        assert!(err.to_string().contains("no decode buckets"), "{err}");
    }

    #[test]
    fn oversized_group_is_an_error_not_a_clamp() {
        let s = scheduler(SchedulerConfig::default());
        // Largest mock bucket is 4; 9 must error, not clamp to 4.
        let err = s.bucket_for(9).unwrap_err();
        assert!(err.to_string().contains("does not fit"), "{err}");
        assert_eq!(s.bucket_for(3).unwrap(), 4);
        assert_eq!(s.bucket_for(1).unwrap(), 1);
    }

    #[test]
    fn oversized_prompt_rejected_without_stranding_the_queue() {
        // A 200-token prompt can never fit the 128-token window; it is
        // rejected per-request (no KV touched, no error poisoning the
        // run) and everyone behind it is still served.
        let mut s = scheduler(SchedulerConfig::default());
        s.submit(request(0, 200, 4));
        s.submit(request(1, 8, 4));
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 2);
        let bad = s.finished().iter().find(|f| f.request.id == 0).unwrap();
        assert!(bad.rejected && bad.generated.is_empty() && bad.finish_us.is_some());
        let good = s.finished().iter().find(|f| f.request.id == 1).unwrap();
        assert!(!good.rejected);
        assert_eq!(good.generated.len(), 4);
        assert_eq!(s.kv.used_pages(), 0);
    }

    #[test]
    fn pool_infeasible_request_rejected_at_submit() {
        // Worst case pages_for(min(40+40, 128)) = 5 exceeds the whole
        // 4-page pool: rejected at the door, and the feasible request
        // behind it is served normally (no head-of-line block).
        let cfg = SchedulerConfig {
            max_batch: 4,
            max_groups: 2,
            kv_pages: 4,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        };
        let mut s = scheduler(cfg);
        s.submit(request(0, 40, 40));
        s.submit(request(1, 16, 8));
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 2);
        assert!(s.finished().iter().find(|f| f.request.id == 0).unwrap().rejected);
        let ok = s.finished().iter().find(|f| f.request.id == 1).unwrap();
        assert!(!ok.rejected);
        assert_eq!(ok.generated.len(), 8);
        assert_eq!(s.kv.used_pages(), 0);
    }

    #[test]
    fn reservation_clamps_to_context_window() {
        // Unclamped worst case would be pages_for(8 + 200) = 13 pages
        // and could never fit; decode halts at max_seq = 128, so the
        // honest demand is pages_for(128) = 8.
        let cfg = SchedulerConfig {
            max_batch: 1,
            max_groups: 1,
            kv_pages: 8,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        };
        let mut s = scheduler(cfg);
        s.submit(request(0, 8, 200));
        s.step().unwrap();
        assert_eq!(s.active_group_shapes().len(), 1, "clamped demand fits the pool");
        assert_eq!(s.kv.used_pages(), 8, "reserved exactly pages_for(max_seq)");
        s.run_to_completion().unwrap();
        let f = &s.finished()[0];
        assert!(f.generated.len() < 200, "context-exhausted cutoff");
        assert!(f.finish_us.is_some());
        assert_eq!(s.kv.used_pages(), 0);
    }

    #[test]
    fn backpressure_preempts_youngest_without_crashing() {
        // Bypass reservations (register exact prompt pages only, the
        // seed behavior) to force decode-time page exhaustion, and
        // check advance() degrades to preemption instead of erroring.
        let cfg = SchedulerConfig {
            max_batch: 1,
            max_groups: 2,
            kv_pages: 4,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        };
        let mut s = scheduler(cfg);
        // Hand-roll the seed's check-only admission for both requests
        // (they enter as live groups directly, not via submit).
        for g in 0..2u64 {
            s.kv.register(g, 16).unwrap();
            let prompts = vec![vec![7i32; 16]];
            let (next, cache) = s.backend.prefill_group(&prompts).unwrap();
            let mut st = RequestState::new(request(g, 16, 32));
            st.generated.push(next[0]);
            st.first_token_us = Some(s.backend.now_us());
            s.groups.push(Group {
                members: vec![st],
                padded_len: 16,
                cache: Some(cache),
                pos: 16,
                bucket: 1,
                last_tokens: vec![next[0]],
            });
        }
        // 4 pages, 2 allocated; both groups need a 3rd page at token
        // 17 and a 4th at 33 — the pool runs dry mid-decode.
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 2, "both complete after preemption requeue");
        assert!(s.preemptions >= 1, "backpressure must have preempted");
        assert_eq!(s.kv.used_pages(), 0);
    }

    #[test]
    fn scripted_decisions_reproduce_the_schedule() {
        let submit_all = |s: &mut Scheduler<MockBackend>| {
            for r in synthetic_requests(8, 251, 128, 7) {
                s.submit(r);
            }
            s.submit(request(99, 200, 4)); // door-rejected in the recording
        };
        // Record: run under a constrained config, logging each step's
        // decision and the mock backend's call pattern.
        let cfg = SchedulerConfig {
            max_batch: 4,
            max_groups: 1,
            kv_pages: 64,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        };
        let mut rec = scheduler(cfg);
        submit_all(&mut rec);
        let mut decisions = Vec::new();
        while !rec.is_idle() {
            rec.step().unwrap();
            decisions.push(rec.last_decision().clone());
        }
        let outputs = |s: Scheduler<MockBackend>| {
            let mut f = s.into_finished();
            f.sort_by_key(|st| st.request.id);
            f.into_iter()
                .map(|st| (st.request.id, st.rejected, st.generated))
                .collect::<Vec<_>>()
        };
        let (rec_prefills, rec_decodes) = (rec.backend.prefills, rec.backend.decodes);
        let recorded = outputs(rec);

        // Replay: a *different* config (tighter batch cap, huge KV pool
        // — the recording already proved feasibility) plus the script
        // must reproduce the exact same schedule and outputs.
        let mut rep = scheduler(SchedulerConfig {
            max_batch: 1,
            max_groups: 1,
            kv_pages: 1 << 20,
            kv_page_tokens: 16,
            ..SchedulerConfig::default()
        });
        rep.script_decisions(decisions.clone());
        submit_all(&mut rep);
        let mut replayed_decisions = Vec::new();
        while !rep.is_idle() {
            rep.step().unwrap();
            replayed_decisions.push(rep.last_decision().clone());
        }
        assert_eq!(decisions, replayed_decisions, "decisions replay verbatim");
        assert_eq!(rep.backend.prefills, rec_prefills);
        assert_eq!(rep.backend.decodes, rec_decodes);
        assert_eq!(outputs(rep), recorded);
    }

    #[test]
    fn overdue_waiting_requests_are_shed_not_served_late() {
        // max_groups = 1 forces the second batch to queue behind the
        // first; by the time the slot frees (t > 1300us on the mock
        // clock) the 1200us TTFT deadline has passed, so the stragglers
        // are shed — terminal, typed, never admitted.
        let cfg = SchedulerConfig {
            max_batch: 4,
            max_groups: 1,
            ttft_deadline_us: 1200.0,
            ..SchedulerConfig::default()
        };
        let mut s = scheduler(cfg);
        for id in 0..8 {
            s.submit(request(id, 16, 4));
        }
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 8);
        assert_eq!(s.sheds, 4, "the queued half sheds at the deadline");
        for f in s.finished() {
            match f.outcome() {
                RequestOutcome::Completed => {
                    assert!(f.request.id < 4);
                    assert_eq!(f.generated.len(), 4);
                }
                RequestOutcome::Shed => {
                    assert!(f.request.id >= 4);
                    assert!(f.generated.is_empty(), "shed before any work");
                    assert!(f.finish_us.is_some(), "shed is terminal");
                }
                other => panic!("unexpected outcome {other:?} for {}", f.request.id),
            }
        }
        assert_eq!(s.kv.used_pages(), 0);
    }

    #[test]
    fn recorded_sheds_replay_verbatim_without_deadline_config() {
        // Record a deadline-shedding run, then replay its decisions on
        // a scheduler with deadlines *off*: the script alone must
        // reproduce every shed (replay never re-runs the heuristics).
        let submit_all = |s: &mut Scheduler<MockBackend>| {
            for id in 0..8 {
                s.submit(request(id, 16, 4));
            }
        };
        let mut rec = scheduler(SchedulerConfig {
            max_batch: 4,
            max_groups: 1,
            ttft_deadline_us: 1200.0,
            ..SchedulerConfig::default()
        });
        submit_all(&mut rec);
        let mut decisions = Vec::new();
        while !rec.is_idle() {
            rec.step().unwrap();
            decisions.push(rec.last_decision().clone());
        }
        assert!(decisions.iter().any(|d| !d.shed.is_empty()), "recording must shed");

        let mut rep = scheduler(SchedulerConfig::default());
        rep.script_decisions(decisions.clone());
        submit_all(&mut rep);
        let mut replayed = Vec::new();
        while !rep.is_idle() {
            rep.step().unwrap();
            replayed.push(rep.last_decision().clone());
        }
        assert_eq!(decisions, replayed, "shed decisions replay verbatim");
        assert_eq!(rep.sheds, rec.sheds);
        let outcomes = |s: &Scheduler<MockBackend>| {
            let mut f: Vec<_> = s
                .finished()
                .iter()
                .map(|st| (st.request.id, st.outcome(), st.generated.clone()))
                .collect();
            f.sort_by_key(|(id, ..)| *id);
            f
        };
        assert_eq!(outcomes(&rep), outcomes(&rec));
    }

    /// Wraps the mock backend with transient launch failures: the next
    /// `fail_prefills` prefill calls and the decode call numbered
    /// `fail_decode_at` (0-based over the run) error with the typed
    /// exhaustion marker, the way `SimEngine` does after
    /// `MAX_LAUNCH_ATTEMPTS` failed launches.
    struct FlakyBackend {
        inner: MockBackend,
        fail_prefills: usize,
        fail_decode_at: Option<usize>,
    }

    impl ModelBackend for FlakyBackend {
        type Cache = super::mock_backend::MockCache;

        fn max_seq(&self) -> usize {
            self.inner.max_seq()
        }
        fn decode_buckets(&self) -> Vec<usize> {
            self.inner.decode_buckets()
        }
        fn pad_id(&self) -> i32 {
            self.inner.pad_id()
        }
        fn prefill_group(
            &mut self,
            prompts: &[Vec<i32>],
        ) -> anyhow::Result<(Vec<i32>, Self::Cache)> {
            if self.fail_prefills > 0 {
                self.fail_prefills -= 1;
                self.inner.clock_us += 500.0;
                anyhow::bail!("{TRANSIENT_LAUNCH_MARKER}: injected prefill failure");
            }
            self.inner.prefill_group(prompts)
        }
        fn decode_group(
            &mut self,
            cache: Self::Cache,
            pos: usize,
            tokens: &[i32],
        ) -> anyhow::Result<(Vec<i32>, Self::Cache)> {
            if self.fail_decode_at == Some(self.inner.decodes) {
                self.inner.clock_us += 500.0;
                anyhow::bail!("{TRANSIENT_LAUNCH_MARKER}: injected decode failure");
            }
            self.inner.decode_group(cache, pos, tokens)
        }
        fn now_us(&self) -> f64 {
            self.inner.now_us()
        }
        fn wait_until_us(&mut self, t_us: f64) {
            self.inner.wait_until_us(t_us);
        }
    }

    #[test]
    fn launch_exhaustion_at_prefill_fails_the_group_and_run_continues() {
        let backend = FlakyBackend {
            inner: MockBackend::new(),
            fail_prefills: 1,
            fail_decode_at: None,
        };
        let mut s = Scheduler::new(
            backend,
            SchedulerConfig {
                max_batch: 2,
                ..SchedulerConfig::default()
            },
        );
        for id in 0..3 {
            s.submit(request(id, 16, 4));
        }
        // Regression: this used to be `?`-propagated and aborted the
        // whole run; now the first group degrades to typed failures and
        // the third request is still served.
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 3);
        assert_eq!(s.failures, 2, "both members of the failed group count");
        for f in s.finished() {
            match f.outcome() {
                RequestOutcome::Failed => {
                    assert!(f.request.id < 2);
                    assert!(f.generated.is_empty());
                    assert!(f.finish_us.is_some());
                }
                RequestOutcome::Completed => {
                    assert_eq!(f.request.id, 2);
                    assert_eq!(f.generated.len(), 4);
                }
                other => panic!("unexpected outcome {other:?}"),
            }
        }
        assert_eq!(s.kv.used_pages(), 0, "failed group's pages all returned");
    }

    #[test]
    fn launch_exhaustion_mid_decode_fails_unfinished_members_only() {
        // Budgets 3 and 6 share a group; the decode that would produce
        // the fourth token errors with the exhaustion marker.  The
        // short member already finished and keeps its tokens; the long
        // member degrades to Failed.
        let backend = FlakyBackend {
            inner: MockBackend::new(),
            fail_prefills: 0,
            fail_decode_at: Some(2),
        };
        let mut s = Scheduler::new(backend, SchedulerConfig::default());
        s.submit(request(0, 16, 3));
        s.submit(request(1, 16, 6));
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 2);
        assert_eq!(s.failures, 1);
        let short = s.finished().iter().find(|f| f.request.id == 0).unwrap();
        assert_eq!(short.outcome(), RequestOutcome::Completed);
        assert_eq!(short.generated.len(), 3, "finished member keeps its results");
        let long = s.finished().iter().find(|f| f.request.id == 1).unwrap();
        assert_eq!(long.outcome(), RequestOutcome::Failed);
        assert!(long.finish_us.is_some());
        assert_eq!(s.kv.used_pages(), 0);
    }

    #[test]
    fn preemption_storm_terminates_with_exactly_one_outcome_each() {
        // Check-only admission (reservations bypassed) over a 4-page
        // pool drives repeated preempt-and-requeue; a TTFT deadline
        // sheds victims whose window has passed.  The storm must
        // terminate with every request in exactly one terminal state.
        let cfg = SchedulerConfig {
            max_batch: 1,
            max_groups: 2,
            kv_pages: 4,
            kv_page_tokens: 16,
            ttft_deadline_us: 3000.0,
            ..SchedulerConfig::default()
        };
        let mut s = scheduler(cfg);
        for g in 0..2u64 {
            s.kv.register(g, 16).unwrap();
            let prompts = vec![vec![7i32; 16]];
            let (next, cache) = s.backend.prefill_group(&prompts).unwrap();
            let mut st = RequestState::new(request(g, 16, 32));
            st.generated.push(next[0]);
            st.first_token_us = Some(s.backend.now_us());
            s.groups.push(Group {
                members: vec![st],
                padded_len: 16,
                cache: Some(cache),
                pos: 16,
                bucket: 1,
                last_tokens: vec![next[0]],
            });
        }
        s.submit(request(2, 16, 8));
        s.submit(request(3, 16, 8));
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 4, "the storm terminates");
        assert!(s.preemptions >= 1, "backpressure must have preempted");
        for f in s.finished() {
            let flags =
                usize::from(f.rejected) + usize::from(f.shed) + usize::from(f.failed);
            assert!(flags <= 1, "outcome flags are exclusive for {}", f.request.id);
            assert!(f.finish_us.is_some(), "every outcome is terminal");
            if f.outcome() == RequestOutcome::Completed {
                assert_eq!(f.generated.len(), f.request.max_new_tokens);
            }
        }
        assert_eq!(s.kv.used_pages(), 0);
    }

    #[test]
    fn kv_pressure_fault_throttles_admission_but_never_deadlocks() {
        // A window sequestering 90% of the pool for (virtually) the
        // whole run: admissions serialize — pressure is invisible to an
        // idle scheduler (the liveness rule in `set_faults`), so each
        // new group starts only after the previous one retires — and
        // every request still completes.
        let mut s = scheduler(SchedulerConfig::default());
        s.set_faults(FaultPlan::parse("kv:0:1000000000:0.9").unwrap());
        for r in synthetic_requests(8, 251, 128, 21) {
            s.submit(r);
        }
        let mut guard = StallGuard::default();
        while !s.is_idle() {
            s.step().unwrap();
            assert!(s.groups.len() <= 1, "pressure serializes admission");
            guard.observe(s.progress_marker(), || "kv pressure stall".into()).unwrap();
        }
        assert_eq!(s.finished().len(), 8);
        assert_eq!(s.sheds, 0, "no deadlines armed: pressure queues, never sheds");
        assert!(
            s.finished().iter().all(|f| f.outcome() == RequestOutcome::Completed),
            "pressure delays work but loses none"
        );
        assert_eq!(s.kv.used_pages(), 0);
    }

    #[test]
    fn zero_admission_capacity_sheds_overdue_instead_of_blocking() {
        // Full sequestration (capped at pool-1 internally) makes
        // admission capacity zero while a group is in flight; the
        // queued request's TTFT deadline passes during the blackout and
        // it must shed rather than wait for capacity that never comes.
        let cfg = SchedulerConfig {
            max_batch: 1,
            max_groups: 2,
            kv_pages: 8,
            kv_page_tokens: 16,
            ttft_deadline_us: 1500.0,
            ..SchedulerConfig::default()
        };
        let mut s = scheduler(cfg);
        s.set_faults(FaultPlan::parse("kv:0:1000000000:1.0").unwrap());
        s.submit(request(0, 16, 8));
        s.submit(request(1, 16, 8));
        s.run_to_completion().unwrap();
        assert_eq!(s.finished().len(), 2);
        let first = s.finished().iter().find(|f| f.request.id == 0).unwrap();
        assert_eq!(first.outcome(), RequestOutcome::Completed);
        let second = s.finished().iter().find(|f| f.request.id == 1).unwrap();
        assert_eq!(second.outcome(), RequestOutcome::Shed);
        assert_eq!(s.sheds, 1);
        assert_eq!(s.kv.used_pages(), 0);
    }
}
