//! Serving layer: request model, paged-KV manager, continuous batcher,
//! and the serving demo that drives a runtime [`Backend`].
//!
//! This is the vLLM/Orca-style substrate the paper's workloads sit on
//! (§II-A): admission control against a paged KV pool, iteration-level
//! scheduling, bucketed continuous batching — with the rust coordinator
//! owning the event loop and a pluggable engine doing the math.  The
//! demo runs against the always-available simulated engine
//! (`runtime::SimEngine`) by default; with the `real-pjrt` feature it
//! can also drive the PJRT engine over AOT artifacts.

pub mod batcher;
pub mod kv;
pub mod request;

pub use batcher::{ModelBackend, Scheduler, SchedulerConfig};
pub use kv::PagedKvManager;
pub use request::{synthetic_requests, Request, RequestState};

use crate::runtime::backend::Backend;
use crate::trace::{EventKind, Trace};
use crate::util::json::Json;
use crate::util::stats::Summary;

#[cfg(feature = "real-pjrt")]
use crate::runtime::Engine;

/// Real-mode cache handle: the PJRT cache literal + its bucket batch.
#[cfg(feature = "real-pjrt")]
pub struct EngineCache {
    literal: xla::Literal,
    bucket: usize,
}

#[cfg(feature = "real-pjrt")]
impl ModelBackend for Engine {
    type Cache = EngineCache;

    fn max_seq(&self) -> usize {
        self.config().max_seq
    }

    fn decode_buckets(&self) -> Vec<usize> {
        Engine::decode_buckets(self)
    }

    fn prefill_group(
        &mut self,
        prompts: &[Vec<i32>],
    ) -> anyhow::Result<(Vec<i32>, EngineCache)> {
        let out = self.prefill(prompts)?;
        let next = out.logits.iter().map(|l| Engine::argmax(l)).collect();
        Ok((
            next,
            EngineCache {
                literal: out.cache,
                bucket: out.bucket_batch,
            },
        ))
    }

    fn decode_group(
        &mut self,
        cache: EngineCache,
        pos: usize,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<i32>, EngineCache)> {
        // Pad/trim the token vector to the cache's compiled bucket.
        let mut toks = tokens.to_vec();
        toks.resize(cache.bucket, 0);
        let out = self.decode(cache.literal, pos, &toks)?;
        let next = out
            .logits
            .iter()
            .take(tokens.len())
            .map(|l| Engine::argmax(l))
            .collect();
        Ok((
            next,
            EngineCache {
                literal: out.cache,
                bucket: cache.bucket,
            },
        ))
    }

    fn now_us(&self) -> f64 {
        self.recorder.now_us()
    }
}

#[cfg(feature = "real-pjrt")]
impl Backend for Engine {
    fn variant(&self) -> &str {
        Engine::variant(self)
    }

    fn vocab(&self) -> usize {
        self.config().vocab
    }

    fn null_run(&mut self) -> anyhow::Result<(f64, f64)> {
        Engine::null_run(self)
    }

    fn take_trace(&mut self) -> Trace {
        Engine::take_trace(self)
    }
}

/// Outcome of the serving demo.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub variant: String,
    pub requests: usize,
    pub iterations: usize,
    pub wall_us: f64,
    pub tokens_generated: usize,
    pub ttft_us: Summary,
    pub tpot_us: Summary,
    /// Σ host prep + execute-call time from the captured trace.
    pub orchestration_us: f64,
    /// Σ device computation time from the captured trace.
    pub device_us: f64,
    /// Null-executable launch floor.
    pub null_floor_us: Summary,
    pub executions: usize,
}

impl ServeSummary {
    pub fn hdbi(&self) -> f64 {
        let total = self.orchestration_us + self.device_us;
        if total == 0.0 {
            0.5
        } else {
            self.device_us / total
        }
    }

    pub fn throughput_tps(&self) -> f64 {
        if self.wall_us <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / (self.wall_us / 1e6)
        }
    }

    pub fn render(&self) -> String {
        format!(
            "== serving ({}) ==\n\
             requests          {}\n\
             iterations        {}\n\
             tokens generated  {}\n\
             wall              {:.1} ms\n\
             throughput        {:.1} tok/s\n\
             TTFT mean/p95     {:.2} / {:.2} ms\n\
             TPOT mean/p95     {:.2} / {:.2} ms\n\
             orchestration     {:.2} ms ({} executions)\n\
             device active     {:.2} ms\n\
             HDBI              {:.2}\n\
             null floor        {:.1} us (p50 {:.1}, p95 {:.1})\n",
            self.variant,
            self.requests,
            self.iterations,
            self.tokens_generated,
            self.wall_us / 1000.0,
            self.throughput_tps(),
            self.ttft_us.mean / 1000.0,
            self.ttft_us.p95 / 1000.0,
            self.tpot_us.mean / 1000.0,
            self.tpot_us.p95 / 1000.0,
            self.orchestration_us / 1000.0,
            self.executions,
            self.device_us / 1000.0,
            self.hdbi(),
            self.null_floor_us.mean,
            self.null_floor_us.p50,
            self.null_floor_us.p95,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("variant", self.variant.as_str())
            .with("requests", self.requests)
            .with("iterations", self.iterations)
            .with("wall_us", self.wall_us)
            .with("tokens_generated", self.tokens_generated)
            .with("throughput_tps", self.throughput_tps())
            .with("ttft_mean_us", self.ttft_us.mean)
            .with("ttft_p95_us", self.ttft_us.p95)
            .with("tpot_mean_us", self.tpot_us.mean)
            .with("tpot_p95_us", self.tpot_us.p95)
            .with("orchestration_us", self.orchestration_us)
            .with("device_us", self.device_us)
            .with("hdbi", self.hdbi())
            .with("null_floor_mean_us", self.null_floor_us.mean)
            .with("executions", self.executions)
    }
}

/// Host/device split of an engine trace.
///
/// Engines run each executable invocation synchronously, so
/// device-active time is the execute window (`RuntimeApi`) plus result
/// materialization (`Kernel`), while the host-orchestration analog is
/// the preparation span (`AtenOp`: batch/literal assembly + executable
/// selection).
pub fn real_trace_split(trace: &Trace) -> (f64, f64, usize) {
    let mut host = 0.0;
    let mut dev = 0.0;
    let mut n = 0usize;
    for e in &trace.events {
        match e.kind {
            EventKind::AtenOp => host += e.dur_us,
            EventKind::RuntimeApi => dev += e.dur_us,
            EventKind::Kernel => {
                dev += e.dur_us;
                n += 1;
            }
            _ => {}
        }
    }
    (host, dev, n)
}

/// Run the serving demo over any runtime [`Backend`]: serve a synthetic
/// request mix through the continuous batcher, measure the null-kernel
/// floor, and summarize the captured trace.
pub fn serve_with<B: Backend>(
    backend: B,
    n_requests: usize,
    max_batch: usize,
    seed: u64,
) -> anyhow::Result<ServeSummary> {
    let vocab = backend.vocab();
    let max_seq = backend.max_seq();
    let variant = backend.variant().to_string();

    let cfg = SchedulerConfig {
        max_batch,
        max_groups: 2,
        kv_pages: 64,
        kv_page_tokens: 16,
    };
    let mut sched = Scheduler::new(backend, cfg);
    for r in synthetic_requests(n_requests, vocab, max_seq, seed) {
        sched.submit(r);
    }
    sched.run_to_completion()?;
    let iterations = sched.iterations;

    // Launch-floor probe (Table III analog).
    let mut floor_runs = Vec::with_capacity(30);
    {
        let engine = &mut sched.backend;
        for i in 0..35 {
            let (_, launch) = engine.null_run()?;
            if i >= 5 {
                floor_runs.push(launch);
            }
        }
    }

    let finished = sched.finished().to_vec();
    let trace = sched.backend.take_trace();
    let (host, dev, execs) = real_trace_split(&trace);

    let ttfts: Vec<f64> = finished.iter().filter_map(|f| f.ttft_us()).collect();
    let tpots: Vec<f64> = finished.iter().filter_map(|f| f.tpot_us()).collect();
    let tokens: usize = finished.iter().map(|f| f.generated.len()).sum();

    Ok(ServeSummary {
        variant,
        requests: finished.len(),
        iterations,
        wall_us: trace.meta.wall_us,
        tokens_generated: tokens,
        ttft_us: Summary::of(&ttfts),
        tpot_us: Summary::of(&tpots),
        orchestration_us: host,
        device_us: dev,
        null_floor_us: Summary::of(&floor_runs),
        executions: execs,
    })
}

/// Serving demo on the simulated engine (default build, no PJRT).
pub fn run_sim_server_demo(
    model_name: &str,
    platform_name: &str,
    n_requests: usize,
    max_batch: usize,
    seed: u64,
) -> anyhow::Result<ServeSummary> {
    let model = crate::models::by_name(model_name)?;
    let platform = crate::hardware::Platform::by_name(platform_name)?;
    let engine = crate::runtime::SimEngine::with_defaults(model, platform, seed);
    serve_with(engine, n_requests, max_batch, seed)
}

/// Run the full real-mode demo: load artifacts, then [`serve_with`]
/// over the PJRT engine.
#[cfg(feature = "real-pjrt")]
pub fn run_server_demo(
    artifacts_dir: &std::path::Path,
    variant: &str,
    n_requests: usize,
    max_batch: usize,
    seed: u64,
) -> anyhow::Result<ServeSummary> {
    let engine = Engine::load(artifacts_dir, variant)?;
    serve_with(engine, n_requests, max_batch, seed)
}
