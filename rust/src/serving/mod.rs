//! Serving layer: request model, paged-KV manager, continuous batcher,
//! the serving demo that drives a runtime [`Backend`], and the
//! arrival-driven load generator ([`loadgen`], `taxbreak loadgen`).
//!
//! This is the vLLM/Orca-style substrate the paper's workloads sit on
//! (§II-A): admission control against a paged KV pool, iteration-level
//! scheduling, bucketed continuous batching — with the rust coordinator
//! owning the event loop and a pluggable engine doing the math.  The
//! demo runs against the always-available simulated engine
//! (`runtime::SimEngine`) by default; with the `real-pjrt` feature it
//! can also drive the PJRT engine over AOT artifacts.

pub mod batcher;
pub mod kv;
pub mod loadgen;
pub mod replay;
pub mod request;

pub use batcher::{ModelBackend, Scheduler, SchedulerConfig, StepDecision};
pub use kv::PagedKvManager;
pub use loadgen::{
    run_sim_loadgen, run_sim_loadgen_streaming, LenDist, LoadgenConfig, LoadgenReport, SinkFactory,
};
pub use replay::{replay, ReplayOutcome};
pub use request::{synthetic_requests, Request, RequestOutcome, RequestState};

use crate::runtime::backend::Backend;
use crate::trace::{EventKind, Trace, TraceEvent};
use crate::util::json::Json;
use crate::util::stats::Summary;

/// Eq. 3 (HDBI) on one host/device time pair — re-exported from the
/// single crate-wide implementation in [`crate::taxbreak::decompose`]
/// (which also documents the empty-run `0.5` convention).  Used by
/// [`ServeSummary`], [`loadgen::PhaseSplit`] and [`loadgen::ModelRun`].
pub use crate::taxbreak::decompose::hdbi_of;

/// Host/device attribution of one trace event under the serving split
/// (see [`real_trace_split`] for the rationale): returns
/// `(host_us, device_us, kernel_count)`.
pub fn event_split(e: &TraceEvent) -> (f64, f64, usize) {
    match e.kind {
        EventKind::AtenOp => (e.dur_us, 0.0, 0),
        EventKind::RuntimeApi => (0.0, e.dur_us, 0),
        EventKind::Kernel => (0.0, e.dur_us, 1),
        _ => (0.0, 0.0, 0),
    }
}

/// Upper bound (exclusive) for prompt-content token draws: the
/// backend's vocabulary with its pad id carved out.  Pad ids outside
/// `[0, vocab)` (the mock's `-1` sentinel) need no carve-out; in-vocab
/// pad ids must sit at the top of the range (the engines' convention)
/// so the exclusion stays expressible as a bound — anything else is an
/// error, since a range draw could then emit the pad as content.
pub fn prompt_token_bound<M: ModelBackend>(backend: &M, vocab: usize) -> anyhow::Result<usize> {
    let pad = backend.pad_id();
    if pad < 0 || pad as usize >= vocab {
        Ok(vocab.max(1))
    } else {
        anyhow::ensure!(
            pad as usize == vocab - 1,
            "in-vocab pad id {pad} must be the top vocab id {} so prompt draws can exclude it",
            vocab - 1
        );
        Ok((vocab - 1).max(1))
    }
}

#[cfg(feature = "real-pjrt")]
use crate::runtime::Engine;

/// Real-mode cache handle: the PJRT cache literal + its bucket batch.
#[cfg(feature = "real-pjrt")]
pub struct EngineCache {
    literal: xla::Literal,
    bucket: usize,
}

#[cfg(feature = "real-pjrt")]
impl ModelBackend for Engine {
    type Cache = EngineCache;

    fn max_seq(&self) -> usize {
        self.config().max_seq
    }

    fn decode_buckets(&self) -> Vec<usize> {
        Engine::decode_buckets(self)
    }

    fn pad_id(&self) -> i32 {
        // Top vocab id reserved for padding: a valid embedding index
        // that workload generation never emits as prompt content.
        (self.config().vocab - 1) as i32
    }

    fn prefill_group(
        &mut self,
        prompts: &[Vec<i32>],
    ) -> anyhow::Result<(Vec<i32>, EngineCache)> {
        let out = self.prefill(prompts)?;
        let next = out.logits.iter().map(|l| Engine::argmax(l)).collect();
        Ok((
            next,
            EngineCache {
                literal: out.cache,
                bucket: out.bucket_batch,
            },
        ))
    }

    fn decode_group(
        &mut self,
        cache: EngineCache,
        pos: usize,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<i32>, EngineCache)> {
        // Pad/trim the token vector to the cache's compiled bucket
        // (unused slots carry the reserved pad id).
        let pad = self.pad_id();
        let mut toks = tokens.to_vec();
        toks.resize(cache.bucket, pad);
        let out = self.decode(cache.literal, pos, &toks)?;
        let next = out
            .logits
            .iter()
            .take(tokens.len())
            .map(|l| Engine::argmax(l))
            .collect();
        Ok((
            next,
            EngineCache {
                literal: out.cache,
                bucket: cache.bucket,
            },
        ))
    }

    fn now_us(&self) -> f64 {
        self.recorder.now_us()
    }
}

#[cfg(feature = "real-pjrt")]
impl Backend for Engine {
    fn variant(&self) -> &str {
        Engine::variant(self)
    }

    fn vocab(&self) -> usize {
        self.config().vocab
    }

    fn null_run(&mut self) -> anyhow::Result<(f64, f64)> {
        Engine::null_run(self)
    }

    fn take_trace(&mut self) -> Trace {
        Engine::take_trace(self)
    }

    fn drain_events(&mut self) -> Vec<TraceEvent> {
        self.recorder.drain_events()
    }

    fn trace_meta(&self) -> crate::trace::TraceMeta {
        self.recorder.meta_now()
    }
}

/// Outcome of the serving demo.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub variant: String,
    pub requests: usize,
    pub iterations: usize,
    pub wall_us: f64,
    pub tokens_generated: usize,
    pub ttft_us: Summary,
    pub tpot_us: Summary,
    /// Σ host prep + execute-call time from the captured trace.
    pub orchestration_us: f64,
    /// Σ device computation time from the captured trace.
    pub device_us: f64,
    /// Null-executable launch floor.
    pub null_floor_us: Summary,
    pub executions: usize,
}

impl ServeSummary {
    pub fn hdbi(&self) -> f64 {
        hdbi_of(self.orchestration_us, self.device_us)
    }

    pub fn throughput_tps(&self) -> f64 {
        if self.wall_us <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / (self.wall_us / 1e6)
        }
    }

    pub fn render(&self) -> String {
        format!(
            "== serving ({}) ==\n\
             requests          {}\n\
             iterations        {}\n\
             tokens generated  {}\n\
             wall              {:.1} ms\n\
             throughput        {:.1} tok/s\n\
             TTFT mean/p95     {:.2} / {:.2} ms\n\
             TPOT mean/p95     {:.2} / {:.2} ms\n\
             orchestration     {:.2} ms ({} executions)\n\
             device active     {:.2} ms\n\
             HDBI              {:.2}\n\
             null floor        {:.1} us (p50 {:.1}, p95 {:.1})\n",
            self.variant,
            self.requests,
            self.iterations,
            self.tokens_generated,
            self.wall_us / 1000.0,
            self.throughput_tps(),
            self.ttft_us.mean / 1000.0,
            self.ttft_us.p95 / 1000.0,
            self.tpot_us.mean / 1000.0,
            self.tpot_us.p95 / 1000.0,
            self.orchestration_us / 1000.0,
            self.executions,
            self.device_us / 1000.0,
            self.hdbi(),
            self.null_floor_us.mean,
            self.null_floor_us.p50,
            self.null_floor_us.p95,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("variant", self.variant.as_str())
            .with("requests", self.requests)
            .with("iterations", self.iterations)
            .with("wall_us", self.wall_us)
            .with("tokens_generated", self.tokens_generated)
            .with("throughput_tps", self.throughput_tps())
            .with("ttft_mean_us", self.ttft_us.mean)
            .with("ttft_p95_us", self.ttft_us.p95)
            .with("tpot_mean_us", self.tpot_us.mean)
            .with("tpot_p95_us", self.tpot_us.p95)
            .with("orchestration_us", self.orchestration_us)
            .with("device_us", self.device_us)
            .with("hdbi", self.hdbi())
            .with("null_floor_mean_us", self.null_floor_us.mean)
            .with("executions", self.executions)
    }
}

/// Host/device split of an engine trace.
///
/// Engines run each executable invocation synchronously, so
/// device-active time is the execute window (`RuntimeApi`) plus result
/// materialization (`Kernel`), while the host-orchestration analog is
/// the preparation span (`AtenOp`: batch/literal assembly + executable
/// selection).
pub fn real_trace_split(trace: &Trace) -> (f64, f64, usize) {
    let mut host = 0.0;
    let mut dev = 0.0;
    let mut n = 0usize;
    for e in &trace.events {
        let (h, d, k) = event_split(e);
        host += h;
        dev += d;
        n += k;
    }
    (host, dev, n)
}

/// Run the serving demo over any runtime [`Backend`]: serve a synthetic
/// request mix through the continuous batcher, measure the null-kernel
/// floor, and summarize the captured trace.
pub fn serve_with<B: Backend>(
    backend: B,
    n_requests: usize,
    max_batch: usize,
    seed: u64,
) -> anyhow::Result<ServeSummary> {
    // Prompts draw below the pad-aware bound so padding can never
    // collide with content.
    let vocab = prompt_token_bound(&backend, backend.vocab())?;
    let max_seq = backend.max_seq();
    let variant = backend.variant().to_string();

    let cfg = SchedulerConfig {
        max_batch,
        max_groups: 2,
        kv_pages: 64,
        kv_page_tokens: 16,
        ..SchedulerConfig::default()
    };
    let mut sched = Scheduler::new(backend, cfg);
    for r in synthetic_requests(n_requests, vocab, max_seq, seed) {
        sched.submit(r);
    }
    sched.run_to_completion()?;
    let iterations = sched.iterations;

    // Launch-floor probe (Table III analog).
    let mut floor_runs = Vec::with_capacity(30);
    {
        let engine = &mut sched.backend;
        for i in 0..35 {
            let (_, launch) = engine.null_run()?;
            if i >= 5 {
                floor_runs.push(launch);
            }
        }
    }

    let finished = sched.finished().to_vec();
    let trace = sched.backend.take_trace();
    let (host, dev, execs) = real_trace_split(&trace);

    let ttfts: Vec<f64> = finished.iter().filter_map(|f| f.ttft_us()).collect();
    let tpots: Vec<f64> = finished.iter().filter_map(|f| f.tpot_us()).collect();
    let tokens: usize = finished.iter().map(|f| f.generated.len()).sum();

    Ok(ServeSummary {
        variant,
        requests: finished.len(),
        iterations,
        wall_us: trace.meta.wall_us,
        tokens_generated: tokens,
        ttft_us: Summary::of(&ttfts),
        tpot_us: Summary::of(&tpots),
        orchestration_us: host,
        device_us: dev,
        null_floor_us: Summary::of(&floor_runs),
        executions: execs,
    })
}

/// Serving demo on the simulated engine (default build, no PJRT).
pub fn run_sim_server_demo(
    model_name: &str,
    platform_name: &str,
    n_requests: usize,
    max_batch: usize,
    seed: u64,
) -> anyhow::Result<ServeSummary> {
    let model = crate::models::by_name(model_name)?;
    let platform = crate::hardware::Platform::by_name(platform_name)?;
    let engine = crate::runtime::SimEngine::with_defaults(model, platform, seed);
    serve_with(engine, n_requests, max_batch, seed)
}

/// Run the full real-mode demo: load artifacts, then [`serve_with`]
/// over the PJRT engine.
#[cfg(feature = "real-pjrt")]
pub fn run_server_demo(
    artifacts_dir: &std::path::Path,
    variant: &str,
    n_requests: usize,
    max_batch: usize,
    seed: u64,
) -> anyhow::Result<ServeSummary> {
    let engine = Engine::load(artifacts_dir, variant)?;
    serve_with(engine, n_requests, max_batch, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::batcher::mock_backend::MockBackend;

    #[test]
    fn hdbi_of_shapes() {
        assert_eq!(hdbi_of(0.0, 0.0), 0.5);
        assert_eq!(hdbi_of(1.0, 3.0), 0.75);
        assert!(hdbi_of(1e9, 1.0) > 0.0);
    }

    #[test]
    fn prompt_token_bound_respects_pad_conventions() {
        // Mock pad (-1) sits outside the vocab: nothing carved out.
        let mock = MockBackend::new();
        assert_eq!(prompt_token_bound(&mock, 251).unwrap(), 251);
        // SimEngine reserves the top vocab id.
        let engine = crate::runtime::SimEngine::with_defaults(
            crate::models::gpt2(),
            crate::hardware::Platform::h200(),
            1,
        );
        let vocab = Backend::vocab(&engine);
        assert_eq!(prompt_token_bound(&engine, vocab).unwrap(), vocab - 1);
        // An in-vocab pad anywhere else is an error, not a panic.
        assert!(prompt_token_bound(&engine, vocab + 10).is_err());
    }
}
