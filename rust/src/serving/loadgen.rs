//! Arrival-driven load generator over the serving scheduler
//! (`taxbreak loadgen`).
//!
//! Drives the reservation-backed scheduler with a Poisson arrival
//! process and configurable prompt/output-length distributions, for a
//! mix of models (dense vs MoE — the paper's §V-A contrast), and
//! reports throughput, TTFT/TPOT, KV occupancy and per-phase HDBI.
//! Statistics reuse [`Summary`] for latency distributions and
//! [`Welford`] for the streaming KV-occupancy track; rendering reuses
//! `util::table` like `taxbreak::report`.
//!
//! The generator is closed over the backend's *virtual* clock: idle
//! gaps between arrivals advance the clock via
//! [`ModelBackend::wait_until_us`], so offered load (not just service
//! time) shapes TTFT — the host-bound serving story the paper's
//! framework-tax analysis targets.

use std::collections::VecDeque;

use crate::faults::FaultPlan;
use crate::obs::{OnlineDecomposer, ServingProbe, Telemetry};
use crate::runtime::backend::Backend;
use crate::serving::batcher::{ModelBackend, StallGuard, StepDecision};
use crate::serving::request::RequestOutcome;
use crate::serving::{event_split, hdbi_of, prompt_token_bound, Request, Scheduler, SchedulerConfig};
use crate::trace::{
    EventKind, NullSink, ReplayArgs, Trace, TraceBufferSink, TraceEvent, TraceMeta, TraceSink,
    Track,
};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::{Summary, Welford};
use crate::util::table::{ms, ratio, Table};

/// A length distribution for prompts or decode budgets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LenDist {
    /// Uniform over `lo..=hi`.
    Uniform { lo: usize, hi: usize },
    /// Log-normal with the given median and shape (right-skewed, like
    /// production prompt mixes); samples round to ≥ 1.
    LogNormal { median: f64, sigma: f64 },
}

impl LenDist {
    /// Parse `uniform:LO:HI` or `lognormal:MEDIAN:SIGMA`.
    pub fn parse(s: &str) -> anyhow::Result<LenDist> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["uniform", lo, hi] => {
                let lo: usize = lo
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad uniform lo '{lo}'"))?;
                let hi: usize = hi
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad uniform hi '{hi}'"))?;
                anyhow::ensure!(lo >= 1 && lo <= hi, "uniform needs 1 <= lo <= hi, got {lo}:{hi}");
                Ok(LenDist::Uniform { lo, hi })
            }
            ["lognormal", med, sigma] => {
                let median: f64 = med
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad lognormal median '{med}'"))?;
                let sigma: f64 = sigma
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad lognormal sigma '{sigma}'"))?;
                anyhow::ensure!(median >= 1.0 && sigma >= 0.0, "lognormal needs median >= 1, sigma >= 0");
                Ok(LenDist::LogNormal { median, sigma })
            }
            _ => anyhow::bail!(
                "length distribution must be uniform:LO:HI or lognormal:MEDIAN:SIGMA, got '{s}'"
            ),
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LenDist::Uniform { lo, hi } => lo + rng.below(hi - lo + 1),
            LenDist::LogNormal { median, sigma } => {
                rng.lognormal_med(median, sigma).round().max(1.0) as usize
            }
        }
    }

    pub fn describe(&self) -> String {
        match *self {
            LenDist::Uniform { lo, hi } => format!("uniform:{lo}:{hi}"),
            LenDist::LogNormal { median, sigma } => format!("lognormal:{median}:{sigma}"),
        }
    }
}

/// Load-generation parameters.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Requests per model.
    pub requests: usize,
    /// Mean Poisson arrival rate, requests per second of virtual time;
    /// 0 sends everything at t = 0 (closed loop).
    pub rate_per_s: f64,
    pub prompt_len: LenDist,
    pub output_len: LenDist,
    pub seed: u64,
    pub sched: SchedulerConfig,
    /// Serving replicas (`--devices N`): requests round-robin across N
    /// independent engine+scheduler replicas, each owning `kv_pages/N`
    /// of the pool; the report carries per-device KV occupancy and
    /// HDBI. 1 = the classic single-engine run.
    pub devices: usize,
    /// CUDA streams per engine (`--streams N`): invocations rotate over
    /// N device lanes in the trace/Chrome timeline (a synchronous
    /// engine cannot overlap them — documented in `SimEngineConfig`).
    pub streams: usize,
    /// Keep each run's captured trace on the [`ModelRun`] — the
    /// serving-side what-if hook (`taxbreak loadgen --capture` /
    /// `--chrome-out`, then `taxbreak whatif --trace`).
    pub capture: bool,
    /// Attach live telemetry ([`ModelRun::telemetry`]): an
    /// [`OnlineDecomposer`] in the event fan-out plus a [`ServingProbe`]
    /// sampling KV/queue state per step (`taxbreak loadgen
    /// --metrics-out`). Streaming — does not imply `capture`.
    pub metrics: bool,
    /// Virtual-time window for the per-window decomposition series, us;
    /// `<= 0` collapses to a single whole-run window.
    pub window_us: f64,
    /// Fault-injection spec (`--faults`, [`FaultPlan::parse`] syntax):
    /// the same seeded plan arms every replica's engine (device stalls,
    /// host jitter, launch failures) and scheduler (KV pressure), and
    /// each window lands in the capture as a spec-v4 `fault` event.
    /// `None` injects nothing and is byte-identical to pre-fault runs.
    pub faults: Option<String>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            requests: 100,
            rate_per_s: 1000.0,
            prompt_len: LenDist::Uniform { lo: 8, hi: 48 },
            output_len: LenDist::Uniform { lo: 4, hi: 12 },
            seed: 2026,
            sched: SchedulerConfig::default(),
            devices: 1,
            streams: 1,
            capture: false,
            metrics: false,
            window_us: 0.0,
            faults: None,
        }
    }
}

/// Generate the arrival-stamped request mix.  Prompt tokens draw from
/// `[0, prompt_vocab)` — callers pass the backend vocab *minus the
/// reserved pad id*.  Lengths clamp to the backend's `max_seq` budget.
pub fn generate_workload(
    cfg: &LoadgenConfig,
    prompt_vocab: usize,
    max_seq: usize,
) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed).fork_str("loadgen");
    let mut t_us = 0.0f64;
    (0..cfg.requests as u64)
        .map(|id| {
            if cfg.rate_per_s > 0.0 {
                // Exponential inter-arrival times (Poisson process).
                let u = rng.next_f64();
                t_us += -(1.0 - u).ln() / cfg.rate_per_s * 1e6;
            }
            let prompt_cap = max_seq.saturating_sub(2).max(1);
            let prompt_len = cfg.prompt_len.sample(&mut rng).clamp(1, prompt_cap);
            let budget = max_seq.saturating_sub(prompt_len + 1);
            let max_new = cfg.output_len.sample(&mut rng).clamp(1, budget.max(1));
            let prompt: Vec<i32> = (0..prompt_len)
                .map(|_| rng.below(prompt_vocab) as i32)
                .collect();
            Request {
                id,
                prompt,
                max_new_tokens: max_new,
                arrival_us: t_us,
            }
        })
        .collect()
}

/// Host/device split of one serving phase (prefill or decode).
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseSplit {
    pub phase: &'static str,
    /// Σ host preparation time (`AtenOp` spans), us.
    pub host_us: f64,
    /// Σ execute-call + device computation time, us.
    pub device_us: f64,
    pub kernels: usize,
}

impl PhaseSplit {
    pub fn hdbi(&self) -> f64 {
        hdbi_of(self.host_us, self.device_us)
    }
}

/// Split a serving trace into per-phase host/device totals, classifying
/// each invocation (correlation-id group) by its `TorchOp` name.
pub fn per_phase_split(trace: &Trace) -> Vec<PhaseSplit> {
    let mut phases = [
        PhaseSplit { phase: "prefill", host_us: 0.0, device_us: 0.0, kernels: 0 },
        PhaseSplit { phase: "decode", host_us: 0.0, device_us: 0.0, kernels: 0 },
    ];
    let mut phase_of = std::collections::HashMap::new();
    for e in &trace.events {
        if e.kind == EventKind::TorchOp {
            if let Some(i) = phases.iter().position(|p| e.name.contains(p.phase)) {
                phase_of.insert(e.correlation_id, i);
            }
        }
    }
    for e in &trace.events {
        let Some(&i) = phase_of.get(&e.correlation_id) else {
            continue;
        };
        let (host, dev, kernels) = event_split(e);
        phases[i].host_us += host;
        phases[i].device_us += dev;
        phases[i].kernels += kernels;
    }
    phases.to_vec()
}

/// Streaming accumulator of the serving splits (per-phase + totals):
/// the single-pass equivalent of [`per_phase_split`] +
/// [`crate::serving::real_trace_split`], fed one event at a time as the
/// backend drains, so capture no longer requires holding the whole
/// trace in memory.
///
/// Classification relies on the invariant both engines guarantee: the
/// events of one invocation share a correlation id and are emitted
/// contiguously, `TorchOp` first. (For arbitrary, possibly reordered
/// traces, use the two-pass [`per_phase_split`].)
#[derive(Debug, Clone)]
struct ServingStats {
    phases: [PhaseSplit; 2],
    /// Phase of the invocation currently streaming through:
    /// `(correlation_id, phase index)`.
    current: Option<(u64, usize)>,
    host_us: f64,
    device_us: f64,
    kernels: usize,
}

impl ServingStats {
    fn new() -> ServingStats {
        ServingStats {
            phases: [
                PhaseSplit { phase: "prefill", host_us: 0.0, device_us: 0.0, kernels: 0 },
                PhaseSplit { phase: "decode", host_us: 0.0, device_us: 0.0, kernels: 0 },
            ],
            current: None,
            host_us: 0.0,
            device_us: 0.0,
            kernels: 0,
        }
    }

    fn observe(&mut self, e: &TraceEvent) {
        let (host, dev, kernels) = event_split(e);
        self.host_us += host;
        self.device_us += dev;
        self.kernels += kernels;
        if e.kind == EventKind::TorchOp {
            self.current = self
                .phases
                .iter()
                .position(|p| e.name.contains(p.phase))
                .map(|i| (e.correlation_id, i));
        }
        if let Some((corr, i)) = self.current {
            if corr == e.correlation_id {
                let p = &mut self.phases[i];
                p.host_us += host;
                p.device_us += dev;
                p.kernels += kernels;
            }
        }
    }
}

/// Per-device (replica) serving statistics — one row per `--devices`
/// replica, partitioning the model run.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceLoad {
    pub device: u32,
    pub completed: usize,
    pub tokens_generated: usize,
    pub wall_us: f64,
    /// This replica's KV pool utilization (its `kv_pages/N` share).
    pub kv_occupancy_mean: f64,
    pub kv_occupancy_max: f64,
    /// Host/device balance of this replica's trace.
    pub hdbi: f64,
}

/// Outcome of one model's load run.
#[derive(Debug, Clone)]
pub struct ModelRun {
    pub model: String,
    pub variant: String,
    pub moe: bool,
    /// Requests served to completion (excludes rejected ones).
    pub completed: usize,
    /// Requests the scheduler refused as unservable
    /// (`RequestState::rejected`, e.g. prompt longer than the context
    /// window).
    pub rejected: usize,
    /// Requests terminated by deadline-aware load shedding
    /// ([`RequestOutcome::Shed`]).
    pub sheds: usize,
    /// Requests terminated by launch-retry exhaustion
    /// ([`RequestOutcome::Failed`]).
    pub failed: usize,
    /// Transient kernel-launch re-issues the backend paid (each one
    /// re-ran the launch path with exponential backoff, DESIGN.md §16).
    pub retries: u64,
    /// Completed requests that blew a configured TTFT/TPOT deadline
    /// (0 when deadlines are disabled).
    pub deadline_misses: usize,
    /// p99 lateness (us past the deadline) over the missing requests;
    /// 0 when nothing missed.
    pub deadline_miss_p99_us: f64,
    pub iterations: usize,
    pub preemptions: usize,
    /// Requests injected before their scheduled arrival because the
    /// backend clock could not jump forward (wall-clock backends).
    /// Non-zero means the configured arrival rate was not honored and
    /// the run degraded toward closed-loop.
    pub late_arrivals: usize,
    pub wall_us: f64,
    pub tokens_generated: usize,
    pub ttft_us: Summary,
    pub tpot_us: Summary,
    /// Streaming KV pool utilization (used pages / total), sampled once
    /// per scheduler iteration.
    pub kv_occupancy_mean: f64,
    pub kv_occupancy_max: f64,
    pub phases: Vec<PhaseSplit>,
    /// Per-device partition of this run (one entry per replica; a
    /// single entry for the classic `--devices 1` run).
    pub per_device: Vec<DeviceLoad>,
    /// The captured serving trace (only with [`LoadgenConfig::capture`])
    /// — input for Chrome export and `taxbreak whatif` replay. Replica
    /// runs merge into one trace with `device`-stamped events and
    /// disjoint correlation-id ranges.
    pub trace: Option<Trace>,
    /// Live telemetry (only with [`LoadgenConfig::metrics`]): the
    /// finalized online decomposition (windowed HDBI series, totals
    /// bit-identical to the post-hoc pass) plus the serving probe's
    /// KV/queue/latency samples.
    pub telemetry: Option<Telemetry>,
    /// High-water mark of events held between backend drain points (one
    /// scheduler step's output). This — not the run's total event count
    /// — bounds the streaming capture path's memory; the O(1)-memory
    /// test pins it. Buffered capture ([`LoadgenConfig::capture`]) still
    /// holds the whole trace on top of this.
    pub peak_buffered_events: usize,
}

impl ModelRun {
    pub fn orchestration_us(&self) -> f64 {
        self.phases.iter().map(|p| p.host_us).sum()
    }

    pub fn device_us(&self) -> f64 {
        self.phases.iter().map(|p| p.device_us).sum()
    }

    pub fn hdbi(&self) -> f64 {
        hdbi_of(self.orchestration_us(), self.device_us())
    }

    pub fn throughput_tps(&self) -> f64 {
        if self.wall_us <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / (self.wall_us / 1e6)
        }
    }

    fn phase(&self, name: &str) -> Option<&PhaseSplit> {
        self.phases.iter().find(|p| p.phase == name)
    }
}

/// Full loadgen report: one run per model plus the workload echo.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub platform: String,
    pub requests: usize,
    pub rate_per_s: f64,
    pub prompt_len: LenDist,
    pub output_len: LenDist,
    pub seed: u64,
    /// Serving replicas the requests were sharded over.
    pub devices: usize,
    /// Streams per engine.
    pub streams: usize,
    pub runs: Vec<ModelRun>,
}

impl LoadgenReport {
    pub fn render(&self) -> String {
        let mut out = format!(
            "== loadgen ({} requests/model, {}, prompt {}, output {}, seed {}, {} x{} dev x{} streams) ==\n",
            self.requests,
            if self.rate_per_s > 0.0 {
                format!("{:.0} req/s", self.rate_per_s)
            } else {
                "closed-loop".to_string()
            },
            self.prompt_len.describe(),
            self.output_len.describe(),
            self.seed,
            self.platform,
            self.devices,
            self.streams,
        );
        let mut t = Table::new(
            "per-model serving KPIs",
            &[
                "model", "kind", "done", "tok/s", "TTFT p50(ms)", "TTFT p95(ms)",
                "TTFT p99(ms)", "TPOT p50(ms)", "TPOT p99(ms)", "HDBI", "HDBI pf",
                "HDBI dec", "KV occ", "preempt", "shed", "fail",
            ],
        );
        for r in &self.runs {
            t.row(vec![
                r.model.clone(),
                if r.moe { "moe" } else { "dense" }.to_string(),
                r.completed.to_string(),
                format!("{:.1}", r.throughput_tps()),
                ms(r.ttft_us.p50 / 1000.0),
                ms(r.ttft_us.p95 / 1000.0),
                ms(r.ttft_us.p99 / 1000.0),
                ms(r.tpot_us.p50 / 1000.0),
                ms(r.tpot_us.p99 / 1000.0),
                ratio(r.hdbi()),
                r.phase("prefill").map(|p| ratio(p.hdbi())).unwrap_or_default(),
                r.phase("decode").map(|p| ratio(p.hdbi())).unwrap_or_default(),
                format!("{:.0}%/{:.0}%", 100.0 * r.kv_occupancy_mean, 100.0 * r.kv_occupancy_max),
                r.preemptions.to_string(),
                r.sheds.to_string(),
                r.failed.to_string(),
            ]);
        }
        out.push_str(&t.render());
        for r in &self.runs {
            out.push_str(&format!(
                "-- {} ({}) --\n\
                 iterations        {}\n\
                 tokens generated  {}\n\
                 wall              {:.1} ms\n\
                 TTFT mean/p95/p99 {:.2} / {:.2} / {:.2} ms\n\
                 TPOT mean/p95/p99 {:.2} / {:.2} / {:.2} ms\n\
                 orchestration     {:.2} ms | device {:.2} ms | HDBI {:.2}\n",
                r.variant,
                if r.moe { "moe" } else { "dense" },
                r.iterations,
                r.tokens_generated,
                r.wall_us / 1000.0,
                r.ttft_us.mean / 1000.0,
                r.ttft_us.p95 / 1000.0,
                r.ttft_us.p99 / 1000.0,
                r.tpot_us.mean / 1000.0,
                r.tpot_us.p95 / 1000.0,
                r.tpot_us.p99 / 1000.0,
                r.orchestration_us() / 1000.0,
                r.device_us() / 1000.0,
                r.hdbi(),
            ));
            if r.rejected > 0 {
                out.push_str(&format!(
                    "  WARNING: {} requests rejected as unservable (prompt \
                     exceeds the context window)\n",
                    r.rejected
                ));
            }
            if r.late_arrivals > 0 {
                out.push_str(&format!(
                    "  WARNING: {} arrivals injected early (wall-clock backend \
                     cannot honor the configured rate)\n",
                    r.late_arrivals
                ));
            }
            if r.sheds + r.failed + r.deadline_misses > 0 || r.retries > 0 {
                out.push_str(&format!(
                    "  resilience: {} shed | {} failed | {} launch retries | \
                     {} deadline misses (p99 lateness {:.2} ms)\n",
                    r.sheds,
                    r.failed,
                    r.retries,
                    r.deadline_misses,
                    r.deadline_miss_p99_us / 1000.0,
                ));
            }
            for p in &r.phases {
                out.push_str(&format!(
                    "  {:<8} host {:>10.2} ms  device {:>10.2} ms  kernels {:>6}  HDBI {:.2}\n",
                    p.phase,
                    p.host_us / 1000.0,
                    p.device_us / 1000.0,
                    p.kernels,
                    p.hdbi(),
                ));
            }
            if let Some(t) = &r.telemetry {
                let o = &t.online;
                out.push_str(&format!(
                    "  online: HDBI {:.3} | T_fw {:.2} ms | T_lib {:.2} ms | T_launch {:.2} ms | \
                     {:.1} launches/token | {} windows\n",
                    o.totals.hdbi(),
                    o.totals.dft_us() / 1000.0,
                    o.totals.dct_us / 1000.0,
                    o.totals.dkt_us / 1000.0,
                    o.launches_per_token(),
                    o.windows.len(),
                ));
                for w in o.windows.iter().take(16) {
                    out.push_str(&format!(
                        "    [{:>3}] {:>8.1}..{:<8.1} ms  hdbi {:.2}  pf {:.2}  dec {:.2}  \
                         kernels {:>6}  tokens {:>5}\n",
                        w.index,
                        w.start_us / 1000.0,
                        w.end_us / 1000.0,
                        w.hdbi(),
                        w.phases[0].hdbi(),
                        w.phases[1].hdbi(),
                        w.n_kernels,
                        w.tokens,
                    ));
                }
                if o.windows.len() > 16 {
                    out.push_str(&format!(
                        "    ... {} more windows\n",
                        o.windows.len() - 16
                    ));
                }
            }
            if r.per_device.len() > 1 {
                let mut t = Table::new(
                    &format!("{} per-device", r.model),
                    &["device", "done", "tokens", "wall(ms)", "KV occ", "HDBI"],
                );
                for d in &r.per_device {
                    t.row(vec![
                        format!("dev {}", d.device),
                        d.completed.to_string(),
                        d.tokens_generated.to_string(),
                        ms(d.wall_us / 1000.0),
                        format!(
                            "{:.0}%/{:.0}%",
                            100.0 * d.kv_occupancy_mean,
                            100.0 * d.kv_occupancy_max
                        ),
                        ratio(d.hdbi),
                    ]);
                }
                out.push_str(&t.render());
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut runs: Vec<Json> = Vec::new();
        for r in &self.runs {
            let mut phases: Vec<Json> = Vec::new();
            for p in &r.phases {
                phases.push(
                    Json::obj()
                        .with("phase", p.phase)
                        .with("host_us", p.host_us)
                        .with("device_us", p.device_us)
                        .with("kernels", p.kernels)
                        .with("hdbi", p.hdbi()),
                );
            }
            let mut per_device: Vec<Json> = Vec::new();
            for d in &r.per_device {
                per_device.push(
                    Json::obj()
                        .with("device", d.device)
                        .with("completed", d.completed)
                        .with("tokens_generated", d.tokens_generated)
                        .with("wall_us", d.wall_us)
                        .with("kv_occupancy_mean", d.kv_occupancy_mean)
                        .with("kv_occupancy_max", d.kv_occupancy_max)
                        .with("hdbi", d.hdbi),
                );
            }
            let mut obj = Json::obj()
                .with("model", r.model.as_str())
                .with("variant", r.variant.as_str())
                .with("moe", r.moe)
                .with("completed", r.completed)
                .with("rejected", r.rejected)
                .with("sheds", r.sheds)
                .with("failed", r.failed)
                .with("retries", r.retries)
                .with("deadline_misses", r.deadline_misses)
                .with("deadline_miss_p99_us", r.deadline_miss_p99_us)
                .with("iterations", r.iterations)
                .with("preemptions", r.preemptions)
                .with("late_arrivals", r.late_arrivals)
                .with("wall_us", r.wall_us)
                .with("tokens_generated", r.tokens_generated)
                .with("throughput_tps", r.throughput_tps())
                .with("ttft_mean_us", r.ttft_us.mean)
                .with("ttft_p50_us", r.ttft_us.p50)
                .with("ttft_p95_us", r.ttft_us.p95)
                .with("ttft_p99_us", r.ttft_us.p99)
                .with("tpot_mean_us", r.tpot_us.mean)
                .with("tpot_p50_us", r.tpot_us.p50)
                .with("tpot_p95_us", r.tpot_us.p95)
                .with("tpot_p99_us", r.tpot_us.p99)
                .with("kv_occupancy_mean", r.kv_occupancy_mean)
                .with("kv_occupancy_max", r.kv_occupancy_max)
                .with("hdbi", r.hdbi())
                .with("phases", phases)
                .with("per_device", per_device);
            if let Some(t) = &r.telemetry {
                obj = obj.with("telemetry", t.online.to_json());
            }
            runs.push(obj);
        }
        Json::obj()
            .with("platform", self.platform.as_str())
            .with("requests", self.requests)
            .with("rate_per_s", self.rate_per_s)
            .with("prompt_len", self.prompt_len.describe())
            .with("output_len", self.output_len.describe())
            .with("seed", self.seed)
            .with("devices", self.devices)
            .with("streams", self.streams)
            .with("runs", runs)
    }

    /// Compact benchmark datapoint (`taxbreak loadgen --bench-out`,
    /// CI's `BENCH_loadgen.json`): the serving KPIs the bench
    /// trajectory tracks, aggregated across the model mix.
    pub fn bench_json(&self) -> Json {
        let tokens: usize = self.runs.iter().map(|r| r.tokens_generated).sum();
        let wall_us: f64 = self.runs.iter().map(|r| r.wall_us).sum();
        let host: f64 = self.runs.iter().map(|r| r.orchestration_us()).sum();
        let dev: f64 = self.runs.iter().map(|r| r.device_us()).sum();
        let tpot_p50s: Vec<f64> = self.runs.iter().map(|r| r.tpot_us.p50).collect();
        let mut per_model: Vec<Json> = Vec::with_capacity(self.runs.len());
        for r in &self.runs {
            let mut per_device: Vec<Json> = Vec::with_capacity(r.per_device.len());
            for d in &r.per_device {
                per_device.push(
                    Json::obj()
                        .with("device", d.device)
                        .with("hdbi", d.hdbi)
                        .with("kv_occupancy_mean", d.kv_occupancy_mean),
                );
            }
            per_model.push(
                Json::obj()
                    .with("model", r.model.as_str())
                    .with("throughput_tps", r.throughput_tps())
                    .with("tpot_p50_us", r.tpot_us.p50)
                    .with("tpot_p99_us", r.tpot_us.p99)
                    .with("ttft_p99_us", r.ttft_us.p99)
                    .with("hdbi", r.hdbi())
                    .with("per_device", per_device),
            );
        }
        // Process-wide interner traffic: `hits` are events that reused
        // an already-interned symbol (allocation-free), `misses` are
        // first-sight strings that had to allocate. A healthy hot path
        // keeps hits >> misses — the bench trajectory tracks the ratio.
        let (intern_hits, intern_misses) = crate::util::intern::stats();
        // Resilience KPIs (DESIGN.md §16): rates are per offered
        // request across the model mix; the p99 lateness is the worst
        // model's. All exactly zero on fault-free, deadline-free runs —
        // `scripts/check_bench.py` pins that the fault path costs
        // nothing when disabled.
        let offered = (self.requests * self.runs.len()).max(1) as f64;
        let sheds: usize = self.runs.iter().map(|r| r.sheds).sum();
        let retries: u64 = self.runs.iter().map(|r| r.retries).sum();
        let miss_p99 = self
            .runs
            .iter()
            .map(|r| r.deadline_miss_p99_us)
            .fold(0.0f64, f64::max);
        Json::obj()
            .with("bench", "loadgen")
            .with("platform", self.platform.as_str())
            .with("requests", self.requests)
            .with("devices", self.devices)
            .with("streams", self.streams)
            .with("intern_hits", intern_hits)
            .with("intern_misses", intern_misses)
            .with(
                "throughput_tps",
                if wall_us <= 0.0 { 0.0 } else { tokens as f64 / (wall_us / 1e6) },
            )
            .with("tpot_p50_us", crate::util::stats::mean(&tpot_p50s))
            .with("hdbi", hdbi_of(host, dev))
            .with("shed_rate", sheds as f64 / offered)
            .with("retry_rate", retries as f64 / offered)
            .with("deadline_miss_p99_us", miss_p99)
            .with("per_model", per_model)
    }

    /// Merge every run's telemetry into one model-labeled registry
    /// (`taxbreak loadgen --metrics-out`). `None` when no run carries
    /// telemetry (the config didn't ask for metrics).
    pub fn metrics_registry(&self) -> Option<crate::obs::MetricsRegistry> {
        let mut reg = crate::obs::MetricsRegistry::new();
        let mut any = false;
        for r in &self.runs {
            if let Some(t) = &r.telemetry {
                t.online.register_into(&mut reg, &r.model);
                t.probe.register_into(&mut reg, &r.model);
                any = true;
            }
        }
        any.then_some(reg)
    }
}

/// [`drive`]'s full outcome: the run plus the raw latency samples
/// (replica merging re-summarizes over the union).
pub(crate) struct DriveOutcome {
    pub(crate) run: ModelRun,
    pub(crate) ttfts: Vec<f64>,
    pub(crate) tpots: Vec<f64>,
    /// Per-request lateness past the configured deadline (us), misses
    /// only — replica merging re-derives the p99 over the union.
    pub(crate) lateness: Vec<f64>,
}

/// The `arrival` recording event for one request: every nondeterministic
/// input to the drive loop (who arrives, when, with what shape) becomes
/// a first-class trace event, so [`crate::serving::replay`] can
/// reconstruct the workload without re-running the generator.
fn arrival_event(r: &Request, model: &str, device: Option<u32>) -> TraceEvent {
    TraceEvent {
        kind: EventKind::Arrival,
        name: "arrival".to_string(),
        ts_us: r.arrival_us,
        dur_us: 0.0,
        correlation_id: 0,
        track: Track::Host,
        device,
        args: Some(ReplayArgs::Arrival {
            req: r.id,
            plen: r.prompt.len() as u64,
            max_new: r.max_new_tokens as u64,
            model: model.to_string(),
        }),
        meta: None,
    }
}

/// Drain the backend's buffered events into stats + sink. The in-flight
/// buffer is bounded by one step's output; only the sink decides
/// whether anything is retained.
fn drain_backend<B: Backend>(
    s: &mut Scheduler<B>,
    stats: &mut ServingStats,
    peak: &mut usize,
    sink: &mut dyn TraceSink,
) -> anyhow::Result<()> {
    let batch = s.backend.drain_events();
    *peak = (*peak).max(batch.len());
    for ev in &batch {
        stats.observe(ev);
        sink.event(ev)?;
    }
    Ok(())
}

/// Drive one backend through an arrival-stamped workload; the requests
/// must be sorted by `arrival_us` (as [`generate_workload`] emits).
/// Capture buffers through a [`TraceBufferSink`] on the same single
/// event path every other sink uses.
pub fn drive<B: Backend>(
    backend: B,
    sched: SchedulerConfig,
    requests: Vec<Request>,
    capture: bool,
) -> anyhow::Result<ModelRun> {
    let mut buffer = capture.then(|| TraceBufferSink::new(backend.trace_meta()));
    let mut null = NullSink;
    let sink: &mut dyn TraceSink = match buffer.as_mut() {
        Some(b) => b,
        None => &mut null,
    };
    let mut out = drive_collect(backend, sched, requests, 0, None, None, None, sink)?;
    if let Some(mut b) = buffer {
        TraceSink::finish(&mut b, out.run.wall_us)?;
        out.run.trace = Some(b.into_trace());
    }
    Ok(out.run)
}

/// The one drive path: arrival-gated submission, iteration-level
/// stepping, streaming drain into `sink`. Recording events (`arrival`,
/// `sched_decision`; the backend contributes `rng_draw` / `clock_jump`)
/// flow through the same sink as the observation events, stamped with
/// the replica `device`. With `decisions`, the scheduler replays the
/// recorded admissions/preemptions instead of re-deciding
/// ([`Scheduler::script_decisions`]).
pub(crate) fn drive_collect<B: Backend>(
    backend: B,
    sched: SchedulerConfig,
    requests: Vec<Request>,
    device: u32,
    decisions: Option<Vec<StepDecision>>,
    faults: Option<&FaultPlan>,
    mut probe: Option<&mut ServingProbe>,
    sink: &mut dyn TraceSink,
) -> anyhow::Result<DriveOutcome> {
    let variant = backend.variant().to_string();
    let model_name = backend.trace_meta().model;
    let stamp = (device != 0).then_some(device);
    let total_pages = sched.kv_pages.max(1) as f64;
    let mut queue: VecDeque<Request> = requests.into();
    let mut s = Scheduler::new(backend, sched);
    if let Some(d) = decisions {
        s.script_decisions(d);
    }
    if let Some(p) = faults {
        // Scheduler-side arming (KV pressure); the caller arms the
        // engine-side faults before handing the backend over, so the
        // spec-v4 fault events are already buffered for the first
        // drain.
        s.set_faults(p.clone());
    }
    let mut occ = Welford::default();
    let mut occ_max = 0.0f64;
    let mut guard = StallGuard::default();
    let mut late_arrivals = 0usize;
    let mut stats = ServingStats::new();
    let mut peak_buffered_events = 0usize;

    while !(queue.is_empty() && s.is_idle()) {
        let now = s.backend.now_us();
        while queue.front().is_some_and(|r| r.arrival_us <= now) {
            let r = queue.pop_front().unwrap();
            let ev = arrival_event(&r, &model_name, stamp);
            stats.observe(&ev);
            sink.event(&ev)?;
            s.submit(r);
        }
        if s.is_idle() {
            if let Some(front) = queue.front() {
                s.backend.wait_until_us(front.arrival_us);
                if s.backend.now_us() < front.arrival_us {
                    // Wall-clock backend: it cannot jump forward, so
                    // treat the request as arriving now instead of
                    // busy-spinning — and count the distortion so the
                    // report can flag that the offered rate degraded.
                    late_arrivals += 1;
                    let mut r = queue.pop_front().unwrap();
                    r.arrival_us = s.backend.now_us();
                    let ev = arrival_event(&r, &model_name, stamp);
                    stats.observe(&ev);
                    sink.event(&ev)?;
                    s.submit(r);
                }
            }
            continue;
        }
        s.step()?;
        drain_backend(&mut s, &mut stats, &mut peak_buffered_events, sink)?;
        // The step's decisions become a first-class recording event
        // (ts = the clock the step started at), closing the replay
        // loop: admissions and preemptions are replayed, not
        // re-decided.
        let d = s.last_decision().clone();
        let ev = TraceEvent {
            kind: EventKind::SchedDecision,
            name: "sched_decision".to_string(),
            ts_us: now,
            dur_us: 0.0,
            correlation_id: 0,
            track: Track::Host,
            device: stamp,
            args: Some(ReplayArgs::SchedDecision {
                step: s.iterations as u64,
                admitted: d.admitted,
                preempted: d.preempted,
                shed: d.shed,
                batch: s.active_members() as u64,
            }),
            meta: None,
        };
        stats.observe(&ev);
        sink.event(&ev)?;
        // Same stall policy as `run_to_completion`: a request whose
        // worst case can never fit the pool must error, not spin.
        guard.observe(s.progress_marker(), || {
            format!(
                "loadgen: {} in flight, {} queued, {} kv pages free",
                s.pending(),
                queue.len(),
                s.kv.free_pages()
            )
        })?;
        let used = s.kv.used_pages() as f64 / total_pages;
        occ.push(used);
        occ_max = occ_max.max(used);
        if let Some(p) = probe.as_deref_mut() {
            let held = s.kv.used_pages() as u64;
            let reserved = s.kv.reserved_pages() as u64;
            p.on_step(
                s.backend.now_us(),
                held - reserved,
                reserved,
                s.kv.free_pages() as u64,
                s.waiting(),
            );
        }
    }
    // Catch anything emitted outside a step (defensive; engines only
    // record inside invocations).
    drain_backend(&mut s, &mut stats, &mut peak_buffered_events, sink)?;

    let iterations = s.iterations;
    let preemptions = s.preemptions;
    let sheds = s.sheds;
    let failed = s.failures;
    let retries = s.backend.retries();
    // Scalar summaries come off the borrowed slice — no need to clone
    // every prompt/token buffer.
    let finished = s.finished();
    let ttfts: Vec<f64> = finished.iter().filter_map(|f| f.ttft_us()).collect();
    let tpots: Vec<f64> = finished.iter().filter_map(|f| f.tpot_us()).collect();
    let tokens: usize = finished.iter().map(|f| f.generated.len()).sum();
    let rejected = finished.iter().filter(|f| f.rejected).count();
    let completed = finished
        .iter()
        .filter(|f| f.outcome() == RequestOutcome::Completed)
        .count();
    // Deadline audit over the *served* requests: lateness is how far a
    // completed request's TTFT/TPOT landed past its configured budget
    // (shed and failed requests are counted by their own counters, not
    // here).
    let mut lateness: Vec<f64> = Vec::new();
    if sched.ttft_deadline_us > 0.0 || sched.tpot_deadline_us > 0.0 {
        for f in finished {
            if f.outcome() != RequestOutcome::Completed {
                continue;
            }
            let mut worst = 0.0f64;
            if sched.ttft_deadline_us > 0.0 {
                if let Some(t) = f.ttft_us() {
                    worst = worst.max(t - sched.ttft_deadline_us);
                }
            }
            if sched.tpot_deadline_us > 0.0 {
                if let Some(t) = f.tpot_us() {
                    worst = worst.max(t - sched.tpot_deadline_us);
                }
            }
            if worst > 0.0 {
                lateness.push(worst);
            }
        }
    }
    let meta = s.backend.trace_meta();
    let wall_us = meta.wall_us;

    let run = ModelRun {
        model: String::new(), // caller fills in the catalog name
        variant,
        moe: false,
        completed,
        rejected,
        sheds,
        failed,
        retries,
        deadline_misses: lateness.len(),
        deadline_miss_p99_us: Summary::of(&lateness).p99,
        iterations,
        preemptions,
        late_arrivals,
        wall_us,
        tokens_generated: tokens,
        ttft_us: Summary::of(&ttfts),
        tpot_us: Summary::of(&tpots),
        kv_occupancy_mean: occ.mean(),
        kv_occupancy_max: occ_max,
        phases: stats.phases.to_vec(),
        per_device: vec![DeviceLoad {
            device: 0, // replica drivers overwrite with the replica id
            completed,
            tokens_generated: tokens,
            wall_us,
            kv_occupancy_mean: occ.mean(),
            kv_occupancy_max: occ_max,
            hdbi: hdbi_of(stats.host_us, stats.device_us),
        }],
        trace: None, // captures live in whatever sink the caller chose
        telemetry: None,
        peak_buffered_events,
    };
    Ok(DriveOutcome { run, ttfts, tpots, lateness })
}

/// Merge the per-replica outcomes of one model into a single
/// [`ModelRun`]: counters sum, wall is the slowest replica (they run
/// concurrently in virtual time), and latency summaries re-derive over
/// the union of samples. Traces are not merged here: every replica
/// already streamed through the shared per-model sink (correlation ids
/// shifted into disjoint ranges by [`OffsetSink`]), so the capture
/// exists exactly once.
pub(crate) fn merge_replicas(mut outcomes: Vec<DriveOutcome>) -> ModelRun {
    debug_assert!(!outcomes.is_empty());
    if outcomes.len() == 1 {
        return outcomes.pop().expect("non-empty").run;
    }
    let mut ttfts = Vec::new();
    let mut tpots = Vec::new();
    let mut lateness = Vec::new();
    let mut per_device = Vec::with_capacity(outcomes.len());
    let mut base = outcomes[0].run.clone();
    base.completed = 0;
    base.rejected = 0;
    base.sheds = 0;
    base.failed = 0;
    base.retries = 0;
    base.deadline_misses = 0;
    base.iterations = 0;
    base.preemptions = 0;
    base.late_arrivals = 0;
    base.wall_us = 0.0;
    base.tokens_generated = 0;
    base.kv_occupancy_mean = 0.0;
    base.kv_occupancy_max = 0.0;
    base.peak_buffered_events = 0;
    for p in &mut base.phases {
        p.host_us = 0.0;
        p.device_us = 0.0;
        p.kernels = 0;
    }
    let n = outcomes.len();
    for (r, mut o) in outcomes.into_iter().enumerate() {
        base.completed += o.run.completed;
        base.rejected += o.run.rejected;
        base.sheds += o.run.sheds;
        base.failed += o.run.failed;
        base.retries += o.run.retries;
        base.deadline_misses += o.run.deadline_misses;
        base.iterations += o.run.iterations;
        base.preemptions += o.run.preemptions;
        base.late_arrivals += o.run.late_arrivals;
        base.wall_us = base.wall_us.max(o.run.wall_us);
        base.tokens_generated += o.run.tokens_generated;
        base.peak_buffered_events = base.peak_buffered_events.max(o.run.peak_buffered_events);
        base.kv_occupancy_mean += o.run.kv_occupancy_mean / n as f64;
        base.kv_occupancy_max = base.kv_occupancy_max.max(o.run.kv_occupancy_max);
        ttfts.append(&mut o.ttfts);
        tpots.append(&mut o.tpots);
        lateness.append(&mut o.lateness);
        for p in &o.run.phases {
            if let Some(m) = base.phases.iter_mut().find(|m| m.phase == p.phase) {
                m.host_us += p.host_us;
                m.device_us += p.device_us;
                m.kernels += p.kernels;
            }
        }
        let mut dev = o.run.per_device.remove(0);
        dev.device = r as u32;
        per_device.push(dev);
    }
    base.ttft_us = Summary::of(&ttfts);
    base.tpot_us = Summary::of(&tpots);
    base.deadline_miss_p99_us = Summary::of(&lateness).p99;
    base.per_device = per_device;
    base
}

/// Re-stamps one replica's events into the shared per-model sink:
/// correlation ids shift into the replica's disjoint range and `finish`
/// is swallowed — the caller seals the merged capture once, with the
/// slowest replica's wall. Recording events (`arrival` / `rng_draw` /
/// `sched_decision` / `clock_jump`) carry correlation id 0 — they
/// belong to no kernel chain, and keep 0 on every replica.
pub(crate) struct OffsetSink<'a> {
    inner: &'a mut dyn TraceSink,
    corr_offset: u64,
    /// Reused across events: re-stamping copies into this scratch
    /// instead of cloning a fresh event, so the hot path only touches
    /// the allocator when a name outgrows the retained `String` buffer
    /// (interned [`crate::trace::KernelMeta`] copies are
    /// allocation-free).
    scratch: TraceEvent,
}

impl<'a> OffsetSink<'a> {
    pub(crate) fn new(inner: &'a mut dyn TraceSink, corr_offset: u64) -> OffsetSink<'a> {
        OffsetSink {
            inner,
            corr_offset,
            scratch: TraceEvent {
                kind: EventKind::TorchOp,
                name: String::new(),
                ts_us: 0.0,
                dur_us: 0.0,
                correlation_id: 0,
                track: Track::Host,
                device: None,
                args: None,
                meta: None,
            },
        }
    }
}

impl TraceSink for OffsetSink<'_> {
    fn event(&mut self, ev: &TraceEvent) -> anyhow::Result<()> {
        if self.corr_offset == 0 || ev.correlation_id == 0 {
            return self.inner.event(ev);
        }
        // Field-wise copy into the scratch: `String::clone_from` reuses
        // the buffer, and shifted events never carry `args` (recording
        // events keep correlation id 0 on every replica).
        let s = &mut self.scratch;
        s.kind = ev.kind;
        s.name.clone_from(&ev.name);
        s.ts_us = ev.ts_us;
        s.dur_us = ev.dur_us;
        s.correlation_id = ev.correlation_id + self.corr_offset;
        s.track = ev.track;
        s.device = ev.device;
        s.args.clone_from(&ev.args);
        s.meta.clone_from(&ev.meta);
        self.inner.event(&self.scratch)
    }

    fn finish(&mut self, _wall_us: f64) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Fans one event stream out to several sinks (e.g. the in-memory
/// capture buffer and a streaming file sink), so the buffered and
/// streamed captures can never diverge.
pub(crate) struct TeeSink<'a> {
    pub(crate) sinks: Vec<&'a mut dyn TraceSink>,
}

impl TraceSink for TeeSink<'_> {
    fn event(&mut self, ev: &TraceEvent) -> anyhow::Result<()> {
        for s in &mut self.sinks {
            s.event(ev)?;
        }
        Ok(())
    }

    fn finish(&mut self, wall_us: f64) -> anyhow::Result<()> {
        for s in &mut self.sinks {
            s.finish(wall_us)?;
        }
        Ok(())
    }
}

/// Opens one [`TraceSink`] per model for the streaming capture path:
/// called with the catalog model name and the run's metadata (e.g.
/// [`crate::trace::sink::file_sink`] on a per-model path).
pub type SinkFactory<'a> =
    dyn FnMut(&str, &TraceMeta) -> anyhow::Result<Box<dyn TraceSink>> + 'a;

/// Run the load generator over the simulated engine for each named
/// model (e.g. a dense/MoE mix) on one platform. With
/// `cfg.devices > 1`, requests round-robin across that many
/// engine+scheduler replicas (each holding `kv_pages/devices` of the
/// pool) and the per-model run reports the merged KPIs plus the
/// per-device partition.
pub fn run_sim_loadgen(
    model_names: &[String],
    platform_name: &str,
    cfg: &LoadgenConfig,
) -> anyhow::Result<LoadgenReport> {
    run_sim_loadgen_inner(model_names, platform_name, cfg, None)
}

/// Streaming-capture loadgen (`taxbreak loadgen --capture out.tbt`):
/// like [`run_sim_loadgen`], but every event additionally streams
/// through a per-model sink as the scheduler steps, so a binary capture
/// is O(1) in event count instead of buffering the whole run. The sink
/// is finished once per model with the merged (slowest-replica) wall.
pub fn run_sim_loadgen_streaming(
    model_names: &[String],
    platform_name: &str,
    cfg: &LoadgenConfig,
    sinks: &mut SinkFactory<'_>,
) -> anyhow::Result<LoadgenReport> {
    run_sim_loadgen_inner(model_names, platform_name, cfg, Some(sinks))
}

fn run_sim_loadgen_inner(
    model_names: &[String],
    platform_name: &str,
    cfg: &LoadgenConfig,
    mut sinks: Option<&mut SinkFactory<'_>>,
) -> anyhow::Result<LoadgenReport> {
    anyhow::ensure!(!model_names.is_empty(), "loadgen needs at least one model");
    anyhow::ensure!(cfg.requests > 0, "loadgen needs at least one request");
    anyhow::ensure!(
        cfg.rate_per_s >= 0.0 && cfg.rate_per_s.is_finite(),
        "--rate must be a finite, non-negative number (0 = closed loop)"
    );
    anyhow::ensure!(cfg.sched.kv_page_tokens >= 1, "--kv-page-tokens must be >= 1");
    anyhow::ensure!(cfg.sched.kv_pages >= 1, "--kv-pages must be >= 1");
    anyhow::ensure!(cfg.sched.max_batch >= 1, "--max-batch must be >= 1");
    anyhow::ensure!(cfg.sched.max_groups >= 1, "--max-groups must be >= 1");
    anyhow::ensure!((1..=64).contains(&cfg.devices), "--devices must be in 1..=64");
    anyhow::ensure!((1..=32).contains(&cfg.streams), "--streams must be in 1..=32");
    anyhow::ensure!(
        cfg.sched.kv_pages >= cfg.devices,
        "--kv-pages must cover at least one page per device"
    );
    // Parse (and thereby validate) the fault spec once, before any
    // engine spins up: a bad `--faults` must fail the run up front.
    let fault_plan = cfg.faults.as_deref().map(FaultPlan::parse).transpose()?;
    let platform = crate::hardware::Platform::by_name(platform_name)?;
    let replica_sched = SchedulerConfig {
        kv_pages: (cfg.sched.kv_pages / cfg.devices).max(1),
        ..cfg.sched
    };
    let mut runs = Vec::new();
    for name in model_names {
        let model = crate::models::by_name(name)?;
        let moe = model.is_moe();
        // Identical arrival trace and lengths for every model; prompt
        // tokens draw below the pad-aware bound.
        let probe = crate::runtime::SimEngine::with_defaults(
            model.clone(),
            platform.clone(),
            cfg.seed,
        );
        let vocab = Backend::vocab(&probe);
        let max_seq = ModelBackend::max_seq(&probe);
        let workload = generate_workload(cfg, prompt_token_bound(&probe, vocab)?, max_seq);
        let meta = Backend::trace_meta(&probe);
        // One sink per model, opened against the run's metadata (wall is
        // stamped at finish, below); replicas stream into it in turn.
        // A buffered capture is just another sink on the same path.
        let mut model_sink: Option<Box<dyn TraceSink>> = match sinks.as_deref_mut() {
            Some(make) => Some(make(name, &meta)?),
            None => None,
        };
        let mut capture_buf = cfg.capture.then(|| TraceBufferSink::new(meta));
        drop(probe);
        // Live telemetry: the online decomposer joins the sink fan-out
        // (it sees exactly the stream a capture would), the serving
        // probe samples scheduler-side state each step. Both stream —
        // neither requires `capture`.
        let mut online = cfg.metrics.then(|| OnlineDecomposer::new(cfg.window_us));
        let mut kv_probe = cfg.metrics.then(|| ServingProbe::new(cfg.window_us));

        let mut outcomes = Vec::with_capacity(cfg.devices);
        for r in 0..cfg.devices {
            let sub: Vec<Request> = workload
                .iter()
                .enumerate()
                .filter(|(i, _)| i % cfg.devices == r)
                .map(|(_, req)| req.clone())
                .collect();
            let mut engine = crate::runtime::SimEngine::with_topology(
                model.clone(),
                platform.clone(),
                cfg.seed.wrapping_add((r as u64).wrapping_mul(0x9E3779B97F4A7C15)),
                cfg.streams,
                r as u32,
            );
            if let Some(p) = &fault_plan {
                // Engine-side arming emits the replica's spec-v4 fault
                // events up front, so they lead the first drain.
                engine.set_faults(p.clone());
            }
            // Every capture destination sits behind the same tee +
            // correlation offset: replicas land in disjoint corr-id
            // ranges, and buffered vs streamed captures see the exact
            // same event sequence.
            let mut fan: Vec<&mut dyn TraceSink> = Vec::new();
            if let Some(buf) = capture_buf.as_mut() {
                fan.push(buf);
            }
            if let Some(sk) = model_sink.as_deref_mut() {
                fan.push(sk);
            }
            if let Some(o) = online.as_mut() {
                fan.push(o);
            }
            let mut tee = TeeSink { sinks: fan };
            let mut off = OffsetSink::new(&mut tee, (r as u64) * 1_000_000_000);
            let out = drive_collect(
                engine,
                replica_sched,
                sub,
                r as u32,
                None,
                fault_plan.as_ref(),
                kv_probe.as_mut(),
                &mut off,
            )?;
            if let Some(p) = kv_probe.as_mut() {
                for &v in &out.ttfts {
                    p.observe_ttft_us(v);
                }
                for &v in &out.tpots {
                    p.observe_tpot_us(v);
                }
                p.observe_outcomes(
                    out.run.sheds as u64,
                    out.run.retries,
                    out.run.failed as u64,
                    out.run.deadline_misses as u64,
                );
            }
            outcomes.push(out);
        }
        let mut run = merge_replicas(outcomes);
        run.model = name.clone();
        run.moe = moe;
        if let Some(mut buf) = capture_buf {
            TraceSink::finish(&mut buf, run.wall_us)?;
            run.trace = Some(buf.into_trace());
        }
        if let Some(sink) = model_sink.as_deref_mut() {
            sink.finish(run.wall_us)?;
        }
        if let (Some(mut o), Some(p)) = (online, kv_probe) {
            TraceSink::finish(&mut o, run.wall_us)?;
            run.telemetry = Some(Telemetry {
                online: o.finalize(platform.clone()),
                probe: p,
            });
        }
        runs.push(run);
    }
    Ok(LoadgenReport {
        platform: platform_name.to_string(),
        requests: cfg.requests,
        rate_per_s: cfg.rate_per_s,
        prompt_len: cfg.prompt_len,
        output_len: cfg.output_len,
        seed: cfg.seed,
        devices: cfg.devices,
        streams: cfg.streams,
        runs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn len_dist_parses_and_describes() {
        assert_eq!(
            LenDist::parse("uniform:8:48").unwrap(),
            LenDist::Uniform { lo: 8, hi: 48 }
        );
        assert_eq!(
            LenDist::parse("lognormal:24:0.5").unwrap(),
            LenDist::LogNormal { median: 24.0, sigma: 0.5 }
        );
        assert_eq!(LenDist::parse("uniform:8:48").unwrap().describe(), "uniform:8:48");
        assert!(LenDist::parse("uniform:9:2").is_err());
        assert!(LenDist::parse("uniform:0:4").is_err());
        assert!(LenDist::parse("gauss:1:2").is_err());
        assert!(LenDist::parse("uniform:x:4").is_err());
    }

    #[test]
    fn len_dist_samples_in_range() {
        let mut rng = Rng::new(5);
        let d = LenDist::Uniform { lo: 3, hi: 9 };
        for _ in 0..200 {
            assert!((3..=9).contains(&d.sample(&mut rng)));
        }
        let ln = LenDist::LogNormal { median: 20.0, sigma: 0.3 };
        for _ in 0..200 {
            assert!(ln.sample(&mut rng) >= 1);
        }
    }

    #[test]
    fn workload_arrivals_are_monotone_and_poisson_spaced() {
        let cfg = LoadgenConfig { requests: 50, rate_per_s: 1000.0, ..Default::default() };
        let w = generate_workload(&cfg, 250, 128);
        assert_eq!(w.len(), 50);
        for pair in w.windows(2) {
            assert!(pair[1].arrival_us >= pair[0].arrival_us);
        }
        assert!(w.last().unwrap().arrival_us > 0.0);
        for r in &w {
            assert!(r.prompt.len() + r.max_new_tokens < 128);
            assert!(r.prompt.iter().all(|&t| (0..250).contains(&t)));
        }
        // Closed loop: everything lands at t = 0.
        let closed = LoadgenConfig { requests: 5, rate_per_s: 0.0, ..Default::default() };
        assert!(generate_workload(&closed, 250, 128).iter().all(|r| r.arrival_us == 0.0));
    }

    #[test]
    fn capture_keeps_the_trace_and_bench_json_aggregates() {
        let cfg = LoadgenConfig {
            requests: 4,
            rate_per_s: 0.0,
            capture: true,
            ..Default::default()
        };
        let report =
            run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg).unwrap();
        let run = &report.runs[0];
        let trace = run.trace.as_ref().expect("capture keeps the trace");
        assert!(trace.kernel_count() > 0);
        assert_eq!(trace.meta.phase, "serve");
        // Without capture the trace is dropped.
        let nocap = LoadgenConfig { capture: false, ..cfg };
        let r2 = run_sim_loadgen(&["gpt2".to_string()], "h200", &nocap).unwrap();
        assert!(r2.runs[0].trace.is_none());

        let bench = report.bench_json();
        assert_eq!(bench.str_of("bench").unwrap(), "loadgen");
        assert!(bench.f64_of("throughput_tps").unwrap() > 0.0);
        assert!(bench.f64_of("tpot_p50_us").unwrap() > 0.0);
        let h = bench.f64_of("hdbi").unwrap();
        assert!(h > 0.0 && h < 1.0);
        assert_eq!(bench.arr_of("per_model").unwrap().len(), 1);
        // Interner traffic is reported, and a serving run is
        // overwhelmingly repeat kernels: hits dominate misses.
        let hits = bench.f64_of("intern_hits").unwrap();
        let misses = bench.f64_of("intern_misses").unwrap();
        assert!(hits > 0.0, "a capture run must hit the symbol table");
        assert!(misses > 0.0, "first sight of each symbol is a miss");
        assert!(hits > misses, "repeat kernels should reuse symbols: {hits} vs {misses}");
    }

    #[test]
    fn multi_device_run_partitions_requests_and_reports_per_device() {
        let cfg = LoadgenConfig {
            requests: 12,
            rate_per_s: 0.0,
            devices: 3,
            streams: 2,
            sched: crate::serving::SchedulerConfig {
                kv_pages: 96,
                ..Default::default()
            },
            capture: true,
            ..Default::default()
        };
        let report = run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg).unwrap();
        let run = &report.runs[0];
        assert_eq!(run.completed, 12, "every replica drains its shard");
        assert_eq!(run.per_device.len(), 3);
        let ids: Vec<u32> = run.per_device.iter().map(|d| d.device).collect();
        assert_eq!(ids, vec![0, 1, 2]);
        let done: usize = run.per_device.iter().map(|d| d.completed).sum();
        assert_eq!(done, 12, "per-device slices partition the run");
        assert_eq!(run.per_device.iter().map(|d| d.completed).max(), Some(4));
        for d in &run.per_device {
            assert!(d.hdbi > 0.0 && d.hdbi < 1.0);
            assert!(d.kv_occupancy_mean > 0.0 && d.kv_occupancy_max <= 1.0);
        }
        assert_eq!(run.ttft_us.n, 12, "latency summaries merge the union");
        // Merged capture trace: replica-stamped events, disjoint corr
        // ranges, wall = slowest replica.
        let trace = run.trace.as_ref().expect("capture keeps the merged trace");
        let devs: std::collections::BTreeSet<u32> =
            trace.events.iter().map(|e| e.device_id()).collect();
        assert_eq!(devs.into_iter().collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!((trace.meta.wall_us - run.wall_us).abs() < 1e-9);
        let max_wall = run
            .per_device
            .iter()
            .map(|d| d.wall_us)
            .fold(0.0f64, f64::max);
        assert!((run.wall_us - max_wall).abs() < 1e-9);
        // Rendering carries the per-device table and the topology echo.
        let rendered = report.render();
        assert!(rendered.contains("per-device"), "{rendered}");
        assert!(rendered.contains("x3 dev x2 streams"), "{rendered}");
        let bench = report.bench_json();
        assert_eq!(bench.usize_of("devices").unwrap(), 3);
        let pm = bench.arr_of("per_model").unwrap();
        assert_eq!(pm[0].arr_of("per_device").unwrap().len(), 3);
    }

    #[test]
    fn device_zero_rejects_bad_topologies() {
        let bad_dev = LoadgenConfig { devices: 0, ..Default::default() };
        assert!(run_sim_loadgen(&["gpt2".to_string()], "h200", &bad_dev).is_err());
        let bad_streams = LoadgenConfig { streams: 0, ..Default::default() };
        assert!(run_sim_loadgen(&["gpt2".to_string()], "h200", &bad_streams).is_err());
        let starved = LoadgenConfig {
            devices: 5,
            sched: crate::serving::SchedulerConfig { kv_pages: 4, ..Default::default() },
            ..Default::default()
        };
        assert!(run_sim_loadgen(&["gpt2".to_string()], "h200", &starved).is_err());
    }

    #[test]
    fn workload_is_deterministic_per_seed() {
        let cfg = LoadgenConfig::default();
        assert_eq!(generate_workload(&cfg, 250, 128), generate_workload(&cfg, 250, 128));
        let other = LoadgenConfig { seed: 1, ..LoadgenConfig::default() };
        assert_ne!(generate_workload(&cfg, 250, 128), generate_workload(&other, 250, 128));
    }

    /// Test sink that lets the caller inspect the capture after the
    /// factory-produced box is dropped inside the loadgen driver.
    #[derive(Clone)]
    struct SharedSink {
        trace: std::rc::Rc<std::cell::RefCell<Trace>>,
        finishes: std::rc::Rc<std::cell::Cell<usize>>,
    }

    impl SharedSink {
        fn new(meta: &TraceMeta) -> SharedSink {
            SharedSink {
                trace: std::rc::Rc::new(std::cell::RefCell::new(Trace::new(meta.clone()))),
                finishes: std::rc::Rc::new(std::cell::Cell::new(0)),
            }
        }
    }

    impl TraceSink for SharedSink {
        fn event(&mut self, ev: &TraceEvent) -> anyhow::Result<()> {
            self.trace.borrow_mut().push(ev.clone());
            Ok(())
        }

        fn finish(&mut self, wall_us: f64) -> anyhow::Result<()> {
            self.trace.borrow_mut().meta.wall_us = wall_us;
            self.finishes.set(self.finishes.get() + 1);
            Ok(())
        }
    }

    #[test]
    fn streaming_capture_matches_buffered_trace() {
        // Multi-replica run so the streamed path exercises OffsetSink's
        // correlation re-stamping; replicas run sequentially, so the
        // streamed order equals the merged buffered order.
        let cfg = LoadgenConfig {
            requests: 9,
            rate_per_s: 0.0,
            devices: 3,
            sched: crate::serving::SchedulerConfig { kv_pages: 96, ..Default::default() },
            capture: true,
            ..Default::default()
        };
        let models = ["gpt2".to_string()];
        let buffered = run_sim_loadgen(&models, "h200", &cfg).unwrap();
        let expect = buffered.runs[0].trace.as_ref().unwrap();

        let mut streamed: Option<SharedSink> = None;
        let mut factory = |name: &str, meta: &TraceMeta| -> anyhow::Result<Box<dyn TraceSink>> {
            assert_eq!(name, "gpt2");
            let sink = SharedSink::new(meta);
            streamed = Some(sink.clone());
            Ok(Box::new(sink))
        };
        let report = run_sim_loadgen_streaming(&models, "h200", &cfg, &mut factory).unwrap();
        let streamed = streamed.expect("factory runs once per model");
        assert_eq!(streamed.finishes.get(), 1, "sink is sealed exactly once");
        let got = streamed.trace.borrow();
        assert_eq!(got.events, expect.events, "streamed events match the merged capture");
        assert!((got.meta.wall_us - expect.meta.wall_us).abs() < 1e-9);
        assert!((got.meta.wall_us - report.runs[0].wall_us).abs() < 1e-9);
        // And the streaming run's KPIs agree with the buffered run's.
        assert_eq!(report.runs[0].phases, buffered.runs[0].phases);
    }

    #[test]
    fn metrics_run_attaches_telemetry_and_builds_a_registry() {
        let cfg = LoadgenConfig {
            requests: 5,
            rate_per_s: 0.0,
            capture: true,
            metrics: true,
            window_us: 200.0,
            ..Default::default()
        };
        let report = run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg).unwrap();
        let run = &report.runs[0];
        let t = run.telemetry.as_ref().expect("metrics runs carry telemetry");
        assert!(t.online.totals.n_kernels > 0);
        assert!(!t.online.windows.is_empty());
        assert!(t.probe.steps() > 0, "the probe samples every scheduler step");
        assert!(run.ttft_us.p99 >= run.ttft_us.p95);
        assert!(run.tpot_us.p99 >= run.tpot_us.p95);
        let reg = report.metrics_registry().expect("telemetry yields a registry");
        let text = reg.prometheus_text();
        assert!(text.contains("taxbreak_hdbi{model=\"gpt2\"}"), "{text}");
        assert!(text.contains("taxbreak_probe_steps_total{model=\"gpt2\"}"), "{text}");
        let json = report.to_json();
        assert!(json.arr_of("runs").unwrap()[0].get("telemetry").is_some());
        // No metrics requested → no telemetry, no registry.
        let plain = LoadgenConfig { metrics: false, ..cfg };
        let r2 = run_sim_loadgen(&["gpt2".to_string()], "h200", &plain).unwrap();
        assert!(r2.runs[0].telemetry.is_none());
        assert!(r2.metrics_registry().is_none());
    }

    #[test]
    fn capture_memory_is_bounded_by_one_step_not_the_run() {
        let run_with = |requests: usize| {
            let cfg = LoadgenConfig {
                requests,
                rate_per_s: 0.0,
                capture: true,
                ..Default::default()
            };
            run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg)
                .unwrap()
                .runs
                .remove(0)
        };
        let small = run_with(4);
        let large = run_with(24);
        let small_total = small.trace.as_ref().unwrap().events.len();
        let large_total = large.trace.as_ref().unwrap().events.len();
        assert!(large_total > 2 * small_total, "the run itself grew");
        // The drain high-water mark is one scheduler step's output — it
        // must not scale with the number of requests served.
        assert!(small.peak_buffered_events > 0);
        assert_eq!(
            small.peak_buffered_events, large.peak_buffered_events,
            "peak in-flight events are O(1) in run length"
        );
        assert!(large.peak_buffered_events < large_total / 4);
    }

    /// The 100k-request variant of the bound above — too slow for the
    /// tier-1 suite, so the CI perf-smoke job runs it explicitly
    /// (`cargo test --release -- --ignored capture_memory_stays`).
    /// Short lengths keep the workload about scheduling pressure
    /// rather than per-token simulation cost.
    #[test]
    #[ignore = "minutes-long; exercised by the CI perf-smoke job"]
    fn capture_memory_stays_bounded_at_100k_requests() {
        let run_with = |requests: usize| {
            let cfg = LoadgenConfig {
                requests,
                rate_per_s: 0.0,
                prompt_len: LenDist::Uniform { lo: 2, hi: 4 },
                output_len: LenDist::Uniform { lo: 1, hi: 2 },
                ..Default::default()
            };
            run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg)
                .unwrap()
                .runs
                .remove(0)
        };
        let small = run_with(64);
        let large = run_with(100_000);
        assert_eq!(large.completed, 100_000);
        assert!(small.peak_buffered_events > 0);
        // The drain high-water mark is one scheduler step's output:
        // independent of run length, it must not grow past the
        // saturated-batch step the small run already reaches.
        // (×2 slack: the exact peak depends on the worst single-step
        // prefill mix, not the request count.)
        assert!(
            large.peak_buffered_events <= 2 * small.peak_buffered_events,
            "peak in-flight events grew with run length: {} (100k) vs {} (64)",
            large.peak_buffered_events,
            small.peak_buffered_events
        );
        // 100k requests of repeat kernels: symbol-table hits must
        // dwarf first-sight allocations.
        let (hits, misses) = crate::util::intern::stats();
        assert!(
            hits > 1000 * misses.max(1),
            "interner should absorb repeat kernels: {hits} hits vs {misses} misses"
        );
    }

    #[test]
    fn streamed_stats_match_post_hoc_trace_splits() {
        let cfg = LoadgenConfig {
            requests: 6,
            rate_per_s: 0.0,
            capture: true,
            ..Default::default()
        };
        let run = run_sim_loadgen(&["gpt2".to_string()], "h200", &cfg)
            .unwrap()
            .runs
            .remove(0);
        let trace = run.trace.as_ref().unwrap();
        assert_eq!(run.phases, per_phase_split(trace), "single-pass == two-pass per-phase");
        let (host, dev, _kernels) = crate::serving::real_trace_split(trace);
        assert!((run.per_device[0].hdbi - hdbi_of(host, dev)).abs() < 1e-12);
    }
}
