//! Paged KV-cache manager (vLLM-style substrate).
//!
//! Tracks block-granular KV allocation per request.  Two allocation
//! modes coexist:
//!
//! * **Reservation-backed** ([`PagedKvManager::reserve`]) — admission
//!   control holds the request's *worst-case* page demand up front;
//!   subsequent [`PagedKvManager::extend`] calls draw from the
//!   reservation, so a request admitted under a reservation can never
//!   hit [`KvError::OutOfPages`] mid-decode.  Unused reserved pages
//!   return to the pool via [`PagedKvManager::release_excess`] or a
//!   full [`PagedKvManager::release`].  This is the scheduler's mode
//!   (DESIGN.md §2): check-then-allocate admission is exactly the
//!   deadlock paged-KV systems exist to prevent.
//! * **Exact** ([`PagedKvManager::register`]) — pages are allocated for
//!   the current length only and `extend` competes with everyone else
//!   for the free pool.  Kept for callers that manage pressure
//!   themselves (and for the property tests that stress the allocator).
//!
//! With the tiny AOT models the physical cache tensor is dense (static
//! shapes), so this manager is the *bookkeeping* layer — the allocator
//! invariants (no double-use, exact reclamation, capacity ceiling) are
//! exactly vLLM's and are property-tested.

use std::collections::HashMap;

/// Page/block identifier.
pub type PageId = u32;

/// Errors from the allocator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvError {
    OutOfPages { need: usize, free: usize },
    UnknownRequest(u64),
    AlreadyRegistered(u64),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfPages { need, free } => {
                write!(f, "out of KV pages: need {need}, free {free}")
            }
            KvError::UnknownRequest(id) => write!(f, "unknown request {id}"),
            KvError::AlreadyRegistered(id) => write!(f, "request {id} already registered"),
        }
    }
}

impl std::error::Error for KvError {}

/// Per-request page state.
#[derive(Debug, Clone, Default)]
struct Entry {
    /// Pages backing tokens already stored.
    pages: Vec<PageId>,
    /// Pages held for future growth (worst-case reservation).
    reserved: Vec<PageId>,
    /// Tokens currently stored.
    stored: usize,
}

/// Block-granular KV allocator.
#[derive(Debug, Clone)]
pub struct PagedKvManager {
    page_tokens: usize,
    free: Vec<PageId>,
    total_pages: usize,
    entries: HashMap<u64, Entry>,
}

impl PagedKvManager {
    pub fn new(total_pages: usize, page_tokens: usize) -> PagedKvManager {
        assert!(page_tokens > 0);
        PagedKvManager {
            page_tokens,
            free: (0..total_pages as PageId).rev().collect(),
            total_pages,
            entries: HashMap::new(),
        }
    }

    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free.len()
    }

    /// Pages currently held in reservations (allocated but not yet
    /// backing stored tokens), across all requests.
    pub fn reserved_pages(&self) -> usize {
        self.entries.values().map(|e| e.reserved.len()).sum()
    }

    /// Can a request needing `tokens` of context be admitted now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens) <= self.free.len()
    }

    /// Register a request and allocate pages for `initial_tokens`
    /// exactly (no reservation; later `extend`s draw from the free
    /// pool).
    pub fn register(&mut self, req: u64, initial_tokens: usize) -> Result<(), KvError> {
        if self.entries.contains_key(&req) {
            return Err(KvError::AlreadyRegistered(req));
        }
        let need = self.pages_for(initial_tokens);
        if need > self.free.len() {
            return Err(KvError::OutOfPages {
                need,
                free: self.free.len(),
            });
        }
        let pages = self.free.split_off(self.free.len() - need);
        self.entries.insert(
            req,
            Entry {
                pages,
                reserved: Vec::new(),
                stored: initial_tokens,
            },
        );
        Ok(())
    }

    /// Register a request holding its **worst-case** page demand
    /// (`max_tokens` of context) in reserve, with zero tokens stored.
    /// Subsequent [`extend`](Self::extend) calls up to `max_tokens`
    /// are guaranteed to succeed without touching the free pool.
    pub fn reserve(&mut self, req: u64, max_tokens: usize) -> Result<(), KvError> {
        if self.entries.contains_key(&req) {
            return Err(KvError::AlreadyRegistered(req));
        }
        let need = self.pages_for(max_tokens);
        if need > self.free.len() {
            return Err(KvError::OutOfPages {
                need,
                free: self.free.len(),
            });
        }
        let reserved = self.free.split_off(self.free.len() - need);
        self.entries.insert(
            req,
            Entry {
                pages: Vec::new(),
                reserved,
                stored: 0,
            },
        );
        Ok(())
    }

    /// Pages an `extend(req, new_tokens)` would have to draw from the
    /// **free pool** — i.e. beyond the request's reservation.  Zero for
    /// unknown requests (the extend itself will report the error) and
    /// for reservation-covered growth.  Schedulers use this to turn
    /// would-be `OutOfPages` failures into backpressure *before*
    /// mutating any state.
    pub fn extend_need(&self, req: u64, new_tokens: usize) -> usize {
        let Some(e) = self.entries.get(&req) else {
            return 0;
        };
        let need_total = self.pages_for(e.stored + new_tokens);
        need_total
            .saturating_sub(e.pages.len())
            .saturating_sub(e.reserved.len())
    }

    /// Grow a request's context by `new_tokens` (decode appends).
    /// Pages come from the request's reservation first, then from the
    /// free pool.
    pub fn extend(&mut self, req: u64, new_tokens: usize) -> Result<(), KvError> {
        let free_len = self.free.len();
        let e = self.entries.get_mut(&req).ok_or(KvError::UnknownRequest(req))?;
        let target = e.stored + new_tokens;
        let need_total = self.pages_for(target);
        if need_total > e.pages.len() {
            let grow = need_total - e.pages.len();
            let from_reserved = grow.min(e.reserved.len());
            let from_free = grow - from_reserved;
            if from_free > free_len {
                return Err(KvError::OutOfPages {
                    need: from_free,
                    free: free_len,
                });
            }
            let start = e.reserved.len() - from_reserved;
            e.pages.extend(e.reserved.drain(start..));
            if from_free > 0 {
                let mut pages = self.free.split_off(free_len - from_free);
                e.pages.append(&mut pages);
            }
        }
        e.stored = target;
        Ok(())
    }

    /// Return a request's unused reserved pages to the free pool,
    /// keeping the pages that back stored tokens.  Returns the number
    /// of pages reclaimed.
    pub fn release_excess(&mut self, req: u64) -> Result<usize, KvError> {
        let e = self.entries.get_mut(&req).ok_or(KvError::UnknownRequest(req))?;
        let n = e.reserved.len();
        self.free.append(&mut e.reserved);
        Ok(n)
    }

    /// Release all pages of a finished request (stored + reserved).
    pub fn release(&mut self, req: u64) -> Result<usize, KvError> {
        let mut e = self.entries.remove(&req).ok_or(KvError::UnknownRequest(req))?;
        let n = e.pages.len() + e.reserved.len();
        self.free.append(&mut e.pages);
        self.free.append(&mut e.reserved);
        Ok(n)
    }

    /// Fraction of held page capacity (stored-backing + reserved)
    /// actually holding tokens — internal fragmentation plus
    /// reservation headroom (vLLM's motivation).
    pub fn occupancy(&self) -> f64 {
        let held_tokens: usize = self
            .entries
            .values()
            .map(|e| (e.pages.len() + e.reserved.len()) * self.page_tokens)
            .sum();
        if held_tokens == 0 {
            return 1.0;
        }
        let used_tokens: usize = self.entries.values().map(|e| e.stored).sum();
        used_tokens as f64 / held_tokens as f64
    }

    pub fn active_requests(&self) -> usize {
        self.entries.len()
    }

    /// Invariant check: page sets (stored-backing, reserved, free) are
    /// disjoint and account for every page (used by property tests).
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        let mut seen = std::collections::HashSet::new();
        for p in &self.free {
            anyhow::ensure!(seen.insert(*p), "page {p} duplicated in free list");
        }
        for (req, e) in &self.entries {
            for p in e.pages.iter().chain(e.reserved.iter()) {
                anyhow::ensure!(seen.insert(*p), "page {p} double-allocated (req {req})");
            }
            anyhow::ensure!(
                e.pages.len() == self.pages_for(e.stored),
                "req {req}: {} pages back {} stored tokens",
                e.pages.len(),
                e.stored
            );
        }
        anyhow::ensure!(
            seen.len() == self.total_pages,
            "page accounting mismatch: {} != {}",
            seen.len(),
            self.total_pages
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_extend_release_cycle() {
        let mut kv = PagedKvManager::new(10, 16);
        kv.register(1, 20).unwrap(); // 2 pages
        assert_eq!(kv.used_pages(), 2);
        kv.extend(1, 12).unwrap(); // 32 tokens -> 2 pages still
        assert_eq!(kv.used_pages(), 2);
        kv.extend(1, 1).unwrap(); // 33 tokens -> 3 pages
        assert_eq!(kv.used_pages(), 3);
        assert_eq!(kv.release(1).unwrap(), 3);
        assert_eq!(kv.used_pages(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_control() {
        let mut kv = PagedKvManager::new(4, 16);
        assert!(kv.can_admit(64));
        assert!(!kv.can_admit(65));
        kv.register(1, 48).unwrap(); // 3 pages
        assert!(kv.can_admit(16));
        assert!(!kv.can_admit(17));
        assert_eq!(
            kv.register(2, 32).unwrap_err(),
            KvError::OutOfPages { need: 2, free: 1 }
        );
    }

    #[test]
    fn error_messages_render() {
        assert_eq!(
            KvError::OutOfPages { need: 3, free: 1 }.to_string(),
            "out of KV pages: need 3, free 1"
        );
        assert_eq!(KvError::UnknownRequest(9).to_string(), "unknown request 9");
        assert_eq!(
            KvError::AlreadyRegistered(2).to_string(),
            "request 2 already registered"
        );
    }

    #[test]
    fn double_register_rejected() {
        let mut kv = PagedKvManager::new(4, 16);
        kv.register(7, 1).unwrap();
        assert_eq!(kv.register(7, 1).unwrap_err(), KvError::AlreadyRegistered(7));
        assert_eq!(kv.reserve(7, 1).unwrap_err(), KvError::AlreadyRegistered(7));
    }

    #[test]
    fn unknown_request_errors() {
        let mut kv = PagedKvManager::new(4, 16);
        assert_eq!(kv.extend(9, 1).unwrap_err(), KvError::UnknownRequest(9));
        assert_eq!(kv.release(9).unwrap_err(), KvError::UnknownRequest(9));
        assert_eq!(kv.release_excess(9).unwrap_err(), KvError::UnknownRequest(9));
    }

    #[test]
    fn occupancy_tracks_fragmentation() {
        let mut kv = PagedKvManager::new(10, 16);
        kv.register(1, 17).unwrap(); // 2 pages for 17 tokens
        let occ = kv.occupancy();
        assert!((occ - 17.0 / 32.0).abs() < 1e-9, "{occ}");
    }

    #[test]
    fn failed_register_leaves_state_clean() {
        let mut kv = PagedKvManager::new(2, 16);
        assert!(kv.register(1, 100).is_err());
        assert!(kv.reserve(1, 100).is_err());
        assert_eq!(kv.active_requests(), 0);
        assert_eq!(kv.free_pages(), 2);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reserve_holds_worst_case_and_extend_draws_from_it() {
        let mut kv = PagedKvManager::new(8, 16);
        kv.reserve(1, 48).unwrap(); // worst case: 3 pages held
        assert_eq!(kv.used_pages(), 3);
        assert_eq!(kv.reserved_pages(), 3);
        assert_eq!(kv.free_pages(), 5);
        kv.check_invariants().unwrap();

        // Committing the prompt moves pages out of the reservation
        // without touching the free pool.
        kv.extend(1, 20).unwrap(); // 2 pages backing, 1 still reserved
        assert_eq!(kv.used_pages(), 3);
        assert_eq!(kv.reserved_pages(), 1);
        assert_eq!(kv.free_pages(), 5);
        assert_eq!(kv.extend_need(1, 12), 0); // covered by the reservation

        // A competitor can take every free page; the reserved request
        // still extends to its maximum without OutOfPages.
        kv.register(2, 80).unwrap(); // 5 pages: pool exhausted
        assert_eq!(kv.free_pages(), 0);
        kv.extend(1, 28).unwrap(); // 48 tokens total: exactly the reservation
        assert_eq!(kv.reserved_pages(), 0);
        kv.check_invariants().unwrap();
        assert_eq!(kv.release(1).unwrap(), 3);
        assert_eq!(kv.release(2).unwrap(), 5);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn extend_beyond_reservation_falls_back_to_free_pool() {
        let mut kv = PagedKvManager::new(4, 16);
        kv.reserve(1, 16).unwrap(); // 1 page reserved
        assert_eq!(kv.extend_need(1, 40), 2); // needs 3 pages, holds 1
        kv.extend(1, 40).unwrap(); // 3 pages: 1 reserved + 2 free
        assert_eq!(kv.used_pages(), 3);
        assert_eq!(kv.reserved_pages(), 0);
        kv.check_invariants().unwrap();
        // Past the pool (40 + 64 tokens -> 7 pages, 4 more than held):
        // fails cleanly, state intact.
        assert_eq!(
            kv.extend(1, 64).unwrap_err(),
            KvError::OutOfPages { need: 4, free: 1 }
        );
        kv.check_invariants().unwrap();
        assert_eq!(kv.release(1).unwrap(), 3);
    }

    #[test]
    fn release_excess_returns_only_unused_reservation() {
        let mut kv = PagedKvManager::new(8, 16);
        kv.reserve(1, 64).unwrap(); // 4 pages held
        kv.extend(1, 17).unwrap(); // 2 backing, 2 reserved
        assert_eq!(kv.release_excess(1).unwrap(), 2);
        assert_eq!(kv.used_pages(), 2);
        assert_eq!(kv.reserved_pages(), 0);
        assert_eq!(kv.free_pages(), 6);
        kv.check_invariants().unwrap();
        // The request is still live and can grow — from the free pool.
        kv.extend(1, 32).unwrap();
        assert_eq!(kv.release(1).unwrap(), 4);
        assert_eq!(kv.used_pages(), 0);
    }

    #[test]
    fn occupancy_counts_reservation_headroom() {
        let mut kv = PagedKvManager::new(10, 16);
        kv.reserve(1, 64).unwrap(); // 4 pages held, 0 tokens stored
        assert!(kv.occupancy() < 1e-9);
        kv.extend(1, 32).unwrap(); // 32 of 64 token capacity
        assert!((kv.occupancy() - 0.5).abs() < 1e-9, "{}", kv.occupancy());
    }
}
