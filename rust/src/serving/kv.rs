//! Paged KV-cache manager (vLLM-style substrate).
//!
//! Tracks block-granular KV allocation per request: admission control
//! reserves pages up to the request's maximum context; pages free on
//! retirement.  With the tiny AOT models the physical cache tensor is
//! dense (static shapes), so this manager is the *bookkeeping* layer —
//! the allocator invariants (no double-use, exact reclamation, capacity
//! ceiling) are exactly vLLM's and are property-tested.

use std::collections::HashMap;

/// Page/block identifier.
pub type PageId = u32;

/// Errors from the allocator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvError {
    OutOfPages { need: usize, free: usize },
    UnknownRequest(u64),
    AlreadyRegistered(u64),
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KvError::OutOfPages { need, free } => {
                write!(f, "out of KV pages: need {need}, free {free}")
            }
            KvError::UnknownRequest(id) => write!(f, "unknown request {id}"),
            KvError::AlreadyRegistered(id) => write!(f, "request {id} already registered"),
        }
    }
}

impl std::error::Error for KvError {}

/// Block-granular KV allocator.
#[derive(Debug, Clone)]
pub struct PagedKvManager {
    page_tokens: usize,
    free: Vec<PageId>,
    total_pages: usize,
    tables: HashMap<u64, Vec<PageId>>,
    /// Tokens currently stored per request (for utilization stats).
    lengths: HashMap<u64, usize>,
}

impl PagedKvManager {
    pub fn new(total_pages: usize, page_tokens: usize) -> PagedKvManager {
        assert!(page_tokens > 0);
        PagedKvManager {
            page_tokens,
            free: (0..total_pages as PageId).rev().collect(),
            total_pages,
            tables: HashMap::new(),
            lengths: HashMap::new(),
        }
    }

    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_tokens)
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.total_pages - self.free.len()
    }

    /// Can a request needing `tokens` of context be admitted now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.pages_for(tokens) <= self.free.len()
    }

    /// Register a request and reserve pages for `initial_tokens`.
    pub fn register(&mut self, req: u64, initial_tokens: usize) -> Result<(), KvError> {
        if self.tables.contains_key(&req) {
            return Err(KvError::AlreadyRegistered(req));
        }
        let need = self.pages_for(initial_tokens);
        if need > self.free.len() {
            return Err(KvError::OutOfPages {
                need,
                free: self.free.len(),
            });
        }
        let pages = self.free.split_off(self.free.len() - need);
        self.tables.insert(req, pages);
        self.lengths.insert(req, initial_tokens);
        Ok(())
    }

    /// Grow a request's context by `new_tokens` (decode appends),
    /// allocating pages as needed.
    pub fn extend(&mut self, req: u64, new_tokens: usize) -> Result<(), KvError> {
        let len = *self
            .lengths
            .get(&req)
            .ok_or(KvError::UnknownRequest(req))?;
        let target = len + new_tokens;
        let have = self.tables[&req].len();
        let need_total = self.pages_for(target);
        if need_total > have {
            let extra = need_total - have;
            if extra > self.free.len() {
                return Err(KvError::OutOfPages {
                    need: extra,
                    free: self.free.len(),
                });
            }
            let mut pages = self.free.split_off(self.free.len() - extra);
            self.tables.get_mut(&req).unwrap().append(&mut pages);
        }
        self.lengths.insert(req, target);
        Ok(())
    }

    /// Release all pages of a finished request.
    pub fn release(&mut self, req: u64) -> Result<usize, KvError> {
        let pages = self.tables.remove(&req).ok_or(KvError::UnknownRequest(req))?;
        self.lengths.remove(&req);
        let n = pages.len();
        self.free.extend(pages);
        Ok(n)
    }

    /// Fraction of reserved page capacity actually holding tokens —
    /// internal fragmentation (vLLM's motivation).
    pub fn occupancy(&self) -> f64 {
        let reserved_tokens: usize = self
            .tables
            .values()
            .map(|p| p.len() * self.page_tokens)
            .sum();
        if reserved_tokens == 0 {
            return 1.0;
        }
        let used_tokens: usize = self.lengths.values().sum();
        used_tokens as f64 / reserved_tokens as f64
    }

    pub fn active_requests(&self) -> usize {
        self.tables.len()
    }

    /// Invariant check: page sets are disjoint and account for every
    /// non-free page (used by property tests).
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        let mut seen = std::collections::HashSet::new();
        for p in &self.free {
            anyhow::ensure!(seen.insert(*p), "page {p} duplicated in free list");
        }
        for (req, pages) in &self.tables {
            for p in pages {
                anyhow::ensure!(seen.insert(*p), "page {p} double-allocated (req {req})");
            }
        }
        anyhow::ensure!(
            seen.len() == self.total_pages,
            "page accounting mismatch: {} != {}",
            seen.len(),
            self.total_pages
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_extend_release_cycle() {
        let mut kv = PagedKvManager::new(10, 16);
        kv.register(1, 20).unwrap(); // 2 pages
        assert_eq!(kv.used_pages(), 2);
        kv.extend(1, 12).unwrap(); // 32 tokens -> 2 pages still
        assert_eq!(kv.used_pages(), 2);
        kv.extend(1, 1).unwrap(); // 33 tokens -> 3 pages
        assert_eq!(kv.used_pages(), 3);
        assert_eq!(kv.release(1).unwrap(), 3);
        assert_eq!(kv.used_pages(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_control() {
        let mut kv = PagedKvManager::new(4, 16);
        assert!(kv.can_admit(64));
        assert!(!kv.can_admit(65));
        kv.register(1, 48).unwrap(); // 3 pages
        assert!(kv.can_admit(16));
        assert!(!kv.can_admit(17));
        assert_eq!(
            kv.register(2, 32).unwrap_err(),
            KvError::OutOfPages { need: 2, free: 1 }
        );
    }

    #[test]
    fn error_messages_render() {
        assert_eq!(
            KvError::OutOfPages { need: 3, free: 1 }.to_string(),
            "out of KV pages: need 3, free 1"
        );
        assert_eq!(KvError::UnknownRequest(9).to_string(), "unknown request 9");
        assert_eq!(
            KvError::AlreadyRegistered(2).to_string(),
            "request 2 already registered"
        );
    }

    #[test]
    fn double_register_rejected() {
        let mut kv = PagedKvManager::new(4, 16);
        kv.register(7, 1).unwrap();
        assert_eq!(kv.register(7, 1).unwrap_err(), KvError::AlreadyRegistered(7));
    }

    #[test]
    fn unknown_request_errors() {
        let mut kv = PagedKvManager::new(4, 16);
        assert_eq!(kv.extend(9, 1).unwrap_err(), KvError::UnknownRequest(9));
        assert_eq!(kv.release(9).unwrap_err(), KvError::UnknownRequest(9));
    }

    #[test]
    fn occupancy_tracks_fragmentation() {
        let mut kv = PagedKvManager::new(10, 16);
        kv.register(1, 17).unwrap(); // 2 pages for 17 tokens
        let occ = kv.occupancy();
        assert!((occ - 17.0 / 32.0).abs() < 1e-9, "{occ}");
    }

    #[test]
    fn failed_register_leaves_state_clean() {
        let mut kv = PagedKvManager::new(2, 16);
        assert!(kv.register(1, 100).is_err());
        assert_eq!(kv.active_requests(), 0);
        assert_eq!(kv.free_pages(), 2);
        kv.check_invariants().unwrap();
    }
}
