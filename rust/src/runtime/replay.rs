//! Real-mode Phase-2 backend: replays PJRT executables in isolation.
//!
//! The real analog of the paper's nsys replay: each unique "kernel"
//! (PJRT executable invocation) is re-executed R times after W warm-ups
//! with a full sync between runs, measuring host dispatch (buffer prep
//! through `execute` call) and launch-to-result time. The null-kernel
//! artifact provides the real launch floor.

use crate::kernels::database::KernelEntry;
use crate::runtime::engine::Engine;
use crate::taxbreak::phase2::{ReplayBackend, ReplayConfig, ReplayMeasurement};

/// PJRT-backed replay. Executable resolution is by the kernel name the
/// recorder stamped (`pjrt::<artifact_name>`); the null probe uses the
/// dedicated null artifact.
pub struct PjrtReplayBackend<'e> {
    engine: &'e mut Engine,
}

impl<'e> PjrtReplayBackend<'e> {
    pub fn new(engine: &'e mut Engine) -> PjrtReplayBackend<'e> {
        PjrtReplayBackend { engine }
    }
}

impl ReplayBackend for PjrtReplayBackend<'_> {
    fn replay(&mut self, entry: &KernelEntry, cfg: &ReplayConfig) -> ReplayMeasurement {
        // Real replays re-run the *null* executable shape-for-shape when
        // the original executable cannot be re-invoked without its full
        // input state (decode needs a live cache). Dispatch cost is
        // dominated by buffer prep + execute-call overhead, which the
        // null probe shares; the measured launch path is the real PJRT
        // floor. Entries are tagged with their observed name so Eq. 9
        // matching still applies.
        let mut m = ReplayMeasurement {
            observed_name: entry.meta.kernel_name.to_string(),
            ..Default::default()
        };
        for i in 0..cfg.warmup + cfg.runs {
            match self.engine.null_run() {
                Ok((dispatch, launch)) if i >= cfg.warmup => {
                    m.t_dispatch_us.push(dispatch);
                    m.t_launch_us.push(launch);
                }
                _ => {}
            }
        }
        m
    }

    fn null_kernel(&mut self, cfg: &ReplayConfig) -> Vec<f64> {
        let mut out = Vec::with_capacity(cfg.runs);
        for i in 0..cfg.warmup + cfg.runs {
            if let Ok((_, launch)) = self.engine.null_run() {
                if i >= cfg.warmup {
                    out.push(launch);
                }
            }
        }
        out
    }
}
