//! Real-trace recorder: timestamps the rust→PJRT dispatch path into the
//! same [`Trace`] format the simulator emits, so the identical TaxBreak
//! pipeline analyzes real runs.
//!
//! Mapping (one record per executable invocation):
//! * `TorchOp`   — host preparation (literal/batch assembly + executable
//!   selection): the framework-translation analog;
//! * `RuntimeApi`— the `execute` call itself (launch-path analog);
//! * `Kernel`    — device computation: from `execute` return until the
//!   result literal is materialized (CPU PJRT runs the computation
//!   within this window).

use std::time::Instant;

use crate::trace::{EventKind, KernelMeta, Trace, TraceEvent, TraceMeta, Track};

/// Records wall-clock events relative to a common origin.
#[derive(Debug)]
pub struct TraceRecorder {
    origin: Instant,
    trace: Trace,
    next_corr: u64,
}

/// Handle for one in-flight invocation's timestamps.
#[derive(Debug, Clone, Copy)]
pub struct InvocationTimer {
    corr: u64,
    prep_start_us: f64,
    exec_start_us: f64,
    exec_return_us: f64,
}

impl InvocationTimer {
    pub fn prep_start_us(&self) -> f64 {
        self.prep_start_us
    }

    pub fn exec_start_us(&self) -> f64 {
        self.exec_start_us
    }

    pub fn exec_return_us(&self) -> f64 {
        self.exec_return_us
    }
}

impl TraceRecorder {
    pub fn new(meta: TraceMeta) -> TraceRecorder {
        TraceRecorder {
            origin: Instant::now(),
            trace: Trace::new(meta),
            next_corr: 0,
        }
    }

    pub fn now_us(&self) -> f64 {
        self.origin.elapsed().as_secs_f64() * 1e6
    }

    /// Begin an invocation (host preparation starts).
    pub fn begin(&mut self) -> InvocationTimer {
        self.next_corr += 1;
        InvocationTimer {
            corr: self.next_corr,
            prep_start_us: self.now_us(),
            exec_start_us: 0.0,
            exec_return_us: 0.0,
        }
    }

    /// Host preparation done; `execute` is about to be called.
    pub fn mark_exec_start(&self, t: &mut InvocationTimer) {
        t.exec_start_us = self.now_us();
    }

    /// `execute` returned (buffers issued).
    pub fn mark_exec_return(&self, t: &mut InvocationTimer) {
        t.exec_return_us = self.now_us();
    }

    /// Result literal materialized; emit the three events.
    pub fn finish(&mut self, t: InvocationTimer, name: &str, flops: f64, bytes: f64) {
        let sync_end = self.now_us();
        let meta = KernelMeta {
            kernel_name: format!("pjrt::{name}").into(),
            family: "pjrt_exec".into(),
            aten_op: format!("exec::{name}").into(),
            shapes_key: name.into(),
            grid: [1, 1, 1],
            block: [1, 1, 1],
            lib_mediated: false,
            flops,
            bytes,
        };
        self.trace.push(TraceEvent {
            kind: EventKind::TorchOp,
            name: format!("serve.{name}"),
            ts_us: t.prep_start_us,
            dur_us: t.exec_return_us - t.prep_start_us,
            correlation_id: t.corr,
            track: Track::Host,
            device: None,
            args: None,
            meta: None,
        });
        self.trace.push(TraceEvent {
            kind: EventKind::AtenOp,
            name: format!("prep::{name}"),
            ts_us: t.prep_start_us,
            dur_us: t.exec_start_us - t.prep_start_us,
            correlation_id: t.corr,
            track: Track::Host,
            device: None,
            args: None,
            meta: None,
        });
        self.trace.push(TraceEvent {
            kind: EventKind::RuntimeApi,
            name: "pjrt::execute".to_string(),
            ts_us: t.exec_start_us,
            dur_us: t.exec_return_us - t.exec_start_us,
            correlation_id: t.corr,
            track: Track::Host,
            device: None,
            args: None,
            meta: None,
        });
        self.trace.push(TraceEvent {
            kind: EventKind::Kernel,
            name: format!("pjrt::{name}"),
            ts_us: t.exec_return_us,
            dur_us: sync_end - t.exec_return_us,
            correlation_id: t.corr,
            track: Track::Device(0),
            device: None,
            args: None,
            meta: Some(meta),
        });
    }

    /// Close the recorder, stamping the wall-clock.
    pub fn into_trace(mut self) -> Trace {
        self.trace.meta.wall_us = self.now_us();
        self.trace
    }

    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    /// Move out the events recorded since the last drain (metadata and
    /// correlation numbering stay in place) — streaming capture support.
    pub fn drain_events(&mut self) -> Vec<TraceEvent> {
        std::mem::take(&mut self.trace.events)
    }

    /// Run metadata with the wall-clock stamped "now".
    pub fn meta_now(&self) -> TraceMeta {
        let mut meta = self.trace.meta.clone();
        meta.wall_us = self.now_us();
        meta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_invocation_chain() {
        let mut r = TraceRecorder::new(TraceMeta::default());
        let mut t = r.begin();
        std::thread::sleep(std::time::Duration::from_micros(200));
        r.mark_exec_start(&mut t);
        std::thread::sleep(std::time::Duration::from_micros(100));
        r.mark_exec_return(&mut t);
        std::thread::sleep(std::time::Duration::from_micros(100));
        r.finish(t, "prefill_b1_s32", 1e6, 1e4);

        let trace = r.into_trace();
        assert_eq!(trace.events.len(), 4);
        assert_eq!(trace.kernel_count(), 1);
        let chains = trace.correlation_chains();
        let c = &chains[&1];
        assert!(c.torch_op.is_some() && c.runtime_api.is_some() && c.kernel.is_some());
        // Ordering: prep <= exec_start <= exec_return <= kernel end.
        let api = c.runtime_api.unwrap();
        let k = c.kernel.unwrap();
        assert!(api.ts_us >= c.torch_op.unwrap().ts_us);
        assert!(k.ts_us >= api.ts_us);
        assert!(trace.meta.wall_us >= k.end_us());
    }

    #[test]
    fn correlation_ids_increment() {
        let mut r = TraceRecorder::new(TraceMeta::default());
        for i in 1..=3u64 {
            let mut t = r.begin();
            r.mark_exec_start(&mut t);
            r.mark_exec_return(&mut t);
            r.finish(t, "step", 0.0, 0.0);
            assert_eq!(r.trace().events.last().unwrap().correlation_id, i);
        }
    }
}
