//! Runtime layer: the engines that execute serving workloads, plus AOT
//! artifact loading and real-trace instrumentation.
//!
//! The layer is split by the `real-pjrt` cargo feature (DESIGN.md §8):
//!
//! * **Always compiled** — [`backend`] (the [`Backend`] trait and the
//!   deterministic, pure-Rust [`SimEngine`]), [`artifact`] (manifest and
//!   weights parsing; plain files + minijson) and [`recorder`] (the
//!   wall-clock trace recorder).  The default build has **zero**
//!   dependency on any `xla`/PJRT crate.
//! * **`real-pjrt` only** — `engine` (the PJRT execution engine) and
//!   `replay` (the real-mode Phase-2 backend).  These load AOT
//!   artifacts (HLO text + weights) and run them on the PJRT CPU
//!   client.  Python/JAX runs only at `make artifacts`; interchange is
//!   HLO *text* — jax ≥ 0.5 emits 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects in proto form; the text parser
//!   reassigns ids (see `python/compile/aot.py`).
//!
//! The real-mode analog of the paper's stack:
//! * host buffer prep + executable selection  ↔ framework translation,
//! * the PJRT `execute` call                   ↔ the launch API,
//! * device computation (sync wait)            ↔ kernel execution.
//!
//! In real mode the unit of dispatch is one PJRT *executable* rather
//! than one CUDA kernel — TaxBreak consumes the same trace format
//! either way (trace-format-as-interface, DESIGN.md §9).  The simulated
//! engine emits the identical event shape, so everything downstream of
//! the trace is backend-agnostic.

pub mod artifact;
pub mod backend;
#[cfg(feature = "real-pjrt")]
pub mod engine;
pub mod recorder;
#[cfg(feature = "real-pjrt")]
pub mod replay;

pub use artifact::{ArtifactIndex, Manifest, ParamsFile, TensorSpec};
pub use backend::{Backend, SimEngine, SimEngineConfig};
#[cfg(feature = "real-pjrt")]
pub use engine::Engine;
pub use recorder::TraceRecorder;
#[cfg(feature = "real-pjrt")]
pub use replay::PjrtReplayBackend;
