//! Real-mode runtime: load AOT artifacts (HLO text + weights) and run
//! them on the PJRT CPU client from the rust hot path.
//!
//! Python/JAX runs only at `make artifacts`; this module is the entire
//! request-path compute story.  Interchange is HLO *text* — jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects in
//! proto form; the text parser reassigns ids (see
//! /opt/xla-example/README.md and python/compile/aot.py).
//!
//! The real-mode analog of the paper's stack:
//! * host buffer prep + executable selection  ↔ framework translation,
//! * the PJRT `execute` call                   ↔ the launch API,
//! * device computation (sync wait)            ↔ kernel execution.
//!
//! In real mode the unit of dispatch is one PJRT *executable* rather
//! than one CUDA kernel — TaxBreak consumes the same trace format
//! either way (trace-format-as-interface, DESIGN.md §9).

pub mod artifact;
pub mod engine;
pub mod recorder;
pub mod replay;

pub use artifact::{ArtifactIndex, Manifest, ParamsFile, TensorSpec};
pub use engine::Engine;
pub use recorder::TraceRecorder;
pub use replay::PjrtReplayBackend;
