//! The PJRT execution engine: compiled entry points, weights, and the
//! typed prefill/decode/null operations with trace instrumentation.

use std::path::Path;

use crate::runtime::artifact::{ArtifactIndex, Manifest, ParamsFile};
use crate::runtime::recorder::TraceRecorder;
use crate::trace::TraceMeta;

/// One compiled entry point (executable + its manifest).
struct Compiled {
    manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
}

/// Model facts the engine needs at run time (from the manifests).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineConfig {
    pub vocab: usize,
    pub max_seq: usize,
    pub cache_elems_b1: usize,
}

/// PJRT engine for one model variant.
///
/// Holds the CPU PJRT client, every compiled (entry, bucket) executable
/// of the variant, the weights as device-ready literals, and a
/// [`TraceRecorder`] capturing the real dispatch path.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    variant: String,
    config: EngineConfig,
    prefills: Vec<Compiled>, // sorted by (batch, seq)
    decodes: Vec<Compiled>,  // sorted by batch
    null: Compiled,
    params: Vec<xla::Literal>,
    pub recorder: TraceRecorder,
}

/// Result of one prefill: last-real-position logits per sequence + the
/// cache literal (max_seq-sized, bucket batch).
pub struct PrefillOut {
    pub logits: Vec<Vec<f32>>,
    pub cache: xla::Literal,
    /// Bucket batch the cache is shaped for.
    pub bucket_batch: usize,
}

/// Result of one decode step.
pub struct DecodeOut {
    pub logits: Vec<Vec<f32>>,
    pub cache: xla::Literal,
}

impl Engine {
    /// Load and compile every artifact of `variant` from `dir`.
    pub fn load(dir: &Path, variant: &str) -> anyhow::Result<Engine> {
        let idx = ArtifactIndex::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;

        let compile = |name: &str| -> anyhow::Result<Compiled> {
            let manifest = Manifest::load(&idx.manifest_path(name))?;
            let proto = xla::HloModuleProto::from_text_file(idx.hlo_path(name))
                .map_err(|e| anyhow::anyhow!("parsing HLO for {name}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
            Ok(Compiled { manifest, exe })
        };

        let mut prefills = Vec::new();
        for name in idx.of_variant(variant, "prefill").cloned().collect::<Vec<_>>() {
            prefills.push(compile(&name)?);
        }
        anyhow::ensure!(!prefills.is_empty(), "no prefill artifacts for '{variant}'");
        prefills.sort_by_key(|c| (c.manifest.batch, c.manifest.seq));

        let mut decodes = Vec::new();
        for name in idx.of_variant(variant, "decode").cloned().collect::<Vec<_>>() {
            decodes.push(compile(&name)?);
        }
        anyhow::ensure!(!decodes.is_empty(), "no decode artifacts for '{variant}'");
        decodes.sort_by_key(|c| c.manifest.batch);

        let null = compile("null_kernel")?;

        let m0 = &prefills[0].manifest;
        let vocab = m0.config_usize("vocab")?;
        let max_seq = m0.config_usize("max_seq")?;
        let cache_spec = &m0.outputs[1];
        anyhow::ensure!(cache_spec.name == "cache", "unexpected output layout");
        let cache_elems_b1 = cache_spec.elements() / m0.batch;

        let params = ParamsFile::load(dir, variant)?.literals()?;

        let recorder = TraceRecorder::new(TraceMeta {
            platform: "pjrt-cpu".to_string(),
            model: variant.to_string(),
            phase: "serve".to_string(),
            batch: 0,
            seq: 0,
            m_tokens: 0,
            wall_us: 0.0,
        });

        Ok(Engine {
            client,
            variant: variant.to_string(),
            config: EngineConfig {
                vocab,
                max_seq,
                cache_elems_b1,
            },
            prefills,
            decodes,
            null,
            params,
            recorder,
        })
    }

    pub fn variant(&self) -> &str {
        &self.variant
    }

    pub fn config(&self) -> EngineConfig {
        self.config
    }

    /// Available decode bucket batch sizes.
    pub fn decode_buckets(&self) -> Vec<usize> {
        self.decodes.iter().map(|c| c.manifest.batch).collect()
    }

    /// Smallest prefill bucket fitting (batch, len).
    fn pick_prefill(&self, batch: usize, len: usize) -> anyhow::Result<usize> {
        self.prefills
            .iter()
            .position(|c| c.manifest.batch >= batch && c.manifest.seq >= len)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no prefill bucket for batch={batch} len={len} (have {:?})",
                    self.prefills
                        .iter()
                        .map(|c| (c.manifest.batch, c.manifest.seq))
                        .collect::<Vec<_>>()
                )
            })
    }

    fn pick_decode(&self, batch: usize) -> anyhow::Result<usize> {
        self.decodes
            .iter()
            .position(|c| c.manifest.batch >= batch)
            .ok_or_else(|| anyhow::anyhow!("no decode bucket for batch={batch}"))
    }

    /// Run prefill over `prompts` (ragged), padding to the bucket.
    /// Returns last-real-token logits per prompt + the cache.
    pub fn prefill(&mut self, prompts: &[Vec<i32>]) -> anyhow::Result<PrefillOut> {
        let batch = prompts.len();
        anyhow::ensure!(batch > 0, "empty prefill batch");
        let max_len = prompts.iter().map(|p| p.len()).max().unwrap();
        let mut timer = self.recorder.begin();

        let ci = self.pick_prefill(batch, max_len)?;
        let (bb, bs) = (
            self.prefills[ci].manifest.batch,
            self.prefills[ci].manifest.seq,
        );
        // Pad tokens to the (bucket_batch, bucket_seq) grid.
        let mut tokens = vec![0i32; bb * bs];
        for (i, p) in prompts.iter().enumerate() {
            tokens[i * bs..i * bs + p.len()].copy_from_slice(p);
        }
        let tokens_lit = xla::Literal::vec1(&tokens)
            .reshape(&[bb as i64, bs as i64])
            .map_err(|e| anyhow::anyhow!("tokens literal: {e:?}"))?;

        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.push(&tokens_lit);

        self.recorder.mark_exec_start(&mut timer);
        let result = self.prefills[ci]
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("prefill execute: {e:?}"))?;
        drop(args);
        self.recorder.mark_exec_return(&mut timer);

        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("prefill sync: {e:?}"))?;
        let (logits_lit, cache) = out
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("prefill tuple: {e:?}"))?;
        let flat: Vec<f32> = logits_lit
            .to_vec()
            .map_err(|e| anyhow::anyhow!("logits vec: {e:?}"))?;
        // logits: (bb, bs, vocab) — pick each prompt's last real token.
        let v = self.config.vocab;
        let logits = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let base = (i * bs + (p.len() - 1)) * v;
                flat[base..base + v].to_vec()
            })
            .collect();

        let name = self.prefills[ci].manifest.name.clone();
        self.recorder
            .finish(timer, &name, 0.0, (tokens.len() * 4) as f64);
        Ok(PrefillOut {
            logits,
            cache,
            bucket_batch: bb,
        })
    }

    /// One decode step over a bucket-shaped cache.
    ///
    /// `tokens.len()` must equal the cache's bucket batch; `pos` is the
    /// index the new tokens occupy.
    pub fn decode(
        &mut self,
        cache: xla::Literal,
        pos: usize,
        tokens: &[i32],
    ) -> anyhow::Result<DecodeOut> {
        let batch = tokens.len();
        let mut timer = self.recorder.begin();
        let ci = self.pick_decode(batch)?;
        let bb = self.decodes[ci].manifest.batch;
        anyhow::ensure!(
            bb == batch,
            "decode bucket batch {bb} != caller batch {batch} (pad tokens to the bucket)"
        );
        let tokens_lit = xla::Literal::vec1(tokens);
        let pos_lit = xla::Literal::vec1(&[pos as i32]);

        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.push(&cache);
        args.push(&pos_lit);
        args.push(&tokens_lit);

        self.recorder.mark_exec_start(&mut timer);
        let result = self.decodes[ci]
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("decode execute: {e:?}"))?;
        drop(args);
        self.recorder.mark_exec_return(&mut timer);

        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("decode sync: {e:?}"))?;
        let (logits_lit, new_cache) = out
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("decode tuple: {e:?}"))?;
        let flat: Vec<f32> = logits_lit
            .to_vec()
            .map_err(|e| anyhow::anyhow!("logits vec: {e:?}"))?;
        let v = self.config.vocab;
        let logits = (0..batch).map(|i| flat[i * v..(i + 1) * v].to_vec()).collect();

        let name = self.decodes[ci].manifest.name.clone();
        self.recorder
            .finish(timer, &name, 0.0, (batch * 4) as f64);
        Ok(DecodeOut {
            logits,
            cache: new_cache,
        })
    }

    /// Null-kernel run: the real-mode launch-floor probe (Table III
    /// analog on PJRT).  Returns (dispatch_us, launch_to_result_us).
    pub fn null_run(&mut self) -> anyhow::Result<(f64, f64)> {
        let mut timer = self.recorder.begin();
        let x = xla::Literal::vec1(&[0f32; 8]);
        let args = [&x];
        self.recorder.mark_exec_start(&mut timer);
        let result = self
            .null
            .exe
            .execute::<&xla::Literal>(&args)
            .map_err(|e| anyhow::anyhow!("null execute: {e:?}"))?;
        self.recorder.mark_exec_return(&mut timer);
        let _ = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("null sync: {e:?}"))?;
        let now = self.recorder.now_us();
        let dispatch = timer.exec_start_us() - timer.prep_start_us();
        let launch = now - timer.exec_start_us();
        self.recorder.finish(timer, "null_kernel", 0.0, 32.0);
        Ok((dispatch, launch))
    }

    /// Swap the recorder out, returning the captured trace.
    pub fn take_trace(&mut self) -> crate::trace::Trace {
        let meta = self.recorder.trace().meta.clone();
        let fresh = TraceRecorder::new(meta);
        std::mem::replace(&mut self.recorder, fresh).into_trace()
    }

    /// Greedy argmax over logits (delegates to the backend-shared rule
    /// so real and simulated greedy decoding cannot diverge).
    pub fn argmax(logits: &[f32]) -> i32 {
        crate::runtime::backend::argmax(logits)
    }
}

