//! AOT artifact discovery and loading: manifests, weights, index.
//!
//! File layout produced by `python/compile/aot.py` (one weights file per
//! variant, one HLO + manifest per (variant, entry, bucket)):
//!
//! ```text
//! artifacts/index.json
//! artifacts/<name>.hlo.txt
//! artifacts/<name>.manifest.json
//! artifacts/<variant>.params.bin   (flat little-endian f32)
//! artifacts/<variant>.params.json  (name/shape/offset table)
//! ```

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One tensor's (name, shape, dtype) from a manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(v: &Json) -> anyhow::Result<TensorSpec> {
        let shape = v
            .arr_of("shape")?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("non-integer dim in shape"))
            })
            .collect::<anyhow::Result<Vec<usize>>>()?;
        Ok(TensorSpec {
            name: v.str_of("name")?.to_string(),
            shape,
            dtype: v.str_of("dtype")?.to_string(),
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// A compiled entry point's manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub name: String,
    /// "prefill" | "decode" | "null".
    pub entry: String,
    pub variant: String,
    pub batch: usize,
    pub seq: usize,
    pub params_file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Model config echoed by the AOT pipeline (vocab, max_seq, ...).
    pub config: Option<Json>,
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let v = Json::parse(&text)?;
        let specs = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
            v.arr_of(key)?.iter().map(TensorSpec::from_json).collect()
        };
        Ok(Manifest {
            name: v.str_of("name")?.to_string(),
            entry: v.str_of("entry")?.to_string(),
            variant: v.str_of("variant")?.to_string(),
            batch: v.usize_of("batch")?,
            seq: v.usize_of("seq")?,
            params_file: v.str_of("params_file")?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            config: v.get("config").cloned(),
        })
    }

    /// Number of leading inputs that are weights (everything before the
    /// non-param runtime inputs: tokens / cache / pos).
    pub fn n_param_inputs(&self) -> usize {
        self.inputs
            .iter()
            .take_while(|s| !matches!(s.name.as_str(), "tokens" | "cache" | "pos" | "x"))
            .count()
    }

    /// Config field accessor (vocab, max_seq ...).
    pub fn config_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.config
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("manifest {} has no config", self.name))?
            .usize_of(key)
    }
}

/// One weight tensor's placement in the flat file.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

/// The variant's flat weights file + offset table.
#[derive(Debug, Clone)]
pub struct ParamsFile {
    pub variant: String,
    pub entries: Vec<ParamEntry>,
    pub data: Vec<u8>,
}

impl ParamsFile {
    pub fn load(dir: &Path, variant: &str) -> anyhow::Result<ParamsFile> {
        let table_path = dir.join(format!("{variant}.params.json"));
        let text = std::fs::read_to_string(&table_path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", table_path.display()))?;
        let v = Json::parse(&text)?;
        let mut entries = Vec::new();
        for e in v.arr_of("params")? {
            entries.push(ParamEntry {
                name: e.str_of("name")?.to_string(),
                shape: e
                    .arr_of("shape")?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                offset: e.usize_of("offset")?,
                bytes: e.usize_of("bytes")?,
            });
        }
        let bin_path = dir.join(format!("{variant}.params.bin"));
        let data = std::fs::read(&bin_path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", bin_path.display()))?;
        let total = v.usize_of("total_bytes")?;
        anyhow::ensure!(
            data.len() == total,
            "weights file size {} != table total {total}",
            data.len()
        );
        Ok(ParamsFile {
            variant: variant.to_string(),
            entries,
            data,
        })
    }

    /// Raw bytes of one tensor.
    pub fn bytes_of(&self, entry: &ParamEntry) -> &[u8] {
        &self.data[entry.offset..entry.offset + entry.bytes]
    }

    /// Build PJRT literals for every tensor, in file order (which is
    /// the manifest input order by construction). Real-mode only: the
    /// default build keeps artifact *parsing* available without any
    /// xla dependency.
    #[cfg(feature = "real-pjrt")]
    pub fn literals(&self) -> anyhow::Result<Vec<xla::Literal>> {
        self.entries
            .iter()
            .map(|e| {
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &e.shape,
                    self.bytes_of(e),
                )
                .map_err(|err| anyhow::anyhow!("literal for {}: {err:?}", e.name))
            })
            .collect()
    }
}

/// The artifacts directory index.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactIndex {
    pub dir: PathBuf,
    pub artifacts: Vec<String>,
}

impl ArtifactIndex {
    pub fn load(dir: &Path) -> anyhow::Result<ArtifactIndex> {
        let text = std::fs::read_to_string(dir.join("index.json"))
            .map_err(|e| anyhow::anyhow!("no artifacts at {} ({e}); run `make artifacts`", dir.display()))?;
        let v = Json::parse(&text)?;
        let artifacts = v
            .arr_of("artifacts")?
            .iter()
            .filter_map(|a| a.as_str().map(|s| s.to_string()))
            .collect();
        Ok(ArtifactIndex {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn manifest_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.manifest.json"))
    }

    /// Artifact names of one variant + entry kind.
    pub fn of_variant<'a>(&'a self, variant: &'a str, entry: &'a str) -> impl Iterator<Item = &'a String> {
        self.artifacts
            .iter()
            .filter(move |n| n.starts_with(&format!("{variant}_{entry}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("index.json").exists()
    }

    #[test]
    fn index_lists_all_variants() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let idx = ArtifactIndex::load(&artifacts_dir()).unwrap();
        assert!(idx.artifacts.iter().any(|a| a == "null_kernel"));
        assert!(idx.of_variant("dense_fused", "prefill").count() >= 2);
        assert!(idx.of_variant("dense_fused", "decode").count() >= 1);
        assert!(idx.of_variant("moe", "prefill").count() >= 2);
    }

    #[test]
    fn manifest_roundtrip() {
        if !have_artifacts() {
            return;
        }
        let idx = ArtifactIndex::load(&artifacts_dir()).unwrap();
        let m = Manifest::load(&idx.manifest_path("dense_fused_prefill_b1_s32")).unwrap();
        assert_eq!(m.entry, "prefill");
        assert_eq!((m.batch, m.seq), (1, 32));
        // params..., tokens
        assert_eq!(m.inputs.last().unwrap().name, "tokens");
        assert_eq!(m.inputs.last().unwrap().shape, vec![1, 32]);
        assert_eq!(m.n_param_inputs(), m.inputs.len() - 1);
        assert_eq!(m.outputs[0].name, "logits");
        assert!(m.config_usize("vocab").unwrap() > 0);
    }

    #[test]
    fn params_file_matches_manifest_order() {
        if !have_artifacts() {
            return;
        }
        let idx = ArtifactIndex::load(&artifacts_dir()).unwrap();
        let m = Manifest::load(&idx.manifest_path("dense_fused_prefill_b1_s32")).unwrap();
        let p = ParamsFile::load(&artifacts_dir(), "dense_fused").unwrap();
        assert_eq!(p.entries.len(), m.n_param_inputs());
        for (pe, spec) in p.entries.iter().zip(m.inputs.iter()) {
            assert_eq!(pe.name, spec.name);
            assert_eq!(pe.shape, spec.shape);
            assert_eq!(pe.bytes, 4 * spec.elements());
        }
    }

    #[cfg(feature = "real-pjrt")]
    #[test]
    fn params_literals_build() {
        if !have_artifacts() {
            return;
        }
        let p = ParamsFile::load(&artifacts_dir(), "dense_fused").unwrap();
        let lits = p.literals().unwrap();
        assert_eq!(lits.len(), p.entries.len());
        assert_eq!(
            lits[0].element_count(),
            p.entries[0].shape.iter().product::<usize>()
        );
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = ArtifactIndex::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
