//! Runtime backend abstraction: one surface over every engine that can
//! drive the serving layer end to end.
//!
//! The serving demo needs four capabilities beyond the scheduler-facing
//! [`ModelBackend`] contract: a variant label, the vocabulary size (to
//! synthesize request mixes), the null-executable launch-floor probe
//! (Table III analog), and trace capture.  [`Backend`] bundles them.
//!
//! Two implementations exist:
//!
//! * [`SimEngine`] (this module, always compiled) — a deterministic,
//!   pure-Rust stand-in for the PJRT engine.  Logits are a seeded
//!   function of the token history (`util::rng`), so greedy generation
//!   is reproducible and prefill/decode teacher-forcing consistency
//!   holds exactly; per-invocation timing comes from the host-latency
//!   distributions and the device cost model (`kernels::cost`), and the
//!   emitted trace has the same event shape as the real recorder's.
//! * `runtime::engine::Engine` (behind the `real-pjrt` feature) — the
//!   real PJRT engine over AOT artifacts; see DESIGN.md §8 for the
//!   split.

use std::collections::VecDeque;

use crate::faults::{
    FaultPlan, HostSeg, BACKOFF_BASE_US, MAX_LAUNCH_ATTEMPTS, TRANSIENT_LAUNCH_MARKER,
};
use crate::hardware::Platform;
use crate::kernels::cost;
use crate::kernels::family::Family;
use crate::models::ModelSpec;
use crate::serving::ModelBackend;
use crate::timeline::{self, StreamRef, Topology};
use crate::trace::{EventKind, KernelMeta, ReplayArgs, Trace, TraceEvent, TraceMeta, Track};
use crate::util::rng::Rng;

/// Greedy argmax over logits (first index wins ties) — the one shared
/// greedy-decoding rule; both the simulated and the real engine
/// delegate here so the backends cannot diverge.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// What the serving demo needs from an engine, on top of the
/// scheduler-facing [`ModelBackend`] contract.
pub trait Backend: ModelBackend {
    /// Model-variant label for reports.
    fn variant(&self) -> &str;

    /// Vocabulary size (bounds synthetic request token ids).
    fn vocab(&self) -> usize;

    /// Null-executable launch-floor probe; returns
    /// `(dispatch_us, launch_to_result_us)`.
    fn null_run(&mut self) -> anyhow::Result<(f64, f64)>;

    /// Swap the recorder out, returning the captured trace.
    fn take_trace(&mut self) -> Trace;

    /// Move out the events buffered since the last drain (run metadata
    /// stays in place). The streaming capture path calls this after
    /// every scheduler step and forwards into a [`crate::trace::TraceSink`],
    /// so backend event memory stays bounded by one step's output
    /// instead of growing with the whole run.
    fn drain_events(&mut self) -> Vec<TraceEvent>;

    /// Current run metadata, wall-clock stamped "now".
    fn trace_meta(&self) -> TraceMeta;

    /// Failed launch attempts the engine has re-issued so far (fault
    /// injection, DESIGN.md §16). Engines without fault support
    /// report 0.
    fn retries(&self) -> u64 {
        0
    }
}

/// Compiled-shape grid of the simulated engine (mirrors the AOT toy
/// artifact grid produced by `python/compile/aot.py`), plus its
/// timeline topology.
#[derive(Debug, Clone)]
pub struct SimEngineConfig {
    pub vocab: usize,
    pub max_seq: usize,
    /// Decode bucket batch sizes, ascending.
    pub buckets: Vec<usize>,
    /// CUDA streams the engine rotates executable invocations over.
    /// The serving contract is host-blocking (logits are consumed each
    /// step), so streams re-label device lanes in the trace and the
    /// Chrome timeline without changing wall-clock — honest modeling:
    /// a synchronous engine cannot exploit stream overlap, which is
    /// itself a TaxBreak finding.
    pub streams: usize,
    /// Device id stamped on emitted events — replica serving
    /// (`taxbreak loadgen --devices N`) runs one engine per device.
    /// Device 0 omits the stamp, keeping single-replica traces
    /// byte-identical to spec v1.
    pub device_id: u32,
}

impl Default for SimEngineConfig {
    fn default() -> Self {
        SimEngineConfig {
            vocab: 251,
            max_seq: 128,
            buckets: vec![1, 4],
            streams: 1,
            device_id: 0,
        }
    }
}

/// Group cache of the simulated engine: the per-slot token histories
/// (the functional analog of the real engine's KV-cache literal).
pub struct SimCache {
    tokens: Vec<Vec<i32>>,
    bucket: usize,
}

/// Deterministic, pure-Rust engine with the real engine's surface.
///
/// One `prefill`/`decode` call maps to one executable invocation, as in
/// real mode: the trace records a TorchOp (whole host span), an AtenOp
/// (preparation), a RuntimeApi (the execute call) and a Kernel (device
/// computation) per invocation, on a virtual microsecond clock.
pub struct SimEngine {
    model: ModelSpec,
    platform: Platform,
    cfg: SimEngineConfig,
    variant: String,
    seed: u64,
    timing_rng: Rng,
    /// The shared discrete-event timeline: one host thread (the
    /// engine's virtual clock) + the configured stream set.
    tl: timeline::Engine,
    /// Stream the next invocation lands on (round-robin).
    next_stream: u32,
    /// Replay script: when armed, every timing draw pops the next
    /// recorded value instead of sampling `timing_rng`, so a replayed
    /// run reproduces the recording's virtual clock exactly (the RNG is
    /// never re-seeded — Box-Muller spare caching makes re-seeding
    /// unsound mid-stream).
    script: Option<VecDeque<f64>>,
    /// Armed fault plan (`--faults`, DESIGN.md §16): pre-realized
    /// windows injected deterministically into host-latency draws,
    /// device submissions and the launch path. `None` leaves every hot
    /// path structurally untouched, so fault-free runs stay
    /// byte-identical to pre-fault builds.
    faults: Option<FaultPlan>,
    /// Failed launch attempts re-issued so far (monotone counter).
    retries: u64,
    trace: Trace,
    corr: u64,
}

impl SimEngine {
    pub fn new(
        model: ModelSpec,
        platform: Platform,
        cfg: SimEngineConfig,
        seed: u64,
    ) -> SimEngine {
        assert!(cfg.streams >= 1, "SimEngine needs at least one stream");
        let trace = Trace::new(TraceMeta {
            platform: platform.name.clone(),
            model: model.name.clone(),
            phase: "serve".to_string(),
            batch: 0,
            seq: 0,
            m_tokens: 0,
            wall_us: 0.0,
        });
        let tl = timeline::Engine::new(Topology {
            devices: 1,
            streams_per_device: cfg.streams,
            host_threads: 1,
        });
        SimEngine {
            variant: format!("sim:{}", model.name),
            timing_rng: Rng::new(seed).fork_str("sim-engine-timing"),
            seed,
            model,
            platform,
            cfg,
            tl,
            next_stream: 0,
            script: None,
            faults: None,
            retries: 0,
            trace,
            corr: 0,
        }
    }

    /// Engine with the default toy shape grid.
    pub fn with_defaults(model: ModelSpec, platform: Platform, seed: u64) -> SimEngine {
        SimEngine::new(model, platform, SimEngineConfig::default(), seed)
    }

    /// Engine with an explicit timeline topology (`taxbreak loadgen
    /// --streams/--devices`): `streams` per engine, stamped as replica
    /// `device_id`.
    pub fn with_topology(
        model: ModelSpec,
        platform: Platform,
        seed: u64,
        streams: usize,
        device_id: u32,
    ) -> SimEngine {
        SimEngine::new(
            model,
            platform,
            SimEngineConfig {
                streams,
                device_id,
                ..SimEngineConfig::default()
            },
            seed,
        )
    }

    /// Device stamp for emitted events (`None` on the default device so
    /// single-replica traces stay spec-v1 byte-identical).
    fn stamp(&self) -> Option<u32> {
        (self.cfg.device_id != 0).then_some(self.cfg.device_id)
    }

    /// Arm the replay script: subsequent timing draws consume `draws`
    /// front-to-front instead of sampling. `serving::replay` fills this
    /// with the `rng_draw` values of a recording, in stream order.
    pub fn script_draws(&mut self, draws: Vec<f64>) {
        self.script = Some(draws.into());
    }

    /// Arm a fault plan. Every window is recorded immediately as a
    /// first-class spec-v4 `fault` event (correlation id 0, ts = the
    /// onset, the full window in args), so a capture carries its own
    /// fault schedule up front: `serving::replay` re-arms the identical
    /// plan from these events, and their position in the stream (ahead
    /// of the first step's work) is deterministic. Like every other
    /// recording event, fault events are decomposition-blind.
    pub fn set_faults(&mut self, plan: FaultPlan) {
        for w in &plan.windows {
            self.trace.push(TraceEvent {
                kind: EventKind::Fault,
                name: format!("fault::{}", w.kind.as_str()),
                ts_us: w.onset_us,
                dur_us: w.dur_us,
                correlation_id: 0,
                track: Track::Host,
                device: self.stamp(),
                args: Some(ReplayArgs::Fault {
                    kind: w.kind.as_str().to_string(),
                    target: w.target.clone(),
                    onset_us: w.onset_us,
                    dur_us: w.dur_us,
                    magnitude: w.magnitude,
                }),
                meta: None,
            });
        }
        self.faults = Some(plan);
    }

    /// The armed fault plan, if any.
    pub fn faults(&self) -> Option<&FaultPlan> {
        self.faults.as_ref()
    }

    /// Host-jitter dilation for a draw issued at the current host
    /// clock: the plan's active-factor product, exactly 1.0 with no
    /// plan armed (and `x * 1.0` is an IEEE identity, so the fault-free
    /// path stays bit-exact).
    fn jitter(&self, seg: HostSeg) -> f64 {
        match &self.faults {
            Some(p) => p.host_factor(self.tl.host_now(0), seg),
            None => 1.0,
        }
    }

    /// Fold transient launch failures into one invocation's exec span.
    /// When a `launch_fail` window covers the invocation's host clock,
    /// the launch is re-issued once per failed attempt: each re-issue
    /// pays the launch path again — a fresh exec-segment draw (recorded
    /// as a normal `rng_draw`, so replay re-consumes it) plus the
    /// deterministic exponential backoff `BACKOFF_BASE_US * 2^i`.
    /// Everything folds into the single RuntimeApi span, so the chain
    /// keeps the recorder shape and the decomposition still partitions
    /// wall time. A window demanding [`MAX_LAUNCH_ATTEMPTS`] or more
    /// failures exhausts the retry budget: the invocation aborts with a
    /// typed error carrying [`TRANSIENT_LAUNCH_MARKER`], which the
    /// scheduler degrades to a `Failed` outcome — never a panic.
    fn exec_with_retries(
        &mut self,
        name: &str,
        base_exec_us: f64,
        sample: impl Fn(&mut Rng) -> f64,
    ) -> anyhow::Result<f64> {
        let failures = match &self.faults {
            Some(p) => p.launch_failures(self.tl.host_now(0)),
            None => 0,
        };
        if failures == 0 {
            return Ok(base_exec_us);
        }
        let mut exec_us = base_exec_us;
        // The base draw was attempt 1; every failure after it re-issues
        // (up to the budget), paying the launch path + backoff again.
        let reissues = failures.min(MAX_LAUNCH_ATTEMPTS - 1);
        for i in 0..reissues {
            let re = self.draw(format!("exec::{name}#retry{i}"), &sample);
            exec_us += re + BACKOFF_BASE_US * f64::from(1u32 << i);
            self.retries += 1;
        }
        anyhow::ensure!(
            failures < MAX_LAUNCH_ATTEMPTS,
            "{TRANSIENT_LAUNCH_MARKER}: '{name}' failed {MAX_LAUNCH_ATTEMPTS} \
             launch attempts, giving up"
        );
        Ok(exec_us)
    }

    /// One timing draw: sample (or pop the replay script) and record it
    /// as a first-class `rng_draw` event, so the run's nondeterminism
    /// is part of the trace and a replay can reproduce the clock
    /// bit-identically. The recorded value is the *final* one — after
    /// any `st_speed` scaling — so replay never re-derives it.
    fn draw(&mut self, site: String, f: impl FnOnce(&mut Rng) -> f64) -> f64 {
        let value = match self.script.as_mut() {
            Some(q) => q.pop_front().unwrap_or_else(|| {
                panic!("replay rng script exhausted at site '{site}' — the recording and the replayed run diverged")
            }),
            None => f(&mut self.timing_rng),
        };
        self.trace.push(TraceEvent {
            kind: EventKind::RngDraw,
            name: site.clone(),
            ts_us: self.tl.host_now(0),
            dur_us: 0.0,
            correlation_id: 0,
            track: Track::Host,
            device: self.stamp(),
            args: Some(ReplayArgs::RngDraw { site, value }),
            meta: None,
        });
        value
    }

    /// Smallest compiled bucket that fits `n` sequences.
    fn bucket_for(&self, n: usize) -> anyhow::Result<usize> {
        self.cfg
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "group of {n} exceeds the largest compiled bucket {:?}",
                    self.cfg.buckets
                )
            })
    }

    /// Deterministic logits over a token history: a pure function of
    /// `(seed, history)`, so identical histories always produce
    /// identical logits regardless of call order — this is what makes
    /// greedy generation reproducible and prefill/decode teacher
    /// forcing exactly consistent.
    fn logits(&self, history: &[i32]) -> Vec<f32> {
        let mut h: u64 = 0xcbf29ce484222325 ^ self.seed;
        for &t in history {
            for b in (t as u32).to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
        let mut rng = Rng::new(h);
        (0..self.cfg.vocab).map(|_| rng.next_f64() as f32).collect()
    }

    /// Record one executable invocation (recorder-shaped events) on the
    /// timeline: the host thread prepares and issues the execute call,
    /// the device computation lands on the next round-robin stream, and
    /// the host blocks through it (engines return materialized logits —
    /// the synchronous serving contract).
    fn record(
        &mut self,
        name: &str,
        prep_us: f64,
        exec_us: f64,
        device_us: f64,
        flops: f64,
        bytes: f64,
    ) {
        self.corr += 1;
        let stream = self.next_stream;
        self.next_stream = (self.next_stream + 1) % self.cfg.streams as u32;
        let (t0, _) = self.tl.host_advance(0, prep_us);
        let (_, exec_end) = self.tl.host_advance(0, exec_us);
        // Device stall: kernel time is *computed* (not drawn), so the
        // straggler factor re-applies identically on replay once the
        // plan is re-armed — the submission clock is bit-identical.
        // The work (flops/bytes) is unchanged; only time stretches.
        let device_us = match &self.faults {
            Some(p) => device_us * p.stall_factor(exec_end, stream),
            None => device_us,
        };
        let timing = self.tl.submit(
            StreamRef { device: 0, stream },
            exec_end,
            0.0,
            device_us,
        );
        self.tl.host_wait_until(0, timing.end_us);
        let meta = KernelMeta {
            kernel_name: format!("sim::{name}").into(),
            family: "sim_exec".into(),
            aten_op: format!("exec::{name}").into(),
            shapes_key: name.into(),
            grid: [1, 1, 1],
            block: [1, 1, 1],
            lib_mediated: false,
            flops,
            bytes,
        };
        let device = self.stamp();
        self.trace.push(TraceEvent {
            kind: EventKind::TorchOp,
            name: format!("serve.{name}"),
            ts_us: t0,
            dur_us: prep_us + exec_us,
            correlation_id: self.corr,
            track: Track::Host,
            device,
            args: None,
            meta: None,
        });
        self.trace.push(TraceEvent {
            kind: EventKind::AtenOp,
            name: format!("prep::{name}"),
            ts_us: t0,
            dur_us: prep_us,
            correlation_id: self.corr,
            track: Track::Host,
            device,
            args: None,
            meta: None,
        });
        self.trace.push(TraceEvent {
            kind: EventKind::RuntimeApi,
            name: "sim::execute".to_string(),
            ts_us: t0 + prep_us,
            dur_us: exec_us,
            correlation_id: self.corr,
            track: Track::Host,
            device,
            args: None,
            meta: None,
        });
        self.trace.push(TraceEvent {
            kind: EventKind::Kernel,
            name: format!("sim::{name}"),
            ts_us: timing.start_us,
            dur_us: device_us,
            correlation_id: self.corr,
            track: Track::Device(stream),
            device,
            args: None,
            meta: Some(meta),
        });
    }

    /// Device time of one pass over `tokens_processed` tokens, from the
    /// analytic cost model (weight-streaming roofline of the active
    /// parameter set).
    fn device_us(&self, tokens_processed: usize) -> f64 {
        let active = self.model.params_active();
        let flops = 2.0 * active * tokens_processed as f64;
        let bytes = 2.0 * active;
        cost::device_duration_us(Family::GemmCublas, flops, bytes, &self.platform.gpu)
    }
}

impl ModelBackend for SimEngine {
    type Cache = SimCache;

    fn max_seq(&self) -> usize {
        self.cfg.max_seq
    }

    fn decode_buckets(&self) -> Vec<usize> {
        self.cfg.buckets.clone()
    }

    fn pad_id(&self) -> i32 {
        // The top vocab id is reserved for padding (it stays a valid
        // embedding index for the real engine); workload generators
        // draw prompt tokens strictly below it.
        self.cfg.vocab.saturating_sub(1) as i32
    }

    fn wait_until_us(&mut self, t_us: f64) {
        // Virtual clock: jump over idle gaps so arrival-gated load
        // generation doesn't busy-spin (a timeline idle jump). The jump
        // is a nondeterministic input to the recording (it depends on
        // arrival timing), so it is recorded as a first-class
        // `clock_jump` event: ts is the clock before the jump, dur the
        // amount skipped.
        let now = self.tl.host_now(0);
        if t_us > now {
            self.trace.push(TraceEvent {
                kind: EventKind::ClockJump,
                name: "clock_jump".to_string(),
                ts_us: now,
                dur_us: t_us - now,
                correlation_id: 0,
                track: Track::Host,
                device: self.stamp(),
                args: None,
                meta: None,
            });
        }
        self.tl.host_wait_until(0, t_us);
    }

    fn prefill_group(&mut self, prompts: &[Vec<i32>]) -> anyhow::Result<(Vec<i32>, SimCache)> {
        anyhow::ensure!(!prompts.is_empty(), "empty prefill group");
        let padded = prompts.iter().map(|p| p.len()).max().unwrap();
        anyhow::ensure!(
            padded <= self.cfg.max_seq,
            "prompt length {padded} exceeds max_seq {}",
            self.cfg.max_seq
        );
        let bucket = self.bucket_for(prompts.len())?;

        // Ragged prompts and phantom bucket slots pad with the
        // reserved pad id, never a real vocab token.
        let pad = self.pad_id();
        let mut tokens: Vec<Vec<i32>> = Vec::with_capacity(bucket);
        for i in 0..bucket {
            let mut h = prompts.get(i).cloned().unwrap_or_default();
            h.resize(padded, pad);
            tokens.push(h);
        }
        let next: Vec<i32> = prompts
            .iter()
            .enumerate()
            .map(|(i, _)| argmax(&self.logits(&tokens[i])))
            .collect();

        let st = self.platform.cpu.st_speed;
        let name = format!("prefill_b{bucket}_s{padded}");
        // Jitter dilation folds into the sampled values themselves, so
        // the recorded `rng_draw` carries the fault and scripted replay
        // never re-applies it.
        let jp = self.jitter(HostSeg::Prep);
        let prep = self.draw(format!("prep::{name}"), |rng| {
            rng.lognormal_med(40.0, 0.20) / st * jp
        });
        let je = self.jitter(HostSeg::Exec);
        let exec_sample = move |rng: &mut Rng| rng.lognormal_med(8.0, 0.15) / st * je;
        let exec = self.draw(format!("exec::{name}"), exec_sample);
        let exec = self.exec_with_retries(&name, exec, exec_sample)?;
        let dev = self.device_us(bucket * padded);
        let active = self.model.params_active();
        self.record(
            &name,
            prep,
            exec,
            dev,
            2.0 * active * (bucket * padded) as f64,
            2.0 * active,
        );
        Ok((next, SimCache { tokens, bucket }))
    }

    fn decode_group(
        &mut self,
        mut cache: SimCache,
        pos: usize,
        tokens: &[i32],
    ) -> anyhow::Result<(Vec<i32>, SimCache)> {
        // Phantom bucket slots carry the reserved pad id.
        let pad = self.pad_id();
        let mut toks = tokens.to_vec();
        toks.resize(cache.bucket, pad);
        anyhow::ensure!(
            pos == cache.tokens[0].len(),
            "cache position continuity: pos {pos} != stored {}",
            cache.tokens[0].len()
        );
        anyhow::ensure!(pos < self.cfg.max_seq, "decode past max_seq {}", self.cfg.max_seq);
        let mut next = Vec::with_capacity(cache.bucket);
        for (slot, &t) in toks.iter().enumerate() {
            cache.tokens[slot].push(t);
            next.push(argmax(&self.logits(&cache.tokens[slot])));
        }

        let st = self.platform.cpu.st_speed;
        let name = format!("decode_b{}", cache.bucket);
        let jp = self.jitter(HostSeg::Prep);
        let prep = self.draw(format!("prep::{name}"), |rng| {
            rng.lognormal_med(25.0, 0.20) / st * jp
        });
        let je = self.jitter(HostSeg::Exec);
        let exec_sample = move |rng: &mut Rng| rng.lognormal_med(8.0, 0.15) / st * je;
        let exec = self.draw(format!("exec::{name}"), exec_sample);
        let exec = self.exec_with_retries(&name, exec, exec_sample)?;
        let dev = self.device_us(cache.bucket);
        let active = self.model.params_active();
        self.record(
            &name,
            prep,
            exec,
            dev,
            2.0 * active * cache.bucket as f64,
            2.0 * active,
        );
        Ok((next, cache))
    }

    fn now_us(&self) -> f64 {
        self.tl.host_now(0)
    }
}

impl Backend for SimEngine {
    fn variant(&self) -> &str {
        &self.variant
    }

    fn vocab(&self) -> usize {
        self.cfg.vocab
    }

    fn null_run(&mut self) -> anyhow::Result<(f64, f64)> {
        // The floor probe is an instrumentation path, not serving work:
        // host jitter dilates it (a jittery host has a jittery probe),
        // but launch-failure injection targets only scheduled
        // invocations, so the probe never aborts a run.
        let st = self.platform.cpu.st_speed;
        let jp = self.jitter(HostSeg::Prep);
        let dispatch = self.draw("prep::null_kernel".to_string(), |rng| {
            rng.lognormal_med(5.0, 0.15) / st * jp
        });
        let (floor, sigma) = (self.platform.gpu.t_sys_floor_us, self.platform.gpu.floor_sigma);
        let je = self.jitter(HostSeg::Exec);
        let launch = self.draw("exec::null_kernel".to_string(), |rng| {
            rng.lognormal_med(floor, sigma) * je
        });
        self.record("null_kernel", dispatch, launch, 1.0, 0.0, 32.0);
        Ok((dispatch, launch))
    }

    fn take_trace(&mut self) -> Trace {
        self.trace.meta.wall_us = self.tl.host_now(0);
        let fresh = Trace::new(self.trace.meta.clone());
        std::mem::replace(&mut self.trace, fresh)
    }

    fn drain_events(&mut self) -> Vec<TraceEvent> {
        std::mem::take(&mut self.trace.events)
    }

    fn trace_meta(&self) -> TraceMeta {
        let mut meta = self.trace.meta.clone();
        meta.wall_us = self.tl.host_now(0);
        meta
    }

    fn retries(&self) -> u64 {
        self.retries
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn engine(seed: u64) -> SimEngine {
        SimEngine::with_defaults(models::gpt2(), Platform::h200(), seed)
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let run = |seed| {
            let mut e = engine(seed);
            let (mut next, mut cache) = e.prefill_group(&[vec![1, 2, 3, 4]]).unwrap();
            let mut out = vec![next[0]];
            for pos in 4..9 {
                let step = e.decode_group(cache, pos, &next).unwrap();
                next = step.0;
                cache = step.1;
                out.push(next[0]);
            }
            out
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
        assert!(run(7).iter().all(|&t| (0..251).contains(&t)));
    }

    #[test]
    fn prefill_decode_teacher_forcing_consistency() {
        // Decoding the last prompt token must produce the same next
        // token as prefilling the whole prompt — the invariant the real
        // engine verifies end-to-end through HLO + PJRT.
        let prompt: Vec<i32> = (1..=12).collect();
        let mut e = engine(3);
        let (full_next, _) = e.prefill_group(&[prompt.clone()]).unwrap();

        let mut e2 = engine(3);
        let (_, cache) = e2.prefill_group(&[prompt[..11].to_vec()]).unwrap();
        let (step_next, _) = e2.decode_group(cache, 11, &[prompt[11]]).unwrap();
        assert_eq!(full_next[0], step_next[0]);
    }

    #[test]
    fn trace_has_recorder_shape() {
        let mut e = engine(5);
        let (next, cache) = e.prefill_group(&[vec![1, 2, 3]]).unwrap();
        let _ = e.decode_group(cache, 3, &next).unwrap();
        let trace = e.take_trace();
        // 6 events per invocation: 2 rng draws + 4 observations.
        assert_eq!(trace.events.len(), 12);
        assert_eq!(trace.kernel_count(), 2);
        assert_eq!(
            trace
                .events
                .iter()
                .filter(|e| e.kind == EventKind::RngDraw)
                .count(),
            4
        );
        crate::taxbreak::phase1::validate_trace(&trace).unwrap();
        assert!(trace.meta.wall_us > 0.0);
        // Virtual clock is monotone over host events.
        let mut last = 0.0;
        for ev in trace.events.iter().filter(|e| e.track == Track::Host) {
            assert!(ev.ts_us >= last - 1e-9);
            last = last.max(ev.ts_us);
        }
    }

    #[test]
    fn drain_events_is_incremental_and_equivalent_to_take_trace() {
        let mut a = engine(5);
        let mut b = engine(5);
        let (next, cache) = a.prefill_group(&[vec![1, 2, 3]]).unwrap();
        let _ = a.decode_group(cache, 3, &next).unwrap();
        let whole = a.take_trace();

        let (next, cache) = b.prefill_group(&[vec![1, 2, 3]]).unwrap();
        let mut drained = b.drain_events();
        assert_eq!(drained.len(), 6, "one invocation = 6 events");
        let _ = b.decode_group(cache, 3, &next).unwrap();
        drained.extend(b.drain_events());
        assert_eq!(drained, whole.events, "drained events == buffered events");
        assert!(b.drain_events().is_empty(), "drain is a move, not a copy");
        assert_eq!(b.trace_meta().wall_us, whole.meta.wall_us);
        assert_eq!(b.trace_meta().phase, "serve");
    }

    #[test]
    fn null_run_floor_matches_platform() {
        let mut e = engine(11);
        let mut floors = Vec::new();
        for _ in 0..200 {
            let (dispatch, launch) = e.null_run().unwrap();
            assert!(dispatch > 0.0);
            floors.push(launch);
        }
        let mean = crate::util::stats::mean(&floors);
        let want = Platform::h200().gpu.t_sys_floor_us;
        assert!((mean - want).abs() < 0.3, "floor {mean} vs {want}");
    }

    #[test]
    fn bucket_rounding_and_padding() {
        let mut e = engine(2);
        // 3 prompts round up to the 4-bucket; ragged prompts pad.
        let (next, cache) = e
            .prefill_group(&[vec![1, 2, 3, 4, 5], vec![6], vec![7, 8]])
            .unwrap();
        assert_eq!(next.len(), 3);
        assert_eq!(cache.bucket, 4);
        assert!(cache.tokens.iter().all(|h| h.len() == 5));
        // Decode accepts a short token vector and pads to the bucket.
        let (next2, _) = e.decode_group(cache, 5, &next).unwrap();
        assert_eq!(next2.len(), 4);
    }

    #[test]
    fn oversized_group_errors() {
        let mut e = engine(2);
        let prompts: Vec<Vec<i32>> = (0..5).map(|i| vec![i]).collect();
        assert!(e.prefill_group(&prompts).is_err());
    }

    #[test]
    fn multi_stream_topology_rotates_streams_without_changing_the_clock() {
        // The serving contract is synchronous, so streams must not
        // change wall-clock — only the lanes kernels land on.
        let run = |streams: usize, device_id: u32| {
            let mut e = SimEngine::with_topology(
                models::gpt2(),
                Platform::h200(),
                5,
                streams,
                device_id,
            );
            let (next, cache) = e.prefill_group(&[vec![1, 2, 3]]).unwrap();
            let (next, cache) = e.decode_group(cache, 3, &next).unwrap();
            let _ = e.decode_group(cache, 4, &next).unwrap();
            e.take_trace()
        };
        let single = run(1, 0);
        let multi = run(3, 2);
        assert_eq!(single.meta.wall_us, multi.meta.wall_us);
        assert_eq!(single.kernel_count(), multi.kernel_count());
        // Kernels rotate 0,1,2 across the three invocations.
        let streams: Vec<u32> = multi
            .kernels()
            .map(|k| match k.track {
                Track::Device(s) => s,
                Track::Host => unreachable!(),
            })
            .collect();
        assert_eq!(streams, vec![0, 1, 2]);
        // Replica stamping: device 2 on every event; the default engine
        // emits no stamp at all (spec-v1 byte identity).
        assert!(multi.events.iter().all(|e| e.device == Some(2)));
        assert!(single.events.iter().all(|e| e.device.is_none()));
    }

    #[test]
    fn scripted_draws_reproduce_a_recording_bit_identically() {
        use crate::trace::ReplayArgs;
        let drive = |e: &mut SimEngine| {
            let _ = e.null_run().unwrap();
            e.wait_until_us(500.0);
            let (next, cache) = e.prefill_group(&[vec![1, 2, 3]]).unwrap();
            let _ = e.decode_group(cache, 3, &next).unwrap();
            e.take_trace()
        };
        let recorded = drive(&mut engine(5));
        let draws: Vec<f64> = recorded
            .events
            .iter()
            .filter_map(|ev| match &ev.args {
                Some(ReplayArgs::RngDraw { value, .. }) => Some(*value),
                _ => None,
            })
            .collect();
        assert_eq!(draws.len(), 6);
        // A replay under a *different* seed, driven by the recorded
        // draws, re-records the exact same trace.
        let mut replayed = engine(99);
        replayed.script_draws(draws);
        let rerecorded = drive(&mut replayed);
        assert_eq!(recorded.to_json().dump(), rerecorded.to_json().dump());
    }

    #[test]
    fn armed_fault_plan_emits_spec_v4_fault_events_up_front() {
        use crate::faults::FaultPlan;
        let mut e = engine(5);
        e.set_faults(FaultPlan::parse("jitter:0:100:2.0;stall:50:10:3.0:0").unwrap());
        let (next, cache) = e.prefill_group(&[vec![1, 2, 3]]).unwrap();
        let _ = e.decode_group(cache, 3, &next).unwrap();
        let t = e.take_trace();
        // The two fault events lead the stream (armed before any work).
        assert_eq!(t.events[0].kind, EventKind::Fault);
        assert_eq!(t.events[1].kind, EventKind::Fault);
        assert_eq!(t.events[0].correlation_id, 0);
        assert_eq!(t.events[0].name, "fault::host_jitter");
        assert_eq!(t.events[1].ts_us, 50.0);
        assert_eq!(t.events[1].dur_us, 10.0);
        match &t.events[1].args {
            Some(ReplayArgs::Fault {
                kind,
                target,
                magnitude,
                ..
            }) => {
                assert_eq!(kind, "device_stall");
                assert_eq!(target, "stream:0");
                assert_eq!(*magnitude, 3.0);
            }
            other => panic!("expected fault args, got {other:?}"),
        }
        // Fault events are decomposition-blind: the trace still
        // validates as a Phase-1 input.
        crate::taxbreak::phase1::validate_trace(&t).unwrap();
    }

    #[test]
    fn host_jitter_dilates_draws_only_inside_the_window() {
        use crate::faults::FaultPlan;
        let drive = |plan: Option<&str>| {
            let mut e = engine(5);
            if let Some(spec) = plan {
                e.set_faults(FaultPlan::parse(spec).unwrap());
            }
            let (next, cache) = e.prefill_group(&[vec![1, 2, 3]]).unwrap();
            let _ = e.decode_group(cache, 3, &next).unwrap();
            e.take_trace()
        };
        let base = drive(None);
        // A window covering the whole run dilates every host draw 2x:
        // the recorded rng_draw values carry the factor.
        let jit = drive(Some("jitter:0:1000000:2.0"));
        let vals = |t: &Trace| -> Vec<f64> {
            t.events
                .iter()
                .filter_map(|ev| match &ev.args {
                    Some(ReplayArgs::RngDraw { value, .. }) => Some(*value),
                    _ => None,
                })
                .collect()
        };
        let (b, j) = (vals(&base), vals(&jit));
        assert_eq!(b.len(), j.len());
        for (b, j) in b.iter().zip(j.iter()) {
            assert!((j / b - 2.0).abs() < 1e-12, "draw {j} is not 2x {b}");
        }
        // A window that never activates leaves the run byte-identical.
        let cold = drive(Some("jitter:900000000:10:4.0"));
        let mut cold_stripped = cold.clone();
        cold_stripped.events.retain(|ev| ev.kind != EventKind::Fault);
        assert_eq!(cold_stripped.events, base.events);
        assert_eq!(cold_stripped.meta.wall_us, base.meta.wall_us);
    }

    #[test]
    fn device_stalls_stretch_kernels_on_the_target_stream() {
        use crate::faults::FaultPlan;
        let kernel_durs = |spec: Option<&str>| -> Vec<f64> {
            let mut e =
                SimEngine::with_topology(models::gpt2(), Platform::h200(), 5, 2, 0);
            if let Some(s) = spec {
                e.set_faults(FaultPlan::parse(s).unwrap());
            }
            let (next, cache) = e.prefill_group(&[vec![1, 2, 3]]).unwrap();
            let _ = e.decode_group(cache, 3, &next).unwrap();
            e.take_trace().kernels().map(|k| k.dur_us).collect()
        };
        let base = kernel_durs(None);
        // Stream 1 only: the decode kernel (second invocation, round-
        // robin stream 1) stretches 4x; the prefill kernel does not.
        let stalled = kernel_durs(Some("stall:0:1000000:4.0:1"));
        assert_eq!(base.len(), 2);
        assert!((stalled[0] - base[0]).abs() < 1e-12);
        assert!((stalled[1] / base[1] - 4.0).abs() < 1e-9);
        // stream:* hits both.
        let all = kernel_durs(Some("stall:0:1000000:4.0"));
        assert!((all[0] / base[0] - 4.0).abs() < 1e-9);
        assert!((all[1] / base[1] - 4.0).abs() < 1e-9);
    }

    #[test]
    fn launch_failures_pay_the_launch_path_again_and_eventually_fail_typed() {
        use crate::faults::{FaultPlan, MAX_LAUNCH_ATTEMPTS, TRANSIENT_LAUNCH_MARKER};
        // 2 failures: the invocation succeeds, 2 extra exec draws are
        // recorded, the exec span grows by draws + backoff.
        let mut e = engine(5);
        e.set_faults(FaultPlan::parse("launchfail:0:1000000:2").unwrap());
        let (_, _) = e.prefill_group(&[vec![1, 2, 3]]).unwrap();
        assert_eq!(Backend::retries(&e), 2);
        let t = e.take_trace();
        let retry_draws: Vec<&TraceEvent> = t
            .events
            .iter()
            .filter(|ev| ev.kind == EventKind::RngDraw && ev.name.contains("#retry"))
            .collect();
        assert_eq!(retry_draws.len(), 2);
        crate::taxbreak::phase1::validate_trace(&t).unwrap();

        // MAX_LAUNCH_ATTEMPTS failures: typed, marker-carrying error.
        let mut e = engine(5);
        e.set_faults(
            FaultPlan::parse(&format!("launchfail:0:1000000:{MAX_LAUNCH_ATTEMPTS}")).unwrap(),
        );
        let err = e.prefill_group(&[vec![1, 2, 3]]).unwrap_err();
        assert!(
            err.to_string().contains(TRANSIENT_LAUNCH_MARKER),
            "error should carry the transient marker: {err}"
        );
        // The exhausted attempts were still recorded (replay must
        // re-consume them), and the engine stays usable afterwards.
        assert_eq!(Backend::retries(&e), (MAX_LAUNCH_ATTEMPTS - 1) as u64);
        let n_draws = e.drain_events().len();
        assert_eq!(n_draws as u32, 1 + 2 + MAX_LAUNCH_ATTEMPTS - 1); // fault ev + prep/exec + retries
        let _ = e.prefill_group(&[vec![1, 2, 3]]).unwrap_err(); // still inside the window
    }

    #[test]
    fn faulted_recordings_replay_bit_identically_when_the_plan_is_rearmed() {
        use crate::faults::FaultPlan;
        let spec = "jitter:0:100000:3.0:exec;stall:0:100000:2.0;launchfail:0:100000:1";
        let drive = |e: &mut SimEngine| {
            let (next, cache) = e.prefill_group(&[vec![1, 2, 3]]).unwrap();
            let _ = e.decode_group(cache, 3, &next).unwrap();
            e.take_trace()
        };
        let mut rec = engine(5);
        rec.set_faults(FaultPlan::parse(spec).unwrap());
        let recorded = drive(&mut rec);
        let draws: Vec<f64> = recorded
            .events
            .iter()
            .filter_map(|ev| match &ev.args {
                Some(ReplayArgs::RngDraw { value, .. }) => Some(*value),
                _ => None,
            })
            .collect();
        // Replay under a different seed: scripted draws carry the
        // jitter + retry samples; the re-armed plan re-applies the
        // computed stall and the retry/backoff structure.
        let mut rep = engine(99);
        rep.set_faults(FaultPlan::parse(spec).unwrap());
        rep.script_draws(draws);
        let replayed = drive(&mut rep);
        assert_eq!(recorded.to_json().dump(), replayed.to_json().dump());
    }

    #[test]
    fn idle_jumps_become_clock_jump_events() {
        let mut e = engine(7);
        e.wait_until_us(120.0);
        e.wait_until_us(80.0); // backwards: no jump, no event
        let (next, cache) = e.prefill_group(&[vec![1, 2]]).unwrap();
        let _ = e.decode_group(cache, 2, &next).unwrap();
        let t = e.take_trace();
        let jumps: Vec<&TraceEvent> = t
            .events
            .iter()
            .filter(|ev| ev.kind == EventKind::ClockJump)
            .collect();
        assert_eq!(jumps.len(), 1);
        assert_eq!(jumps[0].ts_us, 0.0);
        assert_eq!(jumps[0].dur_us, 120.0);
        assert_eq!(jumps[0].correlation_id, 0);
        crate::taxbreak::phase1::validate_trace(&t).unwrap();
    }
}
