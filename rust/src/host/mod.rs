//! Host dispatch-path model: the single-threaded chain every eager-mode
//! kernel traverses before the GPU sees it (paper Fig. 3):
//!
//! ```text
//! torch op ──T_Py──▶ ATen dispatch ──T_dispatch_base──▶
//!     [vendor-library front-end ──ΔCT──▶]  cudaLaunchKernel ──▶
//!         (launch gap: T_sys_floor + ΔKT_fw) ──▶ kernel start
//! ```
//!
//! All host components divide by the platform CPU's single-thread speed
//! (the paper's §VI variable); the launch floor is GPU/driver territory
//! and does not.

use crate::hardware::Platform;
use crate::kernels::family::{
    Family, CT_SIGMA, DISPATCH_BASE_MED_US, DISPATCH_SIGMA, PY_SIGMA,
};
use crate::util::rng::Rng;

/// Host-side duration of the `cudaLaunchKernel` call itself (the call
/// returns asynchronously well before the kernel starts), us at the
/// reference CPU.
pub const API_CALL_MED_US: f64 = 0.8;
const API_SIGMA: f64 = 0.08;

/// One kernel's sampled host-path latencies (all us).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostSample {
    /// Python-side dispatch overhead T_Py (torch op start → ATen).
    pub t_py: f64,
    /// Irreducible ATen dispatch cost.
    pub t_base: f64,
    /// Vendor-library front-end excess ΔCT (0 for framework-native).
    pub t_ct: f64,
    /// Host-visible duration of the launch API call.
    pub api_dur: f64,
    /// API call → kernel start when the stream is empty:
    /// `T_sys_floor + ΔKT_fw`.
    pub launch_gap: f64,
    /// The floor component of `launch_gap` alone.
    pub floor: f64,
}

impl HostSample {
    /// Host-thread occupancy for this kernel (what serial dispatch
    /// spends before it can touch the next op).
    pub fn occupancy(&self) -> f64 {
        self.t_py + self.t_base + self.t_ct + self.api_dur
    }
}

/// Draws per-kernel host latencies for a platform.
#[derive(Debug, Clone)]
pub struct HostModel {
    pub platform: Platform,
}

impl HostModel {
    pub fn new(platform: Platform) -> HostModel {
        HostModel { platform }
    }

    /// Sample the full host path for one kernel of `family`.
    pub fn sample(&self, family: Family, rng: &mut Rng) -> HostSample {
        let p = family.params();
        let st = self.platform.cpu.st_speed;
        let t_py = rng.lognormal_med(p.py_med_us, PY_SIGMA) / st;
        let t_base = rng.lognormal_med(DISPATCH_BASE_MED_US, DISPATCH_SIGMA) / st;
        let t_ct = if p.lib_mediated {
            rng.lognormal_med(p.ct_med_us, CT_SIGMA) / st
        } else {
            0.0
        };
        let api_dur = rng.lognormal_med(API_CALL_MED_US, API_SIGMA) / st;
        let floor = self.sample_floor(rng);
        // ΔKT_fw is driver/runtime software — scales with the host CPU.
        let excess = rng.lognormal_med(p.launch_excess_med_us, p.launch_excess_sigma) / st;
        HostSample {
            t_py,
            t_base,
            t_ct,
            api_dur,
            launch_gap: floor + excess,
            floor,
        }
    }

    /// Null-kernel floor draw: `T_sys_floor` alone (Table III protocol).
    pub fn sample_floor(&self, rng: &mut Rng) -> f64 {
        let g = &self.platform.gpu;
        rng.lognormal_med(g.t_sys_floor_us, g.floor_sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn samples(platform: Platform, family: Family, n: usize) -> Vec<HostSample> {
        let model = HostModel::new(platform);
        let mut rng = Rng::new(42);
        (0..n).map(|_| model.sample(family, &mut rng)).collect()
    }

    #[test]
    fn ct_zero_for_framework_native() {
        for s in samples(Platform::h100(), Family::ElemVector, 100) {
            assert_eq!(s.t_ct, 0.0);
        }
        let cublas = samples(Platform::h100(), Family::GemmCublas, 100);
        assert!(cublas.iter().all(|s| s.t_ct > 0.0));
    }

    #[test]
    fn medians_match_family_params() {
        let xs: Vec<f64> = samples(Platform::h100(), Family::Scan, 4000)
            .iter()
            .map(|s| s.launch_gap - s.floor)
            .collect();
        let med = stats::median(&xs);
        assert!((med - 0.32).abs() < 0.05, "ΔKT_fw median {med} (Table IV: 0.32)");
    }

    #[test]
    fn h200_host_components_are_faster() {
        let h100: Vec<f64> = samples(Platform::h100(), Family::ElemVector, 2000)
            .iter()
            .map(|s| s.occupancy())
            .collect();
        let h200: Vec<f64> = samples(Platform::h200(), Family::ElemVector, 2000)
            .iter()
            .map(|s| s.occupancy())
            .collect();
        let ratio = stats::mean(&h200) / stats::mean(&h100);
        assert!(
            (ratio - 1.0 / 1.30).abs() < 0.03,
            "occupancy ratio {ratio} should track CPU st_speed"
        );
    }

    #[test]
    fn floor_does_not_scale_with_cpu() {
        let f100: Vec<f64> = samples(Platform::h100(), Family::Reduce, 3000)
            .iter()
            .map(|s| s.floor)
            .collect();
        let f200: Vec<f64> = samples(Platform::h200(), Family::Reduce, 3000)
            .iter()
            .map(|s| s.floor)
            .collect();
        // Table III: floors differ only via the GPU (4.72 vs 4.50).
        assert!((stats::mean(&f100) - 4.72).abs() < 0.1);
        assert!((stats::mean(&f200) - 4.503).abs() < 0.1);
    }

    #[test]
    fn gpt2_per_kernel_host_cost_matches_paper() {
        // §V-C: GPT-2 on H200 — per-kernel host cost ≈ 13.7 us
        // decomposed as T_Py ≈ 1.35 + base ≈ 7.85 + floor ≈ 4.5.
        let xs: Vec<f64> = samples(Platform::h200(), Family::GemmNvjet, 4000)
            .iter()
            .map(|s| s.t_py + s.t_base + s.floor)
            .collect();
        let mean = stats::mean(&xs);
        assert!((mean - 13.7).abs() < 0.8, "per-kernel host cost {mean}");
    }

    #[test]
    fn occupancy_excludes_floor() {
        let s = samples(Platform::h100(), Family::ElemUnroll, 1)[0];
        assert!((s.occupancy() - (s.t_py + s.t_base + s.t_ct + s.api_dur)).abs() < 1e-12);
    }

    #[test]
    fn deterministic_with_seed() {
        let m = HostModel::new(Platform::h100());
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        assert_eq!(
            m.sample(Family::TopK, &mut r1),
            m.sample(Family::TopK, &mut r2)
        );
    }
}
