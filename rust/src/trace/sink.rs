//! `TraceSink` — the streaming event consumer every producer (simulator,
//! timeline engine, loadgen capture) writes through.
//!
//! Producers no longer decide between "buffer everything" and "stream to
//! disk": they emit events into a sink and call [`TraceSink::finish`]
//! with the final wall-clock. [`TraceBufferSink`] reproduces the old
//! in-memory behavior; [`BinaryTraceWriter`] streams to any `Write`
//! with O(1) memory; [`file_sink`] picks by extension (`.tbt` streams
//! binary, anything else buffers and saves canonical JSON — the JSON
//! dialect stores `wall_us` in its head, so it cannot be streamed).

use std::io::Write;
use std::path::{Path, PathBuf};

use super::binary::{BinaryTraceWriter, Dialect};
use super::event::TraceEvent;
use super::{Trace, TraceMeta};

/// Streaming consumer of trace events.
pub trait TraceSink {
    /// Consume one event.
    fn event(&mut self, ev: &TraceEvent) -> anyhow::Result<()>;
    /// Seal the capture with the run's wall-clock latency (us). Called
    /// exactly once, after the last event.
    fn finish(&mut self, wall_us: f64) -> anyhow::Result<()>;
}

/// The old buffer-everything behavior as a sink: accumulates into an
/// in-memory [`Trace`], stamping the wall at `finish`.
#[derive(Debug, Clone, Default)]
pub struct TraceBufferSink {
    trace: Trace,
}

impl TraceBufferSink {
    pub fn new(meta: TraceMeta) -> TraceBufferSink {
        TraceBufferSink {
            trace: Trace::new(meta),
        }
    }

    pub fn trace(&self) -> &Trace {
        &self.trace
    }

    pub fn into_trace(self) -> Trace {
        self.trace
    }
}

impl TraceSink for TraceBufferSink {
    fn event(&mut self, ev: &TraceEvent) -> anyhow::Result<()> {
        self.trace.push(ev.clone());
        Ok(())
    }

    fn finish(&mut self, wall_us: f64) -> anyhow::Result<()> {
        self.trace.meta.wall_us = wall_us;
        Ok(())
    }
}

/// Discards everything (summary-only runs).
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn event(&mut self, _ev: &TraceEvent) -> anyhow::Result<()> {
        Ok(())
    }

    fn finish(&mut self, _wall_us: f64) -> anyhow::Result<()> {
        Ok(())
    }
}

impl<W: Write> TraceSink for BinaryTraceWriter<W> {
    fn event(&mut self, ev: &TraceEvent) -> anyhow::Result<()> {
        Ok(BinaryTraceWriter::event(self, ev)?)
    }

    fn finish(&mut self, wall_us: f64) -> anyhow::Result<()> {
        Ok(BinaryTraceWriter::finish(self, wall_us)?)
    }
}

/// Pass-through wrapper counting events and the finish wall — used by
/// tests to observe what a producer streams without buffering it.
pub struct CountingSink<S: TraceSink> {
    pub inner: S,
    pub events: u64,
    pub wall_us: Option<f64>,
}

impl<S: TraceSink> CountingSink<S> {
    pub fn new(inner: S) -> CountingSink<S> {
        CountingSink {
            inner,
            events: 0,
            wall_us: None,
        }
    }
}

impl<S: TraceSink> TraceSink for CountingSink<S> {
    fn event(&mut self, ev: &TraceEvent) -> anyhow::Result<()> {
        self.events += 1;
        self.inner.event(ev)
    }

    fn finish(&mut self, wall_us: f64) -> anyhow::Result<()> {
        self.wall_us = Some(wall_us);
        self.inner.finish(wall_us)
    }
}

/// JSON file sink: buffers (the JSON head carries `wall_us`, so the
/// format is not streamable) and writes the canonical compact dump at
/// `finish`.
struct JsonFileSink {
    path: PathBuf,
    buffer: TraceBufferSink,
}

impl TraceSink for JsonFileSink {
    fn event(&mut self, ev: &TraceEvent) -> anyhow::Result<()> {
        self.buffer.event(ev)
    }

    fn finish(&mut self, wall_us: f64) -> anyhow::Result<()> {
        self.buffer.finish(wall_us)?;
        self.buffer.trace().save(&self.path)
    }
}

/// Open a file-backed sink, dispatching dialect by extension: `.tbt`
/// streams the binary dialect with O(1) memory; any other extension
/// buffers and saves canonical JSON at `finish`.
pub fn file_sink(path: &Path, meta: &TraceMeta) -> anyhow::Result<Box<dyn TraceSink>> {
    match Dialect::of_path(path) {
        Dialect::Binary => {
            let file = std::fs::File::create(path)
                .map_err(|e| anyhow::anyhow!("creating {}: {e}", path.display()))?;
            let w = BinaryTraceWriter::new(std::io::BufWriter::new(file), meta)?;
            Ok(Box::new(w))
        }
        Dialect::Json => Ok(Box::new(JsonFileSink {
            path: path.to_path_buf(),
            buffer: TraceBufferSink::new(meta.clone()),
        })),
    }
}

#[cfg(test)]
mod tests {
    use super::super::binary;
    use super::super::event::{EventKind, Track};
    use super::*;

    fn meta() -> TraceMeta {
        TraceMeta {
            platform: "h100".into(),
            model: "gpt2".into(),
            phase: "prefill".into(),
            batch: 1,
            seq: 128,
            m_tokens: 1,
            wall_us: 0.0,
        }
    }

    fn ev(corr: u64) -> TraceEvent {
        TraceEvent {
            kind: EventKind::Kernel,
            name: format!("k{corr}"),
            ts_us: corr as f64,
            dur_us: 1.0,
            correlation_id: corr,
            track: Track::Device(0),
            device: None,
            args: None,
            meta: None,
        }
    }

    #[test]
    fn buffer_sink_reproduces_push_loop() {
        let mut s = TraceBufferSink::new(meta());
        s.event(&ev(1)).unwrap();
        s.event(&ev(2)).unwrap();
        s.finish(99.5).unwrap();
        let t = s.into_trace();
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.meta.wall_us, 99.5);
    }

    #[test]
    fn binary_writer_is_a_sink_and_roundtrips() {
        let mut w = BinaryTraceWriter::new(Vec::new(), &meta()).unwrap();
        for i in 1..=3 {
            TraceSink::event(&mut w, &ev(i)).unwrap();
        }
        TraceSink::finish(&mut w, 42.0).unwrap();
        let t = binary::decode(&w.into_inner()).unwrap();
        assert_eq!(t.events.len(), 3);
        assert_eq!(t.meta.wall_us, 42.0);
        assert_eq!(t.meta.model, "gpt2");
    }

    #[test]
    fn counting_sink_observes_without_interfering() {
        let mut s = CountingSink::new(NullSink);
        s.event(&ev(1)).unwrap();
        s.event(&ev(2)).unwrap();
        s.finish(7.0).unwrap();
        assert_eq!(s.events, 2);
        assert_eq!(s.wall_us, Some(7.0));
    }

    #[test]
    fn file_sink_dispatches_by_extension() {
        let dir = std::env::temp_dir().join("taxbreak_sink_test");
        std::fs::create_dir_all(&dir).unwrap();
        for (name, dialect) in [("t.tbt", Dialect::Binary), ("t.json", Dialect::Json)] {
            let path = dir.join(name);
            let mut s = file_sink(&path, &meta()).unwrap();
            s.event(&ev(1)).unwrap();
            s.finish(5.0).unwrap();
            drop(s);
            let bytes = std::fs::read(&path).unwrap();
            assert_eq!(Dialect::sniff(&bytes), dialect, "{name}");
            let t = Trace::load(&path).unwrap();
            assert_eq!(t.events.len(), 1);
            assert_eq!(t.meta.wall_us, 5.0);
        }
    }
}
