//! Trace event model — the nsys/CUPTI analog.
//!
//! The paper's Phase 1 consumes "timestamped Python/torch operators,
//! ATen operators, CUDA runtime calls, and GPU kernels linked by
//! correlation IDs" plus NVTX ranges in Phase 2.  These five event kinds
//! are modeled here; both the simulator (`sim`) and the real PJRT
//! runtime (`runtime`) emit them, and every TaxBreak analysis consumes
//! only this representation (trace-format-as-interface, DESIGN.md §9).

use crate::util::json::Json;

/// Which trace source produced an event (CUPTI activity-kind analog).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EventKind {
    /// Python-level framework operator (`torch.*` call).
    TorchOp,
    /// C++ dispatcher-level operator (`aten::*`).
    AtenOp,
    /// Host runtime API call (cudaLaunchKernel / cudaMemcpyAsync / ...).
    RuntimeApi,
    /// Device kernel execution on a stream.
    Kernel,
    /// NVTX instrumentation range (Phase-2 replay scoping).
    Nvtx,
}

impl EventKind {
    /// Every kind, in the order documented in `docs/trace_format.md`
    /// (the spec's coverage test iterates this).
    ///
    /// The wildcard-free `guard` match makes a new variant a compile
    /// error *here* (not just in `as_str`): extend this array AND the
    /// §4.1 table in `docs/trace_format.md` together.
    pub const ALL: [EventKind; 5] = {
        const fn guard(k: EventKind) -> EventKind {
            match k {
                EventKind::TorchOp
                | EventKind::AtenOp
                | EventKind::RuntimeApi
                | EventKind::Kernel
                | EventKind::Nvtx => k,
            }
        }
        [
            guard(EventKind::TorchOp),
            guard(EventKind::AtenOp),
            guard(EventKind::RuntimeApi),
            guard(EventKind::Kernel),
            guard(EventKind::Nvtx),
        ]
    };

    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::TorchOp => "torch_op",
            EventKind::AtenOp => "aten_op",
            EventKind::RuntimeApi => "runtime_api",
            EventKind::Kernel => "kernel",
            EventKind::Nvtx => "nvtx",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<EventKind> {
        Ok(match s {
            "torch_op" => EventKind::TorchOp,
            "aten_op" => EventKind::AtenOp,
            "runtime_api" => EventKind::RuntimeApi,
            "kernel" => EventKind::Kernel,
            "nvtx" => EventKind::Nvtx,
            other => anyhow::bail!("unknown event kind '{other}'"),
        })
    }
}

/// Timeline an event lives on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Track {
    /// The single-threaded host dispatch path.
    Host,
    /// A device stream (stream id).
    Device(u32),
}

impl Track {
    fn to_json(self) -> Json {
        match self {
            Track::Host => Json::Num(-1.0),
            Track::Device(s) => Json::Num(s as f64),
        }
    }

    fn from_json(v: &Json) -> anyhow::Result<Track> {
        let n = v
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("track must be a number"))?;
        if n < 0.0 {
            Ok(Track::Host)
        } else {
            Ok(Track::Device(n as u32))
        }
    }
}

/// Kernel metadata attached to `Kernel` events: everything the Phase-2
/// dedup cache keys on (paper §III-B: "operator, shapes, dtypes, scalar
/// arguments, target kernel name, and launch configuration"), plus the
/// analytic work estimates used for utilization reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelMeta {
    /// Raw kernel symbol as a profiler would see it.
    pub kernel_name: String,
    /// Kernel family tag (see `kernels::family`).
    pub family: String,
    /// Originating ATen operator (e.g. `aten::mm`).
    pub aten_op: String,
    /// Canonical shapes/dtypes/scalars key.
    pub shapes_key: String,
    pub grid: [u32; 3],
    pub block: [u32; 3],
    /// `I_lib`: routed through a vendor library front-end (cuBLAS/cuDNN).
    pub lib_mediated: bool,
    /// Analytic FLOPs of the kernel (0 for pure data movement).
    pub flops: f64,
    /// Analytic bytes moved.
    pub bytes: f64,
}

impl KernelMeta {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("kernel_name", self.kernel_name.as_str())
            .with("family", self.family.as_str())
            .with("aten_op", self.aten_op.as_str())
            .with("shapes_key", self.shapes_key.as_str())
            .with(
                "grid",
                Json::Arr(self.grid.iter().map(|&g| Json::from(g)).collect()),
            )
            .with(
                "block",
                Json::Arr(self.block.iter().map(|&b| Json::from(b)).collect()),
            )
            .with("lib", self.lib_mediated)
            .with("flops", self.flops)
            .with("bytes", self.bytes)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<KernelMeta> {
        let dim3 = |key: &str| -> anyhow::Result<[u32; 3]> {
            let arr = v.arr_of(key)?;
            anyhow::ensure!(arr.len() == 3, "{key} must have 3 entries");
            Ok([
                arr[0].as_u64().unwrap_or(1) as u32,
                arr[1].as_u64().unwrap_or(1) as u32,
                arr[2].as_u64().unwrap_or(1) as u32,
            ])
        };
        Ok(KernelMeta {
            kernel_name: v.str_of("kernel_name")?.to_string(),
            family: v.str_of("family")?.to_string(),
            aten_op: v.str_of("aten_op")?.to_string(),
            shapes_key: v.str_of("shapes_key")?.to_string(),
            grid: dim3("grid")?,
            block: dim3("block")?,
            lib_mediated: v.req("lib")?.as_bool().unwrap_or(false),
            flops: v.f64_of("flops")?,
            bytes: v.f64_of("bytes")?,
        })
    }

    /// The Phase-2 deduplication key (paper: kernels sharing identical
    /// ATen metadata, kernel name and launch config are replayed once).
    pub fn dedup_key(&self) -> String {
        format!(
            "{}|{}|{}|{:?}|{:?}",
            self.aten_op, self.shapes_key, self.kernel_name, self.grid, self.block
        )
    }
}

/// One trace event. Times are microseconds on a common clock.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub kind: EventKind,
    pub name: String,
    pub ts_us: f64,
    pub dur_us: f64,
    /// Links TorchOp -> AtenOp -> RuntimeApi -> Kernel chains.
    pub correlation_id: u64,
    pub track: Track,
    /// Device (GPU / rank) the event belongs to. `None` means device 0
    /// — single-device producers omit the field entirely (spec §4),
    /// which keeps their on-disk traces byte-identical to spec v1.
    /// Multi-device producers (tensor-parallel sim, replica serving)
    /// stamp it; `track` stays the stream id *within* the device.
    pub device: Option<u32>,
    pub meta: Option<KernelMeta>,
}

impl TraceEvent {
    pub fn end_us(&self) -> f64 {
        self.ts_us + self.dur_us
    }

    /// Device this event belongs to (the `None` default is device 0).
    pub fn device_id(&self) -> u32 {
        self.device.unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .with("kind", self.kind.as_str())
            .with("name", self.name.as_str())
            .with("ts", self.ts_us)
            .with("dur", self.dur_us)
            .with("corr", self.correlation_id)
            .with("track", self.track.to_json());
        if let Some(d) = self.device {
            o.set("device", Json::from(d));
        }
        if let Some(meta) = &self.meta {
            o.set("meta", meta.to_json());
        }
        o
    }

    pub fn from_json(v: &Json) -> anyhow::Result<TraceEvent> {
        Ok(TraceEvent {
            kind: EventKind::parse(v.str_of("kind")?)?,
            name: v.str_of("name")?.to_string(),
            ts_us: v.f64_of("ts")?,
            dur_us: v.f64_of("dur")?,
            correlation_id: v.req("corr")?.as_u64().unwrap_or(0),
            track: Track::from_json(v.req("track")?)?,
            device: v.get("device").and_then(|d| d.as_u64()).map(|d| d as u32),
            meta: match v.get("meta") {
                Some(m) => Some(KernelMeta::from_json(m)?),
                None => None,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta() -> KernelMeta {
        KernelMeta {
            kernel_name: "ampere_bf16_gemm_128x64".into(),
            family: "gemm_cublas".into(),
            aten_op: "aten::mm".into(),
            shapes_key: "f32[128,64]x[64,32]".into(),
            grid: [8, 4, 1],
            block: [128, 1, 1],
            lib_mediated: true,
            flops: 2.0 * 128.0 * 64.0 * 32.0,
            bytes: 4.0 * (128.0 * 64.0 + 64.0 * 32.0 + 128.0 * 32.0),
        }
    }

    #[test]
    fn kind_roundtrip() {
        for k in [
            EventKind::TorchOp,
            EventKind::AtenOp,
            EventKind::RuntimeApi,
            EventKind::Kernel,
            EventKind::Nvtx,
        ] {
            assert_eq!(EventKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(EventKind::parse("bogus").is_err());
    }

    #[test]
    fn event_json_roundtrip() {
        let ev = TraceEvent {
            kind: EventKind::Kernel,
            name: "gemm".into(),
            ts_us: 12.5,
            dur_us: 3.25,
            correlation_id: 42,
            track: Track::Device(0),
            device: None,
            meta: Some(sample_meta()),
        };
        let back = TraceEvent::from_json(&ev.to_json()).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn host_event_roundtrip_without_meta() {
        let ev = TraceEvent {
            kind: EventKind::RuntimeApi,
            name: "cudaLaunchKernel".into(),
            ts_us: 0.0,
            dur_us: 1.0,
            correlation_id: 7,
            track: Track::Host,
            device: None,
            meta: None,
        };
        let back = TraceEvent::from_json(&ev.to_json()).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn device_field_roundtrips_and_defaults_to_zero() {
        let mut ev = TraceEvent {
            kind: EventKind::Kernel,
            name: "gemm".into(),
            ts_us: 1.0,
            dur_us: 2.0,
            correlation_id: 3,
            track: Track::Device(1),
            device: Some(2),
            meta: None,
        };
        assert_eq!(ev.device_id(), 2);
        let back = TraceEvent::from_json(&ev.to_json()).unwrap();
        assert_eq!(back, ev);
        assert!(ev.to_json().dump().contains("\"device\":2"));
        // The omitted field decodes as device 0 and is never emitted.
        ev.device = None;
        assert_eq!(ev.device_id(), 0);
        let back = TraceEvent::from_json(&ev.to_json()).unwrap();
        assert_eq!(back.device, None);
        assert!(!ev.to_json().dump().contains("device"));
    }

    #[test]
    fn dedup_key_distinguishes_config() {
        let a = sample_meta();
        let mut b = sample_meta();
        b.grid = [16, 4, 1];
        assert_ne!(a.dedup_key(), b.dedup_key());
        let c = sample_meta();
        assert_eq!(a.dedup_key(), c.dedup_key());
    }

    #[test]
    fn end_us() {
        let ev = TraceEvent {
            kind: EventKind::Nvtx,
            name: "replay".into(),
            ts_us: 10.0,
            dur_us: 2.5,
            correlation_id: 0,
            track: Track::Host,
            device: None,
            meta: None,
        };
        assert_eq!(ev.end_us(), 12.5);
    }
}
