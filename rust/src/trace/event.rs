//! Trace event model — the nsys/CUPTI analog.
//!
//! The paper's Phase 1 consumes "timestamped Python/torch operators,
//! ATen operators, CUDA runtime calls, and GPU kernels linked by
//! correlation IDs" plus NVTX ranges in Phase 2.  These five event kinds
//! are modeled here; both the simulator (`sim`) and the real PJRT
//! runtime (`runtime`) emit them, and every TaxBreak analysis consumes
//! only this representation (trace-format-as-interface, DESIGN.md §9).

use crate::util::intern::Sym;
use crate::util::json::Json;

/// Which trace source produced an event (CUPTI activity-kind analog).
///
/// The first five kinds are the spec-v1/v2 *observations*; the last
/// four (spec v3, §4.2) are *recordings* — every source of
/// nondeterminism a serving run consumes, captured so the run replays
/// bit-identically (`serving::replay`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EventKind {
    /// Python-level framework operator (`torch.*` call).
    TorchOp,
    /// C++ dispatcher-level operator (`aten::*`).
    AtenOp,
    /// Host runtime API call (cudaLaunchKernel / cudaMemcpyAsync / ...).
    RuntimeApi,
    /// Device kernel execution on a stream.
    Kernel,
    /// NVTX instrumentation range (Phase-2 replay scoping).
    Nvtx,
    /// A request entering the serving system (spec v3). `ts` is the
    /// effective submit time; the request parameters live in `args`.
    Arrival,
    /// One consumed random number (spec v3): site + final value, so
    /// replay feeds the recorded value back instead of re-sampling.
    RngDraw,
    /// One scheduler step's admission/preemption outcome (spec v3) —
    /// replayed, not re-decided.
    SchedDecision,
    /// The virtual clock jumping forward over idle time (spec v3).
    /// `ts` is the clock before the jump, `dur` the jump amount.
    ClockJump,
    /// One injected fault window (spec v4): a deterministic, seeded
    /// perturbation (device stall, host jitter, launch failure, KV
    /// pressure) armed on the run. `ts` is the onset; the full window
    /// (`kind`/`target`/`onset_us`/`dur_us`/`magnitude`) lives in
    /// `args` so replay can re-arm the identical fault schedule.
    /// Rides correlation id 0 and is decomposition-blind.
    Fault,
}

impl EventKind {
    /// Every kind, in the order documented in `docs/trace_format.md`
    /// (the spec's coverage test iterates this).
    ///
    /// The wildcard-free `guard` match makes a new variant a compile
    /// error *here* (not just in `as_str`): extend this array AND the
    /// §4.1 table in `docs/trace_format.md` together.
    pub const ALL: [EventKind; 10] = {
        const fn guard(k: EventKind) -> EventKind {
            match k {
                EventKind::TorchOp
                | EventKind::AtenOp
                | EventKind::RuntimeApi
                | EventKind::Kernel
                | EventKind::Nvtx
                | EventKind::Arrival
                | EventKind::RngDraw
                | EventKind::SchedDecision
                | EventKind::ClockJump
                | EventKind::Fault => k,
            }
        }
        [
            guard(EventKind::TorchOp),
            guard(EventKind::AtenOp),
            guard(EventKind::RuntimeApi),
            guard(EventKind::Kernel),
            guard(EventKind::Nvtx),
            guard(EventKind::Arrival),
            guard(EventKind::RngDraw),
            guard(EventKind::SchedDecision),
            guard(EventKind::ClockJump),
            guard(EventKind::Fault),
        ]
    };

    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::TorchOp => "torch_op",
            EventKind::AtenOp => "aten_op",
            EventKind::RuntimeApi => "runtime_api",
            EventKind::Kernel => "kernel",
            EventKind::Nvtx => "nvtx",
            EventKind::Arrival => "arrival",
            EventKind::RngDraw => "rng_draw",
            EventKind::SchedDecision => "sched_decision",
            EventKind::ClockJump => "clock_jump",
            EventKind::Fault => "fault",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<EventKind> {
        Ok(match s {
            "torch_op" => EventKind::TorchOp,
            "aten_op" => EventKind::AtenOp,
            "runtime_api" => EventKind::RuntimeApi,
            "kernel" => EventKind::Kernel,
            "nvtx" => EventKind::Nvtx,
            "arrival" => EventKind::Arrival,
            "rng_draw" => EventKind::RngDraw,
            "sched_decision" => EventKind::SchedDecision,
            "clock_jump" => EventKind::ClockJump,
            "fault" => EventKind::Fault,
            other => anyhow::bail!("unknown event kind '{other}'"),
        })
    }

    /// Does this kind carry a [`ReplayArgs`] payload? (`ClockJump`
    /// needs only `ts`/`dur`, so it carries none.)
    pub fn has_args(&self) -> bool {
        matches!(
            self,
            EventKind::Arrival
                | EventKind::RngDraw
                | EventKind::SchedDecision
                | EventKind::Fault
        )
    }
}

/// Timeline an event lives on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Track {
    /// The single-threaded host dispatch path.
    Host,
    /// A device stream (stream id).
    Device(u32),
}

impl Track {
    fn to_json(self) -> Json {
        match self {
            Track::Host => Json::Num(-1.0),
            Track::Device(s) => Json::Num(s as f64),
        }
    }

    fn from_json(v: &Json) -> anyhow::Result<Track> {
        let n = v
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("track must be a number"))?;
        if n < 0.0 {
            Ok(Track::Host)
        } else {
            Ok(Track::Device(n as u32))
        }
    }
}

/// Kernel metadata attached to `Kernel` events: everything the Phase-2
/// dedup cache keys on (paper §III-B: "operator, shapes, dtypes, scalar
/// arguments, target kernel name, and launch configuration"), plus the
/// analytic work estimates used for utilization reporting.
///
/// The four string fields are interned [`Sym`]s: the lowering emits a
/// tiny, tile-quantized vocabulary repeated across millions of events,
/// so cloning/hashing metadata is pointer work and the Phase-2 dedup
/// key is the `Copy` [`DedupKey`] instead of a per-call `String`
/// (DESIGN.md §15). Serialization is unchanged byte-for-byte — the
/// golden corpus pins it.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelMeta {
    /// Raw kernel symbol as a profiler would see it.
    pub kernel_name: Sym,
    /// Kernel family tag (see `kernels::family`).
    pub family: Sym,
    /// Originating ATen operator (e.g. `aten::mm`).
    pub aten_op: Sym,
    /// Canonical shapes/dtypes/scalars key.
    pub shapes_key: Sym,
    pub grid: [u32; 3],
    pub block: [u32; 3],
    /// `I_lib`: routed through a vendor library front-end (cuBLAS/cuDNN).
    pub lib_mediated: bool,
    /// Analytic FLOPs of the kernel (0 for pure data movement).
    pub flops: f64,
    /// Analytic bytes moved.
    pub bytes: f64,
}

impl KernelMeta {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("kernel_name", self.kernel_name.as_str())
            .with("family", self.family.as_str())
            .with("aten_op", self.aten_op.as_str())
            .with("shapes_key", self.shapes_key.as_str())
            .with(
                "grid",
                Json::Arr(self.grid.iter().map(|&g| Json::from(g)).collect()),
            )
            .with(
                "block",
                Json::Arr(self.block.iter().map(|&b| Json::from(b)).collect()),
            )
            .with("lib", self.lib_mediated)
            .with("flops", self.flops)
            .with("bytes", self.bytes)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<KernelMeta> {
        let dim3 = |key: &str| -> anyhow::Result<[u32; 3]> {
            let arr = v.arr_of(key)?;
            anyhow::ensure!(arr.len() == 3, "{key} must have 3 entries");
            Ok([
                arr[0].as_u64().unwrap_or(1) as u32,
                arr[1].as_u64().unwrap_or(1) as u32,
                arr[2].as_u64().unwrap_or(1) as u32,
            ])
        };
        Ok(KernelMeta {
            kernel_name: v.str_of("kernel_name")?.into(),
            family: v.str_of("family")?.into(),
            aten_op: v.str_of("aten_op")?.into(),
            shapes_key: v.str_of("shapes_key")?.into(),
            grid: dim3("grid")?,
            block: dim3("block")?,
            lib_mediated: v.req("lib")?.as_bool().unwrap_or(false),
            flops: v.f64_of("flops")?,
            bytes: v.f64_of("bytes")?,
        })
    }

    /// The Phase-2 deduplication key (paper: kernels sharing identical
    /// ATen metadata, kernel name and launch config are replayed once)
    /// as a `Copy` value — the hot-path form: no allocation, pointer
    /// hash/compare. Two metas share a `DedupKey` iff their
    /// [`dedup_key`](Self::dedup_key) strings are byte-equal (interning
    /// maps equal content to one symbol).
    pub fn dedup(&self) -> DedupKey {
        DedupKey {
            aten_op: self.aten_op,
            shapes_key: self.shapes_key,
            kernel_name: self.kernel_name,
            grid: self.grid,
            block: self.block,
        }
    }

    /// The dedup key rendered as the stable string form. Cold paths
    /// only: the Phase-2 replay RNG forks on these exact bytes (so they
    /// are part of the pinned bit-identity surface) and `whatif`
    /// schedules carry them for reporting.
    pub fn dedup_key(&self) -> String {
        self.dedup().to_string()
    }
}

/// The Phase-2 dedup key as a `Copy`, allocation-free value. Field
/// order mirrors the string form `aten|shapes|kernel|grid|block`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DedupKey {
    pub aten_op: Sym,
    pub shapes_key: Sym,
    pub kernel_name: Sym,
    pub grid: [u32; 3],
    pub block: [u32; 3],
}

impl std::fmt::Display for DedupKey {
    /// Byte-identical to the pre-interning `dedup_key()` format string
    /// — `phase2::SimReplayBackend` forks its RNG on these bytes.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}|{}|{}|{:?}|{:?}",
            self.aten_op, self.shapes_key, self.kernel_name, self.grid, self.block
        )
    }
}

/// Payload of a spec-v3 replay event (spec §4.2). The variant is
/// implied by the owning event's [`EventKind`]; JSON serializes it
/// under the `"args"` key, the binary dialect behind the
/// `PRESENT_ARGS` presence bit.
#[derive(Debug, Clone, PartialEq)]
pub enum ReplayArgs {
    /// `Arrival`: the request as the drive loop saw it. Prompt *token
    /// values* never influence sim timing (kernel names, FLOPs and
    /// draws depend only on counts), so the length suffices for
    /// bit-identical replay.
    Arrival {
        req: u64,
        /// Prompt length in tokens.
        plen: u64,
        /// Generation budget (`max_new_tokens`).
        max_new: u64,
        /// Model the request targets.
        model: String,
    },
    /// `RngDraw`: one consumed random value. `value` is the *final*
    /// quantity the producer used (post any scaling), so replay
    /// substitutes it verbatim without re-deriving RNG state.
    RngDraw { site: String, value: f64 },
    /// `SchedDecision`: one scheduler step. `admitted` preserves group
    /// boundaries (one inner list per admitted batch group, member
    /// request ids in admission order); `preempted` is sorted
    /// ascending; `shed` (spec v4) lists requests dropped by
    /// deadline-aware load shedding this step, sorted ascending —
    /// serialized only when non-empty so fault-free captures stay
    /// byte-identical to spec v3; `batch` is the number of active
    /// sequences after the step.
    SchedDecision {
        step: u64,
        admitted: Vec<Vec<u64>>,
        preempted: Vec<u64>,
        shed: Vec<u64>,
        batch: u64,
    },
    /// `Fault` (spec v4): one injected fault window, re-armable on
    /// replay. `kind` is the fault kind tag (`device_stall` /
    /// `host_jitter` / `launch_fail` / `kv_pressure`), `target` the
    /// perturbed resource (e.g. `stream:1`, `host:all`), and
    /// `magnitude` the kind-specific intensity (a multiplier, an
    /// attempt count, or a sequestered-page fraction).
    Fault {
        kind: String,
        target: String,
        onset_us: f64,
        dur_us: f64,
        magnitude: f64,
    },
}

impl ReplayArgs {
    pub fn to_json(&self) -> Json {
        match self {
            ReplayArgs::Arrival {
                req,
                plen,
                max_new,
                model,
            } => Json::obj()
                .with("req", *req)
                .with("plen", *plen)
                .with("max_new", *max_new)
                .with("model", model.as_str()),
            ReplayArgs::RngDraw { site, value } => {
                Json::obj().with("site", site.as_str()).with("value", *value)
            }
            ReplayArgs::SchedDecision {
                step,
                admitted,
                preempted,
                shed,
                batch,
            } => {
                let mut o = Json::obj()
                    .with("step", *step)
                    .with(
                        "admitted",
                        Json::Arr(
                            admitted
                                .iter()
                                .map(|g| Json::Arr(g.iter().map(|&id| Json::from(id)).collect()))
                                .collect(),
                        ),
                    )
                    .with(
                        "preempted",
                        Json::Arr(preempted.iter().map(|&id| Json::from(id)).collect()),
                    );
                // The `shed` key is a spec-v4 extension: omitted when
                // empty, so fault-free captures stay byte-identical to
                // spec v3.
                if !shed.is_empty() {
                    o.set(
                        "shed",
                        Json::Arr(shed.iter().map(|&id| Json::from(id)).collect()),
                    );
                }
                o.with("batch", *batch)
            }
            ReplayArgs::Fault {
                kind,
                target,
                onset_us,
                dur_us,
                magnitude,
            } => Json::obj()
                .with("kind", kind.as_str())
                .with("target", target.as_str())
                .with("onset_us", *onset_us)
                .with("dur_us", *dur_us)
                .with("magnitude", *magnitude),
        }
    }

    /// Parse the variant matching `kind` (the JSON payload itself is
    /// untagged — the event kind selects the shape).
    pub fn from_json(kind: EventKind, v: &Json) -> anyhow::Result<ReplayArgs> {
        let ids = |key: &str| -> anyhow::Result<Vec<u64>> {
            v.arr_of(key)?
                .iter()
                .map(|x| {
                    x.as_u64()
                        .ok_or_else(|| anyhow::anyhow!("{key} entries must be request ids"))
                })
                .collect()
        };
        Ok(match kind {
            EventKind::Arrival => ReplayArgs::Arrival {
                req: v.req("req")?.as_u64().unwrap_or(0),
                plen: v.req("plen")?.as_u64().unwrap_or(0),
                max_new: v.req("max_new")?.as_u64().unwrap_or(0),
                model: v.str_of("model")?.to_string(),
            },
            EventKind::RngDraw => ReplayArgs::RngDraw {
                site: v.str_of("site")?.to_string(),
                value: v.f64_of("value")?,
            },
            EventKind::SchedDecision => ReplayArgs::SchedDecision {
                step: v.req("step")?.as_u64().unwrap_or(0),
                admitted: v
                    .arr_of("admitted")?
                    .iter()
                    .map(|g| {
                        g.as_arr()
                            .ok_or_else(|| anyhow::anyhow!("admitted must be a list of groups"))?
                            .iter()
                            .map(|x| {
                                x.as_u64().ok_or_else(|| {
                                    anyhow::anyhow!("admitted group entries must be request ids")
                                })
                            })
                            .collect()
                    })
                    .collect::<anyhow::Result<Vec<Vec<u64>>>>()?,
                preempted: ids("preempted")?,
                shed: if v.get("shed").is_some() {
                    ids("shed")?
                } else {
                    Vec::new()
                },
                batch: v.req("batch")?.as_u64().unwrap_or(0),
            },
            EventKind::Fault => ReplayArgs::Fault {
                kind: v.str_of("kind")?.to_string(),
                target: v.str_of("target")?.to_string(),
                onset_us: v.f64_of("onset_us")?,
                dur_us: v.f64_of("dur_us")?,
                magnitude: v.f64_of("magnitude")?,
            },
            other => anyhow::bail!("event kind '{}' carries no args", other.as_str()),
        })
    }
}

/// One trace event. Times are microseconds on a common clock.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub kind: EventKind,
    pub name: String,
    pub ts_us: f64,
    pub dur_us: f64,
    /// Links TorchOp -> AtenOp -> RuntimeApi -> Kernel chains.
    /// Spec-v3 replay events carry `0` — they belong to no chain.
    pub correlation_id: u64,
    pub track: Track,
    /// Device (GPU / rank) the event belongs to. `None` means device 0
    /// — single-device producers omit the field entirely (spec §4),
    /// which keeps their on-disk traces byte-identical to spec v1.
    /// Multi-device producers (tensor-parallel sim, replica serving)
    /// stamp it; `track` stays the stream id *within* the device.
    pub device: Option<u32>,
    /// Spec-v3 replay payload; `None` for observation events and
    /// `ClockJump` (spec §4.2), keeping v1/v2 traces byte-identical.
    pub args: Option<ReplayArgs>,
    pub meta: Option<KernelMeta>,
}

impl TraceEvent {
    pub fn end_us(&self) -> f64 {
        self.ts_us + self.dur_us
    }

    /// Device this event belongs to (the `None` default is device 0).
    pub fn device_id(&self) -> u32 {
        self.device.unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj()
            .with("kind", self.kind.as_str())
            .with("name", self.name.as_str())
            .with("ts", self.ts_us)
            .with("dur", self.dur_us)
            .with("corr", self.correlation_id)
            .with("track", self.track.to_json());
        if let Some(d) = self.device {
            o.set("device", Json::from(d));
        }
        if let Some(args) = &self.args {
            o.set("args", args.to_json());
        }
        if let Some(meta) = &self.meta {
            o.set("meta", meta.to_json());
        }
        o
    }

    pub fn from_json(v: &Json) -> anyhow::Result<TraceEvent> {
        let kind = EventKind::parse(v.str_of("kind")?)?;
        let args = match v.get("args") {
            Some(a) => Some(ReplayArgs::from_json(kind, a)?),
            None => {
                anyhow::ensure!(
                    !kind.has_args(),
                    "'{}' event lacks its args payload",
                    kind.as_str()
                );
                None
            }
        };
        Ok(TraceEvent {
            kind,
            name: v.str_of("name")?.to_string(),
            ts_us: v.f64_of("ts")?,
            dur_us: v.f64_of("dur")?,
            correlation_id: v.req("corr")?.as_u64().unwrap_or(0),
            track: Track::from_json(v.req("track")?)?,
            device: v.get("device").and_then(|d| d.as_u64()).map(|d| d as u32),
            args,
            meta: match v.get("meta") {
                Some(m) => Some(KernelMeta::from_json(m)?),
                None => None,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta() -> KernelMeta {
        KernelMeta {
            kernel_name: "ampere_bf16_gemm_128x64".into(),
            family: "gemm_cublas".into(),
            aten_op: "aten::mm".into(),
            shapes_key: "f32[128,64]x[64,32]".into(),
            grid: [8, 4, 1],
            block: [128, 1, 1],
            lib_mediated: true,
            flops: 2.0 * 128.0 * 64.0 * 32.0,
            bytes: 4.0 * (128.0 * 64.0 + 64.0 * 32.0 + 128.0 * 32.0),
        }
    }

    #[test]
    fn kind_roundtrip() {
        for k in [
            EventKind::TorchOp,
            EventKind::AtenOp,
            EventKind::RuntimeApi,
            EventKind::Kernel,
            EventKind::Nvtx,
        ] {
            assert_eq!(EventKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(EventKind::parse("bogus").is_err());
    }

    #[test]
    fn event_json_roundtrip() {
        let ev = TraceEvent {
            kind: EventKind::Kernel,
            name: "gemm".into(),
            ts_us: 12.5,
            dur_us: 3.25,
            correlation_id: 42,
            track: Track::Device(0),
            device: None,
            args: None,
            meta: Some(sample_meta()),
        };
        let back = TraceEvent::from_json(&ev.to_json()).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn host_event_roundtrip_without_meta() {
        let ev = TraceEvent {
            kind: EventKind::RuntimeApi,
            name: "cudaLaunchKernel".into(),
            ts_us: 0.0,
            dur_us: 1.0,
            correlation_id: 7,
            track: Track::Host,
            device: None,
            args: None,
            meta: None,
        };
        let back = TraceEvent::from_json(&ev.to_json()).unwrap();
        assert_eq!(back, ev);
    }

    #[test]
    fn device_field_roundtrips_and_defaults_to_zero() {
        let mut ev = TraceEvent {
            kind: EventKind::Kernel,
            name: "gemm".into(),
            ts_us: 1.0,
            dur_us: 2.0,
            correlation_id: 3,
            track: Track::Device(1),
            device: Some(2),
            args: None,
            meta: None,
        };
        assert_eq!(ev.device_id(), 2);
        let back = TraceEvent::from_json(&ev.to_json()).unwrap();
        assert_eq!(back, ev);
        assert!(ev.to_json().dump().contains("\"device\":2"));
        // The omitted field decodes as device 0 and is never emitted.
        ev.device = None;
        assert_eq!(ev.device_id(), 0);
        let back = TraceEvent::from_json(&ev.to_json()).unwrap();
        assert_eq!(back.device, None);
        assert!(!ev.to_json().dump().contains("device"));
    }

    #[test]
    fn dedup_key_distinguishes_config() {
        let a = sample_meta();
        let mut b = sample_meta();
        b.grid = [16, 4, 1];
        assert_ne!(a.dedup_key(), b.dedup_key());
        let c = sample_meta();
        assert_eq!(a.dedup_key(), c.dedup_key());
    }

    #[test]
    fn dedup_value_key_agrees_with_string_key() {
        // The Copy key and the string key induce the same equivalence
        // classes, and Display renders the pinned pre-interning format.
        let a = sample_meta();
        let mut b = sample_meta();
        b.block = [64, 1, 1];
        assert_ne!(a.dedup(), b.dedup());
        assert_eq!(a.dedup(), sample_meta().dedup());
        assert_eq!(a.dedup().to_string(), a.dedup_key());
        assert_eq!(
            a.dedup_key(),
            "aten::mm|f32[128,64]x[64,32]|ampere_bf16_gemm_128x64|[8, 4, 1]|[128, 1, 1]"
        );
    }

    #[test]
    fn end_us() {
        let ev = TraceEvent {
            kind: EventKind::Nvtx,
            name: "replay".into(),
            ts_us: 10.0,
            dur_us: 2.5,
            correlation_id: 0,
            track: Track::Host,
            device: None,
            args: None,
            meta: None,
        };
        assert_eq!(ev.end_us(), 12.5);
    }
}
