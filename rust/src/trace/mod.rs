//! Trace container + IO — the interface between execution (simulated or
//! real PJRT) and every TaxBreak analysis.
//!
//! The on-disk JSON format is specified in `docs/trace_format.md`; the
//! compact binary dialect (`.tbt`, module [`binary`]) in its §10. The
//! conformance suites `rust/tests/trace_format.rs` and
//! `rust/tests/trace_binary.rs` enforce the spec (field names,
//! event-kind tags, canonical encoding, byte-stability of
//! save → load → save, cross-dialect golden bytes).
//!
//! [`Trace::load`] auto-detects the dialect by magic, so every reader
//! (`analyze`, `whatif`, `decompose`, the chrome exporter) accepts
//! either format transparently; writers pick by extension via
//! [`Trace::save_auto`] / [`sink::file_sink`].

pub mod binary;
pub mod chrome;
pub mod event;
pub mod sink;

pub use binary::{BinaryTraceError, BinaryTraceReader, BinaryTraceWriter, Dialect, SalvageOutcome};
pub use event::{DedupKey, EventKind, KernelMeta, ReplayArgs, Track, TraceEvent};
pub use sink::{CountingSink, NullSink, TraceBufferSink, TraceSink};

use std::collections::HashMap;
use std::path::Path;

use crate::util::json::Json;

/// Run-level metadata carried with a trace.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TraceMeta {
    /// Platform preset name ("h100", "h200", "pjrt-cpu", ...).
    pub platform: String,
    /// Model name ("llama-3.2-1b", "olmoe-1b-7b", "dense_fused", ...).
    pub model: String,
    /// "prefill" | "decode" | "serve".
    pub phase: String,
    pub batch: usize,
    pub seq: usize,
    /// Generated tokens (m in the paper; 1 for prefill).
    pub m_tokens: usize,
    /// Wall-clock end-to-end latency of the traced region (us).
    pub wall_us: f64,
}

impl TraceMeta {
    fn to_json(&self) -> Json {
        Json::obj()
            .with("platform", self.platform.as_str())
            .with("model", self.model.as_str())
            .with("phase", self.phase.as_str())
            .with("batch", self.batch)
            .with("seq", self.seq)
            .with("m_tokens", self.m_tokens)
            .with("wall_us", self.wall_us)
    }

    fn from_json(v: &Json) -> anyhow::Result<TraceMeta> {
        Ok(TraceMeta {
            platform: v.str_of("platform")?.to_string(),
            model: v.str_of("model")?.to_string(),
            phase: v.str_of("phase")?.to_string(),
            batch: v.usize_of("batch")?,
            seq: v.usize_of("seq")?,
            m_tokens: v.usize_of("m_tokens")?,
            wall_us: v.f64_of("wall_us")?,
        })
    }
}

/// The full event chain behind one kernel invocation, resolved through
/// correlation IDs (paper Fig. 4's (1) nvtx, (2) api, (3) kernel view).
#[derive(Debug, Clone, Copy, Default)]
pub struct CorrelationChain<'a> {
    pub torch_op: Option<&'a TraceEvent>,
    pub aten_op: Option<&'a TraceEvent>,
    pub runtime_api: Option<&'a TraceEvent>,
    pub kernel: Option<&'a TraceEvent>,
    pub nvtx: Option<&'a TraceEvent>,
}

/// A captured run: metadata + time-ordered events.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub meta: TraceMeta,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn new(meta: TraceMeta) -> Trace {
        Trace {
            meta,
            events: Vec::new(),
        }
    }

    pub fn push(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }

    /// All device-kernel events.
    pub fn kernels(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter().filter(|e| e.kind == EventKind::Kernel)
    }

    pub fn kernel_count(&self) -> usize {
        self.kernels().count()
    }

    /// Σ kernel execution time — `T_DeviceActive` (paper Eq. 3 input).
    pub fn device_active_us(&self) -> f64 {
        self.kernels().map(|e| e.dur_us).sum()
    }

    /// Wall-clock latency: recorded value, else the event span.
    pub fn e2e_us(&self) -> f64 {
        if self.meta.wall_us > 0.0 {
            self.meta.wall_us
        } else {
            self.span_us()
        }
    }

    /// Max end minus min start over all events.
    pub fn span_us(&self) -> f64 {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for e in &self.events {
            lo = lo.min(e.ts_us);
            hi = hi.max(e.end_us());
        }
        if lo.is_finite() {
            hi - lo
        } else {
            0.0
        }
    }

    /// Index events by correlation id into per-kernel chains.
    pub fn correlation_chains(&self) -> HashMap<u64, CorrelationChain<'_>> {
        let mut map: HashMap<u64, CorrelationChain<'_>> = HashMap::new();
        for e in &self.events {
            if e.correlation_id == 0 {
                continue;
            }
            let chain = map.entry(e.correlation_id).or_default();
            match e.kind {
                EventKind::TorchOp => chain.torch_op = Some(e),
                EventKind::AtenOp => chain.aten_op = Some(e),
                EventKind::RuntimeApi => chain.runtime_api = Some(e),
                EventKind::Kernel => chain.kernel = Some(e),
                EventKind::Nvtx => chain.nvtx = Some(e),
                // Replay recordings (spec v3/v4) belong to no kernel
                // chain; they always carry correlation id 0, so the
                // guard above already skipped them.
                EventKind::Arrival
                | EventKind::RngDraw
                | EventKind::SchedDecision
                | EventKind::ClockJump
                | EventKind::Fault => {}
            }
        }
        map
    }

    /// Unique kernel names (cleaned) — the Table II diversity numerator.
    pub fn unique_kernel_names(&self) -> usize {
        let mut names: Vec<&str> = self
            .kernels()
            .filter_map(|e| e.meta.as_ref().map(|m| m.kernel_name.as_str()))
            .collect();
        names.sort();
        names.dedup();
        names.len()
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("meta", self.meta.to_json())
            .with(
                "events",
                Json::Arr(self.events.iter().map(|e| e.to_json()).collect()),
            )
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Trace> {
        let meta = TraceMeta::from_json(v.req("meta")?)?;
        let mut events = Vec::new();
        for item in v.arr_of("events")? {
            events.push(TraceEvent::from_json(item)?);
        }
        Ok(Trace { meta, events })
    }

    /// Save as canonical compact JSON (dialect spec §6).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().dump())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }

    /// Save as the compact binary dialect (dialect spec §10).
    pub fn save_binary(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, binary::encode(self))
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }

    /// Save in the dialect implied by the path's extension
    /// (`.tbt` ⇒ binary, anything else ⇒ JSON).
    pub fn save_auto(&self, path: &Path) -> anyhow::Result<()> {
        match Dialect::of_path(path) {
            Dialect::Binary => self.save_binary(path),
            Dialect::Json => self.save(path),
        }
    }

    /// Load a trace in either dialect, detected by magic: files
    /// starting with `TXBT` parse as binary, everything else as JSON.
    pub fn load(path: &Path) -> anyhow::Result<Trace> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        if binary::is_binary(&bytes) {
            Ok(binary::decode(&bytes)?)
        } else {
            let text = std::str::from_utf8(&bytes)
                .map_err(|e| anyhow::anyhow!("{} is not UTF-8 JSON: {e}", path.display()))?;
            Trace::from_json(&Json::parse(text)?)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel_event(corr: u64, ts: f64, dur: f64, name: &str) -> TraceEvent {
        TraceEvent {
            kind: EventKind::Kernel,
            name: name.to_string(),
            ts_us: ts,
            dur_us: dur,
            correlation_id: corr,
            track: Track::Device(0),
            device: None,
            args: None,
            meta: Some(KernelMeta {
                kernel_name: name.into(),
                family: "elem_generic".into(),
                aten_op: "aten::mul".into(),
                shapes_key: "f32[8]".into(),
                grid: [1, 1, 1],
                block: [128, 1, 1],
                lib_mediated: false,
                flops: 8.0,
                bytes: 64.0,
            }),
        }
    }

    fn host_event(kind: EventKind, corr: u64, ts: f64, dur: f64, name: &str) -> TraceEvent {
        TraceEvent {
            kind,
            name: name.to_string(),
            ts_us: ts,
            dur_us: dur,
            correlation_id: corr,
            track: Track::Host,
            device: None,
            args: None,
            meta: None,
        }
    }

    fn sample_trace() -> Trace {
        let mut t = Trace::new(TraceMeta {
            platform: "h200".into(),
            model: "gpt2".into(),
            phase: "prefill".into(),
            batch: 1,
            seq: 512,
            m_tokens: 1,
            wall_us: 100.0,
        });
        t.push(host_event(EventKind::TorchOp, 1, 0.0, 2.0, "torch.mul"));
        t.push(host_event(EventKind::AtenOp, 1, 1.0, 1.0, "aten::mul"));
        t.push(host_event(EventKind::RuntimeApi, 1, 1.5, 0.5, "cudaLaunchKernel"));
        t.push(kernel_event(1, 6.0, 3.0, "vectorized_elementwise"));
        t.push(host_event(EventKind::TorchOp, 2, 8.0, 2.0, "torch.mul"));
        t.push(kernel_event(2, 12.0, 4.0, "vectorized_elementwise"));
        t
    }

    #[test]
    fn device_active_sums_kernels() {
        assert_eq!(sample_trace().device_active_us(), 7.0);
        assert_eq!(sample_trace().kernel_count(), 2);
    }

    #[test]
    fn e2e_prefers_wall() {
        let t = sample_trace();
        assert_eq!(t.e2e_us(), 100.0);
        let mut t2 = t.clone();
        t2.meta.wall_us = 0.0;
        assert_eq!(t2.e2e_us(), 16.0); // span 0..16
    }

    #[test]
    fn chains_link_by_correlation() {
        let t = sample_trace();
        let chains = t.correlation_chains();
        let c1 = &chains[&1];
        assert!(c1.torch_op.is_some());
        assert!(c1.aten_op.is_some());
        assert!(c1.runtime_api.is_some());
        assert!(c1.kernel.is_some());
        let c2 = &chains[&2];
        assert!(c2.aten_op.is_none());
        assert!(c2.kernel.is_some());
    }

    #[test]
    fn unique_names_dedup() {
        assert_eq!(sample_trace().unique_kernel_names(), 1);
    }

    #[test]
    fn json_roundtrip() {
        let t = sample_trace();
        let back = Trace::from_json(&t.to_json()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn save_load_roundtrip() {
        let t = sample_trace();
        let dir = std::env::temp_dir().join("taxbreak_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.json");
        t.save(&path).unwrap();
        assert_eq!(Trace::load(&path).unwrap(), t);
    }

    #[test]
    fn binary_save_load_auto_detects() {
        let t = sample_trace();
        let dir = std::env::temp_dir().join("taxbreak_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.tbt");
        t.save_auto(&path).unwrap();
        assert!(binary::is_binary(&std::fs::read(&path).unwrap()));
        assert_eq!(Trace::load(&path).unwrap(), t);
    }

    #[test]
    fn empty_trace_span_is_zero() {
        let t = Trace::default();
        assert_eq!(t.span_us(), 0.0);
        assert_eq!(t.device_active_us(), 0.0);
    }
}
