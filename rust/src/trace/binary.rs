//! Compact binary trace dialect (`.tbt`) — the streaming twin of the
//! canonical JSON format in `docs/trace_format.md` §10.
//!
//! Layout (all multi-byte integers little-endian):
//!
//! ```text
//! header   := magic "TXBT" | version u16 | flags u16
//! meta     := 0x01 | platform str | model str | phase str
//!                  | batch varint | seq varint | m_tokens varint
//! event    := 0x02 | kind u8 | presence u8 | name str
//!                  | ts f64 | dur f64 | corr varint | track varint
//!                  | [device varint] | [replay-args] | [kernel-meta]
//! trailer  := 0x03 | event_count u64 | wall_us f64 | end "TXBE"
//! ```
//!
//! The trailer — not the meta record — carries `wall_us`: a streaming
//! writer does not know the wall-clock until the run ends, so the value
//! is appended last and readers back-fill `TraceMeta::wall_us` from it.
//! The fixed 21-byte trailer doubles as a truncation detector (missing
//! or malformed trailer ⇒ typed error, never a silent partial parse).
//!
//! Strings are varint-length-prefixed UTF-8; varints are unsigned
//! LEB128 (≤ 10 bytes); `f64`s are IEEE-754 bit patterns, so every
//! value — including ones JSON cannot print losslessly — round-trips
//! exactly. `track` encodes `Host` as 0 and `Device(s)` as `s + 1`.
//!
//! All reader entry points return [`BinaryTraceError`] directly (the
//! vendored `anyhow` has no downcasting); callers that only need an
//! opaque error let `?` convert via `std::error::Error`.

use std::fmt;
use std::io::{Read, Write};
use std::path::Path;

use super::event::{EventKind, KernelMeta, ReplayArgs, Track, TraceEvent};
use super::{Trace, TraceMeta};

/// File magic: first four bytes of every binary trace.
pub const MAGIC: [u8; 4] = *b"TXBT";
/// Current dialect version (docs/trace_format.md §10).
pub const VERSION: u16 = 1;
/// Dialect flags. No flags are defined for version 1; readers reject
/// any nonzero value rather than guess at semantics.
pub const FLAGS: u16 = 0;
/// Trailer end magic: last four bytes of every complete binary trace.
pub const END_MAGIC: [u8; 4] = *b"TXBE";
/// Canonical file extension for the binary dialect.
pub const EXTENSION: &str = "tbt";

/// Record tags.
const TAG_META: u8 = 0x01;
const TAG_EVENT: u8 = 0x02;
const TAG_TRAILER: u8 = 0x03;

/// Trailer size: tag + count u64 + wall f64 + end magic.
pub const TRAILER_LEN: usize = 1 + 8 + 8 + 4;

/// Presence bits in an event record.
const PRESENT_DEVICE: u8 = 0b001;
const PRESENT_META: u8 = 0b010;
/// Spec-v3 replay payload present (`args`, spec §10.4). Encoded
/// between the device field and the kernel meta.
const PRESENT_ARGS: u8 = 0b100;
/// Spec-v4: the `sched_decision` payload carries a non-empty `shed`
/// list (between `preempted` and `batch`). Set only when requests were
/// actually shed, so fault-free captures stay byte-identical to the
/// spec-v3 encoding.
const PRESENT_SHED: u8 = 0b1000;

/// Upper bound on any single string length — a corrupt length prefix
/// must not trigger a huge allocation before the read fails.
const MAX_STR_LEN: u64 = 1 << 20;

/// Typed errors from the binary reader/writer. Implements
/// `std::error::Error`, so `?` converts it into `anyhow::Error` at
/// call sites that don't match on variants.
#[derive(Debug, PartialEq)]
pub enum BinaryTraceError {
    /// Underlying I/O failure (rendered, since `io::Error: !PartialEq`).
    Io(String),
    /// First four bytes are not `TXBT`.
    BadMagic([u8; 4]),
    /// Header version is not [`VERSION`].
    UnsupportedVersion(u16),
    /// Header flags contain bits this reader does not understand.
    UnsupportedFlags(u16),
    /// Input ended mid-record; `0` names what was being read.
    Truncated(&'static str),
    /// Structurally invalid content (bad tag, varint overflow, ...).
    Corrupt(String),
    /// Input ended cleanly on a record boundary but without a trailer —
    /// the capture was cut off.
    MissingTrailer,
    /// Trailer event count disagrees with the events actually read.
    CountMismatch { declared: u64, read: u64 },
}

impl fmt::Display for BinaryTraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BinaryTraceError::Io(e) => write!(f, "binary trace i/o error: {e}"),
            BinaryTraceError::BadMagic(m) => {
                write!(f, "bad magic {m:02x?}: not a TaxBreak binary trace (expected \"TXBT\")")
            }
            BinaryTraceError::UnsupportedVersion(v) => {
                write!(f, "unsupported binary trace version {v} (this reader supports {VERSION})")
            }
            BinaryTraceError::UnsupportedFlags(fl) => {
                write!(f, "unsupported binary trace flags {fl:#06x} (no flags are defined)")
            }
            BinaryTraceError::Truncated(what) => {
                write!(f, "truncated binary trace while reading {what}")
            }
            BinaryTraceError::Corrupt(what) => write!(f, "corrupt binary trace: {what}"),
            BinaryTraceError::MissingTrailer => {
                write!(f, "binary trace ends without a trailer (truncated capture?)")
            }
            BinaryTraceError::CountMismatch { declared, read } => {
                write!(f, "trailer declares {declared} events but {read} were read")
            }
        }
    }
}

impl std::error::Error for BinaryTraceError {}

impl From<std::io::Error> for BinaryTraceError {
    fn from(e: std::io::Error) -> BinaryTraceError {
        BinaryTraceError::Io(e.to_string())
    }
}

type Result<T> = std::result::Result<T, BinaryTraceError>;

/// Stable wire code for each event kind. The exhaustive match makes a
/// new `EventKind` variant a compile error here; extend the §10.3 table
/// in `docs/trace_format.md` together with this function.
pub fn kind_code(kind: EventKind) -> u8 {
    match kind {
        EventKind::TorchOp => 0,
        EventKind::AtenOp => 1,
        EventKind::RuntimeApi => 2,
        EventKind::Kernel => 3,
        EventKind::Nvtx => 4,
        EventKind::Arrival => 5,
        EventKind::RngDraw => 6,
        EventKind::SchedDecision => 7,
        EventKind::ClockJump => 8,
        EventKind::Fault => 9,
    }
}

pub fn kind_from_code(code: u8) -> Result<EventKind> {
    Ok(match code {
        0 => EventKind::TorchOp,
        1 => EventKind::AtenOp,
        2 => EventKind::RuntimeApi,
        3 => EventKind::Kernel,
        4 => EventKind::Nvtx,
        5 => EventKind::Arrival,
        6 => EventKind::RngDraw,
        7 => EventKind::SchedDecision,
        8 => EventKind::ClockJump,
        9 => EventKind::Fault,
        other => {
            return Err(BinaryTraceError::Corrupt(format!(
                "unknown event kind code {other}"
            )))
        }
    })
}

// ---------------------------------------------------------------------------
// Encoding primitives
// ---------------------------------------------------------------------------

fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_varint(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn encode_meta(buf: &mut Vec<u8>, meta: &TraceMeta) {
    buf.push(TAG_META);
    put_str(buf, &meta.platform);
    put_str(buf, &meta.model);
    put_str(buf, &meta.phase);
    put_varint(buf, meta.batch as u64);
    put_varint(buf, meta.seq as u64);
    put_varint(buf, meta.m_tokens as u64);
}

fn encode_args(buf: &mut Vec<u8>, args: &ReplayArgs) {
    match args {
        ReplayArgs::Arrival {
            req,
            plen,
            max_new,
            model,
        } => {
            put_varint(buf, *req);
            put_varint(buf, *plen);
            put_varint(buf, *max_new);
            put_str(buf, model);
        }
        ReplayArgs::RngDraw { site, value } => {
            put_str(buf, site);
            put_f64(buf, *value);
        }
        ReplayArgs::SchedDecision {
            step,
            admitted,
            preempted,
            shed,
            batch,
        } => {
            put_varint(buf, *step);
            put_varint(buf, admitted.len() as u64);
            for group in admitted {
                put_varint(buf, group.len() as u64);
                for &id in group {
                    put_varint(buf, id);
                }
            }
            put_varint(buf, preempted.len() as u64);
            for &id in preempted {
                put_varint(buf, id);
            }
            // Spec v4: the shed list is written only when non-empty,
            // signaled by the PRESENT_SHED bit (decoders of spec-v3
            // records never see it).
            if !shed.is_empty() {
                put_varint(buf, shed.len() as u64);
                for &id in shed {
                    put_varint(buf, id);
                }
            }
            put_varint(buf, *batch);
        }
        ReplayArgs::Fault {
            kind,
            target,
            onset_us,
            dur_us,
            magnitude,
        } => {
            put_str(buf, kind);
            put_str(buf, target);
            put_f64(buf, *onset_us);
            put_f64(buf, *dur_us);
            put_f64(buf, *magnitude);
        }
    }
}

fn encode_event(buf: &mut Vec<u8>, ev: &TraceEvent) {
    buf.push(TAG_EVENT);
    buf.push(kind_code(ev.kind));
    let mut presence = 0u8;
    if ev.device.is_some() {
        presence |= PRESENT_DEVICE;
    }
    if ev.meta.is_some() {
        presence |= PRESENT_META;
    }
    if ev.args.is_some() {
        presence |= PRESENT_ARGS;
    }
    if matches!(&ev.args, Some(ReplayArgs::SchedDecision { shed, .. }) if !shed.is_empty()) {
        presence |= PRESENT_SHED;
    }
    buf.push(presence);
    put_str(buf, &ev.name);
    put_f64(buf, ev.ts_us);
    put_f64(buf, ev.dur_us);
    put_varint(buf, ev.correlation_id);
    put_varint(
        buf,
        match ev.track {
            Track::Host => 0,
            Track::Device(s) => s as u64 + 1,
        },
    );
    if let Some(d) = ev.device {
        put_varint(buf, d as u64);
    }
    if let Some(args) = &ev.args {
        encode_args(buf, args);
    }
    if let Some(m) = &ev.meta {
        put_str(buf, &m.kernel_name);
        put_str(buf, &m.family);
        put_str(buf, &m.aten_op);
        put_str(buf, &m.shapes_key);
        for g in m.grid {
            put_varint(buf, g as u64);
        }
        for b in m.block {
            put_varint(buf, b as u64);
        }
        buf.push(m.lib_mediated as u8);
        put_f64(buf, m.flops);
        put_f64(buf, m.bytes);
    }
}

fn encode_trailer(buf: &mut Vec<u8>, event_count: u64, wall_us: f64) {
    buf.push(TAG_TRAILER);
    buf.extend_from_slice(&event_count.to_le_bytes());
    buf.extend_from_slice(&wall_us.to_le_bytes());
    buf.extend_from_slice(&END_MAGIC);
}

// ---------------------------------------------------------------------------
// Decoding primitives
// ---------------------------------------------------------------------------

fn get_exact<R: Read>(r: &mut R, buf: &mut [u8], what: &'static str) -> Result<()> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            BinaryTraceError::Truncated(what)
        } else {
            BinaryTraceError::Io(e.to_string())
        }
    })
}

fn get_u8<R: Read>(r: &mut R, what: &'static str) -> Result<u8> {
    let mut b = [0u8; 1];
    get_exact(r, &mut b, what)?;
    Ok(b[0])
}

/// Read one byte, distinguishing clean EOF (`None`) from I/O failure.
fn try_get_u8<R: Read>(r: &mut R) -> Result<Option<u8>> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(b[0])),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(BinaryTraceError::Io(e.to_string())),
        }
    }
}

fn get_varint<R: Read>(r: &mut R, what: &'static str) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = get_u8(r, what)?;
        if shift == 63 && byte > 1 {
            return Err(BinaryTraceError::Corrupt(format!("varint overflow in {what}")));
        }
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            return Err(BinaryTraceError::Corrupt(format!("varint overflow in {what}")));
        }
    }
}

fn get_f64<R: Read>(r: &mut R, what: &'static str) -> Result<f64> {
    let mut b = [0u8; 8];
    get_exact(r, &mut b, what)?;
    Ok(f64::from_le_bytes(b))
}

fn get_str<R: Read>(r: &mut R, what: &'static str) -> Result<String> {
    let len = get_varint(r, what)?;
    if len > MAX_STR_LEN {
        return Err(BinaryTraceError::Corrupt(format!(
            "string length {len} in {what} exceeds the {MAX_STR_LEN}-byte cap"
        )));
    }
    let mut bytes = vec![0u8; len as usize];
    get_exact(r, &mut bytes, what)?;
    String::from_utf8(bytes)
        .map_err(|_| BinaryTraceError::Corrupt(format!("invalid UTF-8 in {what}")))
}

/// Upper bound on any single id-list length in a `SchedDecision`
/// payload — same allocation guard as [`MAX_STR_LEN`].
const MAX_LIST_LEN: u64 = 1 << 20;

fn get_len<R: Read>(r: &mut R, what: &'static str) -> Result<usize> {
    let len = get_varint(r, what)?;
    if len > MAX_LIST_LEN {
        return Err(BinaryTraceError::Corrupt(format!(
            "list length {len} in {what} exceeds the {MAX_LIST_LEN}-entry cap"
        )));
    }
    Ok(len as usize)
}

fn decode_args<R: Read>(r: &mut R, kind: EventKind, shed_present: bool) -> Result<ReplayArgs> {
    if shed_present && kind != EventKind::SchedDecision {
        return Err(BinaryTraceError::Corrupt(format!(
            "PRESENT_SHED bit on a '{}' event (only sched_decision sheds)",
            kind.as_str()
        )));
    }
    Ok(match kind {
        EventKind::Arrival => ReplayArgs::Arrival {
            req: get_varint(r, "arrival req")?,
            plen: get_varint(r, "arrival plen")?,
            max_new: get_varint(r, "arrival max_new")?,
            model: get_str(r, "arrival model")?,
        },
        EventKind::RngDraw => ReplayArgs::RngDraw {
            site: get_str(r, "rng_draw site")?,
            value: get_f64(r, "rng_draw value")?,
        },
        EventKind::SchedDecision => {
            let step = get_varint(r, "sched_decision step")?;
            let n_groups = get_len(r, "sched_decision group count")?;
            let mut admitted = Vec::with_capacity(n_groups.min(64));
            for _ in 0..n_groups {
                let n = get_len(r, "sched_decision group size")?;
                let mut group = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    group.push(get_varint(r, "sched_decision admitted id")?);
                }
                admitted.push(group);
            }
            let n_pre = get_len(r, "sched_decision preempted count")?;
            let mut preempted = Vec::with_capacity(n_pre.min(1024));
            for _ in 0..n_pre {
                preempted.push(get_varint(r, "sched_decision preempted id")?);
            }
            let shed = if shed_present {
                let n_shed = get_len(r, "sched_decision shed count")?;
                if n_shed == 0 {
                    return Err(BinaryTraceError::Corrupt(
                        "PRESENT_SHED bit with an empty shed list".to_string(),
                    ));
                }
                let mut shed = Vec::with_capacity(n_shed.min(1024));
                for _ in 0..n_shed {
                    shed.push(get_varint(r, "sched_decision shed id")?);
                }
                shed
            } else {
                Vec::new()
            };
            ReplayArgs::SchedDecision {
                step,
                admitted,
                preempted,
                shed,
                batch: get_varint(r, "sched_decision batch")?,
            }
        }
        EventKind::Fault => ReplayArgs::Fault {
            kind: get_str(r, "fault kind")?,
            target: get_str(r, "fault target")?,
            onset_us: get_f64(r, "fault onset_us")?,
            dur_us: get_f64(r, "fault dur_us")?,
            magnitude: get_f64(r, "fault magnitude")?,
        },
        other => {
            return Err(BinaryTraceError::Corrupt(format!(
                "event kind '{}' cannot carry an args payload",
                other.as_str()
            )))
        }
    })
}

fn decode_event<R: Read>(r: &mut R) -> Result<TraceEvent> {
    let kind = kind_from_code(get_u8(r, "event kind")?)?;
    let presence = get_u8(r, "event presence flags")?;
    if presence & !(PRESENT_DEVICE | PRESENT_META | PRESENT_ARGS | PRESENT_SHED) != 0 {
        return Err(BinaryTraceError::Corrupt(format!(
            "unknown presence bits {presence:#04x}"
        )));
    }
    if presence & PRESENT_SHED != 0 && presence & PRESENT_ARGS == 0 {
        return Err(BinaryTraceError::Corrupt(
            "PRESENT_SHED bit without an args payload".to_string(),
        ));
    }
    let name = get_str(r, "event name")?;
    let ts_us = get_f64(r, "event ts")?;
    let dur_us = get_f64(r, "event dur")?;
    let correlation_id = get_varint(r, "event corr")?;
    let track = match get_varint(r, "event track")? {
        0 => Track::Host,
        s => Track::Device((s - 1) as u32),
    };
    let device = if presence & PRESENT_DEVICE != 0 {
        Some(get_varint(r, "event device")? as u32)
    } else {
        None
    };
    let args = if presence & PRESENT_ARGS != 0 {
        Some(decode_args(r, kind, presence & PRESENT_SHED != 0)?)
    } else if kind.has_args() {
        return Err(BinaryTraceError::Corrupt(format!(
            "'{}' event lacks its args payload",
            kind.as_str()
        )));
    } else {
        None
    };
    let meta = if presence & PRESENT_META != 0 {
        let kernel_name = get_str(r, "meta kernel_name")?;
        let family = get_str(r, "meta family")?;
        let aten_op = get_str(r, "meta aten_op")?;
        let shapes_key = get_str(r, "meta shapes_key")?;
        let mut grid = [0u32; 3];
        for g in &mut grid {
            *g = get_varint(r, "meta grid")? as u32;
        }
        let mut block = [0u32; 3];
        for b in &mut block {
            *b = get_varint(r, "meta block")? as u32;
        }
        let lib = match get_u8(r, "meta lib")? {
            0 => false,
            1 => true,
            other => {
                return Err(BinaryTraceError::Corrupt(format!(
                    "meta lib byte must be 0 or 1, got {other}"
                )))
            }
        };
        Some(KernelMeta {
            kernel_name: kernel_name.into(),
            family: family.into(),
            aten_op: aten_op.into(),
            shapes_key: shapes_key.into(),
            grid,
            block,
            lib_mediated: lib,
            flops: get_f64(r, "meta flops")?,
            bytes: get_f64(r, "meta bytes")?,
        })
    } else {
        None
    };
    Ok(TraceEvent {
        kind,
        name,
        ts_us,
        dur_us,
        correlation_id,
        track,
        device,
        args,
        meta,
    })
}

// ---------------------------------------------------------------------------
// Streaming writer
// ---------------------------------------------------------------------------

/// Streaming append writer: one event is encoded into a reusable
/// scratch buffer and flushed to the underlying `Write` at a time, so
/// memory stays O(largest single event) regardless of event count.
pub struct BinaryTraceWriter<W: Write> {
    w: W,
    scratch: Vec<u8>,
    events_written: u64,
    peak_buffered_bytes: usize,
    finished: bool,
}

impl<W: Write> BinaryTraceWriter<W> {
    /// Write the header + meta record. `meta.wall_us` is ignored here —
    /// the wall-clock goes into the trailer at [`finish`](Self::finish).
    pub fn new(mut w: W, meta: &TraceMeta) -> Result<BinaryTraceWriter<W>> {
        let mut scratch = Vec::with_capacity(256);
        scratch.extend_from_slice(&MAGIC);
        scratch.extend_from_slice(&VERSION.to_le_bytes());
        scratch.extend_from_slice(&FLAGS.to_le_bytes());
        encode_meta(&mut scratch, meta);
        w.write_all(&scratch)?;
        let peak = scratch.len();
        Ok(BinaryTraceWriter {
            w,
            scratch,
            events_written: 0,
            peak_buffered_bytes: peak,
            finished: false,
        })
    }

    /// Encode and flush one event.
    pub fn event(&mut self, ev: &TraceEvent) -> Result<()> {
        debug_assert!(!self.finished, "event() after finish()");
        self.scratch.clear();
        encode_event(&mut self.scratch, ev);
        self.peak_buffered_bytes = self.peak_buffered_bytes.max(self.scratch.len());
        self.w.write_all(&self.scratch)?;
        self.events_written += 1;
        Ok(())
    }

    /// Write the trailer (event count + wall-clock + end magic) and
    /// flush. Idempotent: the trailer is written once.
    pub fn finish(&mut self, wall_us: f64) -> Result<()> {
        if self.finished {
            return Ok(());
        }
        self.scratch.clear();
        encode_trailer(&mut self.scratch, self.events_written, wall_us);
        self.w.write_all(&self.scratch)?;
        self.w.flush()?;
        self.finished = true;
        Ok(())
    }

    pub fn events_written(&self) -> u64 {
        self.events_written
    }

    /// High-water mark of the scratch buffer — the writer's entire
    /// event-dependent memory footprint (tests assert it is O(1) in
    /// event count).
    pub fn peak_buffered_bytes(&self) -> usize {
        self.peak_buffered_bytes
    }

    pub fn into_inner(self) -> W {
        self.w
    }
}

// ---------------------------------------------------------------------------
// Streaming reader
// ---------------------------------------------------------------------------

/// Streaming reader: yields events one at a time without materializing
/// the file. `meta().wall_us` is 0 until the trailer has been reached
/// (it is stored at the end of the file); once `next_event` returns
/// `Ok(None)` the wall is available.
pub struct BinaryTraceReader<R: Read> {
    r: R,
    meta: TraceMeta,
    events_read: u64,
    wall_us: Option<f64>,
    done: bool,
}

impl<R: Read> BinaryTraceReader<R> {
    /// Parse the header + meta record.
    pub fn new(mut r: R) -> Result<BinaryTraceReader<R>> {
        let mut magic = [0u8; 4];
        get_exact(&mut r, &mut magic, "magic")?;
        if magic != MAGIC {
            return Err(BinaryTraceError::BadMagic(magic));
        }
        let mut half = [0u8; 2];
        get_exact(&mut r, &mut half, "version")?;
        let version = u16::from_le_bytes(half);
        if version != VERSION {
            return Err(BinaryTraceError::UnsupportedVersion(version));
        }
        get_exact(&mut r, &mut half, "flags")?;
        let flags = u16::from_le_bytes(half);
        if flags != FLAGS {
            return Err(BinaryTraceError::UnsupportedFlags(flags));
        }
        let tag = get_u8(&mut r, "meta record tag")?;
        if tag != TAG_META {
            return Err(BinaryTraceError::Corrupt(format!(
                "expected meta record tag {TAG_META:#04x}, got {tag:#04x}"
            )));
        }
        let meta = TraceMeta {
            platform: get_str(&mut r, "meta platform")?,
            model: get_str(&mut r, "meta model")?,
            phase: get_str(&mut r, "meta phase")?,
            batch: get_varint(&mut r, "meta batch")? as usize,
            seq: get_varint(&mut r, "meta seq")? as usize,
            m_tokens: get_varint(&mut r, "meta m_tokens")? as usize,
            wall_us: 0.0,
        };
        Ok(BinaryTraceReader {
            r,
            meta,
            events_read: 0,
            wall_us: None,
            done: false,
        })
    }

    /// Metadata from the header. `wall_us` is back-filled from the
    /// trailer once the stream is exhausted.
    pub fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    /// Wall-clock from the trailer; `None` until the stream has been
    /// fully consumed.
    pub fn wall_us(&self) -> Option<f64> {
        self.wall_us
    }

    pub fn events_read(&self) -> u64 {
        self.events_read
    }

    /// Next event, or `Ok(None)` once the (validated) trailer has been
    /// reached. A stream that ends without a trailer, declares a wrong
    /// event count, or carries a malformed record yields a typed error
    /// — never a silent partial parse.
    pub fn next_event(&mut self) -> Result<Option<TraceEvent>> {
        if self.done {
            return Ok(None);
        }
        match try_get_u8(&mut self.r)? {
            None => Err(BinaryTraceError::MissingTrailer),
            Some(TAG_EVENT) => {
                let ev = decode_event(&mut self.r)?;
                self.events_read += 1;
                Ok(Some(ev))
            }
            Some(TAG_TRAILER) => {
                let mut b8 = [0u8; 8];
                get_exact(&mut self.r, &mut b8, "trailer event count")?;
                let declared = u64::from_le_bytes(b8);
                get_exact(&mut self.r, &mut b8, "trailer wall_us")?;
                let wall = f64::from_le_bytes(b8);
                let mut end = [0u8; 4];
                get_exact(&mut self.r, &mut end, "trailer end magic")?;
                if end != END_MAGIC {
                    return Err(BinaryTraceError::Corrupt(format!(
                        "trailer end magic {end:02x?} != \"TXBE\""
                    )));
                }
                if declared != self.events_read {
                    return Err(BinaryTraceError::CountMismatch {
                        declared,
                        read: self.events_read,
                    });
                }
                self.meta.wall_us = wall;
                self.wall_us = Some(wall);
                self.done = true;
                Ok(None)
            }
            Some(tag) => Err(BinaryTraceError::Corrupt(format!(
                "unknown record tag {tag:#04x}"
            ))),
        }
    }

    /// Drain the remaining events into a full [`Trace`].
    pub fn into_trace(mut self) -> Result<Trace> {
        let mut events = Vec::new();
        while let Some(ev) = self.next_event()? {
            events.push(ev);
        }
        Ok(Trace {
            meta: self.meta,
            events,
        })
    }

    /// Crash salvage: recover the longest valid event *prefix* of a
    /// stream whose tail is truncated, trailer-less or corrupt
    /// (`taxbreak convert --salvage`).
    ///
    /// Unlike [`into_trace`](Self::into_trace), a malformed tail does
    /// not fail the read — the scan stops at the first undecodable
    /// record and reports why. Events are only ever appended *whole*
    /// ([`decode_event`] either returns a complete event or an error),
    /// so salvage never yields a partial event; the every-prefix
    /// property test pins this. A validated trailer marks the capture
    /// `complete` and back-fills `wall_us`; anything else leaves
    /// `wall_us` 0 (the capture never learned its wall-clock).
    pub fn salvage(mut self) -> SalvageOutcome {
        let mut events = Vec::new();
        let (complete, reason) = loop {
            match self.next_event() {
                Ok(Some(ev)) => events.push(ev),
                Ok(None) => break (true, "complete (trailer validated)".to_string()),
                Err(e) => break (false, e.to_string()),
            }
        };
        SalvageOutcome {
            trace: Trace {
                meta: self.meta,
                events,
            },
            complete,
            reason,
        }
    }
}

/// What [`BinaryTraceReader::salvage`] recovered.
#[derive(Debug)]
pub struct SalvageOutcome {
    /// The recovered event prefix (every event is complete).
    pub trace: Trace,
    /// Did the stream end with a validated trailer (nothing was lost)?
    pub complete: bool,
    /// Why the scan stopped: the trailer validation note, or the
    /// rendered decode error that cut the recovery short.
    pub reason: String,
}

impl SalvageOutcome {
    pub fn recovered(&self) -> usize {
        self.trace.events.len()
    }
}

/// Salvage a whole byte buffer. The header + meta record must still be
/// intact — without them there is no trace to attach events to — but
/// any event-stream damage past that point degrades to a shorter
/// recovered prefix instead of an error. Trailing bytes after a valid
/// trailer are reported in `reason` rather than rejected.
pub fn salvage(bytes: &[u8]) -> Result<SalvageOutcome> {
    let mut cursor = std::io::Cursor::new(bytes);
    let reader = BinaryTraceReader::new(&mut cursor)?;
    let mut out = reader.salvage();
    if out.complete && (cursor.position() as usize) < bytes.len() {
        out.reason = format!(
            "complete (trailer validated; {} trailing bytes ignored)",
            bytes.len() - cursor.position() as usize
        );
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Whole-buffer helpers + dialect detection
// ---------------------------------------------------------------------------

/// Does this byte prefix look like a binary trace?
pub fn is_binary(bytes: &[u8]) -> bool {
    bytes.len() >= MAGIC.len() && bytes[..MAGIC.len()] == MAGIC
}

/// Encode a whole trace to bytes (header, meta, events, trailer).
pub fn encode(trace: &Trace) -> Vec<u8> {
    // Writing to a Vec cannot fail.
    let mut w =
        BinaryTraceWriter::new(Vec::new(), &trace.meta).expect("Vec write is infallible");
    for ev in &trace.events {
        w.event(ev).expect("Vec write is infallible");
    }
    w.finish(trace.meta.wall_us).expect("Vec write is infallible");
    w.into_inner()
}

/// Decode a whole trace from bytes, rejecting trailing garbage.
pub fn decode(bytes: &[u8]) -> Result<Trace> {
    let mut cursor = std::io::Cursor::new(bytes);
    let mut reader = BinaryTraceReader::new(&mut cursor)?;
    let mut events = Vec::new();
    while let Some(ev) = reader.next_event()? {
        events.push(ev);
    }
    let meta = reader.meta().clone();
    if (cursor.position() as usize) < bytes.len() {
        return Err(BinaryTraceError::Corrupt(format!(
            "{} trailing bytes after trailer",
            bytes.len() - cursor.position() as usize
        )));
    }
    Ok(Trace { meta, events })
}

/// The two on-disk dialects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dialect {
    Json,
    Binary,
}

impl Dialect {
    pub fn as_str(self) -> &'static str {
        match self {
            Dialect::Json => "json",
            Dialect::Binary => "binary",
        }
    }

    /// Detect the dialect of a byte buffer by magic.
    pub fn sniff(bytes: &[u8]) -> Dialect {
        if is_binary(bytes) {
            Dialect::Binary
        } else {
            Dialect::Json
        }
    }

    /// Dialect implied by a path's extension (`.tbt` ⇒ binary).
    pub fn of_path(path: &Path) -> Dialect {
        match path.extension().and_then(|e| e.to_str()) {
            Some(e) if e.eq_ignore_ascii_case(EXTENSION) => Dialect::Binary,
            _ => Dialect::Json,
        }
    }
}

/// What `convert` did, for reporting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ConvertStats {
    pub events: usize,
    pub from: Dialect,
    pub to: Dialect,
    pub in_bytes: usize,
    pub out_bytes: usize,
}

/// Convert a trace file between dialects. Input dialect is detected by
/// magic; output dialect follows `to`, defaulting to the output path's
/// extension. JSON output uses the canonical compact encoding, so
/// JSON → binary → JSON round-trips byte-identically.
pub fn convert(input: &Path, output: &Path, to: Option<Dialect>) -> anyhow::Result<ConvertStats> {
    let bytes = std::fs::read(input)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", input.display()))?;
    let from = Dialect::sniff(&bytes);
    let trace = match from {
        Dialect::Binary => decode(&bytes)?,
        Dialect::Json => {
            let text = std::str::from_utf8(&bytes)
                .map_err(|e| anyhow::anyhow!("{} is not UTF-8 JSON: {e}", input.display()))?;
            Trace::from_json(&crate::util::json::Json::parse(text)?)?
        }
    };
    let to = to.unwrap_or_else(|| Dialect::of_path(output));
    let out = match to {
        Dialect::Binary => encode(&trace),
        Dialect::Json => trace.to_json().dump().into_bytes(),
    };
    std::fs::write(output, &out)
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", output.display()))?;
    Ok(ConvertStats {
        events: trace.events.len(),
        from,
        to,
        in_bytes: bytes.len(),
        out_bytes: out.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn varint_roundtrip(v: u64) {
        let mut buf = Vec::new();
        put_varint(&mut buf, v);
        assert!(buf.len() <= 10);
        let mut r = std::io::Cursor::new(&buf);
        assert_eq!(get_varint(&mut r, "test").unwrap(), v);
        assert_eq!(r.position() as usize, buf.len());
    }

    #[test]
    fn varint_edges() {
        for v in [0, 1, 127, 128, 255, 300, 16383, 16384, u32::MAX as u64, u64::MAX] {
            varint_roundtrip(v);
        }
    }

    #[test]
    fn varint_overflow_is_corrupt() {
        // 11 continuation bytes can never be a valid u64.
        let bytes = [0xffu8; 11];
        let mut r = std::io::Cursor::new(&bytes[..]);
        assert!(matches!(
            get_varint(&mut r, "test"),
            Err(BinaryTraceError::Corrupt(_))
        ));
        // 10 bytes whose last byte carries bits past 2^64.
        let mut bytes = vec![0x80u8; 9];
        bytes.push(0x02);
        let mut r = std::io::Cursor::new(&bytes[..]);
        assert!(matches!(
            get_varint(&mut r, "test"),
            Err(BinaryTraceError::Corrupt(_))
        ));
    }

    #[test]
    fn f64_bit_patterns_roundtrip() {
        for v in [0.0, -0.0, 1.5, f64::MIN_POSITIVE, f64::MAX, f64::NAN] {
            let mut buf = Vec::new();
            put_f64(&mut buf, v);
            let mut r = std::io::Cursor::new(&buf);
            let back = get_f64(&mut r, "test").unwrap();
            assert_eq!(back.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn header_errors_are_typed() {
        assert_eq!(
            BinaryTraceReader::new(&b"NOPE"[..]).err(),
            Some(BinaryTraceError::BadMagic(*b"NOPE"))
        );
        let mut v2 = Vec::new();
        v2.extend_from_slice(&MAGIC);
        v2.extend_from_slice(&2u16.to_le_bytes());
        v2.extend_from_slice(&0u16.to_le_bytes());
        assert_eq!(
            BinaryTraceReader::new(&v2[..]).err(),
            Some(BinaryTraceError::UnsupportedVersion(2))
        );
        let mut fl = Vec::new();
        fl.extend_from_slice(&MAGIC);
        fl.extend_from_slice(&VERSION.to_le_bytes());
        fl.extend_from_slice(&0x0001u16.to_le_bytes());
        assert_eq!(
            BinaryTraceReader::new(&fl[..]).err(),
            Some(BinaryTraceError::UnsupportedFlags(1))
        );
        assert_eq!(
            BinaryTraceReader::new(&b"TX"[..]).err(),
            Some(BinaryTraceError::Truncated("magic"))
        );
    }

    #[test]
    fn shed_list_rides_a_presence_bit_and_stays_v3_compatible() {
        let mk = |shed: Vec<u64>| TraceEvent {
            kind: EventKind::SchedDecision,
            name: "sched_decision".to_string(),
            ts_us: 1.0,
            dur_us: 0.0,
            correlation_id: 0,
            track: Track::Host,
            device: None,
            args: Some(ReplayArgs::SchedDecision {
                step: 3,
                admitted: vec![vec![1, 2]],
                preempted: vec![4],
                shed,
                batch: 2,
            }),
            meta: None,
        };
        // Empty shed: encoding is byte-identical to a record that never
        // heard of the field (the presence bit stays clear).
        let mut with = Vec::new();
        encode_event(&mut with, &mk(vec![]));
        assert_eq!(with[2] & PRESENT_SHED, 0, "empty shed must not set the bit");
        // Non-empty shed round-trips through the bit.
        let mut buf = Vec::new();
        encode_event(&mut buf, &mk(vec![7, 9]));
        assert_ne!(buf[2] & PRESENT_SHED, 0);
        let mut r = std::io::Cursor::new(&buf[1..]); // skip the record tag
        let back = decode_event(&mut r).unwrap();
        assert_eq!(back, mk(vec![7, 9]));
    }

    #[test]
    fn fault_args_roundtrip_with_exact_bit_patterns() {
        let ev = TraceEvent {
            kind: EventKind::Fault,
            name: "fault".to_string(),
            ts_us: 100.0,
            dur_us: 0.0,
            correlation_id: 0,
            track: Track::Host,
            device: Some(1),
            args: Some(ReplayArgs::Fault {
                kind: "device_stall".to_string(),
                target: "stream:1".to_string(),
                onset_us: 100.0,
                dur_us: 0.1 + 0.2, // not exactly 0.3: bit pattern must survive
                magnitude: 3.5,
            }),
            meta: None,
        };
        let mut buf = Vec::new();
        encode_event(&mut buf, &ev);
        assert_eq!(buf[1], 9, "fault kind-code is 9");
        let mut r = std::io::Cursor::new(&buf[1..]);
        assert_eq!(decode_event(&mut r).unwrap(), ev);
    }

    #[test]
    fn salvage_recovers_the_longest_valid_prefix() {
        let meta = TraceMeta {
            platform: "h200".into(),
            model: "gpt2".into(),
            phase: "serve".into(),
            batch: 0,
            seq: 0,
            m_tokens: 0,
            wall_us: 42.0,
        };
        let mut trace = Trace::new(meta);
        for i in 0..5u64 {
            trace.push(TraceEvent {
                kind: EventKind::Nvtx,
                name: format!("r{i}"),
                ts_us: i as f64,
                dur_us: 1.0,
                correlation_id: i,
                track: Track::Host,
                device: None,
                args: None,
                meta: None,
            });
        }
        let bytes = encode(&trace);
        // Complete: everything, trailer validated, wall back-filled.
        let ok = salvage(&bytes).unwrap();
        assert!(ok.complete);
        assert_eq!(ok.recovered(), 5);
        assert_eq!(ok.trace.meta.wall_us, 42.0);
        // Trailer cut off: all events survive, reason says truncated.
        let cut = salvage(&bytes[..bytes.len() - TRAILER_LEN]).unwrap();
        assert!(!cut.complete);
        assert_eq!(cut.recovered(), 5);
        assert_eq!(cut.trace.events, trace.events);
        assert_eq!(cut.trace.meta.wall_us, 0.0, "wall never learned");
        // Cut mid-event: only whole events survive.
        let mid = salvage(&bytes[..bytes.len() - TRAILER_LEN - 3]).unwrap();
        assert!(!mid.complete);
        assert_eq!(mid.recovered(), 4);
        assert_eq!(mid.trace.events, trace.events[..4]);
        // Headerless bytes cannot be salvaged at all.
        assert!(salvage(b"NOPE").is_err());
    }

    #[test]
    fn string_length_cap_guards_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&FLAGS.to_le_bytes());
        buf.push(TAG_META);
        put_varint(&mut buf, u64::MAX); // platform length: absurd
        assert!(matches!(
            BinaryTraceReader::new(&buf[..]).err(),
            Some(BinaryTraceError::Corrupt(_))
        ));
    }
}
