//! Export traces to the chrome://tracing / Perfetto JSON array format,
//! so captured runs can be inspected visually (nsys-timeline analog).

use crate::trace::{Trace, Track};
use crate::util::json::Json;

/// Chrome trace "complete" events ("ph": "X"), one per trace event,
/// preceded by a process-name metadata event ("ph": "M") labeling the
/// run (`model phase @ platform`) so side-by-side comparisons — e.g. a
/// captured loadgen run vs its `taxbreak whatif` counterfactual replay
/// — stay tellable apart in the Perfetto UI. Host events go to tid 0;
/// device stream `s` to tid `100 + s`.
pub fn to_chrome_json(trace: &Trace) -> Json {
    let mut events = Vec::with_capacity(trace.events.len() + 1);
    let label = format!(
        "{} {} @ {}",
        trace.meta.model, trace.meta.phase, trace.meta.platform
    );
    events.push(
        Json::obj()
            .with("name", "process_name")
            .with("ph", "M")
            .with("pid", 1u32)
            .with("tid", 0u32)
            .with("args", Json::obj().with("name", label.as_str())),
    );
    for e in &trace.events {
        let tid = match e.track {
            Track::Host => 0u32,
            Track::Device(s) => 100 + s,
        };
        let cat = e.kind.as_str();
        let mut args = Json::obj().with("correlation", e.correlation_id);
        if let Some(meta) = &e.meta {
            args.set("family", meta.family.as_str());
            args.set("aten_op", meta.aten_op.as_str());
            args.set("lib", meta.lib_mediated);
        }
        events.push(
            Json::obj()
                .with("name", e.name.as_str())
                .with("cat", cat)
                .with("ph", "X")
                .with("ts", e.ts_us)
                .with("dur", e.dur_us)
                .with("pid", 1u32)
                .with("tid", tid)
                .with("args", args),
        );
    }
    Json::Arr(events)
}

/// Write the chrome trace to a file.
pub fn save_chrome(trace: &Trace, path: &std::path::Path) -> anyhow::Result<()> {
    std::fs::write(path, to_chrome_json(trace).dump())
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{EventKind, TraceEvent, TraceMeta};

    #[test]
    fn exports_tracks_and_cats() {
        let mut t = Trace::new(TraceMeta::default());
        t.push(TraceEvent {
            kind: EventKind::RuntimeApi,
            name: "cudaLaunchKernel".into(),
            ts_us: 0.0,
            dur_us: 1.0,
            correlation_id: 1,
            track: Track::Host,
            meta: None,
        });
        t.push(TraceEvent {
            kind: EventKind::Kernel,
            name: "gemm".into(),
            ts_us: 5.0,
            dur_us: 2.0,
            correlation_id: 1,
            track: Track::Device(3),
            meta: None,
        });
        let j = to_chrome_json(&t);
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        // Leading process-name metadata event labels the run.
        assert_eq!(arr[0].str_of("ph").unwrap(), "M");
        assert_eq!(arr[0].str_of("name").unwrap(), "process_name");
        assert_eq!(arr[1].f64_of("tid").unwrap(), 0.0);
        assert_eq!(arr[2].f64_of("tid").unwrap(), 103.0);
        assert_eq!(arr[2].str_of("cat").unwrap(), "kernel");
        assert_eq!(arr[1].str_of("ph").unwrap(), "X");
    }
}
