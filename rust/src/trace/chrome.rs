//! Export traces to the chrome://tracing / Perfetto JSON array format,
//! so captured runs can be inspected visually (nsys-timeline analog).

use crate::trace::{Trace, Track};
use crate::util::json::Json;

/// One Perfetto counter track: a named series of `(ts_us, value)`
/// points, rendered as chrome "C" events under the trace's pid (e.g.
/// the per-window HDBI and KV-occupancy series from a metrics-enabled
/// loadgen run — docs/trace_format.md §7).
pub struct CounterSeries {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Chrome tid for one event: host threads and device streams get
/// disjoint, per-device lanes. Device `d`'s host thread maps to
/// `1000*d` (so the default device keeps the historical tid 0) and its
/// stream `s` to `1000*d + 100 + s` (device 0 stream `s` keeps the
/// historical `100 + s`).
fn tid_of(track: Track, device: u32) -> u32 {
    match track {
        Track::Host => 1000 * device,
        Track::Device(s) => 1000 * device + 100 + s,
    }
}

/// Human label for one tid (the `thread_name` metadata payload).
fn thread_label(track: Track, device: u32) -> String {
    match track {
        Track::Host => format!("host (dev {device})"),
        Track::Device(s) => format!("dev {device} stream {s}"),
    }
}

/// Chrome trace "complete" events ("ph": "X"), one per trace event,
/// preceded by metadata events ("ph": "M"): a process-name labeling the
/// run (`model phase @ platform`) so side-by-side comparisons — e.g. a
/// captured loadgen run vs its `taxbreak whatif` counterfactual replay
/// — stay tellable apart in the Perfetto UI, then one `thread_name`
/// per distinct tid (first-appearance order) so multi-stream /
/// multi-device timelines render as labeled lanes instead of every
/// kernel collapsing onto an anonymous tid.
pub fn to_chrome_json(trace: &Trace) -> Json {
    to_chrome_json_with_counters(trace, &[])
}

/// [`to_chrome_json`] plus counter tracks: each [`CounterSeries`]
/// appends its points as "C" events (tid 0) after the "X" events, so
/// Perfetto renders them as value-over-time lanes below the timeline.
pub fn to_chrome_json_with_counters(trace: &Trace, counters: &[CounterSeries]) -> Json {
    let n_points: usize = counters.iter().map(|c| c.points.len()).sum();
    let mut events = Vec::with_capacity(trace.events.len() + n_points + 4);
    let label = format!(
        "{} {} @ {}",
        trace.meta.model, trace.meta.phase, trace.meta.platform
    );
    events.push(
        Json::obj()
            .with("name", "process_name")
            .with("ph", "M")
            .with("pid", 1u32)
            .with("tid", 0u32)
            .with("args", Json::obj().with("name", label.as_str())),
    );
    // One thread_name metadata event per distinct tid, in the order the
    // tid first appears in the event stream.
    let mut seen: Vec<u32> = Vec::new();
    for e in &trace.events {
        let tid = tid_of(e.track, e.device_id());
        if seen.contains(&tid) {
            continue;
        }
        seen.push(tid);
        events.push(
            Json::obj()
                .with("name", "thread_name")
                .with("ph", "M")
                .with("pid", 1u32)
                .with("tid", tid)
                .with(
                    "args",
                    Json::obj().with("name", thread_label(e.track, e.device_id()).as_str()),
                ),
        );
    }
    for e in &trace.events {
        let tid = tid_of(e.track, e.device_id());
        let cat = e.kind.as_str();
        let mut args = Json::obj().with("correlation", e.correlation_id);
        if let Some(meta) = &e.meta {
            args.set("family", meta.family.as_str());
            args.set("aten_op", meta.aten_op.as_str());
            args.set("lib", meta.lib_mediated);
        }
        events.push(
            Json::obj()
                .with("name", e.name.as_str())
                .with("cat", cat)
                .with("ph", "X")
                .with("ts", e.ts_us)
                .with("dur", e.dur_us)
                .with("pid", 1u32)
                .with("tid", tid)
                .with("args", args),
        );
    }
    for c in counters {
        for &(ts, value) in &c.points {
            events.push(
                Json::obj()
                    .with("name", c.name.as_str())
                    .with("ph", "C")
                    .with("ts", ts)
                    .with("pid", 1u32)
                    .with("tid", 0u32)
                    .with("args", Json::obj().with(c.name.as_str(), value)),
            );
        }
    }
    Json::Arr(events)
}

/// Write the chrome trace to a file.
pub fn save_chrome(trace: &Trace, path: &std::path::Path) -> anyhow::Result<()> {
    save_chrome_with_counters(trace, &[], path)
}

/// Write the chrome trace plus counter tracks to a file.
pub fn save_chrome_with_counters(
    trace: &Trace,
    counters: &[CounterSeries],
    path: &std::path::Path,
) -> anyhow::Result<()> {
    std::fs::write(path, to_chrome_json_with_counters(trace, counters).dump())
        .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{EventKind, TraceEvent, TraceMeta};

    #[test]
    fn exports_tracks_cats_and_thread_names() {
        let mut t = Trace::new(TraceMeta::default());
        t.push(TraceEvent {
            kind: EventKind::RuntimeApi,
            name: "cudaLaunchKernel".into(),
            ts_us: 0.0,
            dur_us: 1.0,
            correlation_id: 1,
            track: Track::Host,
            device: None,
            args: None,
            meta: None,
        });
        t.push(TraceEvent {
            kind: EventKind::Kernel,
            name: "gemm".into(),
            ts_us: 5.0,
            dur_us: 2.0,
            correlation_id: 1,
            track: Track::Device(3),
            device: None,
            args: None,
            meta: None,
        });
        let j = to_chrome_json(&t);
        let arr = j.as_arr().unwrap();
        // process_name + one thread_name per distinct tid + 2 events.
        assert_eq!(arr.len(), 5);
        assert_eq!(arr[0].str_of("ph").unwrap(), "M");
        assert_eq!(arr[0].str_of("name").unwrap(), "process_name");
        assert_eq!(arr[1].str_of("name").unwrap(), "thread_name");
        assert_eq!(arr[1].f64_of("tid").unwrap(), 0.0);
        assert_eq!(
            arr[1].req("args").unwrap().str_of("name").unwrap(),
            "host (dev 0)"
        );
        assert_eq!(arr[2].str_of("name").unwrap(), "thread_name");
        assert_eq!(arr[2].f64_of("tid").unwrap(), 103.0);
        assert_eq!(
            arr[2].req("args").unwrap().str_of("name").unwrap(),
            "dev 0 stream 3"
        );
        assert_eq!(arr[3].f64_of("tid").unwrap(), 0.0);
        assert_eq!(arr[3].str_of("ph").unwrap(), "X");
        assert_eq!(arr[4].f64_of("tid").unwrap(), 103.0);
        assert_eq!(arr[4].str_of("cat").unwrap(), "kernel");
    }

    #[test]
    fn counter_series_append_c_events_after_the_timeline() {
        let mut t = Trace::new(TraceMeta::default());
        t.push(TraceEvent {
            kind: EventKind::Kernel,
            name: "k".into(),
            ts_us: 0.0,
            dur_us: 1.0,
            correlation_id: 1,
            track: Track::Device(0),
            device: None,
            args: None,
            meta: None,
        });
        let counters = [CounterSeries {
            name: "hdbi".into(),
            points: vec![(0.0, 0.4), (50.0, 0.8)],
        }];
        let j = to_chrome_json_with_counters(&t, &counters);
        let arr = j.as_arr().unwrap();
        // process_name + thread_name + 1 X event + 2 C events.
        assert_eq!(arr.len(), 5);
        let c = &arr[3];
        assert_eq!(c.str_of("ph").unwrap(), "C");
        assert_eq!(c.str_of("name").unwrap(), "hdbi");
        assert_eq!(c.f64_of("ts").unwrap(), 0.0);
        assert_eq!(c.req("args").unwrap().f64_of("hdbi").unwrap(), 0.4);
        assert_eq!(arr[4].f64_of("ts").unwrap(), 50.0);
        assert_eq!(arr[4].req("args").unwrap().f64_of("hdbi").unwrap(), 0.8);
        // The no-counter entry point is the counters == [] special case.
        assert_eq!(
            to_chrome_json(&t).dump(),
            to_chrome_json_with_counters(&t, &[]).dump()
        );
    }

    #[test]
    fn devices_map_to_disjoint_tid_lanes() {
        let mut t = Trace::new(TraceMeta::default());
        for dev in [0u32, 1, 2] {
            t.push(TraceEvent {
                kind: EventKind::Kernel,
                name: "k".into(),
                ts_us: 0.0,
                dur_us: 1.0,
                correlation_id: 1 + dev as u64,
                track: Track::Device(0),
                device: (dev > 0).then_some(dev),
                args: None,
                meta: None,
            });
        }
        let j = to_chrome_json(&t);
        let arr = j.as_arr().unwrap();
        // 1 process_name + 3 thread_names + 3 events.
        assert_eq!(arr.len(), 7);
        let tids: Vec<f64> = arr[4..].iter().map(|e| e.f64_of("tid").unwrap()).collect();
        assert_eq!(tids, vec![100.0, 1100.0, 2100.0]);
        assert_eq!(
            arr[2].req("args").unwrap().str_of("name").unwrap(),
            "dev 1 stream 0"
        );
    }
}
