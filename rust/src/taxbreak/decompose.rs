//! The Eq. 1/2/3 decomposition: per-invocation components summed into
//! `T_Orchestration`, per-family slices, HDBI and the derived metrics.

use std::collections::BTreeMap;

use crate::taxbreak::phase1::Phase1;
use crate::taxbreak::phase2::Phase2Result;
use crate::trace::Trace;

/// Eq. 3 (HDBI) on one host/device time pair — the **single** HDBI
/// implementation in the crate ([`Decomposition::hdbi`], the serving
/// reports and the what-if engine all call it).
///
/// Empty-run convention: when nothing was observed on either side
/// (`host + device == 0`), the run is neither host- nor device-bound,
/// so the balance index is defined as the midpoint `0.5`.
pub fn hdbi_of(host_us: f64, device_us: f64) -> f64 {
    let total = host_us + device_us;
    if total == 0.0 {
        0.5
    } else {
        device_us / total
    }
}

/// Per-family slice of the decomposition.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FamilySlice {
    pub invocations: usize,
    pub t_py_us: f64,
    pub t_base_us: f64,
    pub dct_us: f64,
    pub dkt_us: f64,
    pub device_us: f64,
}

impl FamilySlice {
    pub fn orchestration_us(&self) -> f64 {
        self.t_py_us + self.t_base_us + self.dct_us + self.dkt_us
    }
}

/// Per-device slice of the decomposition: every Eq. 1 component of the
/// invocations whose kernel ran on that device (the dispatching host
/// thread's cost is attributed to the rank it serves — SPMD tensor
/// parallelism runs one dispatch thread per device).
///
/// The slices **partition** the aggregate: summed over devices they
/// reproduce [`Decomposition`]'s totals component-by-component (pinned
/// by `rust/tests/timeline.rs`), so the aggregate HDBI is always the
/// invocation-weighted combination of the per-device ones.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DeviceSlice {
    pub invocations: usize,
    pub t_py_us: f64,
    pub t_base_us: f64,
    pub dct_us: f64,
    pub dkt_us: f64,
    pub device_active_us: f64,
}

impl DeviceSlice {
    pub fn orchestration_us(&self) -> f64 {
        self.t_py_us + self.t_base_us + self.dct_us + self.dkt_us
    }

    /// Eq. 3 on this device alone — the per-device [`hdbi_of`] variant.
    pub fn hdbi(&self) -> f64 {
        hdbi_of(self.orchestration_us(), self.device_active_us)
    }
}

/// Eq. 1 components aggregated over a run (Eq. 2), plus device-active
/// time and wall-clock (Eq. 3 inputs and Fig. 6's idle fraction).
#[derive(Debug, Clone, Default)]
pub struct Decomposition {
    pub n_kernels: usize,
    /// Σ T_Py (measured per-invocation in Phase 1).
    pub t_py_us: f64,
    /// Σ T_dispatch_base (Phase-2 baseline × N).
    pub t_base_us: f64,
    /// Σ I_lib·ΔCT.
    pub dct_us: f64,
    /// Σ ΔKT = N × T_sys_floor.
    pub dkt_us: f64,
    /// Σ kernel execution time.
    pub device_active_us: f64,
    /// Wall-clock latency of the traced region.
    pub e2e_us: f64,
    /// The Phase-2 floor used for ΔKT, us.
    pub floor_us: f64,
    pub per_family: BTreeMap<String, FamilySlice>,
    /// Per-device partition of the run (single-device traces have one
    /// entry under key 0).
    pub per_device: BTreeMap<u32, DeviceSlice>,
}

impl Decomposition {
    /// ΔFT = Σ (T_Py + T_dispatch_base)  (framework translation).
    pub fn dft_us(&self) -> f64 {
        self.t_py_us + self.t_base_us
    }

    /// Eq. 2: T_Orchestration.
    pub fn orchestration_us(&self) -> f64 {
        self.dft_us() + self.dct_us + self.dkt_us
    }

    /// Eq. 3: HDBI ∈ (0, 1). → 0 host-bound; → 1 device-bound.
    pub fn hdbi(&self) -> f64 {
        hdbi_of(self.orchestration_us(), self.device_active_us)
    }

    /// GPU idle fraction (Fig. 6): (T_e2e − T_DeviceActive)/T_e2e,
    /// generalized to multi-device runs — the available GPU time is
    /// `e2e × n_devices` (every device spans the same wall-clock), so
    /// N-device traces don't clamp to a bogus 0% idle when their
    /// summed active time exceeds one wall. Single-device runs reduce
    /// to the paper's definition exactly.
    pub fn idle_fraction(&self) -> f64 {
        let wall = self.e2e_us * self.per_device.len().max(1) as f64;
        if wall <= 0.0 {
            0.0
        } else {
            ((wall - self.device_active_us) / wall).clamp(0.0, 1.0)
        }
    }

    /// GPU utilization (Table II): device-active over wall-clock.
    pub fn gpu_utilization(&self) -> f64 {
        1.0 - self.idle_fraction()
    }

    /// Mean per-kernel host cost (§V-C's ≈13.7 us GPT-2 number).
    pub fn per_kernel_host_us(&self) -> f64 {
        if self.n_kernels == 0 {
            0.0
        } else {
            self.orchestration_us() / self.n_kernels as f64
        }
    }
}

/// Combine Phase-1 per-invocation measurements with Phase-2 replay
/// results into the full decomposition.
///
/// Per invocation *i* with Phase-2 entry *k(i)*:
/// `ΔFT_i = T_Py_i + T_dispatch_base`, `ΔCT_i = dct(k(i))`,
/// `ΔKT_i = T_sys_floor` — exactly Eq. 1's accounting. The raw launch
/// cost `T_launch^raw` stays diagnostic-only (not added — its ΔKT_fw
/// part is framework enqueue overhead already captured by ΔFT/ΔCT).
pub fn decompose(trace: &Trace, p1: &Phase1, p2: &Phase2Result) -> Decomposition {
    let mut d = Decomposition {
        e2e_us: trace.e2e_us(),
        floor_us: p2.floor.mean,
        ..Default::default()
    };
    for inv in &p1.invocations {
        let dct = p2
            .replay_of(inv.dedup_key)
            .map(|k| k.dct_us)
            .unwrap_or(0.0);
        let lib_dct = if inv.lib_mediated { dct } else { 0.0 };

        d.n_kernels += 1;
        d.t_py_us += inv.t_py_us;
        d.t_base_us += p2.dispatch_base_us;
        d.dct_us += lib_dct;
        d.dkt_us += p2.floor.mean;
        d.device_active_us += inv.device_us;

        // The family universe is tiny, so probe by `&str` first and
        // allocate the `String` key only when a family is first seen —
        // O(1) allocations per run, not per invocation.
        let slice = match d.per_family.get_mut(inv.family.as_str()) {
            Some(s) => s,
            None => d.per_family.entry(inv.family.to_string()).or_default(),
        };
        slice.invocations += 1;
        slice.t_py_us += inv.t_py_us;
        slice.t_base_us += p2.dispatch_base_us;
        slice.dct_us += lib_dct;
        slice.dkt_us += p2.floor.mean;
        slice.device_us += inv.device_us;

        let dev = d.per_device.entry(inv.device).or_default();
        dev.invocations += 1;
        dev.t_py_us += inv.t_py_us;
        dev.t_base_us += p2.dispatch_base_us;
        dev.dct_us += lib_dct;
        dev.dkt_us += p2.floor.mean;
        dev.device_active_us += inv.device_us;
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Platform;
    use crate::models;
    use crate::sim::{simulate, Workload};
    use crate::taxbreak::phase2::{run, ReplayConfig, SimReplayBackend};

    fn decompose_model(
        model: &crate::models::ModelSpec,
        platform: Platform,
        wl: &Workload,
    ) -> Decomposition {
        let trace = simulate(model, &platform, wl, 9);
        let p1 = Phase1::from_trace(&trace);
        let mut backend = SimReplayBackend::new(platform, 13);
        let p2 = run(&p1.db, &mut backend, &ReplayConfig::fast());
        decompose(&trace, &p1, &p2)
    }

    #[test]
    fn components_sum_to_orchestration() {
        let d = decompose_model(&models::gpt2(), Platform::h200(), &Workload::prefill(1, 256));
        let total = d.t_py_us + d.t_base_us + d.dct_us + d.dkt_us;
        assert!((total - d.orchestration_us()).abs() < 1e-9);
    }

    #[test]
    fn single_device_run_has_one_device_slice_matching_the_aggregate() {
        let d = decompose_model(&models::gpt2(), Platform::h200(), &Workload::prefill(1, 128));
        assert_eq!(d.per_device.len(), 1);
        let s = d.per_device.get(&0).unwrap();
        assert_eq!(s.invocations, d.n_kernels);
        assert!((s.orchestration_us() - d.orchestration_us()).abs() < 1e-9);
        assert!((s.device_active_us - d.device_active_us).abs() < 1e-9);
        assert!((s.hdbi() - d.hdbi()).abs() < 1e-12);
    }

    #[test]
    fn family_slices_sum_to_totals() {
        let d = decompose_model(&models::llama_1b(), Platform::h100(), &Workload::prefill(1, 128));
        let fam_orch: f64 = d.per_family.values().map(|s| s.orchestration_us()).sum();
        assert!((fam_orch - d.orchestration_us()).abs() < 1e-6);
        let fam_n: usize = d.per_family.values().map(|s| s.invocations).sum();
        assert_eq!(fam_n, d.n_kernels);
    }

    #[test]
    fn hdbi_in_unit_interval_and_monotone_in_device_work() {
        let small = decompose_model(&models::gpt2(), Platform::h200(), &Workload::prefill(1, 128));
        let big = decompose_model(&models::gpt2(), Platform::h200(), &Workload::prefill(16, 512));
        assert!(small.hdbi() > 0.0 && small.hdbi() < 1.0);
        assert!(
            big.hdbi() > small.hdbi(),
            "bigger batch => more device-bound: {} vs {}",
            big.hdbi(),
            small.hdbi()
        );
    }

    #[test]
    fn gpt2_dct_is_zero() {
        let d = decompose_model(&models::gpt2(), Platform::h200(), &Workload::prefill(1, 512));
        assert_eq!(d.dct_us, 0.0, "§V-C: GPT-2 has no vendor-library share");
    }

    #[test]
    fn llama_dct_is_positive() {
        let d = decompose_model(&models::llama_1b(), Platform::h100(), &Workload::prefill(1, 128));
        assert!(d.dct_us > 0.0);
    }

    #[test]
    fn idle_plus_utilization_is_one() {
        let d = decompose_model(&models::gpt2(), Platform::h200(), &Workload::prefill(4, 256));
        assert!((d.idle_fraction() + d.gpu_utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn moe_is_more_host_bound_than_dense() {
        let wl = Workload::decode(1, 256, 2);
        let dense = decompose_model(&models::llama_1b(), Platform::h200(), &wl);
        let moe = decompose_model(&models::olmoe(), Platform::h200(), &wl);
        assert!(
            moe.hdbi() < dense.hdbi(),
            "MoE must be more host-bound: {} vs {}",
            moe.hdbi(),
            dense.hdbi()
        );
    }

    #[test]
    fn per_kernel_host_cost_near_paper_gpt2() {
        let d = decompose_model(&models::gpt2(), Platform::h200(), &Workload::prefill(1, 512));
        let c = d.per_kernel_host_us();
        assert!((c - 13.7).abs() < 1.5, "per-kernel host cost {c} (paper ≈13.7)");
    }

    #[test]
    fn hdbi_of_is_the_single_convention() {
        assert_eq!(hdbi_of(0.0, 0.0), 0.5, "empty run sits at the midpoint");
        assert_eq!(hdbi_of(1.0, 3.0), 0.75);
        assert_eq!(hdbi_of(3.0, 1.0), 0.25);
        assert_eq!(hdbi_of(0.0, 5.0), 1.0);
        assert_eq!(hdbi_of(5.0, 0.0), 0.0);
    }

    #[test]
    fn zero_kernel_trace_decomposes_to_neutral_defaults() {
        // A trace with no kernel events must not NaN or panic anywhere
        // downstream: empty decomposition, midpoint HDBI, zero costs.
        let trace = Trace::default();
        let p1 = Phase1::from_trace(&trace);
        assert!(p1.invocations.is_empty());
        let mut backend = SimReplayBackend::new(Platform::h100(), 3);
        let p2 = crate::taxbreak::phase2::run(&p1.db, &mut backend, &ReplayConfig::fast());
        let d = decompose(&trace, &p1, &p2);
        assert_eq!(d.n_kernels, 0);
        assert_eq!(d.orchestration_us(), 0.0);
        assert_eq!(d.hdbi(), 0.5);
        assert_eq!(d.per_kernel_host_us(), 0.0);
        assert_eq!(d.idle_fraction(), 0.0);
        assert_eq!(d.gpu_utilization(), 1.0);
    }

    #[test]
    fn hdbi_stays_inside_open_unit_interval_for_real_runs() {
        for (model, wl) in [
            (models::gpt2(), Workload::prefill(1, 64)),
            (models::olmoe(), Workload::decode(1, 64, 2)),
        ] {
            let d = decompose_model(&model, Platform::h100(), &wl);
            let h = d.hdbi();
            assert!(h > 0.0 && h < 1.0, "{}: hdbi={h}", model.name);
        }
    }

    #[test]
    fn idle_fraction_scales_available_time_by_device_count() {
        // 2 devices, each active 60us over a 100us wall: summed active
        // 120us exceeds one wall but the run is 40% idle per device.
        let mut d = Decomposition {
            n_kernels: 2,
            device_active_us: 120.0,
            e2e_us: 100.0,
            ..Default::default()
        };
        d.per_device.insert(0, DeviceSlice { device_active_us: 60.0, ..Default::default() });
        d.per_device.insert(1, DeviceSlice { device_active_us: 60.0, ..Default::default() });
        assert!((d.idle_fraction() - 0.4).abs() < 1e-12);
        assert!((d.gpu_utilization() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn idle_fraction_clamps_inconsistent_inputs() {
        // Device time exceeding wall-clock (possible with clock skew in
        // real traces) clamps to zero idle, never negative.
        let d = Decomposition {
            n_kernels: 1,
            device_active_us: 200.0,
            e2e_us: 100.0,
            ..Default::default()
        };
        assert_eq!(d.idle_fraction(), 0.0);
        assert_eq!(d.gpu_utilization(), 1.0);
        // Non-positive wall-clock is treated as "no idle observed".
        let z = Decomposition {
            e2e_us: 0.0,
            device_active_us: 5.0,
            ..Default::default()
        };
        assert_eq!(z.idle_fraction(), 0.0);
    }
}
