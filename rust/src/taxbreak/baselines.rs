//! Prior-work baseline metrics that TaxBreak is compared against.
//!
//! * **Framework tax** [14]: the aggregate host residual
//!   `T_e2e − T_DeviceActive` — tells you *that* something is wrong,
//!   not *where* (Fig. 2-left).
//! * **TKLQT** [30]: total kernel launch and queue time,
//!   `Σ (t_kernel_start − t_api_call)` — launch path plus queue delay,
//!   so it blows up once the GPU saturates (Fig. 7a) while HDBI stays
//!   interpretable.

use crate::trace::Trace;

/// Both baseline metrics for one trace.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Baselines {
    /// Aggregate framework tax, us: e2e minus device-active [14].
    pub framework_tax_us: f64,
    /// Total kernel launch + queue time, us [30].
    pub tklqt_us: f64,
    /// Queue-only share of TKLQT (delay beyond the launch gap whenever
    /// the stream was still busy).
    pub queue_share: f64,
    pub n_kernels: usize,
}

/// Compute the baselines from a trace.
pub fn compute(trace: &Trace) -> Baselines {
    let chains = trace.correlation_chains();
    let mut tklqt = 0.0f64;
    let mut min_gap = f64::INFINITY;
    let mut gaps: Vec<f64> = Vec::new();
    for c in chains.values() {
        if let (Some(api), Some(kernel)) = (c.runtime_api, c.kernel) {
            let gap = (kernel.ts_us - api.ts_us).max(0.0);
            tklqt += gap;
            min_gap = min_gap.min(gap);
            gaps.push(gap);
        }
    }
    // Queue share: everything above the observed minimum gap (the
    // best-case launch path) is attributed to queueing.
    let queue = if min_gap.is_finite() {
        gaps.iter().map(|g| g - min_gap).sum::<f64>()
    } else {
        0.0
    };
    Baselines {
        framework_tax_us: (trace.e2e_us() - trace.device_active_us()).max(0.0),
        tklqt_us: tklqt,
        queue_share: if tklqt > 0.0 { queue / tklqt } else { 0.0 },
        n_kernels: gaps.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Platform;
    use crate::models;
    use crate::sim::{simulate, Workload};

    #[test]
    fn framework_tax_is_residual() {
        let t = simulate(
            &models::gpt2(),
            &Platform::h200(),
            &Workload::prefill(1, 256),
            3,
        );
        let b = compute(&t);
        assert!((b.framework_tax_us - (t.e2e_us() - t.device_active_us())).abs() < 1e-9);
        assert!(b.framework_tax_us > 0.0);
    }

    #[test]
    fn tklqt_counts_all_kernels() {
        let t = simulate(
            &models::gpt2(),
            &Platform::h200(),
            &Workload::prefill(1, 256),
            3,
        );
        let b = compute(&t);
        assert_eq!(b.n_kernels, t.kernel_count());
        // Per-kernel gap ≥ floor ≈ 4.5us.
        assert!(b.tklqt_us > 4.0 * b.n_kernels as f64);
    }

    #[test]
    fn tklqt_rises_with_gpu_saturation() {
        // Fig. 7a: queue delay appears at large batch; TKLQT rises much
        // faster than the kernel count.
        let p = Platform::h200();
        let m = models::gpt2();
        let small = compute(&simulate(&m, &p, &Workload::prefill(1, 512), 3));
        let big = compute(&simulate(&m, &p, &Workload::prefill(16, 512), 3));
        let per_small = small.tklqt_us / small.n_kernels as f64;
        let per_big = big.tklqt_us / big.n_kernels as f64;
        assert!(
            per_big > 1.5 * per_small,
            "saturated TKLQT/kernel {per_big} vs {per_small}"
        );
        assert!(big.queue_share > small.queue_share);
    }

    #[test]
    fn empty_trace() {
        let b = compute(&Trace::default());
        assert_eq!(b.n_kernels, 0);
        assert_eq!(b.tklqt_us, 0.0);
    }
}
