//! Phase 1: full-model trace analysis (paper §III-B).
//!
//! From the profiled iteration we extract, per kernel invocation, the
//! Python-side dispatch overhead `T_Py = t_aten_op − t_torch_op` (the
//! time before execution reaches the ATen C++ layer), and build the
//! *kernel database* of unique kernels (cleaned name, launch config,
//! ATen metadata, invocation frequency, `I_lib` classification).

use crate::kernels::KernelDb;
use crate::trace::{DedupKey, EventKind, Trace};
use crate::util::intern::Sym;

/// One kernel invocation's Phase-1 measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Invocation {
    /// Index into the trace's kernel events (invocation order).
    pub correlation_id: u64,
    /// Dedup key into the kernel database (`Copy` — no per-invocation
    /// string formatting on the hot extraction path).
    pub dedup_key: DedupKey,
    /// Measured T_Py for this invocation, us.
    pub t_py_us: f64,
    /// Kernel family tag (interned).
    pub family: Sym,
    /// `I_lib`.
    pub lib_mediated: bool,
    /// Device execution time, us.
    pub device_us: f64,
    /// Launch-path interval (api call → kernel start: launch + queue).
    pub launch_plus_queue_us: f64,
    /// Device (rank) the kernel ran on — `0` for single-device traces,
    /// the stamped `TraceEvent::device` for multi-device producers.
    /// Drives the per-device decomposition slices.
    pub device: u32,
}

/// Phase-1 output: per-invocation measurements + the kernel database.
#[derive(Debug, Clone, Default)]
pub struct Phase1 {
    pub invocations: Vec<Invocation>,
    pub db: KernelDb,
}

impl Phase1 {
    pub fn from_trace(trace: &Trace) -> Phase1 {
        let chains = trace.correlation_chains();
        let mut corr_ids: Vec<u64> = chains
            .iter()
            .filter(|(_, c)| c.kernel.is_some())
            .map(|(&id, _)| id)
            .collect();
        corr_ids.sort();

        let mut invocations = Vec::with_capacity(corr_ids.len());
        let mut db = KernelDb::new();
        for id in corr_ids {
            let chain = &chains[&id];
            let kernel = chain.kernel.expect("filtered for kernels");
            let meta = match &kernel.meta {
                Some(m) => m,
                None => continue, // kernels without metadata are skipped
            };
            db.record(meta, kernel.dur_us);

            // T_Py: torch-op start -> aten-op start. Falls back to 0
            // when either event is missing (e.g. partial traces).
            let t_py = match (chain.torch_op, chain.aten_op) {
                (Some(t), Some(a)) => (a.ts_us - t.ts_us).max(0.0),
                _ => 0.0,
            };
            let launch_plus_queue = match chain.runtime_api {
                Some(api) => (kernel.ts_us - api.ts_us).max(0.0),
                None => 0.0,
            };
            invocations.push(Invocation {
                correlation_id: id,
                dedup_key: meta.dedup(),
                t_py_us: t_py,
                family: meta.family,
                lib_mediated: meta.lib_mediated,
                device_us: kernel.dur_us,
                launch_plus_queue_us: launch_plus_queue,
                device: kernel.device_id(),
            });
        }
        Phase1 { invocations, db }
    }

    /// Σ T_Py over all invocations.
    pub fn total_t_py_us(&self) -> f64 {
        self.invocations.iter().map(|i| i.t_py_us).sum()
    }

    /// Kernels per generated token (Table II).
    pub fn kernels_per_token(&self, m_tokens: usize) -> f64 {
        self.invocations.len() as f64 / m_tokens.max(1) as f64
    }
}

/// Quick structural check that a trace is analyzable (every kernel has
/// a runtime-api parent; host events are present).
pub fn validate_trace(trace: &Trace) -> anyhow::Result<()> {
    let chains = trace.correlation_chains();
    let mut kernels = 0usize;
    let mut orphans = 0usize;
    for c in chains.values() {
        if let Some(_k) = c.kernel {
            kernels += 1;
            if c.runtime_api.is_none() {
                orphans += 1;
            }
        }
    }
    anyhow::ensure!(kernels > 0, "trace contains no kernel events");
    anyhow::ensure!(
        orphans == 0,
        "{orphans}/{kernels} kernels lack a runtime-api event"
    );
    let has_host = trace
        .events
        .iter()
        .any(|e| e.kind == EventKind::TorchOp || e.kind == EventKind::AtenOp);
    anyhow::ensure!(has_host, "trace lacks host-side operator events");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Platform;
    use crate::models;
    use crate::sim::{simulate, Workload};

    fn gpt2_trace() -> Trace {
        simulate(
            &models::gpt2(),
            &Platform::h200(),
            &Workload::prefill(1, 128),
            3,
        )
    }

    #[test]
    fn invocations_match_kernel_count() {
        let t = gpt2_trace();
        let p1 = Phase1::from_trace(&t);
        assert_eq!(p1.invocations.len(), t.kernel_count());
        assert_eq!(p1.db.total_invocations(), t.kernel_count());
    }

    #[test]
    fn invocations_are_in_launch_order() {
        let p1 = Phase1::from_trace(&gpt2_trace());
        for w in p1.invocations.windows(2) {
            assert!(w[0].correlation_id < w[1].correlation_id);
        }
    }

    #[test]
    fn t_py_positive_and_plausible() {
        let p1 = Phase1::from_trace(&gpt2_trace());
        for inv in &p1.invocations {
            assert!(inv.t_py_us > 0.0);
            assert!(inv.t_py_us < 50.0, "t_py={} too large", inv.t_py_us);
        }
    }

    #[test]
    fn launch_plus_queue_at_least_floor() {
        let p1 = Phase1::from_trace(&gpt2_trace());
        for inv in &p1.invocations {
            assert!(
                inv.launch_plus_queue_us > 3.0,
                "launch path {} below any plausible floor",
                inv.launch_plus_queue_us
            );
        }
    }

    #[test]
    fn validate_accepts_sim_traces() {
        validate_trace(&gpt2_trace()).unwrap();
    }

    #[test]
    fn validate_rejects_empty() {
        assert!(validate_trace(&Trace::default()).is_err());
    }

    #[test]
    fn db_dedup_is_effective() {
        // 12 identical layers => far fewer unique kernels than launches.
        let p1 = Phase1::from_trace(&gpt2_trace());
        assert!(p1.db.len() * 3 < p1.db.total_invocations());
    }

    #[test]
    fn kernels_per_token() {
        let t = simulate(
            &models::gpt2(),
            &Platform::h200(),
            &Workload::decode(1, 64, 5),
            3,
        );
        let p1 = Phase1::from_trace(&t);
        let per_tok = p1.kernels_per_token(5);
        assert!((per_tok - t.kernel_count() as f64 / 5.0).abs() < 1e-9);
    }
}
