//! **TaxBreak** — the paper's contribution (§III).
//!
//! A trace-driven decomposition of host-visible orchestration overhead
//! into three mutually exclusive, collectively exhaustive per-kernel
//! components:
//!
//! ```text
//! T_Host = ΔFT + I_lib·ΔCT + ΔKT                       (Eq. 1)
//!   ΔFT = T_Py + T_dispatch_base     framework translation
//!   ΔCT = max(0, T_dispatch − T_dispatch_base)  library front-end
//!   ΔKT = T_sys_floor                launch-path hardware floor
//! T_Orchestration = Σ_i (ΔFT_i + I_lib·ΔCT_i + ΔKT_i)  (Eq. 2)
//! HDBI = T_dev / (T_dev + T_orch)                      (Eq. 3)
//! ```
//!
//! measured in two phases:
//! * **Phase 1** ([`phase1`]): a full-model trace yields per-invocation
//!   `T_Py` and the kernel database;
//! * **Phase 2** ([`phase2`]): a null-kernel run measures the floor,
//!   then each unique kernel is replayed in isolation (deduplicated by
//!   ATen metadata + launch config) to measure `T_dispatch` and
//!   `T_launch` without queue interference, with the Eq. 9 name-matching
//!   fallback for autotuned variant drift ([`matching`]).
//!
//! [`baselines`] implements the two prior-work metrics TaxBreak is
//! compared against (aggregate framework tax [14], TKLQT [30]);
//! [`diagnose`] turns a decomposition into the paper's optimization
//! prescription.

pub mod baselines;
pub mod decompose;
pub mod diagnose;
pub mod matching;
pub mod phase1;
pub mod phase2;
pub mod report;

pub use decompose::{hdbi_of, Decomposition, DeviceSlice, FamilySlice};
pub use diagnose::{diagnose, Diagnosis, OptimizationTarget, QuantifiedAdvice};
pub use phase1::Phase1;
pub use phase2::{Phase2Result, ReplayBackend, ReplayConfig, SimReplayBackend};

use crate::trace::Trace;

/// Full TaxBreak analysis of one trace.
#[derive(Debug, Clone)]
pub struct Analysis {
    pub phase1: Phase1,
    pub phase2: Phase2Result,
    pub decomposition: Decomposition,
    pub baselines: baselines::Baselines,
    pub diagnosis: Diagnosis,
}

/// Run the complete two-phase pipeline on a trace.
pub fn analyze(trace: &Trace, backend: &mut dyn ReplayBackend, cfg: &ReplayConfig) -> Analysis {
    let phase1 = Phase1::from_trace(trace);
    let phase2 = phase2::run(&phase1.db, backend, cfg);
    let decomposition = decompose::decompose(trace, &phase1, &phase2);
    let baselines = baselines::compute(trace);
    let diagnosis = diagnose(&decomposition);
    Analysis {
        phase1,
        phase2,
        decomposition,
        baselines,
        diagnosis,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::Platform;
    use crate::models;
    use crate::sim::{simulate, Workload};

    #[test]
    fn end_to_end_analysis_runs() {
        let trace = simulate(
            &models::gpt2(),
            &Platform::h200(),
            &Workload::prefill(1, 128),
            1,
        );
        let platform = Platform::h200();
        let mut backend = SimReplayBackend::new(platform, 7);
        let a = analyze(&trace, &mut backend, &ReplayConfig::fast());
        assert_eq!(a.decomposition.n_kernels, trace.kernel_count());
        let hdbi = a.decomposition.hdbi();
        assert!(hdbi > 0.0 && hdbi < 1.0, "hdbi={hdbi}");
        assert!(a.decomposition.orchestration_us() > 0.0);
        // Components are mutually exclusive & collectively exhaustive:
        let d = &a.decomposition;
        let total = d.dft_us() + d.dct_us + d.dkt_us;
        assert!((total - d.orchestration_us()).abs() < 1e-6);
    }
}
