//! The diagnostic interpretation of a decomposition (paper §III):
//! turn HDBI + the component breakdown into an optimization
//! prescription.
//!
//! * host-bound + ΔFT/ΔCT dominant → optimize the software stack
//!   (torch.compile, library dispatch paths);
//! * host-bound + N·T_sys_floor dominant → reduce kernel count
//!   (fusion);
//! * host-bound + large ΔKT_fw → amortize the driver/runtime path
//!   (CUDA Graphs, persistent kernels);
//! * device-bound → optimize device-side work (better kernels,
//!   memory traffic).

use crate::taxbreak::decompose::Decomposition;

/// HDBI below this is treated as host-bound (the paper's CPU-effect
/// gate sits near ≈0.3; we use 0.5 as the balance midpoint for target
/// selection and report the raw HDBI alongside).
pub const HOST_BOUND_HDBI: f64 = 0.5;

/// Where optimization effort should go.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizationTarget {
    /// Python dispatch + library front-end dominates: compile/runtime
    /// work (torch.compile, dispatch-path streamlining).
    SoftwareStack,
    /// Launch-floor cost scales with N: fuse kernels.
    KernelFusion,
    /// Device-side work dominates: kernel/memory optimization.
    DeviceWork,
}

impl OptimizationTarget {
    pub fn as_str(&self) -> &'static str {
        match self {
            OptimizationTarget::SoftwareStack => "software-stack",
            OptimizationTarget::KernelFusion => "kernel-fusion",
            OptimizationTarget::DeviceWork => "device-work",
        }
    }
}

/// The quantified backing of a prescription: the best counterfactual
/// replay for the diagnosed target and its predicted deltas.  Filled in
/// by the what-if engine (`crate::whatif::quantify_diagnosis`) — a bare
/// [`diagnose`] call leaves it `None` because quantification needs the
/// replayable schedule, not just the component sums.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantifiedAdvice {
    /// Counterfactual spec that backs the number ("host-cpu:xeon-6538y").
    pub counterfactual: String,
    /// Predicted relative T_Orchestration reduction (positive = less
    /// orchestration; a negative value would mean the counterfactual
    /// grows it, e.g. a device swap raising the launch floor).
    pub orch_reduction: f64,
    /// Predicted relative end-to-end latency reduction. The quantifier
    /// only attaches advice with a strictly positive value.
    pub e2e_reduction: f64,
}

impl QuantifiedAdvice {
    pub fn render(&self) -> String {
        // Signed deltas (negative = time removed), so a reduction of
        // 0.17 prints as "-17.0%" and a regression can never render as
        // a garbled double negative.
        format!(
            "{}: {:+.1}% T_Orchestration, {:+.1}% e2e (counterfactual replay)",
            self.counterfactual,
            -100.0 * self.orch_reduction,
            -100.0 * self.e2e_reduction
        )
    }
}

/// A diagnosis: boundedness + dominant component + prescription.
#[derive(Debug, Clone)]
pub struct Diagnosis {
    pub hdbi: f64,
    pub host_bound: bool,
    pub target: OptimizationTarget,
    /// Share of T_Orchestration per component: (ΔFT, ΔCT, ΔKT).
    pub shares: (f64, f64, f64),
    pub rationale: String,
    /// Best counterfactual for `target`, quantified by schedule replay
    /// (`taxbreak whatif`); `None` until the what-if engine attaches it.
    pub quantified: Option<QuantifiedAdvice>,
}

/// Diagnose a decomposition (paper §III "Diagnostic interpretation").
pub fn diagnose(d: &Decomposition) -> Diagnosis {
    let hdbi = d.hdbi();
    let orch = d.orchestration_us().max(1e-12);
    let shares = (d.dft_us() / orch, d.dct_us / orch, d.dkt_us / orch);
    let host_bound = hdbi < HOST_BOUND_HDBI;

    let (target, rationale) = if !host_bound {
        (
            OptimizationTarget::DeviceWork,
            format!(
                "HDBI={hdbi:.2} (device-bound): reduce device-side work \
                 (e.g. fused attention cuts HBM traffic — Fig. 9)"
            ),
        )
    } else if shares.0 + shares.1 >= shares.2 {
        (
            OptimizationTarget::SoftwareStack,
            format!(
                "HDBI={hdbi:.2} (host-bound), ΔFT+ΔCT = {:.0}% of T_Orch: \
                 bottleneck is Python dispatch / library front-end — \
                 target runtime compilation or dispatch paths",
                100.0 * (shares.0 + shares.1)
            ),
        )
    } else {
        (
            OptimizationTarget::KernelFusion,
            format!(
                "HDBI={hdbi:.2} (host-bound), N·T_sys_floor = {:.0}% of \
                 T_Orch: cost scales with kernel count — fuse kernels \
                 (or amortize the launch path with CUDA Graphs)",
                100.0 * shares.2
            ),
        )
    };
    Diagnosis {
        hdbi,
        host_bound,
        target,
        shares,
        rationale,
        quantified: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decomp(py: f64, base: f64, ct: f64, kt: f64, dev: f64) -> Decomposition {
        Decomposition {
            n_kernels: 100,
            t_py_us: py,
            t_base_us: base,
            dct_us: ct,
            dkt_us: kt,
            device_active_us: dev,
            e2e_us: py + base + ct + kt + dev,
            floor_us: 4.7,
            per_family: Default::default(),
            per_device: Default::default(),
        }
    }

    #[test]
    fn device_bound_targets_device() {
        let d = decomp(10.0, 50.0, 0.0, 40.0, 10_000.0);
        let dg = diagnose(&d);
        assert!(!dg.host_bound);
        assert_eq!(dg.target, OptimizationTarget::DeviceWork);
    }

    #[test]
    fn host_bound_software_stack() {
        let d = decomp(400.0, 500.0, 200.0, 100.0, 50.0);
        let dg = diagnose(&d);
        assert!(dg.host_bound);
        assert_eq!(dg.target, OptimizationTarget::SoftwareStack);
    }

    #[test]
    fn host_bound_floor_dominated_prescribes_fusion() {
        let d = decomp(50.0, 100.0, 0.0, 900.0, 50.0);
        let dg = diagnose(&d);
        assert_eq!(dg.target, OptimizationTarget::KernelFusion);
        assert!(dg.rationale.contains("fuse"));
    }

    #[test]
    fn shares_sum_to_one() {
        let d = decomp(100.0, 200.0, 50.0, 150.0, 1.0);
        let dg = diagnose(&d);
        let s = dg.shares.0 + dg.shares.1 + dg.shares.2;
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn hdbi_boundary() {
        // Exactly balanced: hdbi == 0.5 counts as device-bound side.
        let d = decomp(0.0, 500.0, 0.0, 500.0, 1000.0);
        let dg = diagnose(&d);
        assert!(!dg.host_bound);
    }
}
